"""Sharded, atomic, mesh-agnostic checkpointing.

Layout: ``<dir>/step_<N>/`` holding one ``.npy`` per pytree leaf (path-
encoded filename) plus ``manifest.json`` (tree structure, shapes, dtypes,
step). Writes go to ``step_<N>.tmp`` and are renamed only when complete, so
a killed run never leaves a half checkpoint (the fault-injection test kills
mid-run and restarts).

Checkpoints store *global* host arrays, not device layouts, so restore can
re-shard onto a different mesh (elastic scaling: the 8->4 device test).
``CheckpointManager`` adds async saves (a background thread overlaps
serialization with compute) and retention.
"""
from __future__ import annotations

import json
import os
import re
import shutil
import threading
from typing import Optional

import jax
import ml_dtypes
import numpy as np


def _dtype_from_name(name: str) -> np.dtype:
    """numpy dtype from name, including ml_dtypes (bfloat16, fp8...)."""
    try:
        return np.dtype(name)
    except TypeError:
        return np.dtype(getattr(ml_dtypes, name))


def _leaf_files(tree) -> list:
    leaves, treedef = jax.tree.flatten(tree)
    paths = jax.tree_util.tree_flatten_with_path(tree)[0]
    names = []
    for (path, _leaf) in paths:
        name = "_".join(re.sub(r"[^A-Za-z0-9_]", "", str(p)) for p in path)
        names.append(name or "leaf")
    # Disambiguate duplicates deterministically.
    seen: dict = {}
    out = []
    for n in names:
        k = seen.get(n, 0)
        seen[n] = k + 1
        out.append(f"{n}__{k}.npy")
    return out, leaves, treedef


def save_pytree(path: str, tree, step: int, extra: Optional[dict] = None) -> str:
    final = os.path.join(path, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)
    files, leaves, treedef = _leaf_files(tree)
    dtypes = []
    for fname, leaf in zip(files, leaves):
        arr = np.asarray(leaf)
        dtypes.append(arr.dtype.name)
        np.save(os.path.join(tmp, fname), arr)
    manifest = {
        "step": step,
        "files": files,
        "dtypes": dtypes,
        "treedef": str(treedef),
        "extra": extra or {},
    }
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.replace(tmp, final)
    return final


def latest_step(path: str) -> Optional[int]:
    if not os.path.isdir(path):
        return None
    steps = [
        int(m.group(1))
        for d in os.listdir(path)
        if (m := re.fullmatch(r"step_(\d+)", d))
    ]
    return max(steps) if steps else None


def restore_pytree(path: str, like, step: Optional[int] = None, shardings=None):
    """Restore into the structure of ``like`` (params/state template).

    ``shardings``: optional NamedSharding tree — arrays are device_put with
    it, which is how an elastic restart re-shards onto a new mesh."""
    if step is None:
        step = latest_step(path)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {path}")
    d = os.path.join(path, f"step_{step:08d}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    files, _leaves, treedef = _leaf_files(like)
    assert files == manifest["files"], "checkpoint/template structure mismatch"
    arrays = []
    for fname, dtype_name in zip(files, manifest["dtypes"]):
        arr = np.load(os.path.join(d, fname))
        want = _dtype_from_name(dtype_name)
        if arr.dtype != want:  # np.save stores ml_dtypes as raw void
            arr = arr.view(want)
        arrays.append(arr)
    tree = jax.tree.unflatten(treedef, arrays)
    if shardings is not None:
        tree = jax.tree.map(lambda a, s: jax.device_put(a, s), tree, shardings)
    return tree, step, manifest["extra"]


class CheckpointManager:
    """Async saves + retention."""

    def __init__(self, path: str, keep: int = 3):
        self.path = path
        self.keep = keep
        self._pending: Optional[threading.Thread] = None
        os.makedirs(path, exist_ok=True)

    def save(self, tree, step: int, extra: Optional[dict] = None, blocking: bool = False):
        self.wait()
        host_tree = jax.tree.map(np.asarray, tree)  # snapshot before async

        def work():
            save_pytree(self.path, host_tree, step, extra)
            self._gc()

        if blocking:
            work()
        else:
            self._pending = threading.Thread(target=work, daemon=True)
            self._pending.start()

    def wait(self):
        if self._pending is not None:
            self._pending.join()
            self._pending = None

    def _gc(self):
        steps = sorted(
            int(m.group(1))
            for d in os.listdir(self.path)
            if (m := re.fullmatch(r"step_(\d+)", d))
        )
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.path, f"step_{s:08d}"), ignore_errors=True)
