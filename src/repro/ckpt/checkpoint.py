"""Sharded, atomic, mesh-agnostic checkpointing.

Layout: ``<dir>/step_<N>/`` holding one ``.npy`` per pytree leaf (path-
encoded filename) plus ``manifest.json`` (tree structure, shapes, dtypes,
per-leaf CRC32, step). Writes go to ``step_<N>.tmp`` and are renamed only
when complete, so a killed run never leaves a half checkpoint (the
fault-injection test kills mid-run and restarts).

Integrity: ``save_pytree`` stamps a CRC32 per leaf into the manifest and
``restore_pytree`` re-checks it on load — bit rot, truncation or an
unreadable manifest raise the typed :class:`CheckpointCorruptError`
instead of a raw numpy/json error. ``restore_pytree_with_fallback``
implements the recovery discipline: quarantine the corrupt step (rename
to ``step_<N>.corrupt`` for postmortem), fall back to the next-newest
retained step, and only give up when none is left.

Checkpoints store *global* host arrays, not device layouts, so restore can
re-shard onto a different mesh (elastic scaling: the 8->4 device test).
``CheckpointManager`` adds async saves (a background thread overlaps
serialization with compute) and retention (``retain=`` newest steps kept,
default 2 so a corrupted latest still has a fallback), with the ordering
contract the overlapped DC-kCore pipeline leans on:

* an async ``save`` snapshots the tree **by value** before returning, so
  the caller may keep mutating its arrays while the write is in flight;
* at most one save is ever in flight per manager (a new ``save`` first
  waits out the previous one — callers from different threads are
  serialized by a lock), and a worker failure is re-raised on the next
  ``wait()``/``save()``/``clear_steps()`` instead of dying silently in
  the thread;
* ``clear_steps`` (the purge path) waits out the pending save before
  removing anything — write-then-rename ordering means a save enqueued
  before a purge is either fully on disk (and then removed) or was never
  started; a purge can never shred a ``.tmp`` a writer is still filling;
* the completed save's own wall time is surfaced (``last_save_seconds`` /
  the ``on_done`` callback), distinct from the time the *caller* was
  blocked, which ``save`` returns — async callers report both.
"""
from __future__ import annotations

import json
import logging
import os
import re
import shutil
import threading
import time
import zlib
from typing import Callable, Optional

import jax
import ml_dtypes
import numpy as np

logger = logging.getLogger(__name__)

# Worker threads of in-flight async saves carry this name prefix; the test
# suite asserts none outlive a test (a leaked thread = a missing wait()).
SAVE_THREAD_PREFIX = "ckpt-save"

# Default retention: the newest step plus one predecessor, so a corrupted
# latest step can fall back instead of restarting from scratch.
DEFAULT_RETAIN = 2


class CheckpointCorruptError(RuntimeError):
    """A checkpoint step failed integrity checks (CRC mismatch, unreadable
    leaf file, or a missing/undecodable manifest)."""


def _dtype_from_name(name: str) -> np.dtype:
    """numpy dtype from name, including ml_dtypes (bfloat16, fp8...)."""
    try:
        return np.dtype(name)
    except TypeError:
        return np.dtype(getattr(ml_dtypes, name))


def _leaf_files(tree) -> list:
    leaves, treedef = jax.tree.flatten(tree)
    paths = jax.tree_util.tree_flatten_with_path(tree)[0]
    names = []
    for (path, _leaf) in paths:
        name = "_".join(re.sub(r"[^A-Za-z0-9_]", "", str(p)) for p in path)
        names.append(name or "leaf")
    # Disambiguate duplicates deterministically.
    seen: dict = {}
    out = []
    for n in names:
        k = seen.get(n, 0)
        seen[n] = k + 1
        out.append(f"{n}__{k}.npy")
    return out, leaves, treedef


def _leaf_crc32(arr: np.ndarray) -> int:
    """CRC32 over the leaf's raw bytes (dtype-view agnostic: computed on
    the array exactly as serialized, before any ml_dtypes re-view)."""
    return zlib.crc32(np.ascontiguousarray(arr).tobytes()) & 0xFFFFFFFF


def save_pytree(path: str, tree, step: int, extra: Optional[dict] = None) -> str:
    final = os.path.join(path, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)
    files, leaves, treedef = _leaf_files(tree)
    dtypes = []
    crcs = []
    for fname, leaf in zip(files, leaves):
        arr = np.asarray(leaf)
        dtypes.append(arr.dtype.name)
        crcs.append(_leaf_crc32(arr))
        np.save(os.path.join(tmp, fname), arr)
    manifest = {
        "step": step,
        "files": files,
        "dtypes": dtypes,
        "crc32": crcs,
        "treedef": str(treedef),
        "extra": extra or {},
    }
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.replace(tmp, final)
    return final


def latest_step(path: str) -> Optional[int]:
    if not os.path.isdir(path):
        return None
    steps = [
        int(m.group(1))
        for d in os.listdir(path)
        if (m := re.fullmatch(r"step_(\d+)", d))
    ]
    return max(steps) if steps else None


def restore_pytree(path: str, like, step: Optional[int] = None, shardings=None):
    """Restore into the structure of ``like`` (params/state template).

    ``shardings``: optional NamedSharding tree — arrays are device_put with
    it, which is how an elastic restart re-shards onto a new mesh.

    Integrity failures (unreadable manifest, unloadable leaf, CRC
    mismatch) raise :class:`CheckpointCorruptError`; a structure mismatch
    against ``like`` is a caller error and still asserts."""
    if step is None:
        step = latest_step(path)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {path}")
    d = os.path.join(path, f"step_{step:08d}")
    try:
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        raise CheckpointCorruptError(
            f"unreadable manifest in {d}: {type(e).__name__}: {e}"
        ) from e
    files, _leaves, treedef = _leaf_files(like)
    assert files == manifest["files"], "checkpoint/template structure mismatch"
    # Pre-CRC checkpoints (older layout) carry no crc32 list — load as-is.
    crcs = manifest.get("crc32") or [None] * len(files)
    arrays = []
    for fname, dtype_name, want_crc in zip(files, manifest["dtypes"], crcs):
        try:
            arr = np.load(os.path.join(d, fname))
        except Exception as e:  # noqa: BLE001 — any load failure = corrupt
            raise CheckpointCorruptError(
                f"unreadable leaf {fname} in {d}: {type(e).__name__}: {e}"
            ) from e
        if want_crc is not None and _leaf_crc32(arr) != want_crc:
            raise CheckpointCorruptError(
                f"CRC mismatch for leaf {fname} in {d} (bit rot or torn write)"
            )
        want = _dtype_from_name(dtype_name)
        if arr.dtype != want:  # np.save stores ml_dtypes as raw void
            arr = arr.view(want)
        arrays.append(arr)
    tree = jax.tree.unflatten(treedef, arrays)
    if shardings is not None:
        tree = jax.tree.map(lambda a, s: jax.device_put(a, s), tree, shardings)
    return tree, step, manifest["extra"]


def quarantine_step(path: str, step: int) -> str:
    """Rename ``step_<N>`` to ``step_<N>.corrupt`` (kept for postmortem).

    The quarantined dir no longer matches the step regex, so
    :func:`latest_step`, retention GC and restore all skip it; purge paths
    (``clear_steps``) still remove it."""
    d = os.path.join(path, f"step_{step:08d}")
    q = d + ".corrupt"
    if os.path.isdir(q):
        shutil.rmtree(q, ignore_errors=True)
    os.replace(d, q)
    return q


def restore_pytree_with_fallback(
    path: str,
    like,
    shardings=None,
    on_corrupt: Optional[Callable[[int, "CheckpointCorruptError"], None]] = None,
):
    """Restore the newest step that passes integrity checks.

    A corrupt step is quarantined (renamed ``.corrupt``), ``on_corrupt``
    is notified, and the next-newest retained step is tried — the same
    fallback discipline ``SweepSnapshot.restore`` uses for stale
    snapshots. Raises ``FileNotFoundError`` when no intact step remains
    (callers fall back to the part boundary / a fresh run)."""
    while True:
        step = latest_step(path)
        if step is None:
            raise FileNotFoundError(f"no intact checkpoints under {path}")
        try:
            return restore_pytree(path, like, step=step, shardings=shardings)
        except CheckpointCorruptError as exc:
            q = quarantine_step(path, step)
            logger.warning(
                "checkpoint step %d corrupt (%s) — quarantined to %s, "
                "falling back to previous retained step", step, exc, q,
            )
            if on_corrupt is not None:
                on_corrupt(step, exc)


class CheckpointManager:
    """Async saves + retention (one save in flight at a time).

    ``retain`` is the number of newest steps kept by the post-save GC
    (``keep`` is the legacy alias); the default of 2 means a corrupted
    latest step can always fall back to its predecessor. Save/wait/purge
    entry points are serialized by a lock, so concurrent callers (e.g. a
    retried lead part racing an abandoned hung attempt) never interleave
    two in-flight saves.
    """

    def __init__(self, path: str, keep: Optional[int] = None,
                 retain: Optional[int] = None):
        if retain is None:
            retain = keep if keep is not None else DEFAULT_RETAIN
        self.path = path
        self.retain = retain
        self.keep = retain  # legacy alias, kept in sync
        self._pending: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None
        self._lock = threading.RLock()
        # Wall seconds of the last COMPLETED save (write + rename + GC) —
        # the honest cost of persisting, as opposed to the time save()'s
        # caller was blocked, which is near zero on the async path.
        self.last_save_seconds: float = 0.0
        os.makedirs(path, exist_ok=True)

    def save(
        self,
        tree,
        step: int,
        extra: Optional[dict] = None,
        blocking: bool = False,
        on_done: Optional[Callable[[int, float], None]] = None,
    ) -> float:
        """Save ``tree`` at ``step``; returns seconds the caller was blocked.

        Blocking: the return value is the full save duration. Async: it
        covers only waiting out a previous pending save plus the host-side
        value snapshot of the tree (the caller may mutate its arrays the
        moment this returns — the write works from the copy); the completed
        write's own duration lands in ``last_save_seconds`` and is passed to
        ``on_done(step, seconds)``, called from the worker thread after the
        atomic rename and retention GC. An ``on_done`` failure is captured
        like a write failure and re-raised on the next entry point.

        A failure of the *previous* async save surfaces here (and on
        ``clear_steps()``), not only on ``wait()`` — an early crash can't
        be masked until the final drain.
        """
        with self._lock:
            t_blocked = time.perf_counter()
            self.wait()
            if blocking:
                host_tree = jax.tree.map(np.asarray, tree)
            else:
                host_tree = jax.tree.map(lambda x: np.array(x, copy=True), tree)

            def work():
                t0 = time.perf_counter()
                try:
                    save_pytree(self.path, host_tree, step, extra)
                    self._gc()
                    self.last_save_seconds = time.perf_counter() - t0
                    if on_done is not None:
                        on_done(step, self.last_save_seconds)
                except BaseException as e:  # surfaced on the next entry point
                    self._error = e

            if blocking:
                work()
                self.wait()  # re-raise a failure immediately on the blocking path
            else:
                self._pending = threading.Thread(
                    target=work, daemon=True,
                    name=f"{SAVE_THREAD_PREFIX}:{os.path.basename(self.path)}:{step}",
                )
                self._pending.start()
            return time.perf_counter() - t_blocked

    def wait(self):
        """Join the in-flight save, re-raising any failure it hit."""
        with self._lock:
            if self._pending is not None:
                self._pending.join()
                self._pending = None
            if self._error is not None:
                err, self._error = self._error, None
                raise err

    def clear_steps(self):
        """Remove every step dir (``.tmp`` and quarantined ``.corrupt``
        included) under ``path``.

        Waits out the pending async save first (re-raising its failure, if
        any): write-then-rename ordering means a save enqueued before this
        purge is fully on disk — and then removed — never torn, and the
        purge can never rmtree a ``.tmp`` the worker is still filling
        (which would kill the save mid-write).
        """
        with self._lock:
            self.wait()
            if not os.path.isdir(self.path):
                return
            for d in os.listdir(self.path):
                if re.fullmatch(r"step_\d+(\.tmp|\.corrupt)?", d):
                    shutil.rmtree(os.path.join(self.path, d), ignore_errors=True)

    def _gc(self):
        steps = sorted(
            int(m.group(1))
            for d in os.listdir(self.path)
            if (m := re.fullmatch(r"step_(\d+)", d))
        )
        for s in steps[: -self.retain]:
            shutil.rmtree(os.path.join(self.path, f"step_{s:08d}"), ignore_errors=True)
