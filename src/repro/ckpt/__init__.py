from repro.ckpt.checkpoint import (
    SAVE_THREAD_PREFIX,
    CheckpointManager,
    latest_step,
    restore_pytree,
    save_pytree,
)

__all__ = [
    "save_pytree",
    "restore_pytree",
    "latest_step",
    "CheckpointManager",
    "SAVE_THREAD_PREFIX",
]
