from repro.ckpt.checkpoint import (
    DEFAULT_RETAIN,
    SAVE_THREAD_PREFIX,
    CheckpointCorruptError,
    CheckpointManager,
    latest_step,
    quarantine_step,
    restore_pytree,
    restore_pytree_with_fallback,
    save_pytree,
)

__all__ = [
    "save_pytree",
    "restore_pytree",
    "restore_pytree_with_fallback",
    "latest_step",
    "quarantine_step",
    "CheckpointManager",
    "CheckpointCorruptError",
    "DEFAULT_RETAIN",
    "SAVE_THREAD_PREFIX",
]
