"""Pallas TPU kernels for the compute hot-spots of DC-kCore.

The paper's per-iteration hot-spot is the h-index estimation over every
node's gathered neighbor estimates (Algorithms 1/2):

* ``hindex/`` — the single-device h-index form: blocked sort-free
  compare-and-reduce straight to the new estimates.
* ``counts/`` — the distributed form: per-shard partial suffix counts
  (the psum payload of core/distributed.py), tiled over candidates so the
  VMEM footprint is width-independent.
* ``fused/`` — the whole sweep body in one kernel per row tile: in-kernel
  neighbor gather + h-index + segment-reduce dirty-bit push, so no
  ``[rows, width]`` intermediate ever round-trips HBM (the
  ``engine="fused"`` path of core/decompose.py).

All validated in interpret mode on CPU against pure-jnp oracles
(tests/test_kernels_*.py, tests/test_fused_engine.py); target: TPU v5e.
"""
