"""Pure-jnp reference for the fused sweep kernel.

Mirrors the unfused ``core.decompose._sweep`` bucket body step for step —
gather, h-index (count form), changed compare, ``[rows, width]``
scatter-max dirty push — so differential tests can pin the fused kernel's
three outputs against an implementation with no Pallas in it.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.hindex import hindex_count


def fused_sweep_ref(c, ext_pad, ids, neigh, *, cand: int, track_dirty: bool = True):
    """Reference (est, row_changed, dirty) for one bucket.

    Same signature/contract as :func:`repro.kernels.fused.ops.fused_sweep_op`.
    """
    sentinel = c.shape[0] - 1
    gathered = c[neigh].astype(jnp.int32)
    ext_rows = ext_pad[ids]
    cur_rows = c[ids].astype(jnp.int32)
    cand = int(min(max(cand, 1), neigh.shape[1]))
    # hindex_count has no candidate window; the kernel searches only
    # candidates 1..cand, which equals min(h, cand) (feasibility is a
    # monotone boundary) — clamp to mirror it.
    est = jnp.minimum(
        hindex_count(gathered, ext_rows, cand_chunk=min(256, cand)),
        ext_rows + cand,
    )
    row_changed = (est != cur_rows) & (ids != sentinel)
    dirty = jnp.zeros((c.shape[0],), jnp.int8)
    if track_dirty:
        dirty = dirty.at[neigh].max(
            jnp.broadcast_to(row_changed[:, None], neigh.shape).astype(jnp.int8)
        )
    return est, row_changed.astype(jnp.int32), dirty
