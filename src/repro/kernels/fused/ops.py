"""Jit'd launch glue for the fused sweep kernel.

Chooses tile sizes from the VMEM budget (tile-dependent terms only — the
resident estimate/dirty vectors are tile-independent), pads rows to the
tile multiple with sentinel ids, and exposes the bucket-level op the
``engine="fused"`` decompose path dispatches per bucket / per compacted
width group.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.fused.fused import fused_sweep_pallas, fused_vmem_bytes_estimate

# Same conservative working budget as kernels.hindex.ops.
_VMEM_BUDGET = 8 * 1024 * 1024


def pick_fused_tile_n(width: int, cand_chunk: int = 128,
                      budget: int = _VMEM_BUDGET) -> int:
    """Largest power-of-two tile whose tile-DEPENDENT footprint fits."""
    tile_n = 256
    while tile_n > 8 and fused_vmem_bytes_estimate(
            tile_n, width, cand_chunk, n_state=0) > budget:
        tile_n //= 2
    return tile_n


@partial(jax.jit, static_argnames=("cand", "track_dirty", "interpret"))
def fused_sweep_op(
    c: jax.Array,
    ext_pad: jax.Array,
    ids: jax.Array,
    neigh: jax.Array,
    *,
    cand: int,
    track_dirty: bool = True,
    interpret: bool = True,
):
    """Fused gather + h-index + dirty push for one bucket.

    Args:
      c: [n+1] current estimates (int16/int32), slot n = -1 sentinel.
      ext_pad: [n+1] int32 ext, slot n = 0.
      ids: [rows] int32 node ids (pad rows = n).
      neigh: [rows, width] int32 neighbor ids (pad slots = n).
      cand: candidate window (degeneracy bound; clamped to width).
    Returns:
      ``(est [rows] int32, row_changed [rows] int32, dirty [n+1] int8)``.
    """
    rows, width = neigh.shape
    sentinel = c.shape[0] - 1
    tile_n = pick_fused_tile_n(width)
    n_pad = (-rows) % tile_n
    if n_pad:
        # Sentinel-padded rows gather -1 estimates, produce est 0 and
        # row_changed 0, and push nothing.
        ids = jnp.pad(ids, (0, n_pad), constant_values=sentinel)
        neigh = jnp.pad(neigh, ((0, n_pad), (0, 0)), constant_values=sentinel)
    est, changed, dirty = fused_sweep_pallas(
        c, ext_pad, ids, neigh, cand=cand, tile_n=tile_n,
        track_dirty=track_dirty, interpret=interpret,
    )
    return est[:rows, 0], changed[:rows, 0], dirty
