from repro.kernels.fused.fused import (  # noqa: F401
    fused_sweep_pallas,
    fused_vmem_bytes_estimate,
)
from repro.kernels.fused.ops import fused_sweep_op, pick_fused_tile_n  # noqa: F401
from repro.kernels.fused.ref import fused_sweep_ref  # noqa: F401
