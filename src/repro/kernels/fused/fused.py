"""Pallas TPU kernel: fused gather + h-index + dirty-bit push per row tile.

The unfused sweep (``core.decompose._sweep``) issues several dispatches per
bucket — an O(rows*width) gather, the h-index, a changed-row compare, then a
``[rows, width]`` scatter-max to push dirty bits — and every intermediate
round-trips through HBM. This kernel does all of it in one pass over the
neighbor tile while it is resident in VMEM:

  * **gather**: the full estimate vector ``c`` ([n+1], sentinel slot last)
    is an input block; neighbor estimates are gathered in-kernel, so the
    ``[tile_n, width]`` gathered matrix is never materialized to HBM;
  * **h-index**: the same sort-free suffix-count form as the standalone
    hindex kernel (candidate window ``cand``, static ``cand_chunk`` chunks,
    chunks above the tile's current-estimate max predicated off);
  * **changed + push**: ``est != cur`` is computed on the spot and pushed to
    every neighbor of a changed row as a segment-max over the flattened
    neighbor ids (the segment-reduce formulation of the dirty-bit push —
    one reduction keyed by neighbor id instead of a scatter-max of a
    broadcast ``[rows, width]`` byte matrix). The per-node dirty vector is
    an output block revisited by every grid step: zero-initialised on step
    0 (``pl.when``) and max-accumulated afterwards.

On TPU the estimate vector would live in ANY/HBM with DMA'd gathers; in
interpret mode (this container) block loads are plain XLA slices, so the
kernel doubles as the executable spec. The estimate vector may be int16
(the opt-in halved-wire mode — see ``core.decompose``); all arithmetic is
widened to int32 in-kernel, only the resident state is narrow.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _fused_sweep_kernel(
    c_ref, ext_pad_ref, ids_ref, neigh_ref,
    est_ref, changed_ref, dirty_ref,
    *, cand: int, cand_chunk: int, track_dirty: bool,
):
    """One row tile: gather -> suffix-count h-index -> dirty push."""
    c = c_ref[...]            # [n+1] estimates (int16 or int32), slot n = -1
    ids = ids_ref[...]        # [tile_n, 1] int32 node ids (sentinel-padded)
    neigh = neigh_ref[...]    # [tile_n, width] int32 neighbor ids
    n1 = c.shape[0]
    sentinel = n1 - 1
    tile_n, width = neigh.shape

    # Fused gathers: neighbor estimates + this tile's ext/cur rows. Pad
    # rows (ids == sentinel) gather the -1 sentinel row and ext 0.
    x = c[neigh].astype(jnp.int32)                    # [tile_n, width]
    ext = ext_pad_ref[...][ids]                       # [tile_n, 1] int32
    cur = c[ids].astype(jnp.int32)                    # [tile_n, 1]

    # Suffix-count h-index over the candidate window (same schedule as
    # kernels.hindex: chunks above the tile's current max are dead work
    # because estimates only decrease).
    cur_max = jnp.max(cur - ext)
    best = jnp.zeros((tile_n, 1), dtype=jnp.int32)
    for lo in range(0, cand, cand_chunk):
        w = min(cand_chunk, cand - lo)
        i = lo + 1 + jax.lax.broadcasted_iota(jnp.int32, (1, w), 1)

        def chunk(best, i=i, lo=lo, w=w):
            thr = ext + i
            cnt = jnp.sum(
                (x[:, :, None] >= thr[:, None, :]).astype(jnp.int32), axis=1
            )
            feasible = cnt >= i
            chunk_best = jnp.max(jnp.where(feasible, i, 0), axis=1, keepdims=True)
            return jnp.maximum(best, chunk_best)

        best = jax.lax.cond(lo < cur_max, chunk, lambda b: b, best)
    est = ext + best                                   # [tile_n, 1]
    row_changed = (est != cur) & (ids != sentinel)     # [tile_n, 1]

    est_ref[...] = est
    changed_ref[...] = row_changed.astype(jnp.int32)

    @pl.when(pl.program_id(0) == 0)
    def _init_dirty():
        dirty_ref[...] = jnp.zeros_like(dirty_ref)

    if track_dirty:
        # Segment-reduce push: max the changed flag into each neighbor's
        # slot, keyed by flattened neighbor id. Sentinel slots absorb the
        # pad traffic (never read back).
        flat_ids = neigh.reshape(-1)
        flat_val = jnp.broadcast_to(row_changed, neigh.shape).reshape(-1)
        contrib = jax.ops.segment_max(
            flat_val.astype(jnp.int8), flat_ids, num_segments=n1
        )
        dirty_ref[...] = jnp.maximum(dirty_ref[...], contrib)


def fused_sweep_pallas(
    c: jax.Array,
    ext_pad: jax.Array,
    ids: jax.Array,
    neigh: jax.Array,
    *,
    cand: int,
    tile_n: int = 8,
    cand_chunk: int = 128,
    track_dirty: bool = True,
    interpret: bool = True,
):
    """Fused sweep over one bucket tile set.

    Args:
      c: [n+1] current estimates (int16 or int32), slot n pinned to -1.
      ext_pad: [n+1] int32 external information, slot n = 0.
      ids: [rows] int32 node ids, pad rows = n (the sentinel).
      neigh: [rows, width] int32 neighbor ids, pad slots = n.
      cand: candidate window (clamped to the bucket width).
    Returns:
      ``(est [rows, 1] int32, changed [rows, 1] int32, dirty [n+1] int8)``.
      ``dirty`` is all-zero when ``track_dirty=False``.
    """
    rows, width = neigh.shape
    if rows % tile_n != 0:
        raise ValueError(f"rows {rows} not a multiple of tile_n {tile_n}")
    n1 = c.shape[0]
    cand = int(min(max(cand, 1), width))
    ids2 = ids.reshape(rows, 1).astype(jnp.int32)

    kernel = functools.partial(
        _fused_sweep_kernel, cand=cand, cand_chunk=cand_chunk,
        track_dirty=track_dirty,
    )
    grid = (rows // tile_n,)
    est, changed, dirty = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((n1,), lambda g: (0,)),        # full c
            pl.BlockSpec((n1,), lambda g: (0,)),        # full ext_pad
            pl.BlockSpec((tile_n, 1), lambda g: (g, 0)),
            pl.BlockSpec((tile_n, width), lambda g: (g, 0)),
        ],
        out_specs=(
            pl.BlockSpec((tile_n, 1), lambda g: (g, 0)),
            pl.BlockSpec((tile_n, 1), lambda g: (g, 0)),
            pl.BlockSpec((n1,), lambda g: (0,)),        # full dirty, accumulated
        ),
        out_shape=(
            jax.ShapeDtypeStruct((rows, 1), jnp.int32),
            jax.ShapeDtypeStruct((rows, 1), jnp.int32),
            jax.ShapeDtypeStruct((n1,), jnp.int8),
        ),
        interpret=interpret,
    )(c, ext_pad.astype(jnp.int32), ids2, neigh.astype(jnp.int32))
    return est, changed, dirty


def fused_vmem_bytes_estimate(
    tile_n: int, width: int, cand_chunk: int, n_state: int, wire_bytes: int = 4
) -> int:
    """Static VMEM footprint estimate for one fused grid step.

    The tile-dependent terms mirror the hindex kernel (neighbor block,
    gathered block, compare intermediate); the state terms (``c`` +
    ``dirty`` blocks, ``n_state`` slots each) are tile-independent — on TPU
    they would stay in ANY/HBM with DMA'd gathers, so ops.py sizes the tile
    from the tile-dependent terms only but reports the full estimate.
    """
    block = tile_n * width * 4          # neighbor ids
    gathered = tile_n * width * 4       # in-kernel gathered estimates
    compare = tile_n * width * cand_chunk
    partials = tile_n * cand_chunk * 4 * 2
    push = tile_n * width * 1           # flattened segment values
    state = n_state * (wire_bytes + 1)  # c + dirty blocks
    return block + gathered + compare + partials + push + state
