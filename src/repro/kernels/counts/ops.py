"""Jit'd wrapper for the partial-counts kernel (row padding + tiling)."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.counts.counts import partial_counts_pallas


@partial(jax.jit, static_argnames=("cand", "interpret"))
def partial_counts_op(neigh: jax.Array, ext: jax.Array, *, cand: int,
                      interpret: bool = True) -> jax.Array:
    n, w = neigh.shape
    tile_n = 8
    pad = (-n) % tile_n
    if pad:
        neigh = jnp.pad(neigh, ((0, pad), (0, 0)), constant_values=-1)
        ext = jnp.pad(ext, (0, pad))
    out = partial_counts_pallas(neigh, ext, cand=cand, interpret=interpret)
    return out[:n]
