"""Pure-jnp oracle for the partial-counts kernel.

Semantics: per node row, the suffix count over the LOCAL neighbor-slot
shard for every candidate offset:

    cnt[n, i] = #{ j : x[n, j] >= ext[n] + (i+1) },  i in [0, cand)

This is the distributed conquer step's per-shard contribution; the engine
psums it over the slot ("model") axes before the feasibility argmax
(see core/distributed.py).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def partial_counts_ref(x: jax.Array, ext: jax.Array, cand: int) -> jax.Array:
    """x: [n, w_local] int32 (-1 padded); ext: [n] int32 -> [n, cand] int32."""
    i = 1 + jnp.arange(cand, dtype=jnp.int32)
    thr = ext[:, None] + i[None, :]
    return (x[:, :, None] >= thr[:, None, :]).sum(axis=1).astype(jnp.int32)
