"""Pallas TPU kernel: per-shard h-index partial counts.

The distributed engine splits each node's neighbor slots over the "model"
axis; every shard computes suffix counts over its local slots and the
engine psums them (core/distributed.py). This kernel is that local compute
with explicit VMEM tiling: grid over (node tiles x candidate tiles), inner
accumulation over neighbor-slot chunks so the compare footprint
``tile_n x slot_chunk x tile_c`` stays in VMEM regardless of bucket width.

Compared to the fused hindex kernel (kernels/hindex), the output here is
the [n, cand] count matrix — the collective payload — rather than the
final estimate, because feasibility can only be decided after the psum.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _counts_kernel(neigh_ref, ext_ref, out_ref, *, slot_chunk: int):
    x = neigh_ref[...]  # [tile_n, w_local]
    ext = ext_ref[...]  # [tile_n, 1]
    tile_n, w = x.shape
    tile_c = out_ref.shape[1]
    c0 = pl.program_id(1) * tile_c
    i = c0 + 1 + jax.lax.broadcasted_iota(jnp.int32, (1, tile_c), 1)
    thr = ext + i  # [tile_n, tile_c]

    acc = jnp.zeros((tile_n, tile_c), jnp.int32)
    for lo in range(0, w, slot_chunk):
        hi = min(lo + slot_chunk, w)
        xs = x[:, lo:hi]
        acc = acc + jnp.sum(
            (xs[:, :, None] >= thr[:, None, :]).astype(jnp.int32), axis=1
        )
    out_ref[...] = acc


def partial_counts_pallas(
    neigh: jax.Array,
    ext: jax.Array,
    *,
    cand: int,
    tile_n: int = 8,
    tile_c: int = 128,
    slot_chunk: int = 512,
    interpret: bool = True,
) -> jax.Array:
    """neigh: [n, w_local] int32 (-1 pad); ext: [n] -> [n, cand] int32."""
    n, w = neigh.shape
    if n % tile_n != 0:
        raise ValueError(f"rows {n} not a multiple of tile_n {tile_n}")
    cand_pad = -(-cand // tile_c) * tile_c
    ext2 = ext.reshape(n, 1).astype(jnp.int32)
    kernel = functools.partial(_counts_kernel, slot_chunk=slot_chunk)
    out = pl.pallas_call(
        kernel,
        grid=(n // tile_n, cand_pad // tile_c),
        in_specs=[
            pl.BlockSpec((tile_n, w), lambda gn, gc: (gn, 0)),
            pl.BlockSpec((tile_n, 1), lambda gn, gc: (gn, 0)),
        ],
        out_specs=pl.BlockSpec((tile_n, tile_c), lambda gn, gc: (gn, gc)),
        out_shape=jax.ShapeDtypeStruct((n, cand_pad), jnp.int32),
        interpret=interpret,
    )(neigh.astype(jnp.int32), ext2)
    return out[:, :cand]
