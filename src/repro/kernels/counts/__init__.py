from repro.kernels.counts.counts import partial_counts_pallas
from repro.kernels.counts.ops import partial_counts_op
from repro.kernels.counts.ref import partial_counts_ref

__all__ = ["partial_counts_pallas", "partial_counts_op", "partial_counts_ref"]
