from repro.kernels.hindex.hindex import hindex_pallas
from repro.kernels.hindex.ops import hindex_op
from repro.kernels.hindex.ref import hindex_ref

__all__ = ["hindex_pallas", "hindex_op", "hindex_ref"]
