"""Pure-jnp oracle for the hindex Pallas kernel.

Semantics (paper Algorithm 2, suffix-count form): given gathered neighbor
estimates ``x[n, j]`` (padded slots = -1) and external information
``ext[n]``, return

    out[n] = ext[n] + max{ i in [1, cand] : #{j : x[n, j] >= ext[n] + i} >= i }

(0 if no i is feasible). ``cand`` is the candidate window; with
``cand >= max degree`` this is exactly Algorithm 2. The engines pass the
degeneracy bound U (h-index of the degree sequence, >= k_max), which
preserves exactness while shrinking the window — see DESIGN.md.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def hindex_ref(x: jax.Array, ext: jax.Array, cand: int) -> jax.Array:
    """Oracle. x: [n, w] int32 (-1 padded), ext: [n] int32 -> [n] int32."""
    n, w = x.shape
    cand = int(min(cand, w))
    i = 1 + jnp.arange(cand, dtype=jnp.int32)  # [cand]
    thr = ext[:, None] + i[None, :]  # [n, cand]
    cnt = (x[:, :, None] >= thr[:, None, :]).sum(axis=1)  # [n, cand]
    feasible = cnt >= i[None, :]
    best = jnp.max(jnp.where(feasible, i[None, :], 0), axis=1)
    return (ext + best).astype(jnp.int32)
