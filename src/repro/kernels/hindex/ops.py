"""Jit'd wrapper around the hindex Pallas kernel.

Chooses tile sizes from a VMEM budget, pads rows to the tile multiple, and
exposes a drop-in replacement for :func:`repro.core.hindex.hindex_count`
(the ``op="kernel"`` path of the decompose engines).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.hindex.hindex import hindex_pallas, vmem_bytes_estimate

# Conservative per-core VMEM working budget (v5e has 128 MiB VMEM; leave
# headroom for Mosaic's own buffers and double buffering).
_VMEM_BUDGET = 8 * 1024 * 1024


def pick_tile_n(width: int, cand_chunk: int = 128, budget: int = _VMEM_BUDGET) -> int:
    tile_n = 256
    while tile_n > 8 and vmem_bytes_estimate(tile_n, width, cand_chunk) > budget:
        tile_n //= 2
    return tile_n


@partial(jax.jit, static_argnames=("cand", "interpret"))
def hindex_op(
    neigh_cores: jax.Array,
    ext: jax.Array,
    cur: jax.Array,
    *,
    cand: int,
    interpret: bool = True,
) -> jax.Array:
    """H-index for one padded bucket. Pads rows to the tile multiple.

    Args:
      neigh_cores: [n, w] int32, padded slots -1.
      ext: [n] int32 external information.
      cur: [n] int32 current estimates (kernel predication hint).
      cand: candidate window (degeneracy bound U; >= k_max for exactness).
    """
    n, w = neigh_cores.shape
    tile_n = pick_tile_n(w)
    n_pad = (-n) % tile_n
    if n_pad:
        neigh_cores = jnp.pad(neigh_cores, ((0, n_pad), (0, 0)), constant_values=-1)
        ext = jnp.pad(ext, (0, n_pad))
        cur = jnp.pad(cur, (0, n_pad))
    out = hindex_pallas(
        neigh_cores, ext, cur, cand=cand, tile_n=tile_n, interpret=interpret
    )
    return out[:n]
