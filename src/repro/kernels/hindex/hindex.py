"""Pallas TPU kernel: blocked h-index with external information.

This is the compute hot-spot of the conquer step (paper Algorithm 2): per
graph node, the largest ``i`` such that at least ``i`` neighbors hold an
estimate ``>= ext + i``. The paper's Scala implementation sorts each
neighbor list per iteration; sorting is hostile to the TPU VPU, so the
kernel uses the sort-free suffix-count form — dense compare-and-reduce over
a ``[tile_n, width]`` VMEM block against a candidate window, which maps onto
8x128 vector registers with no data-dependent control flow.

Tiling:
  * grid over node tiles of ``tile_n`` rows; the full padded neighbor row
    (``width`` slots) for the tile lives in VMEM (power-of-two bucket widths
    keep this lane-aligned);
  * the candidate axis is processed in static chunks of ``cand_chunk`` so
    the [tile_n, width, cand_chunk] compare footprint stays inside the VMEM
    budget;
  * chunks whose candidates all exceed the tile's current-estimate maximum
    are predicated off with ``pl.when`` — as the fixed point converges,
    estimates shrink and most chunks are skipped (dynamic work saving with a
    static schedule).

The candidate window ``cand`` is the degeneracy bound U (h-index of the
degree sequence, >= k_max), not the bucket width — exactness is preserved
(estimates stay upper bounds; see DESIGN.md) while the compare volume drops
from O(w^2) to O(w * U).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _hindex_kernel(neigh_ref, ext_ref, cur_ref, out_ref, *, cand: int, cand_chunk: int):
    """One node tile: out[n] = ext[n] + best feasible candidate."""
    x = neigh_ref[...]  # [tile_n, width] int32, -1 padded
    ext = ext_ref[...]  # [tile_n, 1] int32
    cur = cur_ref[...]  # [tile_n, 1] int32 current estimates (predication only)
    tile_n = x.shape[0]

    # Estimates never exceed the tile's current max (monotone decrease), so
    # candidate chunks above it are dead work.
    cur_max = jnp.max(cur - ext)  # candidates are offsets i = c - ext

    best = jnp.zeros((tile_n, 1), dtype=jnp.int32)
    for lo in range(0, cand, cand_chunk):
        w = min(cand_chunk, cand - lo)
        i = lo + 1 + jax.lax.broadcasted_iota(jnp.int32, (1, w), 1)  # [1, w]

        def chunk(best, i=i, lo=lo, w=w):
            thr = ext + i  # [tile_n, w]
            # [tile_n, width, w] compare, reduce over neighbors.
            cnt = jnp.sum(
                (x[:, :, None] >= thr[:, None, :]).astype(jnp.int32), axis=1
            )  # [tile_n, w]
            feasible = cnt >= i
            chunk_best = jnp.max(jnp.where(feasible, i, 0), axis=1, keepdims=True)
            return jnp.maximum(best, chunk_best)

        # Predicate the whole chunk off once estimates dropped below it.
        best = jax.lax.cond(lo < cur_max, chunk, lambda b: b, best)
    out_ref[...] = ext + best


def hindex_pallas(
    neigh_cores: jax.Array,
    ext: jax.Array,
    cur: jax.Array,
    *,
    cand: int,
    tile_n: int = 8,
    cand_chunk: int = 128,
    interpret: bool = True,
) -> jax.Array:
    """Blocked h-index. ``neigh_cores``: [n, w] int32 (-1 pad); ``ext``,
    ``cur``: [n] int32. Returns [n] int32 new estimates.

    ``interpret=True`` executes the kernel body in Python on CPU (this
    container); on a real TPU pass ``interpret=False``.
    """
    n, w = neigh_cores.shape
    if n % tile_n != 0:
        raise ValueError(f"rows {n} not a multiple of tile_n {tile_n}")
    cand = int(min(max(cand, 1), w))
    ext2 = ext.reshape(n, 1).astype(jnp.int32)
    cur2 = cur.reshape(n, 1).astype(jnp.int32)

    kernel = functools.partial(_hindex_kernel, cand=cand, cand_chunk=cand_chunk)
    grid = (n // tile_n,)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile_n, w), lambda g: (g, 0)),
            pl.BlockSpec((tile_n, 1), lambda g: (g, 0)),
            pl.BlockSpec((tile_n, 1), lambda g: (g, 0)),
        ],
        out_specs=pl.BlockSpec((tile_n, 1), lambda g: (g, 0)),
        out_shape=jax.ShapeDtypeStruct((n, 1), jnp.int32),
        interpret=interpret,
    )(neigh_cores.astype(jnp.int32), ext2, cur2)
    return out.reshape(n)


def vmem_bytes_estimate(tile_n: int, width: int, cand_chunk: int) -> int:
    """Static VMEM footprint estimate used by ops.py to pick tile_n."""
    block = tile_n * width * 4  # neighbor tile
    compare = tile_n * width * cand_chunk  # bool intermediate
    partial = tile_n * cand_chunk * 4 * 2
    return block + compare + partial
