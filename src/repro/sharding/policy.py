"""Logical-axis -> mesh-axis sharding policy with divisibility fallback.

One place decides how every tensor in the system is laid out on the mesh.
Layers annotate *logical* axes ("embed", "mlp", "heads", "experts", ...);
:func:`resolve` maps them to mesh axes using a rules table and falls back to
replication whenever the dimension is not divisible by the mesh axis size
(e.g. granite's 49155 vocab before padding, grok's 8 experts on a 16-wide
model axis). Fallbacks are recorded so the dry-run can report them.

Default rules (the "megatron+fsdp" layout):

  batch   -> ("pod", "data")   pure DP across pods (DCN-friendly)
  embed   -> "data"            FSDP/ZeRO-3: params gathered on use
  vocab   -> "model"           tensor-parallel embedding / logits
  heads   -> "model"           attention TP
  mlp     -> "model"           feed-forward TP
  experts -> "model"           expert parallelism (when divisible)
  kv_heads-> "model"           (falls back to replicated for kv < 16)
  layers  -> None              scan dim, never sharded
  seq     -> None              (the long-decode cache overrides to "data")
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Sequence, Tuple

import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

Axis = Optional[str]
MeshAxes = Optional[Tuple[str, ...]]

# ------------------------------------------------------------------ #
# Active mesh axes: layers emit logical activation constraints like
# P(("pod","data"), None); before reaching XLA they are filtered to the
# axes of the mesh actually in scope (single-pod meshes have no "pod";
# CPU smoke tests have no mesh at all -> constraints become no-ops).
# ------------------------------------------------------------------ #
_ACTIVE_AXES: Dict[str, int] = {}
_ACTIVE_RULES: Optional[Dict[str, MeshAxes]] = None


class active_mesh:
    """Context manager: declare the mesh (and optionally the rules table)
    whose axes activation constraints may use."""

    def __init__(self, mesh: Optional[Mesh], rules: Optional[Dict[str, MeshAxes]] = None):
        self.axes = dict(zip(mesh.axis_names, mesh.shape.values())) if mesh is not None else {}
        self.rules = rules

    def __enter__(self):
        global _ACTIVE_AXES, _ACTIVE_RULES
        self._saved = (_ACTIVE_AXES, _ACTIVE_RULES)
        _ACTIVE_AXES = self.axes
        _ACTIVE_RULES = self.rules
        return self

    def __exit__(self, *exc):
        global _ACTIVE_AXES, _ACTIVE_RULES
        _ACTIVE_AXES, _ACTIVE_RULES = self._saved
        return False


def filter_spec(spec: P) -> Optional[P]:
    """Drop axes not present in the active mesh; None if no mesh active."""
    if not _ACTIVE_AXES:
        return None
    parts = []
    for entry in spec:
        if entry is None:
            parts.append(None)
        elif isinstance(entry, (tuple, list)):
            kept = tuple(a for a in entry if a in _ACTIVE_AXES)
            parts.append(kept if kept else None)
        else:
            parts.append(entry if entry in _ACTIVE_AXES else None)
    return P(*parts)


def active_dp_size() -> int:
    """Product of active batch-rule axes (1 without an active mesh)."""
    if not _ACTIVE_AXES:
        return 1
    rules = _ACTIVE_RULES or DEFAULT_RULES
    out = 1
    for ax in rules.get("batch") or ():
        out *= _ACTIVE_AXES.get(ax, 1)
    return out


def logical_spec(shape: Sequence[int], axes: Sequence[Axis]) -> Optional[P]:
    """Resolve LOGICAL axes for an activation against the active mesh with
    divisibility fallback — e.g. an [8, cap, d] expert buffer only gets
    P("model", ...) when 8 divides the model axis (jamba 16e yes, grok 8e
    no). Returns None when no mesh is active."""
    if not _ACTIVE_AXES:
        return None
    rules = _ACTIVE_RULES or DEFAULT_RULES
    used: set = set()
    parts = []
    for size, name in zip(shape, axes):
        if name is None:
            parts.append(None)
            continue
        mesh_axes = rules.get(name)
        if mesh_axes is None:
            parts.append(None)
            continue
        chosen = []
        prod = 1
        for ax in mesh_axes:
            if ax not in _ACTIVE_AXES or ax in used:
                continue
            nsize = _ACTIVE_AXES[ax]
            if size % (prod * nsize) != 0:
                continue
            chosen.append(ax)
            prod *= nsize
        if not chosen:
            parts.append(None)
        else:
            parts.append(chosen[0] if len(chosen) == 1 else tuple(chosen))
            used.update(chosen)
    return P(*parts)

DEFAULT_RULES: Dict[str, MeshAxes] = {
    "batch": ("pod", "data"),
    "embed": ("data",),
    "vocab": ("model",),
    "heads": ("model",),
    "kv_heads": ("model",),
    "mlp": ("model",),
    "expert_mlp": ("model",),
    "experts": ("model",),
    "inner": ("model",),  # SSM d_inner
    "layers": None,
    "seq": None,
    "cache_seq": None,
    "cache_batch": ("pod", "data"),
    "state": None,
    "conv": None,
    "frames": None,
    "patches": None,
}

# Variants used by the perf pass; selected per arch/shape in configs.
LONG_DECODE_RULES = dict(DEFAULT_RULES, cache_seq=("data",), cache_batch=None)
TP_ONLY_RULES = dict(DEFAULT_RULES, embed=None)

# Decode/serving layout (§Perf iteration, jamba decode_32k): the default
# (training) rules FSDP-shard params over "data" and re-gather the full
# weights EVERY decode step — ~full-model bytes of all-gather per token.
# SERVE_RULES instead run Megatron-style tensor parallelism over the
# FLATTENED (data x model) = 256-way axis on the weights' output dims:
# weights stay resident, each block pays one small activation all-reduce
# (column-parallel in, row-parallel out), and the KV cache shards over its
# sequence dim (flash-decode style) so the cache read parallelizes too.
SERVE_RULES = dict(
    DEFAULT_RULES,
    batch=None,
    embed=None,
    mlp=("data", "model"),
    expert_mlp=("data", "model"),
    inner=("data", "model"),
    heads=("data", "model"),
    kv_heads=("model",),
    vocab=("data", "model"),
    cache_batch=None,
    cache_seq=("data",),
)


@dataclasses.dataclass
class ResolveLog:
    """Fallbacks recorded during resolution (reported by the dry-run)."""

    replicated: list = dataclasses.field(default_factory=list)

    def note(self, axes, dim, size, axis_size):
        self.replicated.append((axes, dim, size, axis_size))


def resolve(
    shape: Sequence[int],
    axes: Sequence[Axis],
    mesh: Mesh,
    rules: Optional[Dict[str, MeshAxes]] = None,
    log: Optional[ResolveLog] = None,
) -> P:
    """PartitionSpec for a tensor with the given logical axes."""
    rules = rules or DEFAULT_RULES
    used: set = set()
    parts = []
    for dim, (size, name) in enumerate(zip(shape, axes)):
        if name is None:
            parts.append(None)
            continue
        mesh_axes = rules.get(name)
        if mesh_axes is None:
            parts.append(None)
            continue
        # Keep only axes present in this mesh, unused so far, and divisible.
        chosen = []
        prod = 1
        for ax in mesh_axes:
            if ax not in mesh.shape or ax in used:
                continue
            nsize = mesh.shape[ax]
            if size % (prod * nsize) != 0:
                if log is not None:
                    log.note(tuple(axes), dim, size, nsize)
                continue
            chosen.append(ax)
            prod *= nsize
        if not chosen:
            parts.append(None)
        elif len(chosen) == 1:
            parts.append(chosen[0])
            used.add(chosen[0])
        else:
            parts.append(tuple(chosen))
            used.update(chosen)
    return P(*parts)


def resolve_spec(shape, axes, mesh, rules=None, log=None) -> NamedSharding:
    return NamedSharding(mesh, resolve(shape, axes, mesh, rules, log))


def data_axes(mesh: Mesh, rules=None) -> Tuple[str, ...]:
    """Mesh axes carrying the batch (for per-device batch calculations)."""
    rules = rules or DEFAULT_RULES
    return tuple(a for a in (rules.get("batch") or ()) if a in mesh.shape)


def dp_size(mesh: Mesh, rules=None) -> int:
    return int(np.prod([mesh.shape[a] for a in data_axes(mesh, rules)], initial=1))
