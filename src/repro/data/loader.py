"""File-backed token loader with host sharding and background prefetch.

``MemmapTokens`` reads a flat int32 token file (np.memmap — no RAM copy of
the corpus), slices per (step, host) deterministically, and ``Prefetcher``
overlaps host IO with device compute via a bounded background queue —
the straggler-mitigation story for host-side input hiccups.
"""
from __future__ import annotations

import queue
import threading
import numpy as np


class MemmapTokens:
    def __init__(self, path: str, seq_len: int, batch: int,
                 host_index: int = 0, host_count: int = 1):
        self.data = np.memmap(path, dtype=np.int32, mode="r")
        self.seq_len = seq_len
        self.batch = batch
        self.host_index = host_index
        self.host_count = host_count
        self.tokens_per_step = seq_len + 1
        n_rows = len(self.data) // self.tokens_per_step
        self.rows_per_host = n_rows // host_count
        if self.rows_per_host < batch:
            raise ValueError("dataset too small for batch per host")

    def batch_at(self, step: int) -> dict:
        base = self.host_index * self.rows_per_host
        start = (step * self.batch) % (self.rows_per_host - self.batch + 1)
        rows = []
        for i in range(self.batch):
            r = base + start + i
            off = r * self.tokens_per_step
            rows.append(np.asarray(self.data[off : off + self.tokens_per_step]))
        arr = np.stack(rows)
        return {"tokens": arr[:, :-1], "labels": arr[:, 1:]}


class Prefetcher:
    """Bounded background prefetch of ``source.batch_at(step)``."""

    def __init__(self, source, start_step: int = 0, depth: int = 2):
        self.source = source
        self.q: "queue.Queue" = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self._step = start_step
        self._thread = threading.Thread(target=self._work, daemon=True)
        self._thread.start()

    def _work(self):
        step = self._step
        while not self._stop.is_set():
            try:
                self.q.put((step, self.source.batch_at(step)), timeout=0.2)
                step += 1
            except queue.Full:
                continue

    def next(self):
        return self.q.get()

    def close(self):
        self._stop.set()
        try:
            while True:
                self.q.get_nowait()
        except queue.Empty:
            pass
        self._thread.join(timeout=2)
