"""Deterministic synthetic token stream.

Step-indexed PRNG: batch(step) is a pure function, so a restarted/elastic
run consumes exactly the same data from any step — the property the
fault-tolerance tests pin down (bit-identical resume).
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class SyntheticTokens:
    vocab_size: int
    seq_len: int
    batch: int
    seed: int = 0

    def batch_at(self, step: int) -> dict:
        rng = np.random.default_rng((self.seed, step))
        toks = rng.integers(
            0, self.vocab_size, size=(self.batch, self.seq_len + 1), dtype=np.int32
        )
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

    def __iter__(self):
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1
