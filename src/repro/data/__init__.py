from repro.data.synthetic import SyntheticTokens
from repro.data.loader import MemmapTokens, Prefetcher

__all__ = ["SyntheticTokens", "MemmapTokens", "Prefetcher"]
