"""Serving launcher: batched prefill + greedy decode.

``python -m repro.launch.serve --arch mamba2-130m --smoke --new-tokens 16``
"""
from __future__ import annotations

import argparse
import time

import jax

from repro.configs import get_config, get_smoke_config
from repro.models.model import build_specs
from repro.models.module import init_params
from repro.runtime import greedy_generate


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    params = init_params(build_specs(cfg), jax.random.PRNGKey(args.seed))
    prompt = jax.random.randint(
        jax.random.PRNGKey(args.seed + 1), (args.batch, args.prompt_len),
        0, cfg.vocab_size,
    )
    extras = None
    if cfg.encoder is not None:
        extras = {"frames": jax.random.normal(
            jax.random.PRNGKey(2), (args.batch, cfg.encoder.n_frames, cfg.d_model),
            cfg.dtype)}
    elif cfg.cross_attn_every is not None:
        extras = {"vision_embeds": jax.random.normal(
            jax.random.PRNGKey(2), (args.batch, cfg.n_vision_tokens, cfg.d_model),
            cfg.dtype)}
    t0 = time.perf_counter()
    out = greedy_generate(params, prompt, cfg, args.new_tokens, extras=extras)
    dt = time.perf_counter() - t0
    print(f"{cfg.name}: generated {out.shape} in {dt:.2f}s "
          f"({args.batch * args.new_tokens / dt:.1f} tok/s)")
    print(out[:, :12])


if __name__ == "__main__":
    main()
