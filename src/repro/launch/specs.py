"""Dry-run input specs: ShapeDtypeStruct stand-ins for every model input.

Everything here is shape/sharding metadata only — no device allocation, so
the 314B/398B configs cost nothing to describe. Sharding resolution goes
through :mod:`repro.sharding.policy` with per-arch/per-shape rule variants
(long-context decode shards the cache sequence dim over "data").
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs import SHAPES, get_config
from repro.models import blocks
from repro.models.model import build_specs
from repro.models.module import abstract_params
from repro.optim import get_optimizer
from repro.sharding.policy import (
    DEFAULT_RULES,
    LONG_DECODE_RULES,
    ResolveLog,
    resolve_spec,
)


def rules_for(cfg, shape_name: str, overrides: Optional[dict] = None) -> dict:
    rules = dict(LONG_DECODE_RULES if shape_name == "long_500k" else DEFAULT_RULES)
    rules.update(dict(cfg.sharding_overrides))
    if overrides:
        rules.update(overrides)
    return rules


def _sds(shape, dtype, mesh, spec: P):
    return jax.ShapeDtypeStruct(shape, dtype, sharding=NamedSharding(mesh, spec))


def _batch_spec(mesh, rules, batch: int) -> P:
    """Batch-dim spec; drops axes the batch size cannot be divided over
    (long_500k has global_batch=1 — batch stays replicated and the cache
    sequence dim carries the sharding instead, per LONG_DECODE_RULES)."""
    axes = []
    prod = 1
    for a in rules.get("batch") or ():
        if a in mesh.shape and batch % (prod * mesh.shape[a]) == 0:
            axes.append(a)
            prod *= mesh.shape[a]
    return P(tuple(axes) if axes else None)


def extras_specs(cfg, batch: int, mesh, rules) -> Dict[str, Any]:
    bspec = _batch_spec(mesh, rules, batch)
    ex: Dict[str, Any] = {}
    if cfg.encoder is not None:
        ex["frames"] = _sds(
            (batch, cfg.encoder.n_frames, cfg.d_model), cfg.dtype, mesh,
            P(*bspec, None, None),
        )
    elif cfg.cross_attn_every is not None:
        ex["vision_embeds"] = _sds(
            (batch, cfg.n_vision_tokens, cfg.d_model), cfg.dtype, mesh,
            P(*bspec, None, None),
        )
    return ex


def _cache_abstract(cfg, batch: int, max_len: int, mesh, rules, log=None):
    tree = blocks.cache_specs_tree(cfg, batch, max_len)
    is_sd = lambda x: isinstance(x, tuple) and len(x) == 3 and isinstance(x[0], tuple)
    return jax.tree.map(
        lambda sd: jax.ShapeDtypeStruct(
            sd[0], sd[2],
            sharding=resolve_spec(sd[0], sd[1], mesh, rules, log),
        ),
        tree,
        is_leaf=is_sd,
    )


def input_specs(arch: str, shape_name: str, mesh: Mesh,
                rules: Optional[dict] = None,
                lr: float = 3e-4) -> Tuple[Dict[str, Any], Any, ResolveLog]:
    """Returns (kwargs_specs, cfg, resolve_log) for the shape's step fn.

    kwargs keys per kind:
      train   -> params, opt_state, step, batch{tokens, labels, extras}
      prefill -> params, tokens, extras
      decode  -> params, caches, token, position, extras
    """
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    rules = rules or rules_for(cfg, shape_name)
    log = ResolveLog()

    params = abstract_params(build_specs(cfg), mesh, rules, log)
    gb, seq = shape.global_batch, shape.seq_len
    bspec = _batch_spec(mesh, rules, gb)
    specs: Dict[str, Any] = {"params": params}

    if shape.kind == "train":
        opt = get_optimizer(cfg, lr=lr)
        specs["opt_state"] = jax.eval_shape(opt.init, params)
        # Optimizer state inherits parameter shardings (ZeRO-1).
        specs["opt_state"] = _reshard_like(specs["opt_state"], params, mesh)
        specs["step"] = jax.ShapeDtypeStruct((), jnp.int32)
        specs["batch"] = {
            "tokens": _sds((gb, seq), jnp.int32, mesh, P(*bspec, None)),
            "labels": _sds((gb, seq), jnp.int32, mesh, P(*bspec, None)),
        }
        ex = extras_specs(cfg, gb, mesh, rules)
        if ex:
            specs["batch"]["extras"] = ex
    elif shape.kind == "prefill":
        specs["tokens"] = _sds((gb, seq), jnp.int32, mesh, P(*bspec, None))
        specs["extras"] = extras_specs(cfg, gb, mesh, rules)
    else:  # decode
        specs["caches"] = _cache_abstract(cfg, gb, seq, mesh, rules, log)
        specs["token"] = _sds((gb, 1), jnp.int32, mesh, P(*bspec, None))
        specs["position"] = _sds((gb,), jnp.int32, mesh, bspec)
        specs["extras"] = extras_specs(cfg, gb, mesh, rules)
    return specs, cfg, log


def _reshard_like(opt_state, params, mesh):
    """Give optimizer-state leaves the sharding of their parameter where
    shapes match; replicate reduced (factored) leaves."""
    flat_params = {
        tuple(str(k) for k in path): leaf
        for path, leaf in jax.tree_util.tree_flatten_with_path(params)[0]
    }

    def assign(path, leaf):
        # Match by shape against the parameter with the same trailing path.
        for ppath, p in flat_params.items():
            if p.shape == leaf.shape and _suffix_match(path, ppath):
                return jax.ShapeDtypeStruct(leaf.shape, leaf.dtype, sharding=p.sharding)
        return jax.ShapeDtypeStruct(
            leaf.shape, leaf.dtype, sharding=NamedSharding(mesh, P())
        )

    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: assign(tuple(str(k) for k in path), leaf), opt_state
    )


def _suffix_match(opt_path, param_path) -> bool:
    """Optimizer paths look like ('m', <param path...>) or (<param path...>, 'v')."""
    pp = list(param_path)
    op = [p for p in opt_path]
    i, j = 0, 0
    while i < len(op) and j < len(pp):
        if op[i] == pp[j]:
            i += 1
            j += 1
        else:
            i += 1
    return j == len(pp)
