"""Coreness serving front end — incremental maintenance under query load.

  python -m repro.launch.kcore_serve --graph rmat:12:8 --edit-log /tmp/log
  python -m repro.launch.kcore_serve --graph ba:2000:5 --edit-log /tmp/log \
      --engine count --query-batch 256 --max-batches 50

Boots the graph, runs one full decompose, publishes the snapshot through
:class:`~repro.core.snapshot_pub.SnapshotPublisher`, then splits into two
roles: an update worker thread (named ``kcore-serve-update``) tails the
``--edit-log`` directory (:class:`~repro.graph.editlog.EditLogReader`,
EdgeStore chunk format), folds each sealed batch through
:func:`~repro.core.incremental.apply_updates`, and republishes; the main
thread plays query traffic (batched coreness lookups, k-core membership,
top-core) against whatever snapshot is currently published. The run drains
every sealed batch (stopping after ``--max-batches`` if set, or once the
log has been idle for ``--idle-timeout-s``) and prints the publisher's
metrics: updates/sec, publishes/sec, query p50/p99 latency, and staleness
(edits pending at query time).
"""
from __future__ import annotations

import argparse
import json
import threading
import time

import numpy as np

from repro.core.decompose import decompose
from repro.core.incremental import apply_updates
from repro.core.snapshot_pub import SnapshotPublisher
from repro.graph.build import bucketize
from repro.graph.editlog import EditLogReader
from repro.launch.kcore import load_graph

UPDATE_THREAD_NAME = "kcore-serve-update"


def _update_loop(
    pub: SnapshotPublisher,
    reader: EditLogReader,
    state: dict,
    *,
    op: str,
    dirty_budget_frac: float,
    max_batches: int | None,
    idle_timeout_s: float,
    poll_interval_s: float,
    stop: threading.Event,
) -> None:
    idle_since = time.perf_counter()
    try:
        while not stop.is_set():
            if reader.poll() == 0:
                if time.perf_counter() - idle_since > idle_timeout_s:
                    return
                time.sleep(poll_interval_s)
                continue
            edits = reader.read_batch()
            idle_since = time.perf_counter()
            pub.note_pending(edits.n_raw)
            res = apply_updates(
                state["graph"], state["coreness"], edits,
                op=op, dirty_budget_frac=dirty_budget_frac,
            )
            state["graph"], state["coreness"] = res.graph, res.coreness
            state["modes"][res.mode] = state["modes"].get(res.mode, 0) + 1
            state["n_batches"] += 1
            pub.publish(res.graph, res.coreness, n_edits=edits.n_raw)
            if max_batches is not None and state["n_batches"] >= max_batches:
                return
    except Exception as exc:  # surfaced as the CLI's exit error
        state["error"] = exc


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--graph", default="rmat:12:8")
    ap.add_argument("--edit-log", required=True,
                    help="EditLog directory to tail (EdgeStore slot format)")
    ap.add_argument("--engine", choices=["sorted", "count", "kernel", "fused"],
                    default="count", help="sweep engine for re-sweeps")
    ap.add_argument("--dirty-budget-frac", type=float, default=0.5,
                    help="dirty-region fraction beyond which an update "
                         "falls back to a full re-sweep")
    ap.add_argument("--query-batch", type=int, default=128,
                    help="node ids per batched coreness query")
    ap.add_argument("--max-batches", type=int, default=None,
                    help="stop after draining this many sealed batches")
    ap.add_argument("--idle-timeout-s", type=float, default=1.0,
                    help="exit once the log has been idle this long")
    ap.add_argument("--poll-interval-s", type=float, default=0.01)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--json", action="store_true",
                    help="emit the final metrics as one JSON line")
    args = ap.parse_args(argv)

    g, _ = load_graph(args.graph, args.seed)
    t0 = time.perf_counter()
    boot = decompose(bucketize(g), op=args.engine)
    pub = SnapshotPublisher()
    pub.publish(g, boot.coreness)
    print(f"boot: n={g.n_nodes:,} m={g.n_edges:,} "
          f"k_max={int(boot.coreness.max(initial=0))} "
          f"decompose {time.perf_counter() - t0:.2f}s; serving")

    state = {"graph": g, "coreness": boot.coreness, "modes": {},
             "n_batches": 0, "error": None}
    stop = threading.Event()
    worker = threading.Thread(
        target=_update_loop,
        args=(pub, EditLogReader(args.edit_log), state),
        kwargs=dict(op=args.engine,
                    dirty_budget_frac=args.dirty_budget_frac,
                    max_batches=args.max_batches,
                    idle_timeout_s=args.idle_timeout_s,
                    poll_interval_s=args.poll_interval_s,
                    stop=stop),
        name=UPDATE_THREAD_NAME, daemon=True,
    )
    worker.start()

    rng = np.random.default_rng(args.seed)
    try:
        while worker.is_alive():
            snap = pub.snapshot
            ids = rng.integers(0, max(1, snap.n_nodes), args.query_batch)
            pub.query_coreness(ids)
            pub.query_in_kcore(ids[: max(1, args.query_batch // 4)],
                               max(1, snap.max_core // 2))
            pub.query_top_kcore()
            if not snap.verify():  # pragma: no cover - the torn-state alarm
                raise RuntimeError(f"torn snapshot v{snap.version}")
            worker.join(timeout=0.002)
    finally:
        stop.set()
        worker.join()
    if state["error"] is not None:
        raise state["error"]

    m = pub.metrics()
    m["batches_drained"] = state["n_batches"]
    m["update_modes"] = state["modes"]
    m["final_n_nodes"] = int(state["graph"].n_nodes)
    m["final_k_max"] = int(state["coreness"].max(initial=0))
    if args.json:
        print(json.dumps(m, sort_keys=True))
    else:
        print(f"drained {state['n_batches']} batch(es), modes={state['modes']}")
        print(f"updates/s = {m['updates_per_s']:.1f}  "
              f"publishes/s = {m['publishes_per_s']:.1f}  "
              f"queries = {m['n_queries']:,}")
        print(f"query latency p50 = {m['query_p50_ms']:.3f} ms  "
              f"p99 = {m['query_p99_ms']:.3f} ms")
        print(f"staleness: mean {m['staleness_mean_edits']:.1f} / "
              f"max {m['staleness_max_edits']:.0f} pending edits at query "
              f"time; {m['pending_edits']} still pending at exit")
    return m


if __name__ == "__main__":
    main()
