"""Coreness serving front end — incremental maintenance under query load.

  python -m repro.launch.kcore_serve --graph rmat:12:8 --edit-log /tmp/log
  python -m repro.launch.kcore_serve --graph ba:2000:5 --edit-log /tmp/log \
      --engine count --query-batch 256 --max-batches 50

Boots the graph, runs one full decompose, publishes the snapshot through
:class:`~repro.core.snapshot_pub.SnapshotPublisher`, then splits into two
roles: an update worker thread (named ``kcore-serve-update``) tails the
``--edit-log`` directory (:class:`~repro.graph.editlog.EditLogReader`,
EdgeStore chunk format), folds each sealed batch through
:func:`~repro.core.incremental.apply_updates`, and republishes; the main
thread plays query traffic (batched coreness lookups, k-core membership,
top-core) against whatever snapshot is currently published. The run drains
every sealed batch (stopping after ``--max-batches`` if set, or once the
log has been idle for ``--idle-timeout-s``) and prints the publisher's
metrics: updates/sec, publishes/sec, query p50/p99 latency, and staleness
(edits pending at query time, plus the maximum snapshot age observed by a
query). A transient ``apply_updates``/publish failure is retried in place
with exponential backoff (``--update-retries`` / ``--update-backoff-s``)
before it takes the worker down — the batch is already drained from the
log and ``apply_updates`` is pure over its inputs, so a retry is
idempotent. ``--stale-warn-s`` prints a warning the first time a query
sees a snapshot older than that; ``--fault serve_update:crash...``
injects failures into the update path for chaos testing (see
``repro.runtime.FaultPlan``).
"""
from __future__ import annotations

import argparse
import json
import threading
import time

import numpy as np

from repro.core.decompose import decompose
from repro.core.incremental import apply_updates
from repro.core.snapshot_pub import SnapshotPublisher
from repro.graph.build import bucketize
from repro.graph.editlog import EditLogReader
from repro.launch.kcore import load_graph

UPDATE_THREAD_NAME = "kcore-serve-update"


def _update_loop(
    pub: SnapshotPublisher,
    reader: EditLogReader,
    state: dict,
    *,
    op: str,
    dirty_budget_frac: float,
    max_batches: int | None,
    idle_timeout_s: float,
    poll_interval_s: float,
    stop: threading.Event,
    retries: int = 3,
    backoff_s: float = 0.05,
    fault_plan=None,
) -> None:
    def fold_and_publish(edits):
        # One retry unit: the edits are already drained from the log and
        # apply_updates is pure over (graph, coreness, edits), so rerunning
        # after a transient failure is idempotent. State is only committed
        # after publish succeeds.
        if fault_plan is not None:
            fault_plan.visit("serve_update", batch=state["n_batches"])
        res = apply_updates(
            state["graph"], state["coreness"], edits,
            op=op, dirty_budget_frac=dirty_budget_frac,
        )
        pub.publish(res.graph, res.coreness, n_edits=edits.n_raw)
        state["graph"], state["coreness"] = res.graph, res.coreness
        state["modes"][res.mode] = state["modes"].get(res.mode, 0) + 1
        state["n_batches"] += 1

    idle_since = time.perf_counter()
    try:
        while not stop.is_set():
            if reader.poll() == 0:
                if time.perf_counter() - idle_since > idle_timeout_s:
                    return
                time.sleep(poll_interval_s)
                continue
            edits = reader.read_batch()
            idle_since = time.perf_counter()
            pub.note_pending(edits.n_raw)
            attempt = 0
            while True:
                try:
                    fold_and_publish(edits)
                    break
                except Exception as exc:
                    attempt += 1
                    if attempt > retries or stop.is_set():
                        raise
                    state["update_retries"] += 1
                    print(f"update batch failed ({exc!r}); "
                          f"retry {attempt}/{retries}")
                    time.sleep(backoff_s * (2 ** (attempt - 1)))
            if max_batches is not None and state["n_batches"] >= max_batches:
                return
    except Exception as exc:  # surfaced as the CLI's exit error
        state["error"] = exc


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--graph", default="rmat:12:8")
    ap.add_argument("--edit-log", required=True,
                    help="EditLog directory to tail (EdgeStore slot format)")
    ap.add_argument("--engine", choices=["sorted", "count", "kernel", "fused"],
                    default="count", help="sweep engine for re-sweeps")
    ap.add_argument("--dirty-budget-frac", type=float, default=0.5,
                    help="dirty-region fraction beyond which an update "
                         "falls back to a full re-sweep")
    ap.add_argument("--query-batch", type=int, default=128,
                    help="node ids per batched coreness query")
    ap.add_argument("--max-batches", type=int, default=None,
                    help="stop after draining this many sealed batches")
    ap.add_argument("--idle-timeout-s", type=float, default=1.0,
                    help="exit once the log has been idle this long")
    ap.add_argument("--poll-interval-s", type=float, default=0.01)
    ap.add_argument("--update-retries", type=int, default=3,
                    help="retry a failed update batch this many times with "
                         "exponential backoff before exiting")
    ap.add_argument("--update-backoff-s", type=float, default=0.05,
                    help="base backoff between update retries (doubles "
                         "per attempt)")
    ap.add_argument("--stale-warn-s", type=float, default=None,
                    help="warn when a query observes a snapshot older "
                         "than this many seconds")
    ap.add_argument("--fault", action="append", default=[], metavar="SPEC",
                    help="inject a failure: site:kind[:at[:count[:delay]]] "
                         "(chaos testing; the update worker visits the "
                         "serve_update site per batch)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--json", action="store_true",
                    help="emit the final metrics as one JSON line")
    args = ap.parse_args(argv)

    g, _ = load_graph(args.graph, args.seed)
    t0 = time.perf_counter()
    boot = decompose(bucketize(g), op=args.engine)
    pub = SnapshotPublisher()
    pub.publish(g, boot.coreness)
    print(f"boot: n={g.n_nodes:,} m={g.n_edges:,} "
          f"k_max={int(boot.coreness.max(initial=0))} "
          f"decompose {time.perf_counter() - t0:.2f}s; serving")

    fault_plan = None
    if args.fault:
        from repro.runtime import FaultPlan

        fault_plan = FaultPlan.parse(args.fault)

    state = {"graph": g, "coreness": boot.coreness, "modes": {},
             "n_batches": 0, "error": None, "update_retries": 0}
    stop = threading.Event()
    worker = threading.Thread(
        target=_update_loop,
        args=(pub, EditLogReader(args.edit_log), state),
        kwargs=dict(op=args.engine,
                    dirty_budget_frac=args.dirty_budget_frac,
                    max_batches=args.max_batches,
                    idle_timeout_s=args.idle_timeout_s,
                    poll_interval_s=args.poll_interval_s,
                    stop=stop,
                    retries=args.update_retries,
                    backoff_s=args.update_backoff_s,
                    fault_plan=fault_plan),
        name=UPDATE_THREAD_NAME, daemon=True,
    )
    worker.start()

    rng = np.random.default_rng(args.seed)
    max_age_s = 0.0
    stale_warned = False
    try:
        while worker.is_alive():
            snap = pub.snapshot
            age_s = time.perf_counter() - snap.published_at
            max_age_s = max(max_age_s, age_s)
            if (args.stale_warn_s is not None and not stale_warned
                    and age_s > args.stale_warn_s):
                stale_warned = True
                print(f"WARNING: serving a snapshot {age_s:.2f}s old "
                      f"(v{snap.version}; threshold {args.stale_warn_s}s)")
            ids = rng.integers(0, max(1, snap.n_nodes), args.query_batch)
            pub.query_coreness(ids)
            pub.query_in_kcore(ids[: max(1, args.query_batch // 4)],
                               max(1, snap.max_core // 2))
            pub.query_top_kcore()
            if not snap.verify():  # pragma: no cover - the torn-state alarm
                raise RuntimeError(f"torn snapshot v{snap.version}")
            worker.join(timeout=0.002)
    finally:
        stop.set()
        if fault_plan is not None:
            fault_plan.release()  # wake any injected hang so join returns
        worker.join()
    if state["error"] is not None:
        raise state["error"]

    m = pub.metrics()
    m["batches_drained"] = state["n_batches"]
    m["update_modes"] = state["modes"]
    m["update_retries"] = state["update_retries"]
    m["staleness_max_age_s"] = max_age_s
    m["final_n_nodes"] = int(state["graph"].n_nodes)
    m["final_k_max"] = int(state["coreness"].max(initial=0))
    if args.json:
        print(json.dumps(m, sort_keys=True))
    else:
        print(f"drained {state['n_batches']} batch(es), modes={state['modes']}")
        print(f"updates/s = {m['updates_per_s']:.1f}  "
              f"publishes/s = {m['publishes_per_s']:.1f}  "
              f"queries = {m['n_queries']:,}")
        print(f"query latency p50 = {m['query_p50_ms']:.3f} ms  "
              f"p99 = {m['query_p99_ms']:.3f} ms")
        print(f"staleness: mean {m['staleness_mean_edits']:.1f} / "
              f"max {m['staleness_max_edits']:.0f} pending edits at query "
              f"time; {m['pending_edits']} still pending at exit; "
              f"max snapshot age {m['staleness_max_age_s']:.2f}s")
        if m["update_retries"]:
            print(f"update worker: {m['update_retries']} transient "
                  f"failure(s) retried")
    return m


if __name__ == "__main__":
    main()
