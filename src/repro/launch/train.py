"""Training launcher: ``python -m repro.launch.train --arch <id> [...]``.

Runs real training on whatever devices exist (CPU here; TPU pods on the
target). ``--smoke`` selects the reduced config; the FULL configs are meant
for the production meshes (exercised via the dry-run on this container).
"""
from __future__ import annotations

import argparse

import jax

from repro.configs import get_config, get_smoke_config
from repro.data import SyntheticTokens
from repro.models.model import build_specs
from repro.models.module import count_params, init_params
from repro.optim import get_optimizer
from repro.runtime import TrainLoop


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true", help="reduced config (CPU)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    specs = build_specs(cfg)
    print(f"{cfg.name}: {count_params(specs)/1e6:.1f}M params, "
          f"{len(jax.devices())} device(s)")
    params = init_params(specs, jax.random.PRNGKey(args.seed))
    data = SyntheticTokens(
        vocab_size=cfg.vocab_size, seq_len=args.seq, batch=args.batch, seed=args.seed
    )
    loop = TrainLoop(
        cfg=cfg, params=params,
        optimizer=get_optimizer(cfg, lr=args.lr, total=args.steps),
        data=data, ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every,
    )
    if args.resume and loop.try_resume():
        print(f"resumed from step {loop.step}")
    hist = loop.run(args.steps, log_every=max(1, args.steps // 20))
    for s, l, t in zip(hist["step"], hist["loss"], hist["tokens_per_s"]):
        print(f"step {s:6d}  loss {l:8.4f}  {t:9.0f} tok/s")


if __name__ == "__main__":
    main()
