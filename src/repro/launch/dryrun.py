import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
"""Multi-pod dry-run (deliverable e).

For every (architecture x input shape) cell, on the single-pod 16x16 mesh
AND the 2x16x16 multi-pod mesh:

    with mesh:
        lowered  = jax.jit(step, in_shardings=..., out_shardings=...).lower(**input_specs(arch))
        compiled = lowered.compile()
        print(compiled.memory_analysis())   # proves it fits 16 GB/chip
        print(compiled.cost_analysis())     # FLOPs/bytes for the roofline

plus collective-byte extraction from the post-SPMD HLO. One JSON artifact
per cell lands in ``benchmarks/artifacts/dryrun/`` — the roofline tables in
EXPERIMENTS.md and ``benchmarks/bench_dryrun.py`` read from there.

Usage:
    python -m repro.launch.dryrun --arch qwen3-8b --shape train_4k
    python -m repro.launch.dryrun --all                  # single-pod pass
    python -m repro.launch.dryrun --all --multi-pod      # 512-chip pass
"""
import argparse
import json
import time
import traceback

import jax

from repro.compat import cost_analysis_dict
from repro.configs import SHAPES, cells
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import input_specs, rules_for
from repro.launch.steps import step_fn_for
from repro.models.model import build_specs
from repro.models.module import count_params
from repro.roofline import hw
from repro.roofline import flops_model
from repro.roofline.analysis import (
    active_params,
    model_flops,
    parse_collectives,
    roofline_terms,
)
from repro.sharding.policy import active_mesh, dp_size

MICRO_PER_DEVICE = 2  # target per-device microbatch rows for train cells
BIG_MODEL_PARAMS = 50e9  # above this, microbatch 1 row/device (stash budget)

ARTIFACT_DIR = os.path.join(
    os.path.dirname(os.path.dirname(os.path.dirname(os.path.dirname(os.path.abspath(__file__))))),
    "benchmarks", "artifacts", "dryrun",
)


def run_cell(arch: str, shape_name: str, multi_pod: bool, rules=None,
             artifact_dir: str = ARTIFACT_DIR, tag: str = "",
             accum_override: int = None, grad_constrain: bool = False,
             accum_dtype=None) -> dict:
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh.size
    shape = SHAPES[shape_name]
    t0 = time.perf_counter()
    specs, cfg, log = input_specs(arch, shape_name, mesh, rules=rules)
    the_rules = rules or rules_for(cfg, shape_name)
    n_params = count_params(build_specs(cfg))
    accum = 1
    if shape.kind == "train":
        per_dev = max(1, shape.global_batch // dp_size(mesh, the_rules))
        micro = 1 if n_params > BIG_MODEL_PARAMS else MICRO_PER_DEVICE
        accum = max(1, per_dev // micro)
        if accum_override:
            accum = accum_override
    grad_shardings = None
    if grad_constrain:
        grad_shardings = jax.tree.map(lambda s: s.sharding, specs["params"])
    fn, order = step_fn_for(
        cfg, shape.kind, accum_steps=accum, grad_shardings=grad_shardings,
        accum_dtype=accum_dtype,
    )
    kwargs = {k: specs[k] for k in order}

    with mesh, active_mesh(mesh, the_rules):
        lowered = jax.jit(fn).lower(**kwargs)
        t_lower = time.perf_counter() - t0
        compiled = lowered.compile()
        t_compile = time.perf_counter() - t0 - t_lower

    mem = compiled.memory_analysis()
    cost = cost_analysis_dict(compiled)
    print(mem)
    print({k: v for k, v in cost.items() if k in ("flops", "bytes accessed")})
    hlo = compiled.as_text()
    colls = parse_collectives(hlo)

    # HLO cost_analysis counts scan bodies once (loop-blind); the roofline
    # compute/memory terms come from the analytic model instead, which
    # tests validate against unrolled HLO. Collectives are loop-corrected
    # by parse_collectives.
    hlo_flops_dev = float(cost.get("flops", 0.0))
    hlo_bytes_dev = float(cost.get("bytes accessed", 0.0))
    n_active = active_params(cfg)
    mflops = model_flops(cfg, shape, n_params, n_active)
    analytic = flops_model.cost(
        cfg, shape, n_params, n_chips, remat=(shape.kind == "train")
    )
    flops_dev = analytic.flops_total / n_chips
    bytes_dev = analytic.hbm_bytes_per_device
    rl = roofline_terms(flops_dev, bytes_dev, colls.total_wire, n_chips, mflops)

    per_dev_hbm = (
        mem.argument_size_in_bytes + mem.output_size_in_bytes + mem.temp_size_in_bytes
    )
    mem_model = flops_model.device_memory_model(
        cfg, shape, n_params, n_chips, dp_size(mesh, the_rules), accum
    )
    record = {
        "arch": arch,
        "shape": shape_name,
        "kind": shape.kind,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "n_chips": n_chips,
        "params": n_params,
        "active_params": n_active,
        "accum_steps": accum,
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "flops_per_device": flops_dev,
        "bytes_per_device": bytes_dev,
        "hlo_flops_per_device_loopblind": hlo_flops_dev,
        "hlo_bytes_per_device_loopblind": hlo_bytes_dev,
        "analytic_detail": analytic.detail,
        "memory_analysis": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
            "cpu_backend_peak_bytes": per_dev_hbm,
        },
        # TPU-faithful analytic budget (CPU temp includes scatter-expander /
        # convert-hoist artifacts absent on the target; see flops_model).
        "memory_model": mem_model,
        "fits_16gb": bool(mem_model["total"] < hw.HBM_BYTES),
        "collectives": {
            "count": colls.count,
            "raw_bytes": colls.op_bytes,
            "wire_bytes": colls.wire_bytes,
            "total_wire_bytes": colls.total_wire,
        },
        "roofline": rl.as_dict(),
        "replicated_fallbacks": [
            {"axes": list(map(str, a)), "dim": d, "size": s, "axis_size": m}
            for (a, d, s, m) in log.replicated
        ],
    }
    os.makedirs(artifact_dir, exist_ok=True)
    fname = f"{arch}__{shape_name}__{record['mesh']}{tag}.json"
    with open(os.path.join(artifact_dir, fname), "w") as f:
        json.dump(record, f, indent=1)
    return record


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--artifact-dir", default=ARTIFACT_DIR)
    ap.add_argument("--tag", default="", help="artifact filename suffix (perf variants)")
    ap.add_argument("--accum", type=int, default=None)
    ap.add_argument("--grad-constrain", action="store_true")
    ap.add_argument("--accum-dtype", choices=["f32", "bf16"], default=None)
    ap.add_argument("--rules", choices=["default", "serve"], default="default")
    args = ap.parse_args()

    import jax.numpy as jnp
    accum_dtype = {None: None, "f32": jnp.float32, "bf16": jnp.bfloat16}[args.accum_dtype]
    rules_override = None
    if args.rules == "serve":
        from repro.sharding.policy import SERVE_RULES
        rules_override = dict(SERVE_RULES)

    todo = []
    if args.all:
        todo = cells()
    else:
        assert args.arch and args.shape, "--arch and --shape (or --all)"
        todo = [(args.arch, args.shape)]
    meshes = [args.multi_pod] if not args.both_meshes else [False, True]

    failures = []
    for arch, shape_name in todo:
        for mp in meshes:
            label = f"{arch} x {shape_name} x {'2x16x16' if mp else '16x16'}"
            print(f"=== {label} ===", flush=True)
            try:
                rec = run_cell(
                    arch, shape_name, mp, rules=rules_override,
                    artifact_dir=args.artifact_dir, tag=args.tag,
                    accum_override=args.accum,
                    grad_constrain=args.grad_constrain,
                    accum_dtype=accum_dtype,
                )
                rl = rec["roofline"]
                print(
                    f"  ok: compute={rl['compute_s']:.4g}s memory={rl['memory_s']:.4g}s "
                    f"collective={rl['collective_s']:.4g}s bottleneck={rl['bottleneck']} "
                    f"(lower {rec['lower_s']}s, compile {rec['compile_s']}s)",
                    flush=True,
                )
            except Exception as e:  # noqa: BLE001
                failures.append((label, repr(e)))
                traceback.print_exc()
    if failures:
        print(f"\n{len(failures)} FAILURES:")
        for label, err in failures:
            print(" ", label, err)
        raise SystemExit(1)
    print("\nall dry-run cells compiled OK")


if __name__ == "__main__":
    main()
