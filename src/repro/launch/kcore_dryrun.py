import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
"""Dry-run of the DISTRIBUTED K-CORE sweep at the paper's true scales.

The paper's graphs (com-friendster 1.8B, WX-15B, WX-136B edges) cannot be
materialized here, but the shard_map sweep can be lowered and compiled from
ShapeDtypeStruct stand-ins exactly like the LM dry-run: bucket shapes come
from a power-law degree model calibrated to (n, m). This reproduces the
paper's central scalability claim on the TPU mesh:

  * WX-136B **monolithic** (the PSGraph baseline): node ids exceed int32 and
    the replicated coreness + ext vectors alone need ~18 GiB/chip -> does
    NOT fit the 16 GiB v5e budget. (Paper: "PSGraph fails WX-136B".)
  * WX-136B **divided** (Rough-Divide at t=250, the paper's threshold): the
    top part is small; the rest part fits int32 ids and — with the int16
    coreness wire — the 16 GiB budget. (Paper: DC-kCore completes WX-136B.)

Usage:
    python -m repro.launch.kcore_dryrun [--wire int16] [--cand 2048]
"""
import argparse
import dataclasses
import json
import math
import time

import numpy as np

ARTIFACT_DIR = os.path.join(
    os.path.dirname(os.path.dirname(os.path.dirname(os.path.dirname(os.path.abspath(__file__))))),
    "benchmarks", "artifacts", "kcore",
)

# (name, n_nodes, n_edges, divide_threshold, k_max from the paper)
WORKLOADS = {
    "com-friendster": (65_608_366, 1_806_067_135, 80, 304),
    "WX-15B": (646_408_482, 15_179_911_593, 100, 401),
    "WX-136B": (2_226_845_928, 136_588_315_957, 250, 1_179),
}


def powerlaw_bucket_rows(n: int, m: int, max_width: int = 1 << 20):
    """Rows per power-of-two degree bucket for a power-law degree model
    calibrated so the mean degree matches 2m/n. Hub nodes above max_width
    are assumed degree-split (standard virtual-node trick; documented)."""
    mu = 2 * m / n
    # discrete P(d) ~ d^-alpha on [1, max_width]; solve alpha for mean mu.
    ds = np.arange(1, max_width + 1, dtype=np.float64)

    def mean_for(alpha):
        w = ds ** (-alpha)
        return float((ds * w).sum() / w.sum())

    lo, hi = 1.05, 3.5
    for _ in range(60):
        mid = (lo + hi) / 2
        if mean_for(mid) > mu:
            lo = mid
        else:
            hi = mid
    alpha = (lo + hi) / 2
    w = ds ** (-alpha)
    p = w / w.sum()
    buckets = []
    width = 8
    lo_d = 1
    while lo_d <= max_width:
        hi_d = min(width, max_width)
        frac = p[lo_d - 1 : hi_d].sum()
        rows = int(n * frac)
        if rows > 0:
            buckets.append((width, rows))
        lo_d = width + 1
        width *= 2
    return alpha, buckets


def degseq_hindex(buckets) -> int:
    """h-index of the modeled degree sequence (candidate window bound)."""
    best = 0
    for h in [8, 16, 32, 64, 128, 256, 512, 1024, 2048, 4096, 8192, 16384, 32768]:
        cnt = sum(rows for width, rows in buckets if width >= h)
        if cnt >= h:
            best = h
    return best


def build_specs_for(n: int, buckets, plan, wire_dtype, id_dtype):
    import jax
    import jax.numpy as jnp

    ns, ms = plan.n_node_shards, plan.n_slot_shards
    bucket_specs = []
    for width, rows in buckets:
        rows_p = max(ns, int(math.ceil(rows / ns)) * ns)
        width_p = max(ms * 8, int(math.ceil(width / ms)) * ms)
        bucket_specs.append(
            (
                jax.ShapeDtypeStruct((rows_p,), jnp.int32),
                jax.ShapeDtypeStruct((rows_p, width_p), id_dtype),
            )
        )
    c = jax.ShapeDtypeStruct((n + 1,), wire_dtype)
    ext = jax.ShapeDtypeStruct((n + 1,), jnp.int32)
    # Frontier plumbing: the mask models a full sweep (all buckets active)
    # at compile time; node_tile is the replicated int16 node -> bucket map
    # (bucket counts are tiny; 2 bytes/node, same class as the int16 wire).
    active = jax.ShapeDtypeStruct((len(bucket_specs),), jnp.bool_)
    node_tile = jax.ShapeDtypeStruct((n + 1,), jnp.int16)
    return c, ext, active, node_tile, bucket_specs


def run_case(name, n, m, cand, wire, multi_pod=True, tag="", n_iters=30):
    import jax
    import jax.numpy as jnp

    from repro.compat import cost_analysis_dict
    from repro.core.distributed import (
        MeshPlan,
        make_sweep_fn,
        planned_collective_schedule,
    )
    from repro.launch.mesh import make_production_mesh
    from repro.roofline import hw
    from repro.roofline.analysis import parse_collectives, roofline_terms

    mesh = make_production_mesh(multi_pod=multi_pod)
    node_axes = ("pod", "data") if multi_pod else ("data",)
    plan = MeshPlan(mesh=mesh, node_axes=node_axes, slot_axes=("model",))
    alpha, buckets = powerlaw_bucket_rows(n, m)
    wire_dtype = jnp.int16 if wire == "int16" else jnp.int32
    id_dtype = jnp.int32 if n < 2**31 else jnp.int64

    # Feasibility: replicated state + sharded tiles per device.
    id_bytes = 4 if id_dtype == jnp.int32 else 8
    wire_bytes = 2 if wire == "int16" else 4
    slots = sum(r * max(8, w) for w, r in buckets)
    tiles_dev = slots * id_bytes / mesh.size
    # coreness (wire) + ext (int16) + frontier node->bucket map (int16)
    state_dev = (n + 1) * (wire_bytes + 2 + 2)
    total_dev = tiles_dev + state_dev + 512 * 2**20
    fits = total_dev < hw.HBM_BYTES
    rec = {
        "case": f"{name}{tag}",
        "n": n,
        "m": m,
        "alpha": round(alpha, 3),
        "mesh": "2x16x16" if multi_pod else "16x16",
        "cand": cand,
        "wire": wire,
        "id_dtype": str(id_dtype.__name__),
        "memory_model": {
            "tiles_dev": tiles_dev,
            "state_dev": state_dev,
            "total_dev": total_dev,
        },
        "fits_16gb": bool(fits),
    }
    # Modeled collective traffic: a dry run never sweeps, so the table
    # derives per-iteration ICI bytes from the planned frontier schedule
    # over the modeled bucket shapes — same per-bucket ring formula as the
    # live engine's measured counter (see planned_collective_schedule; the
    # pinning test holds the two together). Reported even for infeasible
    # layouts: the formula only needs shapes.
    sched = planned_collective_schedule(
        [r for _w, r in buckets], plan, cand,
        wire_bytes=wire_bytes, n_iters=n_iters,
    )
    rec["modeled_collectives"] = {
        "n_iters": n_iters,
        "first_sweep_bytes": sched[0],
        "total_bytes": sum(sched),
        "per_iter_bytes": sched,
    }
    if n + 1 >= 2**31:
        # int64 ids double the tile bytes AND overflow JAX's int32 scatter
        # paths — the monolithic 2.2B-node layout is infeasible outright;
        # the divide step is what brings every part under 2^31 ids.
        rec["fits_16gb"] = False
        rec["skipped_compile"] = "node ids exceed int32 (monolithic 2.2B-node layout)"
        _dump(rec)
        return rec
    if not fits:
        rec["skipped_compile"] = "exceeds per-device HBM — infeasible layout"
        _dump(rec)
        return rec

    c, ext, active, node_tile, bucket_specs = build_specs_for(
        n, buckets, plan, wire_dtype, id_dtype
    )
    sweep = make_sweep_fn(plan, cand, wire_dtype)(len(bucket_specs))
    t0 = time.perf_counter()
    with mesh:
        lowered = sweep.lower(c, ext, active, node_tile, bucket_specs)
        compiled = lowered.compile()
    rec["compile_s"] = round(time.perf_counter() - t0, 1)
    mem = compiled.memory_analysis()
    cost = cost_analysis_dict(compiled)
    colls = parse_collectives(compiled.as_text())
    rl = roofline_terms(
        float(cost.get("flops", 0.0)),
        float(cost.get("bytes accessed", 0.0)),
        colls.total_wire,
        mesh.size,
    )
    rec["xla_temp_bytes"] = mem.temp_size_in_bytes
    rec["collectives"] = {"wire_bytes": colls.wire_bytes, "count": colls.count}
    rec["roofline"] = rl.as_dict()
    _dump(rec)
    return rec


def _dump(rec):
    os.makedirs(ARTIFACT_DIR, exist_ok=True)
    path = os.path.join(ARTIFACT_DIR, f"{rec['case']}__{rec['mesh']}.json")
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)
    rl = rec.get("roofline")
    extra = (
        f"compute={rl['compute_s']:.4g}s memory={rl['memory_s']:.4g}s "
        f"collective={rl['collective_s']:.4g}s [{rl['bottleneck']}]"
        if rl
        else rec.get("skipped_compile", "")
    )
    mc = rec.get("modeled_collectives")
    coll = (
        f"coll/iter0={mc['first_sweep_bytes']/2**30:.3f}GiB "
        f"coll_total={mc['total_bytes']/2**30:.2f}GiB "
        if mc else ""
    )
    print(
        f"{rec['case']:34s} mesh={rec['mesh']} fits16g={rec['fits_16gb']} "
        f"dev_mem={rec['memory_model']['total_dev']/2**30:.1f}GiB {coll}{extra}",
        flush=True,
    )


def run_split3(name, n, m, t, kmax, wire, tag=""):
    """Recursive Rough-Divide into 3 parts (paper §5.6): the TPU id/memory
    budget forces more parts for WX-136B than the paper's CPU cluster used.
    Part sizes are modeled from the degree buckets (in-part adjacency is
    conservatively the full bucket width)."""
    _alpha, buckets = powerlaw_bucket_rows(n, m)
    top = [(w, r) for w, r in buckets if w >= 2 * t]
    mid = [(w, r) for w, r in buckets if 8 < w < 2 * t]
    bot = [(w, r) for w, r in buckets if w <= 8]
    for label, part, cand in [
        (f"top(t={t})", top, min(2 * kmax, 4096)),
        (f"mid(8<d<{t})", mid, t),
        ("bottom(d<=8)", bot, 8),
    ]:
        pn = sum(r for _w, r in part)
        pm = sum(r * w for w, r in part) // 2
        run_case(f"{name}-3p-{label}", max(pn, 1 << 20), max(pm, 1 << 22), cand,
                 wire, multi_pod=True, tag=tag)


def run_slices(name, n, m, t, kmax, wire, n_slices, tag=""):
    """Part-parallel schedule table: price the 3-part split's parts with
    the production scheduler (``part_cost`` + ``assign_parts``) on the
    single-pod 16x16 mesh divided into ``n_slices`` slices along "data".
    Pure planning-layer math — no devices are touched, so this prints the
    same placement the live part-parallel engine would compute."""
    from repro.core.partsched import SliceSpec, assign_parts, part_cost

    node_shards, slot_shards = 16, 16
    if node_shards % n_slices != 0:
        raise SystemExit(f"--slices must divide the {node_shards}-way node axis")
    specs = [
        SliceSpec(index=i, n_node_shards=node_shards // n_slices,
                  n_slot_shards=slot_shards)
        for i in range(n_slices)
    ]
    wire_bytes = 2 if wire == "int16" else 4
    _alpha, buckets = powerlaw_bucket_rows(n, m)
    splits = [
        (f"top(t={t})", [(w, r) for w, r in buckets if w >= 2 * t],
         min(2 * kmax, 4096)),
        (f"mid(8<d<{t})", [(w, r) for w, r in buckets if 8 < w < 2 * t], t),
        ("bottom(d<=8)", [(w, r) for w, r in buckets if w <= 8], 8),
    ]
    costs, labels = [], {}
    for cursor, (label, part, cand) in enumerate(splits):
        shapes = [(r, w) for w, r in part]
        pn = max(sum(r for _w, r in part), 1)
        c = part_cost(shapes, cand, pn, specs[0], wire_bytes=wire_bytes)
        costs.append(dataclasses.replace(c, cursor=cursor))
        labels[cursor] = label
    sched = assign_parts(costs, specs)
    loads = sched.slice_loads()
    peak = max(loads) or 1
    print(f"\n{name}{tag}: 3-part split on 16x16 / {n_slices} slices "
          f"({specs[0].n_node_shards}x{specs[0].n_slot_shards} each, wire={wire})")
    for a in sched.assignments:
        c = a.cost
        print(f"  part {a.cursor} {labels[a.cursor]:16s} -> slice {a.slice_index}  "
              f"coll={c.collective_bytes/2**30:8.2f}GiB  "
              f"hbm/dev={c.hbm_bytes/2**30:8.2f}GiB  "
              f"resident/dev={c.part_bytes/2**30:6.2f}GiB")
    for i, load in enumerate(loads):
        bar = "#" * int(40 * load / peak)
        print(f"  slice {i}: modeled {load/2**30:10.2f}GiB  "
              f"util={load/peak:5.1%}  {bar}")
    rec = {
        "case": f"{name}{tag}-slices{n_slices}",
        "mesh": "16x16",
        "n_slices": n_slices,
        "wire": wire,
        "decisions": [{**d, "label": labels[d["cursor"]]}
                      for d in sched.decisions()],
        "slice_loads": loads,
        "slice_utilization": [load / peak for load in loads],
    }
    os.makedirs(ARTIFACT_DIR, exist_ok=True)
    with open(os.path.join(ARTIFACT_DIR, f"{rec['case']}__16x16.json"), "w") as f:
        json.dump(rec, f, indent=1)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--wire", choices=["int32", "int16"], default="int32")
    ap.add_argument("--cand", type=int, default=None, help="candidate window")
    ap.add_argument("--tag", default="")
    ap.add_argument("--case", default=None)
    ap.add_argument("--split3", action="store_true")
    ap.add_argument("--mono-only", action="store_true")
    ap.add_argument("--slices", type=int, default=None,
                    help="print the part-parallel schedule table for the "
                         "3-part split across N mesh slices (planning only)")
    args = ap.parse_args()

    for name, (n, m, t, kmax) in WORKLOADS.items():
        if args.case and args.case != name:
            continue
        if args.slices:
            run_slices(name, n, m, t, kmax, args.wire, args.slices, tag=args.tag)
            continue
        if args.split3:
            run_split3(name, n, m, t, kmax, args.wire, tag=args.tag)
            continue
        _alpha, buckets = powerlaw_bucket_rows(n, m)
        cand = args.cand or degseq_hindex(buckets)
        # Monolithic (PSGraph baseline).
        run_case(name, n, m, cand, args.wire, multi_pod=True, tag=args.tag + "-mono")
        if args.mono_only:
            continue
        # Rough-Divide at the paper's threshold: top part (deg >= t) and the
        # rest (modeled sizes: nodes with modeled degree >= t go to the top).
        top_n = sum(r for w, r in buckets if w >= t)
        top_m = sum(r * min(w, 4 * t) for w, r in buckets if w >= t) // 2
        rest_n, rest_m = n - top_n, m - top_m
        run_case(f"{name}-top(t={t})", max(top_n, 1 << 20), max(top_m, 1 << 22),
                 min(cand, kmax * 2), args.wire, multi_pod=True, tag=args.tag)
        run_case(f"{name}-rest(t={t})", rest_n, rest_m, min(cand, t),
                 args.wire, multi_pod=True, tag=args.tag)


if __name__ == "__main__":
    main()
