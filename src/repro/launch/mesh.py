"""Production meshes.

Defined as FUNCTIONS so importing this module never touches jax device
state (the dry-run must set XLA_FLAGS before any device query).

Single pod: 16x16 = 256 v5e chips, axes ("data", "model").
Multi-pod:  2 x 16 x 16 = 512 chips, axes ("pod", "data", "model") — the
"pod" axis carries only data parallelism (gradient all-reduce), keeping
cross-pod (DCN-class) traffic minimal.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes)
    )


def make_mesh_for_devices(n: int, model_parallel: int = 1, axis_names=("data", "model")):
    """Small helper for tests / examples on N local (virtual) devices."""
    assert n % model_parallel == 0
    return jax.make_mesh(
        (n // model_parallel, model_parallel),
        axis_names,
        axis_types=(jax.sharding.AxisType.Auto,) * 2,
    )
