"""Production meshes.

Defined as FUNCTIONS so importing this module never touches jax device
state (the dry-run must set XLA_FLAGS before any device query).

All mesh construction routes through :func:`repro.compat.make_mesh`, the
version-portable helper (``axis_types=Auto`` where supported, omitted on
JAX 0.4.x which has no ``jax.sharding.AxisType``).

Single pod: 16x16 = 256 v5e chips, axes ("data", "model").
Multi-pod:  2 x 16 x 16 = 512 chips, axes ("pod", "data", "model") — the
"pod" axis carries only data parallelism (gradient all-reduce), keeping
cross-pod (DCN-class) traffic minimal.
"""
from __future__ import annotations

from repro.compat import make_mesh


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_mesh_for_devices(n: int, model_parallel: int = 1, axis_names=("data", "model")):
    """Small helper for tests / examples on N local (virtual) devices."""
    assert n % model_parallel == 0
    return make_mesh((n // model_parallel, model_parallel), axis_names)


def make_mesh_plan_for_devices(n: int, model_parallel: int = 1):
    """A :class:`~repro.core.distributed.MeshPlan` over ``n`` local devices:
    rows sharded over ``"data"``, neighbor slots over ``"model"`` — the
    layout the part-parallel scheduler slices along its first node axis."""
    from repro.core.distributed import MeshPlan

    return MeshPlan(
        mesh=make_mesh_for_devices(n, model_parallel),
        node_axes=("data",),
        slot_axes=("model",),
    )


def force_host_device_count(n: int) -> None:
    """Make the CPU host expose ``n`` virtual devices (test/emulation
    backend for part-parallel runs) by rewriting ``XLA_FLAGS``.

    Must run BEFORE jax instantiates a backend — the flag is read once at
    backend init, so a late call would silently do nothing; this raises
    instead (via :func:`repro.compat.backends_initialized`). Any previous
    ``--xla_force_host_platform_device_count`` token is dropped so repeated
    calls don't accumulate contradictory flags.
    """
    import os

    from repro.compat import backends_initialized

    if backends_initialized():
        raise RuntimeError(
            "force_host_device_count must be called before jax initializes "
            "its backends (the flag is read once at backend init)"
        )
    kept = [
        t for t in os.environ.get("XLA_FLAGS", "").split()
        if not t.startswith("--xla_force_host_platform_device_count")
    ]
    kept.append(f"--xla_force_host_platform_device_count={int(n)}")
    os.environ["XLA_FLAGS"] = " ".join(kept)


def init_multiprocess(
    coordinator_address: str,
    num_processes: int,
    process_id: int,
    local_device_ids=None,
) -> None:
    """Join this process to a multi-process jax mesh (one host per mesh
    slice in the part-parallel deployment story). Thin wrapper over
    :func:`repro.compat.distributed_initialize` so the version-sensitive
    call stays in the compat layer; after it returns, ``jax.devices()``
    spans every process and the global MeshPlan can be built as usual."""
    from repro.compat import distributed_initialize

    distributed_initialize(
        coordinator_address, num_processes, process_id, local_device_ids
    )
