"""Production meshes.

Defined as FUNCTIONS so importing this module never touches jax device
state (the dry-run must set XLA_FLAGS before any device query).

All mesh construction routes through :func:`repro.compat.make_mesh`, the
version-portable helper (``axis_types=Auto`` where supported, omitted on
JAX 0.4.x which has no ``jax.sharding.AxisType``).

Single pod: 16x16 = 256 v5e chips, axes ("data", "model").
Multi-pod:  2 x 16 x 16 = 512 chips, axes ("pod", "data", "model") — the
"pod" axis carries only data parallelism (gradient all-reduce), keeping
cross-pod (DCN-class) traffic minimal.
"""
from __future__ import annotations

from repro.compat import make_mesh


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_mesh_for_devices(n: int, model_parallel: int = 1, axis_names=("data", "model")):
    """Small helper for tests / examples on N local (virtual) devices."""
    assert n % model_parallel == 0
    return make_mesh((n // model_parallel, model_parallel), axis_names)
