"""DC-kCore launcher — the paper's workload as a CLI.

  python -m repro.launch.kcore --graph rmat:18:16 --thresholds 16,64
  python -m repro.launch.kcore --graph file:/data/com-friendster.txt \
      --budget-gb 2 --strategy rough --edge-chunk 1048576 --check
  python -m repro.launch.kcore --graph rmat:14:12 --reorder rcm --check
  python -m repro.launch.kcore --graph rmat:14:12 --thresholds 16 \
      --checkpoint-dir /tmp/kcore-ck --resume

Graphs: ``rmat:<scale>:<edge_factor>``, ``ba:<n>:<m>``, ``er:<n>:<deg>``,
``file:<path>`` (SNAP edge list), ``npz:<path>``.

``--edge-chunk N`` routes ingest through the streaming path: ``file:``
graphs are read in N-edge chunks and built via the spill-to-disk external
dedup (synthetic graphs are re-streamed through the same builder), and the
CLI reports the tracked peak transient host bytes next to the in-memory
loader's baseline. ``--divide-chunk N`` sizes the chunked divide passes
(adjacency slots of transient per extraction chunk; the divide step is
always chunk-bounded — this only overrides the default budget), with each
part's observed peak in the report table. ``--checkpoint-dir`` saves the
pipeline state after every part (atomic, ``.tmp``-then-rename);
``--sweep-checkpoint-every K`` additionally snapshots the conquer state
every K sweeps, so ``--resume`` re-enters a killed run *mid-part* at the
last completed sweep (falling back to the part boundary when no valid
snapshot exists). ``--overlap`` turns on the staged pipeline — the next
part's divide runs on a worker thread and checkpoint saves go async while
the current part sweeps; coreness is byte-identical either way, and the
summary reports the accelerator-idle fraction the flag exists to shrink.
``--reorder {identity,bfs,rcm}`` applies
a locality-aware node ordering to each part before tiling
(``--reorder-sample N`` computes it from an N-slot edge sample);
``--max-bucket-rows`` overrides the tile autotuner with a uniform row cap
(``auto`` = degree-profile autotuner, ``none`` = one tile per degree
class). ``--engine {sorted,count,kernel,fused}`` selects the conquer
sweep engine — ``fused`` is the single-kernel Pallas sweep (gather +
h-index + dirty push fused per row tile; interpret mode on CPU) — and
``--int16`` opts the fused engine into the halved-width estimate mode
(falls back to int32 automatically when any starting estimate reaches
2^15; coreness is bit-identical in every case). With ``--part-parallel``,
slices are priced against the real memory budget: slice capacity defaults
to the ``--budget-gb`` value (override with ``--slice-capacity-gb``), and a
part whose modeled resident bytes no slice admits triggers a re-divide
with smaller parts (``plan_thresholds`` at a halved budget) instead of
aborting the pipeline. ``--slice-timeout`` / ``--max-retries`` arm the
part-parallel fault-tolerance layer: a crashed part retries on its slice
with backoff, and a slice that hangs past the timeout (or exhausts its
retries) is blacklisted with its unfinished parts re-planned over the
survivors — the run completes degraded, byte-identical to sequential.
``--ckpt-retain`` keeps the N newest boundary/sweep checkpoints (default
2, so a corrupted latest step can fall back to its predecessor).
``--fault site:kind[:at[:count[:delay]]]`` injects failures for chaos
testing (sites: slice_conquer, boundary_fold, checkpoint_save, prefetch,
serve_update; kinds: crash, hang, slow); ``--fault-log FILE`` writes the
run's fault/recovery event trail as JSON.
"""
from __future__ import annotations

import argparse
import json
import time

from repro.core.dckcore import dc_kcore
from repro.core.divide import plan_thresholds
from repro.core.partsched import SliceCapacityError
from repro.graph import barabasi_albert, erdos_renyi, rmat
from repro.graph.io import (
    csr_from_edge_chunks,
    graph_edge_chunks,
    load_edgelist,
    load_npz,
    stream_edgelist,
)
from repro.graph.oracle import peel_coreness


def load_graph(spec: str, seed: int, edge_chunk: int | None = None):
    """Build the graph for ``spec``; with ``edge_chunk`` set, run ingest
    through the streaming builder and return its :class:`IngestStats`."""
    kind, _, rest = spec.partition(":")
    if kind == "file":
        if edge_chunk is not None:
            return stream_edgelist(rest, chunk_edges=edge_chunk)
        return load_edgelist(rest), None
    if kind == "rmat":
        scale, ef = (rest.split(":") + ["16"])[:2]
        g = rmat(int(scale), int(ef), seed=seed)
    elif kind == "ba":
        n, m = rest.split(":")
        g = barabasi_albert(int(n), int(m), seed=seed)
    elif kind == "er":
        n, d = rest.split(":")
        g = erdos_renyi(int(n), float(d), seed=seed)
    elif kind == "npz":
        g = load_npz(rest)
    else:
        raise ValueError(f"unknown graph spec {spec}")
    if edge_chunk is not None:
        # Re-stream the in-memory graph through the chunked builder so the
        # streaming path (and its resident-bytes accounting) is exercised
        # for synthetic specs too.
        g, stats = csr_from_edge_chunks(
            graph_edge_chunks(g, edge_chunk), n_nodes=g.n_nodes,
            chunk_edges=edge_chunk,
        )
        return g, stats
    return g, None


def run_with_capacity_replan(
    g,
    thresholds,
    *,
    replan_budget_bytes=None,
    max_replans=3,
    dc=dc_kcore,
    **dc_kwargs,
):
    """Run ``dc_kcore``; on :class:`SliceCapacityError`, re-divide and retry.

    The wave scheduler refuses a part whose modeled resident bytes exceed
    every slice's capacity. When that happens mid-run the right response is
    not to abort: re-plan the thresholds with a smaller per-part budget
    (halved each attempt, with a proportionally larger part allowance) so
    the oversized part is split, and start over from scratch. The shrink
    starts from whichever of ``replan_budget_bytes`` and the wave's
    ``slice_capacity_bytes`` is smaller — capacity is the constraint that
    tripped, and halving a budget orders of magnitude above it would burn
    every retry without changing the plan. ``resume`` is forced off on
    retries because the aborted attempt's
    checkpoints describe a different partition. Gives up and re-raises
    after ``max_replans`` re-divides, or immediately when no
    ``replan_budget_bytes`` is known to shrink from.

    Returns ``(core, report, thresholds, n_replans)`` with the thresholds
    that actually completed.
    """
    attempt = 0
    while True:
        try:
            core, report = dc(g, thresholds=thresholds, **dc_kwargs)
            return core, report, thresholds, attempt
        except SliceCapacityError as exc:
            attempt += 1
            if replan_budget_bytes is None or attempt > max_replans:
                raise
            base = int(replan_budget_bytes)
            cap = dc_kwargs.get("slice_capacity_bytes")
            if cap is not None:
                base = min(base, int(cap))
            shrunk = max(1, base >> attempt)
            thresholds = plan_thresholds(
                g.degrees, shrunk, max_parts=8 * (1 << attempt)
            )
            print(f"slice capacity exceeded ({exc}); re-divided for "
                  f"{shrunk / 2**30:.3f} GB/part -> thresholds {thresholds} "
                  f"(retry {attempt}/{max_replans})")
            dc_kwargs["resume"] = False


def parse_max_bucket_rows(v: str):
    """argparse type for --max-bucket-rows: "auto" | "none" -> None | int."""
    if v == "auto":
        return "auto"
    if v == "none":
        return None
    try:
        return int(v)
    except ValueError:
        raise argparse.ArgumentTypeError(f"expected 'auto', 'none' or an int, got {v!r}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--graph", default="rmat:14:16")
    ap.add_argument("--thresholds", default="", help="comma list; empty = monolithic")
    ap.add_argument("--budget-gb", type=float, default=None,
                    help="auto-plan thresholds for this per-part budget")
    ap.add_argument("--strategy", choices=["rough", "exact"], default="rough")
    ap.add_argument("--reorder", choices=["identity", "bfs", "rcm"], default="identity",
                    help="locality-aware node ordering applied per part")
    ap.add_argument("--reorder-sample", type=int, default=None, metavar="SLOTS",
                    help="compute the ordering from an edge sample of this "
                         "many slots (out-of-core variant) instead of the "
                         "full CSR traversal")
    ap.add_argument("--engine", choices=["sorted", "count", "kernel", "fused"],
                    default="sorted",
                    help="conquer sweep engine (fused = single-kernel "
                         "Pallas sweep)")
    ap.add_argument("--int16", action="store_true",
                    help="fused engine only: int16 estimate vector for 2x "
                         "effective bandwidth (overflow-guarded int32 "
                         "fallback; bit-identical coreness)")
    ap.add_argument("--max-bucket-rows", type=parse_max_bucket_rows, default="auto",
                    help='tile row cap: "auto" (degree-profile autotuner), '
                         '"none" (one tile per degree class) or an int')
    ap.add_argument("--edge-chunk", type=int, default=None, metavar="EDGES",
                    help="stream ingest in chunks of this many edges "
                         "(bounded-transient spill-to-disk CSR build)")
    ap.add_argument("--divide-chunk", type=int, default=None, metavar="SLOTS",
                    help="chunk budget (adjacency slots) of the divide "
                         "passes; default = the built-in bounded budget")
    ap.add_argument("--checkpoint-dir", default=None,
                    help="save pipeline state here after every part")
    ap.add_argument("--sweep-checkpoint-every", type=int, default=None,
                    metavar="K",
                    help="also snapshot the conquer state every K sweeps "
                         "(mid-part resume; requires --checkpoint-dir)")
    ap.add_argument("--resume", action="store_true",
                    help="resume from --checkpoint-dir at the first "
                         "unfinished part (or mid-part, at the last "
                         "completed sweep snapshot)")
    ap.add_argument("--overlap", action=argparse.BooleanOptionalAction,
                    default=False,
                    help="pipeline the stages: prefetch the next part's "
                         "divide on a worker thread and make checkpoint "
                         "saves async while the current part sweeps "
                         "(byte-identical coreness either way)")
    ap.add_argument("--part-parallel", type=int, default=None, metavar="S",
                    help="conquer up to S parts concurrently per wave "
                         "(speculative shrink chain, validated in plan "
                         "order; byte-identical coreness). Without "
                         "--devices the slices are worker threads sharing "
                         "--engine")
    ap.add_argument("--slice-capacity-gb", type=float, default=None,
                    metavar="GB",
                    help="cap each part-parallel slice's modeled resident "
                         "bytes (default: the --budget-gb value, so slices "
                         "are priced against the same budget the divide "
                         "planned for; requires --part-parallel)")
    ap.add_argument("--slice-timeout", type=float, default=None, metavar="S",
                    help="declare a part-parallel slice dead when its "
                         "sweep heartbeat stalls this many seconds "
                         "(blacklist + re-plan over the survivors; "
                         "requires --part-parallel)")
    ap.add_argument("--max-retries", type=int, default=None, metavar="N",
                    help="retry a crashed part on its slice up to N times "
                         "with exponential backoff before blacklisting "
                         "the slice (requires --part-parallel)")
    ap.add_argument("--ckpt-retain", type=int, default=2, metavar="N",
                    help="keep the N newest boundary/sweep checkpoint "
                         "steps (default 2: a corrupted latest step falls "
                         "back to its predecessor on --resume)")
    ap.add_argument("--fault", action="append", default=[], metavar="SPEC",
                    help="inject a failure: site:kind[:at[:count[:delay]]] "
                         "(repeatable; chaos testing)")
    ap.add_argument("--fault-log", default=None, metavar="FILE",
                    help="write the fault/recovery event trail as JSON")
    ap.add_argument("--devices", type=int, default=None, metavar="N",
                    help="force N virtual host devices and run the "
                         "shard_map engine over a data x model mesh split "
                         "into --part-parallel slices, with device-resident "
                         "E(v) boundary exchange (requires --part-parallel; "
                         "N must be divisible by S)")
    ap.add_argument("--check", action="store_true", help="verify vs BZ peeling")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    if args.resume and args.checkpoint_dir is None:
        ap.error("--resume requires --checkpoint-dir")
    if args.sweep_checkpoint_every is not None and args.checkpoint_dir is None:
        ap.error("--sweep-checkpoint-every requires --checkpoint-dir")
    if args.int16 and args.engine != "fused":
        ap.error("--int16 requires --engine fused")
    if args.devices is not None and args.part_parallel is None:
        ap.error("--devices requires --part-parallel")
    if args.part_parallel is not None and args.overlap:
        ap.error("--part-parallel subsumes --overlap (the wave IS the "
                 "speculation) — pass one or the other")
    if args.devices is not None and args.engine != "sorted":
        ap.error("--devices selects the shard_map engine; drop --engine")
    if args.slice_capacity_gb is not None and args.part_parallel is None:
        ap.error("--slice-capacity-gb requires --part-parallel")
    if (args.slice_timeout is not None or args.max_retries is not None) \
            and args.part_parallel is None:
        ap.error("--slice-timeout/--max-retries configure the part-parallel "
                 "watchdog; they require --part-parallel")
    if args.ckpt_retain < 1:
        ap.error("--ckpt-retain must be >= 1")

    fault_plan = None
    if args.fault:
        from repro.runtime import FaultPlan

        try:
            fault_plan = FaultPlan.parse(args.fault)
        except ValueError as e:
            ap.error(str(e))

    part_parallel_plan = None
    if args.devices is not None:
        # Flag edit must precede the first backend query; every import so
        # far touches only numpy/argparse, so the backend is still cold.
        from repro.launch.mesh import (
            force_host_device_count,
            make_mesh_plan_for_devices,
        )

        force_host_device_count(args.devices)
        # Slot-shard over "model" when the "data" axis still divides into
        # --part-parallel slices afterwards; otherwise keep the mesh flat.
        mp = 2 if args.devices % (2 * args.part_parallel) == 0 else 1
        part_parallel_plan = make_mesh_plan_for_devices(
            args.devices, model_parallel=mp
        )

    t0 = time.perf_counter()
    g, ingest = load_graph(args.graph, args.seed, edge_chunk=args.edge_chunk)
    ingest_s = time.perf_counter() - t0
    print(f"graph: n={g.n_nodes:,} m={g.n_edges:,} max_deg={int(g.degrees.max())}")
    if ingest is not None:
        print(f"ingest (streamed, {ingest_s:.2f}s): chunk={ingest.chunk_edges:,} edges, "
              f"{ingest.n_chunks} chunks, {ingest.n_bins} dedup bins, "
              f"spill={ingest.spill_bytes/2**20:.1f} MiB; "
              f"peak transient {ingest.peak_transient_bytes/2**20:.2f} MiB "
              f"vs in-memory baseline {ingest.baseline_transient_bytes/2**20:.2f} MiB "
              f"(output CSR {ingest.output_bytes/2**20:.2f} MiB)")

    budget_bytes = (
        int(args.budget_gb * 2**30) if args.budget_gb is not None else None
    )
    if budget_bytes is not None:
        thresholds = plan_thresholds(g.degrees, budget_bytes)
        print(f"planned thresholds for {args.budget_gb} GB/part: {thresholds}")
    else:
        thresholds = [int(t) for t in args.thresholds.split(",") if t]

    # Price the part-parallel slices against the real budget: an oversized
    # part then fails LPT assignment at planning time (SliceCapacityError,
    # caught below as a re-divide) instead of OOMing mid-wave.
    slice_capacity_bytes = None
    if args.part_parallel is not None:
        if args.slice_capacity_gb is not None:
            slice_capacity_bytes = int(args.slice_capacity_gb * 2**30)
        elif budget_bytes is not None:
            slice_capacity_bytes = budget_bytes

    core, report, thresholds, n_replans = run_with_capacity_replan(
        g, thresholds,
        replan_budget_bytes=budget_bytes,
        strategy=args.strategy,
        reorder=args.reorder,
        reorder_sample_edges=args.reorder_sample,
        max_bucket_rows=args.max_bucket_rows,
        checkpoint_dir=args.checkpoint_dir,
        resume=args.resume,
        divide_chunk=args.divide_chunk,
        sweep_checkpoint_every=args.sweep_checkpoint_every,
        overlap=args.overlap,
        engine=args.engine, int16=args.int16,
        part_parallel=args.part_parallel,
        part_parallel_plan=part_parallel_plan,
        slice_capacity_bytes=slice_capacity_bytes,
        slice_timeout_s=args.slice_timeout,
        max_retries=args.max_retries,
        fault_plan=fault_plan,
        ckpt_retain=args.ckpt_retain)
    if n_replans:
        print(f"capacity re-divides: {n_replans} (final thresholds "
              f"{thresholds})")
    print(f"\nDC-kCore done in {report.total_time_s:.2f}s "
          f"(preprocess {report.preprocess_time_s:.2f}s, engine={args.engine}"
          f"{'+int16' if args.int16 else ''}, reorder={args.reorder}, "
          f"overlap={'on' if report.overlap else 'off'})")
    print(f"accelerator idle fraction: {report.idle_fraction:.3f} "
          f"(sweeping {report.total_decompose_time_s:.2f}s of "
          f"{report.total_time_s:.2f}s wall)")
    if report.overlap:
        print(f"prefetch: {report.prefetch_hits} hit(s), "
              f"{report.prefetch_misses} miss(es) recomputed")
    if report.part_parallel:
        util = "/".join(f"{u:.2f}" for u in report.slice_utilization)
        print(f"part-parallel: {report.part_parallel} slice(s), wave wall "
              f"{report.conquer_wall_s:.2f}s, slice utilization [{util}], "
              f"{report.prefetch_hits} speculation hit(s), "
              f"{report.prefetch_misses} miss(es), "
              f"{report.speculation_discards} conquer(s) discarded, "
              f"boundary-exchange bytes = {report.boundary_exchange_bytes:,}")
    if (report.retries or report.blacklisted_slices or report.degraded_waves
            or report.quarantined_steps):
        bl = ",".join(str(s) for s in report.blacklisted_slices) or "-"
        print(f"fault tolerance: {report.retries} part retr"
              f"{'y' if report.retries == 1 else 'ies'}, "
              f"blacklisted slices [{bl}], "
              f"{report.degraded_waves} degraded wave(s), "
              f"{report.quarantined_steps} quarantined checkpoint step(s)")
    if args.fault_log:
        events = list(report.fault_events)
        if fault_plan is not None:
            events += [e for e in fault_plan.events if e not in events]
        with open(args.fault_log, "w") as f:
            json.dump({"events": events}, f, indent=2, default=str)
        print(f"fault-event log: {len(events)} event(s) -> {args.fault_log}")
    if report.resumed_parts:
        print(f"resumed: {report.resumed_parts} part(s) restored from "
              f"{args.checkpoint_dir}, not re-run")
    mid = [p for p in report.parts if p.resumed_at_sweep]
    for p in mid:
        print(f"resumed mid-part: {p.name} warm-restarted at sweep "
              f"{p.resumed_at_sweep} from a sweep snapshot")
    print(f"k_max = {int(core.max())}, total comm = {report.total_comm:,} updates, "
          f"peak part bytes = {report.peak_bytes/2**20:.1f} MiB")
    print(f"sweep work (frontier): {report.total_gathered_rows:,} gathered rows "
          f"vs {report.total_full_sweep_rows:,} full-sweep rows; "
          f"measured collective bytes = {report.total_collective_bytes:,}")
    if args.checkpoint_dir:
        # save_s = time the pipeline was BLOCKED on saving; save_wall_s =
        # what the completed writes actually cost (hidden behind sweeps
        # when --overlap makes the saves async).
        print(f"checkpoint saves: blocked {report.total_save_time_s:.3f}s, "
              f"completed writes {report.total_save_wall_s:.3f}s "
              f"({args.checkpoint_dir})")
    for p in report.parts:
        print(f"  part {p.name:>10}: n={p.n_nodes:>9,} m={p.n_edges:>11,} "
              f"iters={p.iterations:>3} comm={p.comm_amount:>10,} "
              f"work={p.gathered_rows:>10,}/{p.full_sweep_rows:<10,} "
              f"adj_density={p.bitmap_density:.3f} coll_bytes={p.collective_bytes:,} "
              f"divide_peak={p.divide_transient_bytes/2**20:.2f}MiB "
              f"save_s={p.save_time_s:.3f} save_wall_s={p.save_wall_s:.3f} "
              f"finalized={p.finalized:,}"
              + (f" slice={p.slice_index} wave={p.wave} "
                 f"modeled={p.modeled_cost_bytes:,}B"
                 if p.slice_index >= 0 else "")
              + (" [prefetched]" if p.prefetched else ""))
    if args.check:
        t0 = time.perf_counter()
        oracle = peel_coreness(g)
        ok = bool((core == oracle).all())
        print(f"oracle check ({time.perf_counter()-t0:.1f}s): {'CONSISTENT' if ok else 'MISMATCH'}")
        if not ok:
            raise SystemExit(1)


if __name__ == "__main__":
    main()
