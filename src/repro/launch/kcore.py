"""DC-kCore launcher — the paper's workload as a CLI.

  python -m repro.launch.kcore --graph rmat:18:16 --thresholds 16,64
  python -m repro.launch.kcore --graph file:/data/com-friendster.txt \
      --budget-gb 2 --strategy rough --check
  python -m repro.launch.kcore --graph rmat:14:12 --reorder rcm --check

Graphs: ``rmat:<scale>:<edge_factor>``, ``ba:<n>:<m>``, ``er:<n>:<deg>``,
``file:<path>`` (SNAP edge list), ``npz:<path>``.

``--reorder {identity,bfs,rcm}`` applies a locality-aware node ordering to
each part before tiling (sparser bucket-adjacency bitmap, better static
frontier skipping); ``--max-bucket-rows`` overrides the tile autotuner with
a uniform row cap (``auto`` = degree-profile autotuner, ``none`` = one tile
per degree class).
"""
from __future__ import annotations

import argparse
import time

from repro.core.dckcore import dc_kcore
from repro.core.divide import plan_thresholds
from repro.graph import barabasi_albert, erdos_renyi, rmat
from repro.graph.io import load_edgelist, load_npz
from repro.graph.oracle import peel_coreness


def load_graph(spec: str, seed: int):
    kind, _, rest = spec.partition(":")
    if kind == "rmat":
        scale, ef = (rest.split(":") + ["16"])[:2]
        return rmat(int(scale), int(ef), seed=seed)
    if kind == "ba":
        n, m = rest.split(":")
        return barabasi_albert(int(n), int(m), seed=seed)
    if kind == "er":
        n, d = rest.split(":")
        return erdos_renyi(int(n), float(d), seed=seed)
    if kind == "file":
        return load_edgelist(rest)
    if kind == "npz":
        return load_npz(rest)
    raise ValueError(f"unknown graph spec {spec}")


def parse_max_bucket_rows(v: str):
    """argparse type for --max-bucket-rows: "auto" | "none" -> None | int."""
    if v == "auto":
        return "auto"
    if v == "none":
        return None
    try:
        return int(v)
    except ValueError:
        raise argparse.ArgumentTypeError(f"expected 'auto', 'none' or an int, got {v!r}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--graph", default="rmat:14:16")
    ap.add_argument("--thresholds", default="", help="comma list; empty = monolithic")
    ap.add_argument("--budget-gb", type=float, default=None,
                    help="auto-plan thresholds for this per-part budget")
    ap.add_argument("--strategy", choices=["rough", "exact"], default="rough")
    ap.add_argument("--reorder", choices=["identity", "bfs", "rcm"], default="identity",
                    help="locality-aware node ordering applied per part")
    ap.add_argument("--max-bucket-rows", type=parse_max_bucket_rows, default="auto",
                    help='tile row cap: "auto" (degree-profile autotuner), '
                         '"none" (one tile per degree class) or an int')
    ap.add_argument("--check", action="store_true", help="verify vs BZ peeling")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    g = load_graph(args.graph, args.seed)
    print(f"graph: n={g.n_nodes:,} m={g.n_edges:,} max_deg={int(g.degrees.max())}")

    if args.budget_gb is not None:
        thresholds = plan_thresholds(g, int(args.budget_gb * 2**30))
        print(f"planned thresholds for {args.budget_gb} GB/part: {thresholds}")
    else:
        thresholds = [int(t) for t in args.thresholds.split(",") if t]

    t0 = time.time()
    core, report = dc_kcore(g, thresholds=thresholds, strategy=args.strategy,
                            reorder=args.reorder,
                            max_bucket_rows=args.max_bucket_rows)
    print(f"\nDC-kCore done in {report.total_time_s:.2f}s "
          f"(preprocess {report.preprocess_time_s:.2f}s, reorder={args.reorder})")
    print(f"k_max = {int(core.max())}, total comm = {report.total_comm:,} updates, "
          f"peak part bytes = {report.peak_bytes/2**20:.1f} MiB")
    print(f"sweep work (frontier): {report.total_gathered_rows:,} gathered rows "
          f"vs {report.total_full_sweep_rows:,} full-sweep rows; "
          f"measured collective bytes = {report.total_collective_bytes:,}")
    for p in report.parts:
        print(f"  part {p.name:>10}: n={p.n_nodes:>9,} m={p.n_edges:>11,} "
              f"iters={p.iterations:>3} comm={p.comm_amount:>10,} "
              f"work={p.gathered_rows:>10,}/{p.full_sweep_rows:<10,} "
              f"adj_density={p.bitmap_density:.3f} coll_bytes={p.collective_bytes:,} "
              f"finalized={p.finalized:,}")
    if args.check:
        t0 = time.time()
        oracle = peel_coreness(g)
        ok = bool((core == oracle).all())
        print(f"oracle check ({time.time()-t0:.1f}s): {'CONSISTENT' if ok else 'MISMATCH'}")
        if not ok:
            raise SystemExit(1)


if __name__ == "__main__":
    main()
