"""Step functions per shape kind, ready for jit + lower."""
from __future__ import annotations

import dataclasses
from repro.models.model import decode_step, prefill
from repro.optim import get_optimizer
from repro.runtime.train_loop import make_train_step


def step_fn_for(cfg, kind: str, lr: float = 3e-4, accum_steps: int = 1,
                grad_shardings=None, accum_dtype=None):
    """Returns (fn, kwargs_order) matching launch.specs.input_specs."""
    if kind == "train":
        import jax.numpy as jnp

        # Big-model training always remats: saved-activation footprint would
        # otherwise scale with depth x sequence (see EXPERIMENTS.md memory
        # table). Configs may still pin an explicit policy.
        if cfg.remat == "none":
            cfg = dataclasses.replace(cfg, remat="full")
        optimizer = get_optimizer(cfg, lr=lr)
        fn = make_train_step(
            cfg, optimizer, accum_steps=accum_steps,
            grad_shardings=grad_shardings,
            accum_dtype=accum_dtype or jnp.float32,
        )

        def train_fn(params, opt_state, step, batch):
            return fn(params, opt_state, step, batch)

        return train_fn, ("params", "opt_state", "step", "batch")
    if kind == "prefill":
        def prefill_fn(params, tokens, extras):
            return prefill(params, tokens, cfg, extras=extras or None)

        return prefill_fn, ("params", "tokens", "extras")
    if kind == "decode":
        def serve_fn(params, caches, token, position, extras):
            return decode_step(params, caches, token, position, cfg, extras=extras or None)

        return serve_fn, ("params", "caches", "token", "position", "extras")
    raise ValueError(kind)
