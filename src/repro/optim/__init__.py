"""Optimizers (no optax dependency): AdamW and Adafactor.

Optimizer states inherit the parameters' sharding (ZeRO-1 comes for free
from the FSDP param layout). ``get_optimizer`` dispatches on the arch
config — the >=100B archs use Adafactor so the training state fits the
16 GB/chip v5e budget (see configs/grok1_314b.py)."""
from repro.optim.adamw import adamw
from repro.optim.adafactor import adafactor
from repro.optim.schedule import warmup_cosine
from repro.optim.base import Optimizer, apply_updates, global_norm, clip_by_global_norm


def get_optimizer(cfg, lr: float = 3e-4, warmup: int = 100, total: int = 10_000):
    sched = warmup_cosine(lr, warmup, total)
    if cfg.optimizer == "adafactor":
        return adafactor(sched)
    return adamw(sched)


__all__ = [
    "adamw",
    "adafactor",
    "warmup_cosine",
    "Optimizer",
    "apply_updates",
    "global_norm",
    "clip_by_global_norm",
    "get_optimizer",
]
