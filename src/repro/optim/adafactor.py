"""Adafactor (Shazeer & Stern 2018): factored second moments, no momentum.

For a [r, c] parameter the second-moment estimate is stored as a rank-1
factorization (row + col running means) — O(r + c) instead of O(r c)
optimizer state. This is what lets the 314B/398B assigned archs train
inside the v5e 16 GB/chip budget (see EXPERIMENTS.md memory table).
1-D parameters fall back to the full second moment.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.optim.base import Optimizer


def adafactor(lr_fn, decay: float = 0.8, eps1: float = 1e-30, eps2: float = 1e-3,
              clip_threshold: float = 1.0, weight_decay: float = 0.0) -> Optimizer:
    def _factored(shape) -> bool:
        return len(shape) >= 2

    def init(params):
        def leaf(p):
            if _factored(p.shape):
                return {
                    "vr": jnp.zeros(p.shape[:-1], jnp.float32),  # row means
                    "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32),
                }
            return {"v": jnp.zeros(p.shape, jnp.float32)}

        return jax.tree.map(leaf, params)

    def update(grads, state, params, step):
        step_f = step.astype(jnp.float32) + 1.0
        lr = lr_fn(step_f)
        beta = 1.0 - step_f ** (-decay)

        def upd(g, s, p):
            g = g.astype(jnp.float32)
            g2 = g * g + eps1
            if _factored(g.shape):
                vr = beta * s["vr"] + (1 - beta) * g2.mean(axis=-1)
                vc = beta * s["vc"] + (1 - beta) * g2.mean(axis=-2)
                denom = jnp.maximum(vr.mean(axis=-1, keepdims=True), eps1)
                precond = (vr / denom)[..., None] * vc[..., None, :]
                u = g * jax.lax.rsqrt(precond + eps1)
                new_s = {"vr": vr, "vc": vc}
            else:
                v = beta * s["v"] + (1 - beta) * g2
                u = g * jax.lax.rsqrt(v + eps1)
                new_s = {"v": v}
            # Update clipping (RMS <= clip_threshold).
            rms = jnp.sqrt(jnp.mean(u * u) + eps1)
            u = u / jnp.maximum(1.0, rms / clip_threshold)
            scale = jnp.maximum(
                eps2, jnp.sqrt(jnp.mean(p.astype(jnp.float32) ** 2))
            )  # relative step
            out = -lr * scale * u
            if weight_decay:
                out = out - lr * weight_decay * p.astype(jnp.float32)
            return out, new_s

        g_leaves, tdef = jax.tree.flatten(grads)
        s_leaves = tdef.flatten_up_to(state)
        p_leaves = jax.tree.leaves(params)
        outs = [upd(g, s, p) for g, s, p in zip(g_leaves, s_leaves, p_leaves)]
        updates = jax.tree.unflatten(tdef, [o[0] for o in outs])
        new_state = jax.tree.unflatten(tdef, [o[1] for o in outs])
        return updates, new_state

    return Optimizer(init=init, update=update)
