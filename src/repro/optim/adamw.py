"""AdamW with decoupled weight decay."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.optim.base import Optimizer


def adamw(lr_fn, b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
          weight_decay: float = 0.1) -> Optimizer:
    def init(params):
        zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
        return {"m": jax.tree.map(zeros, params), "v": jax.tree.map(zeros, params)}

    def update(grads, state, params, step):
        step_f = step.astype(jnp.float32) + 1.0
        lr = lr_fn(step_f)
        bc1 = 1.0 - b1**step_f
        bc2 = 1.0 - b2**step_f

        def upd(g, m, v, p):
            g = g.astype(jnp.float32)
            m_new = b1 * m + (1 - b1) * g
            v_new = b2 * v + (1 - b2) * g * g
            mhat = m_new / bc1
            vhat = v_new / bc2
            u = -lr * (mhat / (jnp.sqrt(vhat) + eps) + weight_decay * p.astype(jnp.float32))
            return u, m_new, v_new

        out = jax.tree.map(upd, grads, state["m"], state["v"], params)
        updates = jax.tree.map(lambda o: o[0], out, is_leaf=lambda x: isinstance(x, tuple))
        m = jax.tree.map(lambda o: o[1], out, is_leaf=lambda x: isinstance(x, tuple))
        v = jax.tree.map(lambda o: o[2], out, is_leaf=lambda x: isinstance(x, tuple))
        return updates, {"m": m, "v": v}

    return Optimizer(init=init, update=update)
