"""Optimizer interface and gradient utilities."""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class Optimizer:
    """Pair of pure functions (optax-style, dependency-free)."""

    init: Callable[[Any], Any]  # params -> state
    update: Callable[..., Any]  # (grads, state, params, step) -> (updates, state)


def apply_updates(params, updates):
    return jax.tree.map(lambda p, u: (p + u).astype(p.dtype), params, updates)


def global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: g * scale.astype(g.dtype), grads), norm
