"""TPU v5e hardware constants (the TARGET platform of this repo)."""

PEAK_FLOPS_BF16 = 197e12  # per chip
HBM_BW = 819e9  # bytes/s per chip
ICI_BW_PER_LINK = 50e9  # bytes/s per link (~50 GB/s)
# 2-D torus: collectives along one mesh axis use the bidirectional ring on
# that axis => 2 links of wire bandwidth per chip.
ICI_LINKS_PER_AXIS = 2
HBM_BYTES = 16 * 1024**3  # 16 GiB per chip
