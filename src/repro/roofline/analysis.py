"""Roofline extraction from compiled dry-run artifacts.

Three terms per (arch x shape x mesh), all in seconds:

  compute    = HLO_FLOPs_per_device / PEAK_FLOPS_BF16
  memory     = HLO_bytes_per_device / HBM_BW
  collective = wire_bytes_per_device / (ICI_LINKS_PER_AXIS * ICI_BW_PER_LINK)

``cost_analysis()`` on the compiled (post-SPMD) module is already
per-device. Collective bytes are NOT in cost_analysis: we parse the
optimized HLO text and sum, per collective op, a ring-model wire estimate:

  all-gather      (n-1)/n * result_bytes
  reduce-scatter  (n-1)/n * operand_bytes
  all-reduce      2 (n-1)/n * operand_bytes
  all-to-all      (n-1)/n * operand_bytes
  collective-permute  operand_bytes

with ``n`` the replica-group size parsed from the op. Raw operand bytes
are also recorded for reference.
"""
from __future__ import annotations

import dataclasses
import re
from collections import defaultdict
from typing import Dict, List, Optional

from repro.roofline import hw

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COLL_RE = re.compile(
    r"=\s*((?:\([^)]*\))|(?:\S+))\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\("
)
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")
_COMP_START_RE = re.compile(r"^(ENTRY\s+)?(%[\w\.\-]+)\s*\(.*\{\s*$")
_WHILE_RE = re.compile(r"while\(.*?condition=(%[\w\.\-]+),\s*body=(%[\w\.\-]+)")
_TRIP_RE = re.compile(r"s32\[\]\s+constant\((\d+)\)")
# conditional(...) branches: `branch_computations={%a, %b}` (new HLO) or
# `true_computation=%a, false_computation=%b` (older text form).
_BRANCHES_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_TRUE_FALSE_RE = re.compile(
    r"true_computation=(%[\w\.\-]+),\s*false_computation=(%[\w\.\-]+)"
)
_COMP_NAME_RE = re.compile(r"%[\w\.\-]+")


def split_computations(hlo_text: str):
    """-> (comps: {name: [lines]}, entry_name)."""
    comps: Dict[str, List[str]] = {}
    entry = None
    cur: Optional[str] = None
    for line in hlo_text.splitlines():
        m = _COMP_START_RE.match(line)
        if m and cur is None:
            cur = m.group(2)
            if m.group(1):
                entry = cur
            comps[cur] = []
            continue
        if cur is not None:
            if line.rstrip() == "}":
                cur = None
            else:
                comps[cur].append(line)
    return comps, entry


def loop_multipliers(hlo_text: str) -> Dict[str, float]:
    """Execution count per computation, accounting nested while loops.

    XLA's cost_analysis (and a naive text scan) counts a while body ONCE;
    real execution repeats it trip-count times. The scan trip count is the
    s32 constant in the while's condition computation (the loop bound the
    counter is compared against).

    ``conditional`` branch computations inherit the caller's multiplier
    (an at-most-once upper bound per call — the frontier-gated k-core sweep
    puts its collectives inside ``lax.cond`` branches, and dropping them
    would zero the collective term of the roofline)."""
    comps, entry = split_computations(hlo_text)
    if entry is None:
        return {}
    mult: Dict[str, float] = {name: 0.0 for name in comps}
    mult[entry] = 1.0

    def trips_of(cond_name: str) -> int:
        best = 1
        for line in comps.get(cond_name, []):
            for m in _TRIP_RE.finditer(line):
                best = max(best, int(m.group(1)))
        return best

    # Propagate through the while nesting (bodies can contain whiles).
    changed = True
    guard = 0
    while changed and guard < 100:
        changed = False
        guard += 1
        for name, lines in comps.items():
            if mult.get(name, 0.0) <= 0.0:
                continue
            for line in lines:
                w = _WHILE_RE.search(line)
                if w:
                    cond, body = w.group(1), w.group(2)
                    m_new = mult[name] * trips_of(cond)
                    if m_new > mult.get(body, 0.0):
                        mult[body] = m_new
                        changed = True
                branches = []
                bm = _BRANCHES_RE.search(line)
                if bm:
                    branches = _COMP_NAME_RE.findall(bm.group(1))
                else:
                    tf = _TRUE_FALSE_RE.search(line)
                    if tf:
                        branches = [tf.group(1), tf.group(2)]
                for br in branches:
                    if mult[name] > mult.get(br, 0.0):
                        mult[br] = mult[name]
                        changed = True
    return mult


def _shape_bytes(shape_str: str) -> int:
    """Total bytes of 'f32[16,4096]' or a tuple '(f32[..], s32[..])'."""
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclasses.dataclass
class CollectiveStats:
    op_bytes: Dict[str, int]  # op kind -> raw result/operand bytes
    wire_bytes: Dict[str, int]  # op kind -> ring-model wire bytes per device
    count: Dict[str, int]

    @property
    def total_wire(self) -> int:
        return sum(self.wire_bytes.values())

    @property
    def total_raw(self) -> int:
        return sum(self.op_bytes.values())


def parse_collectives(hlo_text: str) -> CollectiveStats:
    """Loop-aware collective extraction: per-op wire bytes are multiplied by
    the execution count of the enclosing computation (while trip products)."""
    comps, entry = split_computations(hlo_text)
    mult = loop_multipliers(hlo_text)
    op_bytes: Dict[str, int] = defaultdict(int)
    wire: Dict[str, int] = defaultdict(int)
    count: Dict[str, int] = defaultdict(int)
    for comp_name, lines in comps.items():
        k = mult.get(comp_name, 0.0)
        if k <= 0.0:
            continue
        for line in lines:
            m = _COLL_RE.search(line)
            if m is None:
                continue
            shape_str, kind = m.group(1), m.group(2)
            size = _shape_bytes(shape_str)
            if size == 0:
                continue
            n = None
            gm = _GROUPS_RE.search(line)
            if gm:
                n = int(gm.group(2))
            else:
                gl = _GROUPS_LIST_RE.search(line)
                if gl:
                    n = len([x for x in gl.group(1).split(",") if x.strip() != ""])
            if n is None or n <= 1:
                n = 2  # conservative
            ring = (n - 1) / n
            count[kind] += int(k)
            op_bytes[kind] += int(k * size)
            if kind == "all-gather":
                wire[kind] += int(k * ring * size)  # size = result bytes
            elif kind == "reduce-scatter":
                wire[kind] += int(k * ring * size)
            elif kind == "all-reduce":
                wire[kind] += int(k * 2 * ring * size)
            elif kind == "all-to-all":
                wire[kind] += int(k * ring * size)
            else:  # collective-permute
                wire[kind] += int(k * size)
    return CollectiveStats(op_bytes=dict(op_bytes), wire_bytes=dict(wire), count=dict(count))


@dataclasses.dataclass
class Roofline:
    flops_per_device: float
    hbm_bytes_per_device: float
    wire_bytes_per_device: float
    compute_s: float
    memory_s: float
    collective_s: float
    bottleneck: str
    model_flops_total: Optional[float] = None
    useful_fraction: Optional[float] = None  # MODEL_FLOPS / (flops * chips)

    def as_dict(self):
        return dataclasses.asdict(self)


def roofline_terms(flops_per_device: float, hbm_bytes: float, wire_bytes: float,
                   n_chips: int, model_flops: Optional[float] = None) -> Roofline:
    compute = flops_per_device / hw.PEAK_FLOPS_BF16
    memory = hbm_bytes / hw.HBM_BW
    coll = wire_bytes / (hw.ICI_LINKS_PER_AXIS * hw.ICI_BW_PER_LINK)
    terms = {"compute": compute, "memory": memory, "collective": coll}
    bottleneck = max(terms, key=terms.get)
    useful = None
    if model_flops:
        useful = model_flops / max(flops_per_device * n_chips, 1.0)
    return Roofline(
        flops_per_device=flops_per_device,
        hbm_bytes_per_device=hbm_bytes,
        wire_bytes_per_device=wire_bytes,
        compute_s=compute,
        memory_s=memory,
        collective_s=coll,
        bottleneck=bottleneck,
        model_flops_total=model_flops,
        useful_fraction=useful,
    )


# --------------------------------------------------------------------- #
# MODEL_FLOPS (the "useful work" yardstick)
# --------------------------------------------------------------------- #
def active_params(cfg) -> int:
    """Active parameters per token (MoE: top_k/n_experts of routed experts)."""
    from repro.models.model import build_specs
    from repro.models.module import is_spec

    import jax

    specs = build_specs(cfg)
    total = 0
    for path, spec in jax.tree_util.tree_flatten_with_path(
        specs, is_leaf=is_spec
    )[0]:
        n = 1
        for d in spec.shape:
            n *= d
        if "experts" in (spec.axes or ()):  # routed expert weight
            n = int(n * cfg.moe.top_k / cfg.moe.n_experts)
        total += n
    return total


def model_flops(cfg, shape, total_params: int, act_params: int) -> float:
    tokens = shape.global_batch * shape.seq_len
    if shape.kind == "train":
        return 6.0 * act_params * tokens
    if shape.kind == "prefill":
        return 2.0 * act_params * tokens
    # decode: one token per sequence
    return 2.0 * act_params * shape.global_batch
