"""Analytic FLOPs / HBM-traffic model per (arch x shape).

Why analytic: XLA's ``cost_analysis`` counts every ``while`` body once
(verified: a scanned 8-step matmul reports exactly 1/8 of the unrolled
flops), and our stacks are scan-based by design. Rather than re-deriving
per-op costs from HLO, we model them from the architecture — this is the
same napkin math the §Perf hypothesis loop uses, and it is validated
against unrolled-HLO counts in tests/test_roofline.py (<2% error on
matmul-dominated configs).

Conventions:
  * matmul [m,k]x[k,n]: 2mkn flops.
  * backward = 2x forward; ``remat=full`` re-runs the forward once more.
  * HBM traffic is the *roofline lower bound*: every parameter read once
    per pass, activations written+read once between layers, KV cache
    read/written once — i.e. perfect on-chip fusion. Real traffic is
    higher; the bound is what the memory term of the roofline needs.
"""
from __future__ import annotations

import dataclasses
from typing import Dict

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import blocks


@dataclasses.dataclass
class CostBreakdown:
    flops_total: float  # whole step, all chips
    hbm_bytes_per_device: float
    detail: Dict[str, float]


def _layer_counts(cfg: ModelConfig):
    period, n_groups, kinds, tail_kinds = blocks.stack_layout(cfg)
    all_kinds = kinds * n_groups + tail_kinds
    return all_kinds


def _attn_flops_per_token(cfg, ctx_len: float) -> float:
    d, hq, hkv, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    proj = 2 * d * dh * (2 * hq + 2 * hkv)  # q,o + k,v
    scores = 4 * hq * dh * ctx_len  # QK^T + PV
    return proj + scores


def _cross_flops_per_token(cfg, n_src: int) -> float:
    d, hq, hkv, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    proj_q = 2 * d * dh * 2 * hq
    scores = 4 * hq * dh * n_src
    return proj_q + scores


def _mlp_flops_per_token(cfg, kind) -> float:
    if kind.moe:
        m = cfg.moe
        routed = m.top_k * 6 * cfg.d_model * m.d_expert
        shared = 6 * cfg.d_model * m.d_shared if m.n_shared else 0.0
        router = 2 * cfg.d_model * m.n_experts
        return routed + shared + router
    if cfg.d_ff == 0:
        return 0.0
    mult = 4 if cfg.mlp_type == "gelu" else 6
    return mult * cfg.d_model * cfg.d_ff


def _ssm_flops_per_token(cfg) -> float:
    s = cfg.ssm
    d, di, n, h, p = cfg.d_model, s.d_inner(cfg.d_model), s.d_state, s.n_heads(cfg.d_model), s.head_dim
    proj = 2 * d * (2 * di) + 2 * d * (2 * n) + 2 * d * h + 2 * di * d
    conv = 2 * s.d_conv * (di + 2 * n)
    L = s.chunk
    # SSD: intra-chunk CB^T (2LN) + masked matmul to outputs (2*L*h*p... per
    # token: row of M times X) + inter-chunk state in/out (4*n*h*p) + state
    # contribution (2*n*h*p).
    ssd = 2 * L * n + 2 * L * h * p + 6 * n * h * p
    return proj + conv + ssd


def _head_flops_per_token(cfg) -> float:
    return 2 * cfg.d_model * cfg.vocab_padded


def _param_bytes(cfg, n_params: int) -> float:
    import numpy as np

    return float(n_params) * np.dtype(cfg.param_dtype).itemsize


def forward_flops_per_token(cfg: ModelConfig, ctx_len: float, decode: bool = False) -> float:
    """Average per-token forward flops at the given (average) context."""
    total = 0.0
    n_src = cfg.encoder.n_frames if cfg.encoder else cfg.n_vision_tokens
    for kind in _layer_counts(cfg):
        if kind.attn:
            eff_ctx = min(ctx_len, kind.window) if kind.window else ctx_len
            total += _attn_flops_per_token(cfg, eff_ctx)
        else:
            total += _ssm_flops_per_token(cfg)
        if kind.cross:
            total += _cross_flops_per_token(cfg, n_src)
        total += _mlp_flops_per_token(cfg, kind)
    total += _head_flops_per_token(cfg)
    if cfg.encoder is not None and not decode:
        # Encoder runs once per sequence over n_frames tokens; amortized
        # outside (see cost()).
        pass
    return total


def _encoder_flops(cfg) -> float:
    if cfg.encoder is None:
        return 0.0
    frames = cfg.encoder.n_frames
    per_tok = 0.0
    for i in range(cfg.encoder.n_layers):
        per_tok += _attn_flops_per_token(cfg, frames / 2) + _mlp_flops_per_token(
            cfg, blocks.layer_kind(cfg, i, allow_cross=False)
        )
    return per_tok * frames


def cost(cfg: ModelConfig, shape: ShapeConfig, n_params: int, n_chips: int,
         remat: bool = True) -> CostBreakdown:
    gb, seq = shape.global_batch, shape.seq_len
    detail: Dict[str, float] = {}
    pbytes = _param_bytes(cfg, n_params)

    if shape.kind in ("train", "prefill"):
        tokens = gb * seq
        fwd = forward_flops_per_token(cfg, ctx_len=seq / 2) * tokens
        fwd += _encoder_flops(cfg) * gb
        if shape.kind == "train":
            factor = 3.0 + (1.0 if remat else 0.0)
            flops = fwd * factor
            detail["fwd"] = fwd
            detail["bwd"] = 2 * fwd
            detail["remat"] = fwd if remat else 0.0
            # HBM per device: params (fwd+bwd+remat reads + optimizer rw)
            # + activation stash (per-group boundaries) + grads.
            opt_mult = 5.0  # read p,m,v + write p,m,v -ish (adamw)
            hbm = pbytes * (factor + opt_mult) / n_chips
            act = 2.0 * tokens * cfg.d_model * 2 / n_chips  # bf16 boundaries
            n_layers = cfg.n_layers
            hbm += act * n_layers
            detail["hbm_params"] = pbytes * (factor + opt_mult) / n_chips
            detail["hbm_acts"] = act * n_layers
        else:
            flops = fwd
            hbm = pbytes / n_chips
            act = 2.0 * tokens * cfg.d_model * 2 / n_chips
            hbm += act * cfg.n_layers
            # KV cache write.
            kv = _kv_cache_bytes(cfg, gb, seq)
            hbm += kv / n_chips
            detail["hbm_kv_write"] = kv / n_chips
    else:  # decode: one token per sequence
        fwd = forward_flops_per_token(cfg, ctx_len=seq, decode=True) * gb
        flops = fwd
        kv = _kv_cache_bytes(cfg, gb, seq)
        hbm = pbytes / n_chips + kv / n_chips  # read all params + full cache
        detail["hbm_params"] = pbytes / n_chips
        detail["hbm_kv_read"] = kv / n_chips
    detail["flops_total"] = flops
    return CostBreakdown(flops_total=flops, hbm_bytes_per_device=hbm, detail=detail)


def device_memory_model(cfg, shape, n_params: int, n_chips: int, dp: int,
                        accum_steps: int = 1) -> Dict[str, float]:
    """Analytic per-device HBM residency on the TARGET (TPU v5e).

    The XLA CPU backend's temp numbers include CPU-only expansions (scatter
    expander index matrices, hoisted f32 stash converts) that a TPU build
    does not allocate; this model is the TPU-faithful budget check and the
    CPU temp figure is kept as a cross-check (see EXPERIMENTS.md §Dry-run).

    Components: parameters (+grads +optimizer state for train), the remat
    residual stash, per-microbatch live activations, KV caches (decode),
    and a fixed workspace allowance.
    """
    import numpy as np

    pd = np.dtype(cfg.param_dtype).itemsize
    ad = np.dtype(cfg.dtype).itemsize
    gb, seq = shape.global_batch, shape.seq_len
    out: Dict[str, float] = {}
    out["params"] = n_params * pd / n_chips
    if shape.kind == "train":
        out["grads"] = n_params * 4 / n_chips  # fp32 accumulation buffer
        opt_per_param = 8 if cfg.optimizer == "adamw" else 0.5  # adafactor ~rank-1
        out["opt_state"] = n_params * opt_per_param / n_chips
        micro_rows = max(1, gb // (dp * accum_steps))  # per-device rows
        # Remat stash: one residual per layer boundary per microbatch.
        out["stash"] = float(cfg.n_layers) * micro_rows * seq * cfg.d_model * ad
        # Live working set inside one rematted group (few activation-sized
        # tensors) + logits in fp32 over the model-sharded vocab.
        live = 6 * micro_rows * seq * cfg.d_model * ad
        logits = micro_rows * seq * cfg.vocab_padded * 4 / max(n_chips // dp, 1)
        out["live"] = (live + logits) / 1.0
    elif shape.kind == "prefill":
        rows = max(1, gb // dp)
        out["stash"] = 0.0
        out["live"] = 8 * rows * seq * cfg.d_model * ad
        out["kv_cache"] = _kv_cache_bytes(cfg, gb, seq) / n_chips
    else:
        out["kv_cache"] = _kv_cache_bytes(cfg, gb, seq) / n_chips
        out["live"] = 4 * max(1, gb // dp) * cfg.d_model * ad + cfg.vocab_padded * 4
    out["workspace"] = 512 * 2**20
    out["total"] = float(sum(out.values()))
    return out


def _kv_cache_bytes(cfg, batch: int, seq: int) -> float:
    import numpy as np

    total = 0.0
    dt = np.dtype(cfg.dtype).itemsize
    for kind in _layer_counts(cfg):
        if kind.attn:
            slots = min(kind.window, seq) if kind.window else seq
            total += 2 * batch * slots * cfg.n_kv_heads * cfg.head_dim * dt
        else:
            s = cfg.ssm
            total += (
                batch * s.n_heads(cfg.d_model) * s.d_state * s.head_dim * 4
                + batch * (s.d_conv - 1) * (s.d_inner(cfg.d_model) + 2 * s.d_state) * dt
            )
        if kind.cross:
            n_src = cfg.encoder.n_frames if cfg.encoder else cfg.n_vision_tokens
            total += 2 * batch * n_src * cfg.n_kv_heads * cfg.head_dim * dt
    return total
