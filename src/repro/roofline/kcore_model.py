"""Roofline cost model for the k-core conquer sweep.

``flops_model.py`` models the LM workloads the roofline harness was built
for; this module is its k-core counterpart: per-bucket HBM bytes and
compare-FLOPs for one sweep, in both the unfused multi-dispatch form
(gather materialized, dirty push re-reads the neighbor tile) and the fused
single-kernel form (``kernels.fused`` — the neighbor tile is read once, no
gathered intermediate ever hits HBM). ``core.decompose`` accumulates these
per live sweep from the active-frontier mask, so a run reports modeled
achieved-vs-roofline bandwidth next to its wall time (fig17), and the
opt-in int16 estimate mode shows up as a measured bytes-moved reduction
(``wire_bytes=2``).

The model counts traffic, not cache luck: every operand is charged one trip
at its natural width. FLOPs are the suffix-count compares (one op per
neighbor-slot x candidate), the term that dominates Algorithm 2.
"""
from __future__ import annotations

from typing import Iterable, Sequence, Tuple

from repro.roofline import hw


def sweep_tile_cost(
    rows: int,
    width: int,
    cand: int,
    *,
    wire_bytes: int = 4,
    fused: bool = True,
    track_dirty: bool = True,
) -> Tuple[int, int]:
    """(HBM bytes, compare FLOPs) for one ``[rows, width]`` bucket sweep.

    ``wire_bytes`` is the estimate dtype width (4, or 2 in int16 mode):
    the gathered neighbor estimates and the current/new estimate rows move
    at that width; ids/ext stay 4-byte. ``cand`` is clamped to ``width``
    exactly as the kernels clamp it.
    """
    cand = max(1, min(int(cand), int(width)))
    neigh = rows * width * 4                 # neighbor-id tile, read once
    gather = rows * width * wire_bytes       # gathered estimates (c reads)
    row_io = rows * (4 + 4 + 2 * wire_bytes + 4)  # ids + ext + cur/est + changed
    push = rows * width * 1 if track_dirty else 0  # int8 dirty contributions
    nbytes = neigh + gather + row_io + push
    if not fused:
        # Multi-dispatch sweep: the [rows, width] gathered matrix is
        # materialized (store + re-load by the h-index), and the dirty
        # scatter-max re-reads the neighbor-id tile a second time.
        nbytes += 2 * rows * width * 4
        if track_dirty:
            nbytes += rows * width * 4
    flops = rows * width * cand + rows * cand  # compares + feasibility
    return int(nbytes), int(flops)


def sweep_cost(
    shapes: Iterable[Sequence[int]],
    cand: int,
    *,
    wire_bytes: int = 4,
    fused: bool = True,
    track_dirty: bool = True,
) -> Tuple[int, int]:
    """Sum :func:`sweep_tile_cost` over ``(rows, width)`` bucket shapes."""
    tb = tf = 0
    for rows, width in shapes:
        b, f = sweep_tile_cost(
            rows, width, cand, wire_bytes=wire_bytes, fused=fused,
            track_dirty=track_dirty,
        )
        tb += b
        tf += f
    return tb, tf


def roofline_time_s(
    nbytes: int,
    flops: int,
    *,
    hbm_bw: float = hw.HBM_BW,
    peak_flops: float = hw.PEAK_FLOPS_BF16,
) -> float:
    """Roofline lower bound for one sweep on the target chip."""
    return max(nbytes / hbm_bw, flops / peak_flops)


def achieved_bw_fraction(
    nbytes: int, wall_s: float, *, hbm_bw: float = hw.HBM_BW
) -> float:
    """Achieved fraction of target-chip HBM bandwidth for measured wall."""
    if wall_s <= 0:
        return 0.0
    return (nbytes / wall_s) / hbm_bw
