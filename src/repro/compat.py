"""JAX version-portability shims.

The repo targets the current JAX API but must run on 0.4.x (the pinned
container toolchain). Every version-dependent call site routes through this
module so drift is repaired in exactly one place:

* :func:`shard_map` — ``jax.shard_map`` (new) vs
  ``jax.experimental.shard_map.shard_map`` (0.4.x), mapping the
  ``check_vma`` kwarg to the old ``check_rep`` name.
* :func:`make_mesh` — ``jax.make_mesh`` with ``axis_types`` only when
  ``jax.sharding.AxisType`` exists (it does not on 0.4.37).
* :func:`cost_analysis_dict` — ``Compiled.cost_analysis()`` returns a dict
  on new JAX but a one-element list of dicts on 0.4.x; normalize to a dict.
* :func:`optimization_barrier` — differentiable wrapper around
  ``jax.lax.optimization_barrier`` (0.4.37 has no differentiation rule for
  the primitive); the barrier is preserved on both the forward and backward
  paths, which is exactly the placement the remat-stash fix needs.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Sequence

import jax


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = True):
    """Resolve shard_map across JAX versions.

    ``check_vma`` follows the new-API name; on 0.4.x it is forwarded as
    ``check_rep`` (same semantics: static replication/varying-manual-axes
    checking of the mapped outputs).
    """
    fn = getattr(jax, "shard_map", None)
    if fn is not None:
        try:
            return fn(
                f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                check_vma=check_vma,
            )
        except TypeError:
            pass  # a version with jax.shard_map but the old kwarg name
    else:
        from jax.experimental.shard_map import shard_map as fn  # type: ignore
    return fn(
        f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
        check_rep=check_vma,
    )


def make_mesh(
    axis_shapes: Sequence[int],
    axis_names: Sequence[str],
    *,
    devices: Optional[Sequence[Any]] = None,
):
    """Version-portable ``jax.make_mesh`` with Auto axis types when available.

    On JAX versions with explicit-sharding support the mesh is built with
    ``AxisType.Auto`` on every axis (the behavior the sharding policy
    assumes); on 0.4.x — where ``jax.sharding.AxisType`` does not exist and
    every axis is implicitly auto — the kwarg is simply omitted.
    """
    kwargs: Dict[str, Any] = {}
    if devices is not None:
        kwargs["devices"] = devices
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        try:
            return jax.make_mesh(
                tuple(axis_shapes), tuple(axis_names),
                axis_types=(axis_type.Auto,) * len(tuple(axis_names)),
                **kwargs,
            )
        except TypeError:
            pass
    return jax.make_mesh(tuple(axis_shapes), tuple(axis_names), **kwargs)


def cost_analysis_dict(compiled) -> Dict[str, float]:
    """Normalized ``Compiled.cost_analysis()``: always a (possibly empty) dict.

    JAX 0.4.x returns ``[{...}]`` (one entry per partition, len 1 post-SPMD);
    newer versions return the dict directly; either may be ``None`` on
    backends without cost analysis.
    """
    ca = compiled.cost_analysis()
    if ca is None:
        return {}
    if isinstance(ca, (list, tuple)):
        return dict(ca[0]) if len(ca) else {}
    return dict(ca)


def backends_initialized() -> bool:
    """Has jax already instantiated a backend (device queries ran)?

    Gates the launch layer's ``XLA_FLAGS`` edits: forcing a host device
    count after backend init silently does nothing, so callers raise
    instead. Reaches into ``jax._src.xla_bridge`` (no public probe exists);
    defaults to ``False`` if the internal layout shifts — the worst case is
    a clear late-flag failure instead of an early one.
    """
    try:
        from jax._src import xla_bridge

        return bool(xla_bridge._backends)
    except Exception:
        return False


def distributed_initialize(
    coordinator_address: str,
    num_processes: int,
    process_id: int,
    local_device_ids: Optional[Sequence[int]] = None,
) -> None:
    """Version-portable ``jax.distributed.initialize``.

    ``local_device_ids`` is forwarded only when given; a TypeError from an
    older signature retries without it (the 0.4.x fallback — the process
    then owns all its local devices, which is the common case anyway).
    """
    kwargs: Dict[str, Any] = {
        "coordinator_address": coordinator_address,
        "num_processes": int(num_processes),
        "process_id": int(process_id),
    }
    if local_device_ids is not None:
        kwargs["local_device_ids"] = list(local_device_ids)
    try:
        jax.distributed.initialize(**kwargs)
    except TypeError:
        kwargs.pop("local_device_ids", None)
        jax.distributed.initialize(**kwargs)


@jax.custom_vjp
def optimization_barrier(x):
    """``jax.lax.optimization_barrier`` that is reverse-mode differentiable.

    JAX 0.4.x has no differentiation rule for the primitive. The custom VJP
    barriers the cotangent too: the backward-pass barrier is what actually
    keeps XLA from hoisting the first-use f32 upcast out of the backward
    scan (the residual-stash blowup the call sites guard against).
    """
    return jax.lax.optimization_barrier(x)


def _ob_fwd(x):
    return jax.lax.optimization_barrier(x), None


def _ob_bwd(_, g):
    return (jax.lax.optimization_barrier(g),)


optimization_barrier.defvjp(_ob_fwd, _ob_bwd)
