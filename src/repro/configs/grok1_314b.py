"""grok-1-314b [moe]: 64L d_model=6144 48H (GQA kv=8) d_ff=32768
vocab=131072, 8 experts top-2. [hf:xai-org/grok-1; unverified]

314B params / 256 v5e chips: Adafactor (factored second moment) + full
remat keep the training state inside 16 GB/chip (see EXPERIMENTS.md)."""
import dataclasses
import jax.numpy as jnp
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="grok-1-314b",
    family="moe",
    n_layers=64,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    head_dim=128,
    d_ff=32768,
    vocab_size=131_072,
    moe=MoEConfig(n_experts=8, top_k=2, d_expert=32768, every_k_layers=1),
    rope_theta=10_000.0,
    tie_embeddings=False,
    max_seq_len=8_192,
    optimizer="adafactor",
    remat="full",
    param_dtype=jnp.bfloat16,  # 16 GB/chip: bf16 params + factored optimizer
)


def smoke_config() -> ModelConfig:
    import jax.numpy as jnp
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab_size=512, max_seq_len=128, dtype=jnp.float32,
        remat="none",
        moe=MoEConfig(n_experts=4, top_k=2, d_expert=128, every_k_layers=1),
    )
