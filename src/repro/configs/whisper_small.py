"""whisper-small [audio]: enc-dec 12L d_model=768 12H d_ff=3072 vocab=51865
— conv frontend STUB (input_specs provides precomputed frame embeddings
[B, 1500, 768]). LayerNorm + GELU + learned positions (no RoPE).
[arXiv:2212.04356; unverified]

Deviation (documented): real Whisper caps decoder positions at 448; the
assigned decode shapes need 32k, so the learned position table is extended.
long_500k is skipped (enc-dec with fixed 1500-frame source; full attention).
"""
import dataclasses
from repro.configs.base import EncoderConfig, ModelConfig

CONFIG = ModelConfig(
    name="whisper-small",
    family="audio",
    n_layers=12,
    d_model=768,
    n_heads=12,
    n_kv_heads=12,
    head_dim=64,
    d_ff=3072,
    vocab_size=51_865,
    encoder=EncoderConfig(n_layers=12, n_frames=1500),
    cross_attn_every=1,  # every decoder layer cross-attends the encoder
    norm_type="layer",
    use_rope=False,
    mlp_type="gelu",
    tie_embeddings=True,
    max_seq_len=32_768,
)


def smoke_config() -> ModelConfig:
    import jax.numpy as jnp
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
        d_ff=128, vocab_size=512, max_seq_len=128, dtype=jnp.float32,
        encoder=EncoderConfig(n_layers=2, n_frames=12),
    )
