"""qwen2-moe-a2.7b [moe]: 24L d_model=2048 16H (GQA kv=16) d_ff=1408
vocab=151936, 60 routed experts top-4 + 4 shared experts.
[hf:Qwen/Qwen1.5-MoE-A2.7B; hf]"""
import dataclasses
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="qwen2-moe-a2.7b",
    family="moe",
    n_layers=24,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    head_dim=128,
    d_ff=1408,
    vocab_size=151_936,
    moe=MoEConfig(
        n_experts=60, top_k=4, d_expert=1408, n_shared=4, d_shared=5632,
        every_k_layers=1,
    ),
    rope_theta=1_000_000.0,
    tie_embeddings=False,
    max_seq_len=131_072,
)


def smoke_config() -> ModelConfig:
    import jax.numpy as jnp
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
        d_ff=32, vocab_size=512, max_seq_len=128, dtype=jnp.float32,
        moe=MoEConfig(n_experts=8, top_k=2, d_expert=32, n_shared=2,
                      d_shared=64, every_k_layers=1),
    )
