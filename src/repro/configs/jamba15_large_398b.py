"""jamba-1.5-large-398b [hybrid]: 72L d_model=8192 64H (GQA kv=8)
d_ff=24576 vocab=65536 — Mamba+attention 1:7 interleave, MoE 16 experts
top-2 every other layer. [arXiv:2403.19887; hf]

72 layers = 9 groups of 8 (1 attention + 7 mamba); MoE on odd layers.
398B total / ~94B active; Adafactor + full remat for the 256-chip pod."""
import dataclasses
import jax.numpy as jnp
from repro.configs.base import ModelConfig, MoEConfig, SSMConfig

CONFIG = ModelConfig(
    name="jamba-1.5-large-398b",
    family="hybrid",
    n_layers=72,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    head_dim=128,
    d_ff=24576,
    vocab_size=65_536,
    attn_every=8,  # 1 attention layer per 8 (1:7)
    ssm=SSMConfig(d_state=16, d_conv=4, expand=2, head_dim=64, chunk=256),
    moe=MoEConfig(n_experts=16, top_k=2, d_expert=24576, every_k_layers=2),
    rope_theta=10_000.0,
    tie_embeddings=False,
    max_seq_len=262_144,
    optimizer="adafactor",
    remat="full",
    param_dtype=jnp.bfloat16,  # 16 GB/chip: bf16 params + factored optimizer
)


def smoke_config() -> ModelConfig:
    import jax.numpy as jnp
    return dataclasses.replace(
        CONFIG, n_layers=8, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab_size=512, max_seq_len=1024, dtype=jnp.float32,
        remat="none",
        ssm=SSMConfig(d_state=16, d_conv=4, expand=2, head_dim=16, chunk=16),
        moe=MoEConfig(n_experts=4, top_k=2, d_expert=64, every_k_layers=2),
    )
