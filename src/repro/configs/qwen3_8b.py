"""qwen3-8b [dense]: 36L d_model=4096 32H (GQA kv=8) d_ff=12288
vocab=151936 — qk_norm, GQA. [hf:Qwen/Qwen3-8B; hf]"""
import dataclasses
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-8b",
    family="dense",
    n_layers=36,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=12288,
    vocab_size=151_936,
    qk_norm=True,
    rope_theta=1_000_000.0,
    tie_embeddings=False,
    max_seq_len=131_072,
)


def smoke_config() -> ModelConfig:
    import jax.numpy as jnp
    return dataclasses.replace(
        CONFIG, n_layers=3, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab_size=512, max_seq_len=128, dtype=jnp.float32,
    )
