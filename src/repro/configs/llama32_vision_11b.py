"""llama-3.2-vision-11b [vlm]: 40L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=128256 — gated cross-attention image layers every 5th layer; the
vision frontend is a STUB (input_specs provides precomputed patch
embeddings [B, 1600, d_model]). [hf:meta-llama/Llama-3.2-11B-Vision;
unverified]"""
import dataclasses
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama-3.2-vision-11b",
    family="vlm",
    n_layers=40,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=128_256,
    cross_attn_every=5,
    n_vision_tokens=1600,
    rope_theta=500_000.0,
    tie_embeddings=False,
    max_seq_len=131_072,
)


def smoke_config() -> ModelConfig:
    import jax.numpy as jnp
    return dataclasses.replace(
        CONFIG, n_layers=5, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab_size=512, n_vision_tokens=16, max_seq_len=128,
        dtype=jnp.float32,
    )
