"""Architecture registry: ``--arch <id>`` resolution.

Ten assigned architectures (public configs) plus the paper's own graph
workloads (:mod:`repro.configs.graphs`)."""
from __future__ import annotations

import importlib
from typing import Dict, List

from repro.configs.base import SHAPES, ModelConfig, ShapeConfig

_ARCH_MODULES: Dict[str, str] = {
    "gemma3-27b": "repro.configs.gemma3_27b",
    "qwen3-8b": "repro.configs.qwen3_8b",
    "granite-3-2b": "repro.configs.granite_3_2b",
    "phi4-mini-3.8b": "repro.configs.phi4_mini_3_8b",
    "qwen2-moe-a2.7b": "repro.configs.qwen2_moe_a2_7b",
    "grok-1-314b": "repro.configs.grok1_314b",
    "mamba2-130m": "repro.configs.mamba2_130m",
    "llama-3.2-vision-11b": "repro.configs.llama32_vision_11b",
    "whisper-small": "repro.configs.whisper_small",
    "jamba-1.5-large-398b": "repro.configs.jamba15_large_398b",
}

ARCHS: List[str] = list(_ARCH_MODULES)

# long_500k needs sub-quadratic attention; run only for SSM/hybrid/
# sliding-window archs (see DESIGN.md "Shape/step mapping").
LONG_CONTEXT_ARCHS = {"mamba2-130m", "jamba-1.5-large-398b", "gemma3-27b"}


def get_config(name: str) -> ModelConfig:
    if name not in _ARCH_MODULES:
        raise KeyError(f"unknown arch {name!r}; known: {ARCHS}")
    return importlib.import_module(_ARCH_MODULES[name]).CONFIG


def get_smoke_config(name: str) -> ModelConfig:
    if name not in _ARCH_MODULES:
        raise KeyError(f"unknown arch {name!r}; known: {ARCHS}")
    return importlib.import_module(_ARCH_MODULES[name]).smoke_config()


def cells(include_skipped: bool = False):
    """All (arch, shape) dry-run cells; skipped long_500k cells excluded by
    default (documented in DESIGN.md)."""
    out = []
    for arch in ARCHS:
        for shape in SHAPES.values():
            if (
                shape.name == "long_500k"
                and arch not in LONG_CONTEXT_ARCHS
                and not include_skipped
            ):
                continue
            out.append((arch, shape.name))
    return out


__all__ = [
    "ARCHS",
    "SHAPES",
    "LONG_CONTEXT_ARCHS",
    "ModelConfig",
    "ShapeConfig",
    "get_config",
    "get_smoke_config",
    "cells",
]
