"""phi4-mini-3.8b [dense]: 32L d_model=3072 24H (GQA kv=8) d_ff=8192
vocab=200064 — partial RoPE, SwiGLU, GQA. [arXiv:2412.08905; hf]"""
import dataclasses
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="phi4-mini-3.8b",
    family="dense",
    n_layers=32,
    d_model=3072,
    n_heads=24,
    n_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab_size=200_064,
    rope_theta=10_000.0,
    rope_fraction=0.75,
    tie_embeddings=True,
    max_seq_len=131_072,
)


def smoke_config() -> ModelConfig:
    import jax.numpy as jnp
    return dataclasses.replace(
        CONFIG, n_layers=3, d_model=48, n_heads=3, n_kv_heads=1, head_dim=16,
        d_ff=128, vocab_size=512, max_seq_len=128, dtype=jnp.float32,
    )
