"""granite-3-2b [dense]: 40L d_model=2048 32H (GQA kv=8) d_ff=8192
vocab=49155 — GQA. [hf:ibm-granite/granite-3.0-2b-base; hf]

vocab 49155 is not divisible by any mesh axis; padded to 49408 (x256) for
model-axis sharding, pad logits masked in the loss (see ModelConfig)."""
import dataclasses
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="granite-3-2b",
    family="dense",
    n_layers=40,
    d_model=2048,
    n_heads=32,
    n_kv_heads=8,
    head_dim=64,
    d_ff=8192,
    vocab_size=49_155,
    rope_theta=10_000.0,
    tie_embeddings=True,
    max_seq_len=131_072,
)


def smoke_config() -> ModelConfig:
    import jax.numpy as jnp
    return dataclasses.replace(
        CONFIG, n_layers=3, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab_size=515, max_seq_len=128, dtype=jnp.float32,
    )
