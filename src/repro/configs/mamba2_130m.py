"""mamba2-130m [ssm]: 24L d_model=768 attn-free, vocab=50280,
ssm_state=128 — SSD (state-space duality). [arXiv:2405.21060; unverified]"""
import dataclasses
from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="mamba2-130m",
    family="ssm",
    n_layers=24,
    d_model=768,
    n_heads=12,  # unused (attention-free); kept for dataclass completeness
    n_kv_heads=12,
    head_dim=64,
    d_ff=0,  # no FFN in mamba blocks
    vocab_size=50_280,
    ssm=SSMConfig(d_state=128, d_conv=4, expand=2, head_dim=64, chunk=256),
    attn_every=None,  # pure SSM
    tie_embeddings=True,
    max_seq_len=1_048_576,
)


def smoke_config() -> ModelConfig:
    import jax.numpy as jnp
    return dataclasses.replace(
        CONFIG, n_layers=3, d_model=64, vocab_size=512, max_seq_len=1024,
        dtype=jnp.float32,
        ssm=SSMConfig(d_state=16, d_conv=4, expand=2, head_dim=16, chunk=16),
    )
