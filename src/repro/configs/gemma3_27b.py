"""gemma3-27b [dense]: 62L d_model=5376 32H (GQA kv=16) d_ff=21504
vocab=262144 — 5:1 local:global sliding window, 128k context.
[hf:google/gemma-3-1b-pt; unverified]"""
import dataclasses
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-27b",
    family="dense",
    n_layers=62,
    d_model=5376,
    n_heads=32,
    n_kv_heads=16,
    head_dim=128,
    d_ff=21504,
    vocab_size=262_144,
    qk_norm=True,
    post_norms=True,
    sliding_window=1024,
    global_every=6,  # 5 local : 1 global
    rope_theta=10_000.0,
    rope_global_theta=1_000_000.0,
    tie_embeddings=True,
    max_seq_len=131_072,
    remat="full",
)


def smoke_config() -> ModelConfig:
    import jax.numpy as jnp
    return dataclasses.replace(
        CONFIG, n_layers=8, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab_size=512, sliding_window=8, max_seq_len=128,
        dtype=jnp.float32, remat="none",
    )
