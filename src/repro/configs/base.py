"""Model / workload configuration dataclasses.

Each assigned architecture file (``src/repro/configs/<id>.py``) exports
``CONFIG`` (the exact published configuration) and ``smoke_config()`` (a
reduced same-family variant for CPU tests). ``repro.configs`` is the
registry.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, Optional, Tuple

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_expert: int  # per-expert FFN width
    n_shared: int = 0  # shared experts (qwen2-moe)
    d_shared: int = 0  # combined shared-expert FFN width
    every_k_layers: int = 1  # 1 = every layer; 2 = alternate (jamba)
    capacity_factor: float = 1.25


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    d_state: int = 128
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64
    chunk: int = 128

    def d_inner(self, d_model: int) -> int:
        return self.expand * d_model

    def n_heads(self, d_model: int) -> int:
        return self.d_inner(d_model) // self.head_dim


@dataclasses.dataclass(frozen=True)
class EncoderConfig:
    """Encoder stack for enc-dec archs (whisper). The modality frontend is a
    STUB: input_specs() provides precomputed frame embeddings."""

    n_layers: int
    n_frames: int  # fixed source length (whisper: 1500)


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | ssm | vlm | audio | hybrid
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int

    # attention details
    qk_norm: bool = False
    rope_theta: float = 10_000.0
    rope_fraction: float = 1.0  # phi4: partial rotary
    sliding_window: Optional[int] = None  # local attention window
    global_every: Optional[int] = None  # gemma3: 1 global per N layers
    rope_global_theta: Optional[float] = None  # gemma3 global layers

    # mixture of experts
    moe: Optional[MoEConfig] = None

    # state-space layers
    ssm: Optional[SSMConfig] = None
    attn_every: Optional[int] = None  # jamba: 1 attention layer per N

    # cross-attention (vlm) / enc-dec (audio)
    cross_attn_every: Optional[int] = None
    n_vision_tokens: int = 0
    encoder: Optional[EncoderConfig] = None

    # embeddings / norms
    tie_embeddings: bool = True
    norm_eps: float = 1e-6
    norm_type: str = "rms"  # rms | layer (whisper)
    use_rope: bool = True  # whisper: learned positions instead
    mlp_type: str = "swiglu"  # swiglu | gelu (whisper)
    post_norms: bool = False  # gemma3: post-attention/ffw norms
    max_seq_len: int = 131_072

    # numerics / runtime
    dtype: Any = jnp.bfloat16  # activations
    param_dtype: Any = jnp.float32
    remat: str = "none"  # none | full | dots
    attention_impl: str = "auto"  # auto | full | chunked
    attn_chunk: int = 1024
    optimizer: str = "adamw"  # adamw | adafactor
    sharding_overrides: Tuple[Tuple[str, Any], ...] = ()

    # ---------------------------------------------------------------- #
    @property
    def vocab_padded(self) -> int:
        """Vocab padded to x256 for model-axis shardability (Megatron
        style); logits over the pad are masked in the loss."""
        return int(math.ceil(self.vocab_size / 256) * 256)

    @property
    def q_per_kv(self) -> int:
        return self.n_heads // self.n_kv_heads

    def is_attn_layer(self, idx: int) -> bool:
        """Hybrid stacks: which layers are attention (rest are SSM)."""
        if self.ssm is None:
            return True
        if self.attn_every is None:
            return False  # pure SSM
        return idx % self.attn_every == self.attn_every // 2

    def is_global_layer(self, idx: int) -> bool:
        """Sliding-window stacks: which layers attend globally."""
        if self.sliding_window is None:
            return True
        if self.global_every is None:
            return False
        return idx % self.global_every == self.global_every - 1

    def is_moe_layer(self, idx: int) -> bool:
        if self.moe is None:
            return False
        return idx % self.moe.every_k_layers == self.moe.every_k_layers - 1

    def is_cross_layer(self, idx: int) -> bool:
        if self.cross_attn_every is None:
            return False
        return idx % self.cross_attn_every == self.cross_attn_every - 1

    def param_count_estimate(self) -> int:
        """Exact parameter count from the spec tree."""
        from repro.models.model import build_specs
        from repro.models.module import count_params

        return count_params(build_specs(self))


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One assigned input-shape cell."""

    name: str  # train_4k | prefill_32k | decode_32k | long_500k
    kind: str  # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES: Dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", "train", 4_096, 256),
    "prefill_32k": ShapeConfig("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": ShapeConfig("decode_32k", "decode", 32_768, 128),
    "long_500k": ShapeConfig("long_500k", "decode", 524_288, 1),
}
