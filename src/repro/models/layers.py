"""Basic layers: norms, rotary embeddings, token embedding, sharding helpers."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.module import ParamSpec


# --------------------------------------------------------------------- #
# Sharding constraint helper (no-op outside jit/mesh contexts)
# --------------------------------------------------------------------- #
def with_sharding(x, spec: Optional[P]):
    """Apply a logical activation constraint, filtered to the active mesh's
    axes (see sharding.policy.active_mesh). No-op without an active mesh."""
    if spec is None:
        return x
    from repro.sharding.policy import filter_spec

    actual = filter_spec(spec)
    if actual is None:
        return x
    return jax.lax.with_sharding_constraint(x, actual)


def with_logical(x, axes):
    """Constraint by LOGICAL axis names, resolved against the active mesh
    with divisibility fallback (see sharding.policy.logical_spec)."""
    from repro.sharding.policy import logical_spec

    spec = logical_spec(x.shape, axes)
    if spec is None:
        return x
    return jax.lax.with_sharding_constraint(x, spec)


# --------------------------------------------------------------------- #
# RMSNorm
# --------------------------------------------------------------------- #
def rmsnorm_specs(dim: int) -> dict:
    return {"scale": ParamSpec((dim,), (None,), init="ones")}


def rmsnorm(params, x, eps: float = 1e-6):
    dtype = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * params["scale"].astype(jnp.float32)).astype(dtype)


def layernorm_specs(dim: int) -> dict:
    return {
        "scale": ParamSpec((dim,), (None,), init="ones"),
        "bias": ParamSpec((dim,), (None,), init="zeros"),
    }


def layernorm(params, x, eps: float = 1e-6):
    dtype = x.dtype
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    y = (x32 - mu) * jax.lax.rsqrt(var + eps)
    return (y * params["scale"] + params["bias"]).astype(dtype)


# --------------------------------------------------------------------- #
# Rotary position embedding
# --------------------------------------------------------------------- #
def rope_frequencies(head_dim: int, fraction: float, theta: float):
    rot_dim = int(head_dim * fraction) // 2 * 2
    inv = 1.0 / (theta ** (jnp.arange(0, rot_dim, 2, dtype=jnp.float32) / rot_dim))
    return inv, rot_dim


def apply_rope(x, positions, theta: float, fraction: float = 1.0):
    """x: [B, S, H, D]; positions: [B, S] int32."""
    head_dim = x.shape[-1]
    inv, rot_dim = rope_frequencies(head_dim, fraction, theta)
    if rot_dim == 0:
        return x
    ang = positions[..., None].astype(jnp.float32) * inv  # [B, S, rot/2]
    sin = jnp.sin(ang)[:, :, None, :]
    cos = jnp.cos(ang)[:, :, None, :]
    xr, xp = x[..., :rot_dim], x[..., rot_dim:]
    x1, x2 = xr[..., 0::2], xr[..., 1::2]
    r1 = x1 * cos - x2 * sin
    r2 = x2 * cos + x1 * sin
    rotated = jnp.stack([r1, r2], axis=-1).reshape(xr.shape)
    return jnp.concatenate([rotated.astype(x.dtype), xp], axis=-1)


# --------------------------------------------------------------------- #
# Token embedding / logits head
# --------------------------------------------------------------------- #
def embedding_specs(cfg) -> dict:
    specs = {
        "tokens": ParamSpec(
            (cfg.vocab_padded, cfg.d_model), ("vocab", "embed"), init="embed",
            scale=1.0, dtype=cfg.param_dtype,
        )
    }
    if not cfg.tie_embeddings:
        specs["unembed"] = ParamSpec(
            (cfg.d_model, cfg.vocab_padded), ("embed", "vocab"), init="small",
            dtype=cfg.param_dtype,
        )
    return specs


def embed_tokens(params, tokens, cfg):
    emb = params["tokens"].astype(cfg.dtype)[tokens]
    return emb * jnp.asarray(cfg.d_model, cfg.dtype) ** 0.5


def logits_head(params, x, cfg):
    if cfg.tie_embeddings:
        w = params["tokens"].astype(cfg.dtype)
        return jnp.einsum("bsd,vd->bsv", x, w)
    return jnp.einsum("bsd,dv->bsv", x, params["unembed"].astype(cfg.dtype))


# --------------------------------------------------------------------- #
# Learned positional embedding (whisper decoder/encoder)
# --------------------------------------------------------------------- #
def learned_pos_specs(n_positions: int, dim: int) -> dict:
    return {"pos": ParamSpec((n_positions, dim), (None, "embed"), init="small")}


def learned_pos(params, positions, dtype):
    return params["pos"].astype(dtype)[positions]
