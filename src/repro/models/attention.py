"""Attention: GQA, qk-norm, RoPE, sliding window, cross-attention, KV cache.

One implementation serves every assigned architecture:

* GQA with arbitrary ``n_kv_heads`` (projection weights stay flat 2-D so the
  model axis shards them even when head counts are not divisible by it).
* ``chunked`` full-sequence path: online-softmax over KV chunks (the
  flash-attention recurrence in pure JAX) — bounds activation memory at
  32k/500k sequence lengths.
* Sliding-window layers keep a ring-buffer cache of ``window`` slots with an
  explicit per-slot position array, so local layers cost O(window) HBM at
  decode regardless of sequence length (what makes gemma3 long_500k viable).
* Cross-attention (vlm/enc-dec) reuses the same machinery without RoPE or
  causal masking; its KV is computed once and cached at prefill.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from repro.models.layers import apply_rope, rmsnorm, with_logical
from repro.models.module import ParamSpec

NEG_INF = -2.0e38


# --------------------------------------------------------------------- #
# Specs
# --------------------------------------------------------------------- #
def attention_specs(cfg, cross: bool = False) -> dict:
    d, hq, hkv, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    pd = cfg.param_dtype
    specs = {
        "wq": ParamSpec((d, hq * dh), ("embed", "heads"), dtype=pd),
        "wk": ParamSpec((d, hkv * dh), ("embed", "kv_heads"), dtype=pd),
        "wv": ParamSpec((d, hkv * dh), ("embed", "kv_heads"), dtype=pd),
        "wo": ParamSpec((hq * dh, d), ("heads", "embed"), dtype=pd),
    }
    if cfg.qk_norm and not cross:
        specs["qnorm"] = {"scale": ParamSpec((dh,), (None,), init="ones")}
        specs["knorm"] = {"scale": ParamSpec((dh,), (None,), init="ones")}
    return specs


def _project_q(params, x, cfg):
    b, s, _ = x.shape
    q = jnp.einsum("bsd,dh->bsh", x, params["wq"].astype(cfg.dtype))
    q = q.reshape(b, s, cfg.n_heads, cfg.head_dim)
    if "qnorm" in params:
        q = rmsnorm(params["qnorm"], q, cfg.norm_eps)
    return q


def _project_kv(params, x, cfg):
    b, s, _ = x.shape
    k = jnp.einsum("bsd,dh->bsh", x, params["wk"].astype(cfg.dtype))
    v = jnp.einsum("bsd,dh->bsh", x, params["wv"].astype(cfg.dtype))
    k = k.reshape(b, s, cfg.n_kv_heads, cfg.head_dim)
    v = v.reshape(b, s, cfg.n_kv_heads, cfg.head_dim)
    if "knorm" in params:
        k = rmsnorm(params["knorm"], k, cfg.norm_eps)
    return k, v


def _out_proj(params, ctx, cfg):
    b, s = ctx.shape[:2]
    # NB: constraining the flat head dim of ctx to the wo "heads" sharding
    # here was tried (to psum outputs instead of gathering wo at decode) and
    # REFUTED: it forces worse resharding upstream of the cache-sharded
    # attention (16.3G vs 2.3G of all-gather) — see EXPERIMENTS.md §Perf.
    out = jnp.einsum("bsh,hd->bsd", ctx.reshape(b, s, -1), params["wo"].astype(cfg.dtype))
    return with_logical(out, ("batch", None, None))


# --------------------------------------------------------------------- #
# Full-sequence attention (train / prefill)
# --------------------------------------------------------------------- #
def _mask(q_pos, kv_pos, causal: bool, window: Optional[int]):
    """[.., S_q, S_kv] bool validity mask from position grids."""
    m = jnp.ones(q_pos.shape[:-1] + (q_pos.shape[-1], kv_pos.shape[-1]), bool)
    d = q_pos[..., :, None] - kv_pos[..., None, :]
    if causal:
        m &= d >= 0
    if window is not None:
        m &= d < window
    return m


def _sdpa(q, k, v, mask):
    """q: [B,Sq,Hkv,G,dh]; k/v: [B,Skv,Hkv,dh]; mask: [B,Sq,Skv] or None."""
    dh = q.shape[-1]
    scores = jnp.einsum("bqhgd,bkhd->bhgqk", q, k).astype(jnp.float32)
    scores = scores / jnp.sqrt(jnp.asarray(dh, jnp.float32))
    if mask is not None:
        scores = jnp.where(mask[:, None, None, :, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    return jnp.einsum("bhgqk,bkhd->bqhgd", probs, v)


def _sdpa_chunked(q, k, v, q_pos, kv_pos, causal, window, chunk):
    """Online-softmax over KV chunks — O(S*chunk) live memory."""
    b, sq, hkv, g, dh = q.shape
    skv = k.shape[1]
    n_chunks = skv // chunk
    k_c = k.reshape(b, n_chunks, chunk, hkv, dh).transpose(1, 0, 2, 3, 4)
    v_c = v.reshape(b, n_chunks, chunk, hkv, dh).transpose(1, 0, 2, 3, 4)
    p_c = kv_pos.reshape(b, n_chunks, chunk).transpose(1, 0, 2)

    def step(carry, chunk_in):
        m, l, acc = carry
        kc, vc, pc = chunk_in
        s = jnp.einsum("bqhgd,bkhd->bhgqk", q, kc).astype(jnp.float32)
        s = s / jnp.sqrt(jnp.asarray(dh, jnp.float32))
        msk = _mask(q_pos, pc, causal, window)  # [b, sq, chunk]
        s = jnp.where(msk[:, None, None, :, :], s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(s - m_new[..., None])
        l_new = l * alpha + p.sum(axis=-1)
        acc_new = acc * alpha[..., None] + jnp.einsum(
            "bhgqk,bkhd->bhgqd", p.astype(q.dtype), vc
        ).astype(jnp.float32)
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, hkv, g, sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, hkv, g, sq), jnp.float32)
    a0 = jnp.zeros((b, hkv, g, sq, dh), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(step, (m0, l0, a0), (k_c, v_c, p_c))
    ctx = acc / jnp.maximum(l, 1e-30)[..., None]
    return ctx.transpose(0, 3, 1, 2, 4).astype(q.dtype)  # [b, sq, hkv, g, dh]


def attention(
    params,
    x,
    cfg,
    *,
    positions,  # [B, S] int32
    causal: bool = True,
    window: Optional[int] = None,
    theta: Optional[float] = None,
    kv_src=None,  # cross-attention source [B, S_kv, D]
    kv_positions=None,
):
    """Full-sequence attention (train / prefill). Returns (out, (k, v))."""
    b, s, _ = x.shape
    theta = cfg.rope_theta if theta is None else theta
    q = _project_q(params, x, cfg)
    src = x if kv_src is None else kv_src
    k, v = _project_kv(params, src, cfg)
    if kv_src is None:  # self-attention: RoPE on q and k
        q = apply_rope(q, positions, theta, cfg.rope_fraction)
        k = apply_rope(k, positions if kv_positions is None else kv_positions,
                       theta, cfg.rope_fraction)
        kv_pos = positions if kv_positions is None else kv_positions
    else:
        kv_pos = (
            kv_positions
            if kv_positions is not None
            else jnp.broadcast_to(jnp.arange(src.shape[1], dtype=jnp.int32), (b, src.shape[1]))
        )
    qg = q.reshape(b, s, cfg.n_kv_heads, cfg.q_per_kv, cfg.head_dim)

    use_chunked = cfg.attention_impl == "chunked" or (
        cfg.attention_impl == "auto"
        and src.shape[1] > 2048
        and src.shape[1] % cfg.attn_chunk == 0
    )
    if use_chunked:
        ctx = _sdpa_chunked(qg, k, v, positions, kv_pos, causal, window, cfg.attn_chunk)
    else:
        mask = _mask(positions, kv_pos, causal, window) if (causal or window) else None
        ctx = _sdpa(qg, k, v, mask)
    return _out_proj(params, ctx, cfg), (k, v)


# --------------------------------------------------------------------- #
# KV cache + decode step
# --------------------------------------------------------------------- #
def init_cache_layer(cfg, batch: int, max_len: int, window: Optional[int], rules=None):
    """Cache pytree for one attention layer (ring buffer for local layers)."""
    slots = min(window, max_len) if window is not None else max_len
    shape = (batch, slots, cfg.n_kv_heads, cfg.head_dim)
    return {
        "k": jnp.zeros(shape, cfg.dtype),
        "v": jnp.zeros(shape, cfg.dtype),
        "slot_pos": jnp.full((batch, slots), -1, jnp.int32),
    }


def cache_layer_specs(cfg, batch: int, max_len: int, window: Optional[int]):
    """(shape, logical axes) pairs for dry-run input specs."""
    slots = min(window, max_len) if window is not None else max_len
    kv = ((batch, slots, cfg.n_kv_heads, cfg.head_dim),
          ("cache_batch", "cache_seq", "kv_heads", None))
    return {
        "k": (kv[0], kv[1], cfg.dtype),
        "v": (kv[0], kv[1], cfg.dtype),
        "slot_pos": ((batch, slots), ("cache_batch", "cache_seq"), jnp.int32),
    }


def cache_write(cache, k_new, v_new, positions):
    """Write S_new entries at their ring slots. positions: [B, S_new]."""
    slots_total = cache["k"].shape[1]
    slot = positions % slots_total  # [B, S_new]
    b_idx = jnp.arange(k_new.shape[0], dtype=jnp.int32)[:, None]
    k = cache["k"].at[b_idx, slot].set(k_new)
    v = cache["v"].at[b_idx, slot].set(v_new)
    sp = cache["slot_pos"].at[b_idx, slot].set(positions)
    return {"k": k, "v": v, "slot_pos": sp}


def attention_decode(
    params,
    x,  # [B, 1, D]
    cache,
    cfg,
    *,
    position,  # [B] int32 current position
    window: Optional[int] = None,
    theta: Optional[float] = None,
    cross: bool = False,
):
    """One-token decode against the cache. Returns (out, new_cache)."""
    b = x.shape[0]
    theta = cfg.rope_theta if theta is None else theta
    q = _project_q(params, x, cfg)  # [B, 1, Hq, dh]
    pos2 = position[:, None]
    if not cross:
        q = apply_rope(q, pos2, theta, cfg.rope_fraction)
        k_new, v_new = _project_kv(params, x, cfg)
        k_new = apply_rope(k_new, pos2, theta, cfg.rope_fraction)
        cache = cache_write(cache, k_new, v_new, pos2)
    qg = q.reshape(b, 1, cfg.n_kv_heads, cfg.q_per_kv, cfg.head_dim)

    k, v, slot_pos = cache["k"], cache["v"], cache["slot_pos"]
    scores = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k).astype(jnp.float32)
    scores = scores / jnp.sqrt(jnp.asarray(cfg.head_dim, jnp.float32))
    valid = slot_pos >= 0
    if not cross:
        valid &= slot_pos <= position[:, None]
        if window is not None:
            valid &= (position[:, None] - slot_pos) < window
    scores = jnp.where(valid[:, None, None, None, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
    ctx = jnp.einsum("bhgqk,bkhd->bqhgd", probs, v)
    return _out_proj(params, ctx, cfg), cache
