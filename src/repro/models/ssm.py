"""Mamba2-style SSD (state-space duality) block, chunked matmul form.

Implements the SSD algorithm of Mamba-2 (arXiv:2405.21060): the selective
state-space recurrence

    h_t = exp(dt_t * A) h_{t-1} + dt_t * B_t (x) x_t ,   y_t = C_t . h_t + D x_t

evaluated chunk-wise so that within a chunk the quadratic (attention-like)
matmul form runs on the MXU, and across chunks only the [B, H, N, P] state
is carried by a ``lax.scan`` — the TPU-native middle ground between a full
sequential scan (latency-bound) and the full quadratic form (O(S^2)).

The causal depthwise conv (kernel ``d_conv``) is a shift-and-add over taps
(no im2col). Decode keeps an O(1) cache: the SSD state plus the last
``d_conv - 1`` conv inputs.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from repro.models.layers import rmsnorm, with_logical
from repro.models.module import ParamSpec


# --------------------------------------------------------------------- #
# Specs
# --------------------------------------------------------------------- #
def ssm_specs(cfg) -> dict:
    s, d, pd = cfg.ssm, cfg.d_model, cfg.param_dtype
    di, n, h = s.d_inner(d), s.d_state, s.n_heads(d)
    return {
        "wz": ParamSpec((d, di), ("embed", "inner"), dtype=pd),
        "wx": ParamSpec((d, di), ("embed", "inner"), dtype=pd),
        "wB": ParamSpec((d, n), ("embed", "state"), dtype=pd),
        "wC": ParamSpec((d, n), ("embed", "state"), dtype=pd),
        "wdt": ParamSpec((d, h), ("embed", None), dtype=pd),
        "conv_x": ParamSpec((s.d_conv, di), (None, "inner"), init="small", dtype=pd),
        "conv_B": ParamSpec((s.d_conv, n), (None, "state"), init="small", dtype=pd),
        "conv_C": ParamSpec((s.d_conv, n), (None, "state"), init="small", dtype=pd),
        "A_log": ParamSpec((h,), (None,), init="zeros", dtype=jnp.float32),
        "dt_bias": ParamSpec((h,), (None,), init="zeros", dtype=jnp.float32),
        "D": ParamSpec((h,), (None,), init="ones", dtype=jnp.float32),
        "norm": {"scale": ParamSpec((di,), ("inner",), init="ones", dtype=pd)},
        "wo": ParamSpec((di, d), ("inner", "embed"), dtype=pd),
    }


def _causal_conv(x, w, tail=None):
    """Depthwise causal conv via shift-and-add. x: [B, S, C]; w: [K, C].

    ``tail``: [B, K-1, C] previous inputs (decode);  returns conv output of
    the same length as x."""
    k = w.shape[0]
    if tail is None:
        pad = jnp.zeros((x.shape[0], k - 1, x.shape[2]), x.dtype)
    else:
        pad = tail.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)  # [B, S+K-1, C]
    s = x.shape[1]
    out = sum(xp[:, i : i + s, :] * w[i][None, None, :].astype(x.dtype) for i in range(k))
    return out


def _project(params, x, cfg):
    s = cfg.ssm
    dt = cfg.dtype
    z = jnp.einsum("bsd,di->bsi", x, params["wz"].astype(dt))
    xs = jnp.einsum("bsd,di->bsi", x, params["wx"].astype(dt))
    B = jnp.einsum("bsd,dn->bsn", x, params["wB"].astype(dt))
    C = jnp.einsum("bsd,dn->bsn", x, params["wC"].astype(dt))
    dtv = jnp.einsum("bsd,dh->bsh", x, params["wdt"].astype(dt))
    return z, xs, B, C, dtv


# --------------------------------------------------------------------- #
# Chunked SSD (train / prefill)
# --------------------------------------------------------------------- #
def ssd_chunked(x, B, C, dt, A, chunk: int, h0=None):
    """x: [B,S,H,P]; B,C: [B,S,N]; dt: [B,S,H] (>0); A: [H] (<0).

    Returns (y [B,S,H,P], h_final [B,H,N,P])."""
    b, s, h, p = x.shape
    n = B.shape[-1]
    pad = (-s) % chunk
    if pad:
        # Zero-pad: dt=0 => decay exp(0)=1 and contribution dt*B*x = 0, so
        # padded steps are identity on the state; their outputs are dropped.
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
    s_pad = s + pad
    nc = s_pad // chunk
    xc = x.reshape(b, nc, chunk, h, p)
    Bc = B.reshape(b, nc, chunk, n)
    Cc = C.reshape(b, nc, chunk, n)
    dtc = dt.reshape(b, nc, chunk, h)
    del s_pad

    loga = dtc * A[None, None, None, :]  # [b, nc, L, h], negative
    cum = jnp.cumsum(loga, axis=2)  # inclusive within-chunk cumsum

    if h0 is None:
        h0 = jnp.zeros((b, h, n, p), jnp.float32)

    def step(hprev, inp):
        xc_, Bc_, Cc_, dtc_, cum_ = inp  # leading dim b
        L = xc_.shape[1]
        # Intra-chunk quadratic form (per head decay mask).
        cb = jnp.einsum("bin,bjn->bij", Cc_, Bc_).astype(jnp.float32)  # [b,L,L]
        seg = cum_[:, :, None, :] - cum_[:, None, :, :]  # [b,i,j,h]
        mask = jnp.tril(jnp.ones((L, L), bool))
        # Mask in log space BEFORE exp: above the diagonal seg > 0 and
        # exp(seg) overflows, which poisons the backward pass (inf * 0).
        seg = jnp.where(mask[None, :, :, None], seg, -jnp.inf)
        decay = jnp.exp(seg)
        m = cb[:, :, :, None] * decay * dtc_[:, None, :, :]  # [b,i,j,h]
        y_intra = jnp.einsum("bijh,bjhp->bihp", m.astype(xc_.dtype), xc_)
        # Inter-chunk: contribution of carried state.
        instate = jnp.exp(cum_)  # [b,i,h]
        y_inter = jnp.einsum(
            "bin,bhnp,bih->bihp", Cc_.astype(jnp.float32), hprev, instate
        ).astype(xc_.dtype)
        # New carried state.
        tail = jnp.exp(cum_[:, -1:, :] - cum_)  # exp(cum_L - cum_j) [b,j,h]
        contrib = jnp.einsum(
            "bjn,bjhp,bjh->bhnp",
            Bc_.astype(jnp.float32),
            xc_.astype(jnp.float32),
            (dtc_ * tail).astype(jnp.float32),
        )
        hnew = jnp.exp(cum_[:, -1, :])[:, :, None, None] * hprev + contrib
        return hnew, y_intra + y_inter

    inputs = (
        xc.transpose(1, 0, 2, 3, 4),
        Bc.transpose(1, 0, 2, 3),
        Cc.transpose(1, 0, 2, 3),
        dtc.transpose(1, 0, 2, 3),
        cum.transpose(1, 0, 2, 3),
    )
    h_final, yc = jax.lax.scan(step, h0, inputs)
    y = yc.transpose(1, 0, 2, 3, 4).reshape(b, s + pad, h, p)[:, :s]
    return y, h_final


def ssd_sequential_ref(x, B, C, dt, A):
    """O(S) sequential oracle for tests (fp32)."""
    b, s, h, p = x.shape
    n = B.shape[-1]
    hs = jnp.zeros((b, h, n, p), jnp.float32)
    ys = []
    for t in range(s):
        a = jnp.exp(dt[:, t] * A[None, :])  # [b,h]
        upd = jnp.einsum("bn,bhp,bh->bhnp", B[:, t].astype(jnp.float32),
                         x[:, t].astype(jnp.float32), dt[:, t])
        hs = a[:, :, None, None] * hs + upd
        ys.append(jnp.einsum("bn,bhnp->bhp", C[:, t].astype(jnp.float32), hs))
    return jnp.stack(ys, axis=1)  # [b,s,h,p]


# --------------------------------------------------------------------- #
# Block-level apply
# --------------------------------------------------------------------- #
def _split_heads(xs, cfg):
    s = cfg.ssm
    b, L, di = xs.shape
    return xs.reshape(b, L, di // s.head_dim, s.head_dim)


def ssm_block(params, x, cfg, conv_tail=None, h0=None, return_cache: bool = False):
    """Full-sequence SSD block. x: [B, S, D] -> [B, S, D] (+ cache)."""
    s = cfg.ssm
    z, xs, B, C, dtv = _project(params, x, cfg)
    tail_x = tail_B = tail_C = None
    if conv_tail is not None:
        tail_x, tail_B, tail_C = conv_tail["x"], conv_tail["B"], conv_tail["C"]
    conv_in = {"x": xs, "B": B, "C": C}
    xs = jax.nn.silu(_causal_conv(xs, params["conv_x"], tail_x))
    B = jax.nn.silu(_causal_conv(B, params["conv_B"], tail_B))
    C = jax.nn.silu(_causal_conv(C, params["conv_C"], tail_C))
    xs = with_logical(xs, ("batch", None, "inner"))

    A = -jnp.exp(params["A_log"].astype(jnp.float32))
    dt = jax.nn.softplus(dtv.astype(jnp.float32) + params["dt_bias"][None, None, :])
    xh = _split_heads(xs, cfg)
    y, h_final = ssd_chunked(xh, B, C, dt, A, chunk=min(s.chunk, x.shape[1]), h0=h0)
    y = y + params["D"][None, None, :, None].astype(y.dtype) * xh
    y = y.reshape(z.shape)
    y = rmsnorm(params["norm"], y * jax.nn.silu(z), cfg.norm_eps)
    out = jnp.einsum("bsi,id->bsd", y, params["wo"].astype(cfg.dtype))
    out = with_logical(out, ("batch", None, None))
    if not return_cache:
        return out, None
    k = s.d_conv - 1
    cache = {
        "h": h_final,
        "conv": {name: arr[:, -k:, :] for name, arr in conv_in.items()},
    }
    return out, cache


def ssm_cache_specs(cfg, batch: int):
    s = cfg.ssm
    di, n, h = s.d_inner(cfg.d_model), s.d_state, s.n_heads(cfg.d_model)
    k = s.d_conv - 1
    return {
        "h": ((batch, h, n, s.head_dim), ("cache_batch", None, "state", None), jnp.float32),
        "conv": {
            "x": ((batch, k, di), ("cache_batch", None, "inner"), cfg.dtype),
            "B": ((batch, k, n), ("cache_batch", None, "state"), cfg.dtype),
            "C": ((batch, k, n), ("cache_batch", None, "state"), cfg.dtype),
        },
    }


def init_ssm_cache(cfg, batch: int):
    return jax.tree.map(
        lambda sd: jnp.zeros(sd[0], sd[2]),
        ssm_cache_specs(cfg, batch),
        is_leaf=lambda x: isinstance(x, tuple) and len(x) == 3 and isinstance(x[0], tuple),
    )


def ssm_block_decode(params, x, cache, cfg):
    """One-token decode. x: [B, 1, D] -> (out [B, 1, D], new cache)."""
    s = cfg.ssm
    z, xs, B, C, dtv = _project(params, x, cfg)
    conv_prev = cache["conv"]
    new_conv = {
        "x": jnp.concatenate([conv_prev["x"][:, 1:], xs], axis=1),
        "B": jnp.concatenate([conv_prev["B"][:, 1:], B], axis=1),
        "C": jnp.concatenate([conv_prev["C"][:, 1:], C], axis=1),
    }
    xs = jax.nn.silu(_causal_conv(xs, params["conv_x"], conv_prev["x"]))
    B = jax.nn.silu(_causal_conv(B, params["conv_B"], conv_prev["B"]))
    C = jax.nn.silu(_causal_conv(C, params["conv_C"], conv_prev["C"]))

    A = -jnp.exp(params["A_log"].astype(jnp.float32))
    dt = jax.nn.softplus(dtv.astype(jnp.float32) + params["dt_bias"][None, None, :])[:, 0]
    xh = _split_heads(xs, cfg)[:, 0]  # [B, H, P]
    a = jnp.exp(dt * A[None, :])  # [B, H]
    upd = jnp.einsum("bn,bhp,bh->bhnp", B[:, 0].astype(jnp.float32),
                     xh.astype(jnp.float32), dt)
    h = a[:, :, None, None] * cache["h"] + upd
    y = jnp.einsum("bn,bhnp->bhp", C[:, 0].astype(jnp.float32), h).astype(cfg.dtype)
    y = y + params["D"][None, :, None].astype(y.dtype) * xh
    y = y.reshape(z.shape[0], 1, -1)
    y = rmsnorm(params["norm"], y * jax.nn.silu(z), cfg.norm_eps)
    out = jnp.einsum("bsi,id->bsd", y, params["wo"].astype(cfg.dtype))
    return out, {"h": h, "conv": new_conv}
