"""Decoder blocks and the scanned heterogeneous layer stack.

Every assigned architecture is a periodic pattern of block kinds
(attention / SSM / dense-MLP / MoE / cross-attention / local / global).
The stack groups layers into one *pattern period* (gemma3: 6, jamba: 8,
llama-vision: 5, homogeneous archs: 1), stacks parameters per period slot
over groups, and runs ``lax.scan`` over groups — HLO size stays O(period),
independent of depth (62- and 72-layer models compile like 6- and 8-layer
ones). Layers beyond ``n_groups * period`` form an unrolled tail
(gemma3: 62 = 10x6 + 2).

Caches thread through the scan as per-slot stacked pytrees
(``[n_groups, ...]`` leaves), so prefill/decode share the same structure.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.compat import optimization_barrier
from repro.models import attention as attn_lib
from repro.models import ssm as ssm_lib
from repro.models.layers import layernorm, layernorm_specs, rmsnorm, rmsnorm_specs
from repro.models.mlp import gelu_mlp, gelu_mlp_specs, swiglu, swiglu_specs
from repro.models.moe import moe, moe_specs
from repro.models.module import ParamSpec, stack_specs


# --------------------------------------------------------------------- #
# Layer kinds
# --------------------------------------------------------------------- #
@dataclasses.dataclass(frozen=True)
class LayerKind:
    attn: bool
    ssm: bool
    moe: bool
    cross: bool
    window: Optional[int]
    theta: float
    causal: bool = True


def layer_kind(cfg, idx: int, causal: bool = True, allow_cross: bool = True) -> LayerKind:
    is_attn = cfg.is_attn_layer(idx)
    window = None
    theta = cfg.rope_theta
    if is_attn and cfg.sliding_window is not None:
        if cfg.is_global_layer(idx):
            theta = cfg.rope_global_theta or cfg.rope_theta
        else:
            window = cfg.sliding_window
    return LayerKind(
        attn=is_attn,
        ssm=not is_attn,
        moe=cfg.is_moe_layer(idx),
        cross=allow_cross and cfg.is_cross_layer(idx),
        window=window,
        theta=theta,
        causal=causal,
    )


def pattern_period(cfg) -> int:
    period = 1
    for cycle in (cfg.global_every, cfg.attn_every, cfg.cross_attn_every,
                  cfg.moe.every_k_layers if cfg.moe else None):
        if cycle:
            period = math.lcm(period, cycle)
    return period


# --------------------------------------------------------------------- #
# Norm dispatch
# --------------------------------------------------------------------- #
def _norm_specs(cfg):
    return layernorm_specs(cfg.d_model) if cfg.norm_type == "layer" else rmsnorm_specs(cfg.d_model)


def _norm(params, x, cfg):
    fn = layernorm if cfg.norm_type == "layer" else rmsnorm
    return fn(params, x, cfg.norm_eps)


# --------------------------------------------------------------------- #
# One block
# --------------------------------------------------------------------- #
def block_specs(cfg, kind: LayerKind) -> dict:
    specs: Dict[str, Any] = {}
    if kind.cross:
        specs["cross_norm"] = _norm_specs(cfg)
        specs["cross"] = attn_lib.attention_specs(cfg, cross=True)
        specs["cross_gate"] = ParamSpec((), (), init="zeros")
    specs["pre_norm"] = _norm_specs(cfg)
    if kind.attn:
        specs["attn"] = attn_lib.attention_specs(cfg)
    else:
        specs["ssm"] = ssm_lib.ssm_specs(cfg)
    if cfg.post_norms:
        specs["post_norm"] = _norm_specs(cfg)
    if kind.moe:
        specs["mlp_norm"] = _norm_specs(cfg)
        specs["moe"] = moe_specs(cfg)
    elif cfg.d_ff > 0:
        specs["mlp_norm"] = _norm_specs(cfg)
        if cfg.mlp_type == "gelu":
            specs["mlp"] = gelu_mlp_specs(cfg.d_model, cfg.d_ff, cfg.param_dtype)
        else:
            specs["mlp"] = swiglu_specs(cfg.d_model, cfg.d_ff, cfg.param_dtype)
    return specs


def _mlp_part(params, x, cfg, kind: LayerKind):
    if "mlp_norm" not in params:  # pure-SSM blocks (mamba2) have no FFN
        return x, jnp.float32(0)
    h = _norm(params["mlp_norm"], x, cfg)
    if kind.moe:
        out, aux = moe(params["moe"], h, cfg)
    elif cfg.mlp_type == "gelu":
        out, aux = gelu_mlp(params["mlp"], h, cfg), jnp.float32(0)
    else:
        out, aux = swiglu(params["mlp"], h, cfg), jnp.float32(0)
    return x + out, aux


def block_apply(params, x, cfg, kind: LayerKind, ctx, collect_cache: bool = False):
    """Full-sequence block. ctx: positions [B,S], cross_src, cross_positions.

    Returns (x, aux, cache_or_None)."""
    cache = {}
    if kind.cross:
        h = _norm(params["cross_norm"], x, cfg)
        c_out, (ck, cv) = attn_lib.attention(
            params["cross"], h, cfg,
            positions=ctx["positions"], causal=False,
            kv_src=ctx["cross_src"], kv_positions=ctx.get("cross_positions"),
        )
        x = x + jnp.tanh(params["cross_gate"]).astype(x.dtype) * c_out
        if collect_cache:
            n_src = ck.shape[1]
            src_pos = jnp.broadcast_to(
                jnp.arange(n_src, dtype=jnp.int32), (ck.shape[0], n_src)
            )
            cache["cross_kv"] = {"k": ck, "v": cv, "slot_pos": src_pos}
    h = _norm(params["pre_norm"], x, cfg)
    if kind.attn:
        a_out, (k, v) = attn_lib.attention(
            params["attn"], h, cfg,
            positions=ctx["positions"], causal=kind.causal,
            window=kind.window, theta=kind.theta,
        )
        if collect_cache:
            lc = attn_lib.init_cache_layer(cfg, x.shape[0], ctx["max_len"], kind.window)
            cache["attn"] = attn_lib.cache_write(lc, k, v, ctx["positions"])
    else:
        a_out, ssm_cache = ssm_lib.ssm_block(
            params["ssm"], h, cfg, return_cache=collect_cache
        )
        if collect_cache:
            cache["ssm"] = ssm_cache
    if cfg.post_norms:
        a_out = _norm(params["post_norm"], a_out, cfg)
    x = x + a_out
    x, aux = _mlp_part(params, x, cfg, kind)
    return x, aux, (cache if collect_cache else None)


def block_decode(params, x, cache, cfg, kind: LayerKind, ctx):
    """One-token block step. ctx: position [B]. Returns (x, new_cache)."""
    new_cache = dict(cache)
    if kind.cross:
        h = _norm(params["cross_norm"], x, cfg)
        c_out, _ = attn_lib.attention_decode(
            params["cross"], h, cache["cross_kv"], cfg,
            position=ctx["position"], cross=True,
        )
        x = x + jnp.tanh(params["cross_gate"]).astype(x.dtype) * c_out
    h = _norm(params["pre_norm"], x, cfg)
    if kind.attn:
        a_out, new_cache["attn"] = attn_lib.attention_decode(
            params["attn"], h, cache["attn"], cfg,
            position=ctx["position"], window=kind.window, theta=kind.theta,
        )
    else:
        a_out, new_cache["ssm"] = ssm_lib.ssm_block_decode(params["ssm"], h, cache["ssm"], cfg)
    if cfg.post_norms:
        a_out = _norm(params["post_norm"], a_out, cfg)
    x = x + a_out
    x, _ = _mlp_part(params, x, cfg, kind)
    return x, new_cache


# --------------------------------------------------------------------- #
# Stack: scan over groups + unrolled tail
# --------------------------------------------------------------------- #
def stack_layout(cfg, n_layers: Optional[int] = None, causal: bool = True,
                 allow_cross: bool = True):
    n_layers = n_layers if n_layers is not None else cfg.n_layers
    period = pattern_period(cfg)
    n_groups, tail = divmod(n_layers, period)
    if n_groups == 0:
        period, n_groups, tail = 1, 0, n_layers
    kinds = [layer_kind(cfg, i, causal, allow_cross) for i in range(period)]
    tail_kinds = [
        layer_kind(cfg, n_groups * period + i, causal, allow_cross)
        for i in range(tail)
    ]
    return period, n_groups, kinds, tail_kinds


def stack_specs_tree(cfg, n_layers: Optional[int] = None, causal: bool = True,
                     allow_cross: bool = True) -> dict:
    period, n_groups, kinds, tail_kinds = stack_layout(cfg, n_layers, causal, allow_cross)
    tree: Dict[str, Any] = {}
    if n_groups > 0:
        group = {f"slot{i}": block_specs(cfg, k) for i, k in enumerate(kinds)}
        tree["scan"] = stack_specs(group, n_groups, axis_name="layers")
    if tail_kinds:
        tree["tail"] = {f"layer{i}": block_specs(cfg, k) for i, k in enumerate(tail_kinds)}
    return tree


def _maybe_remat(fn, cfg):
    if cfg.remat == "full":
        return jax.checkpoint(fn)
    if cfg.remat == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims
        )
    return fn


def stack_apply(params, x, cfg, ctx, n_layers: Optional[int] = None,
                causal: bool = True, collect_cache: bool = False,
                allow_cross: bool = True):
    """Run the whole stack. Returns (x, aux_total, caches_or_None)."""
    period, n_groups, kinds, tail_kinds = stack_layout(cfg, n_layers, causal, allow_cross)
    caches: Dict[str, Any] = {}

    if n_groups > 0:
        def group_fn(x, group_params):
            # Barrier: without it XLA hoists the first-use f32 upcast of x
            # out of the backward scan, materializing the whole residual
            # stash in f32 (2x the bf16 stash; measured on grok-1). The
            # compat wrapper keeps it differentiable on JAX 0.4.x and
            # barriers the cotangent on the backward path too.
            x = optimization_barrier(x)
            aux = jnp.float32(0)
            gcache = {}
            for i, kind in enumerate(kinds):
                x, a, c = block_apply(
                    group_params[f"slot{i}"], x, cfg, kind, ctx, collect_cache
                )
                aux = aux + a
                if collect_cache:
                    gcache[f"slot{i}"] = c
            return x, (aux, gcache) if collect_cache else (aux, None)

        group_fn = _maybe_remat(group_fn, cfg)

        def scan_body(carry, group_params):
            x, aux = carry
            x, (a, gcache) = group_fn(x, group_params)
            return (x, aux + a), gcache

        (x, aux), gcaches = jax.lax.scan(scan_body, (x, jnp.float32(0)), params["scan"])
        if collect_cache:
            caches["scan"] = gcaches
    else:
        aux = jnp.float32(0)

    if tail_kinds:
        tcaches = {}
        for i, kind in enumerate(tail_kinds):
            x, a, c = block_apply(
                params["tail"][f"layer{i}"], x, cfg, kind, ctx, collect_cache
            )
            aux = aux + a
            if collect_cache:
                tcaches[f"layer{i}"] = c
        if collect_cache:
            caches["tail"] = tcaches
    return x, aux, (caches if collect_cache else None)


def stack_decode(params, x, caches, cfg, ctx, n_layers: Optional[int] = None):
    """One-token step through the stack. Returns (x, new_caches)."""
    period, n_groups, kinds, tail_kinds = stack_layout(cfg, n_layers)

    if n_groups > 0:
        def scan_body(x, inp):
            group_params, gcache = inp
            new_gcache = {}
            for i, kind in enumerate(kinds):
                x, new_gcache[f"slot{i}"] = block_decode(
                    group_params[f"slot{i}"], x, gcache[f"slot{i}"], cfg, kind, ctx
                )
            return x, new_gcache

        x, new_scan = jax.lax.scan(scan_body, x, (params["scan"], caches["scan"]))
        new_caches = {"scan": new_scan}
    else:
        new_caches = {}

    if tail_kinds:
        new_tail = {}
        for i, kind in enumerate(tail_kinds):
            x, new_tail[f"layer{i}"] = block_decode(
                params["tail"][f"layer{i}"], x, caches["tail"][f"layer{i}"], cfg, kind, ctx
            )
        new_caches["tail"] = new_tail
    return x, new_caches


# --------------------------------------------------------------------- #
# Cache spec trees (dry-run inputs, no allocation)
# --------------------------------------------------------------------- #
def _block_cache_specs(cfg, kind: LayerKind, batch: int, max_len: int):
    spec: Dict[str, Any] = {}
    if kind.cross:
        n_src = cfg.encoder.n_frames if cfg.encoder else cfg.n_vision_tokens
        spec["cross_kv"] = {
            "k": ((batch, n_src, cfg.n_kv_heads, cfg.head_dim),
                  ("cache_batch", None, "kv_heads", None), cfg.dtype),
            "v": ((batch, n_src, cfg.n_kv_heads, cfg.head_dim),
                  ("cache_batch", None, "kv_heads", None), cfg.dtype),
            "slot_pos": ((batch, n_src), ("cache_batch", None), jnp.int32),
        }
    if kind.attn:
        spec["attn"] = attn_lib.cache_layer_specs(cfg, batch, max_len, kind.window)
    else:
        spec["ssm"] = ssm_lib.ssm_cache_specs(cfg, batch)
    return spec


def cache_specs_tree(cfg, batch: int, max_len: int, n_layers: Optional[int] = None):
    """(shape, axes, dtype) tree matching stack_decode's cache structure."""
    period, n_groups, kinds, tail_kinds = stack_layout(cfg, n_layers)
    is_sd = lambda x: isinstance(x, tuple) and len(x) == 3 and isinstance(x[0], tuple)
    tree: Dict[str, Any] = {}
    if n_groups > 0:
        group = {
            f"slot{i}": _block_cache_specs(cfg, k, batch, max_len)
            for i, k in enumerate(kinds)
        }
        tree["scan"] = jax.tree.map(
            lambda sd: ((n_groups,) + sd[0], (None,) + sd[1], sd[2]), group, is_leaf=is_sd
        )
    if tail_kinds:
        tree["tail"] = {
            f"layer{i}": _block_cache_specs(cfg, k, batch, max_len)
            for i, k in enumerate(tail_kinds)
        }
    return tree
