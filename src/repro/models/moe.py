"""Mixture-of-Experts with sort-based capacity dispatch (EP-shardable).

Dispatch is the Megatron/MaxText "sort by expert" formulation — all static
shapes, no [tokens, experts, capacity] one-hot blow-up:

  1. route: top-k experts per token (softmax over all, renormalized top-k);
  2. argsort the (token, slot) pairs by expert id; position-within-expert
     comes from a cumulative count, entries beyond the expert capacity are
     dropped (standard capacity dropping, factor in MoEConfig);
  3. scatter tokens into the ``[n_experts, capacity, d_model]`` buffer —
     this is the tensor expert parallelism shards over the "model" axis;
  4. batched-matmul SwiGLU over experts;
  5. gather back and combine with router weights.

A switch-style load-balance auxiliary loss is returned alongside.

Shared experts (qwen2-moe) are a plain SwiGLU over the combined shared
width, added to the routed output.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
from repro.models.layers import with_logical
from repro.models.mlp import swiglu, swiglu_specs
from repro.models.module import ParamSpec


def moe_specs(cfg) -> dict:
    m, d, pd = cfg.moe, cfg.d_model, cfg.param_dtype
    specs = {
        "router": ParamSpec((d, m.n_experts), ("embed", "experts"), init="small", dtype=pd),
        "wi_gate": ParamSpec(
            (m.n_experts, d, m.d_expert), ("experts", "embed", "expert_mlp"), dtype=pd
        ),
        "wi_up": ParamSpec(
            (m.n_experts, d, m.d_expert), ("experts", "embed", "expert_mlp"), dtype=pd
        ),
        "wo": ParamSpec(
            (m.n_experts, m.d_expert, d), ("experts", "expert_mlp", "embed"), dtype=pd
        ),
    }
    if m.n_shared:
        specs["shared"] = swiglu_specs(d, m.d_shared, pd)
    return specs


def _capacity(n_tokens: int, cfg) -> int:
    m = cfg.moe
    c = int(n_tokens * m.top_k * m.capacity_factor / m.n_experts) + 1
    return max(8, -(-c // 8) * 8)  # round up to x8


def _dispatch_groups(t: int) -> int:
    """Tokens are dispatched within data-parallel groups so the scatter is
    batched over a sharded leading dim (GSPMD shards batched scatters; a
    flat scatter over all tokens would be replicated on every device).
    Per-group capacity also matches how real EP systems provision buffers.
    """
    from repro.sharding.policy import active_dp_size

    g = active_dp_size()
    return g if (g > 1 and t % g == 0) else 1


def moe(params, x, cfg) -> Tuple[jax.Array, jax.Array]:
    """x: [B, S, D] -> (out [B, S, D], aux_loss scalar)."""
    m = cfg.moe
    b, s, d = x.shape
    t = b * s
    k, e = m.top_k, m.n_experts
    g = _dispatch_groups(t)
    tg = t // g  # tokens per dispatch group
    cap = _capacity(tg, cfg)
    xf = x.reshape(g, tg, d)
    xf = with_logical(xf, ("batch", None, None))

    # --- route -------------------------------------------------------- #
    logits = jnp.einsum("gtd,de->gte", xf, params["router"].astype(cfg.dtype))
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    top_p, top_e = jax.lax.top_k(probs, k)  # [g, tg, k]
    top_w = (top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)).astype(cfg.dtype)


    # --- dispatch (sort by expert, within each group; GATHER-only) ------ #
    # The forward dispatch uses no scatter at all: sorted entries for expert
    # E occupy the contiguous range [start[E], start[E]+counts[E]), so the
    # [e, cap] buffer is a gather with index start[E] + c. Gathers vectorize
    # on TPU where scatters serialize (and the CPU backend's ScatterExpander
    # would materialize giant index matrices in the dry-run).
    flat_e = top_e.reshape(g, tg * k)
    order = jnp.argsort(flat_e, axis=-1, stable=True)  # [g, tg*k]
    sorted_e = jnp.take_along_axis(flat_e, order, axis=-1)
    counts = jnp.sum(
        flat_e[:, :, None] == jnp.arange(e, dtype=flat_e.dtype)[None, None, :],
        axis=1,
        dtype=jnp.int32,
    )  # [g, e] (compare-reduce; no scatter)
    start = jnp.cumsum(counts, axis=-1) - counts  # [g, e]

    # Load-balance aux (switch loss): E * sum_e f_e * p_e.
    f = counts.sum(axis=0).astype(jnp.float32) / (t * k)
    aux = e * jnp.sum(f * probs.mean(axis=(0, 1)))
    pos = (
        jnp.arange(tg * k, dtype=jnp.int32)[None, :]
        - jnp.take_along_axis(start, sorted_e, axis=-1)
    )
    keep = pos < cap  # [g, tg*k] capacity-dropped slots

    tok_of = order // k  # token index within group, sorted order
    sorted_vals = jnp.take_along_axis(xf, tok_of[..., None], axis=1)  # [g, tgk, d]
    src = start[:, :, None] + jnp.arange(cap, dtype=jnp.int32)[None, None, :]
    valid = jnp.arange(cap, dtype=jnp.int32)[None, None, :] < counts[:, :, None]
    src = jnp.clip(src, 0, tg * k - 1).reshape(g, e * cap)
    eb = jnp.take_along_axis(sorted_vals, src[..., None], axis=1)  # gather
    eb = eb * valid.reshape(g, e * cap, 1).astype(cfg.dtype)
    # EP constraint only when the expert count divides the model axis
    # (jamba 16e: yes; grok 8e / qwen2 60e: fall back to GSPMD's choice).
    eb = with_logical(eb.reshape(g, e, cap, d), ("batch", "experts", None, None))

    # --- expert SwiGLU (batched over groups and experts) --------------- #
    hspec = ("batch", "experts", None, "expert_mlp")
    gate = with_logical(
        jnp.einsum("gecd,edf->gecf", eb, params["wi_gate"].astype(cfg.dtype)), hspec
    )
    up = with_logical(
        jnp.einsum("gecd,edf->gecf", eb, params["wi_up"].astype(cfg.dtype)), hspec
    )
    h = jax.nn.silu(gate) * up
    out_b = jnp.einsum("gecf,efd->gecd", h, params["wo"].astype(cfg.dtype))
    out_b = with_logical(out_b, ("batch", "experts", None, None))
    out_b = out_b.reshape(g, e * cap, d)

    # --- combine (gather-only) ------------------------------------------ #
    # Sorted slot j reads buffer row sorted_e[j]*cap + pos[j]; token t's k
    # slots sit at sorted positions inv_order[t*k + s] (inverse permutation
    # via a second argsort) — again pure gathers.
    slot_of_sorted = jnp.clip(sorted_e * cap + pos, 0, e * cap - 1)
    slot_out = jnp.take_along_axis(out_b, slot_of_sorted[..., None], axis=1)
    slot_out = slot_out * keep[..., None].astype(cfg.dtype)  # [g, tgk, d]
    inv_order = jnp.argsort(order, axis=-1)  # [g, tg*k]
    per_slot = jnp.take_along_axis(slot_out, inv_order[..., None], axis=1)
    per_slot = per_slot.reshape(g, tg, k, d)
    out = jnp.einsum("gtkd,gtk->gtd", per_slot, top_w.reshape(g, tg, k))
    out = with_logical(out, ("batch", None, None))

    if m.n_shared:
        out = out + swiglu(params["shared"], xf.reshape(1, t, d), cfg).reshape(g, tg, d)
    return out.reshape(b, s, d), aux.astype(jnp.float32)
