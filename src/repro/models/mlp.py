"""Feed-forward blocks: SwiGLU (llama family) and GELU (whisper)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
from repro.models.layers import with_logical
from repro.models.module import ParamSpec


def swiglu_specs(d_model: int, d_ff: int, param_dtype) -> dict:
    return {
        "wi_gate": ParamSpec((d_model, d_ff), ("embed", "mlp"), dtype=param_dtype),
        "wi_up": ParamSpec((d_model, d_ff), ("embed", "mlp"), dtype=param_dtype),
        "wo": ParamSpec((d_ff, d_model), ("mlp", "embed"), dtype=param_dtype),
    }


def swiglu(params, x, cfg):
    gate = jnp.einsum("bsd,df->bsf", x, params["wi_gate"].astype(cfg.dtype))
    up = jnp.einsum("bsd,df->bsf", x, params["wi_up"].astype(cfg.dtype))
    h = jax.nn.silu(gate) * up
    h = with_logical(h, ("batch", None, "mlp"))
    out = jnp.einsum("bsf,fd->bsd", h, params["wo"].astype(cfg.dtype))
    return with_logical(out, ("batch", None, None))


def gelu_mlp_specs(d_model: int, d_ff: int, param_dtype) -> dict:
    return {
        "wi": ParamSpec((d_model, d_ff), ("embed", "mlp"), dtype=param_dtype),
        "bi": ParamSpec((d_ff,), ("mlp",), init="zeros", dtype=param_dtype),
        "wo": ParamSpec((d_ff, d_model), ("mlp", "embed"), dtype=param_dtype),
        "bo": ParamSpec((d_model,), (None,), init="zeros", dtype=param_dtype),
    }


def gelu_mlp(params, x, cfg):
    h = jnp.einsum("bsd,df->bsf", x, params["wi"].astype(cfg.dtype))
    h = jax.nn.gelu(h + params["bi"].astype(cfg.dtype))
    h = with_logical(h, ("batch", None, "mlp"))
    out = jnp.einsum("bsf,fd->bsd", h, params["wo"].astype(cfg.dtype))
    return out + params["bo"].astype(cfg.dtype)
