"""Model assembly: CausalLM / VLM / enc-dec forward, loss, step builders.

``build_specs(cfg)`` gives the full parameter spec tree; ``forward`` /
``prefill`` / ``decode_step`` are pure functions over (params, inputs).
The launch layer wraps them with jit + shardings; smoke tests call them
directly on CPU with real (reduced-config) parameters.
"""
from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.models import blocks
from repro.models.layers import (
    embed_tokens,
    embedding_specs,
    layernorm,
    layernorm_specs,
    learned_pos,
    learned_pos_specs,
    logits_head,
    rmsnorm,
    rmsnorm_specs,
)


def _norm_specs(cfg):
    return layernorm_specs(cfg.d_model) if cfg.norm_type == "layer" else rmsnorm_specs(cfg.d_model)


def _norm(params, x, cfg):
    fn = layernorm if cfg.norm_type == "layer" else rmsnorm
    return fn(params, x, cfg.norm_eps)


# --------------------------------------------------------------------- #
# Specs
# --------------------------------------------------------------------- #
def build_specs(cfg) -> dict:
    specs: Dict[str, Any] = {
        "embed": embedding_specs(cfg),
        "stack": blocks.stack_specs_tree(cfg),
        "final_norm": _norm_specs(cfg),
    }
    if not cfg.use_rope:
        specs["pos_dec"] = learned_pos_specs(cfg.max_seq_len, cfg.d_model)
    if cfg.encoder is not None:
        enc_cfg = cfg
        specs["encoder"] = {
            "stack": blocks.stack_specs_tree(
                enc_cfg, n_layers=cfg.encoder.n_layers, causal=False,
                allow_cross=False,
            ),
            "final_norm": _norm_specs(cfg),
            "pos_enc": learned_pos_specs(cfg.encoder.n_frames, cfg.d_model),
        }
    return specs


# --------------------------------------------------------------------- #
# Forward paths
# --------------------------------------------------------------------- #
def _encode(params, frames, cfg):
    """Whisper encoder over precomputed frame embeddings (frontend stub)."""
    b, s, _ = frames.shape
    pos = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    x = frames + learned_pos(params["encoder"]["pos_enc"], pos, cfg.dtype)
    ctx = {"positions": pos, "max_len": s}
    x, _, _ = blocks.stack_apply(
        params["encoder"]["stack"], x, cfg, ctx,
        n_layers=cfg.encoder.n_layers, causal=False, allow_cross=False,
    )
    return _norm(params["encoder"]["final_norm"], x, cfg)


def _make_ctx(params, tokens, cfg, extras, max_len: Optional[int] = None):
    b, s = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    ctx: Dict[str, Any] = {"positions": positions, "max_len": max_len or s}
    if cfg.encoder is not None:
        ctx["cross_src"] = _encode(params, extras["frames"], cfg)
    elif cfg.cross_attn_every is not None:
        ctx["cross_src"] = extras["vision_embeds"]
    return ctx


def forward(params, tokens, cfg, extras=None, collect_cache: bool = False,
            max_len: Optional[int] = None):
    """tokens: [B, S] int32 -> (logits [B, S, Vp], aux, caches)."""
    extras = extras or {}
    ctx = _make_ctx(params, tokens, cfg, extras, max_len)
    x = embed_tokens(params["embed"], tokens, cfg)
    if not cfg.use_rope:
        x = x + learned_pos(params["pos_dec"], ctx["positions"], cfg.dtype)
    x, aux, caches = blocks.stack_apply(
        params["stack"], x, cfg, ctx, collect_cache=collect_cache
    )
    x = _norm(params["final_norm"], x, cfg)
    logits = logits_head(params["embed"], x, cfg)
    return logits, aux, caches


def prefill(params, tokens, cfg, extras=None, max_len: Optional[int] = None):
    """Populate KV/SSM caches; return (last-token logits, caches)."""
    logits, _aux, caches = forward(
        params, tokens, cfg, extras, collect_cache=True, max_len=max_len
    )
    return logits[:, -1:], caches


def decode_step(params, caches, token, position, cfg, extras=None):
    """token: [B, 1]; position: [B]. Returns (logits [B,1,Vp], new caches)."""
    extras = extras or {}
    b = token.shape[0]
    ctx: Dict[str, Any] = {
        "position": position,
        "positions": position[:, None],
    }
    x = embed_tokens(params["embed"], token, cfg)
    if not cfg.use_rope:
        x = x + learned_pos(params["pos_dec"], position[:, None], cfg.dtype)
    x, new_caches = blocks.stack_decode(params["stack"], x, caches, cfg, ctx)
    x = _norm(params["final_norm"], x, cfg)
    logits = logits_head(params["embed"], x, cfg)
    return logits, new_caches


# --------------------------------------------------------------------- #
# Loss
# --------------------------------------------------------------------- #
def ce_loss(logits, labels, cfg, z_loss: float = 1e-4):
    """Cross-entropy over the padded vocab (pad ids masked out)."""
    vp = logits.shape[-1]
    logits = logits.astype(jnp.float32)
    if vp > cfg.vocab_size:
        neg = jnp.full((vp - cfg.vocab_size,), -1e9, jnp.float32)
        bias = jnp.concatenate([jnp.zeros((cfg.vocab_size,), jnp.float32), neg])
        logits = logits + bias
    logz = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    loss = jnp.mean(logz - ll)
    if z_loss:
        loss = loss + z_loss * jnp.mean(logz**2)
    return loss


def loss_fn(params, batch, cfg, aux_weight: float = 0.01):
    logits, aux, _ = forward(params, batch["tokens"], cfg, extras=batch.get("extras"))
    loss = ce_loss(logits, batch["labels"], cfg)
    return loss + aux_weight * aux, {"ce": loss, "aux": aux}
