"""Minimal spec-first module system (no flax dependency).

Every layer declares its parameters as a pytree of :class:`ParamSpec`
(shape + *logical axis names* + initializer). From one spec tree we derive:

* real parameters for CPU smoke tests (:func:`init_params`),
* ``ShapeDtypeStruct`` stand-ins with mesh shardings for the dry-run
  (:func:`abstract_params` — no allocation),
* ``NamedSharding`` trees for ``jit(in_shardings=...)``
  (:func:`param_shardings`).

Logical axis names are resolved to mesh axes by
:mod:`repro.sharding.policy`; layers never mention physical axes.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp

@dataclasses.dataclass(frozen=True)
class ParamSpec:
    """Declaration of one parameter tensor."""

    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]  # logical axis name per dim (None = never sharded)
    init: str = "normal"  # normal | zeros | ones | embed | small
    scale: float = 1.0
    dtype: Any = jnp.float32

    def __post_init__(self):
        if len(self.shape) != len(self.axes):
            raise ValueError(f"axes/shape rank mismatch: {self.shape} vs {self.axes}")


def _fan_in(shape: Tuple[int, ...]) -> int:
    return shape[0] if len(shape) >= 2 else max(shape[-1], 1)


def _init_leaf(key: jax.Array, spec: ParamSpec) -> jax.Array:
    if spec.init == "zeros":
        return jnp.zeros(spec.shape, spec.dtype)
    if spec.init == "ones":
        return jnp.ones(spec.shape, spec.dtype)
    if spec.init == "embed":
        return (jax.random.normal(key, spec.shape) * spec.scale).astype(spec.dtype)
    if spec.init == "small":
        std = 0.02 * spec.scale
        return (jax.random.normal(key, spec.shape) * std).astype(spec.dtype)
    # default: truncated-normal fan-in scaling
    std = spec.scale / math.sqrt(max(_fan_in(spec.shape), 1))
    return (jax.random.truncated_normal(key, -2.0, 2.0, spec.shape) * std).astype(spec.dtype)


def is_spec(x) -> bool:
    return isinstance(x, ParamSpec)


def init_params(specs, key: jax.Array):
    """Materialize real parameters (CPU smoke tests / examples)."""
    leaves, treedef = jax.tree.flatten(specs, is_leaf=is_spec)
    keys = jax.random.split(key, len(leaves))
    vals = [_init_leaf(k, s) for k, s in zip(keys, leaves)]
    return jax.tree.unflatten(treedef, vals)


def param_shardings(specs, mesh, rules, log=None):
    """NamedSharding tree from logical axes via the sharding policy."""
    from repro.sharding.policy import resolve_spec

    return jax.tree.map(
        lambda s: resolve_spec(s.shape, s.axes, mesh, rules, log), specs, is_leaf=is_spec
    )


def abstract_params(specs, mesh=None, rules=None, log=None):
    """ShapeDtypeStruct tree (optionally with shardings) — dry-run inputs."""
    if mesh is None:
        return jax.tree.map(
            lambda s: jax.ShapeDtypeStruct(s.shape, s.dtype), specs, is_leaf=is_spec
        )
    sh = param_shardings(specs, mesh, rules, log)
    return jax.tree.map(
        lambda s, ns: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=ns),
        specs,
        sh,
        is_leaf=is_spec,
    )


def count_params(specs) -> int:
    leaves = jax.tree.leaves(specs, is_leaf=is_spec)
    return int(sum(math.prod(s.shape) for s in leaves))


def stack_specs(specs, n: int, axis_name: Optional[str] = None):
    """Stack a spec tree along a new leading 'layers' dim (for lax.scan)."""
    return jax.tree.map(
        lambda s: ParamSpec(
            shape=(n,) + s.shape,
            axes=(axis_name,) + s.axes,
            init=s.init,
            scale=s.scale,
            dtype=s.dtype,
        ),
        specs,
        is_leaf=is_spec,
    )
