"""Single-device k-core decomposition engine (jit).

This is the conquer step's compute engine: the h-index fixed point of paper
Algorithms 1/2 over a :class:`~repro.graph.structs.BucketedGraph` part.
Estimates start at ``deg + ext`` and monotonically decrease to the exact
coreness (paper Corollary 2 / Montresor et al.).

The state vector ``c`` has ``n + 1`` entries: slot ``n`` is the ``-1``
sentinel that padded neighbor slots gather from, so padding never needs a
mask in the inner loop. Per iteration, per degree-bucket:

    gathered = c[bucket.neigh]                  # [nb, width]
    new      = hindex(gathered, ext[bucket])    # Algorithm 2
    c        = c.at[bucket.node_ids].set(new)   # pad rows hit slot n

Three interchangeable h-index operators (``op=``):
  * ``"sorted"`` — descending sort + prefix scan (paper's literal loop).
  * ``"count"``  — sort-free suffix counts (pure jnp).
  * ``"kernel"`` — the Pallas TPU kernel (interpret mode on CPU), with the
    degeneracy-bounded candidate window.

The *communication amount* (paper Section 5.4 metric: number of updated
estimates communicated per iteration) is counted on every step; it is the
quantity Figures 8 and 10 plot and what the divide step reduces.
"""
from __future__ import annotations

import dataclasses
import time
from functools import partial
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.hindex import hindex_count, hindex_of_sequence, hindex_sorted
from repro.graph.structs import BucketedGraph


@dataclasses.dataclass
class DecomposeResult:
    """Outcome of one part decomposition."""

    coreness: np.ndarray  # [n_nodes] int32
    iterations: int
    comm_amount: int  # total changed estimates across iterations
    comm_per_iter: List[int]
    peak_bytes: int  # device bytes of graph tiles + state
    wall_time_s: float


def _device_buckets(bg: BucketedGraph):
    return [
        (jnp.asarray(b.node_ids), jnp.asarray(b.neigh), jnp.asarray(b.deg))
        for b in bg.buckets
    ]


def _apply_op(gathered, ext_rows, cur_rows, op: str, cand: int):
    if op == "sorted":
        return hindex_sorted(gathered, ext_rows)
    if op == "count":
        return hindex_count(gathered, ext_rows, cand_chunk=min(256, cand))
    if op == "kernel":
        from repro.kernels.hindex import hindex_op

        return hindex_op(gathered, ext_rows, cur_rows, cand=cand)
    raise ValueError(f"unknown op {op!r}")


@partial(jax.jit, static_argnames=("op", "cand", "frozen_reads"))
def _sweep(c, ext_pad, buckets, op: str = "sorted", cand: int = 1 << 30,
           frozen_reads: bool = False):
    """One sweep over all buckets. Returns (new_c, changed_count).

    ``frozen_reads=False`` is Gauss-Seidel: later buckets read estimates
    already updated this sweep (within-sweep propagation, like the paper's
    in-place parameter-server updates) — strictly fewer iterations.
    ``True`` gives textbook Jacobi (what a pull-based PS round does).
    """
    frozen = c
    new_c = c
    for node_ids, neigh, _deg in buckets:
        src = frozen if frozen_reads else new_c
        gathered = src[neigh]  # sentinel slot -> -1
        ext_rows = ext_pad[node_ids]
        cur_rows = src[node_ids]
        est = _apply_op(gathered, ext_rows, cur_rows, op, cand)
        new_c = new_c.at[node_ids].set(est)
        new_c = new_c.at[-1].set(-1)  # re-pin sentinel
    changed = jnp.sum((new_c != c)[:-1])
    return new_c, changed


def decompose(
    bg: BucketedGraph,
    *,
    op: str = "sorted",
    max_iter: Optional[int] = None,
    gauss_seidel: bool = True,
    init_coreness: Optional[np.ndarray] = None,
    on_sweep=None,
) -> DecomposeResult:
    """Run the h-index fixed point on one part until no estimate changes.

    ``init_coreness`` resumes from a snapshot (fixed-point iterations are
    restartable from ANY valid upper bound of the true coreness — the
    fault-tolerance hook for the paper's 27.5h-scale runs);
    ``on_sweep(iteration, coreness_view)`` is the snapshot callback.
    """
    n = bg.n_nodes
    t0 = time.time()
    ext = jnp.asarray(bg.ext, dtype=jnp.int32)
    ext_pad = jnp.concatenate([ext, jnp.zeros((1,), jnp.int32)])
    start = (
        jnp.asarray(init_coreness, jnp.int32)
        if init_coreness is not None
        else jnp.asarray(bg.degrees, jnp.int32) + ext
    )
    c = jnp.concatenate([start, jnp.full((1,), -1, jnp.int32)])
    buckets = _device_buckets(bg)
    # Candidate-window bound (exact; see hindex_of_sequence docstring).
    cand = max(1, hindex_of_sequence(bg.degrees.astype(np.int64) + bg.ext))

    state_bytes = int(c.size * 4 + ext_pad.size * 4)
    peak = bg.memory_bytes() + state_bytes

    limit = max_iter if max_iter is not None else max(4, n)
    comm_per_iter: List[int] = []
    total = 0
    it = 0
    while it < limit:
        c, changed = _sweep(
            c, ext_pad, buckets, op=op, cand=cand, frozen_reads=not gauss_seidel
        )
        changed = int(changed)
        comm_per_iter.append(changed)
        total += changed
        it += 1
        if on_sweep is not None:
            on_sweep(it, c[:-1])
        if changed == 0:
            break
    coreness = np.asarray(c[:-1])
    return DecomposeResult(
        coreness=coreness,
        iterations=it,
        comm_amount=total,
        comm_per_iter=comm_per_iter,
        peak_bytes=int(peak),
        wall_time_s=time.time() - t0,
    )
