"""Single-device k-core decomposition engine (jit).

This is the conquer step's compute engine: the h-index fixed point of paper
Algorithms 1/2 over a :class:`~repro.graph.structs.BucketedGraph` part.
Estimates start at ``deg + ext`` and monotonically decrease to the exact
coreness (paper Corollary 2 / Montresor et al.).

The state vector ``c`` has ``n + 1`` entries: slot ``n`` is the ``-1``
sentinel that padded neighbor slots gather from, so padding never needs a
mask in the inner loop. Per iteration, per degree-bucket:

    gathered = c[bucket.neigh]                  # [nb, width]
    new      = hindex(gathered, ext[bucket])    # Algorithm 2
    c        = c.at[bucket.node_ids].set(new)   # pad rows hit slot n

Four interchangeable sweep engines (``op=``):
  * ``"sorted"`` — descending sort + prefix scan (paper's literal loop).
  * ``"count"``  — sort-free suffix counts (pure jnp).
  * ``"kernel"`` — the Pallas TPU h-index kernel (interpret mode on CPU),
    with the degeneracy-bounded candidate window.
  * ``"fused"``  — the fused Pallas sweep kernel (``kernels.fused``):
    gather + h-index + dirty-bit push in ONE kernel per row tile, the
    gathered matrix never materialized. With few tiles each bucket keeps
    its own ``lax.cond``-gated launch (bit-identical trajectory to the
    engines above); past ``fused_compaction_min_tiles`` tiles the cond
    chain is replaced by a dense active-row-index compaction — per sweep,
    the active tiles of each width group are compacted into one launch
    (estimate reads are Jacobi within the group, Gauss-Seidel across
    groups). The fixed point is unique, so final coreness stays
    bit-identical in every mode; per-sweep trajectories are identical
    except under compaction with ``gauss_seidel=True`` when a width group
    holds more than one active tile.

``int16=True`` (fused only) keeps the resident estimate vector int16 for
2x effective memory bandwidth; an overflow guard falls back to int32
whenever any starting estimate (``deg + ext``) reaches ``2**15`` —
estimates only decrease, so below that bound int16 can never wrap. The
result reports the dtype actually used (``est_dtype``).

**Active-frontier sweep scheduling** (Montresor et al.: after the first few
rounds only a small frontier still changes): each sweep returns a per-bucket
changed-count vector plus a per-bucket dirty flag, and the next sweep skips
— behind ``lax.cond``, so the gather and h-index are not executed — every
bucket that is quiescent. Two sound filters compose:

  1. the static ``bucket_adj`` bitmap (recorded once at bucketize time):
     a bucket none of whose adjacent buckets changed cannot change;
  2. per-node dirty bits pushed on device from changed rows of active
     buckets along their adjacency: a bucket none of whose OWN rows has a
     changed neighbor cannot change. This is the row-exact refinement that
     makes skipping effective on power-law graphs, where degree-class
     adjacency is dense.

A node's estimate is a function of its neighbors' estimates only, so both
filters are sound, not heuristic, and the fixed point is bit-identical to
the full-sweep schedule. ``frontier=False`` restores always-full sweeps
(the baseline the benchmarks compare against). Frontier granularity is the
bucket *tile* — bucketize splits degree classes into bounded row-tiles.

The *communication amount* (paper Section 5.4 metric: number of updated
estimates communicated per iteration) is counted on every step; it is the
quantity Figures 8 and 10 plot and what the divide step reduces. The
frontier adds the matching *work* metric: gathered rows per sweep.
"""
from __future__ import annotations

import dataclasses
import time
from functools import partial
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.hindex import hindex_count, hindex_of_sequence, hindex_sorted
from repro.graph.structs import BucketedGraph
from repro.roofline.kcore_model import sweep_cost


@dataclasses.dataclass
class DecomposeResult:
    """Outcome of one part decomposition.

    ``coreness`` is always reported in **original**-id order: engines
    running on a reordered layout (``BucketedGraph.perm`` set) gather
    ``coreness[inv_perm]`` before returning, so reordering never leaks.
    """

    coreness: np.ndarray  # [n_nodes] int32
    iterations: int
    comm_amount: int  # total changed estimates across iterations
    comm_per_iter: List[int]
    peak_bytes: int  # device bytes of graph tiles + state
    wall_time_s: float
    # Work metric (frontier scheduling): bucket rows gathered+h-indexed per
    # sweep, and what one always-full sweep would have gathered.
    active_rows_per_iter: List[int] = dataclasses.field(default_factory=list)
    rows_per_full_sweep: int = 0
    # Measured collective traffic (distributed engine): per-device ICI bytes
    # the sweep's collectives actually moved each iteration, from the live
    # frontier mask and the padded device-array shapes — including the
    # frontier's own dirty-bit psum, which the analytic
    # ``sweep_collective_bytes`` model omits. Empty for single-device runs
    # (they issue no collectives).
    collective_bytes_per_iter: List[int] = dataclasses.field(default_factory=list)
    # Modeled HBM traffic / compare-FLOPs per live sweep
    # (roofline.kcore_model, from the active-frontier mask and the engine's
    # fused/unfused dispatch shape) — what fig17 plots against the roofline.
    sweep_bytes_per_iter: List[int] = dataclasses.field(default_factory=list)
    sweep_flops_per_iter: List[int] = dataclasses.field(default_factory=list)
    # Estimate dtype the sweep actually ran with ("int16" only when the
    # opt-in mode passed the overflow guard) and, for op="fused", which
    # dispatch shape ran ("cond" | "compaction").
    est_dtype: str = "int32"
    fused_mode: str = ""

    @property
    def sweep_bytes(self) -> int:
        """Total modeled sweep HBM bytes across all iterations."""
        return int(sum(self.sweep_bytes_per_iter))

    @property
    def sweep_flops(self) -> int:
        """Total modeled sweep compare-FLOPs across all iterations."""
        return int(sum(self.sweep_flops_per_iter))

    @property
    def gathered_rows(self) -> int:
        """Total rows gathered across all sweeps (the work-done counter)."""
        return int(sum(self.active_rows_per_iter))

    @property
    def full_sweep_rows(self) -> int:
        """Rows the always-full-sweep schedule would have gathered."""
        return int(self.rows_per_full_sweep * self.iterations)

    @property
    def collective_bytes(self) -> int:
        """Total measured per-device collective bytes across all sweeps."""
        return int(sum(self.collective_bytes_per_iter))


def _device_buckets(bg: BucketedGraph):
    return [
        (jnp.asarray(b.node_ids), jnp.asarray(b.neigh), jnp.asarray(b.deg))
        for b in bg.buckets
    ]


def _apply_op(gathered, ext_rows, cur_rows, op: str, cand: int):
    if op == "sorted":
        return hindex_sorted(gathered, ext_rows)
    if op == "count":
        return hindex_count(gathered, ext_rows, cand_chunk=min(256, cand))
    if op == "kernel":
        from repro.kernels.hindex import hindex_op

        return hindex_op(gathered, ext_rows, cur_rows, cand=cand)
    raise ValueError(f"unknown op {op!r}")


@partial(jax.jit, static_argnames=("op", "cand", "frozen_reads", "track_dirty"))
def _sweep(c, ext_pad, buckets, active, op: str = "sorted", cand: int = 1 << 30,
           frozen_reads: bool = False, track_dirty: bool = True):
    """One sweep over the active buckets.

    Returns ``(new_c, changed [n_buckets], dirty_next [n_buckets])``:
    ``changed[i]`` counts rows of bucket ``i`` whose estimate changed (the
    paper's communication amount, per bucket); ``dirty_next[j]`` is True iff
    some row of bucket ``j`` has a neighbor that changed this sweep —
    changed rows *push* a per-node dirty bit along their adjacency, and each
    bucket then reads back only its own rows' bits. A node's estimate is a
    function of its neighbors' estimates, so ``dirty_next`` is exactly the
    set of buckets that could change next sweep.

    ``active`` is the [n_buckets] bool frontier mask; inactive buckets skip
    gather + h-index at runtime (``lax.cond``) and report 0 changed rows.
    ``track_dirty=False`` (the always-full-sweep baseline) compiles the
    dirty-bit push and read-back out entirely and returns an all-False
    ``dirty_next``.

    ``frozen_reads=False`` is Gauss-Seidel: later buckets read estimates
    already updated this sweep (within-sweep propagation, like the paper's
    in-place parameter-server updates) — strictly fewer iterations.
    ``True`` gives textbook Jacobi (what a pull-based PS round does).
    """
    sentinel = c.shape[0] - 1
    frozen = c
    new_c = c
    dirty = jnp.zeros((c.shape[0],), jnp.int8)  # per-node "a neighbor changed"
    changed_parts = []
    for bi, (node_ids, neigh, _deg) in enumerate(buckets):

        def update(nc, dt, node_ids=node_ids, neigh=neigh):
            src = frozen if frozen_reads else nc
            gathered = src[neigh]  # sentinel slot -> -1
            ext_rows = ext_pad[node_ids]
            cur_rows = src[node_ids]
            est = _apply_op(gathered, ext_rows, cur_rows, op, cand)
            # Pad rows (node_ids == sentinel) scatter into slot n, which is
            # re-pinned below, and never count as changed.
            row_changed = (est != cur_rows) & (node_ids != sentinel)
            ch = jnp.sum(row_changed).astype(jnp.int32)
            if track_dirty:
                # Push dirty bits to every neighbor of a changed row. Work
                # is proportional to the ACTIVE tile sizes, not the graph.
                dt = dt.at[neigh].max(
                    jnp.broadcast_to(row_changed[:, None], neigh.shape).astype(jnp.int8)
                )
            nc = nc.at[node_ids].set(est)
            nc = nc.at[-1].set(-1)  # re-pin sentinel
            return nc, dt, ch

        new_c, dirty, ch = jax.lax.cond(
            active[bi], update, lambda nc, dt: (nc, dt, jnp.int32(0)), new_c, dirty
        )
        changed_parts.append(ch)
    changed = (
        jnp.stack(changed_parts) if changed_parts else jnp.zeros((0,), jnp.int32)
    )
    if track_dirty and buckets:
        # Each bucket reads back its own rows' dirty bits ([rows] gathers).
        dirty_next = jnp.stack(
            [
                jnp.any((dirty[node_ids] > 0) & (node_ids != sentinel))
                for node_ids, _neigh, _deg in buckets
            ]
        )
    else:
        dirty_next = jnp.zeros((len(buckets),), bool)
    return new_c, changed, dirty_next


@partial(jax.jit, static_argnames=("cand", "frozen_reads", "track_dirty"))
def _sweep_fused(c, ext_pad, buckets, active, cand: int = 1 << 30,
                 frozen_reads: bool = False, track_dirty: bool = True):
    """One fused-engine sweep, cond dispatch (few tiles).

    Same contract and per-bucket sequencing as :func:`_sweep`, but each
    bucket's gather + h-index + dirty push is one fused kernel launch
    (``kernels.fused.fused_sweep_op``) instead of separate dispatches, so
    the trajectory — estimates, changed counts, dirty bits — is
    bit-identical to the unfused engines sweep by sweep. ``c`` may be
    int16 (opt-in estimate mode); the kernel widens in-register.
    """
    sentinel = c.shape[0] - 1
    frozen = c
    new_c = c
    dirty = jnp.zeros((c.shape[0],), jnp.int8)
    changed_parts = []
    for bi, (node_ids, neigh, _deg) in enumerate(buckets):

        def update(nc, dt, node_ids=node_ids, neigh=neigh):
            from repro.kernels.fused import fused_sweep_op

            src = frozen if frozen_reads else nc
            est, row_changed, d = fused_sweep_op(
                src, ext_pad, node_ids, neigh, cand=cand,
                track_dirty=track_dirty,
            )
            ch = jnp.sum(row_changed).astype(jnp.int32)
            if track_dirty:
                dt = jnp.maximum(dt, d)
            nc = nc.at[node_ids].set(est.astype(nc.dtype))
            nc = nc.at[-1].set(-1)  # re-pin sentinel
            return nc, dt, ch

        new_c, dirty, ch = jax.lax.cond(
            active[bi], update, lambda nc, dt: (nc, dt, jnp.int32(0)), new_c, dirty
        )
        changed_parts.append(ch)
    changed = (
        jnp.stack(changed_parts) if changed_parts else jnp.zeros((0,), jnp.int32)
    )
    if track_dirty and buckets:
        dirty_next = jnp.stack(
            [
                jnp.any((dirty[node_ids] > 0) & (node_ids != sentinel))
                for node_ids, _neigh, _deg in buckets
            ]
        )
    else:
        dirty_next = jnp.zeros((len(buckets),), bool)
    return new_c, changed, dirty_next


class _FusedGroups:
    """Width-grouped resident layout for the dense active-row-index
    compaction dispatch of the fused engine.

    With hundreds of tiles the per-bucket ``lax.cond`` chain dominates
    compile and dispatch time (both branches stay resident in XLA). This
    layout concatenates every tile of a width class into one resident
    ``[rows+1, width]`` array (ascending width == bucketize's emission
    order; the extra row is an all-sentinel pad target), and each sweep
    compacts the ACTIVE tiles' row indices into one dense index vector per
    group — one fused launch per width class, work proportional to the
    live frontier. The index vector is padded to a power of two so jit
    retraces stay logarithmic in frontier size.
    """

    def __init__(self, bg: BucketedGraph):
        n = bg.n_nodes
        nb = len(bg.buckets)
        by_width: dict = {}
        for bi, b in enumerate(bg.buckets):
            by_width.setdefault(b.width, []).append(bi)
        self.n_buckets = nb
        self.groups = []
        self.memory_bytes = 0
        for width in sorted(by_width):
            bis = by_width[width]
            ids = np.concatenate(
                [np.asarray(bg.buckets[bi].node_ids, np.int32) for bi in bis]
                + [np.full(1, n, np.int32)]
            )
            neigh = np.concatenate(
                [np.asarray(bg.buckets[bi].neigh, np.int32) for bi in bis]
                + [np.full((1, width), n, np.int32)]
            )
            tile_all = np.concatenate(
                [np.full(bg.buckets[bi].n_rows, bi, np.int32) for bi in bis]
                + [np.full(1, nb, np.int32)]
            )
            ranges, start = [], 0
            for bi in bis:
                r = bg.buckets[bi].n_rows
                ranges.append((bi, start, r))
                start += r
            self.groups.append({
                "ids": jnp.asarray(ids),
                "neigh": jnp.asarray(neigh),
                "tile_all": jnp.asarray(tile_all),
                "ranges": ranges,
                "pad_row": start,  # the all-sentinel row
            })
            self.memory_bytes += ids.nbytes + neigh.nbytes + tile_all.nbytes

    @staticmethod
    def active_rows(grp, active: np.ndarray, n_buckets: int):
        """Dense row-index compaction of ``grp``'s active tiles.

        Returns ``(row_idx, tile_of_row)`` int32 arrays padded to a power
        of two with the group's sentinel pad row, or ``None`` when no tile
        of this group is active.
        """
        sel = [(bi, s, r) for bi, s, r in grp["ranges"] if active[bi]]
        if not sel:
            return None
        row_idx = np.concatenate([np.arange(s, s + r, dtype=np.int32)
                                  for _bi, s, r in sel])
        tile_of = np.concatenate([np.full(r, bi, np.int32)
                                  for bi, _s, r in sel])
        k = row_idx.size
        k_pad = max(8, 1 << (k - 1).bit_length())
        if k_pad > k:
            # Pad rows gather the all-sentinel row (changed=0) and key the
            # throwaway segment-count slot n_buckets.
            row_idx = np.pad(row_idx, (0, k_pad - k),
                             constant_values=grp["pad_row"])
            tile_of = np.pad(tile_of, (0, k_pad - k),
                             constant_values=n_buckets)
        return row_idx, tile_of


@partial(jax.jit, static_argnames=("cand", "track_dirty", "n_counts"))
def _fused_compact_step(nc, src, ext_pad, ids_w, neigh_w, row_idx, tile_of_row,
                        changed, dirty, *, cand: int, track_dirty: bool,
                        n_counts: int):
    """One compacted fused launch over the active rows of a width group.

    ``src`` is the estimate vector the gather reads (``nc`` itself for
    Gauss-Seidel across groups, the sweep's frozen snapshot for Jacobi);
    per-bucket changed counts come back as a segment-sum keyed by
    ``tile_of_row`` (pad rows key -1 -> dropped by segment_sum).
    """
    from repro.kernels.fused import fused_sweep_op

    ids_a = ids_w[row_idx]
    neigh_a = neigh_w[row_idx]
    est, row_changed, d = fused_sweep_op(
        src, ext_pad, ids_a, neigh_a, cand=cand, track_dirty=track_dirty,
    )
    changed = changed + jax.ops.segment_sum(
        row_changed, tile_of_row, num_segments=n_counts
    )
    if track_dirty:
        dirty = jnp.maximum(dirty, d)
    nc = nc.at[ids_a].set(est.astype(nc.dtype))
    nc = nc.at[-1].set(-1)  # re-pin sentinel
    return nc, changed, dirty


@partial(jax.jit, static_argnames=("n_buckets",))
def _fused_compact_dirty_next(dirty, ids_list, tile_list, *, n_buckets: int):
    """Per-bucket dirty read-back over the resident group layouts."""
    sentinel = dirty.shape[0] - 1
    out = jnp.zeros((n_buckets + 1,), jnp.int32)
    for ids_w, tile_all in zip(ids_list, tile_list):
        flag = ((dirty[ids_w] > 0) & (ids_w != sentinel)).astype(jnp.int32)
        out = out.at[tile_all].max(flag)  # pad row keys slot n_buckets
    return out[:n_buckets] > 0


def _compaction_sweep(groups: _FusedGroups, c, ext_pad, active: np.ndarray,
                      cand: int, frozen_reads: bool, track_dirty: bool):
    """One fused-engine sweep, compaction dispatch (many tiles).

    Width groups run ascending (bucketize order): Gauss-Seidel across
    groups when ``frozen_reads=False``, textbook Jacobi (reads frozen at
    sweep start) otherwise. Within one group's single launch the reads are
    always Jacobi — see the engine docstring for when that changes the
    per-sweep trajectory (never the fixed point).
    """
    nb = groups.n_buckets
    frozen = c
    changed = jnp.zeros((nb + 1,), jnp.int32)
    dirty = jnp.zeros((c.shape[0],), jnp.int8)
    for grp in groups.groups:
        compacted = _FusedGroups.active_rows(grp, active, nb)
        if compacted is None:
            continue
        row_idx, tile_of = compacted
        src = frozen if frozen_reads else c
        c, changed, dirty = _fused_compact_step(
            c, src, ext_pad, grp["ids"], grp["neigh"],
            jnp.asarray(row_idx), jnp.asarray(tile_of), changed, dirty,
            cand=cand, track_dirty=track_dirty, n_counts=nb + 1,
        )
    if track_dirty:
        dirty_next = _fused_compact_dirty_next(
            dirty,
            tuple(g["ids"] for g in groups.groups),
            tuple(g["tile_all"] for g in groups.groups),
            n_buckets=nb,
        )
    else:
        dirty_next = jnp.zeros((nb,), bool)
    return c, changed[:nb], dirty_next


def decompose(
    bg: BucketedGraph,
    *,
    op: str = "sorted",
    max_iter: Optional[int] = None,
    gauss_seidel: bool = True,
    frontier: bool = True,
    init_coreness: Optional[np.ndarray] = None,
    seed_nodes: Optional[np.ndarray] = None,
    on_sweep=None,
    int16: bool = False,
    fused_compaction_min_tiles: int = 64,
) -> DecomposeResult:
    """Run the h-index fixed point on one part until no estimate changes.

    ``frontier`` enables active-frontier sweep scheduling (sound bucket
    skipping via the bucket-adjacency bitmap); ``False`` re-sweeps every
    bucket every iteration. ``init_coreness`` resumes from a snapshot
    (fixed-point iterations are restartable from ANY valid upper bound of
    the true coreness — the fault-tolerance hook for the paper's 27.5h-scale
    runs); ``on_sweep(iteration, coreness)`` is the snapshot callback,
    called after every sweep with an int32 original-id-order array view
    (lazy device array — ``np.asarray`` it to materialize; no host sync is
    forced on sweeps whose snapshot the hook discards) —
    :func:`repro.core.dckcore.dc_kcore` feeds its sweep-granularity
    checkpoints from it.

    If ``bg`` was built from a reordered graph (``bg.perm`` set), the
    reordering is invisible here: ``init_coreness`` is taken in original-id
    order and permuted in, ``on_sweep`` views and the returned ``coreness``
    are permuted back — a snapshot taken under one ordering restarts
    correctly under any other.

    ``seed_nodes`` restricts the INITIAL active frontier to the buckets
    owning the given nodes (original-id boolean mask or id array) instead
    of every bucket — the incremental engine's entry point: with a valid
    ``init_coreness`` upper bound and a seed set that covers every node
    whose estimate must move (see :mod:`repro.core.incremental` for the
    soundness argument), the fixed point reached is identical to a full
    sweep, but quiescent regions are never touched. Requires
    ``frontier=True`` (the dirty-bit propagation is what re-activates
    neighbors of changed seeds).

    ``op="fused"`` dispatches the fused Pallas sweep kernel; ``int16``
    (fused only) opts into the halved-width estimate vector behind the
    overflow guard, and ``fused_compaction_min_tiles`` sets the tile count
    at which the per-bucket ``lax.cond`` chain is replaced by the dense
    active-row-index compaction (see module docstring). Snapshot traffic
    (``init_coreness`` in, ``on_sweep`` views and ``coreness`` out) is
    int32 regardless, so every resume/checkpoint consumer is dtype-blind.
    """
    n = bg.n_nodes
    t0 = time.perf_counter()
    est_dtype = jnp.int32
    if int16:
        if op != "fused":
            raise ValueError("int16=True requires op='fused' (the fused "
                             "kernel widens in-register; the unfused "
                             "engines assume int32 state)")
        max_start = int(
            (bg.degrees.astype(np.int64) + np.asarray(bg.ext, np.int64))
            .max(initial=0)
        )
        # Overflow guard: estimates start at deg + ext and only decrease,
        # so int16 is exact iff every start fits. Fall back, never wrap.
        if max_start < (1 << 15):
            est_dtype = jnp.int16
    ext = jnp.asarray(bg.ext, dtype=jnp.int32)
    ext_pad = jnp.concatenate([ext, jnp.zeros((1,), jnp.int32)])
    if init_coreness is not None:
        start = np.asarray(init_coreness)
        if bg.perm is not None:
            start = start[bg.perm]  # original-id order -> layout order
        start = jnp.asarray(start, est_dtype)
    else:
        start = (jnp.asarray(bg.degrees, jnp.int32) + ext).astype(est_dtype)
    c = jnp.concatenate([start, jnp.full((1,), -1, est_dtype)])
    # Candidate-window bound (exact; see hindex_of_sequence docstring).
    cand = max(1, hindex_of_sequence(bg.degrees.astype(np.int64) + bg.ext))

    fused_mode = ""
    groups = None
    if op == "fused":
        fused_mode = (
            "compaction" if len(bg.buckets) >= fused_compaction_min_tiles
            else "cond"
        )
    if fused_mode == "compaction":
        groups = _FusedGroups(bg)
        buckets = []
        tiles_bytes = groups.memory_bytes
    else:
        buckets = _device_buckets(bg)
        tiles_bytes = bg.memory_bytes()

    wire = 2 if est_dtype == jnp.int16 else 4
    state_bytes = int(c.size * wire + ext_pad.size * 4)
    peak = tiles_bytes + state_bytes

    n_buckets = len(bg.buckets)
    bucket_rows = np.array([b.n_rows for b in bg.buckets], dtype=np.int64)
    bucket_widths = list(bg.widths)
    adj = bg.bucket_adjacency()
    active = np.ones(n_buckets, dtype=bool)
    if seed_nodes is not None:
        if not frontier:
            raise ValueError("seed_nodes requires frontier=True (seed "
                             "restriction relies on dirty-bit scheduling "
                             "to re-activate neighbors)")
        seeds = np.asarray(seed_nodes)
        if seeds.dtype == bool:
            if seeds.shape != (n,):
                raise ValueError(f"seed mask shape {seeds.shape} != ({n},)")
            seeds = np.nonzero(seeds)[0]
        if bg.inv_perm is not None:
            # Seeds arrive as original ids; the owner map is in layout
            # order, and original id o sits at layout row inv_perm[o].
            seeds = np.asarray(bg.inv_perm)[seeds]
        owner = bg.node_bucket_map()[:-1][seeds]
        active = np.zeros(n_buckets, dtype=bool)
        active[owner[owner >= 0]] = True  # -1: deg-0 rows own no bucket

    limit = max_iter if max_iter is not None else max(4, n)
    # Hoisted once: re-uploading the O(n) permutation every sweep would put
    # an H2D transfer in the hot loop just to build the on_sweep view.
    inv_perm_dev = (
        jnp.asarray(bg.inv_perm)
        if on_sweep is not None and bg.inv_perm is not None else None
    )
    comm_per_iter: List[int] = []
    active_rows_per_iter: List[int] = []
    sweep_bytes_per_iter: List[int] = []
    sweep_flops_per_iter: List[int] = []
    total = 0
    it = 0
    while it < limit:
        active_rows_per_iter.append(int(bucket_rows[active].sum()))
        # Modeled HBM traffic / FLOPs of this sweep's live shape (fig17's
        # achieved-vs-roofline input; int16 halves the wire terms).
        mb, mf = sweep_cost(
            [(int(bucket_rows[bi]), bucket_widths[bi])
             for bi in np.nonzero(active)[0]],
            cand, wire_bytes=wire, fused=(op == "fused"),
            track_dirty=frontier,
        )
        sweep_bytes_per_iter.append(mb)
        sweep_flops_per_iter.append(mf)
        if fused_mode == "compaction":
            c, changed_vec, dirty_next = _compaction_sweep(
                groups, c, ext_pad, active, cand,
                frozen_reads=not gauss_seidel, track_dirty=frontier,
            )
        elif fused_mode == "cond":
            c, changed_vec, dirty_next = _sweep_fused(
                c, ext_pad, buckets, jnp.asarray(active),
                cand=cand, frozen_reads=not gauss_seidel,
                track_dirty=frontier,
            )
        else:
            c, changed_vec, dirty_next = _sweep(
                c, ext_pad, buckets, jnp.asarray(active),
                op=op, cand=cand, frozen_reads=not gauss_seidel,
                track_dirty=frontier,
            )
        changed_vec = np.asarray(changed_vec)
        changed = int(changed_vec.sum())
        comm_per_iter.append(changed)
        total += changed
        it += 1
        if on_sweep is not None:
            # Contract (shared with the distributed engine): int32 values
            # in original-id order. The view stays a lazy device array —
            # no host sync is forced here — so a hook that samples every
            # k-th sweep (the sweep-granularity checkpoints of
            # repro.core.dckcore) pays np.asarray only when it keeps one.
            view = c[:-1]
            if view.dtype != jnp.int32:
                view = view.astype(jnp.int32)  # int16 mode: contract is int32
            if inv_perm_dev is not None:
                view = view[inv_perm_dev]  # -> original-id order
            on_sweep(it, view)
        if changed == 0:
            break
        if frontier:
            # Next frontier: buckets with a dirty row (a neighbor changed),
            # intersected with the static bucket-adjacency certificate —
            # dirty bits refine the bitmap, never widen it.
            reach = adj[changed_vec > 0].any(axis=0)
            active = np.asarray(dirty_next) & reach
    coreness = np.asarray(c[:-1]).astype(np.int32, copy=False)
    if bg.inv_perm is not None:
        coreness = coreness[bg.inv_perm]  # layout order -> original-id order
    return DecomposeResult(
        coreness=coreness,
        iterations=it,
        comm_amount=total,
        comm_per_iter=comm_per_iter,
        peak_bytes=int(peak),
        wall_time_s=time.perf_counter() - t0,
        active_rows_per_iter=active_rows_per_iter,
        rows_per_full_sweep=bg.rows_per_full_sweep,
        sweep_bytes_per_iter=sweep_bytes_per_iter,
        sweep_flops_per_iter=sweep_flops_per_iter,
        est_dtype="int16" if est_dtype == jnp.int16 else "int32",
        fused_mode=fused_mode,
    )
