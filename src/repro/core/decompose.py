"""Single-device k-core decomposition engine (jit).

This is the conquer step's compute engine: the h-index fixed point of paper
Algorithms 1/2 over a :class:`~repro.graph.structs.BucketedGraph` part.
Estimates start at ``deg + ext`` and monotonically decrease to the exact
coreness (paper Corollary 2 / Montresor et al.).

The state vector ``c`` has ``n + 1`` entries: slot ``n`` is the ``-1``
sentinel that padded neighbor slots gather from, so padding never needs a
mask in the inner loop. Per iteration, per degree-bucket:

    gathered = c[bucket.neigh]                  # [nb, width]
    new      = hindex(gathered, ext[bucket])    # Algorithm 2
    c        = c.at[bucket.node_ids].set(new)   # pad rows hit slot n

Three interchangeable h-index operators (``op=``):
  * ``"sorted"`` — descending sort + prefix scan (paper's literal loop).
  * ``"count"``  — sort-free suffix counts (pure jnp).
  * ``"kernel"`` — the Pallas TPU kernel (interpret mode on CPU), with the
    degeneracy-bounded candidate window.

**Active-frontier sweep scheduling** (Montresor et al.: after the first few
rounds only a small frontier still changes): each sweep returns a per-bucket
changed-count vector plus a per-bucket dirty flag, and the next sweep skips
— behind ``lax.cond``, so the gather and h-index are not executed — every
bucket that is quiescent. Two sound filters compose:

  1. the static ``bucket_adj`` bitmap (recorded once at bucketize time):
     a bucket none of whose adjacent buckets changed cannot change;
  2. per-node dirty bits pushed on device from changed rows of active
     buckets along their adjacency: a bucket none of whose OWN rows has a
     changed neighbor cannot change. This is the row-exact refinement that
     makes skipping effective on power-law graphs, where degree-class
     adjacency is dense.

A node's estimate is a function of its neighbors' estimates only, so both
filters are sound, not heuristic, and the fixed point is bit-identical to
the full-sweep schedule. ``frontier=False`` restores always-full sweeps
(the baseline the benchmarks compare against). Frontier granularity is the
bucket *tile* — bucketize splits degree classes into bounded row-tiles.

The *communication amount* (paper Section 5.4 metric: number of updated
estimates communicated per iteration) is counted on every step; it is the
quantity Figures 8 and 10 plot and what the divide step reduces. The
frontier adds the matching *work* metric: gathered rows per sweep.
"""
from __future__ import annotations

import dataclasses
import time
from functools import partial
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.hindex import hindex_count, hindex_of_sequence, hindex_sorted
from repro.graph.structs import BucketedGraph


@dataclasses.dataclass
class DecomposeResult:
    """Outcome of one part decomposition.

    ``coreness`` is always reported in **original**-id order: engines
    running on a reordered layout (``BucketedGraph.perm`` set) gather
    ``coreness[inv_perm]`` before returning, so reordering never leaks.
    """

    coreness: np.ndarray  # [n_nodes] int32
    iterations: int
    comm_amount: int  # total changed estimates across iterations
    comm_per_iter: List[int]
    peak_bytes: int  # device bytes of graph tiles + state
    wall_time_s: float
    # Work metric (frontier scheduling): bucket rows gathered+h-indexed per
    # sweep, and what one always-full sweep would have gathered.
    active_rows_per_iter: List[int] = dataclasses.field(default_factory=list)
    rows_per_full_sweep: int = 0
    # Measured collective traffic (distributed engine): per-device ICI bytes
    # the sweep's collectives actually moved each iteration, from the live
    # frontier mask and the padded device-array shapes — including the
    # frontier's own dirty-bit psum, which the analytic
    # ``sweep_collective_bytes`` model omits. Empty for single-device runs
    # (they issue no collectives).
    collective_bytes_per_iter: List[int] = dataclasses.field(default_factory=list)

    @property
    def gathered_rows(self) -> int:
        """Total rows gathered across all sweeps (the work-done counter)."""
        return int(sum(self.active_rows_per_iter))

    @property
    def full_sweep_rows(self) -> int:
        """Rows the always-full-sweep schedule would have gathered."""
        return int(self.rows_per_full_sweep * self.iterations)

    @property
    def collective_bytes(self) -> int:
        """Total measured per-device collective bytes across all sweeps."""
        return int(sum(self.collective_bytes_per_iter))


def _device_buckets(bg: BucketedGraph):
    return [
        (jnp.asarray(b.node_ids), jnp.asarray(b.neigh), jnp.asarray(b.deg))
        for b in bg.buckets
    ]


def _apply_op(gathered, ext_rows, cur_rows, op: str, cand: int):
    if op == "sorted":
        return hindex_sorted(gathered, ext_rows)
    if op == "count":
        return hindex_count(gathered, ext_rows, cand_chunk=min(256, cand))
    if op == "kernel":
        from repro.kernels.hindex import hindex_op

        return hindex_op(gathered, ext_rows, cur_rows, cand=cand)
    raise ValueError(f"unknown op {op!r}")


@partial(jax.jit, static_argnames=("op", "cand", "frozen_reads", "track_dirty"))
def _sweep(c, ext_pad, buckets, active, op: str = "sorted", cand: int = 1 << 30,
           frozen_reads: bool = False, track_dirty: bool = True):
    """One sweep over the active buckets.

    Returns ``(new_c, changed [n_buckets], dirty_next [n_buckets])``:
    ``changed[i]`` counts rows of bucket ``i`` whose estimate changed (the
    paper's communication amount, per bucket); ``dirty_next[j]`` is True iff
    some row of bucket ``j`` has a neighbor that changed this sweep —
    changed rows *push* a per-node dirty bit along their adjacency, and each
    bucket then reads back only its own rows' bits. A node's estimate is a
    function of its neighbors' estimates, so ``dirty_next`` is exactly the
    set of buckets that could change next sweep.

    ``active`` is the [n_buckets] bool frontier mask; inactive buckets skip
    gather + h-index at runtime (``lax.cond``) and report 0 changed rows.
    ``track_dirty=False`` (the always-full-sweep baseline) compiles the
    dirty-bit push and read-back out entirely and returns an all-False
    ``dirty_next``.

    ``frozen_reads=False`` is Gauss-Seidel: later buckets read estimates
    already updated this sweep (within-sweep propagation, like the paper's
    in-place parameter-server updates) — strictly fewer iterations.
    ``True`` gives textbook Jacobi (what a pull-based PS round does).
    """
    sentinel = c.shape[0] - 1
    frozen = c
    new_c = c
    dirty = jnp.zeros((c.shape[0],), jnp.int8)  # per-node "a neighbor changed"
    changed_parts = []
    for bi, (node_ids, neigh, _deg) in enumerate(buckets):

        def update(nc, dt, node_ids=node_ids, neigh=neigh):
            src = frozen if frozen_reads else nc
            gathered = src[neigh]  # sentinel slot -> -1
            ext_rows = ext_pad[node_ids]
            cur_rows = src[node_ids]
            est = _apply_op(gathered, ext_rows, cur_rows, op, cand)
            # Pad rows (node_ids == sentinel) scatter into slot n, which is
            # re-pinned below, and never count as changed.
            row_changed = (est != cur_rows) & (node_ids != sentinel)
            ch = jnp.sum(row_changed).astype(jnp.int32)
            if track_dirty:
                # Push dirty bits to every neighbor of a changed row. Work
                # is proportional to the ACTIVE tile sizes, not the graph.
                dt = dt.at[neigh].max(
                    jnp.broadcast_to(row_changed[:, None], neigh.shape).astype(jnp.int8)
                )
            nc = nc.at[node_ids].set(est)
            nc = nc.at[-1].set(-1)  # re-pin sentinel
            return nc, dt, ch

        new_c, dirty, ch = jax.lax.cond(
            active[bi], update, lambda nc, dt: (nc, dt, jnp.int32(0)), new_c, dirty
        )
        changed_parts.append(ch)
    changed = (
        jnp.stack(changed_parts) if changed_parts else jnp.zeros((0,), jnp.int32)
    )
    if track_dirty and buckets:
        # Each bucket reads back its own rows' dirty bits ([rows] gathers).
        dirty_next = jnp.stack(
            [
                jnp.any((dirty[node_ids] > 0) & (node_ids != sentinel))
                for node_ids, _neigh, _deg in buckets
            ]
        )
    else:
        dirty_next = jnp.zeros((len(buckets),), bool)
    return new_c, changed, dirty_next


def decompose(
    bg: BucketedGraph,
    *,
    op: str = "sorted",
    max_iter: Optional[int] = None,
    gauss_seidel: bool = True,
    frontier: bool = True,
    init_coreness: Optional[np.ndarray] = None,
    on_sweep=None,
) -> DecomposeResult:
    """Run the h-index fixed point on one part until no estimate changes.

    ``frontier`` enables active-frontier sweep scheduling (sound bucket
    skipping via the bucket-adjacency bitmap); ``False`` re-sweeps every
    bucket every iteration. ``init_coreness`` resumes from a snapshot
    (fixed-point iterations are restartable from ANY valid upper bound of
    the true coreness — the fault-tolerance hook for the paper's 27.5h-scale
    runs); ``on_sweep(iteration, coreness)`` is the snapshot callback,
    called after every sweep with an int32 original-id-order array view
    (lazy device array — ``np.asarray`` it to materialize; no host sync is
    forced on sweeps whose snapshot the hook discards) —
    :func:`repro.core.dckcore.dc_kcore` feeds its sweep-granularity
    checkpoints from it.

    If ``bg`` was built from a reordered graph (``bg.perm`` set), the
    reordering is invisible here: ``init_coreness`` is taken in original-id
    order and permuted in, ``on_sweep`` views and the returned ``coreness``
    are permuted back — a snapshot taken under one ordering restarts
    correctly under any other.
    """
    n = bg.n_nodes
    t0 = time.time()
    ext = jnp.asarray(bg.ext, dtype=jnp.int32)
    ext_pad = jnp.concatenate([ext, jnp.zeros((1,), jnp.int32)])
    if init_coreness is not None:
        start = np.asarray(init_coreness)
        if bg.perm is not None:
            start = start[bg.perm]  # original-id order -> layout order
        start = jnp.asarray(start, jnp.int32)
    else:
        start = jnp.asarray(bg.degrees, jnp.int32) + ext
    c = jnp.concatenate([start, jnp.full((1,), -1, jnp.int32)])
    buckets = _device_buckets(bg)
    # Candidate-window bound (exact; see hindex_of_sequence docstring).
    cand = max(1, hindex_of_sequence(bg.degrees.astype(np.int64) + bg.ext))

    state_bytes = int(c.size * 4 + ext_pad.size * 4)
    peak = bg.memory_bytes() + state_bytes

    n_buckets = len(buckets)
    bucket_rows = np.array([b.n_rows for b in bg.buckets], dtype=np.int64)
    adj = bg.bucket_adjacency()
    active = np.ones(n_buckets, dtype=bool)

    limit = max_iter if max_iter is not None else max(4, n)
    # Hoisted once: re-uploading the O(n) permutation every sweep would put
    # an H2D transfer in the hot loop just to build the on_sweep view.
    inv_perm_dev = (
        jnp.asarray(bg.inv_perm)
        if on_sweep is not None and bg.inv_perm is not None else None
    )
    comm_per_iter: List[int] = []
    active_rows_per_iter: List[int] = []
    total = 0
    it = 0
    while it < limit:
        active_rows_per_iter.append(int(bucket_rows[active].sum()))
        c, changed_vec, dirty_next = _sweep(
            c, ext_pad, buckets, jnp.asarray(active),
            op=op, cand=cand, frozen_reads=not gauss_seidel,
            track_dirty=frontier,
        )
        changed_vec = np.asarray(changed_vec)
        changed = int(changed_vec.sum())
        comm_per_iter.append(changed)
        total += changed
        it += 1
        if on_sweep is not None:
            # Contract (shared with the distributed engine): int32 values
            # in original-id order. The view stays a lazy device array —
            # no host sync is forced here — so a hook that samples every
            # k-th sweep (the sweep-granularity checkpoints of
            # repro.core.dckcore) pays np.asarray only when it keeps one.
            view = c[:-1]
            if inv_perm_dev is not None:
                view = view[inv_perm_dev]  # -> original-id order
            on_sweep(it, view)
        if changed == 0:
            break
        if frontier:
            # Next frontier: buckets with a dirty row (a neighbor changed),
            # intersected with the static bucket-adjacency certificate —
            # dirty bits refine the bitmap, never widen it.
            reach = adj[changed_vec > 0].any(axis=0)
            active = np.asarray(dirty_next) & reach
    coreness = np.asarray(c[:-1])
    if bg.inv_perm is not None:
        coreness = coreness[bg.inv_perm]  # layout order -> original-id order
    return DecomposeResult(
        coreness=coreness,
        iterations=it,
        comm_amount=total,
        comm_per_iter=comm_per_iter,
        peak_bytes=int(peak),
        wall_time_s=time.time() - t0,
        active_rows_per_iter=active_rows_per_iter,
        rows_per_full_sweep=bg.rows_per_full_sweep,
    )
