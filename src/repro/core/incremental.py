"""Incremental coreness maintenance under edge churn.

``apply_updates(graph, coreness, edits)`` applies one batch of edge
inserts/deletes and returns the new graph plus its EXACT coreness, bit-
identical to a from-scratch :func:`~repro.core.decompose.decompose` on the
post-edit graph — but touching only a bounded *dirty region* around the
edits, per the h-index locality result of Montresor et al.

Soundness design (the invariants the differential suite pins):

**Estimate seed.** The h-index fixed point converges to the true coreness
from ANY per-node upper bound ``est`` with ``core_new <= est <= deg_new +
ext``. With ``b_ins`` effective undirected inserts, no coreness rises by
more than ``b_ins``; deletes never raise coreness. So

    ``est = min(old_core + b_ins·[rise-region], deg_new)``

is a valid upper bound (``min`` with the new degree also covers brand-new
nodes and rows that lost edges).

**Dirty region (initial frontier).** Restricting the first sweep to a seed
set ``D`` is exact iff every node whose estimate must MOVE during the
iteration either lies in ``D`` or is reached by the dirty-bit frontier
from a node that changed. Two hazards force explicit BFS regions:

- *Rise region* (inserts): coreness can only rise along a path from an
  insert endpoint where each hop's old coreness stays within ``b_ins - 1``
  of the previous hop's (with ``b_ins = 1`` this is the classic equal-
  coreness subcore). Nodes outside cannot rise, by a cause-chain argument:
  the first riser outside the band would need a neighbor risen further.
- *Fall region* (deletes): a node's estimate can start AT its final value
  yet its neighbors still need re-evaluation (delete one edge of a
  triangle: both endpoints drop to est=1 at seed time — no sweep-time
  change event — while the third corner must fall from 2 to 1 "on its
  own"). So every node that might fall must be in ``D`` itself: BFS from
  delete endpoints, expanding x→y iff ``old(y) ∈ [old(x) - b_del + 1,
  old(x)]``.

Any node not in either region keeps ``est = old_core`` exactly and is
provably already at its fixed point; the terminal-state argument (no
change ⇒ every swept row satisfies ``c = H(c)``, plus the regions cover
all movers) gives bit-identity.

**Fallback.** When the dirty region exceeds ``dirty_budget_frac`` of the
graph the locality win is gone — ``apply_updates`` falls back to a full
from-scratch decompose (same bit-exact result, mode ``"full"`` in the
report). Esfandiari-style sketching is the lossy alternative; this engine
keeps the exactness contract and pays the full sweep instead.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Optional

import numpy as np

from repro.core.decompose import DecomposeResult, decompose
from repro.graph.build import bucketize
from repro.graph.delta import DeltaResult, EdgeEdits, apply_edge_deltas
from repro.graph.structs import Graph


@dataclasses.dataclass(frozen=True)
class UpdateResult:
    """Outcome of one incremental update batch.

    ``mode`` is ``"incremental"`` (seed-restricted re-sweep), ``"full"``
    (dirty region blew the budget — from-scratch decompose), or ``"noop"``
    (the batch had no effective edits). ``dirty_mask`` is the original-id
    boolean seed region (all-True under ``"full"``, for uniformity);
    ``gathered_rows`` is the total row count actually swept — the number
    the dirty-region-bound tests compare against a full run's.
    """

    graph: Graph
    coreness: np.ndarray
    mode: str
    delta: DeltaResult
    dirty_mask: np.ndarray
    dirty_count: int
    dirty_frac: float
    gathered_rows: int
    decompose_result: Optional[DecomposeResult]
    wall_time_s: float

    @property
    def n_inserted(self) -> int:
        return self.delta.n_inserted

    @property
    def n_deleted(self) -> int:
        return self.delta.n_deleted


def _band_flood(
    g: Graph,
    seed_mask: np.ndarray,
    old: np.ndarray,
    lo_off: int,
    hi_off: int,
) -> np.ndarray:
    """Band-constrained BFS over ``g``: grow ``seed_mask`` by repeatedly
    adding any neighbor ``y`` of a frontier node ``x`` with
    ``old[y] ∈ [old[x] + lo_off, old[x] + hi_off]``. Returns the closure
    as a boolean mask (seeds included). Vectorized frontier flood: each
    round gathers the frontier rows' CSR slices in one shot.
    """
    region = seed_mask.copy()
    frontier = np.nonzero(seed_mask)[0]
    indptr, indices = g.indptr, g.indices
    while frontier.size:
        counts = (indptr[frontier + 1] - indptr[frontier]).astype(np.int64)
        keep = counts > 0
        rows, counts = frontier[keep], counts[keep]
        if rows.size == 0:
            break
        # Concatenated slot indices of the frontier rows (cumsum trick).
        total = int(counts.sum())
        step = np.ones(total, dtype=np.int64)
        starts = indptr[rows].astype(np.int64)
        ends = np.cumsum(counts)
        step[0] = starts[0]
        step[ends[:-1]] = starts[1:] - (starts[:-1] + counts[:-1] - 1)
        slots = np.cumsum(step)
        neigh = indices[slots].astype(np.int64)
        src_old = np.repeat(old[rows], counts)
        ok = (
            (old[neigh] >= src_old + lo_off)
            & (old[neigh] <= src_old + hi_off)
            & ~region[neigh]
        )
        nxt = np.unique(neigh[ok])
        region[nxt] = True
        frontier = nxt
    return region


def apply_updates(
    g: Graph,
    coreness: np.ndarray,
    edits: EdgeEdits,
    *,
    dirty_budget_frac: float = 0.5,
    op: str = "count",
    max_bucket_rows="auto",
    n_nodes: Optional[int] = None,
) -> UpdateResult:
    """Apply one edit batch and maintain exact coreness.

    ``coreness`` must be the exact coreness of ``g`` (original-id order) —
    the previous batch's output, or a from-scratch decompose / oracle run.
    ``dirty_budget_frac`` caps the seed region; past it the engine falls
    back to a full re-sweep (set to ``0.0`` to force the fallback, ``1.0``
    to never take it). ``op``/``max_bucket_rows`` pass through to the
    engine, so the incremental path exercises the same sweep kernels as
    batch runs.
    """
    t0 = time.perf_counter()
    old = np.asarray(coreness, dtype=np.int64)
    if old.shape != (g.n_nodes,):
        raise ValueError(
            f"coreness shape {old.shape} != ({g.n_nodes},)"
        )
    delta = apply_edge_deltas(g, edits, n_nodes=n_nodes)
    g_new = delta.graph
    n_new = g_new.n_nodes
    if n_new > old.size:  # new trailing nodes enter with old coreness 0
        old = np.concatenate(
            [old, np.zeros(n_new - old.size, dtype=np.int64)]
        )

    if delta.n_effective == 0:
        return UpdateResult(
            graph=g_new, coreness=old.astype(np.int32, copy=False),
            mode="noop", delta=delta,
            dirty_mask=np.zeros(n_new, dtype=bool), dirty_count=0,
            dirty_frac=0.0, gathered_rows=0, decompose_result=None,
            wall_time_s=time.perf_counter() - t0,
        )

    b_ins, b_del = delta.n_inserted, delta.n_deleted
    single = delta.n_effective == 1
    rise = np.zeros(n_new, dtype=bool)
    if b_ins:
        if single:
            # Classic single-insert theorem: only nodes with old core ==
            # K = min(old(u), old(v)) in the K-subcore of the root can
            # rise (by exactly 1). The higher endpoint cannot move.
            k = min(old[delta.ins_u[0]], old[delta.ins_v[0]])
            for e in (delta.ins_u[0], delta.ins_v[0]):
                if old[e] == k:
                    rise[e] = True
        else:
            rise[delta.ins_u] = True
            rise[delta.ins_v] = True
        # Coreness rises only along paths where each hop's old value is
        # within [old(x), old(x) + b_ins - 1] of the previous hop's.
        rise = _band_flood(g_new, rise, old, 0, b_ins - 1)
    fall = np.zeros(n_new, dtype=bool)
    if b_del:
        seeds = np.zeros(n_new, dtype=bool)
        if single:
            # Dual single-delete theorem: only the K-subcore of the
            # endpoints can fall. Both endpoints of the deleted edge are
            # seeded, so old-graph subcore paths crossing it stay covered.
            k = min(old[delta.del_u[0]], old[delta.del_v[0]])
            for e in (delta.del_u[0], delta.del_v[0]):
                if old[e] == k:
                    seeds[e] = True
        else:
            seeds[delta.del_u] = True
            seeds[delta.del_v] = True
        # Fallers may never emit a change event (triangle case: both
        # delete endpoints seed at their final value), so the whole
        # potential-fall closure must be in the initial frontier.
        fall = _band_flood(g_new, seeds, old, -(b_del - 1), 0)
    dirty = rise | fall
    dirty_count = int(dirty.sum())
    dirty_frac = dirty_count / max(1, n_new)

    deg_new = g_new.degrees.astype(np.int64)
    if dirty_frac > dirty_budget_frac:
        # Locality win is gone — full from-scratch sweep (same bits).
        bg = bucketize(g_new, max_bucket_rows=max_bucket_rows)
        res = decompose(bg, op=op)
        return UpdateResult(
            graph=g_new, coreness=res.coreness, mode="full", delta=delta,
            dirty_mask=np.ones(n_new, dtype=bool), dirty_count=dirty_count,
            dirty_frac=dirty_frac,
            gathered_rows=int(sum(res.active_rows_per_iter)),
            decompose_result=res, wall_time_s=time.perf_counter() - t0,
        )

    est = np.minimum(np.where(rise, old + b_ins, old), deg_new)
    bg = bucketize(g_new, max_bucket_rows=max_bucket_rows)
    res = decompose(
        bg, op=op,
        init_coreness=est.astype(np.int32),
        seed_nodes=dirty,
    )
    return UpdateResult(
        graph=g_new, coreness=res.coreness, mode="incremental", delta=delta,
        dirty_mask=dirty, dirty_count=dirty_count, dirty_frac=dirty_frac,
        gathered_rows=int(sum(res.active_rows_per_iter)),
        decompose_result=res, wall_time_s=time.perf_counter() - t0,
    )
