"""Double-buffered coreness snapshot publication + batched query serving.

The serving shape is alloc/swap: the update thread builds the next
:class:`CorenessSnapshot` COMPLETELY off to the side (fresh arrays, marked
read-only), then publishes it with a single reference assignment — the one
atomic pointer flip readers ever observe. Query threads grab
``self._front`` once per query and work off that object; they either see
the old snapshot or the new one in full, never a mix. No locks sit on the
query path; the publish lock only serializes writers.

Torn-state detection is built into the snapshot: ``checksum`` is derived
from the coreness payload at build time, and :meth:`CorenessSnapshot.
verify` recomputes it — the serve test hammers queries during swaps and
asserts every observed snapshot self-verifies and carries monotonically
non-decreasing versions.

Metrics (:meth:`SnapshotPublisher.metrics`): publishes/sec and edits/sec
over the process lifetime, query p50/p99 latency over a bounded window,
and staleness — how many edits were pending (drained from the log but not
yet published, plus sealed-but-undrained if the caller reports them) at
the moment each query ran.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from collections import deque
from typing import Optional

import numpy as np

from repro.graph.structs import Graph


def _payload_checksum(coreness: np.ndarray, version: int) -> int:
    """Cheap order-sensitive digest of the published payload."""
    c = coreness.astype(np.uint64, copy=False)
    idx = np.arange(1, c.size + 1, dtype=np.uint64)
    salt = np.uint64((version * 0x9E3779B97F4A7C15) & 0xFFFFFFFFFFFFFFFF)
    return int((c * idx).sum(dtype=np.uint64) ^ salt)


@dataclasses.dataclass(frozen=True)
class CorenessSnapshot:
    """One immutable published state: graph + exact coreness + provenance."""

    graph: Graph
    coreness: np.ndarray  # int32, original-id order, read-only
    version: int
    checksum: int
    published_at: float  # perf_counter stamp, for staleness-age metrics

    @property
    def n_nodes(self) -> int:
        return int(self.coreness.size)

    @property
    def max_core(self) -> int:
        return int(self.coreness.max(initial=0))

    def verify(self) -> bool:
        """Recompute the payload digest — False means a torn/corrupt read."""
        return _payload_checksum(self.coreness, self.version) == self.checksum


class SnapshotPublisher:
    """Single-writer / many-reader coreness snapshot exchange."""

    def __init__(self, latency_window: int = 4096):
        self._front: Optional[CorenessSnapshot] = None
        self._publish_lock = threading.Lock()
        self._version = 0
        self._t_start = time.perf_counter()
        self._n_publishes = 0
        self._n_edits_published = 0
        self._pending_lock = threading.Lock()
        self._pending_edits = 0
        self._query_lat_s: deque = deque(maxlen=latency_window)
        self._query_staleness: deque = deque(maxlen=latency_window)
        self._n_queries = 0

    # -- writer side -----------------------------------------------------

    def publish(
        self, graph: Graph, coreness: np.ndarray, n_edits: int = 0
    ) -> CorenessSnapshot:
        """Build and flip in a new snapshot; returns it.

        ``coreness`` is copied into a fresh read-only buffer first (the
        alloc of alloc/swap — the caller may keep mutating its array), the
        snapshot is assembled completely, and only then does the single
        reference assignment make it visible.
        """
        with self._publish_lock:
            self._version += 1
            version = self._version
            payload = np.array(coreness, dtype=np.int32, copy=True)
            payload.setflags(write=False)
            snap = CorenessSnapshot(
                graph=graph,
                coreness=payload,
                version=version,
                checksum=_payload_checksum(payload, version),
                published_at=time.perf_counter(),
            )
            self._front = snap  # the atomic pointer flip
            self._n_publishes += 1
            self._n_edits_published += int(n_edits)
            if n_edits:
                with self._pending_lock:
                    self._pending_edits = max(0, self._pending_edits - int(n_edits))
        return snap

    def note_pending(self, n_edits: int) -> None:
        """Report edits seen in the log but not yet folded into a publish."""
        with self._pending_lock:
            self._pending_edits += int(n_edits)

    # -- reader side -----------------------------------------------------

    @property
    def snapshot(self) -> Optional[CorenessSnapshot]:
        """The current front snapshot (None before the first publish)."""
        return self._front

    def _serve(self, fn):
        snap = self._front
        if snap is None:
            raise RuntimeError("no snapshot published yet")
        t0 = time.perf_counter()
        out = fn(snap)
        self._query_lat_s.append(time.perf_counter() - t0)
        self._query_staleness.append(self._pending_edits)
        self._n_queries += 1
        return out

    def query_coreness(self, node_ids) -> np.ndarray:
        """Batched coreness lookup; out-of-range ids answer 0 (unknown)."""
        def run(snap):
            ids = np.asarray(node_ids, dtype=np.int64)
            out = np.zeros(ids.shape, dtype=np.int32)
            ok = (ids >= 0) & (ids < snap.n_nodes)
            out[ok] = snap.coreness[ids[ok]]
            return out
        return self._serve(run)

    def query_kcore_members(self, k: int) -> np.ndarray:
        """Node ids of the k-core (coreness >= k), ascending."""
        return self._serve(
            lambda snap: np.nonzero(snap.coreness >= int(k))[0].astype(np.int64)
        )

    def query_top_kcore(self) -> tuple[int, np.ndarray]:
        """(k_max, member ids of the innermost non-empty core)."""
        def run(snap):
            k = snap.max_core
            return k, np.nonzero(snap.coreness >= k)[0].astype(np.int64)
        return self._serve(run)

    def query_in_kcore(self, node_ids, k: int) -> np.ndarray:
        """Batched k-core membership test."""
        def run(snap):
            ids = np.asarray(node_ids, dtype=np.int64)
            out = np.zeros(ids.shape, dtype=bool)
            ok = (ids >= 0) & (ids < snap.n_nodes)
            out[ok] = snap.coreness[ids[ok]] >= int(k)
            return out
        return self._serve(run)

    # -- metrics ---------------------------------------------------------

    def metrics(self) -> dict:
        lat = np.asarray(self._query_lat_s, dtype=np.float64)
        stale = np.asarray(self._query_staleness, dtype=np.float64)
        dt = max(1e-9, time.perf_counter() - self._t_start)
        return {
            "n_publishes": self._n_publishes,
            "n_edits_published": self._n_edits_published,
            "updates_per_s": self._n_edits_published / dt,
            "publishes_per_s": self._n_publishes / dt,
            "n_queries": self._n_queries,
            "pending_edits": self._pending_edits,
            "query_p50_ms": float(np.percentile(lat, 50) * 1e3) if lat.size else 0.0,
            "query_p99_ms": float(np.percentile(lat, 99) * 1e3) if lat.size else 0.0,
            "staleness_mean_edits": float(stale.mean()) if stale.size else 0.0,
            "staleness_max_edits": float(stale.max()) if stale.size else 0.0,
            "version": self._version,
        }
