"""Partition-level scheduler: conquer many planned parts concurrently.

The sequential DC-kCore loop (``repro.core.dckcore``) conquers one part at
a time, so a mesh bigger than one part's sweep can saturate sits idle —
the opposite of the paper's 136B-edge story, where *many* parts are in
flight across the cluster at once. This module closes that gap in three
layers, kept separate so the planning layer is pure numpy/ints and can be
property-tested without a single device:

* **Slices** — the global device mesh is split into ``n_slices``
  equal submeshes along its first node axis (:func:`slice_mesh_plans`).
  Each slice is a full :class:`~repro.core.distributed.MeshPlan` of its
  own, so the existing shard_map engine runs on it unchanged. The pure
  description of a slice is a :class:`SliceSpec` (shard counts + optional
  per-device capacity), which duck-types the ``plan`` argument of
  :func:`~repro.core.distributed.planned_collective_schedule` — the
  scheduler's cost model and the dry-run's feasibility tables are the
  same formula by construction.

* **Cost model + assignment** — a part's modeled conquer cost
  (:func:`part_cost`) prices the planned frontier schedule over the
  part's bucket shapes on a given slice: the collective term is exactly
  ``sum(planned_collective_schedule(...))`` (the model PR 7 pinned
  byte-for-byte against a measured ``frontier=False`` run), and the HBM
  term prices each planned live set with
  :func:`repro.roofline.kcore_model.sweep_cost` so single-device slices
  (which issue no collectives) still get a nonzero, size-ordered cost.
  :func:`assign_parts` places parts on slices with the classic
  longest-processing-time greedy: parts descending by modeled cost, each
  onto the least-loaded slice whose capacity admits the part's modeled
  per-device resident bytes. Assignment is deterministic (ties break on
  cursor, then slice index) and total — a part that fits no slice raises
  :class:`SliceCapacityError` rather than silently over-packing.

* **Wave executor** — :func:`conquer_wave` runs one planned wave: one
  worker thread per slice (named ``dckcore-conquer-*`` for the test
  suite's leak gate), each conquering its assigned parts in plan-cursor
  order. Slices share no mutable state; by default a slice failure is
  re-raised in the caller after every slice has drained (the
  earliest-cursor failure wins, deterministically). Passing a
  :class:`WatchdogConfig` arms the fault-tolerance layer instead: failed
  parts retry on their slice with exponential backoff, per-slice
  heartbeats detect hangs, and a slice that exhausts its retries or
  hangs is blacklisted with its unfinished parts re-planned over the
  survivors through the same :func:`assign_parts` pass — parts are
  idempotent over immutable inputs, so the degraded wave stays
  byte-identical. Within a single process the "slices" are disjoint
  device subsets of one mesh; across processes each host runs the same
  schedule restricted to its own slice (see
  ``launch.mesh.init_multiprocess``).

How concurrency stays byte-identical to the sequential path: the wave
planner in ``dckcore`` extends the PR 5 speculation discipline from depth
1 to depth ``n_slices`` — part ``i+1`` is planned on the *predicted*
shrink of part ``i`` (every candidate finalizes: exact by construction
for Exact-Divide, a bet for Rough), and after the wave the predictions
are validated **in plan order**; the first miss discards every later
part's speculative result and the pipeline recomputes from there, exactly
as the sequential loop would. Merges, checkpoints and sweep snapshots
therefore happen in plan order with the same contents as the sequential
run — see ``dckcore`` for the merge/checkpoint ordering contract.
"""
from __future__ import annotations

import dataclasses
import inspect
import math
import threading
import time
from typing import Callable, Dict, List, Optional, Sequence

import numpy as np

from repro.core.distributed import (
    MeshPlan,
    make_distributed_decompose,
    planned_collective_schedule,
    planned_live_sets,
)
from repro.core.hindex import hindex_of_sequence
from repro.roofline.kcore_model import sweep_cost

# Wave-conquer worker threads carry this name prefix; the test suite
# asserts none outlive a test (a leaked thread = a missing drain).
CONQUER_THREAD_PREFIX = "dckcore-conquer"


class SliceCapacityError(ValueError):
    """A part's modeled resident bytes fit no slice's capacity.

    Raised by :func:`assign_parts` instead of over-packing a slice — the
    caller (or the user, via a bigger ``--budget-gb`` divide) must plan
    smaller parts; a silently overflowing assignment would just OOM later
    with a worse error.
    """


@dataclasses.dataclass(frozen=True)
class SliceSpec:
    """Pure description of one mesh slice — the planning-layer unit.

    Duck-compatible with the ``plan`` argument of
    :func:`~repro.core.distributed.planned_collective_schedule` /
    :func:`~repro.core.distributed.sweep_collective_bytes` (both only read
    ``n_node_shards`` / ``n_slot_shards``), so the scheduler prices parts
    with the exact formula the dry-run tables and the measured-counter
    pinning tests use. ``capacity_bytes`` is the per-device resident
    budget (``None`` = unbounded, the test default).
    """

    index: int
    n_node_shards: int
    n_slot_shards: int
    capacity_bytes: Optional[int] = None

    @property
    def n_devices(self) -> int:
        return self.n_node_shards * self.n_slot_shards


@dataclasses.dataclass(frozen=True)
class PartCost:
    """Modeled cost of conquering one planned part on a slice.

    ``collective_bytes`` is ``sum(planned_collective_schedule(...))`` over
    the part's bucket rows — zero on single-device slices. ``hbm_bytes``
    prices the same planned live sets' HBM traffic per device
    (:func:`~repro.roofline.kcore_model.sweep_cost` over the live bucket
    shapes, divided by the slice's device count), so cost stays nonzero
    and size-ordered even when no collective is ever issued.
    ``part_bytes`` is the modeled per-device *resident* footprint
    (sharded tiles + replicated coreness/ext/node-tile state) — the
    quantity checked against :attr:`SliceSpec.capacity_bytes`.
    """

    cursor: int
    collective_bytes: int
    hbm_bytes: int
    part_bytes: int

    @property
    def total(self) -> int:
        return self.collective_bytes + self.hbm_bytes


@dataclasses.dataclass(frozen=True)
class Assignment:
    cursor: int
    slice_index: int
    cost: PartCost


@dataclasses.dataclass(frozen=True)
class WaveSchedule:
    """One wave's part -> slice placement, in plan (cursor) order."""

    assignments: List[Assignment]
    n_slices: int

    def parts_for(self, slice_index: int) -> List[int]:
        """Cursors assigned to ``slice_index``, ascending (execution order)."""
        return sorted(
            a.cursor for a in self.assignments if a.slice_index == slice_index
        )

    def slice_loads(self) -> List[int]:
        """Total modeled cost per slice (the LPT objective)."""
        loads = [0] * self.n_slices
        for a in self.assignments:
            loads[a.slice_index] += a.cost.total
        return loads

    def decisions(self) -> List[dict]:
        """JSON-friendly schedule decisions (dry-run / report plumbing)."""
        return [
            {
                "cursor": a.cursor,
                "slice": a.slice_index,
                "modeled_collective_bytes": a.cost.collective_bytes,
                "modeled_hbm_bytes": a.cost.hbm_bytes,
                "modeled_part_bytes": a.cost.part_bytes,
            }
            for a in self.assignments
        ]


def cost_inputs_of(bg) -> tuple:
    """``(bucket_shapes, cand, n_nodes)`` of a bucketized part — what
    :func:`part_cost` needs, extracted once per plan."""
    shapes = [(int(b.n_rows), int(b.width)) for b in bg.buckets]
    cand = max(1, hindex_of_sequence(bg.degrees.astype(np.int64) + bg.ext))
    return shapes, cand, int(bg.n_nodes)


def part_cost(
    bucket_shapes: Sequence[Sequence[int]],
    cand: int,
    n_nodes: int,
    spec: SliceSpec,
    *,
    wire_bytes: int = 4,
    n_iters: int = 30,
    full_sweeps: int = 3,
    decay: float = 0.6,
    frontier: bool = True,
) -> PartCost:
    """Model one part's conquer cost on ``spec`` from its bucket shapes.

    The planned frontier schedule (``full_sweeps`` full iterations, then
    geometric decay concentrated in the densest classes — identical knobs
    and live sets to :func:`planned_collective_schedule`) prices both
    terms, so the collective term of a ``frontier=False`` cost is pinned
    byte-for-byte against a measured run by the same test that pins the
    dry-run tables.
    """
    rows = [int(r) for r, _w in bucket_shapes]
    ns = max(1, spec.n_node_shards)
    padded = [math.ceil(r / ns) * ns for r in rows]
    coll = sum(
        planned_collective_schedule(
            rows, spec, cand, wire_bytes=wire_bytes, n_iters=n_iters,
            full_sweeps=full_sweeps, decay=decay, frontier=frontier,
        )
    ) if spec.n_devices > 1 else 0
    hbm = 0
    for live in planned_live_sets(
        padded, n_iters=n_iters, full_sweeps=full_sweeps, decay=decay,
        frontier=frontier,
    ):
        b, _f = sweep_cost(
            [(padded[bi], bucket_shapes[bi][1]) for bi in live],
            cand, wire_bytes=wire_bytes, fused=False, track_dirty=frontier,
        )
        hbm += b // spec.n_devices
    # Per-device resident footprint: sharded tiles + replicated state
    # (coreness wire + int32 ext + int16 node->bucket map) — the same
    # memory model as the dry-run feasibility tables.
    tile_bytes = sum(pr * max(1, w) * 4 for pr, (_r, w) in zip(padded, bucket_shapes))
    part_bytes = tile_bytes // spec.n_devices + (n_nodes + 1) * (wire_bytes + 4 + 2)
    return PartCost(
        cursor=-1,
        collective_bytes=int(coll),
        hbm_bytes=int(hbm),
        part_bytes=int(part_bytes),
    )


def cost_for_plan(bg, cursor: int, spec: SliceSpec, **kw) -> PartCost:
    """:func:`part_cost` of a bucketized part, stamped with its cursor."""
    shapes, cand, n = cost_inputs_of(bg)
    c = part_cost(shapes, cand, n, spec, **kw)
    return dataclasses.replace(c, cursor=cursor)


def assign_parts(
    costs: Sequence[PartCost], slices: Sequence[SliceSpec]
) -> WaveSchedule:
    """Place parts on slices: longest-processing-time greedy.

    Parts are taken descending by modeled total cost (ties ascending by
    cursor — deterministic), each placed on the least-loaded slice whose
    ``capacity_bytes`` admits the part's modeled resident footprint (ties
    ascending by slice index). Handles every shape the wave planner can
    emit: no parts (empty schedule), one part, more parts than slices
    (slices queue, executing their parts in cursor order), more slices
    than parts (trailing slices idle).
    """
    if not slices:
        raise ValueError("assign_parts needs at least one slice")
    if len({s.index for s in slices}) != len(slices):
        raise ValueError("duplicate slice indices")
    order = sorted(costs, key=lambda c: (-c.total, c.cursor))
    loads: Dict[int, int] = {s.index: 0 for s in slices}
    out: List[Assignment] = []
    for c in order:
        fits = [
            s for s in slices
            if s.capacity_bytes is None or c.part_bytes <= s.capacity_bytes
        ]
        if not fits:
            raise SliceCapacityError(
                f"part cursor={c.cursor} needs {c.part_bytes} resident "
                f"bytes/device but no slice admits it (capacities: "
                f"{[s.capacity_bytes for s in slices]}) — plan smaller parts"
            )
        best = min(fits, key=lambda s: (loads[s.index], s.index))
        loads[best.index] += c.total
        out.append(Assignment(cursor=c.cursor, slice_index=best.index, cost=c))
    out.sort(key=lambda a: a.cursor)
    return WaveSchedule(assignments=out, n_slices=len(slices))


# --------------------------------------------------------------------- #
# Mesh layer: real slices of a real mesh.
# --------------------------------------------------------------------- #
def slice_mesh_plans(plan: MeshPlan, n_slices: int) -> List[MeshPlan]:
    """Split ``plan``'s mesh into ``n_slices`` equal submeshes.

    The split runs along the FIRST node axis (parts shard rows over node
    axes, so shrinking that axis keeps every slice a valid layout for the
    unchanged shard_map engine); its size must be divisible by
    ``n_slices``. Each slice keeps the global axis names, so
    ``MeshPlan(node_axes=..., slot_axes=...)`` carries over verbatim.
    """
    from jax.sharding import Mesh

    if n_slices < 1:
        raise ValueError(f"n_slices must be >= 1, got {n_slices}")
    if not plan.node_axes:
        raise ValueError("cannot slice a plan with no node axes")
    axis = plan.node_axes[0]
    names = tuple(plan.mesh.axis_names)
    size = plan.mesh.shape[axis]
    if size % n_slices != 0:
        raise ValueError(
            f"node axis {axis!r} has {size} shards — not divisible into "
            f"{n_slices} slices; pick a slice count dividing the axis"
        )
    pos = names.index(axis)
    devs = np.asarray(plan.mesh.devices)
    out = []
    for block in np.split(devs, n_slices, axis=pos):
        out.append(
            MeshPlan(
                mesh=Mesh(block, names),
                node_axes=plan.node_axes,
                slot_axes=plan.slot_axes,
            )
        )
    return out


def spec_of(plan: MeshPlan, index: int,
            capacity_bytes: Optional[int] = None) -> SliceSpec:
    """The pure :class:`SliceSpec` of a concrete slice plan."""
    return SliceSpec(
        index=index,
        n_node_shards=plan.n_node_shards,
        n_slot_shards=plan.n_slot_shards,
        capacity_bytes=capacity_bytes,
    )


def make_slice_decomposes(plan: MeshPlan, n_slices: int, **kw):
    """``(slice_plans, decompose_fns)`` for part-parallel ``dc_kcore``:
    one :func:`~repro.core.distributed.make_distributed_decompose` per
    slice of ``plan``, all sharing the engine kwargs (``wire_dtype``,
    ``use_kernel``, ``frontier``, ...)."""
    plans = slice_mesh_plans(plan, n_slices)
    return plans, [make_distributed_decompose(p, **kw) for p in plans]


# --------------------------------------------------------------------- #
# Wave executor.
# --------------------------------------------------------------------- #
class SliceHangError(RuntimeError):
    """The watchdog declared a slice hung: no heartbeat (sweep progress)
    within ``slice_timeout_s`` while a part was in flight."""


@dataclasses.dataclass
class WatchdogConfig:
    """Fault-tolerance knobs for :func:`conquer_wave`.

    ``slice_timeout_s``: declare a slice dead after this long without a
    heartbeat while a part is in flight (``None`` = never — crashes are
    still retried). ``max_retries``: failed attempts per part on the same
    slice before the slice is blacklisted. ``backoff_s``: base of the
    exponential retry backoff. ``poll_s``: watchdog poll period.
    ``drain_timeout_s``: how long the caller waits for abandoned worker
    threads to terminate after the wave settles (injected hangs are
    released and always terminate; a truly wedged thread past this is
    reported in telemetry — nothing in-process can kill it).
    """

    slice_timeout_s: Optional[float] = None
    max_retries: int = 2
    backoff_s: float = 0.05
    poll_s: float = 0.02
    drain_timeout_s: float = 10.0


@dataclasses.dataclass
class WaveTelemetry:
    """What the fault-tolerance layer did during one wave."""

    retries: int = 0
    blacklisted: List[int] = dataclasses.field(default_factory=list)
    replans: int = 0
    events: List[dict] = dataclasses.field(default_factory=list)

    def record(self, event: str, **ctx):
        self.events.append({"event": event, **ctx})

    @property
    def degraded(self) -> bool:
        return bool(self.blacklisted)


def _accepts_heartbeat(fn) -> bool:
    try:
        return "heartbeat" in inspect.signature(fn).parameters
    except (TypeError, ValueError):
        return False


class _WaveRunner:
    """One wave's execution state: per-slice work queues, heartbeats,
    retry/blacklist bookkeeping. All mutable state is guarded by one
    condition variable; ``run_part`` itself runs outside the lock."""

    def __init__(self, schedule, run_part, slices, watchdog, fault_plan, tel):
        self.schedule = schedule
        self.run_part = run_part
        self.wd = watchdog
        self.fault_plan = fault_plan
        self.tel = tel
        self.fail_fast = watchdog is None
        self.hb_aware = _accepts_heartbeat(run_part)
        if slices is None:
            slices = [SliceSpec(index=s, n_node_shards=1, n_slot_shards=1)
                      for s in range(schedule.n_slices)]
        self.slices = list(slices)
        self.cond = threading.Condition()
        self.queues: Dict[int, List[int]] = {
            sp.index: schedule.parts_for(sp.index) for sp in self.slices
        }
        self.costs: Dict[int, PartCost] = {
            a.cursor: a.cost for a in schedule.assignments
        }
        self.n_parts = len(schedule.assignments)
        self.results: Dict[int, object] = {}
        self.done: set = set()
        self.inflight: Dict[int, int] = {}     # slice index -> cursor
        self.beat: Dict[int, float] = {}       # slice index -> monotonic
        self.dead: Dict[int, BaseException] = {}
        self.failures: List[tuple] = []        # fail-fast: (cursor, exc)
        self.fatal: Optional[tuple] = None     # (cursor, exc) — FT exhausted
        self.stop = False

    # -- lifecycle ----------------------------------------------------- #
    def run(self) -> Dict[int, object]:
        threads = [
            threading.Thread(
                target=self._worker, args=(sp.index,), daemon=True,
                name=f"{CONQUER_THREAD_PREFIX}-{sp.index}",
            )
            for sp in self.slices
        ]
        for t in threads:
            t.start()
        try:
            if not self.fail_fast:
                self._monitor()
        finally:
            # Fail-fast workers drain their static queues and exit on their
            # own — raising ``stop`` early would race them into dropping
            # work (or a failure record). Only FT workers park for re-plans
            # and need the explicit wake-up once the monitor settles.
            if not self.fail_fast:
                with self.cond:
                    self.stop = True
                    self.cond.notify_all()
                if self.fault_plan is not None:
                    # The monitor only exits once the wave settled (all
                    # parts done or fatal), so any worker still parked in
                    # an injected hang is abandoned — wake it now so the
                    # drain join doesn't wait out the hang's delay.
                    self.fault_plan.release()
            deadline = self.wd.drain_timeout_s if self.wd else None
            for t in threads:
                t.join(timeout=deadline)
            if any(t.is_alive() for t in threads) and self.fault_plan is not None:
                self.fault_plan.release()
                for t in threads:
                    t.join(timeout=deadline)
            for t in threads:
                if t.is_alive():
                    self.tel.record("thread_leak", thread=t.name)
        if self.fail_fast and self.failures:
            self.failures.sort(key=lambda f: f[0])
            raise self.failures[0][1]
        if self.fatal is not None:
            raise self.fatal[1]
        return self.results

    def _monitor(self):
        with self.cond:
            while len(self.done) < self.n_parts and self.fatal is None:
                if self.wd.slice_timeout_s is not None:
                    now = time.monotonic()
                    for idx, cur in list(self.inflight.items()):
                        if idx in self.dead:
                            continue
                        if now - self.beat.get(idx, now) > self.wd.slice_timeout_s:
                            self._declare_dead(
                                idx, cur,
                                SliceHangError(
                                    f"slice {idx} hung on part cursor={cur}: no "
                                    f"heartbeat for {self.wd.slice_timeout_s}s"
                                ),
                                reason="hang",
                            )
                self.cond.wait(timeout=self.wd.poll_s)

    # -- blacklist + re-plan (cond held) ------------------------------- #
    def _declare_dead(self, idx: int, cur: Optional[int],
                      exc: BaseException, reason: str):
        if idx in self.dead:
            return
        self.dead[idx] = exc
        self.inflight.pop(idx, None)
        self.tel.blacklisted.append(idx)
        self.tel.record("blacklist", slice=idx, cursor=cur, reason=reason,
                        error=repr(exc))
        unfinished = [c for c in ([cur] if cur is not None else [])
                      if c not in self.done]
        unfinished += self.queues[idx]
        self.queues[idx] = []
        survivors = [sp for sp in self.slices if sp.index not in self.dead]
        if not survivors:
            self.fatal = (cur if cur is not None else -1, exc)
        elif unfinished:
            try:
                sub = assign_parts([self.costs[c] for c in unfinished], survivors)
            except SliceCapacityError as ce:
                self.fatal = (unfinished[0], ce)
            else:
                self.tel.replans += 1
                self.tel.record(
                    "replan", cursors=sorted(unfinished),
                    survivors=[sp.index for sp in survivors],
                )
                for a in sub.assignments:
                    self.queues[a.slice_index].append(a.cursor)
                for q in self.queues.values():
                    q.sort()
        self.cond.notify_all()

    # -- per-slice worker ---------------------------------------------- #
    def _worker(self, idx: int):
        def heartbeat(*_a, **_k):
            with self.cond:
                self.beat[idx] = time.monotonic()

        while True:
            with self.cond:
                cur = None
                while cur is None:
                    if self.stop or idx in self.dead or self.fatal is not None:
                        return
                    if self.queues[idx]:
                        cur = self.queues[idx].pop(0)
                        self.inflight[idx] = cur
                        self.beat[idx] = time.monotonic()
                        break
                    if self.fail_fast or len(self.done) >= self.n_parts:
                        # Fail-fast queues are static — an empty queue means
                        # this slice is drained; FT workers park for re-plans
                        # until the whole wave settles.
                        return
                    self.cond.wait(timeout=0.05)
            attempt = 0
            while True:
                try:
                    if self.fault_plan is not None:
                        self.fault_plan.visit(
                            "slice_conquer", cursor=cur, slice=idx,
                            attempt=attempt,
                        )
                    if self.hb_aware:
                        out = self.run_part(cur, idx, heartbeat=heartbeat)
                    else:
                        out = self.run_part(cur, idx)
                except BaseException as e:  # noqa: BLE001 — retried/re-raised
                    with self.cond:
                        if idx in self.dead or self.stop:
                            return  # abandoned mid-attempt; result not wanted
                        if self.fail_fast:
                            self.failures.append((cur, e))
                            self.inflight.pop(idx, None)
                            self.cond.notify_all()
                            return
                        attempt += 1
                        if attempt > self.wd.max_retries:
                            self._declare_dead(idx, cur, e, reason="crash")
                            return
                        self.tel.retries += 1
                        self.tel.record("retry", slice=idx, cursor=cur,
                                        attempt=attempt, error=repr(e))
                        self.beat[idx] = time.monotonic()
                    time.sleep(self.wd.backoff_s * (2 ** (attempt - 1)))
                    continue
                with self.cond:
                    if idx in self.dead:
                        # Declared hung while (slowly) finishing: the part
                        # was re-planned; parts are idempotent over
                        # immutable inputs, so the survivor's byte-identical
                        # result is the one committed.
                        self.tel.record("discarded_result", slice=idx, cursor=cur)
                        return
                    self.results[cur] = out
                    self.done.add(cur)
                    self.inflight.pop(idx, None)
                    self.cond.notify_all()
                break


def conquer_wave(
    schedule: WaveSchedule,
    run_part: Callable[[int, int], object],
    *,
    slices: Optional[Sequence[SliceSpec]] = None,
    watchdog: Optional[WatchdogConfig] = None,
    fault_plan=None,
    telemetry: Optional[WaveTelemetry] = None,
) -> Dict[int, object]:
    """Run one wave: each slice conquers its assigned parts concurrently.

    ``run_part(cursor, slice_index)`` conquers one part and returns its
    result; each slice's parts run in ascending cursor order on that
    slice's worker thread. If ``run_part`` accepts a ``heartbeat`` keyword
    it receives a zero-arg callable to signal liveness (the pipeline wires
    it to the engine's per-sweep ``on_sweep`` hook).

    Default (``watchdog=None``) is fail-fast: every slice drains before
    this returns — on failure the earliest-cursor slice's exception is
    re-raised (the others are suppressed deterministically), and no worker
    thread outlives the call either way.

    With a :class:`WatchdogConfig` the wave becomes fault-tolerant: a
    failed part is retried on its slice with exponential backoff up to
    ``max_retries``; a slice whose heartbeat stalls past
    ``slice_timeout_s`` (or that exhausts its retries) is blacklisted and
    its unfinished parts are re-planned over the surviving slices via
    :func:`assign_parts` (S -> S-1 -> ... -> 1 ≡ sequential). Parts are
    idempotent over immutable inputs, so a retried or re-planned part
    produces byte-identical coreness. Only when *no* slice survives (or a
    re-plan hits :class:`SliceCapacityError`) does the wave raise.
    ``telemetry`` (a :class:`WaveTelemetry`) collects retry/blacklist/
    re-plan events; ``fault_plan`` (:class:`repro.runtime.FaultPlan`) is
    consulted at the ``slice_conquer`` site before each attempt.
    ``slices`` carries the actual :class:`SliceSpec`\\ s (required for
    re-planning; defaults to unit specs indexed ``0..n_slices-1``).
    """
    tel = telemetry if telemetry is not None else WaveTelemetry()
    runner = _WaveRunner(schedule, run_part, slices, watchdog, fault_plan, tel)
    return runner.run()
