"""The Divide step — Exact-Divide and Rough-Divide (paper Section 4.2).

Both strategies select, on the *remaining* graph (original graph minus all
already-finalized upper parts), a candidate node set whose decomposition
will finalize every node with coreness >= the threshold ``t``:

* **Exact-Divide** extracts the exact generalized t-core: iteratively peel
  nodes with ``deg(v) + ext(v) < t``. Expensive (paper Fig 9) but every node
  of the extracted part finalizes.
* **Rough-Divide** takes the one-shot degree filter
  ``{v : deg(v) + ext(v) >= t}`` — a superset of the t-core that is
  3.7-14.3x cheaper to extract in the paper. Nodes that decompose to a value
  < t are *not* final and fall through to the next part.

``ext`` here generalizes the paper's Definition 3 to the multi-part setting:
it counts neighbors in the union of all finalized upper parts, whose
coreness is >= every threshold still to be processed — so they behave as
infinite-coreness virtual neighbors for the remainder (Corollary 1 analog).

Also provides :func:`plan_thresholds`, the resource-driven threshold picker:
given a per-part memory budget, choose division thresholds from the degree
distribution so every part's device footprint fits — this automates the
paper's "limited resources" knob.
"""
from __future__ import annotations

import time
from typing import List, Tuple

import numpy as np

from repro.graph.structs import Graph


def rough_candidates(deg: np.ndarray, ext: np.ndarray, t: int) -> np.ndarray:
    """Rough-Divide candidate mask on the remaining graph."""
    return (deg.astype(np.int64) + ext.astype(np.int64)) >= t


def exact_candidates(g: Graph, ext: np.ndarray, t: int) -> np.ndarray:
    """Exact-Divide: generalized t-core mask via peeling with ext credit."""
    alive = np.ones(g.n_nodes, dtype=bool)
    deg = g.degrees.astype(np.int64) + ext.astype(np.int64)
    src = np.repeat(np.arange(g.n_nodes, dtype=np.int64), g.degrees)
    frontier = np.nonzero(alive & (deg < t))[0]
    while frontier.size:
        alive[frontier] = False
        f = np.zeros(g.n_nodes, dtype=bool)
        f[frontier] = True
        hits = f[src] & alive[g.indices]
        dec = np.bincount(g.indices[hits], minlength=g.n_nodes)
        deg -= dec
        frontier = np.nonzero(alive & (deg < t) & (dec > 0))[0]
    return alive


def timed_candidates(
    g: Graph, ext: np.ndarray, t: int, strategy: str
) -> Tuple[np.ndarray, float]:
    """Candidate mask plus extraction wall time (paper Fig 9 measurement)."""
    t0 = time.time()
    if strategy == "rough":
        mask = rough_candidates(g.degrees, ext, t)
    elif strategy == "exact":
        mask = exact_candidates(g, ext, t)
    else:
        raise ValueError(f"unknown divide strategy: {strategy}")
    return mask, time.time() - t0


def plan_thresholds(
    g: Graph,
    part_budget_bytes: int,
    max_parts: int = 8,
    bytes_per_edge: int = 8,
) -> List[int]:
    """Pick division thresholds so each part's footprint fits the budget.

    Walks the degree distribution from the top: the highest-threshold part
    contains the highest-degree nodes (a superset of the densest cores).
    Greedy: grow the current part until its padded edge estimate exceeds the
    budget, then emit a threshold. Returns descending thresholds (possibly
    empty = no division needed).
    """
    deg = np.sort(g.degrees.astype(np.int64))[::-1]
    if deg.size == 0:
        return []
    total = int(deg.sum()) * bytes_per_edge
    if total <= part_budget_bytes:
        return []
    thresholds: List[int] = []
    acc = 0
    for d in deg:
        acc += int(d) * bytes_per_edge
        if acc > part_budget_bytes:
            t = int(d)
            if t <= 1 or (thresholds and t >= thresholds[-1]):
                break
            thresholds.append(t)
            acc = 0
            if len(thresholds) >= max_parts - 1:
                break
    return thresholds
