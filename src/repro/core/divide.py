"""The Divide step — Exact-Divide and Rough-Divide (paper Section 4.2).

Both strategies select, on the *remaining* graph (original graph minus all
already-finalized upper parts), a candidate node set whose decomposition
will finalize every node with coreness >= the threshold ``t``:

* **Exact-Divide** extracts the exact generalized t-core: iteratively peel
  nodes with ``deg(v) + ext(v) < t``. Expensive (paper Fig 9) but every node
  of the extracted part finalizes.
* **Rough-Divide** takes the one-shot degree filter
  ``{v : deg(v) + ext(v) >= t}`` — a superset of the t-core that is
  3.7-14.3x cheaper to extract in the paper. Nodes that decompose to a value
  < t are *not* final and fall through to the next part.

``ext`` here generalizes the paper's Definition 3 to the multi-part setting:
it counts neighbors in the union of all finalized upper parts, whose
coreness is >= every threshold still to be processed — so they behave as
infinite-coreness virtual neighbors for the remainder (Corollary 1 analog).

Also provides :func:`plan_thresholds`, the resource-driven threshold picker:
given a per-part memory budget, choose division thresholds from the degree
distribution so every part's device footprint fits — this automates the
paper's "limited resources" knob.
"""
from __future__ import annotations

import time
from typing import List, Optional, Tuple, Union

import numpy as np

from repro.graph.build import DivideStats, _resolve_chunk_slots, iter_row_ranges
from repro.graph.structs import Graph


def rough_candidates(deg: np.ndarray, ext: np.ndarray, t: int) -> np.ndarray:
    """Rough-Divide candidate mask on the remaining graph.

    Pure ``O(n)`` arithmetic over the degree and ext arrays — no edge-sized
    scratch; on the streaming ingest path it runs before (or without) the
    CSR via :func:`rough_candidates_from_store`.
    """
    return (deg.astype(np.int64) + ext.astype(np.int64)) >= t


def rough_candidates_from_store(store, n_nodes: int, ext: np.ndarray, t: int) -> np.ndarray:
    """Rough-Divide directly over a spilled :class:`~repro.graph.io.EdgeStore`.

    Uses the store's duplicate-inclusive degree counts, so the mask is a
    superset of :func:`rough_candidates` on the deduplicated CSR (equal when
    the stream carries no duplicate edges) — still a valid Rough-Divide
    candidate set (supersets only defer non-final nodes to the next part).
    Together with :func:`~repro.graph.io.induced_subgraph_from_store` this
    lets the first part of a streamed pipeline be planned *and* extracted
    without the full CSR ever resident.
    """
    return rough_candidates(store.dup_degrees(int(n_nodes)), ext, t)


def exact_candidates(
    g: Graph,
    ext: np.ndarray,
    t: int,
    chunk_slots: Optional[int] = None,
    stats: Optional[DivideStats] = None,
) -> np.ndarray:
    """Exact-Divide: generalized t-core mask via peeling with ext credit.

    Each peel round gathers only the *frontier* rows' adjacency, in chunks
    of at most ``chunk_slots`` slots (``None`` =
    :data:`~repro.graph.build.DEFAULT_DIVIDE_CHUNK_SLOTS`) — the transient
    is bounded by the chunk budget plus ``O(n)`` state, where the previous
    implementation pinned an edge-sized ``np.repeat`` source vector for the
    whole peel. The peeled set is identical at every chunk size (each round
    decrements alive neighbors of the full frontier, chunked or not).
    """
    n = g.n_nodes
    budget = _resolve_chunk_slots(chunk_slots)
    alive = np.ones(n, dtype=bool)
    deg = g.degrees.astype(np.int64) + ext.astype(np.int64)
    row_len = np.diff(g.indptr).astype(np.int64)
    persistent = alive.nbytes + deg.nbytes + row_len.nbytes
    frontier = np.nonzero(alive & (deg < t))[0]
    while frontier.size:
        alive[frontier] = False
        dec = np.zeros(n, dtype=np.int64)
        lens = row_len[frontier]
        round_live = 0
        # cum is an indptr over the frontier rows, so the same row-range
        # chunker that drives induced_subgraph/external_info groups them.
        cum = np.concatenate([[0], np.cumsum(lens, dtype=np.int64)])
        for start, stop in iter_row_ranges(cum, budget):
            rows = frontier[start:stop]
            group = lens[start:stop]
            total = int(cum[stop] - cum[start])
            if total == 0:
                continue
            # Vectorized multi-slice gather of the group's adjacency.
            idx = (
                np.arange(total, dtype=np.int64)
                - np.repeat(cum[start:stop] - cum[start], group)
                + np.repeat(g.indptr[rows], group)
            )
            cols = g.indices[idx]
            live = alive[cols]
            dec += np.bincount(cols[live], minlength=n)
            round_live += int(live.sum())
            if stats is not None:
                stats.n_chunks += 1
                stats.input_slots += total
                stats.kept_slots += int(live.sum())
                stats.bump(
                    persistent + dec.nbytes + frontier.nbytes + lens.nbytes
                    + idx.nbytes * 2 + cols.nbytes + live.nbytes
                )
        if stats is not None:
            # Dense model of one peel round: the pinned np.repeat source
            # vector plus three edge masks over ALL slots (regardless of
            # frontier size) and the int32 compaction of this round's hits.
            stats.note_pass(2 * g.n_edges, round_live, slot_bytes=11, kept_bytes=4)
        deg -= dec
        frontier = np.nonzero(alive & (deg < t) & (dec > 0))[0]
    return alive


def timed_candidates(
    g: Graph,
    ext: np.ndarray,
    t: int,
    strategy: str,
    chunk_slots: Optional[int] = None,
    stats: Optional[DivideStats] = None,
) -> Tuple[np.ndarray, float]:
    """Candidate mask plus extraction wall time (paper Fig 9 measurement)."""
    t0 = time.perf_counter()
    if strategy == "rough":
        mask = rough_candidates(g.degrees, ext, t)
    elif strategy == "exact":
        mask = exact_candidates(g, ext, t, chunk_slots=chunk_slots, stats=stats)
    else:
        raise ValueError(f"unknown divide strategy: {strategy}")
    return mask, time.perf_counter() - t0


def plan_thresholds(
    g: Union[Graph, np.ndarray],
    part_budget_bytes: int,
    max_parts: int = 8,
    bytes_per_edge: int = 8,
) -> List[int]:
    """Pick division thresholds so each part's footprint fits the budget.

    ``g`` may be a :class:`Graph` or just its **degree array** — planning
    needs nothing else, so on the streaming ingest path it can run from
    :meth:`EdgeStore.dup_degrees <repro.graph.io.EdgeStore.dup_degrees>`
    before (or without) the edge list being resident.

    Walks the degree distribution from the top as runs of equal degree
    (nodes of one degree value are indivisible by thresholds): the current
    part greedily absorbs runs while its padded edge estimate fits the
    budget; the first run that would overflow closes the part, whose
    threshold is the degree of its last absorbed run (part = ``deg >= t``).
    A repeated overflow at the same degree value — the old early-``break``
    bug — cannot occur: runs are strictly decreasing, so every emitted
    threshold is strictly below the previous one. Returns descending
    thresholds (possibly empty = no division needed).

    Every planned part's estimate fits the budget, with one unavoidable
    exception: a single run that alone exceeds it (equal-degree nodes
    cannot be split by a degree threshold) becomes its own over-budget
    part. The trailing run group is always closed with its own threshold:
    division was needed (total > budget), so the planned remainder must
    not merge with the unsplittable low-degree tail into an over-budget
    rest part. Thresholds <= 1 are never emitted — the implicit final
    "rest" covers the deg <= 1 tail.
    """
    deg_src = g.degrees if isinstance(g, Graph) else np.asarray(g)
    deg = np.sort(deg_src.astype(np.int64))[::-1]
    if deg.size == 0:
        return []
    total = int(deg.sum()) * bytes_per_edge
    if total <= part_budget_bytes:
        return []
    # Runs of equal degree, descending: values[i] with total bytes run_bytes[i].
    values, run_len = np.unique(deg, return_counts=True)
    values, run_len = values[::-1], run_len[::-1]
    run_bytes = values * run_len * bytes_per_edge
    thresholds: List[int] = []
    acc = 0
    prev_v = None
    for v, rb in zip(values, run_bytes):
        if v <= 1:
            break
        if acc > 0 and acc + int(rb) > part_budget_bytes:
            # Close the current part before this run; its threshold is the
            # last absorbed run's degree (strictly greater than v).
            thresholds.append(int(prev_v))
            acc = 0
            if len(thresholds) >= max_parts - 1:
                break
        acc += int(rb)
        prev_v = v
    # Close the trailing group too: reaching the loop means total > budget,
    # so without this cut the planned remainder would merge with the
    # deg <= 1 tail into an over-budget rest and the graph could even end
    # up monolithic (the old planner's under-division modes).
    if (acc > 0 and prev_v is not None and prev_v > 1
            and len(thresholds) < max_parts - 1
            and (not thresholds or prev_v < thresholds[-1])):
        thresholds.append(int(prev_v))
    return thresholds
