"""Distributed conquer engine: shard_map k-core decomposition.

TPU-native mapping of the paper's parameter-server loop (Section 4.3.2,
Figure 6):

  paper step                      | here
  --------------------------------+----------------------------------------
  (1) vertex-centric data loading | bucket rows block-sharded over the node
                                  | mesh axes; neighbor slots sharded over
                                  | the slot ("model") axes
  (2) pull coreness from PS       | local gather from the replicated part
                                  | coreness vector
  (3) estimate coreness (Alg 2)   | partial suffix-counts per slot shard,
                                  | psum over slot axes, feasibility argmax
  (4) push updated coreness       | all_gather of the per-shard estimates
                                  | over the node axes
  (5) PS in-place update          | functional scatter into the replicated
                                  | vector

The replicated coreness vector is the PS analogue; its size is the *part*
node count, which is exactly what the divide step caps — the peak-HBM story
of the paper carries over unchanged.

Collective traffic is counted analytically per sweep (ring all-gather /
reduce-scatter terms) by :func:`sweep_collective_bytes`; the paper's
"communication amount" (changed estimates) is counted on-device like the
single-device engine.

Active-frontier sweep scheduling mirrors the single-device engine: the
replicated frontier mask gates each bucket's gather, h-index, psum AND
all_gather behind ``lax.cond`` (every device branches on the same
replicated predicate), so both compute and collective bytes shrink with
the frontier. Dirty bits are pushed at bucket granularity through the
replicated ``node_tile`` map and unioned across the mesh by one
[n_buckets] psum per sweep — no state-sized collective is ever added.
The skip soundness argument is the same static bucket-adjacency bitmap +
row-exact dirty-bit refinement documented in ``repro.core.decompose``.
"""
from __future__ import annotations

import dataclasses
import math
import time
from functools import partial
from typing import List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.compat import shard_map as compat_shard_map
from repro.core.decompose import DecomposeResult
from repro.core.hindex import hindex_of_sequence
from repro.graph.structs import BucketedGraph


@dataclasses.dataclass(frozen=True)
class MeshPlan:
    """How the graph maps onto the device mesh."""

    mesh: Mesh
    node_axes: Tuple[str, ...]  # bucket rows sharded over these
    slot_axes: Tuple[str, ...]  # neighbor slots sharded over these

    @property
    def n_node_shards(self) -> int:
        return math.prod(self.mesh.shape[a] for a in self.node_axes)

    @property
    def n_slot_shards(self) -> int:
        return math.prod(self.mesh.shape[a] for a in self.slot_axes)


def _pad_to(x: np.ndarray, mult: int, axis: int, fill) -> np.ndarray:
    size = x.shape[axis]
    pad = (-size) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return np.pad(x, widths, constant_values=fill)


def shard_buckets(bg: BucketedGraph, plan: MeshPlan, wire_dtype=jnp.int32):
    """Device-put bucket arrays with their distributed shardings."""
    ns, ms = plan.n_node_shards, plan.n_slot_shards
    mesh = plan.mesh
    row_spec = NamedSharding(mesh, P(plan.node_axes))
    tile_spec = NamedSharding(mesh, P(plan.node_axes, plan.slot_axes))
    out = []
    for b in bg.buckets:
        ids = _pad_to(b.node_ids, ns, 0, bg.n_nodes)
        neigh = _pad_to(_pad_to(b.neigh, ns, 0, bg.n_nodes), ms, 1, bg.n_nodes)
        out.append(
            (
                jax.device_put(ids.astype(np.int32), row_spec),
                jax.device_put(neigh.astype(np.int32), tile_spec),
            )
        )
    return out


def _ring_bucket_bytes(padded_rows: int, ns: int, ms: int, cand: int,
                       wire_bytes: int, include_ids: bool) -> int:
    """Per-device ICI bytes of ONE bucket's sweep collectives (ring model).

    The single shape-level formula every collective-bytes accounting in
    this module derives from — the analytic planning model, the measured
    per-iteration counter, and the dry-run's planned schedule can then
    never disagree about what one bucket costs:

    * psum of the ``[rows_loc, cand]`` int32 count partials over the slot
      axes: a ring all-reduce moves ``2 (m-1)/m`` of the operand;
    * all_gather of the ``[rows_loc]`` estimates (``wire_bytes`` wide) over
      the node axes: ``(n-1)`` local shards per device — plus, when
      ``include_ids``, the int32 ids all_gather issued alongside it.

    ``padded_rows`` must already be the node-shard-padded row count.
    """
    rows_loc = padded_rows // ns
    total = 0
    if ms > 1:
        total += int(2 * (ms - 1) / ms * rows_loc * cand * 4)
    if ns > 1:
        total += int((ns - 1) * rows_loc * (wire_bytes + (4 if include_ids else 0)))
    return total


def _dirty_psum_bytes(n_buckets: int, mesh_size: int) -> int:
    """Per-device bytes of the frontier's [n_buckets] dirty-bit psum."""
    if mesh_size <= 1:
        return 0
    return int(2 * (mesh_size - 1) / mesh_size * n_buckets * 4)


def sweep_collective_bytes(bg: BucketedGraph, plan: MeshPlan, cand: int,
                           wire_bytes: int = 4,
                           active: Optional[np.ndarray] = None) -> int:
    """Analytic per-device ICI bytes of one sweep (ring-algorithm model).

    Two collective terms per *active* bucket:

    * psum of the ``[rows_loc, cand]`` int32 count partials over the slot
      axes — a ring all-reduce moves ``2 (m-1)/m`` of the operand per
      device (``m`` = slot shards);
    * all_gather of the ``[rows_loc]`` estimates over the node axes — a
      ring all-gather moves ``(n-1)`` local shards per device (``n`` =
      node shards), each ``wire_bytes`` wide (int16 wire halves exactly
      this term).

    ``active`` restricts the count to the frontier's buckets — skipped
    buckets skip their collectives too, so per-sweep collective bytes
    shrink with the frontier.

    This is the *planning* model: it works from ``bg`` alone (no device
    arrays needed), which is what the dry-run feasibility tables use at
    the paper's 136B-edge scales. It deliberately excludes the frontier's
    own [n_buckets] dirty-bit psum. The *measured* counterpart — computed
    per iteration from the live frontier mask and the actual padded device
    shapes, dirty psum included — is :func:`measured_sweep_bytes`, which
    :func:`decompose_distributed` records into
    ``DecomposeResult.collective_bytes_per_iter``.
    """
    ns, ms = plan.n_node_shards, plan.n_slot_shards
    total = 0
    for bi, b in enumerate(bg.buckets):
        if active is not None and not active[bi]:
            continue
        rows = math.ceil(b.n_rows / ns) * ns
        total += _ring_bucket_bytes(rows, ns, ms, cand, wire_bytes,
                                    include_ids=False)
    return total


def measured_sweep_bytes(dev_buckets, plan: MeshPlan, cand: int,
                         wire_bytes: int, active: np.ndarray,
                         frontier: bool) -> int:
    """Per-device ICI bytes one sweep actually moves, from live state.

    Unlike the analytic :func:`sweep_collective_bytes` model this reads the
    *device* bucket arrays (whose rows :func:`shard_buckets` re-padded to
    the node-shard multiple), takes the actual per-iteration frontier mask,
    and counts two terms the analytic model omits:

    * the int32 ``ids_loc`` all_gather each active bucket issues alongside
      its estimate gather (node ids are re-gathered transiently every
      sweep rather than replicated — keeping them resident would put the
      whole row-id vector back into per-device HBM, the budget the divide
      step exists to cap);
    * the frontier's [n_buckets] dirty-bit psum over the whole mesh (a
      ``2 (k-1)/k`` ring all-reduce, ``k`` = mesh size).

    This is the counter :func:`decompose_distributed` accumulates per
    iteration into ``DecomposeResult.collective_bytes_per_iter``.
    """
    ns, ms = plan.n_node_shards, plan.n_slot_shards
    total = 0
    for bi, (ids, _neigh) in enumerate(dev_buckets):
        if not active[bi]:
            continue
        # est_full (wire dtype) + ids_full (int32) ring all-gathers.
        total += _ring_bucket_bytes(ids.shape[0], ns, ms, cand, wire_bytes,
                                    include_ids=True)
    if frontier:
        # dirty_next psum: [n_buckets] int32 over every mesh axis; runs
        # whenever the frontier sweep runs, active or not.
        total += _dirty_psum_bytes(len(dev_buckets), ns * ms)
    return total


def planned_collective_schedule(
    bucket_rows: Sequence[int],
    plan: MeshPlan,
    cand: int,
    *,
    wire_bytes: int = 4,
    n_iters: int = 30,
    full_sweeps: int = 3,
    decay: float = 0.6,
    frontier: bool = True,
) -> List[int]:
    """Modeled per-iteration collective bytes for a run that never sweeps.

    The dry-run feasibility tables need collective traffic without running
    a single sweep, so this derives it from a *planned* frontier schedule
    over the bucket shapes: the first ``full_sweeps`` iterations sweep
    every bucket (estimates are still far from their fixed point
    everywhere), after which the live row fraction decays geometrically by
    ``decay`` per sweep and the frontier concentrates in the LAST buckets
    of the list — bucketize emits degree classes ascending, and on
    power-law graphs the dense classes (hubs) converge last (Montresor et
    al.; paper Fig 8). Each planned iteration is costed with the same
    per-bucket ring formula as the measured counter (ids all_gather and
    dirty-bit psum included), so on a ``frontier=False`` run — where the
    planned schedule is exact, every sweep full — the model reproduces
    ``DecomposeResult.collective_bytes_per_iter`` byte for byte (the
    pinning test of tests/test_distributed_kcore.py).

    ``bucket_rows`` are the UNpadded per-bucket row counts (node-shard
    padding is applied here, as :func:`shard_buckets` would).
    """
    ns, ms = plan.n_node_shards, plan.n_slot_shards
    nb = len(bucket_rows)
    padded = [math.ceil(r / ns) * ns for r in bucket_rows]
    dirty = _dirty_psum_bytes(nb, ns * ms) if frontier else 0
    return [
        sum(_ring_bucket_bytes(padded[bi], ns, ms, cand, wire_bytes,
                               include_ids=True) for bi in live)
        + dirty
        for live in planned_live_sets(padded, n_iters=n_iters,
                                      full_sweeps=full_sweeps, decay=decay,
                                      frontier=frontier)
    ]


def planned_live_sets(
    padded_rows: Sequence[int],
    *,
    n_iters: int = 30,
    full_sweeps: int = 3,
    decay: float = 0.6,
    frontier: bool = True,
) -> List[List[int]]:
    """The planned frontier schedule itself: live bucket indices per sweep.

    This is the live-set rule :func:`planned_collective_schedule` prices —
    extracted so other cost models (the part-parallel scheduler's HBM
    term in ``repro.core.partsched``) price the *same* schedule. The first
    ``full_sweeps`` iterations keep every bucket live; afterwards the live
    row budget decays geometrically by ``decay`` and is filled from the
    LAST buckets of the list downward (densest degree classes converge
    last on power-law graphs). ``padded_rows`` must already carry the
    node-shard padding.
    """
    nb = len(padded_rows)
    total_rows = sum(padded_rows) or 1
    out: List[List[int]] = []
    for it in range(n_iters):
        if not frontier or it < full_sweeps:
            live = list(range(nb))
        else:
            budget = total_rows * (decay ** (it - full_sweeps + 1))
            live, acc = [], 0
            for bi in range(nb - 1, -1, -1):  # densest classes stay live
                live.append(bi)
                acc += padded_rows[bi]
                if acc >= budget:
                    break
        out.append(live)
    return out


def _partial_counts(gathered, ext_rows, cand: int, cand_chunk: int = 256):
    """Suffix counts over the LOCAL slot shard: cnt[r, i] for i in [1, cand]."""
    chunks = []
    for lo in range(0, cand, cand_chunk):
        w = min(cand_chunk, cand - lo)
        i = lo + 1 + jnp.arange(w, dtype=jnp.int32)
        thr = ext_rows[:, None] + i[None, :]
        chunks.append(
            jnp.sum((gathered[:, :, None] >= thr[:, None, :]).astype(jnp.int32), axis=1)
        )
    return jnp.concatenate(chunks, axis=1) if len(chunks) > 1 else chunks[0]


def make_sweep_fn(plan: MeshPlan, cand: int, wire_dtype=jnp.int32,
                  use_kernel: bool = False, frontier: bool = True):
    """Build the jitted shard_map sweep:
    ``(c, ext_pad, active, node_tile, buckets) -> (c', changed, dirty_next)``.

    ``active`` is the replicated [n_buckets] bool frontier mask: inactive
    buckets skip gather, h-index, AND their psum/all_gather behind
    ``lax.cond`` — per-sweep collective bytes shrink with the frontier.
    ``node_tile`` maps node id -> owning bucket ([n + 1], sentinel/deg-0
    rows -> n_buckets). ``changed[i]`` counts rows of bucket ``i`` whose
    estimate changed (replicated arithmetic, no extra collective);
    ``dirty_next[j]`` is True iff some changed row has a neighbor in bucket
    ``j`` — each device pushes shard-local dirty bits at bucket granularity
    and one tiny [n_buckets] psum unions them across the mesh.
    ``frontier=False`` (the always-full-sweep baseline) compiles the dirty
    push and its psum out and returns an all-False ``dirty_next``.

    ``use_kernel=True`` computes the per-shard partial counts with the
    Pallas kernel (kernels/counts) instead of the pure-jnp path."""
    mesh = plan.mesh
    node_axes, slot_axes = plan.node_axes, plan.slot_axes
    all_axes = tuple(node_axes) + tuple(slot_axes)
    rep = P()  # replicated
    row_p = P(node_axes)
    tile_p = P(node_axes, slot_axes)

    def counts(gathered, ext_rows):
        if use_kernel:
            from repro.kernels.counts import partial_counts_op

            return partial_counts_op(gathered, ext_rows, cand=cand)
        return _partial_counts(gathered, ext_rows, cand)

    def sweep(c, ext_pad, active, node_tile, buckets):
        n_buckets = len(buckets)
        sentinel = c.shape[0] - 1
        new_c = c
        # Shard-local per-bucket dirty partials (slot n_buckets = dump row
        # for sentinel-padded neighbors); unioned by one [nb] psum below.
        tile_dirty = jnp.zeros((n_buckets + 1,), jnp.int32)
        changed_parts = []
        for bi, (ids_loc, neigh_loc) in enumerate(buckets):

            def update(nc, td, ids_loc=ids_loc, neigh_loc=neigh_loc):
                gathered = nc[neigh_loc].astype(jnp.int32)  # wire may be int16
                ext_rows = ext_pad[ids_loc]
                cnt = counts(gathered, ext_rows)
                if plan.n_slot_shards > 1:
                    cnt = jax.lax.psum(cnt, slot_axes)
                i = 1 + jnp.arange(cand, dtype=jnp.int32)
                feasible = cnt >= i[None, :]
                est = ext_rows + jnp.max(jnp.where(feasible, i[None, :], 0), axis=1)
                est = est.astype(wire_dtype)
                # Push dirty bits: each changed local row marks the buckets
                # owning its local neighbor slots (union across devices via
                # the final psum). Work stays proportional to the frontier.
                if frontier:
                    row_changed = (est.astype(nc.dtype) != nc[ids_loc]) & (
                        ids_loc != sentinel
                    )
                    td = td.at[node_tile[neigh_loc].astype(jnp.int32)].max(
                        jnp.broadcast_to(
                            row_changed[:, None], neigh_loc.shape
                        ).astype(jnp.int32)
                    )
                if plan.n_node_shards > 1:
                    est_full = jax.lax.all_gather(est, node_axes, tiled=True)
                    ids_full = jax.lax.all_gather(ids_loc, node_axes, tiled=True)
                else:
                    est_full, ids_full = est, ids_loc
                prev_full = nc[ids_full]
                ch = jnp.sum(
                    (est_full.astype(nc.dtype) != prev_full)
                    & (ids_full != sentinel)
                ).astype(jnp.int32)
                nc = nc.at[ids_full].set(est_full.astype(nc.dtype))
                nc = nc.at[-1].set(-1)
                return nc, td, ch

            new_c, tile_dirty, ch = jax.lax.cond(
                active[bi],
                update,
                lambda nc, td: (nc, td, jnp.int32(0)),
                new_c,
                tile_dirty,
            )
            changed_parts.append(ch)
        changed = (
            jnp.stack(changed_parts)
            if changed_parts
            else jnp.zeros((0,), jnp.int32)
        )
        dirty_next = tile_dirty[:n_buckets]
        if frontier and len(all_axes) > 0:
            dirty_next = jax.lax.psum(dirty_next, all_axes)
        return new_c, changed, dirty_next > 0

    def build(n_buckets: int):
        """shard_map needs exact pytree in_specs — build per bucket count.

        check_vma=False: outputs ARE replicated by construction (psum over
        slot axes + all_gather over node axes before every scatter), but the
        static checker cannot see through the scatter."""
        return jax.jit(
            compat_shard_map(
                sweep,
                mesh=mesh,
                in_specs=(rep, rep, rep, rep, [(row_p, tile_p)] * n_buckets),
                out_specs=(rep, rep, rep),
                check_vma=False,
            )
        )

    return build


def node_tile_map(bg: BucketedGraph) -> np.ndarray:
    """[n + 1] node -> owning bucket; sentinel/deg-0 -> n_buckets.

    int16 whenever the bucket count allows (it always does in practice:
    buckets are degree classes x bounded row-tiles). At the paper's WX-136B
    scale the replicated map is 2 bytes/node — the same budget class as the
    int16 coreness wire, which is what keeps the divided parts inside the
    16 GiB/chip feasibility story."""
    nb = len(bg.buckets)
    dtype = np.int16 if nb < np.iinfo(np.int16).max else np.int32
    m = bg.node_bucket_map()
    return np.where(m < 0, nb, m).astype(dtype)


def decompose_distributed(
    bg: BucketedGraph,
    plan: MeshPlan,
    *,
    wire_dtype=jnp.int32,
    use_kernel: bool = False,
    frontier: bool = True,
    max_iter: Optional[int] = None,
    init_coreness: Optional[np.ndarray] = None,
    on_sweep=None,
) -> DecomposeResult:
    """Distributed fixed point; same contract as
    :func:`repro.core.decompose.decompose` (including ``frontier``,
    ``init_coreness`` warm restart and the ``on_sweep(iteration, coreness)``
    snapshot hook — both speak **original**-id order int32, the hook view
    staying a lazy device array, so a snapshot taken by this engine
    restarts the single-device one and vice versa; with an int16 wire,
    snapshots widen to int32 on the way out and narrow back on the way
    in)."""
    n = bg.n_nodes
    t0 = time.perf_counter()
    cand = max(1, hindex_of_sequence(bg.degrees.astype(np.int64) + bg.ext))

    mesh = plan.mesh
    rep_sh = NamedSharding(mesh, P())
    ext = jnp.asarray(bg.ext, dtype=jnp.int32)
    ext_pad = jax.device_put(
        jnp.concatenate([ext, jnp.zeros((1,), jnp.int32)]), rep_sh
    )
    if init_coreness is not None:
        start = np.asarray(init_coreness)
        if bg.perm is not None:
            start = start[bg.perm]  # original-id order -> layout order
        start = jnp.asarray(start, jnp.int32).astype(wire_dtype)
    else:
        start = (jnp.asarray(bg.degrees, jnp.int32) + ext).astype(wire_dtype)
    c = jax.device_put(
        jnp.concatenate([start, jnp.full((1,), -1, wire_dtype)]),
        rep_sh,
    )
    node_tile = jax.device_put(jnp.asarray(node_tile_map(bg)), rep_sh)
    buckets = shard_buckets(bg, plan, wire_dtype)
    sweep = make_sweep_fn(plan, cand, wire_dtype, use_kernel, frontier)(len(buckets))

    # Peak per-device bytes: sharded tiles + replicated state (coreness,
    # ext, and the node -> bucket frontier map).
    ns, ms = plan.n_node_shards, plan.n_slot_shards
    tile_bytes = sum(int(ids.size * 4 / ns + neigh.size * 4 / (ns * ms)) for ids, neigh in buckets)
    state_bytes = int(
        c.size * c.dtype.itemsize
        + ext_pad.size * 4
        + node_tile.size * node_tile.dtype.itemsize
    )
    peak = tile_bytes + state_bytes

    n_buckets = len(bg.buckets)
    bucket_rows = np.array([b.n_rows for b in bg.buckets], dtype=np.int64)
    adj = bg.bucket_adjacency()
    active = np.ones(n_buckets, dtype=bool)

    wire_bytes = jnp.dtype(wire_dtype).itemsize
    limit = max_iter if max_iter is not None else max(4, n)
    # Hoisted once: no per-sweep H2D upload just to build the hook view.
    inv_perm_dev = (
        jnp.asarray(bg.inv_perm)
        if on_sweep is not None and bg.inv_perm is not None else None
    )
    comm_per_iter: List[int] = []
    active_rows_per_iter: List[int] = []
    collective_bytes_per_iter: List[int] = []
    total = 0
    it = 0
    while it < limit:
        active_rows_per_iter.append(int(bucket_rows[active].sum()))
        collective_bytes_per_iter.append(
            measured_sweep_bytes(buckets, plan, cand, wire_bytes, active, frontier)
        )
        c, changed_vec, dirty_next = sweep(
            c, ext_pad, jnp.asarray(active), node_tile, buckets
        )
        changed_vec = np.asarray(changed_vec)
        changed = int(changed_vec.sum())
        comm_per_iter.append(changed)
        total += changed
        it += 1
        if on_sweep is not None:
            # Lazy int32 view in original-id order (same contract as the
            # single-device engine): the hook materializes only the
            # snapshots it keeps.
            view = c[:-1].astype(jnp.int32)
            if inv_perm_dev is not None:
                view = view[inv_perm_dev]
            on_sweep(it, view)
        if changed == 0:
            break
        if frontier:
            reach = adj[changed_vec > 0].any(axis=0)
            active = np.asarray(dirty_next) & reach
    coreness = np.asarray(c[:-1]).astype(np.int32)
    if bg.inv_perm is not None:
        coreness = coreness[bg.inv_perm]  # layout order -> original-id order
    return DecomposeResult(
        coreness=coreness,
        iterations=it,
        comm_amount=total,
        comm_per_iter=comm_per_iter,
        peak_bytes=int(peak),
        wall_time_s=time.perf_counter() - t0,
        active_rows_per_iter=active_rows_per_iter,
        rows_per_full_sweep=bg.rows_per_full_sweep,
        collective_bytes_per_iter=collective_bytes_per_iter,
    )


def make_distributed_decompose(plan: MeshPlan, **kw):
    """Adapter: DecomposeFn for :func:`repro.core.dckcore.dc_kcore`."""
    return partial(decompose_distributed, plan=plan, **kw)


def device_external_info(
    g,
    keep_mask: np.ndarray,
    upper_mask: np.ndarray,
    plan: MeshPlan,
    chunk_slots: Optional[int] = None,
    stats=None,
) -> Tuple[np.ndarray, int]:
    """Device-resident E(v) boundary fold: :func:`repro.graph.build.
    external_info` computed on the mesh, plus the collective bytes it moved.

    This is the Montresor message discipline at the part boundary — when a
    part finalizes, the only information its neighbors need is *how many*
    of their neighbors now sit in the finalized upper set, i.e. the E(v)
    increment. The host pipeline folds that with a chunked numpy pass;
    in part-parallel mode the mesh is already holding the graph's working
    set, so each adjacency chunk's slots are sharded over every mesh axis,
    each device counts the contributions of its local slots, and one
    [rows] psum per chunk unions the partial counts — the boundary
    exchange is a collective, never a host round-trip.

    Bit-exactness contract (differentially tested): the returned vector
    equals the host pass at every ``chunk_slots``, because integer
    bincounts are associative across any slot partition; and when
    ``stats`` is given, the bookkeeping numbers mirror the host pass's
    arithmetic exactly (same transient model, priced from the same shapes)
    so checkpointed divide stats cannot reveal which fold ran.

    Returns ``(ext, bytes_moved)``: E(v) per surviving node in
    ``keep_mask`` order, and the per-device ICI bytes of the psums (a
    ``2 (k-1)/k`` ring over the ``k``-device mesh; 0 when ``k == 1``).
    """
    from repro.graph.build import _iter_adjacency_chunks, _resolve_chunk_slots

    keep_mask = np.asarray(keep_mask, dtype=bool)
    upper_mask = np.asarray(upper_mask, dtype=bool)
    n = g.n_nodes
    mesh = plan.mesh
    k = int(mesh.size)
    all_axes = tuple(plan.node_axes) + tuple(plan.slot_axes)
    rep_sh = NamedSharding(mesh, P())
    slot_sh = NamedSharding(mesh, P(all_axes if all_axes else None))
    # Sentinel-padded masks: pad slots point src at a real row (their
    # contribution is masked off by upper_pad[n] = False on the cols side).
    keep_dev = jax.device_put(jnp.asarray(keep_mask), rep_sh)
    upper_dev = jax.device_put(
        jnp.asarray(np.concatenate([upper_mask, [False]])), rep_sh
    )

    @partial(jax.jit, static_argnames=("lo", "rows"))
    def fold_chunk(src_dev, cols_dev, keep, upper, *, lo: int, rows: int):
        def body(src_loc, cols_loc, keep, upper):
            contributes = keep[src_loc] & upper[cols_loc]
            part = jnp.zeros((rows,), jnp.int32).at[src_loc - lo].add(
                contributes.astype(jnp.int32)
            )
            if k > 1:
                part = jax.lax.psum(part, all_axes)
            return part

        return compat_shard_map(
            body,
            mesh=mesh,
            in_specs=(P(all_axes), P(all_axes), P(), P()),
            out_specs=P(),
            check_vma=False,
        )(src_dev, cols_dev, keep, upper)

    ext_full = np.zeros(n, dtype=np.int64)
    budget = _resolve_chunk_slots(chunk_slots)
    # Host-pass transient model, mirrored term for term (see the
    # bit-exactness contract above): persistent = masks + accumulator,
    # per-chunk = int64 src + 2x bool slot masks.
    persistent = keep_mask.nbytes + upper_mask.nbytes + ext_full.nbytes
    contributed = 0
    bytes_moved = 0
    for lo, hi, src, cols in _iter_adjacency_chunks(g, budget):
        src_pad = _pad_to(src.astype(np.int32), k, 0, lo)
        cols_pad = _pad_to(np.asarray(cols, dtype=np.int32), k, 0, n)
        part = fold_chunk(
            jax.device_put(src_pad, slot_sh),
            jax.device_put(cols_pad, slot_sh),
            keep_dev,
            upper_dev,
            lo=int(lo),
            rows=int(hi - lo),
        )
        ext_full[lo:hi] = np.asarray(part)
        if k > 1:
            bytes_moved += int(2 * (k - 1) / k * (hi - lo) * 4)
        if stats is not None:
            stats.n_chunks += 1
            stats.input_slots += int(src.size)
            contributed += int(ext_full[lo:hi].sum())
            stats.bump(persistent + src.nbytes + src.size * 2)
    if stats is not None:
        stats.kept_slots += contributed
        stats.note_pass(2 * g.n_edges, contributed, slot_bytes=9, kept_bytes=8)
    return ext_full[keep_mask].astype(np.int32), bytes_moved
