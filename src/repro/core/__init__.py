"""DC-kCore: divide-and-conquer distributed k-core decomposition (the
paper's contribution) on JAX.

Public API:

* :func:`repro.core.dckcore.dc_kcore` — the divide/conquer/merge pipeline.
* :func:`repro.core.decompose.decompose` — single-device conquer engine.
* :mod:`repro.core.distributed` — multi-device shard_map conquer engine.
* :mod:`repro.core.hindex` — paper Algorithms 1 & 2, vectorized.
* :func:`repro.core.divide.plan_thresholds` — resource-driven divide planner.
"""
from repro.core.dckcore import DCKCoreReport, PartReport, PipelineState, dc_kcore
from repro.core.decompose import DecomposeResult, decompose
from repro.core.divide import plan_thresholds
from repro.core.hindex import hindex_brute, hindex_count, hindex_sorted

__all__ = [
    "dc_kcore",
    "DCKCoreReport",
    "PartReport",
    "PipelineState",
    "decompose",
    "DecomposeResult",
    "plan_thresholds",
    "hindex_sorted",
    "hindex_count",
    "hindex_brute",
]
