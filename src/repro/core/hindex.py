"""H-index operators — paper Algorithms 1 and 2, vectorized.

Algorithm 1 (Montresor et al. node index): given the previous-iteration
estimates of a node's neighbors, the new estimate is the largest ``h`` such
that at least ``h`` neighbors have estimate ``>= h``.

Algorithm 2 (this paper): with external information ``E(v)`` (the count of
neighbors in the already-finalized upper part), the new estimate is
``E(v) + max{ i : at least i in-part neighbors have estimate >= E(v) + i }``.
Algorithm 1 is the special case ``E(v) = 0``.

Two equivalent vectorized forms are provided:

* :func:`hindex_sorted` — sort each row descending and count the all-true
  prefix of ``row[i] >= E + i + 1`` (exactly the paper's loop). O(d log d).
* :func:`hindex_count` — suffix-count form with no sort:
  ``cnt(i) = #{u : c(u) >= E + i}``, answer ``E + max{i : cnt(i) >= i}``.
  O(d^2) work but pure compare-and-reduce — the form the Pallas TPU kernel
  uses (sorting is hostile to the VPU; dense compares are not).

Both operate on padded dense rows where padded slots hold ``-1`` (they never
satisfy any threshold since estimates are >= 0).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def hindex_sorted(neigh_cores: jax.Array, ext: jax.Array) -> jax.Array:
    """Paper Algorithm 2 via descending sort. ``neigh_cores``: [n, d] (-1 pad).

    Returns [n] int32 new estimates.
    """
    n, d = neigh_cores.shape
    cores = jnp.sort(neigh_cores, axis=1)[:, ::-1]  # descending
    i = jnp.arange(d, dtype=neigh_cores.dtype)
    # Paper line 6: while Cores(i) >= E + i + 1 -> i++. New estimate = E + i
    # at the first violation (or E + len if none).
    ok = cores >= (ext[:, None] + i[None, :] + 1)
    prefix = jnp.cumprod(ok.astype(jnp.int32), axis=1).sum(axis=1)
    return (ext + prefix).astype(jnp.int32)


def hindex_count(neigh_cores: jax.Array, ext: jax.Array, cand_chunk: int = 256) -> jax.Array:
    """Paper Algorithm 2 via suffix counts (sort-free, chunked candidates).

    For candidate index i in [1, d]: value = E + i is feasible iff at least i
    neighbors have estimate >= E + i. The answer is E + (largest feasible i).
    Candidates are processed in chunks of ``cand_chunk`` to bound the
    [n, d_chunk] compare footprint (the VMEM budget knob in the kernel).
    """
    n, d = neigh_cores.shape
    best = jnp.zeros((n,), dtype=jnp.int32)
    for lo in range(0, d, cand_chunk):
        w = min(cand_chunk, d - lo)
        i = (lo + 1) + jnp.arange(w, dtype=neigh_cores.dtype)  # [w]
        thr = ext[:, None] + i[None, :]  # [n, w]
        cnt = (neigh_cores[:, :, None] >= thr[:, None, :]).sum(axis=1)  # [n, w]
        feasible = cnt >= i[None, :]
        best_chunk = jnp.max(jnp.where(feasible, i[None, :], 0), axis=1)
        best = jnp.maximum(best, best_chunk.astype(jnp.int32))
    return (ext + best).astype(jnp.int32)


def hindex_of_sequence(values: np.ndarray) -> int:
    """H-index of a host value sequence: max h with at least h values >= h.

    Used as the *candidate-window bound*: per part, no h-index offset ``i``
    can ever be feasible beyond ``hindex_of_sequence(deg + ext)`` — a node
    would need ``i`` neighbors whose estimates (<= deg+ext at all times)
    reach ``ext_v + i >= i``. For ext=0 this is the classic degeneracy bound
    (k_max <= h-index of the degree sequence). This is what lets the Pallas
    kernel and the distributed psum shrink the candidate axis from the
    bucket width to ~k_max with zero loss of exactness.
    """
    v = np.sort(np.asarray(values, dtype=np.int64))[::-1]
    i = np.arange(1, v.size + 1)
    ok = v >= i
    return int(i[ok].max(initial=0))


def hindex_brute(neigh_cores: np.ndarray, ext: int) -> int:
    """Literal transcription of paper Algorithm 2 (scalar; tests only)."""
    cores = sorted([c for c in neigh_cores.tolist() if c >= 0], reverse=True)
    i = 0
    c_v = ext + len(cores)
    while i < len(cores):
        if cores[i] >= ext + i + 1:
            i += 1
        else:
            c_v = ext + i
            break
    return int(c_v)
