"""DC-kCore orchestrator — divide, conquer (sequentially), merge.

Implements the full pipeline of paper Section 4 for an arbitrary number of
parts (Section 5.6 evaluates 2-4):

  1. Sort thresholds descending: ``t_p > ... > t_1``.
  2. For each threshold ``t`` on the *remaining* graph: extract candidates
     (Exact- or Rough-Divide), build the part with its external information,
     decompose it (conquer), and finalize every node whose value is >= ``t``
     (Exact finalizes all by construction). Update ``ext`` of the remaining
     nodes with their freshly-finalized neighbors and shrink the remaining
     graph.
  3. Decompose the final remaining part and finalize everything.
  4. Merge: scatter part coreness back through the id maps.

Parts are processed **sequentially**, so the peak device footprint is the
max over parts instead of the whole graph — the paper's resource story. Per
part we record nodes/edges/iterations/communication/peak bytes/extract and
decompose times, plus the frontier work metric (rows gathered per sweep vs
the always-full-sweep baseline); these power every benchmark table
(Figs 7-11, Table 3) and the work-per-iteration columns.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, List, Optional, Sequence

import numpy as np

from repro.core.decompose import DecomposeResult, decompose
from repro.core.divide import timed_candidates
from repro.graph.build import bucketize, external_info, induced_subgraph
from repro.graph.reorder import bitmap_density, reorder_graph
from repro.graph.structs import BucketedGraph, Graph


@dataclasses.dataclass
class PartReport:
    name: str
    threshold: Optional[int]
    n_nodes: int
    n_edges: int
    iterations: int
    comm_amount: int
    peak_bytes: int
    extract_time_s: float
    decompose_time_s: float
    finalized: int
    # Work metric (active-frontier scheduling): rows actually gathered +
    # h-indexed across all sweeps, vs what always-full sweeps would gather.
    gathered_rows: int = 0
    full_sweep_rows: int = 0
    active_rows_per_iter: List[int] = dataclasses.field(default_factory=list)
    # Measured per-device collective bytes across the part's sweeps (0 for
    # the single-device engine — it issues no collectives).
    collective_bytes: int = 0
    # Fraction of set bits in the part's bucket-adjacency bitmap: how often
    # the static frontier filter could NOT rule out a tile (lower = sparser
    # = locality-aware reordering worked).
    bitmap_density: float = 1.0


@dataclasses.dataclass
class DCKCoreReport:
    parts: List[PartReport]
    total_time_s: float
    preprocess_time_s: float

    @property
    def total_comm(self) -> int:
        return sum(p.comm_amount for p in self.parts)

    @property
    def peak_bytes(self) -> int:
        return max((p.peak_bytes for p in self.parts), default=0)

    @property
    def total_iterations(self) -> int:
        return sum(p.iterations for p in self.parts)

    @property
    def total_gathered_rows(self) -> int:
        """Total sweep work across parts (frontier-scheduled)."""
        return sum(p.gathered_rows for p in self.parts)

    @property
    def total_full_sweep_rows(self) -> int:
        """Work the always-full-sweep schedule would have done."""
        return sum(p.full_sweep_rows for p in self.parts)

    @property
    def total_collective_bytes(self) -> int:
        """Measured per-device collective bytes summed over all parts."""
        return sum(p.collective_bytes for p in self.parts)


DecomposeFn = Callable[[BucketedGraph], DecomposeResult]


def dc_kcore(
    g: Graph,
    thresholds: Sequence[int] = (),
    strategy: str = "rough",
    decompose_fn: Optional[DecomposeFn] = None,
    row_align: int = 8,
    reorder: str = "identity",
    max_bucket_rows="auto",
) -> tuple[np.ndarray, DCKCoreReport]:
    """Run DC-kCore. ``thresholds=()`` degenerates to the monolithic baseline
    (= the PSGraph competitor in the paper's tables).

    ``decompose_fn`` lets callers swap the conquer engine (single-device jit,
    Pallas-kernel, or the distributed shard_map engine) without touching the
    divide/merge logic.

    ``reorder`` (``"identity"`` / ``"bfs"`` / ``"rcm"``) applies a
    locality-aware node ordering to *each part* before bucketizing it: the
    part's tiles then see co-located neighbor ids, the bucket-adjacency
    bitmap gets sparser, and the static frontier filter starts paying off.
    Purely a layout decision — the permutation is carried on the
    ``BucketedGraph`` and the engines report coreness in part-local original
    ids, so divide/merge is untouched. ``max_bucket_rows`` is forwarded to
    :func:`~repro.graph.build.bucketize` (``"auto"`` = the degree-profile
    tile autotuner).
    """
    if decompose_fn is None:
        decompose_fn = lambda bg: decompose(bg)  # noqa: E731
    thresholds = sorted(set(int(t) for t in thresholds), reverse=True)
    t_start = time.time()

    n = g.n_nodes
    coreness = np.full(n, -1, dtype=np.int32)
    finalized = np.zeros(n, dtype=bool)
    # Remaining graph state (original ids).
    ext_full = np.zeros(n, dtype=np.int32)
    remaining_graph = g
    remaining_ids = np.arange(n, dtype=np.int64)  # remaining-local -> original

    parts: List[PartReport] = []
    preprocess = 0.0

    def run_part(part_g: Graph, part_ext: np.ndarray, name: str,
                 threshold: Optional[int], extract_time: float):
        nonlocal preprocess
        t0 = time.time()
        # Reorder the part, not the whole graph: each part is a fresh id
        # space, and locality only has to hold within the tiles actually
        # decomposed together. part_ext stays in part-local original order;
        # bucketize permutes it in and the engine un-permutes coreness out.
        bg = bucketize(reorder_graph(part_g, reorder), ext=part_ext,
                       row_align=row_align, max_bucket_rows=max_bucket_rows)
        preprocess += (time.time() - t0) + extract_time
        return decompose_fn(bg), bitmap_density(bg)

    for t in thresholds:
        cand_mask, extract_time = timed_candidates(remaining_graph, ext_full, t, strategy)
        if not cand_mask.any():
            continue
        t_ext0 = time.time()
        part_g, part_local_ids = induced_subgraph(remaining_graph, cand_mask)
        part_ext = ext_full[cand_mask]
        extract_time += time.time() - t_ext0

        res, density = run_part(part_g, part_ext, f"core>={t}", t, extract_time)

        # Finalize nodes that resolved at >= t (all of them for Exact-Divide).
        final_local = res.coreness >= t
        part_orig_ids = remaining_ids[part_local_ids]
        newly = part_orig_ids[final_local]
        coreness[newly] = res.coreness[final_local]
        finalized[newly] = True

        parts.append(
            PartReport(
                name=f"core>={t}",
                threshold=t,
                n_nodes=part_g.n_nodes,
                n_edges=part_g.n_edges,
                iterations=res.iterations,
                comm_amount=res.comm_amount,
                peak_bytes=res.peak_bytes,
                extract_time_s=extract_time,
                decompose_time_s=res.wall_time_s,
                finalized=int(final_local.sum()),
                gathered_rows=res.gathered_rows,
                full_sweep_rows=res.full_sweep_rows,
                active_rows_per_iter=list(res.active_rows_per_iter),
                collective_bytes=res.collective_bytes,
                bitmap_density=density,
            )
        )

        # Shrink the remaining graph; fold finalized neighbors into ext.
        t_ext0 = time.time()
        newly_mask_local = np.zeros(remaining_graph.n_nodes, dtype=bool)
        newly_mask_local[part_local_ids[final_local]] = True
        keep_local = ~newly_mask_local
        ext_delta = external_info(remaining_graph, keep_local, newly_mask_local)
        new_graph, keep_ids = induced_subgraph(remaining_graph, keep_local)
        ext_full = ext_full[keep_local] + ext_delta
        remaining_ids = remaining_ids[keep_ids]
        remaining_graph = new_graph
        preprocess += time.time() - t_ext0

    # Final (bottom) part: everything left.
    if remaining_graph.n_nodes > 0:
        res, density = run_part(remaining_graph, ext_full, "rest", None, 0.0)
        coreness[remaining_ids] = res.coreness
        parts.append(
            PartReport(
                name="rest",
                threshold=None,
                n_nodes=remaining_graph.n_nodes,
                n_edges=remaining_graph.n_edges,
                iterations=res.iterations,
                comm_amount=res.comm_amount,
                peak_bytes=res.peak_bytes,
                extract_time_s=0.0,
                decompose_time_s=res.wall_time_s,
                finalized=remaining_graph.n_nodes,
                gathered_rows=res.gathered_rows,
                full_sweep_rows=res.full_sweep_rows,
                active_rows_per_iter=list(res.active_rows_per_iter),
                collective_bytes=res.collective_bytes,
                bitmap_density=density,
            )
        )

    report = DCKCoreReport(
        parts=parts,
        total_time_s=time.time() - t_start,
        preprocess_time_s=preprocess,
    )
    assert (coreness >= 0).all(), "merge left unfinalized nodes"
    return coreness, report
