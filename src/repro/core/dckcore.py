"""DC-kCore orchestrator — divide, conquer (sequentially), merge, resume.

Implements the full pipeline of paper Section 4 for an arbitrary number of
parts (Section 5.6 evaluates 2-4):

  1. Sort thresholds descending: ``t_p > ... > t_1``.
  2. For each threshold ``t`` on the *remaining* graph: extract candidates
     (Exact- or Rough-Divide), build the part with its external information,
     decompose it (conquer), and finalize every node whose value is >= ``t``
     (Exact finalizes all by construction). Update ``ext`` of the remaining
     nodes with their freshly-finalized neighbors and shrink the remaining
     graph.
  3. Decompose the final remaining part and finalize everything.
  4. Merge: scatter part coreness back through the id maps.

Parts are processed **sequentially**, so the peak device footprint is the
max over parts instead of the whole graph — the paper's resource story. Per
part we record nodes/edges/iterations/communication/peak bytes/extract and
decompose times, plus the frontier work metric (rows gathered per sweep vs
the always-full-sweep baseline); these power every benchmark table
(Figs 7-11, Table 3) and the work-per-iteration columns.

**Per-part checkpointing.** The paper's headline stability claim (136B
edges, 27.5h runs) only holds if a failed part does not forfeit the parts
already decomposed. The loop state between parts is an explicit
:class:`PipelineState`; with ``checkpoint_dir`` set it is saved atomically
through :func:`repro.ckpt.save_pytree` after every part, and
``resume=True`` re-enters at the first unfinished part:

* the checkpoint holds the *host merge state* — coreness, the finalized
  mask, ``ext`` of the remaining nodes, the remaining-id map, the
  threshold cursor and the per-part reports (JSON extra);
* it deliberately does NOT hold the remaining graph or any device tiles —
  the remaining graph is recomputed from the original graph and the
  finalized mask (induced-subgraph composition is byte-stable), and parts
  rebuild their tiles anyway;
* a killed run leaves at most a ``step_*.tmp`` directory, which restore
  ignores — resume always starts from the last *complete* part boundary
  and reproduces byte-identical coreness (every stage is deterministic).

**Sweep-granularity checkpointing.** A part boundary is a coarse resume
unit — a part at paper scale sweeps for hours. ``sweep_checkpoint_every=k``
saves a :class:`SweepSnapshot` (the conquer engine's estimate vector, fed
by its ``on_sweep`` hook) every ``k`` sweeps through the same atomic
``CheckpointManager`` path under ``<checkpoint_dir>/sweeps``; resume then
re-enters *mid-part* at the last completed sweep via ``init_coreness`` —
the fixed point is exact from any valid upper bound, so the final coreness
stays byte-identical. Stale or half-written snapshots are detected
(cursor/fingerprint/plan/part-size validation) and resume falls back to
the part boundary; snapshots of a finished part are purged at its
boundary save, so disk stays bounded at one state + one snapshot.

**Divide transient.** All extraction passes between parts run chunked
(``divide_chunk`` adjacency slots, default
:data:`~repro.graph.build.DEFAULT_DIVIDE_CHUNK_SLOTS`), so the host
transient of the divide step is bounded by the chunk budget — never by
the edge count — and each part reports its observed peak.
"""
from __future__ import annotations

import dataclasses
import os
import re
import shutil
import time
import zlib
from typing import Callable, Dict, List, Optional, Sequence

import numpy as np

from repro.core.decompose import DecomposeResult, decompose
from repro.core.divide import timed_candidates
from repro.graph.build import (
    DivideStats,
    _resolve_chunk_slots,
    bucketize,
    external_info,
    induced_subgraph,
)
from repro.graph.reorder import bitmap_density, reorder_graph
from repro.graph.structs import BucketedGraph, Graph

STATE_FORMAT = 1
SWEEP_FORMAT = 1


def graph_fingerprint(g: Graph) -> Dict[str, int]:
    """Cheap identity of a graph for checkpoint/resume validation: node and
    edge counts plus a CRC of the degree sequence. O(n), no edge traversal —
    collisions require an identical degree sequence, at which point the
    resume-time remaining-id assertion is the backstop."""
    deg = np.ascontiguousarray(g.degrees, dtype=np.int64)
    return {
        "n_nodes": int(g.n_nodes),
        "n_edges": int(g.n_edges),
        "deg_crc32": int(zlib.crc32(deg.tobytes())),
    }


def _clear_checkpoints(path: str) -> None:
    """Remove every step dir (and half-written .tmp) under ``path`` — a
    fresh run must not leave stale higher-numbered steps from a previous
    run for a later ``resume=True`` to pick up."""
    if not os.path.isdir(path):
        return
    for d in os.listdir(path):
        if re.fullmatch(r"step_\d+(\.tmp)?", d):
            shutil.rmtree(os.path.join(path, d), ignore_errors=True)


@dataclasses.dataclass
class PartReport:
    name: str
    threshold: Optional[int]
    n_nodes: int
    n_edges: int
    iterations: int
    comm_amount: int
    peak_bytes: int
    extract_time_s: float
    decompose_time_s: float
    finalized: int
    # Work metric (active-frontier scheduling): rows actually gathered +
    # h-indexed across all sweeps, vs what always-full sweeps would gather.
    gathered_rows: int = 0
    full_sweep_rows: int = 0
    active_rows_per_iter: List[int] = dataclasses.field(default_factory=list)
    # Measured per-device collective bytes across the part's sweeps (0 for
    # the single-device engine — it issues no collectives).
    collective_bytes: int = 0
    # Fraction of set bits in the part's bucket-adjacency bitmap: how often
    # the static frontier filter could NOT rule out a tile (lower = sparser
    # = locality-aware reordering worked).
    bitmap_density: float = 1.0
    # Wall time of the atomic per-part checkpoint save (0 when disabled).
    save_time_s: float = 0.0
    # Peak transient host bytes of the part's divide passes (candidate
    # extraction + induced subgraph + ext fold + shrink), bounded by the
    # chunk budget — see repro.graph.build.DivideStats.
    divide_transient_bytes: int = 0
    # Sweep number the part's conquer was warm-restarted at from a
    # sweep-granularity snapshot (0 = started from scratch).
    resumed_at_sweep: int = 0


@dataclasses.dataclass
class DCKCoreReport:
    parts: List[PartReport]
    total_time_s: float
    preprocess_time_s: float
    resumed_parts: int = 0  # parts restored from checkpoint, not re-run

    @property
    def total_comm(self) -> int:
        return sum(p.comm_amount for p in self.parts)

    @property
    def peak_bytes(self) -> int:
        return max((p.peak_bytes for p in self.parts), default=0)

    @property
    def total_iterations(self) -> int:
        return sum(p.iterations for p in self.parts)

    @property
    def total_gathered_rows(self) -> int:
        """Total sweep work across parts (frontier-scheduled)."""
        return sum(p.gathered_rows for p in self.parts)

    @property
    def total_full_sweep_rows(self) -> int:
        """Work the always-full-sweep schedule would have done."""
        return sum(p.full_sweep_rows for p in self.parts)

    @property
    def total_collective_bytes(self) -> int:
        """Measured per-device collective bytes summed over all parts."""
        return sum(p.collective_bytes for p in self.parts)

    @property
    def total_save_time_s(self) -> float:
        """Wall time spent in per-part checkpoint saves."""
        return sum(p.save_time_s for p in self.parts)


@dataclasses.dataclass
class PipelineState:
    """Host state of a DC-kCore run at a part boundary — the checkpoint unit.

    ``parts_done`` is the RNG-free cursor: how many thresholds of the
    (descending, deduplicated) plan have been consumed. ``complete`` marks
    that the final "rest" part also finished — a resume of a complete state
    returns the stored result without touching the graph.
    """

    coreness: np.ndarray       # [n] int32, -1 where unfinalized
    finalized: np.ndarray      # [n] bool
    ext_remaining: np.ndarray  # [n_remaining] int32, remaining-local order
    remaining_ids: np.ndarray  # [n_remaining] int64, remaining-local -> orig
    thresholds: List[int]      # the descending plan (consistency-checked)
    fingerprint: Dict[str, int] = dataclasses.field(default_factory=dict)
    parts_done: int = 0
    complete: bool = False
    reports: List[PartReport] = dataclasses.field(default_factory=list)

    @staticmethod
    def fresh(g: Graph, thresholds: Sequence[int]) -> "PipelineState":
        n_nodes = g.n_nodes
        return PipelineState(
            coreness=np.full(n_nodes, -1, dtype=np.int32),
            finalized=np.zeros(n_nodes, dtype=bool),
            ext_remaining=np.zeros(n_nodes, dtype=np.int32),
            remaining_ids=np.arange(n_nodes, dtype=np.int64),
            thresholds=[int(t) for t in thresholds],
            fingerprint=graph_fingerprint(g),
        )

    # -- checkpoint wire format ----------------------------------------- #
    def arrays(self) -> dict:
        """The array pytree saved per part (scalars/reports ride in extra)."""
        return {
            "coreness": self.coreness,
            "finalized": self.finalized,
            "ext_remaining": self.ext_remaining,
            "remaining_ids": self.remaining_ids,
        }

    def extra(self) -> dict:
        return {
            "format": STATE_FORMAT,
            "parts_done": int(self.parts_done),
            "complete": bool(self.complete),
            "thresholds": [int(t) for t in self.thresholds],
            "fingerprint": dict(self.fingerprint),
            "reports": [dataclasses.asdict(p) for p in self.reports],
        }

    def save(self, checkpoint_dir: str) -> float:
        """Atomic save at the current part boundary; returns wall seconds.

        Step number = parts completed so far (the rest part counts one
        past the last threshold), so ``latest_step`` is the cursor. A
        part's own ``save_time_s`` is only known after its save returns,
        so it is persisted one boundary later (the next save serializes
        the updated report); the final part's save cost exists only in the
        live report.

        Restore only ever reads the latest step, so retention is
        ``CheckpointManager(keep=1)``: earlier steps are pruned *after* the
        atomic rename — disk stays bounded at one checkpoint (the state
        arrays are O(n); at paper scale a P-part run must not hold P of
        them). A crash between rename and prune leaves two steps; resume
        still picks the newest."""
        from repro.ckpt import CheckpointManager

        t0 = time.time()
        step = self.parts_done + (1 if self.complete else 0)
        CheckpointManager(checkpoint_dir, keep=1).save(
            self.arrays(), step, extra=self.extra(), blocking=True
        )
        return time.time() - t0

    @staticmethod
    def restore(checkpoint_dir: str, n_nodes: int) -> Optional["PipelineState"]:
        """Latest complete checkpoint under ``checkpoint_dir`` (``None`` if
        there is none — half-written ``step_*.tmp`` dirs are ignored by
        :func:`repro.ckpt.latest_step`)."""
        from repro.ckpt import latest_step, restore_pytree

        if latest_step(checkpoint_dir) is None:
            return None
        template = {
            "coreness": np.zeros(0, np.int32),
            "finalized": np.zeros(0, bool),
            "ext_remaining": np.zeros(0, np.int32),
            "remaining_ids": np.zeros(0, np.int64),
        }
        arrays, _step, extra = restore_pytree(checkpoint_dir, template)
        if extra.get("format") != STATE_FORMAT:
            raise ValueError(
                f"checkpoint format {extra.get('format')!r} != {STATE_FORMAT}"
            )
        if arrays["coreness"].shape[0] != n_nodes:
            raise ValueError(
                f"checkpoint is for a {arrays['coreness'].shape[0]}-node graph, "
                f"got {n_nodes} nodes"
            )
        return PipelineState(
            coreness=arrays["coreness"],
            finalized=arrays["finalized"],
            ext_remaining=arrays["ext_remaining"],
            remaining_ids=arrays["remaining_ids"],
            thresholds=[int(t) for t in extra["thresholds"]],
            fingerprint={k: int(v) for k, v in extra["fingerprint"].items()},
            parts_done=int(extra["parts_done"]),
            complete=bool(extra["complete"]),
            reports=[PartReport(**r) for r in extra["reports"]],
        )


def _sweep_dir(checkpoint_dir: str) -> str:
    return os.path.join(checkpoint_dir, "sweeps")


@dataclasses.dataclass
class SweepSnapshot:
    """Mid-part checkpoint: one conquer sweep's coreness estimates.

    The conquer engines' fixed point is restartable from ANY valid upper
    bound of the true coreness, so a snapshot of the estimate vector taken
    by the ``on_sweep`` hook is a complete mid-part resume point: re-enter
    the part with ``init_coreness=snapshot`` and the remaining sweeps run
    to the same (exact) fixed point — final coreness is byte-identical to
    the uninterrupted run no matter where the crash landed.

    Saved through the same atomic ``CheckpointManager`` path as
    :class:`PipelineState`, under ``<checkpoint_dir>/sweeps`` with the
    sweep number as the step (monotone across crash/resume cycles: a
    resumed part offsets its sweep numbering by the restored snapshot's),
    retention one. A snapshot is only *valid* for the part it was taken in:
    restore checks the pipeline cursor, graph fingerprint, threshold plan
    and part size, and anything stale — a snapshot from an already-finished
    part, another run, or a half-written ``.tmp`` — is ignored, falling
    back to the part-boundary checkpoint. Snapshots of a finished part are
    purged at its boundary save, so disk stays bounded at one snapshot.

    ``coreness`` is numpy int32 in **part-local original-id order** (what
    ``on_sweep`` hands out), so a snapshot taken under one engine, node
    ordering or tile policy restarts correctly under any other.
    """

    coreness: np.ndarray       # [n_part] int32, part-local original order
    parts_done: int            # pipeline cursor when taken
    sweep: int                 # sweep number within the part
    n_part: int
    threshold: Optional[int]   # None for the rest part
    thresholds: List[int]
    fingerprint: Dict[str, int]

    # Step numbering must be monotone across the WHOLE run, not just within
    # a part: CheckpointManager(keep=1) retains the highest-numbered step,
    # so if a later part's snapshots restarted at step 1, one stale
    # higher-numbered snapshot surviving a crash between a boundary save
    # and the sweeps purge would win the GC and silently swallow every new
    # save. parts_done-major, sweep-minor ordering closes that window.
    _PART_STRIDE = 1 << 40

    @property
    def step(self) -> int:
        return self.parts_done * SweepSnapshot._PART_STRIDE + self.sweep

    def save(self, sweep_dir: str) -> float:
        from repro.ckpt import CheckpointManager

        t0 = time.time()
        extra = {
            "format": SWEEP_FORMAT,
            "parts_done": int(self.parts_done),
            "sweep": int(self.sweep),
            "n_part": int(self.n_part),
            "threshold": None if self.threshold is None else int(self.threshold),
            "thresholds": [int(t) for t in self.thresholds],
            "fingerprint": dict(self.fingerprint),
        }
        CheckpointManager(sweep_dir, keep=1).save(
            {"part_coreness": np.asarray(self.coreness, dtype=np.int32)},
            self.step, extra=extra, blocking=True,
        )
        return time.time() - t0

    @staticmethod
    def restore(sweep_dir: str) -> Optional["SweepSnapshot"]:
        """Latest complete snapshot under ``sweep_dir``; ``None`` when there
        is none or it is unreadable/from another format — sweep snapshots
        are an optimization, so a bad one degrades to part-boundary resume
        instead of failing the run."""
        from repro.ckpt import latest_step, restore_pytree

        if latest_step(sweep_dir) is None:
            return None
        try:
            arrays, _step, extra = restore_pytree(
                sweep_dir, {"part_coreness": np.zeros(0, np.int32)}
            )
        except Exception:
            return None
        if extra.get("format") != SWEEP_FORMAT:
            return None
        return SweepSnapshot(
            coreness=arrays["part_coreness"],
            parts_done=int(extra["parts_done"]),
            sweep=int(extra["sweep"]),
            n_part=int(extra["n_part"]),
            threshold=(None if extra["threshold"] is None else int(extra["threshold"])),
            thresholds=[int(t) for t in extra["thresholds"]],
            fingerprint={k: int(v) for k, v in extra["fingerprint"].items()},
        )

    def matches(self, state: "PipelineState", cursor: int,
                n_part: int, threshold: Optional[int]) -> bool:
        """Is this snapshot a resume point for the part about to run?"""
        return (
            self.parts_done == cursor
            and self.n_part == n_part == self.coreness.shape[0]
            and self.threshold == threshold
            and self.thresholds == state.thresholds
            and self.fingerprint == state.fingerprint
        )


# Conquer-engine adapter. Called as ``fn(bg)`` normally; when
# ``dc_kcore(sweep_checkpoint_every=...)`` is set it is called as
# ``fn(bg, init_coreness=..., on_sweep=...)`` — a custom engine must accept
# those kwargs (both built-in engines and make_distributed_decompose do;
# a plain ``lambda bg: ...`` only works without sweep checkpointing).
DecomposeFn = Callable[..., DecomposeResult]
PartHook = Callable[[int, PartReport], None]
SweepSavedHook = Callable[[int, int, float], None]


def dc_kcore(
    g: Graph,
    thresholds: Sequence[int] = (),
    strategy: str = "rough",
    decompose_fn: Optional[DecomposeFn] = None,
    row_align: int = 8,
    reorder: str = "identity",
    max_bucket_rows="auto",
    reorder_sample_edges: Optional[int] = None,
    checkpoint_dir: Optional[str] = None,
    resume: bool = False,
    on_part_done: Optional[PartHook] = None,
    divide_chunk: Optional[int] = None,
    sweep_checkpoint_every: Optional[int] = None,
    on_sweep_saved: Optional[SweepSavedHook] = None,
) -> tuple[np.ndarray, DCKCoreReport]:
    """Run DC-kCore. ``thresholds=()`` degenerates to the monolithic baseline
    (= the PSGraph competitor in the paper's tables).

    ``decompose_fn`` lets callers swap the conquer engine (single-device jit,
    Pallas-kernel, or the distributed shard_map engine) without touching the
    divide/merge logic. With ``sweep_checkpoint_every`` set it is invoked as
    ``decompose_fn(bg, init_coreness=..., on_sweep=...)``, so a custom engine
    must accept those kwargs (see :data:`DecomposeFn`); without the flag it
    is always called as plain ``decompose_fn(bg)``.

    ``reorder`` (``"identity"`` / ``"bfs"`` / ``"rcm"``) applies a
    locality-aware node ordering to *each part* before bucketizing it: the
    part's tiles then see co-located neighbor ids, the bucket-adjacency
    bitmap gets sparser, and the static frontier filter starts paying off.
    Purely a layout decision — the permutation is carried on the
    ``BucketedGraph`` and the engines report coreness in part-local original
    ids, so divide/merge is untouched. ``reorder_sample_edges`` switches the
    ordering computation to the bounded edge-sample variant
    (:func:`~repro.graph.reorder.sampled_order`). ``max_bucket_rows`` is
    forwarded to :func:`~repro.graph.build.bucketize` (``"auto"`` = the
    degree-profile tile autotuner).

    ``divide_chunk`` bounds the divide step's transient host bytes: every
    extraction pass (candidates, induced subgraph, ext fold, shrink — and
    the resume-time remaining-graph rebuild) runs chunked over CSR row
    ranges of at most that many adjacency slots, bit-identical to the
    unchunked result at every chunk size (``None`` = the
    :data:`~repro.graph.build.DEFAULT_DIVIDE_CHUNK_SLOTS` budget — the
    divide transient is *always* bounded; the knob only sizes it). Each
    part's observed peak rides in ``PartReport.divide_transient_bytes``.

    ``checkpoint_dir`` enables per-part checkpointing: the
    :class:`PipelineState` is saved atomically after every part, and
    ``resume=True`` restores the latest complete checkpoint and re-enters at
    the first unfinished part — a killed run resumed this way produces
    coreness **byte-identical** to the uninterrupted run. ``on_part_done``
    (``hook(part_index, report)``) fires after each part's save — the
    fault-injection tests raise from it to simulate a crash at the worst
    moment (state saved, next part not started).

    ``sweep_checkpoint_every=k`` (requires ``checkpoint_dir``) additionally
    saves a :class:`SweepSnapshot` every ``k`` conquer sweeps through the
    same atomic path; ``resume=True`` (with the flag still set) then
    re-enters *mid-part* at the last completed sweep via the engines'
    ``init_coreness`` warm restart — still byte-identical, because the
    fixed point is exact from any snapshot. A stale or unreadable snapshot
    (finished part, other run, half-written ``.tmp``) is ignored and resume
    falls back to the part boundary. ``on_sweep_saved``
    (``hook(part_cursor, sweep, save_seconds)``) fires after each snapshot
    save — the mid-sweep fault-injection tests crash from it.
    """
    if decompose_fn is None:
        decompose_fn = lambda bg, **kw: decompose(bg, **kw)  # noqa: E731
    if resume and checkpoint_dir is None:
        raise ValueError("resume=True requires checkpoint_dir")
    if sweep_checkpoint_every is not None and checkpoint_dir is None:
        raise ValueError("sweep_checkpoint_every requires checkpoint_dir")
    thresholds = sorted(set(int(t) for t in thresholds), reverse=True)
    t_start = time.time()

    n = g.n_nodes
    state: Optional[PipelineState] = None
    resumed_parts = 0
    sweep_dir = _sweep_dir(checkpoint_dir) if checkpoint_dir is not None else None
    pending_snap: Optional[SweepSnapshot] = None
    if resume:
        state = PipelineState.restore(checkpoint_dir, n)
        if sweep_checkpoint_every is not None:
            # Mid-part resume point — consulted even when no part boundary
            # exists yet (a run killed during part 0 leaves only sweep
            # snapshots), and validated against the part it claims to
            # belong to at the moment that part runs.
            pending_snap = SweepSnapshot.restore(sweep_dir)
    if state is None:
        if checkpoint_dir is not None and not resume:
            # Fresh run: purge stale steps (and sweep snapshots) from any
            # previous run in this dir, so a later resume can only see this
            # run's boundaries. A resume that found no boundary keeps the
            # dir as is — snapshot validation screens anything stale.
            _clear_checkpoints(checkpoint_dir)
            _clear_checkpoints(sweep_dir)
        state = PipelineState.fresh(g, thresholds)
        remaining_graph = g
    else:
        if state.fingerprint != graph_fingerprint(g):
            raise ValueError(
                f"checkpoint was written for a different graph "
                f"(fingerprint {state.fingerprint} != {graph_fingerprint(g)})"
            )
        if state.thresholds != thresholds:
            raise ValueError(
                f"checkpoint plans thresholds {state.thresholds}, "
                f"this run asked for {thresholds}"
            )
        resumed_parts = len(state.reports)
        if state.complete:
            report = DCKCoreReport(
                parts=state.reports,
                total_time_s=time.time() - t_start,
                preprocess_time_s=0.0,
                resumed_parts=resumed_parts,
            )
            return state.coreness.copy(), report
        # Rebuild the remaining graph from the original + finalized mask.
        # Induced-subgraph composition is byte-stable (monotone relabeling
        # of a sorted CSR), so this equals the incrementally shrunk graph.
        remaining_graph, keep_ids = induced_subgraph(
            g, ~state.finalized, chunk_slots=divide_chunk
        )
        assert np.array_equal(keep_ids, state.remaining_ids), (
            "checkpoint remaining-id map inconsistent with finalized mask"
        )

    parts: List[PartReport] = state.reports
    preprocess = 0.0

    def run_part(part_g: Graph, part_ext: np.ndarray, name: str,
                 threshold: Optional[int], extract_time: float, cursor: int):
        nonlocal preprocess, pending_snap
        t0 = time.time()
        # Reorder the part, not the whole graph: each part is a fresh id
        # space, and locality only has to hold within the tiles actually
        # decomposed together. part_ext stays in part-local original order;
        # bucketize permutes it in and the engine un-permutes coreness out.
        bg = bucketize(
            reorder_graph(part_g, reorder, sample_edges=reorder_sample_edges),
            ext=part_ext, row_align=row_align, max_bucket_rows=max_bucket_rows,
        )
        init = None
        start_sweep = 0
        if pending_snap is not None:
            if pending_snap.matches(state, cursor, part_g.n_nodes, threshold):
                init = pending_snap.coreness
                start_sweep = pending_snap.sweep
            else:
                # Stale (e.g. a crash landed between a boundary save and
                # the sweeps purge): remove it so it cannot shadow this
                # run's snapshots on a later resume.
                _clear_checkpoints(sweep_dir)
            # One shot either way: a snapshot can only belong to the first
            # part a resumed run executes; anything else is stale.
            pending_snap = None
        hook = None
        if sweep_checkpoint_every is not None:
            every = max(1, int(sweep_checkpoint_every))
            last_saved = {"c": None if init is None else np.asarray(init)}

            def hook(it, coreness, _cursor=cursor, _threshold=threshold,
                     _n=part_g.n_nodes, _start=start_sweep, _last=last_saved):
                if it % every:
                    return
                c = np.asarray(coreness, dtype=np.int32)
                if _last["c"] is not None and np.array_equal(_last["c"], c):
                    return  # fixed point (or no progress): nothing to save
                save_s = SweepSnapshot(
                    coreness=c, parts_done=_cursor, sweep=_start + it,
                    n_part=_n, threshold=_threshold,
                    thresholds=state.thresholds, fingerprint=state.fingerprint,
                ).save(sweep_dir)
                _last["c"] = c
                if on_sweep_saved is not None:
                    on_sweep_saved(_cursor, _start + it, save_s)

        preprocess += (time.time() - t0) + extract_time
        if init is not None or hook is not None:
            res = decompose_fn(bg, init_coreness=init, on_sweep=hook)
        else:
            res = decompose_fn(bg)
        return res, bitmap_density(bg), start_sweep

    def checkpoint_part(report: Optional[PartReport]):
        """Save state at a part boundary, then fire the hook. Sweep
        snapshots of the just-finished part are purged after the boundary
        save (they are stale the moment the boundary exists; a crash
        between save and purge is caught by snapshot validation)."""
        if checkpoint_dir is not None:
            save_s = state.save(checkpoint_dir)
            _clear_checkpoints(sweep_dir)
            if report is not None:
                report.save_time_s = save_s
        if on_part_done is not None and report is not None:
            on_part_done(len(parts) - 1, report)

    for ti in range(state.parts_done, len(thresholds)):
        t = thresholds[ti]
        dstats = DivideStats(chunk_slots=_resolve_chunk_slots(divide_chunk))
        cand_mask, extract_time = timed_candidates(
            remaining_graph, state.ext_remaining, t, strategy,
            chunk_slots=divide_chunk, stats=dstats,
        )
        if not cand_mask.any():
            state.parts_done = ti + 1
            checkpoint_part(None)
            continue
        t_ext0 = time.time()
        part_g, part_local_ids = induced_subgraph(
            remaining_graph, cand_mask, chunk_slots=divide_chunk, stats=dstats
        )
        part_ext = state.ext_remaining[cand_mask]
        extract_time += time.time() - t_ext0

        res, density, start_sweep = run_part(
            part_g, part_ext, f"core>={t}", t, extract_time, ti
        )

        # Finalize nodes that resolved at >= t (all of them for Exact-Divide).
        final_local = res.coreness >= t
        part_orig_ids = state.remaining_ids[part_local_ids]
        newly = part_orig_ids[final_local]
        state.coreness[newly] = res.coreness[final_local]
        state.finalized[newly] = True

        report = PartReport(
            name=f"core>={t}",
            threshold=t,
            n_nodes=part_g.n_nodes,
            n_edges=part_g.n_edges,
            iterations=res.iterations,
            comm_amount=res.comm_amount,
            peak_bytes=res.peak_bytes,
            extract_time_s=extract_time,
            decompose_time_s=res.wall_time_s,
            finalized=int(final_local.sum()),
            gathered_rows=res.gathered_rows,
            full_sweep_rows=res.full_sweep_rows,
            active_rows_per_iter=list(res.active_rows_per_iter),
            collective_bytes=res.collective_bytes,
            bitmap_density=density,
            resumed_at_sweep=start_sweep,
        )
        parts.append(report)

        # Shrink the remaining graph; fold finalized neighbors into ext.
        t_ext0 = time.time()
        newly_mask_local = np.zeros(remaining_graph.n_nodes, dtype=bool)
        newly_mask_local[part_local_ids[final_local]] = True
        keep_local = ~newly_mask_local
        ext_delta = external_info(
            remaining_graph, keep_local, newly_mask_local,
            chunk_slots=divide_chunk, stats=dstats,
        )
        new_graph, keep_ids = induced_subgraph(
            remaining_graph, keep_local, chunk_slots=divide_chunk, stats=dstats
        )
        state.ext_remaining = state.ext_remaining[keep_local] + ext_delta
        state.remaining_ids = state.remaining_ids[keep_ids]
        remaining_graph = new_graph
        preprocess += time.time() - t_ext0
        report.divide_transient_bytes = dstats.peak_transient_bytes

        state.parts_done = ti + 1
        checkpoint_part(report)

    # Final (bottom) part: everything left.
    if remaining_graph.n_nodes > 0:
        res, density, start_sweep = run_part(
            remaining_graph, state.ext_remaining, "rest", None, 0.0,
            len(thresholds),
        )
        state.coreness[state.remaining_ids] = res.coreness
        state.finalized[state.remaining_ids] = True
        report = PartReport(
            name="rest",
            threshold=None,
            n_nodes=remaining_graph.n_nodes,
            n_edges=remaining_graph.n_edges,
            iterations=res.iterations,
            comm_amount=res.comm_amount,
            peak_bytes=res.peak_bytes,
            extract_time_s=0.0,
            decompose_time_s=res.wall_time_s,
            finalized=remaining_graph.n_nodes,
            gathered_rows=res.gathered_rows,
            full_sweep_rows=res.full_sweep_rows,
            active_rows_per_iter=list(res.active_rows_per_iter),
            collective_bytes=res.collective_bytes,
            bitmap_density=density,
            resumed_at_sweep=start_sweep,
        )
        parts.append(report)
        state.remaining_ids = np.zeros(0, dtype=np.int64)
        state.ext_remaining = np.zeros(0, dtype=np.int32)
        state.complete = True
        checkpoint_part(report)
    else:
        state.complete = True
        checkpoint_part(None)

    report = DCKCoreReport(
        parts=parts,
        total_time_s=time.time() - t_start,
        preprocess_time_s=preprocess,
        resumed_parts=resumed_parts,
    )
    assert (state.coreness >= 0).all(), "merge left unfinalized nodes"
    return state.coreness, report
