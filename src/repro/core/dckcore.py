"""DC-kCore orchestrator — a staged divide / conquer / checkpoint pipeline.

Implements the full pipeline of paper Section 4 for an arbitrary number of
parts (Section 5.6 evaluates 2-4):

  1. Sort thresholds descending: ``t_p > ... > t_1``.
  2. For each threshold ``t`` on the *remaining* graph: extract candidates
     (Exact- or Rough-Divide), build the part with its external information,
     decompose it (conquer), and finalize every node whose value is >= ``t``
     (Exact finalizes all by construction). Update ``ext`` of the remaining
     nodes with their freshly-finalized neighbors and shrink the remaining
     graph.
  3. Decompose the final remaining part and finalize everything.
  4. Merge: scatter part coreness back through the id maps.

Parts still *conquer* one at a time, so the peak device footprint is the
max over parts instead of the whole graph — the paper's resource story.
But the loop is organized as three explicit stages per part:

* **divide/prefetch** — candidate selection + the chunked
  ``induced_subgraph`` / ``external_info`` passes plus the part's
  reorder+bucketize. Pure-numpy host work; with ``overlap=True`` a single
  worker thread runs the *next* part's divide (and the shrink of the
  current remaining graph) while the current part sweeps on the device.
* **conquer** — device sweeps through the pluggable engine, with
  sweep-granularity snapshots via the engine's ``on_sweep`` hook.
* **checkpoint** — the part-boundary state save and the sweep snapshots,
  routed through one persistent :class:`~repro.ckpt.CheckpointManager`
  per directory. With ``overlap=True`` these saves are async (the write
  happens on the manager's thread while the next part sweeps); purges go
  through ``CheckpointManager.clear_steps`` which waits out any pending
  save, so a purge can never race an in-flight write.

**Prefetch is speculative — correctness first.** The worker assumes every
candidate of the conquering part finalizes (exact by construction for
Exact-Divide, a bet for Rough-Divide). After the conquer the prediction is
checked against the actual finalized set: on a hit the prefetched shrink
and next-part plan are adopted (byte-identical to the sequential fold,
because every divide pass is deterministic and the masks coincide); on a
miss everything speculative is discarded and recomputed synchronously,
exactly as the sequential path would. ``overlap=True`` therefore changes
wall-clock only — coreness is byte-identical to ``overlap=False``.

**Per-part checkpointing.** The paper's headline stability claim (136B
edges, 27.5h runs) only holds if a failed part does not forfeit the parts
already decomposed. The loop state between parts is an explicit
:class:`PipelineState`; with ``checkpoint_dir`` set it is saved atomically
after every part, and ``resume=True`` re-enters at the first unfinished
part:

* the checkpoint holds the *host merge state* — coreness, the finalized
  mask, ``ext`` of the remaining nodes, the remaining-id map, the
  threshold cursor and the per-part reports (JSON extra);
* it deliberately does NOT hold the remaining graph or any device tiles —
  the remaining graph is recomputed from the original graph and the
  finalized mask (induced-subgraph composition is byte-stable), and parts
  rebuild their tiles anyway;
* a killed run leaves at most a ``step_*.tmp`` directory, which restore
  ignores — resume always starts from the last *complete* part boundary
  and reproduces byte-identical coreness (every stage is deterministic).
  An *async* save that was still in flight at the crash either fully
  landed (write-then-rename) or is ignored as ``.tmp`` — same guarantee.
  When the crash is an exception (the fault-injection tests), the
  pipeline drains pending saves and joins its prefetch worker before
  re-raising, so the on-disk state at "crash" time is deterministic.

**Sweep-granularity checkpointing.** A part boundary is a coarse resume
unit — a part at paper scale sweeps for hours. ``sweep_checkpoint_every=k``
saves a :class:`SweepSnapshot` (the conquer engine's estimate vector, fed
by its ``on_sweep`` hook) every ``k`` sweeps through the same atomic
``CheckpointManager`` path under ``<checkpoint_dir>/sweeps``; resume then
re-enters *mid-part* at the last completed sweep via ``init_coreness`` —
the fixed point is exact from any valid upper bound, so the final coreness
stays byte-identical. Stale or half-written snapshots are detected
(cursor/fingerprint/plan/part-size validation) and resume falls back to
the part boundary; snapshots of a finished part are purged at its
boundary save, so disk stays bounded at one state + one snapshot.

**Divide transient.** All extraction passes between parts run chunked
(``divide_chunk`` adjacency slots, default
:data:`~repro.graph.build.DEFAULT_DIVIDE_CHUNK_SLOTS`), so the host
transient of the divide step is bounded by the chunk budget — never by
the edge count — and each part reports its observed peak. The prefetch
worker uses its own :class:`~repro.graph.build.DivideStats` instance
(folded into the part's via :meth:`DivideStats.merge`), so the worker and
the main thread share no mutable state.
"""
from __future__ import annotations

import concurrent.futures
import dataclasses
import logging
import os
import re
import shutil
import time
import zlib
from typing import Callable, Dict, List, Optional, Sequence

import numpy as np

from repro.core.decompose import DecomposeResult, decompose
from repro.core.divide import timed_candidates
from repro.graph.build import (
    DivideStats,
    _resolve_chunk_slots,
    bucketize,
    external_info,
    induced_subgraph,
)
from repro.graph.reorder import bitmap_density, reorder_graph
from repro.graph.structs import BucketedGraph, Graph

STATE_FORMAT = 1
SWEEP_FORMAT = 1

# The prefetch worker thread carries this name prefix; the test suite
# asserts none outlive a test (a leaked thread = a missing close()).
PREFETCH_THREAD_PREFIX = "dckcore-prefetch"


class MergeIncompleteError(RuntimeError):
    """The final merge left nodes without a coreness value.

    This is the pipeline's last correctness gate (every node must be
    finalized by exactly one part); a bare ``assert`` here would vanish
    under ``python -O`` and let a broken merge return garbage silently.
    """


def graph_fingerprint(g: Graph) -> Dict[str, int]:
    """Cheap identity of a graph for checkpoint/resume validation: node and
    edge counts plus a CRC of the degree sequence. O(n), no edge traversal —
    collisions require an identical degree sequence, at which point the
    resume-time remaining-id assertion is the backstop."""
    deg = np.ascontiguousarray(g.degrees, dtype=np.int64)
    return {
        "n_nodes": int(g.n_nodes),
        "n_edges": int(g.n_edges),
        "deg_crc32": int(zlib.crc32(deg.tobytes())),
    }


def _clear_checkpoints(path: str) -> None:
    """Remove every step dir (half-written ``.tmp`` and quarantined
    ``.corrupt`` included) under ``path`` — a fresh run must not leave
    stale higher-numbered steps from a previous run for a later
    ``resume=True`` to pick up. Only safe when no async save targets
    ``path``; live managers purge via ``clear_steps``."""
    if not os.path.isdir(path):
        return
    for d in os.listdir(path):
        if re.fullmatch(r"step_\d+(\.tmp|\.corrupt)?", d):
            shutil.rmtree(os.path.join(path, d), ignore_errors=True)


@dataclasses.dataclass
class PartReport:
    name: str
    threshold: Optional[int]
    n_nodes: int
    n_edges: int
    iterations: int
    comm_amount: int
    peak_bytes: int
    extract_time_s: float
    decompose_time_s: float
    finalized: int
    # Work metric (active-frontier scheduling): rows actually gathered +
    # h-indexed across all sweeps, vs what always-full sweeps would gather.
    gathered_rows: int = 0
    full_sweep_rows: int = 0
    active_rows_per_iter: List[int] = dataclasses.field(default_factory=list)
    # Measured per-device collective bytes across the part's sweeps (0 for
    # the single-device engine — it issues no collectives).
    collective_bytes: int = 0
    # Fraction of set bits in the part's bucket-adjacency bitmap: how often
    # the static frontier filter could NOT rule out a tile (lower = sparser
    # = locality-aware reordering worked).
    bitmap_density: float = 1.0
    # Seconds the pipeline was BLOCKED on this part's boundary save (the
    # full save on the blocking path; wait-out-previous + host snapshot on
    # the async path). 0 when checkpointing is disabled.
    save_time_s: float = 0.0
    # Wall seconds of the COMPLETED boundary save (write + rename + GC),
    # stamped by the checkpoint manager when the write lands — on the
    # async path this is the honest persistence cost, most of it hidden
    # behind the next part's sweeps.
    save_wall_s: float = 0.0
    # Peak transient host bytes of the part's divide passes (candidate
    # extraction + induced subgraph + ext fold + shrink), bounded by the
    # chunk budget — see repro.graph.build.DivideStats.
    divide_transient_bytes: int = 0
    # Sweep number the part's conquer was warm-restarted at from a
    # sweep-granularity snapshot (0 = started from scratch).
    resumed_at_sweep: int = 0
    # True when this part's divide ran speculatively on the prefetch
    # worker (and the speculation was adopted).
    prefetched: bool = False
    # Part-parallel placement (``dc_kcore(part_parallel=...)``): which mesh
    # slice conquered this part, which wave it ran in, and the scheduler's
    # modeled cost (collective + HBM bytes) that placed it. Defaults mark
    # the sequential path (and keep old checkpoints restorable).
    slice_index: int = -1
    wave: int = -1
    modeled_cost_bytes: int = 0
    # Failed conquer attempts of this part that were retried by the wave
    # executor's fault-tolerance layer (0 on the fail-fast default path).
    retries: int = 0


@dataclasses.dataclass
class DCKCoreReport:
    parts: List[PartReport]
    total_time_s: float
    preprocess_time_s: float
    resumed_parts: int = 0  # parts restored from checkpoint, not re-run
    overlap: bool = False   # divide/checkpoint overlapped with conquer?
    prefetch_hits: int = 0    # speculative shrinks adopted
    prefetch_misses: int = 0  # speculative shrinks discarded + recomputed
    # Part-parallel conquer (0 = sequential): slice count, wall seconds the
    # wave executor was running, per-slice busy seconds (sweep wall summed
    # over the slice's parts), speculative conquers discarded after a
    # mispredicted wave, and the collective bytes the device-resident E(v)
    # boundary folds moved (0 when the fold ran on the host).
    part_parallel: int = 0
    conquer_wall_s: float = 0.0
    slice_busy_s: List[float] = dataclasses.field(default_factory=list)
    speculation_discards: int = 0
    boundary_exchange_bytes: int = 0
    # Fault-tolerance accounting (dc_kcore(slice_timeout_s=/max_retries=)):
    # failed conquer attempts that were retried, slices blacklisted after
    # exhausting their retries (or hanging past the watchdog timeout),
    # waves that finished on fewer slices than planned, checkpoint steps
    # quarantined as corrupt during restore, and the raw event log
    # (retry/blacklist/replan/quarantine entries, in order).
    retries: int = 0
    blacklisted_slices: List[int] = dataclasses.field(default_factory=list)
    degraded_waves: int = 0
    quarantined_steps: int = 0
    fault_events: List[dict] = dataclasses.field(default_factory=list)

    @property
    def total_comm(self) -> int:
        return sum(p.comm_amount for p in self.parts)

    @property
    def peak_bytes(self) -> int:
        return max((p.peak_bytes for p in self.parts), default=0)

    @property
    def total_iterations(self) -> int:
        return sum(p.iterations for p in self.parts)

    @property
    def total_gathered_rows(self) -> int:
        """Total sweep work across parts (frontier-scheduled)."""
        return sum(p.gathered_rows for p in self.parts)

    @property
    def total_full_sweep_rows(self) -> int:
        """Work the always-full-sweep schedule would have done."""
        return sum(p.full_sweep_rows for p in self.parts)

    @property
    def total_collective_bytes(self) -> int:
        """Measured per-device collective bytes summed over all parts."""
        return sum(p.collective_bytes for p in self.parts)

    @property
    def total_save_time_s(self) -> float:
        """Wall time the pipeline was BLOCKED on per-part checkpoint saves
        (the full save cost when saves are blocking; near zero when async)."""
        return sum(p.save_time_s for p in self.parts)

    @property
    def total_save_wall_s(self) -> float:
        """Wall time of the COMPLETED per-part saves — the honest cost of
        persisting, whether or not the pipeline waited for it."""
        return sum(p.save_wall_s for p in self.parts)

    @property
    def total_decompose_time_s(self) -> float:
        """Wall time the conquer engine was actually sweeping."""
        return sum(p.decompose_time_s for p in self.parts)

    @property
    def idle_fraction(self) -> float:
        """Fraction of the run's wall clock the accelerator spent NOT
        sweeping (divide passes, bucketize, checkpoint saves, merge) — the
        stall metric ``overlap=True`` exists to shrink."""
        if self.total_time_s <= 0:
            return 0.0
        return max(0.0, 1.0 - self.total_decompose_time_s / self.total_time_s)

    @property
    def slice_utilization(self) -> List[float]:
        """Per-slice busy fraction of the wave executor's wall clock —
        how evenly the LPT schedule filled the slices (empty when
        sequential)."""
        if self.conquer_wall_s <= 0:
            return [0.0 for _ in self.slice_busy_s]
        return [min(1.0, b / self.conquer_wall_s) for b in self.slice_busy_s]


@dataclasses.dataclass
class PipelineState:
    """Host state of a DC-kCore run at a part boundary — the checkpoint unit.

    ``parts_done`` is the RNG-free cursor: how many thresholds of the
    (descending, deduplicated) plan have been consumed. ``complete`` marks
    that the final "rest" part also finished — a resume of a complete state
    returns the stored result without touching the graph.
    """

    coreness: np.ndarray       # [n] int32, -1 where unfinalized
    finalized: np.ndarray      # [n] bool
    ext_remaining: np.ndarray  # [n_remaining] int32, remaining-local order
    remaining_ids: np.ndarray  # [n_remaining] int64, remaining-local -> orig
    thresholds: List[int]      # the descending plan (consistency-checked)
    fingerprint: Dict[str, int] = dataclasses.field(default_factory=dict)
    parts_done: int = 0
    complete: bool = False
    reports: List[PartReport] = dataclasses.field(default_factory=list)

    @staticmethod
    def fresh(g: Graph, thresholds: Sequence[int]) -> "PipelineState":
        n_nodes = g.n_nodes
        return PipelineState(
            coreness=np.full(n_nodes, -1, dtype=np.int32),
            finalized=np.zeros(n_nodes, dtype=bool),
            ext_remaining=np.zeros(n_nodes, dtype=np.int32),
            remaining_ids=np.arange(n_nodes, dtype=np.int64),
            thresholds=[int(t) for t in thresholds],
            fingerprint=graph_fingerprint(g),
        )

    # -- checkpoint wire format ----------------------------------------- #
    def arrays(self) -> dict:
        """The array pytree saved per part (scalars/reports ride in extra)."""
        return {
            "coreness": self.coreness,
            "finalized": self.finalized,
            "ext_remaining": self.ext_remaining,
            "remaining_ids": self.remaining_ids,
        }

    def extra(self) -> dict:
        return {
            "format": STATE_FORMAT,
            "parts_done": int(self.parts_done),
            "complete": bool(self.complete),
            "thresholds": [int(t) for t in self.thresholds],
            "fingerprint": dict(self.fingerprint),
            "reports": [dataclasses.asdict(p) for p in self.reports],
        }

    def save(
        self,
        checkpoint_dir: str,
        manager=None,
        blocking: bool = True,
        on_done: Optional[Callable[[int, float], None]] = None,
    ) -> float:
        """Atomic save at the current part boundary; returns the wall
        seconds the caller was blocked (the full save when ``blocking``,
        wait-out-previous + host snapshot when async).

        Step number = parts completed so far (the rest part counts one
        past the last threshold), so ``latest_step`` is the cursor. A
        part's own save timings are only known after (or, async, *while*)
        its save runs, so they are persisted one boundary later (the next
        save serializes the updated report); the final part's save cost
        exists only in the live report.

        ``manager`` lets the pipeline reuse one persistent
        :class:`~repro.ckpt.CheckpointManager` (required for async saves —
        something must stay alive to be waited on); without it a throwaway
        blocking manager is used. The previous in-flight save is waited
        out *before* ``extra()`` serializes the reports, so a pending
        ``on_done`` stamping the previous report's completed-save time
        always lands first. Restore reads the newest step that passes
        integrity checks, so retention is the manager's ``retain``
        (default 2): the latest boundary plus one predecessor a corrupted
        latest can fall back to — disk stays bounded at ``retain``
        checkpoints (the state arrays are O(n); at paper scale a P-part
        run must not hold P of them). A crash between rename and prune
        leaves one extra step; resume still picks the newest intact."""
        from repro.ckpt import CheckpointManager

        if manager is None:
            manager = CheckpointManager(checkpoint_dir)
            blocking = True
        t0 = time.perf_counter()
        manager.wait()
        step = self.parts_done + (1 if self.complete else 0)
        manager.save(
            self.arrays(), step, extra=self.extra(),
            blocking=blocking, on_done=on_done,
        )
        return time.perf_counter() - t0

    @staticmethod
    def restore(checkpoint_dir: str, n_nodes: int,
                events: Optional[List[dict]] = None) -> Optional["PipelineState"]:
        """Latest *intact* checkpoint under ``checkpoint_dir`` (``None`` if
        there is none — half-written ``step_*.tmp`` dirs are ignored by
        :func:`repro.ckpt.latest_step`). A corrupt step (CRC mismatch, bit
        rot) is quarantined to ``step_*.corrupt`` and restore falls back
        to the previous retained step; ``events`` (if given) collects one
        ``{"event": "quarantine", ...}`` record per quarantined step for
        the run report."""
        from repro.ckpt import latest_step, restore_pytree_with_fallback

        if latest_step(checkpoint_dir) is None:
            return None
        template = {
            "coreness": np.zeros(0, np.int32),
            "finalized": np.zeros(0, bool),
            "ext_remaining": np.zeros(0, np.int32),
            "remaining_ids": np.zeros(0, np.int64),
        }

        def on_corrupt(step, exc):
            if events is not None:
                events.append({
                    "event": "quarantine", "path": checkpoint_dir,
                    "step": int(step), "error": str(exc),
                })

        try:
            arrays, _step, extra = restore_pytree_with_fallback(
                checkpoint_dir, template, on_corrupt=on_corrupt
            )
        except FileNotFoundError:
            # Every step was corrupt (all quarantined): resume from scratch
            # — the part boundary discipline's last fallback.
            return None
        if extra.get("format") != STATE_FORMAT:
            raise ValueError(
                f"checkpoint format {extra.get('format')!r} != {STATE_FORMAT}"
            )
        if arrays["coreness"].shape[0] != n_nodes:
            raise ValueError(
                f"checkpoint is for a {arrays['coreness'].shape[0]}-node graph, "
                f"got {n_nodes} nodes"
            )
        return PipelineState(
            coreness=arrays["coreness"],
            finalized=arrays["finalized"],
            ext_remaining=arrays["ext_remaining"],
            remaining_ids=arrays["remaining_ids"],
            thresholds=[int(t) for t in extra["thresholds"]],
            fingerprint={k: int(v) for k, v in extra["fingerprint"].items()},
            parts_done=int(extra["parts_done"]),
            complete=bool(extra["complete"]),
            reports=[PartReport(**r) for r in extra["reports"]],
        )


def _sweep_dir(checkpoint_dir: str) -> str:
    return os.path.join(checkpoint_dir, "sweeps")


@dataclasses.dataclass
class SweepSnapshot:
    """Mid-part checkpoint: one conquer sweep's coreness estimates.

    The conquer engines' fixed point is restartable from ANY valid upper
    bound of the true coreness, so a snapshot of the estimate vector taken
    by the ``on_sweep`` hook is a complete mid-part resume point: re-enter
    the part with ``init_coreness=snapshot`` and the remaining sweeps run
    to the same (exact) fixed point — final coreness is byte-identical to
    the uninterrupted run no matter where the crash landed.

    Saved through the same atomic ``CheckpointManager`` path as
    :class:`PipelineState`, under ``<checkpoint_dir>/sweeps`` with the
    sweep number as the step (monotone across crash/resume cycles: a
    resumed part offsets its sweep numbering by the restored snapshot's),
    retention = the manager's ``retain`` (default 2, so a corrupt latest
    snapshot falls back to its predecessor — any snapshot is a valid
    upper bound, so an older one is merely a slower resume point, never a
    wrong one). A snapshot is only *valid* for the part it was taken in:
    restore checks the pipeline cursor, graph fingerprint, threshold plan
    and part size, and anything stale — a snapshot from an already-finished
    part, another run, or a half-written ``.tmp`` — is ignored, falling
    back to the part-boundary checkpoint. Snapshots of a finished part are
    purged at its boundary save, so disk stays bounded at one snapshot.

    ``coreness`` is numpy int32 in **part-local original-id order** (what
    ``on_sweep`` hands out), so a snapshot taken under one engine, node
    ordering or tile policy restarts correctly under any other.
    """

    coreness: np.ndarray       # [n_part] int32, part-local original order
    parts_done: int            # pipeline cursor when taken
    sweep: int                 # sweep number within the part
    n_part: int
    threshold: Optional[int]   # None for the rest part
    thresholds: List[int]
    fingerprint: Dict[str, int]

    # Step numbering must be monotone across the WHOLE run, not just within
    # a part: the CheckpointManager retains the highest-numbered steps,
    # so if a later part's snapshots restarted at step 1, one stale
    # higher-numbered snapshot surviving a crash between a boundary save
    # and the sweeps purge would win the GC and silently swallow every new
    # save. parts_done-major, sweep-minor ordering closes that window.
    _PART_STRIDE = 1 << 40

    @property
    def step(self) -> int:
        return self.parts_done * SweepSnapshot._PART_STRIDE + self.sweep

    def save(
        self,
        sweep_dir: str,
        manager=None,
        blocking: bool = True,
        on_done: Optional[Callable[[int, float], None]] = None,
    ) -> float:
        """Save the snapshot; returns seconds the caller was blocked.

        ``manager`` reuses a persistent :class:`CheckpointManager` (the
        overlapped pipeline's async path — the save runs on the manager's
        thread while the part keeps sweeping); without it a throwaway
        blocking manager is used."""
        from repro.ckpt import CheckpointManager

        if manager is None:
            manager = CheckpointManager(sweep_dir)
            blocking = True
        t0 = time.perf_counter()
        extra = {
            "format": SWEEP_FORMAT,
            "parts_done": int(self.parts_done),
            "sweep": int(self.sweep),
            "n_part": int(self.n_part),
            "threshold": None if self.threshold is None else int(self.threshold),
            "thresholds": [int(t) for t in self.thresholds],
            "fingerprint": dict(self.fingerprint),
        }
        manager.save(
            {"part_coreness": np.asarray(self.coreness, dtype=np.int32)},
            self.step, extra=extra, blocking=blocking, on_done=on_done,
        )
        return time.perf_counter() - t0

    @staticmethod
    def restore(sweep_dir: str,
                events: Optional[List[dict]] = None) -> Optional["SweepSnapshot"]:
        """Latest intact snapshot under ``sweep_dir``; ``None`` when there
        is none or it is unreadable/from another format — sweep snapshots
        are an optimization, so a bad one degrades to part-boundary resume
        instead of failing the run. A *corrupt* snapshot (CRC mismatch) is
        quarantined to ``.corrupt`` and the previous retained one is tried
        first — any snapshot is a valid upper bound, so falling back one
        step is still an exact resume point. The degradation is logged
        (one line, path + reason) so a resume that unexpectedly fell back
        to the part boundary is diagnosable; ``events`` collects one
        quarantine record per corrupt step."""
        from repro.ckpt import latest_step, restore_pytree_with_fallback

        if latest_step(sweep_dir) is None:
            return None

        def on_corrupt(step, exc):
            if events is not None:
                events.append({
                    "event": "quarantine", "path": sweep_dir,
                    "step": int(step), "error": str(exc),
                })

        try:
            arrays, _step, extra = restore_pytree_with_fallback(
                sweep_dir, {"part_coreness": np.zeros(0, np.int32)},
                on_corrupt=on_corrupt,
            )
        except FileNotFoundError:
            return None  # every snapshot corrupt — part-boundary resume
        except Exception as exc:
            logging.getLogger(__name__).warning(
                "sweep snapshot %s unreadable (%s: %s) — resuming from the "
                "part boundary instead", sweep_dir, type(exc).__name__, exc,
            )
            return None
        if extra.get("format") != SWEEP_FORMAT:
            logging.getLogger(__name__).warning(
                "sweep snapshot %s has format %r (expected %r) — resuming "
                "from the part boundary instead",
                sweep_dir, extra.get("format"), SWEEP_FORMAT,
            )
            return None
        return SweepSnapshot(
            coreness=arrays["part_coreness"],
            parts_done=int(extra["parts_done"]),
            sweep=int(extra["sweep"]),
            n_part=int(extra["n_part"]),
            threshold=(None if extra["threshold"] is None else int(extra["threshold"])),
            thresholds=[int(t) for t in extra["thresholds"]],
            fingerprint={k: int(v) for k, v in extra["fingerprint"].items()},
        )

    def matches(self, state: "PipelineState", cursor: int,
                n_part: int, threshold: Optional[int]) -> bool:
        """Is this snapshot a resume point for the part about to run?"""
        return (
            self.parts_done == cursor
            and self.n_part == n_part == self.coreness.shape[0]
            and self.threshold == threshold
            and self.thresholds == state.thresholds
            and self.fingerprint == state.fingerprint
        )


# Conquer-engine adapter. Called as ``fn(bg)`` normally; when
# ``dc_kcore(sweep_checkpoint_every=...)`` is set it is called as
# ``fn(bg, init_coreness=..., on_sweep=...)`` — a custom engine must accept
# those kwargs (both built-in engines and make_distributed_decompose do;
# a plain ``lambda bg: ...`` only works without sweep checkpointing).
DecomposeFn = Callable[..., DecomposeResult]
PartHook = Callable[[int, PartReport], None]
SweepSavedHook = Callable[[int, int, float], None]


@dataclasses.dataclass
class PartPlan:
    """Divide-stage output: everything the conquer stage needs for one part.

    ``threshold is None`` marks the final "rest" part (everything left,
    no candidate mask). ``part_g is None`` marks an *empty* threshold part
    (no candidates at this threshold — the cursor advances, nothing runs).
    ``speculative`` records that the plan was built by the prefetch worker
    on the *predicted* remaining graph; it is only ever executed after the
    prediction was validated.
    """

    cursor: int
    name: str
    threshold: Optional[int]
    part_g: Optional[Graph]
    part_local_ids: Optional[np.ndarray]
    part_ext: Optional[np.ndarray]
    cand_mask: Optional[np.ndarray]
    dstats: DivideStats
    extract_time_s: float
    bg: Optional[BucketedGraph] = None
    bucketize_time_s: float = 0.0
    speculative: bool = False

    @property
    def is_rest(self) -> bool:
        return self.threshold is None

    @property
    def is_empty(self) -> bool:
        return self.part_g is None


@dataclasses.dataclass
class _Prefetch:
    """Prefetch-worker output: the speculative shrink of the remaining
    graph (assuming every candidate of part ``base_cursor`` finalizes)
    plus, when there is one, the next part's plan built on that shrink."""

    base_cursor: int
    shrink_graph: Graph
    shrink_keep_ids: np.ndarray   # remaining-local ids kept by the shrink
    ext_next: np.ndarray          # ext of the kept nodes after the fold
    shrink_stats: DivideStats
    shrink_time_s: float
    plan: Optional[PartPlan] = None


class _PartPipeline:
    """The staged scheduler behind :func:`dc_kcore`.

    One instance per run. The main thread owns ``state`` and the conquer
    stage; the (optional, single) prefetch worker only ever READS the
    graph/ext snapshots passed to it at submit time — the main thread
    rebinds ``state.ext_remaining`` / ``state.remaining_ids`` /
    ``self.remaining_graph`` to fresh arrays instead of mutating them, so
    a worker holding the old references is always safe. Checkpoint I/O
    lives on the two persistent managers; ``close()`` drains both and
    joins the worker on every exit path (success or crash), which is what
    makes the fault-injection tests deterministic.
    """

    def __init__(
        self, *,
        state: PipelineState,
        remaining_graph: Graph,
        thresholds: List[int],
        strategy: str,
        decompose_fn: DecomposeFn,
        row_align: int,
        reorder: str,
        max_bucket_rows,
        reorder_sample_edges: Optional[int],
        checkpoint_dir: Optional[str],
        sweep_dir: Optional[str],
        divide_chunk: Optional[int],
        sweep_checkpoint_every: Optional[int],
        on_part_done: Optional[PartHook],
        on_sweep_saved: Optional[SweepSavedHook],
        overlap: bool,
        pending_snap: Optional[SweepSnapshot],
        state_mgr=None,
        sweeps_mgr=None,
        part_parallel: Optional[int] = None,
        slice_decomposes: Optional[List[DecomposeFn]] = None,
        slice_specs: Optional[list] = None,
        fold_plan=None,
        watchdog=None,
        fault_plan=None,
    ):
        self.state = state
        self.remaining_graph = remaining_graph
        self.thresholds = thresholds
        self.strategy = strategy
        self.decompose_fn = decompose_fn
        self.row_align = row_align
        self.reorder = reorder
        self.max_bucket_rows = max_bucket_rows
        self.reorder_sample_edges = reorder_sample_edges
        self.checkpoint_dir = checkpoint_dir
        self.sweep_dir = sweep_dir
        self.divide_chunk = divide_chunk
        self.sweep_checkpoint_every = sweep_checkpoint_every
        self.on_part_done = on_part_done
        self.on_sweep_saved = on_sweep_saved
        self.overlap = overlap
        self.pending_snap = pending_snap
        self.state_mgr = state_mgr
        self.sweeps_mgr = sweeps_mgr

        # Part-parallel conquer: slice count, one DecomposeFn per mesh
        # slice (None = every slice thread shares ``decompose_fn``), the
        # pure SliceSpecs the scheduler prices against, and the GLOBAL
        # MeshPlan routing the E(v) boundary fold through the device
        # collectives (None = host fold).
        self.part_parallel = part_parallel
        self.slice_decomposes = slice_decomposes
        self.slice_specs = slice_specs
        self.fold_plan = fold_plan
        self.slice_busy_s = [0.0] * (part_parallel or 0)
        self.conquer_wall_s = 0.0
        self.boundary_exchange_bytes = 0
        self.speculation_discards = 0
        self._wave_index = 0

        # Fault tolerance: the wave watchdog config (None = fail-fast, the
        # historical semantics), the chaos-injection plan consulted at the
        # named sites, slices blacklisted so far (they stay dead for the
        # rest of the run — wave width shrinks S -> S-1 -> ... -> 1), and
        # the accumulated retry/blacklist/replan event accounting.
        self.watchdog = watchdog
        self.fault_plan = fault_plan
        self.blacklisted: set = set()
        self.retries = 0
        self.replans = 0
        self.degraded_waves = 0
        self.fault_events: List[dict] = []

        self.parts: List[PartReport] = state.reports
        self.preprocess_time_s = 0.0
        self.prefetch_hits = 0
        self.prefetch_misses = 0
        self._future: Optional[concurrent.futures.Future] = None
        self._executor: Optional[concurrent.futures.ThreadPoolExecutor] = None
        if overlap:
            self._executor = concurrent.futures.ThreadPoolExecutor(
                max_workers=1, thread_name_prefix=PREFETCH_THREAD_PREFIX
            )

    def _visit_fault(self, site: str, **ctx) -> None:
        """Chaos hook: consult the fault plan at a named site (no-op
        without one). Faults at main-thread sites (``boundary_fold``,
        ``checkpoint_save``, ``prefetch``) are fail-fast — they kill the
        run like a real crash would, and recovery is the resume path;
        only ``slice_conquer`` faults (visited inside the wave executor)
        are retried/re-planned in-run."""
        if self.fault_plan is not None:
            self.fault_plan.visit(site, **ctx)

    # ---------------- divide stage ---------------- #
    def _fresh_stats(self) -> DivideStats:
        return DivideStats(chunk_slots=_resolve_chunk_slots(self.divide_chunk))

    def _plan_on(self, graph: Graph, ext: np.ndarray, cursor: int,
                 speculative: bool = False) -> Optional[PartPlan]:
        """Divide: plan the part at ``cursor`` on ``graph``/``ext``. Pure —
        runs on either the main thread (synchronous path) or the prefetch
        worker (``speculative=True``, on the predicted shrink)."""
        if cursor < len(self.thresholds):
            t = self.thresholds[cursor]
            dstats = self._fresh_stats()
            cand_mask, extract_time = timed_candidates(
                graph, ext, t, self.strategy,
                chunk_slots=self.divide_chunk, stats=dstats,
            )
            if not cand_mask.any():
                return PartPlan(
                    cursor=cursor, name=f"core>={t}", threshold=t,
                    part_g=None, part_local_ids=None, part_ext=None,
                    cand_mask=cand_mask, dstats=dstats,
                    extract_time_s=extract_time, speculative=speculative,
                )
            t0 = time.perf_counter()
            part_g, part_local_ids = induced_subgraph(
                graph, cand_mask, chunk_slots=self.divide_chunk, stats=dstats
            )
            part_ext = ext[cand_mask]
            extract_time += time.perf_counter() - t0
            return PartPlan(
                cursor=cursor, name=f"core>={t}", threshold=t,
                part_g=part_g, part_local_ids=part_local_ids,
                part_ext=part_ext, cand_mask=cand_mask, dstats=dstats,
                extract_time_s=extract_time, speculative=speculative,
            )
        # Final (bottom) part: everything left.
        if graph.n_nodes == 0:
            return None
        return PartPlan(
            cursor=cursor, name="rest", threshold=None,
            part_g=graph, part_local_ids=None, part_ext=ext,
            cand_mask=None, dstats=self._fresh_stats(),
            extract_time_s=0.0, speculative=speculative,
        )

    def _build_plan(self, cursor: int) -> Optional[PartPlan]:
        """Synchronous divide on the CURRENT remaining graph."""
        return self._plan_on(
            self.remaining_graph, self.state.ext_remaining, cursor
        )

    def _bucketize(self, plan: PartPlan) -> None:
        """Reorder + bucketize the part — the device-layout half of the
        divide stage (prefetched plans arrive with ``bg`` already built)."""
        if plan.bg is not None or plan.part_g is None:
            return
        t0 = time.perf_counter()
        # Reorder the part, not the whole graph: each part is a fresh id
        # space, and locality only has to hold within the tiles actually
        # decomposed together. part_ext stays in part-local original order;
        # bucketize permutes it in and the engine un-permutes coreness out.
        plan.bg = bucketize(
            reorder_graph(
                plan.part_g, self.reorder,
                sample_edges=self.reorder_sample_edges,
            ),
            ext=plan.part_ext, row_align=self.row_align,
            max_bucket_rows=self.max_bucket_rows,
        )
        plan.bucketize_time_s = time.perf_counter() - t0

    # ---------------- prefetch stage ---------------- #
    def _submit_prefetch(self, plan: PartPlan) -> None:
        """Speculate past ``plan``'s conquer on the worker thread: shrink
        the remaining graph as if EVERY candidate finalizes (exact by
        construction for Exact-Divide, a bet for Rough) and build the next
        part's plan on the predicted shrink. The worker gets the current
        array references; the main thread only ever rebinds them."""
        if self._executor is None or plan.is_rest or plan.is_empty:
            return
        assert self._future is None, "a prefetch is already in flight"
        self._future = self._executor.submit(
            self._prefetch_task,
            self.remaining_graph, self.state.ext_remaining,
            plan.cand_mask, plan.cursor,
        )

    def _fold_external(self, graph: Graph, keep_local: np.ndarray,
                       upper_local: np.ndarray, stats: DivideStats) -> np.ndarray:
        """E(v) boundary fold — host pass, or device collectives when the
        pipeline holds a global mesh plan (part-parallel distributed mode).
        Bit-identical either way (differentially tested); the device path
        additionally accounts its psum bytes. Only ever called from the
        thread that owns ``stats`` — the byte counter is main-thread-only
        because the prefetch worker never runs with a fold plan (overlap
        and part_parallel are mutually exclusive)."""
        self._visit_fault("boundary_fold", n_nodes=int(graph.n_nodes))
        if self.fold_plan is not None:
            from repro.core.distributed import device_external_info

            delta, moved = device_external_info(
                graph, keep_local, upper_local, self.fold_plan,
                chunk_slots=self.divide_chunk, stats=stats,
            )
            self.boundary_exchange_bytes += moved
            return delta
        return external_info(
            graph, keep_local, upper_local,
            chunk_slots=self.divide_chunk, stats=stats,
        )

    def _speculative_shrink(self, graph: Graph, ext: np.ndarray,
                            cand_mask: np.ndarray, cursor: int) -> _Prefetch:
        """Shrink ``graph`` as if EVERY candidate of part ``cursor``
        finalizes — the shared speculation body of the overlap prefetch
        (depth 1, worker thread) and the part-parallel wave planner
        (depth ``part_parallel``, main thread)."""
        t0 = time.perf_counter()
        stats = self._fresh_stats()
        keep_local = ~cand_mask
        ext_delta = self._fold_external(graph, keep_local, cand_mask, stats)
        shrink_graph, keep_ids = induced_subgraph(
            graph, keep_local, chunk_slots=self.divide_chunk, stats=stats
        )
        ext_next = ext[keep_local] + ext_delta
        return _Prefetch(
            base_cursor=cursor, shrink_graph=shrink_graph,
            shrink_keep_ids=keep_ids, ext_next=ext_next,
            shrink_stats=stats, shrink_time_s=time.perf_counter() - t0,
        )

    def _prefetch_task(self, graph: Graph, ext: np.ndarray,
                       cand_mask: np.ndarray, cursor: int) -> _Prefetch:
        self._visit_fault("prefetch", cursor=cursor)
        pf = self._speculative_shrink(graph, ext, cand_mask, cursor)
        pf.plan = self._plan_on(
            pf.shrink_graph, pf.ext_next, cursor + 1, speculative=True
        )
        if pf.plan is not None:
            self._bucketize(pf.plan)
        return pf

    def _take_prefetch(self, cursor: int) -> Optional[_Prefetch]:
        """Join the in-flight prefetch (if any). Worker failures re-raise
        here — a broken divide pass is a real failure, not a missed bet."""
        if self._future is None:
            return None
        fut, self._future = self._future, None
        pf = fut.result()
        return pf if pf.base_cursor == cursor else None

    # ---------------- conquer stage ---------------- #
    def _conquer(self, plan: PartPlan, fn: Optional[DecomposeFn] = None,
                 lead: bool = True, account: bool = True, heartbeat=None):
        """Conquer one part. ``fn`` overrides the engine (a wave slice's
        decompose); ``lead=False`` (a wave's non-first parts) skips the
        pending-snapshot consult and the sweep-snapshot hook — only the
        part the boundary checkpoint actually points at may write
        snapshots, so a crashed wave leaves exactly the disk state a
        sequential run crashed in that part would. ``account=False``
        defers the preprocess-time accounting to the caller (the wave
        runner books it on the main thread — slice threads must not race
        on the counter). ``heartbeat`` (watchdog mode) is a zero-arg
        liveness callable composed into the engine's ``on_sweep`` hook —
        progress = sweep count, exactly what the watchdog times out on."""
        state = self.state
        t0 = time.perf_counter()
        init = None
        start_sweep = 0
        if lead and self.pending_snap is not None:
            snap = self.pending_snap
            if snap.matches(state, plan.cursor, plan.part_g.n_nodes,
                            plan.threshold):
                init = snap.coreness
                start_sweep = snap.sweep
            else:
                # Stale (e.g. a crash landed between a boundary save and
                # the sweeps purge): remove it so it cannot shadow this
                # run's snapshots on a later resume.
                self._purge_sweeps()
            # One shot either way: a snapshot can only belong to the first
            # part a resumed run executes; anything else is stale.
            self.pending_snap = None
        hook = None
        if lead and self.sweep_checkpoint_every is not None:
            every = max(1, int(self.sweep_checkpoint_every))
            last_saved = {"c": None if init is None else np.asarray(init)}

            def hook(it, coreness, _cursor=plan.cursor,
                     _threshold=plan.threshold, _n=plan.part_g.n_nodes,
                     _start=start_sweep, _last=last_saved):
                if it % every:
                    return
                c = np.asarray(coreness, dtype=np.int32)
                if _last["c"] is not None and np.array_equal(_last["c"], c):
                    return  # fixed point (or no progress): nothing to save
                save_s = SweepSnapshot(
                    coreness=c, parts_done=_cursor, sweep=_start + it,
                    n_part=_n, threshold=_threshold,
                    thresholds=state.thresholds,
                    fingerprint=state.fingerprint,
                ).save(
                    self.sweep_dir, manager=self.sweeps_mgr,
                    blocking=not self.overlap,
                )
                _last["c"] = c
                if self.on_sweep_saved is not None:
                    self.on_sweep_saved(_cursor, _start + it, save_s)

        if heartbeat is not None:
            inner = hook

            def hook(it, coreness, _inner=inner):
                heartbeat()
                if _inner is not None:
                    _inner(it, coreness)

        if account:
            self.preprocess_time_s += (
                (time.perf_counter() - t0) + plan.bucketize_time_s + plan.extract_time_s
            )
        fn = fn if fn is not None else self.decompose_fn
        if init is not None or hook is not None:
            res = fn(plan.bg, init_coreness=init, on_sweep=hook)
        else:
            res = fn(plan.bg)
        return res, bitmap_density(plan.bg), start_sweep

    # ---------------- merge + shrink ---------------- #
    def _report_for(self, plan: PartPlan, res, density: float,
                    start_sweep: int, finalized: int) -> PartReport:
        return PartReport(
            name=plan.name,
            threshold=plan.threshold,
            n_nodes=plan.part_g.n_nodes,
            n_edges=plan.part_g.n_edges,
            iterations=res.iterations,
            comm_amount=res.comm_amount,
            peak_bytes=res.peak_bytes,
            extract_time_s=plan.extract_time_s,
            decompose_time_s=res.wall_time_s,
            finalized=finalized,
            gathered_rows=res.gathered_rows,
            full_sweep_rows=res.full_sweep_rows,
            active_rows_per_iter=list(res.active_rows_per_iter),
            collective_bytes=res.collective_bytes,
            bitmap_density=density,
            resumed_at_sweep=start_sweep,
            prefetched=plan.speculative,
        )

    def _finalize_threshold(self, plan: PartPlan, res, density: float,
                            start_sweep: int):
        """Merge a threshold part's result into the global state and
        append its report (before the shrink — matching the report order
        the checkpoints have always serialized)."""
        state = self.state
        # Finalize nodes that resolved at >= t (all of them for Exact-Divide).
        final_local = res.coreness >= plan.threshold
        part_orig_ids = state.remaining_ids[plan.part_local_ids]
        newly = part_orig_ids[final_local]
        state.coreness[newly] = res.coreness[final_local]
        state.finalized[newly] = True
        report = self._report_for(
            plan, res, density, start_sweep, int(final_local.sum())
        )
        self.parts.append(report)
        return report, final_local

    def _shrink(self, plan: PartPlan, final_local: np.ndarray,
                report: PartReport) -> Optional[PartPlan]:
        """Fold the finalized nodes out of the remaining graph. Adopts the
        speculative shrink when the prediction held (byte-identical: the
        masks coincide and every divide pass is deterministic); otherwise
        discards it and recomputes synchronously, exactly as the
        sequential path. Returns the prefetched next plan on a hit."""
        pf = self._take_prefetch(plan.cursor)
        if pf is not None and bool(final_local.all()):
            self.prefetch_hits += 1
            self._adopt_shrink(plan, pf, report)
            return pf.plan
        if pf is not None:
            self.prefetch_misses += 1
        self._shrink_sync(plan, final_local, report)
        return None

    def _adopt_shrink(self, plan: PartPlan, pf: _Prefetch,
                      report: PartReport) -> None:
        """Adopt a validated speculative shrink (prediction held — the
        masks coincide, so this state is byte-identical to the sync fold)."""
        state = self.state
        plan.dstats.merge(pf.shrink_stats)
        state.ext_remaining = pf.ext_next
        state.remaining_ids = state.remaining_ids[pf.shrink_keep_ids]
        self.remaining_graph = pf.shrink_graph
        self.preprocess_time_s += pf.shrink_time_s
        report.divide_transient_bytes = plan.dstats.peak_transient_bytes

    def _shrink_sync(self, plan: PartPlan, final_local: np.ndarray,
                     report: PartReport) -> None:
        """The sequential fold: shrink the remaining graph by the part's
        ACTUALLY finalized nodes."""
        state = self.state
        t0 = time.perf_counter()
        newly_mask_local = np.zeros(self.remaining_graph.n_nodes, dtype=bool)
        newly_mask_local[plan.part_local_ids[final_local]] = True
        keep_local = ~newly_mask_local
        ext_delta = self._fold_external(
            self.remaining_graph, keep_local, newly_mask_local, plan.dstats
        )
        new_graph, keep_ids = induced_subgraph(
            self.remaining_graph, keep_local,
            chunk_slots=self.divide_chunk, stats=plan.dstats,
        )
        state.ext_remaining = state.ext_remaining[keep_local] + ext_delta
        state.remaining_ids = state.remaining_ids[keep_ids]
        self.remaining_graph = new_graph
        self.preprocess_time_s += time.perf_counter() - t0
        report.divide_transient_bytes = plan.dstats.peak_transient_bytes

    def _merge_rest(self, plan: PartPlan, res, density: float,
                    start_sweep: int, annotate=None) -> None:
        state = self.state
        state.coreness[state.remaining_ids] = res.coreness
        state.finalized[state.remaining_ids] = True
        report = self._report_for(
            plan, res, density, start_sweep, plan.part_g.n_nodes
        )
        if annotate is not None:
            annotate(report)  # wave/slice stamps, before the report is saved
        self.parts.append(report)
        state.remaining_ids = np.zeros(0, dtype=np.int64)
        state.ext_remaining = np.zeros(0, dtype=np.int32)
        state.complete = True
        self._checkpoint_boundary(report)

    # ---------------- checkpoint stage ---------------- #
    def _purge_sweeps(self) -> None:
        if self.sweep_dir is None:
            return
        if self.sweeps_mgr is not None:
            # Waits out a pending async snapshot save first — the purge
            # can never shred a write in flight.
            self.sweeps_mgr.clear_steps()
        else:
            _clear_checkpoints(self.sweep_dir)

    def _checkpoint_boundary(self, report: Optional[PartReport]) -> None:
        """Save state at a part boundary, then fire the hook. Sweep
        snapshots of the just-finished part are purged after the boundary
        save (they are stale the moment the boundary exists; a crash
        between save and purge is caught by snapshot validation)."""
        if self.checkpoint_dir is not None:
            self._visit_fault("checkpoint_save",
                              parts_done=int(self.state.parts_done))
            on_done = None
            if report is not None:
                def on_done(_step, secs, _r=report):
                    _r.save_wall_s = secs
            blocked = self.state.save(
                self.checkpoint_dir, manager=self.state_mgr,
                blocking=not self.overlap, on_done=on_done,
            )
            self._purge_sweeps()
            if report is not None:
                report.save_time_s = blocked
        if self.on_part_done is not None and report is not None:
            self.on_part_done(len(self.parts) - 1, report)

    # ---------------- part-parallel waves ---------------- #
    def _wave_width(self) -> int:
        """Parts planned per wave: the configured slice count minus the
        blacklisted slices (elastic degradation — a degraded run plans
        narrower waves; at width 1 it IS the sequential loop)."""
        return max(1, (self.part_parallel or 1) - len(self.blacklisted))

    def _plan_wave(self, first_plan: PartPlan):
        """Plan up to ``part_parallel`` consecutive parts (minus any
        blacklisted slices) by chaining speculative shrinks: part ``i+1``
        is planned on the PREDICTED shrink of part ``i`` (every candidate
        finalizes — the PR 5 speculation discipline at depth
        ``part_parallel`` instead of 1). Returns ``(wave, shrinks)`` with
        ``shrinks[i]`` the speculative shrink applying after ``wave[i]``
        (``None`` for empty parts and for the un-speculated last entry).
        Main-thread, pure host work."""
        wave = [first_plan]
        shrinks: List[Optional[_Prefetch]] = [None]
        graph, ext = self.remaining_graph, self.state.ext_remaining
        while len(wave) < self._wave_width() and not wave[-1].is_rest:
            cur = wave[-1]
            if not cur.is_empty:
                pf = self._speculative_shrink(graph, ext, cur.cand_mask,
                                              cur.cursor)
                shrinks[-1] = pf
                graph, ext = pf.shrink_graph, pf.ext_next
            nxt = self._plan_on(graph, ext, cur.cursor + 1, speculative=True)
            if nxt is None:
                break  # predicted shrink emptied the graph — no rest part
            wave.append(nxt)
            shrinks.append(None)
        for p in wave:
            self._bucketize(p)
        return wave, shrinks

    def _run_wave(self, wave: List[PartPlan],
                  shrinks: List[Optional[_Prefetch]]) -> Optional[PartPlan]:
        """Conquer one wave across the mesh slices, then merge strictly in
        plan order. Returns the next wave's first plan (``None`` = done).

        The LPT schedule places each non-empty part on a slice by its
        modeled cost; every slice conquers its parts concurrently on its
        own worker thread; only the lead part (the one the last boundary
        checkpoint points at) consults/writes sweep snapshots. The merge
        loop then validates each speculation in plan order — on a hit the
        predicted shrink is adopted (byte-identical to the sequential
        fold), on a miss the sync fold runs and every later speculative
        conquer of the wave is discarded, exactly as the sequential loop
        would have recomputed them.

        With a watchdog configured the wave is fault-tolerant: failed
        parts retry on their slice with backoff; a slice that exhausts
        its retries or hangs past the timeout is blacklisted for the rest
        of the run and the wave tail re-plans over the survivors (parts
        are idempotent, so the result stays byte-identical). Telemetry
        (retries/blacklists/replans) folds into the run report."""
        from repro.core.partsched import (
            WaveTelemetry,
            assign_parts,
            conquer_wave,
            cost_for_plan,
        )

        state = self.state
        surviving = [
            sp for sp in self.slice_specs if sp.index not in self.blacklisted
        ]
        live = [p for p in wave if not p.is_empty]
        costs = [
            cost_for_plan(p.bg, p.cursor, surviving[0]) for p in live
        ]
        schedule = assign_parts(costs, surviving)
        # Divide-side accounting for the whole wave, booked on the main
        # thread before the slice threads start (_conquer(account=False)).
        self.preprocess_time_s += sum(
            p.bucketize_time_s + p.extract_time_s for p in wave
        )
        lead_cursor = min((p.cursor for p in live), default=None)
        by_cursor = {p.cursor: p for p in live}
        assign_of = {a.cursor: a for a in schedule.assignments}

        def _run_one(cursor: int, s: int, heartbeat=None):
            plan = by_cursor[cursor]
            fn = (
                self.slice_decomposes[s]
                if self.slice_decomposes is not None else None
            )
            out = self._conquer(
                plan, fn=fn, lead=(cursor == lead_cursor), account=False,
                heartbeat=heartbeat,
            )
            # Only slice ``s``'s worker writes index ``s`` — no lock needed.
            self.slice_busy_s[s] += out[0].wall_time_s
            return out

        if self.watchdog is not None:
            run_part = _run_one
        else:
            # Fail-fast path: keep the historical two-arg call shape (no
            # heartbeat composed into on_sweep), so a custom decompose_fn
            # that accepts no kwargs stays usable without a watchdog.
            def run_part(cursor: int, s: int):
                return _run_one(cursor, s)

        tel = WaveTelemetry()
        t0 = time.perf_counter()
        try:
            results = conquer_wave(
                schedule, run_part, slices=surviving, watchdog=self.watchdog,
                fault_plan=self.fault_plan, telemetry=tel,
            )
        finally:
            self.conquer_wall_s += time.perf_counter() - t0
            self.retries += tel.retries
            self.replans += tel.replans
            if tel.blacklisted:
                self.degraded_waves += 1
                self.blacklisted.update(tel.blacklisted)
            self.fault_events.extend(tel.events)
        retries_of: Dict[int, int] = {}
        for e in tel.events:
            if e.get("event") == "retry":
                retries_of[e["cursor"]] = retries_of.get(e["cursor"], 0) + 1

        for i, plan in enumerate(wave):
            if plan.is_empty:
                state.parts_done = plan.cursor + 1
                self._checkpoint_boundary(None)
                continue
            res, density, start_sweep = results[plan.cursor]
            a = assign_of[plan.cursor]

            def stamp(r, _a=a):
                # slice_index is the PLANNED placement; a re-planned part's
                # actual executor is in the replan event log.
                r.slice_index = _a.slice_index
                r.wave = self._wave_index
                r.modeled_cost_bytes = _a.cost.total
                r.retries = retries_of.get(_a.cursor, 0)

            if plan.is_rest:
                self._merge_rest(plan, res, density, start_sweep,
                                 annotate=stamp)
                return None
            report, final_local = self._finalize_threshold(
                plan, res, density, start_sweep
            )
            stamp(report)
            pf = shrinks[i]
            if pf is not None and bool(final_local.all()):
                self.prefetch_hits += 1
                self._adopt_shrink(plan, pf, report)
                state.parts_done = plan.cursor + 1
                self._checkpoint_boundary(report)
                continue
            # Miss (or the wave's un-speculated tail): fold synchronously,
            # discard every later speculative conquer of this wave.
            if pf is not None:
                self.prefetch_misses += 1
                self.speculation_discards += sum(
                    1 for p in wave[i + 1:] if not p.is_empty
                )
            self._shrink_sync(plan, final_local, report)
            state.parts_done = plan.cursor + 1
            self._checkpoint_boundary(report)
            if pf is not None and i < len(wave) - 1:
                return self._build_plan(plan.cursor + 1)
        return self._build_plan(wave[-1].cursor + 1)

    def run_waves(self) -> None:
        state = self.state
        plan = self._build_plan(state.parts_done)
        while plan is not None:
            wave, shrinks = self._plan_wave(plan)
            plan = self._run_wave(wave, shrinks)
            self._wave_index += 1
        if not state.complete:
            # The shrink emptied the graph before the rest part.
            state.complete = True
            self._checkpoint_boundary(None)

    # ---------------- scheduler ---------------- #
    def run(self) -> None:
        if self.part_parallel is not None:
            self.run_waves()
            return
        state = self.state
        plan = self._build_plan(state.parts_done)
        while plan is not None:
            if plan.is_empty:
                # No candidates at this threshold: consume the cursor.
                state.parts_done = plan.cursor + 1
                self._checkpoint_boundary(None)
                plan = self._build_plan(plan.cursor + 1)
                continue
            self._bucketize(plan)
            self._submit_prefetch(plan)
            res, density, start_sweep = self._conquer(plan)
            if plan.is_rest:
                self._merge_rest(plan, res, density, start_sweep)
                plan = None
                continue
            report, final_local = self._finalize_threshold(
                plan, res, density, start_sweep
            )
            next_plan = self._shrink(plan, final_local, report)
            state.parts_done = plan.cursor + 1
            self._checkpoint_boundary(report)
            if next_plan is None:
                next_plan = self._build_plan(plan.cursor + 1)
            plan = next_plan
        if not state.complete:
            # The shrink emptied the graph before the rest part.
            state.complete = True
            self._checkpoint_boundary(None)

    def close(self, suppress_errors: bool = False) -> None:
        """Drain the prefetch worker and both checkpoint managers. Runs on
        EVERY exit path: after a crash-by-exception (the fault-injection
        tests) the pending async saves land before the exception leaves
        ``dc_kcore``, so the on-disk state at "crash" time is deterministic
        and no worker thread outlives the call."""
        if self._future is not None:
            fut, self._future = self._future, None
            exc = fut.exception()  # waits; consumes a worker failure
            if exc is not None and not suppress_errors:
                raise exc
        if self._executor is not None:
            self._executor.shutdown(wait=True)
            self._executor = None
        for mgr in (self.state_mgr, self.sweeps_mgr):
            if mgr is None:
                continue
            try:
                mgr.wait()
            except BaseException:
                if not suppress_errors:
                    raise


def dc_kcore(
    g: Graph,
    thresholds: Sequence[int] = (),
    strategy: str = "rough",
    decompose_fn: Optional[DecomposeFn] = None,
    row_align: int = 8,
    reorder: str = "identity",
    max_bucket_rows="auto",
    reorder_sample_edges: Optional[int] = None,
    checkpoint_dir: Optional[str] = None,
    resume: bool = False,
    on_part_done: Optional[PartHook] = None,
    divide_chunk: Optional[int] = None,
    sweep_checkpoint_every: Optional[int] = None,
    on_sweep_saved: Optional[SweepSavedHook] = None,
    overlap: bool = False,
    engine: str = "sorted",
    int16: bool = False,
    part_parallel: Optional[int] = None,
    part_parallel_plan=None,
    slice_capacity_bytes: Optional[int] = None,
    slice_timeout_s: Optional[float] = None,
    max_retries: Optional[int] = None,
    retry_backoff_s: float = 0.05,
    fault_plan=None,
    ckpt_retain: int = 2,
) -> tuple[np.ndarray, DCKCoreReport]:
    """Run DC-kCore. ``thresholds=()`` degenerates to the monolithic baseline
    (= the PSGraph competitor in the paper's tables).

    ``decompose_fn`` lets callers swap the conquer engine (single-device jit,
    Pallas-kernel, or the distributed shard_map engine) without touching the
    divide/merge logic. With ``sweep_checkpoint_every`` set it is invoked as
    ``decompose_fn(bg, init_coreness=..., on_sweep=...)``, so a custom engine
    must accept those kwargs (see :data:`DecomposeFn`); without the flag it
    is always called as plain ``decompose_fn(bg)``.

    ``engine`` selects the built-in conquer engine's sweep op
    (``"sorted"`` / ``"count"`` / ``"kernel"`` / ``"fused"`` — see
    :func:`repro.core.decompose.decompose`), and ``int16`` opts the fused
    engine into the halved-width estimate mode (overflow-guarded). Both
    apply only when ``decompose_fn`` is not given — a custom engine owns
    its own configuration, so combining them raises.

    ``overlap=True`` pipelines the stages: a single worker thread runs the
    next part's divide passes and bucketize (and the shrink of the current
    remaining graph) while the current part sweeps on the device, and
    checkpoint saves go through the manager's async thread instead of
    blocking the loop. The prefetch is *speculative* — it assumes every
    candidate of the conquering part finalizes — and is validated against
    the actual finalized set before being adopted, recomputed synchronously
    on a miss (Exact-Divide always hits by construction). Coreness is
    **byte-identical** with the flag on or off, resume included; only the
    wall clock and the accelerator-idle fraction change
    (:attr:`DCKCoreReport.idle_fraction`, Fig 16).

    ``reorder`` (``"identity"`` / ``"bfs"`` / ``"rcm"``) applies a
    locality-aware node ordering to *each part* before bucketizing it: the
    part's tiles then see co-located neighbor ids, the bucket-adjacency
    bitmap gets sparser, and the static frontier filter starts paying off.
    Purely a layout decision — the permutation is carried on the
    ``BucketedGraph`` and the engines report coreness in part-local original
    ids, so divide/merge is untouched. ``reorder_sample_edges`` switches the
    ordering computation to the bounded edge-sample variant
    (:func:`~repro.graph.reorder.sampled_order`). ``max_bucket_rows`` is
    forwarded to :func:`~repro.graph.build.bucketize` (``"auto"`` = the
    degree-profile tile autotuner).

    ``divide_chunk`` bounds the divide step's transient host bytes: every
    extraction pass (candidates, induced subgraph, ext fold, shrink — and
    the resume-time remaining-graph rebuild) runs chunked over CSR row
    ranges of at most that many adjacency slots, bit-identical to the
    unchunked result at every chunk size (``None`` = the
    :data:`~repro.graph.build.DEFAULT_DIVIDE_CHUNK_SLOTS` budget — the
    divide transient is *always* bounded; the knob only sizes it). Each
    part's observed peak rides in ``PartReport.divide_transient_bytes``.

    ``checkpoint_dir`` enables per-part checkpointing: the
    :class:`PipelineState` is saved atomically after every part, and
    ``resume=True`` restores the latest complete checkpoint and re-enters at
    the first unfinished part — a killed run resumed this way produces
    coreness **byte-identical** to the uninterrupted run. ``on_part_done``
    (``hook(part_index, report)``) fires after each part's save (after the
    save *enqueue* in overlapped mode — a crash raised from the hook still
    drains the pending save before propagating, so the boundary is on disk
    either way) — the fault-injection tests raise from it to simulate a
    crash at the worst moment (state saved, next part not started).

    ``part_parallel=S`` conquers up to ``S`` consecutive parts CONCURRENTLY
    per wave: the wave planner chains speculative shrinks (part ``i+1``
    planned on part ``i``'s predicted shrink — the ``overlap`` speculation
    at depth ``S``), the partition scheduler
    (:mod:`repro.core.partsched`) places each part on a slice by its
    modeled collective+HBM cost, and the merge loop validates the
    predictions strictly in plan order, discarding the wave's tail on the
    first miss. Coreness, checkpoints, sweep snapshots and resume are
    **byte-identical** to the sequential path. Without
    ``part_parallel_plan`` the slices are worker threads sharing the
    configured engine (the test backend); with it (a
    :class:`~repro.core.distributed.MeshPlan`) the global mesh is split
    into ``S`` submeshes, each part sweeps on its slice through the
    shard_map engine, and the E(v) boundary folds run device-resident via
    collectives (``DCKCoreReport.boundary_exchange_bytes``).
    ``slice_capacity_bytes`` bounds each slice's modeled resident bytes
    (the scheduler refuses oversized parts). Mutually exclusive with
    ``overlap`` — the wave subsumes the depth-1 prefetch.

    ``sweep_checkpoint_every=k`` (requires ``checkpoint_dir``) additionally
    saves a :class:`SweepSnapshot` every ``k`` conquer sweeps through the
    same atomic path; ``resume=True`` (with the flag still set) then
    re-enters *mid-part* at the last completed sweep via the engines'
    ``init_coreness`` warm restart — still byte-identical, because the
    fixed point is exact from any snapshot. A stale or unreadable snapshot
    (finished part, other run, half-written ``.tmp``) is ignored and resume
    falls back to the part boundary. ``on_sweep_saved``
    (``hook(part_cursor, sweep, save_seconds)``) fires after each snapshot
    save — the mid-sweep fault-injection tests crash from it.

    ``slice_timeout_s`` / ``max_retries`` (require ``part_parallel``) turn
    the wave executor fault-TOLERANT instead of fail-fast: a failed part
    retries on its slice with exponential backoff (``retry_backoff_s``
    base) up to ``max_retries`` times; a slice whose sweep heartbeat
    stalls past ``slice_timeout_s`` — or that exhausts its retries — is
    blacklisted for the rest of the run and its unfinished parts re-plan
    over the surviving slices (S -> S-1 -> ... -> 1, width 1 ≡ the
    sequential loop). Parts are idempotent over immutable inputs, so a
    degraded run's coreness stays **byte-identical** to the fault-free
    sequential run; retries/blacklists/degraded waves land in the report.
    Without either knob the historical fail-fast semantics are unchanged
    (the first slice failure re-raises after the wave drains).

    ``fault_plan`` (a :class:`repro.runtime.FaultPlan`) injects chaos —
    crashes, hangs, slowdowns — into the named pipeline sites
    (``slice_conquer``, ``boundary_fold``, ``checkpoint_save``,
    ``prefetch``); the chaos tests, the CLI ``--fault`` flag and the
    bench harness share this one mechanism. ``ckpt_retain`` sizes both
    checkpoint managers' retention (default 2: the newest boundary plus
    one predecessor, so a corrupted latest step — detected by per-array
    CRC32, quarantined to ``step_*.corrupt`` — resumes from the previous
    retained step instead of restarting the part from scratch).
    """
    slice_decomposes = slice_specs = fold_plan = None
    if part_parallel is not None:
        if part_parallel < 1:
            raise ValueError(f"part_parallel must be >= 1, got {part_parallel}")
        if overlap:
            raise ValueError("part_parallel subsumes overlap (the wave IS "
                             "the speculation) — pass one or the other")
        if part_parallel_plan is not None:
            if decompose_fn is not None:
                raise ValueError("part_parallel_plan builds one distributed "
                                 "engine per mesh slice — decompose_fn would "
                                 "be silently ignored")
            if engine != "sorted" or int16:
                raise ValueError("part_parallel_plan selects the shard_map "
                                 "engine; engine=/int16= would be silently "
                                 "ignored")
            from repro.core.partsched import make_slice_decomposes, spec_of

            slice_plans, slice_decomposes = make_slice_decomposes(
                part_parallel_plan, part_parallel
            )
            slice_specs = [
                spec_of(p, i, slice_capacity_bytes)
                for i, p in enumerate(slice_plans)
            ]
            fold_plan = part_parallel_plan
        else:
            from repro.core.partsched import SliceSpec

            slice_specs = [
                SliceSpec(i, 1, 1, slice_capacity_bytes)
                for i in range(part_parallel)
            ]
    elif part_parallel_plan is not None:
        raise ValueError("part_parallel_plan requires part_parallel")
    watchdog = None
    if slice_timeout_s is not None or max_retries is not None:
        if part_parallel is None:
            raise ValueError("slice_timeout_s/max_retries configure the "
                             "part-parallel wave watchdog — they require "
                             "part_parallel")
        from repro.core.partsched import WatchdogConfig

        watchdog = WatchdogConfig(
            slice_timeout_s=slice_timeout_s,
            max_retries=2 if max_retries is None else int(max_retries),
            backoff_s=float(retry_backoff_s),
        )
    if ckpt_retain < 1:
        raise ValueError(f"ckpt_retain must be >= 1, got {ckpt_retain}")
    if decompose_fn is None:
        decompose_fn = (  # noqa: E731
            lambda bg, **kw: decompose(bg, op=engine, int16=int16, **kw)
        )
    elif engine != "sorted" or int16:
        raise ValueError("engine=/int16= configure the built-in engine; "
                         "with decompose_fn they would be silently ignored "
                         "— configure the custom engine instead")
    if resume and checkpoint_dir is None:
        raise ValueError("resume=True requires checkpoint_dir")
    if sweep_checkpoint_every is not None and checkpoint_dir is None:
        raise ValueError("sweep_checkpoint_every requires checkpoint_dir")
    thresholds = sorted(set(int(t) for t in thresholds), reverse=True)
    t_start = time.perf_counter()

    n = g.n_nodes
    state: Optional[PipelineState] = None
    resumed_parts = 0
    sweep_dir = _sweep_dir(checkpoint_dir) if checkpoint_dir is not None else None
    pending_snap: Optional[SweepSnapshot] = None
    # Quarantine records from corrupt-checkpoint fallbacks during restore —
    # folded into the report's fault accounting.
    restore_events: List[dict] = []
    if resume:
        state = PipelineState.restore(checkpoint_dir, n, events=restore_events)
        if sweep_checkpoint_every is not None:
            # Mid-part resume point — consulted even when no part boundary
            # exists yet (a run killed during part 0 leaves only sweep
            # snapshots), and validated against the part it claims to
            # belong to at the moment that part runs.
            pending_snap = SweepSnapshot.restore(sweep_dir, events=restore_events)
    if state is None:
        if checkpoint_dir is not None and not resume:
            # Fresh run: purge stale steps (and sweep snapshots) from any
            # previous run in this dir, so a later resume can only see this
            # run's boundaries. A resume that found no boundary keeps the
            # dir as is — snapshot validation screens anything stale.
            _clear_checkpoints(checkpoint_dir)
            _clear_checkpoints(sweep_dir)
        state = PipelineState.fresh(g, thresholds)
        remaining_graph = g
    else:
        if state.fingerprint != graph_fingerprint(g):
            raise ValueError(
                f"checkpoint was written for a different graph "
                f"(fingerprint {state.fingerprint} != {graph_fingerprint(g)})"
            )
        if state.thresholds != thresholds:
            raise ValueError(
                f"checkpoint plans thresholds {state.thresholds}, "
                f"this run asked for {thresholds}"
            )
        resumed_parts = len(state.reports)
        if state.complete:
            report = DCKCoreReport(
                parts=state.reports,
                total_time_s=time.perf_counter() - t_start,
                preprocess_time_s=0.0,
                resumed_parts=resumed_parts,
                overlap=overlap,
                part_parallel=part_parallel or 0,
                quarantined_steps=len(restore_events),
                fault_events=list(restore_events),
            )
            return state.coreness.copy(), report
        # Rebuild the remaining graph from the original + finalized mask.
        # Induced-subgraph composition is byte-stable (monotone relabeling
        # of a sorted CSR), so this equals the incrementally shrunk graph.
        remaining_graph, keep_ids = induced_subgraph(
            g, ~state.finalized, chunk_slots=divide_chunk
        )
        assert np.array_equal(keep_ids, state.remaining_ids), (
            "checkpoint remaining-id map inconsistent with finalized mask"
        )

    state_mgr = sweeps_mgr = None
    if checkpoint_dir is not None:
        from repro.ckpt import CheckpointManager

        state_mgr = CheckpointManager(checkpoint_dir, retain=ckpt_retain)
        sweeps_mgr = CheckpointManager(sweep_dir, retain=ckpt_retain)

    pipeline = _PartPipeline(
        state=state,
        remaining_graph=remaining_graph,
        thresholds=thresholds,
        strategy=strategy,
        decompose_fn=decompose_fn,
        row_align=row_align,
        reorder=reorder,
        max_bucket_rows=max_bucket_rows,
        reorder_sample_edges=reorder_sample_edges,
        checkpoint_dir=checkpoint_dir,
        sweep_dir=sweep_dir,
        divide_chunk=divide_chunk,
        sweep_checkpoint_every=sweep_checkpoint_every,
        on_part_done=on_part_done,
        on_sweep_saved=on_sweep_saved,
        overlap=overlap,
        pending_snap=pending_snap,
        state_mgr=state_mgr,
        sweeps_mgr=sweeps_mgr,
        part_parallel=part_parallel,
        slice_decomposes=slice_decomposes,
        slice_specs=slice_specs,
        fold_plan=fold_plan,
        watchdog=watchdog,
        fault_plan=fault_plan,
    )
    try:
        pipeline.run()
    except BaseException:
        # Crash-by-exception (incl. the fault-injection hooks): drain the
        # worker and pending saves FIRST, so the disk state the "crashed"
        # run leaves behind is deterministic, then let the crash propagate.
        # Injected hangs are released first — a parked worker must wake
        # (and raise) for the drain to terminate promptly.
        if fault_plan is not None:
            fault_plan.release()
        pipeline.close(suppress_errors=True)
        raise
    if fault_plan is not None:
        fault_plan.release()
    pipeline.close()

    report = DCKCoreReport(
        parts=pipeline.parts,
        total_time_s=time.perf_counter() - t_start,
        preprocess_time_s=pipeline.preprocess_time_s,
        resumed_parts=resumed_parts,
        overlap=overlap,
        prefetch_hits=pipeline.prefetch_hits,
        prefetch_misses=pipeline.prefetch_misses,
        part_parallel=part_parallel or 0,
        conquer_wall_s=pipeline.conquer_wall_s,
        slice_busy_s=list(pipeline.slice_busy_s),
        speculation_discards=pipeline.speculation_discards,
        boundary_exchange_bytes=pipeline.boundary_exchange_bytes,
        retries=pipeline.retries,
        blacklisted_slices=sorted(pipeline.blacklisted),
        degraded_waves=pipeline.degraded_waves,
        quarantined_steps=len(restore_events),
        fault_events=list(restore_events) + list(pipeline.fault_events),
    )
    if not bool((state.coreness >= 0).all()):
        raise MergeIncompleteError(
            f"merge left {int((state.coreness < 0).sum())} of {n} nodes "
            f"unfinalized — every node must be resolved by exactly one part"
        )
    return state.coreness, report
