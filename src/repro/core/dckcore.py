"""DC-kCore orchestrator — divide, conquer (sequentially), merge, resume.

Implements the full pipeline of paper Section 4 for an arbitrary number of
parts (Section 5.6 evaluates 2-4):

  1. Sort thresholds descending: ``t_p > ... > t_1``.
  2. For each threshold ``t`` on the *remaining* graph: extract candidates
     (Exact- or Rough-Divide), build the part with its external information,
     decompose it (conquer), and finalize every node whose value is >= ``t``
     (Exact finalizes all by construction). Update ``ext`` of the remaining
     nodes with their freshly-finalized neighbors and shrink the remaining
     graph.
  3. Decompose the final remaining part and finalize everything.
  4. Merge: scatter part coreness back through the id maps.

Parts are processed **sequentially**, so the peak device footprint is the
max over parts instead of the whole graph — the paper's resource story. Per
part we record nodes/edges/iterations/communication/peak bytes/extract and
decompose times, plus the frontier work metric (rows gathered per sweep vs
the always-full-sweep baseline); these power every benchmark table
(Figs 7-11, Table 3) and the work-per-iteration columns.

**Per-part checkpointing.** The paper's headline stability claim (136B
edges, 27.5h runs) only holds if a failed part does not forfeit the parts
already decomposed. The loop state between parts is an explicit
:class:`PipelineState`; with ``checkpoint_dir`` set it is saved atomically
through :func:`repro.ckpt.save_pytree` after every part, and
``resume=True`` re-enters at the first unfinished part:

* the checkpoint holds the *host merge state* — coreness, the finalized
  mask, ``ext`` of the remaining nodes, the remaining-id map, the
  threshold cursor and the per-part reports (JSON extra);
* it deliberately does NOT hold the remaining graph or any device tiles —
  the remaining graph is recomputed from the original graph and the
  finalized mask (induced-subgraph composition is byte-stable), and parts
  rebuild their tiles anyway;
* a killed run leaves at most a ``step_*.tmp`` directory, which restore
  ignores — resume always starts from the last *complete* part boundary
  and reproduces byte-identical coreness (every stage is deterministic).
"""
from __future__ import annotations

import dataclasses
import os
import re
import shutil
import time
import zlib
from typing import Callable, Dict, List, Optional, Sequence

import numpy as np

from repro.core.decompose import DecomposeResult, decompose
from repro.core.divide import timed_candidates
from repro.graph.build import bucketize, external_info, induced_subgraph
from repro.graph.reorder import bitmap_density, reorder_graph
from repro.graph.structs import BucketedGraph, Graph

STATE_FORMAT = 1


def graph_fingerprint(g: Graph) -> Dict[str, int]:
    """Cheap identity of a graph for checkpoint/resume validation: node and
    edge counts plus a CRC of the degree sequence. O(n), no edge traversal —
    collisions require an identical degree sequence, at which point the
    resume-time remaining-id assertion is the backstop."""
    deg = np.ascontiguousarray(g.degrees, dtype=np.int64)
    return {
        "n_nodes": int(g.n_nodes),
        "n_edges": int(g.n_edges),
        "deg_crc32": int(zlib.crc32(deg.tobytes())),
    }


def _clear_checkpoints(path: str) -> None:
    """Remove every step dir (and half-written .tmp) under ``path`` — a
    fresh run must not leave stale higher-numbered steps from a previous
    run for a later ``resume=True`` to pick up."""
    if not os.path.isdir(path):
        return
    for d in os.listdir(path):
        if re.fullmatch(r"step_\d+(\.tmp)?", d):
            shutil.rmtree(os.path.join(path, d), ignore_errors=True)


@dataclasses.dataclass
class PartReport:
    name: str
    threshold: Optional[int]
    n_nodes: int
    n_edges: int
    iterations: int
    comm_amount: int
    peak_bytes: int
    extract_time_s: float
    decompose_time_s: float
    finalized: int
    # Work metric (active-frontier scheduling): rows actually gathered +
    # h-indexed across all sweeps, vs what always-full sweeps would gather.
    gathered_rows: int = 0
    full_sweep_rows: int = 0
    active_rows_per_iter: List[int] = dataclasses.field(default_factory=list)
    # Measured per-device collective bytes across the part's sweeps (0 for
    # the single-device engine — it issues no collectives).
    collective_bytes: int = 0
    # Fraction of set bits in the part's bucket-adjacency bitmap: how often
    # the static frontier filter could NOT rule out a tile (lower = sparser
    # = locality-aware reordering worked).
    bitmap_density: float = 1.0
    # Wall time of the atomic per-part checkpoint save (0 when disabled).
    save_time_s: float = 0.0


@dataclasses.dataclass
class DCKCoreReport:
    parts: List[PartReport]
    total_time_s: float
    preprocess_time_s: float
    resumed_parts: int = 0  # parts restored from checkpoint, not re-run

    @property
    def total_comm(self) -> int:
        return sum(p.comm_amount for p in self.parts)

    @property
    def peak_bytes(self) -> int:
        return max((p.peak_bytes for p in self.parts), default=0)

    @property
    def total_iterations(self) -> int:
        return sum(p.iterations for p in self.parts)

    @property
    def total_gathered_rows(self) -> int:
        """Total sweep work across parts (frontier-scheduled)."""
        return sum(p.gathered_rows for p in self.parts)

    @property
    def total_full_sweep_rows(self) -> int:
        """Work the always-full-sweep schedule would have done."""
        return sum(p.full_sweep_rows for p in self.parts)

    @property
    def total_collective_bytes(self) -> int:
        """Measured per-device collective bytes summed over all parts."""
        return sum(p.collective_bytes for p in self.parts)

    @property
    def total_save_time_s(self) -> float:
        """Wall time spent in per-part checkpoint saves."""
        return sum(p.save_time_s for p in self.parts)


@dataclasses.dataclass
class PipelineState:
    """Host state of a DC-kCore run at a part boundary — the checkpoint unit.

    ``parts_done`` is the RNG-free cursor: how many thresholds of the
    (descending, deduplicated) plan have been consumed. ``complete`` marks
    that the final "rest" part also finished — a resume of a complete state
    returns the stored result without touching the graph.
    """

    coreness: np.ndarray       # [n] int32, -1 where unfinalized
    finalized: np.ndarray      # [n] bool
    ext_remaining: np.ndarray  # [n_remaining] int32, remaining-local order
    remaining_ids: np.ndarray  # [n_remaining] int64, remaining-local -> orig
    thresholds: List[int]      # the descending plan (consistency-checked)
    fingerprint: Dict[str, int] = dataclasses.field(default_factory=dict)
    parts_done: int = 0
    complete: bool = False
    reports: List[PartReport] = dataclasses.field(default_factory=list)

    @staticmethod
    def fresh(g: Graph, thresholds: Sequence[int]) -> "PipelineState":
        n_nodes = g.n_nodes
        return PipelineState(
            coreness=np.full(n_nodes, -1, dtype=np.int32),
            finalized=np.zeros(n_nodes, dtype=bool),
            ext_remaining=np.zeros(n_nodes, dtype=np.int32),
            remaining_ids=np.arange(n_nodes, dtype=np.int64),
            thresholds=[int(t) for t in thresholds],
            fingerprint=graph_fingerprint(g),
        )

    # -- checkpoint wire format ----------------------------------------- #
    def arrays(self) -> dict:
        """The array pytree saved per part (scalars/reports ride in extra)."""
        return {
            "coreness": self.coreness,
            "finalized": self.finalized,
            "ext_remaining": self.ext_remaining,
            "remaining_ids": self.remaining_ids,
        }

    def extra(self) -> dict:
        return {
            "format": STATE_FORMAT,
            "parts_done": int(self.parts_done),
            "complete": bool(self.complete),
            "thresholds": [int(t) for t in self.thresholds],
            "fingerprint": dict(self.fingerprint),
            "reports": [dataclasses.asdict(p) for p in self.reports],
        }

    def save(self, checkpoint_dir: str) -> float:
        """Atomic save at the current part boundary; returns wall seconds.

        Step number = parts completed so far (the rest part counts one
        past the last threshold), so ``latest_step`` is the cursor. A
        part's own ``save_time_s`` is only known after its save returns,
        so it is persisted one boundary later (the next save serializes
        the updated report); the final part's save cost exists only in the
        live report.

        Restore only ever reads the latest step, so retention is
        ``CheckpointManager(keep=1)``: earlier steps are pruned *after* the
        atomic rename — disk stays bounded at one checkpoint (the state
        arrays are O(n); at paper scale a P-part run must not hold P of
        them). A crash between rename and prune leaves two steps; resume
        still picks the newest."""
        from repro.ckpt import CheckpointManager

        t0 = time.time()
        step = self.parts_done + (1 if self.complete else 0)
        CheckpointManager(checkpoint_dir, keep=1).save(
            self.arrays(), step, extra=self.extra(), blocking=True
        )
        return time.time() - t0

    @staticmethod
    def restore(checkpoint_dir: str, n_nodes: int) -> Optional["PipelineState"]:
        """Latest complete checkpoint under ``checkpoint_dir`` (``None`` if
        there is none — half-written ``step_*.tmp`` dirs are ignored by
        :func:`repro.ckpt.latest_step`)."""
        from repro.ckpt import latest_step, restore_pytree

        if latest_step(checkpoint_dir) is None:
            return None
        template = {
            "coreness": np.zeros(0, np.int32),
            "finalized": np.zeros(0, bool),
            "ext_remaining": np.zeros(0, np.int32),
            "remaining_ids": np.zeros(0, np.int64),
        }
        arrays, _step, extra = restore_pytree(checkpoint_dir, template)
        if extra.get("format") != STATE_FORMAT:
            raise ValueError(
                f"checkpoint format {extra.get('format')!r} != {STATE_FORMAT}"
            )
        if arrays["coreness"].shape[0] != n_nodes:
            raise ValueError(
                f"checkpoint is for a {arrays['coreness'].shape[0]}-node graph, "
                f"got {n_nodes} nodes"
            )
        return PipelineState(
            coreness=arrays["coreness"],
            finalized=arrays["finalized"],
            ext_remaining=arrays["ext_remaining"],
            remaining_ids=arrays["remaining_ids"],
            thresholds=[int(t) for t in extra["thresholds"]],
            fingerprint={k: int(v) for k, v in extra["fingerprint"].items()},
            parts_done=int(extra["parts_done"]),
            complete=bool(extra["complete"]),
            reports=[PartReport(**r) for r in extra["reports"]],
        )


DecomposeFn = Callable[[BucketedGraph], DecomposeResult]
PartHook = Callable[[int, PartReport], None]


def dc_kcore(
    g: Graph,
    thresholds: Sequence[int] = (),
    strategy: str = "rough",
    decompose_fn: Optional[DecomposeFn] = None,
    row_align: int = 8,
    reorder: str = "identity",
    max_bucket_rows="auto",
    reorder_sample_edges: Optional[int] = None,
    checkpoint_dir: Optional[str] = None,
    resume: bool = False,
    on_part_done: Optional[PartHook] = None,
) -> tuple[np.ndarray, DCKCoreReport]:
    """Run DC-kCore. ``thresholds=()`` degenerates to the monolithic baseline
    (= the PSGraph competitor in the paper's tables).

    ``decompose_fn`` lets callers swap the conquer engine (single-device jit,
    Pallas-kernel, or the distributed shard_map engine) without touching the
    divide/merge logic.

    ``reorder`` (``"identity"`` / ``"bfs"`` / ``"rcm"``) applies a
    locality-aware node ordering to *each part* before bucketizing it: the
    part's tiles then see co-located neighbor ids, the bucket-adjacency
    bitmap gets sparser, and the static frontier filter starts paying off.
    Purely a layout decision — the permutation is carried on the
    ``BucketedGraph`` and the engines report coreness in part-local original
    ids, so divide/merge is untouched. ``reorder_sample_edges`` switches the
    ordering computation to the bounded edge-sample variant
    (:func:`~repro.graph.reorder.sampled_order`). ``max_bucket_rows`` is
    forwarded to :func:`~repro.graph.build.bucketize` (``"auto"`` = the
    degree-profile tile autotuner).

    ``checkpoint_dir`` enables per-part checkpointing: the
    :class:`PipelineState` is saved atomically after every part, and
    ``resume=True`` restores the latest complete checkpoint and re-enters at
    the first unfinished part — a killed run resumed this way produces
    coreness **byte-identical** to the uninterrupted run. ``on_part_done``
    (``hook(part_index, report)``) fires after each part's save — the
    fault-injection tests raise from it to simulate a crash at the worst
    moment (state saved, next part not started).
    """
    if decompose_fn is None:
        decompose_fn = lambda bg: decompose(bg)  # noqa: E731
    if resume and checkpoint_dir is None:
        raise ValueError("resume=True requires checkpoint_dir")
    thresholds = sorted(set(int(t) for t in thresholds), reverse=True)
    t_start = time.time()

    n = g.n_nodes
    state: Optional[PipelineState] = None
    resumed_parts = 0
    if resume:
        state = PipelineState.restore(checkpoint_dir, n)
    if state is None:
        if checkpoint_dir is not None:
            # Fresh run: purge stale steps from any previous run in this
            # dir, so a later resume can only see this run's boundaries.
            _clear_checkpoints(checkpoint_dir)
        state = PipelineState.fresh(g, thresholds)
        remaining_graph = g
    else:
        if state.fingerprint != graph_fingerprint(g):
            raise ValueError(
                f"checkpoint was written for a different graph "
                f"(fingerprint {state.fingerprint} != {graph_fingerprint(g)})"
            )
        if state.thresholds != thresholds:
            raise ValueError(
                f"checkpoint plans thresholds {state.thresholds}, "
                f"this run asked for {thresholds}"
            )
        resumed_parts = len(state.reports)
        if state.complete:
            report = DCKCoreReport(
                parts=state.reports,
                total_time_s=time.time() - t_start,
                preprocess_time_s=0.0,
                resumed_parts=resumed_parts,
            )
            return state.coreness.copy(), report
        # Rebuild the remaining graph from the original + finalized mask.
        # Induced-subgraph composition is byte-stable (monotone relabeling
        # of a sorted CSR), so this equals the incrementally shrunk graph.
        remaining_graph, keep_ids = induced_subgraph(g, ~state.finalized)
        assert np.array_equal(keep_ids, state.remaining_ids), (
            "checkpoint remaining-id map inconsistent with finalized mask"
        )

    parts: List[PartReport] = state.reports
    preprocess = 0.0

    def run_part(part_g: Graph, part_ext: np.ndarray, name: str,
                 threshold: Optional[int], extract_time: float):
        nonlocal preprocess
        t0 = time.time()
        # Reorder the part, not the whole graph: each part is a fresh id
        # space, and locality only has to hold within the tiles actually
        # decomposed together. part_ext stays in part-local original order;
        # bucketize permutes it in and the engine un-permutes coreness out.
        bg = bucketize(
            reorder_graph(part_g, reorder, sample_edges=reorder_sample_edges),
            ext=part_ext, row_align=row_align, max_bucket_rows=max_bucket_rows,
        )
        preprocess += (time.time() - t0) + extract_time
        return decompose_fn(bg), bitmap_density(bg)

    def checkpoint_part(report: Optional[PartReport]):
        """Save state at a part boundary, then fire the hook."""
        if checkpoint_dir is not None:
            save_s = state.save(checkpoint_dir)
            if report is not None:
                report.save_time_s = save_s
        if on_part_done is not None and report is not None:
            on_part_done(len(parts) - 1, report)

    for ti in range(state.parts_done, len(thresholds)):
        t = thresholds[ti]
        cand_mask, extract_time = timed_candidates(
            remaining_graph, state.ext_remaining, t, strategy
        )
        if not cand_mask.any():
            state.parts_done = ti + 1
            checkpoint_part(None)
            continue
        t_ext0 = time.time()
        part_g, part_local_ids = induced_subgraph(remaining_graph, cand_mask)
        part_ext = state.ext_remaining[cand_mask]
        extract_time += time.time() - t_ext0

        res, density = run_part(part_g, part_ext, f"core>={t}", t, extract_time)

        # Finalize nodes that resolved at >= t (all of them for Exact-Divide).
        final_local = res.coreness >= t
        part_orig_ids = state.remaining_ids[part_local_ids]
        newly = part_orig_ids[final_local]
        state.coreness[newly] = res.coreness[final_local]
        state.finalized[newly] = True

        report = PartReport(
            name=f"core>={t}",
            threshold=t,
            n_nodes=part_g.n_nodes,
            n_edges=part_g.n_edges,
            iterations=res.iterations,
            comm_amount=res.comm_amount,
            peak_bytes=res.peak_bytes,
            extract_time_s=extract_time,
            decompose_time_s=res.wall_time_s,
            finalized=int(final_local.sum()),
            gathered_rows=res.gathered_rows,
            full_sweep_rows=res.full_sweep_rows,
            active_rows_per_iter=list(res.active_rows_per_iter),
            collective_bytes=res.collective_bytes,
            bitmap_density=density,
        )
        parts.append(report)

        # Shrink the remaining graph; fold finalized neighbors into ext.
        t_ext0 = time.time()
        newly_mask_local = np.zeros(remaining_graph.n_nodes, dtype=bool)
        newly_mask_local[part_local_ids[final_local]] = True
        keep_local = ~newly_mask_local
        ext_delta = external_info(remaining_graph, keep_local, newly_mask_local)
        new_graph, keep_ids = induced_subgraph(remaining_graph, keep_local)
        state.ext_remaining = state.ext_remaining[keep_local] + ext_delta
        state.remaining_ids = state.remaining_ids[keep_ids]
        remaining_graph = new_graph
        preprocess += time.time() - t_ext0

        state.parts_done = ti + 1
        checkpoint_part(report)

    # Final (bottom) part: everything left.
    if remaining_graph.n_nodes > 0:
        res, density = run_part(
            remaining_graph, state.ext_remaining, "rest", None, 0.0
        )
        state.coreness[state.remaining_ids] = res.coreness
        state.finalized[state.remaining_ids] = True
        report = PartReport(
            name="rest",
            threshold=None,
            n_nodes=remaining_graph.n_nodes,
            n_edges=remaining_graph.n_edges,
            iterations=res.iterations,
            comm_amount=res.comm_amount,
            peak_bytes=res.peak_bytes,
            extract_time_s=0.0,
            decompose_time_s=res.wall_time_s,
            finalized=remaining_graph.n_nodes,
            gathered_rows=res.gathered_rows,
            full_sweep_rows=res.full_sweep_rows,
            active_rows_per_iter=list(res.active_rows_per_iter),
            collective_bytes=res.collective_bytes,
            bitmap_density=density,
        )
        parts.append(report)
        state.remaining_ids = np.zeros(0, dtype=np.int64)
        state.ext_remaining = np.zeros(0, dtype=np.int32)
        state.complete = True
        checkpoint_part(report)
    else:
        state.complete = True
        checkpoint_part(None)

    report = DCKCoreReport(
        parts=parts,
        total_time_s=time.time() - t_start,
        preprocess_time_s=preprocess,
        resumed_parts=resumed_parts,
    )
    assert (state.coreness >= 0).all(), "merge left unfinalized nodes"
    return state.coreness, report
