"""EdgeStore-format edit log — the serving layer's update feed.

An :class:`EditLog` is an append-only on-disk log of edge edits in the
exact spill format :class:`~repro.graph.io.EdgeStore` uses — canonicalized
directed slots as interleaved ``(u, v)`` int64 pairs — split across two
streams (``ins.i64`` / ``del.i64``, each a verbatim ``slots.i64``). A
third file, ``frames.i64``, holds the batch framing: per sealed batch, the
cumulative slot counts of both streams as two int64s, written AFTER the
slot bytes are flushed, so a reader never observes a frame whose payload is
still in flight.

``EditLogReader`` tails the log: :meth:`poll` reports sealed-but-unread
batches, :meth:`read_batch` returns the next one as an
:class:`~repro.graph.delta.EdgeEdits` (payload read in bounded chunks —
same ``chunk_slots`` discipline as ``EdgeStore.iter_slots``). Writer and
reader may live in different threads or processes; the framing file is the
only coordination point.
"""
from __future__ import annotations

import os
import shutil
import tempfile
from typing import Optional

import numpy as np

from repro.graph.build import canonical_slots
from repro.graph.delta import EdgeEdits

_FRAME_WORDS = 2  # per sealed batch: cumulative (ins_slots, del_slots)


class EditLog:
    """Append-only edit-log writer (EdgeStore slot format + batch frames)."""

    def __init__(self, workdir: Optional[str] = None):
        self._own_dir = workdir is None
        self.workdir = workdir or tempfile.mkdtemp(prefix="editlog_")
        os.makedirs(self.workdir, exist_ok=True)
        self.ins_path = os.path.join(self.workdir, "ins.i64")
        self.del_path = os.path.join(self.workdir, "del.i64")
        self.frames_path = os.path.join(self.workdir, "frames.i64")
        self._ins = open(self.ins_path, "wb")
        self._del = open(self.del_path, "wb")
        self._frames = open(self.frames_path, "wb")
        self.ins_slots = 0
        self.del_slots = 0
        self.n_batches = 0

    def _spill(self, f, src, dst) -> int:
        u, v = canonical_slots(
            np.asarray(src, dtype=np.int64), np.asarray(dst, dtype=np.int64)
        )
        if u.size:
            pairs = np.empty(2 * u.size, dtype=np.int64)
            pairs[0::2] = u
            pairs[1::2] = v
            pairs.tofile(f)
        return int(u.size)

    def append(self, src, dst, *, delete: bool = False) -> None:
        """Canonicalize and spill one edit chunk into the open batch."""
        if delete:
            self.del_slots += self._spill(self._del, src, dst)
        else:
            self.ins_slots += self._spill(self._ins, src, dst)

    def seal_batch(self) -> int:
        """Close the open batch: flush payload, then write its frame.

        Returns the sealed batch's index. Sealing an empty batch is legal
        (an idle churn tick); readers see it as a no-op batch.
        """
        self._ins.flush()
        self._del.flush()
        os.fsync(self._ins.fileno())
        os.fsync(self._del.fileno())
        np.array([self.ins_slots, self.del_slots], dtype=np.int64).tofile(
            self._frames
        )
        self._frames.flush()
        self.n_batches += 1
        return self.n_batches - 1

    @property
    def spill_bytes(self) -> int:
        return (self.ins_slots + self.del_slots) * 16

    def cleanup(self) -> None:
        for f in (self._ins, self._del, self._frames):
            if not f.closed:
                f.close()
        if self._own_dir:
            shutil.rmtree(self.workdir, ignore_errors=True)

    def __enter__(self) -> "EditLog":
        return self

    def __exit__(self, *exc) -> None:
        self.cleanup()


def _read_slot_range(
    path: str, lo_slot: int, hi_slot: int, chunk_slots: int
) -> tuple[np.ndarray, np.ndarray]:
    """Slots ``[lo, hi)`` of a slot file, read in bounded chunks."""
    n = hi_slot - lo_slot
    u = np.empty(n, dtype=np.int64)
    v = np.empty(n, dtype=np.int64)
    chunk_slots = max(1, int(chunk_slots))
    with open(path, "rb") as f:
        f.seek(lo_slot * 16)
        done = 0
        while done < n:
            want = min(chunk_slots, n - done)
            buf = np.fromfile(f, dtype=np.int64, count=2 * want)
            if buf.size < 2 * want:
                raise IOError(
                    f"edit log truncated: {path} ends before sealed frame"
                )
            u[done:done + want] = buf[0::2]
            v[done:done + want] = buf[1::2]
            done += want
    return u, v


class EditLogReader:
    """Tail an :class:`EditLog` directory batch by batch."""

    def __init__(self, workdir: str):
        self.workdir = workdir
        self.ins_path = os.path.join(workdir, "ins.i64")
        self.del_path = os.path.join(workdir, "del.i64")
        self.frames_path = os.path.join(workdir, "frames.i64")
        self._cursor = 0           # next batch index to read
        self._ins_done = 0         # slots consumed so far
        self._del_done = 0

    def _frames(self) -> np.ndarray:
        if not os.path.exists(self.frames_path):
            return np.zeros((0, _FRAME_WORDS), dtype=np.int64)
        raw = np.fromfile(self.frames_path, dtype=np.int64)
        n = raw.size // _FRAME_WORDS  # a torn trailing frame is not sealed
        return raw[: n * _FRAME_WORDS].reshape(n, _FRAME_WORDS)

    def poll(self) -> int:
        """Number of sealed batches not yet read."""
        return max(0, self._frames().shape[0] - self._cursor)

    def read_batch(self, chunk_slots: int = 1 << 20) -> Optional[EdgeEdits]:
        """Next sealed batch as raw directed slots (``None`` if none)."""
        frames = self._frames()
        if self._cursor >= frames.shape[0]:
            return None
        ins_hi, del_hi = int(frames[self._cursor, 0]), int(frames[self._cursor, 1])
        iu, iv = _read_slot_range(
            self.ins_path, self._ins_done, ins_hi, chunk_slots
        )
        du, dv = _read_slot_range(
            self.del_path, self._del_done, del_hi, chunk_slots
        )
        self._ins_done, self._del_done = ins_hi, del_hi
        self._cursor += 1
        return EdgeEdits(ins_src=iu, ins_dst=iv, del_src=du, del_dst=dv)
