"""Synthetic graph generators (numpy, reproducible).

The paper evaluates on com-friendster (public, 1.8B edges) and two internal
payment graphs (15B / 136B edges). None fit this container; benchmarks use
*shape-matched* synthetic graphs instead:

* :func:`barabasi_albert` — preferential attachment; heavy-tailed degrees
  like social graphs (com-friendster analogue).
* :func:`rmat` — Kronecker-style power-law generator used by Graph500;
  closest to payment-network skew (WX-* analogue).
* :func:`erdos_renyi` — uniform random baseline for property tests.
"""
from __future__ import annotations

import numpy as np

from repro.graph.structs import Graph


def erdos_renyi(n: int, avg_deg: float, seed: int = 0) -> Graph:
    """G(n, m) with m = n * avg_deg / 2 sampled edge pairs."""
    rng = np.random.default_rng(seed)
    m = int(n * avg_deg / 2)
    src = rng.integers(0, n, size=m, dtype=np.int64)
    dst = rng.integers(0, n, size=m, dtype=np.int64)
    return Graph.from_edges(src, dst, n_nodes=n)


def barabasi_albert(n: int, m: int, seed: int = 0) -> Graph:
    """Preferential attachment: each new node attaches to ``m`` targets.

    Vectorized variant: targets are sampled from the repeated-endpoint pool
    (the classic BA trick), giving the expected power-law degree tail.
    """
    if n <= m:
        raise ValueError("need n > m")
    rng = np.random.default_rng(seed)
    src = np.empty((n - m - 1) * m, dtype=np.int64)
    dst = np.empty_like(src)
    # Seed clique-ish core on the first m+1 nodes.
    seed_src = np.repeat(np.arange(m + 1), m + 1)
    seed_dst = np.tile(np.arange(m + 1), m + 1)
    pool = np.concatenate([seed_src, seed_dst]).tolist()
    pool_arr = np.array(pool, dtype=np.int64)
    pool_len = pool_arr.shape[0]
    cap = pool_len + 2 * m * n
    buf = np.empty(cap, dtype=np.int64)
    buf[:pool_len] = pool_arr
    w = 0
    for v in range(m + 1, n):
        picks = buf[rng.integers(0, pool_len, size=m)]
        src[w : w + m] = v
        dst[w : w + m] = picks
        w += m
        buf[pool_len : pool_len + m] = v
        buf[pool_len + m : pool_len + 2 * m] = picks
        pool_len += 2 * m
    edges_src = np.concatenate([seed_src, src])
    edges_dst = np.concatenate([seed_dst, dst])
    return Graph.from_edges(edges_src, edges_dst, n_nodes=n)


def rmat(scale: int, edge_factor: int = 16, a: float = 0.57, b: float = 0.19,
         c: float = 0.19, seed: int = 0) -> Graph:
    """R-MAT/Kronecker generator (Graph500 defaults)."""
    n = 1 << scale
    m = n * edge_factor
    rng = np.random.default_rng(seed)
    src = np.zeros(m, dtype=np.int64)
    dst = np.zeros(m, dtype=np.int64)
    for bit in range(scale):
        r = rng.random(m)
        # Quadrant probabilities a, b, c, d.
        src_bit = r >= (a + b)
        dst_bit = ((r >= a) & (r < a + b)) | (r >= (a + b + c))
        src |= src_bit.astype(np.int64) << bit
        dst |= dst_bit.astype(np.int64) << bit
    return Graph.from_edges(src, dst, n_nodes=n)
