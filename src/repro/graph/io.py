"""Graph IO: npz snapshots and SNAP-style edge-list text files.

``load_edgelist`` accepts the com-friendster format (``u<TAB>v`` per line,
``#`` comments), so the paper's public dataset drops in directly when
present on disk.
"""
from __future__ import annotations

import os

import numpy as np

from repro.graph.structs import Graph


def save_npz(path: str, g: Graph) -> None:
    tmp = path + ".tmp.npz"
    np.savez_compressed(tmp, indptr=g.indptr, indices=g.indices, n_nodes=g.n_nodes)
    os.replace(tmp, path)


def load_npz(path: str) -> Graph:
    z = np.load(path)
    return Graph(indptr=z["indptr"], indices=z["indices"], n_nodes=int(z["n_nodes"]))


def load_edgelist(path: str, n_nodes: int | None = None) -> Graph:
    """Load a whitespace-separated edge list (SNAP format)."""
    src, dst = [], []
    with open(path) as f:
        for line in f:
            if line.startswith("#") or not line.strip():
                continue
            a, b = line.split()[:2]
            src.append(int(a))
            dst.append(int(b))
    return Graph.from_edges(np.array(src, dtype=np.int64), np.array(dst, dtype=np.int64), n_nodes)


def save_edgelist(path: str, g: Graph) -> None:
    src = np.repeat(np.arange(g.n_nodes, dtype=np.int64), g.degrees)
    mask = src < g.indices  # each undirected edge once
    with open(path, "w") as f:
        for u, v in zip(src[mask], g.indices[mask]):
            f.write(f"{u}\t{v}\n")
