"""Graph IO: npz snapshots, SNAP-style edge lists, and streaming ingest.

``load_edgelist`` accepts the com-friendster format (``u<TAB>v`` per line,
``#`` comments), so the paper's public dataset drops in directly when
present on disk.

The **streaming ingest path** builds the same CSR without ever holding the
full edge list in host memory — the out-of-core half of the paper's
limited-resources story (the device half is per-part division):

* :func:`iter_edgelist_chunks` parses an edge-list file into bounded
  ``(src, dst)`` chunks.
* :class:`EdgeStore` spills canonicalized directed slots (self-loops
  dropped, both directions) to disk, tracking duplicate-inclusive degree
  counts and the max node id — enough for
  :func:`~repro.core.divide.plan_thresholds` and Rough-Divide to run before
  (or without) CSR materialization.
* :func:`csr_from_edge_store` finishes the build with an external bucket
  sort: slots are routed into node-range spill bins sized to the chunk
  budget, each bin is deduped independently
  (:func:`~repro.graph.build.finalize_key_bin`), and the deduped runs
  concatenate — in ascending node order — into a CSR **bit-identical** to
  :meth:`Graph.from_edges <repro.graph.structs.Graph.from_edges>`.

Host-resident transient memory is bounded by ``O(chunk + n_nodes)`` plus
the largest spill bin (``~total_slots / max_bins``, and never less than one
node's full adjacency — a row must be materialized to dedup it). The output
CSR itself is of course edge-sized; :class:`IngestStats` reports the
tracked transient peak next to what the in-memory loader would have held.
"""
from __future__ import annotations

import dataclasses
import os
import shutil
import tempfile
from typing import Iterable, Iterator, List, Optional, Tuple

import numpy as np

from repro.graph.build import canonical_slots, finalize_key_bin
from repro.graph.structs import Graph


def save_npz(path: str, g: Graph) -> None:
    tmp = path + ".tmp.npz"
    np.savez_compressed(tmp, indptr=g.indptr, indices=g.indices, n_nodes=g.n_nodes)
    os.replace(tmp, path)


def load_npz(path: str) -> Graph:
    z = np.load(path)
    return Graph(indptr=z["indptr"], indices=z["indices"], n_nodes=int(z["n_nodes"]))


def load_edgelist(path: str, n_nodes: int | None = None) -> Graph:
    """Load a whitespace-separated edge list (SNAP format) fully in memory.

    Shares the line parser with the streaming path
    (:func:`iter_edgelist_chunks`) so the two loaders cannot diverge."""
    src, dst = [], []
    for s, d in iter_edgelist_chunks(path, chunk_edges=2**62):
        src.append(s)
        dst.append(d)
    cat = lambda parts: (  # noqa: E731
        np.concatenate(parts) if parts else np.zeros(0, dtype=np.int64)
    )
    return Graph.from_edges(cat(src), cat(dst), n_nodes)


def save_edgelist(path: str, g: Graph, chunk_edges: int = 1 << 20) -> None:
    """Write each undirected edge once (``u < v``), in bounded chunks — no
    edge-sized source vector is ever materialized."""
    with open(path, "w") as f:
        for src, dst in graph_edge_chunks(g, chunk_edges):
            for u, v in zip(src, dst):
                f.write(f"{u}\t{v}\n")


# --------------------------------------------------------------------- #
# Streaming ingest
# --------------------------------------------------------------------- #

DEFAULT_CHUNK_EDGES = 1 << 20


@dataclasses.dataclass
class IngestStats:
    """Accounting of one streaming CSR build.

    ``peak_transient_bytes`` tracks the live numpy temporaries of the build
    (chunk buffers, spill-bin loads, the persistent ``O(n_nodes)`` count
    arrays) — everything *except* the output CSR, which any loader must
    produce. ``baseline_transient_bytes`` is the array working set the
    in-memory :meth:`Graph.from_edges` path holds for the same input
    (src/dst, the symmetrized u/v copies, the packed keys and their
    ``np.unique`` copy), excluding Python-list parse overhead — i.e. a
    *conservative* baseline. The acceptance gate is
    ``peak_transient_bytes < baseline_transient_bytes``, with the streaming
    side bounded by the chunk budget, not the edge count.
    """

    chunk_edges: int
    n_chunks: int = 0
    input_pairs: int = 0          # edge lines / pairs fed in
    slots_spilled: int = 0        # directed slots written to the spill store
    n_bins: int = 0
    spill_bytes: int = 0          # bytes written to disk across both phases
    peak_transient_bytes: int = 0
    output_bytes: int = 0

    def bump(self, live_bytes: int) -> None:
        self.peak_transient_bytes = max(self.peak_transient_bytes, int(live_bytes))

    @property
    def baseline_transient_bytes(self) -> int:
        # src + dst int64, u + v symmetrized copies, key + unique(key).
        return self.input_pairs * 16 + self.slots_spilled * 8 * 4


class EdgeStore:
    """Append-only on-disk store of canonicalized directed edge slots.

    ``append`` drops self-loops, symmetrizes, and spills both directed
    slots as interleaved ``(u, v)`` int64 pairs; only ``O(chunk)`` is ever
    resident. Alongside the spill it maintains:

    * ``dup_degrees(n)`` — per-node slot counts *including duplicates*
      (an upper bound on the true degree), enough for
      :func:`~repro.core.divide.plan_thresholds` /
      :func:`~repro.core.divide.rough_candidates` to run without the edge
      list or the CSR resident;
    * ``max_id`` — over raw input endpoints (self-loops included, matching
      ``Graph.from_edges`` node-count inference) — and ``max_slot_id`` over
      canonicalized slots only (``from_edges`` range-checks *after*
      dropping self-loops, so an out-of-range id appearing only in a
      self-loop must load, not raise).

    Use as a context manager (or call :meth:`cleanup`) to remove the spill
    directory; :func:`stream_edgelist` does this automatically.
    """

    def __init__(self, workdir: Optional[str] = None):
        self._own_dir = workdir is None
        self.workdir = workdir or tempfile.mkdtemp(prefix="edgestore_")
        os.makedirs(self.workdir, exist_ok=True)
        self.path = os.path.join(self.workdir, "slots.i64")
        self._f = open(self.path, "wb")
        self._counts = np.zeros(1024, dtype=np.int64)
        self.max_id = -1       # over raw endpoints (self-loops included)
        self.max_slot_id = -1  # over canonicalized slots (loops dropped)
        self.n_slots = 0
        self.n_pairs = 0

    # -- ingest ---------------------------------------------------------- #
    def append(self, src: np.ndarray, dst: np.ndarray) -> None:
        src = np.asarray(src, dtype=np.int64)
        dst = np.asarray(dst, dtype=np.int64)
        self.n_pairs += int(src.size)
        if src.size:
            self.max_id = max(
                self.max_id, int(src.max()), int(dst.max())
            )
        u, v = canonical_slots(src, dst)
        if u.size == 0:
            return
        top = int(u.max())
        self.max_slot_id = max(self.max_slot_id, top)
        if top >= self._counts.size:
            grown = np.zeros(max(2 * self._counts.size, top + 1), dtype=np.int64)
            grown[: self._counts.size] = self._counts
            self._counts = grown
        self._counts += np.bincount(u, minlength=self._counts.size)
        pairs = np.empty(2 * u.size, dtype=np.int64)
        pairs[0::2] = u
        pairs[1::2] = v
        pairs.tofile(self._f)
        self.n_slots += int(u.size)

    def dup_degrees(self, n_nodes: int) -> np.ndarray:
        """[n_nodes] duplicate-inclusive slot counts (true degree <= this)."""
        out = np.zeros(n_nodes, dtype=np.int64)
        m = min(n_nodes, self._counts.size)
        out[:m] = self._counts[:m]
        return out

    # -- read back ------------------------------------------------------- #
    def iter_slots(self, chunk_slots: int) -> Iterator[Tuple[np.ndarray, np.ndarray]]:
        """Yield ``(u, v)`` int64 chunks of at most ``chunk_slots`` slots."""
        self.flush()
        chunk_slots = max(1, int(chunk_slots))
        with open(self.path, "rb") as f:
            while True:
                buf = np.fromfile(f, dtype=np.int64, count=2 * chunk_slots)
                if buf.size == 0:
                    return
                yield buf[0::2], buf[1::2]

    def flush(self) -> None:
        if not self._f.closed:
            self._f.flush()

    @property
    def spill_bytes(self) -> int:
        return self.n_slots * 16

    # -- lifecycle ------------------------------------------------------- #
    def cleanup(self) -> None:
        if not self._f.closed:
            self._f.close()
        if self._own_dir:
            shutil.rmtree(self.workdir, ignore_errors=True)

    def __enter__(self) -> "EdgeStore":
        return self

    def __exit__(self, *exc) -> None:
        self.cleanup()


def _plan_bins(counts_dup: np.ndarray, budget_slots: int, max_bins: int) -> np.ndarray:
    """Node-range bin boundaries for the external dedup.

    Returns ascending ``bounds`` with ``bounds[0] == 0`` and
    ``bounds[-1] == n``; bin ``i`` owns sources in
    ``[bounds[i], bounds[i+1])``. Each bin targets at most ``budget_slots``
    duplicate-inclusive slots but never splits a single node (a CSR row is
    deduped whole), and the bin count is capped at ``max_bins`` so a tiny
    chunk budget cannot explode the open-file count — the documented
    transient bound is ``max(chunk, total / max_bins, largest row)``.
    """
    n = counts_dup.size
    total = int(counts_dup.sum())
    if n == 0 or total == 0:
        return np.array([0, n], dtype=np.int64)
    n_bins = int(min(max_bins, max(1, -(-total // max(1, budget_slots)))))
    cum = np.cumsum(counts_dup)
    targets = (np.arange(1, n_bins, dtype=np.float64) * total) / n_bins
    cuts = np.searchsorted(cum, targets, side="left") + 1
    bounds = np.unique(np.concatenate([[0], cuts, [n]]))
    return bounds.astype(np.int64)


def csr_from_edge_store(
    store: EdgeStore,
    n_nodes: Optional[int] = None,
    chunk_edges: int = DEFAULT_CHUNK_EDGES,
    max_bins: int = 256,
    stats: Optional[IngestStats] = None,
    keep_mask: Optional[np.ndarray] = None,
) -> Tuple[Graph, IngestStats]:
    """Materialize the CSR from a spilled :class:`EdgeStore`.

    External bucket sort in two bounded passes over the spill: (1) route
    packed keys into node-range bins planned from the duplicate-inclusive
    degree counts; (2) dedup each bin independently and stream its rows
    into the final ``indices`` file, read back once into the output array.
    Bit-identical to ``Graph.from_edges`` on the same input.

    ``keep_mask`` (``[n_nodes]`` bool) restricts the build to the **induced
    subgraph** on the kept nodes, relabeled ascending — the divide step's
    extraction fused into the same two bounded passes: slots are filtered
    and relabeled on the way into the bins, so the first part of a streamed
    pipeline never materializes the full CSR. Relabeling is monotone and
    ``np.unique``'s order is u-major/v-minor either way, so the result is
    bit-identical to ``induced_subgraph(csr_from_edge_store(store), mask)``
    at every chunk size.
    """
    if stats is None:
        stats = IngestStats(chunk_edges=int(chunk_edges))
    if n_nodes is None:
        n_nodes = store.max_id + 1  # raw max: from_edges infers pre-loop-drop
    n = int(n_nodes)
    if store.max_slot_id >= n:
        # Range check on canonicalized slots only, like from_edges — an
        # out-of-range id appearing only in a dropped self-loop is legal.
        raise ValueError("edge endpoint out of range")
    stats.input_pairs = store.n_pairs
    stats.slots_spilled = store.n_slots

    counts_dup = store.dup_degrees(n)
    if keep_mask is not None:
        keep_mask = np.asarray(keep_mask, dtype=bool)
        if keep_mask.shape != (n,):
            raise ValueError("mask shape mismatch")
        new_id = np.full(n, -1, dtype=np.int64)
        n_out = int(keep_mask.sum())
        new_id[keep_mask] = np.arange(n_out, dtype=np.int64)
        # Dup counts of kept rows (slots into dropped neighbors included —
        # a conservative upper bound is all bin planning needs).
        counts_dup = counts_dup[keep_mask]
    else:
        new_id = None
        n_out = n
    budget_slots = max(1, 2 * int(chunk_edges))
    bounds = _plan_bins(counts_dup, budget_slots, max_bins)
    n_bins = int(bounds.size - 1)
    stats.n_bins = n_bins
    stats.bump(counts_dup.nbytes * 2)  # counts + cumsum in _plan_bins

    bin_dir = os.path.join(store.workdir, "bins")
    os.makedirs(bin_dir, exist_ok=True)
    try:
        # Pass 1: route slots into per-bin key spills (mask-filtered and
        # relabeled first on the induced path).
        bin_files = [
            open(os.path.join(bin_dir, f"bin_{i:05d}.i64"), "wb")
            for i in range(n_bins)
        ]
        try:
            for u, v in store.iter_slots(budget_slots):
                raw_bytes = 0
                if new_id is not None:
                    kept = keep_mask[u] & keep_mask[v]
                    # The unfiltered chunk (u, v, kept mask) is still live
                    # while the filtered copies below exist — count it.
                    raw_bytes = u.nbytes * 2 + kept.nbytes
                    u, v = new_id[u[kept]], new_id[v[kept]]
                key = u * np.int64(n_out) + v
                if n_bins == 1:
                    stats.bump(counts_dup.nbytes + raw_bytes + u.nbytes * 3)
                    key.tofile(bin_files[0])
                else:
                    # Route via one stable sort + contiguous slices —
                    # O(c log c) per chunk, not O(n_bins * c) masking.
                    bi = np.searchsorted(bounds, u, side="right") - 1
                    order = np.argsort(bi, kind="stable")
                    key_sorted = key[order]
                    run_counts = np.bincount(bi, minlength=n_bins)
                    offs = np.concatenate([[0], np.cumsum(run_counts)])
                    stats.bump(counts_dup.nbytes + raw_bytes + u.nbytes * 6)
                    for b in np.nonzero(run_counts)[0]:
                        key_sorted[offs[b] : offs[b + 1]].tofile(bin_files[b])
                stats.spill_bytes += key.nbytes
        finally:
            for f in bin_files:
                f.close()
        stats.spill_bytes += store.spill_bytes

        # Pass 2: dedup each bin in node order; rows concatenate into the
        # final indices stream.
        counts = np.zeros(n_out, dtype=np.int64)
        idx_path = os.path.join(bin_dir, "indices.i32")
        with open(idx_path, "wb") as idx_f:
            for i in range(n_bins):
                keys = np.fromfile(os.path.join(bin_dir, f"bin_{i:05d}.i64"), dtype=np.int64)
                lo, hi = int(bounds[i]), int(bounds[i + 1])
                bin_counts, neigh = finalize_key_bin(keys, n_out, lo, hi)
                counts[lo:hi] = bin_counts
                neigh.tofile(idx_f)
                stats.bump(
                    counts_dup.nbytes + counts.nbytes
                    + keys.nbytes * 2 + bin_counts.nbytes + neigh.nbytes
                )
        indptr = np.zeros(n_out + 1, dtype=np.int64)
        np.cumsum(counts, out=indptr[1:])
        indices = np.fromfile(idx_path, dtype=np.int32)
    finally:
        shutil.rmtree(bin_dir, ignore_errors=True)

    g = Graph(indptr=indptr, indices=indices, n_nodes=n_out)
    stats.output_bytes = g.memory_bytes()
    stats.bump(counts.nbytes + counts_dup.nbytes)
    return g, stats


def induced_subgraph_from_store(
    store: EdgeStore,
    keep_mask: np.ndarray,
    n_nodes: Optional[int] = None,
    chunk_edges: int = DEFAULT_CHUNK_EDGES,
    max_bins: int = 256,
    stats: Optional[IngestStats] = None,
) -> Tuple[Graph, np.ndarray, IngestStats]:
    """Divide-step extraction directly over the spill: the induced subgraph
    on ``keep_mask``, built without the full CSR ever resident.

    Returns ``(subgraph, node_ids, stats)`` with the same
    ``node_ids[new_id] = old_id`` contract as
    :func:`~repro.graph.build.induced_subgraph`, to which the result is
    bit-identical (composed with :func:`csr_from_edge_store` on the same
    store). With :func:`~repro.core.divide.rough_candidates_from_store`
    supplying the mask from the store's duplicate-inclusive degrees, the
    first (densest) part of a streamed DC-kCore run goes edge-list ->
    part CSR under the chunk budget end to end.
    """
    if n_nodes is None:
        n_nodes = store.max_id + 1
    keep_mask = np.asarray(keep_mask, dtype=bool)
    g, stats = csr_from_edge_store(
        store, n_nodes, chunk_edges=chunk_edges, max_bins=max_bins,
        stats=stats, keep_mask=keep_mask,
    )
    return g, np.nonzero(keep_mask)[0].astype(np.int64), stats


def csr_from_edge_chunks(
    chunks: Iterable[Tuple[np.ndarray, np.ndarray]],
    n_nodes: Optional[int] = None,
    chunk_edges: int = DEFAULT_CHUNK_EDGES,
    max_bins: int = 256,
    workdir: Optional[str] = None,
) -> Tuple[Graph, IngestStats]:
    """Chunked equivalent of ``Graph.from_edges``: consume an iterable of
    bounded ``(src, dst)`` chunks and return the bit-identical CSR plus
    :class:`IngestStats`. The full edge list is never resident — chunks are
    spilled through an :class:`EdgeStore` and deduped externally.
    """
    stats = IngestStats(chunk_edges=int(chunk_edges))
    with EdgeStore(workdir=workdir) as store:
        for src, dst in chunks:
            store.append(src, dst)
            stats.n_chunks += 1
            stats.bump(np.asarray(src).size * 8 * 6 + store._counts.nbytes)
        return csr_from_edge_store(
            store, n_nodes, chunk_edges=chunk_edges, max_bins=max_bins, stats=stats
        )


def iter_edgelist_chunks(
    path: str, chunk_edges: int = DEFAULT_CHUNK_EDGES
) -> Iterator[Tuple[np.ndarray, np.ndarray]]:
    """Parse a SNAP edge list into bounded ``(src, dst)`` int64 chunks.

    Same line semantics as :func:`load_edgelist` (``#`` comments and blank
    lines skipped, first two whitespace tokens per line).
    """
    chunk_edges = max(1, int(chunk_edges))
    src: List[int] = []
    dst: List[int] = []
    with open(path) as f:
        for line in f:
            if line.startswith("#") or not line.strip():
                continue
            a, b = line.split()[:2]
            src.append(int(a))
            dst.append(int(b))
            if len(src) >= chunk_edges:
                yield np.array(src, dtype=np.int64), np.array(dst, dtype=np.int64)
                src, dst = [], []
    if src:
        yield np.array(src, dtype=np.int64), np.array(dst, dtype=np.int64)


def stream_edgelist(
    path: str,
    n_nodes: Optional[int] = None,
    chunk_edges: int = DEFAULT_CHUNK_EDGES,
    max_bins: int = 256,
    workdir: Optional[str] = None,
) -> Tuple[Graph, IngestStats]:
    """Streaming counterpart of :func:`load_edgelist`.

    Reads the file in ``chunk_edges``-sized chunks, spills through an
    :class:`EdgeStore`, and materializes the CSR with the external dedup —
    bit-identical to ``load_edgelist(path, n_nodes)`` at every chunk size.
    """
    return csr_from_edge_chunks(
        iter_edgelist_chunks(path, chunk_edges),
        n_nodes=n_nodes,
        chunk_edges=chunk_edges,
        max_bins=max_bins,
        workdir=workdir,
    )


def graph_edge_chunks(
    g: Graph, chunk_edges: int = DEFAULT_CHUNK_EDGES
) -> Iterator[Tuple[np.ndarray, np.ndarray]]:
    """Yield a graph's undirected edges (each once, ``u < v``) in bounded
    chunks — the adapter that lets synthetic/in-memory graphs exercise and
    benchmark the streaming build path."""
    chunk_edges = max(1, int(chunk_edges))
    n = g.n_nodes
    row = 0
    src_buf: List[np.ndarray] = []
    dst_buf: List[np.ndarray] = []
    buffered = 0
    while row < n:
        # Grow the row window until it holds at least one chunk of slots.
        hi = row
        while hi < n and int(g.indptr[hi + 1] - g.indptr[row]) < 2 * chunk_edges:
            hi += 1
        hi = min(max(hi, row + 1), n)
        lo_ptr, hi_ptr = int(g.indptr[row]), int(g.indptr[hi])
        cols = g.indices[lo_ptr:hi_ptr].astype(np.int64)
        srcs = np.repeat(
            np.arange(row, hi, dtype=np.int64),
            np.diff(g.indptr[row : hi + 1]).astype(np.int64),
        )
        keep = srcs < cols  # each undirected edge exactly once
        srcs, cols = srcs[keep], cols[keep]
        src_buf.append(srcs)
        dst_buf.append(cols)
        buffered += int(srcs.size)
        row = hi
        while buffered >= chunk_edges or (row >= n and buffered > 0):
            src = np.concatenate(src_buf) if len(src_buf) > 1 else src_buf[0]
            dst = np.concatenate(dst_buf) if len(dst_buf) > 1 else dst_buf[0]
            yield src[:chunk_edges], dst[:chunk_edges]
            src_buf, dst_buf = [src[chunk_edges:]], [dst[chunk_edges:]]
            buffered = int(src_buf[0].size)
