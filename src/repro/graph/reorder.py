"""Locality-aware node reordering: BFS and reverse Cuthill–McKee.

Node ids in real (and synthetic power-law) graphs are arbitrary, so the
neighbors of a degree-bucket row-tile are scattered across the whole id
range and the bucket-adjacency bitmap recorded at bucketize time is
near-dense: the static frontier filter almost never fires and the row-exact
dirty bits do all the skipping (PR 1's observation). Both the paper's
divide-and-conquer strategy (arXiv 2112.14840) and Montresor et al.'s
distributed k-core argument (arXiv 1103.5320) lean on neighborhoods being
co-located; a one-shot reordering pass at build time makes that true for
our tiles:

* :func:`bfs_order` — level-synchronous breadth-first order from the
  highest-degree node of each component. Neighbors land in the same or the
  adjacent BFS level, so a contiguous run of ids spans few levels.
* :func:`rcm_order` — reverse Cuthill–McKee: Cuthill–McKee from a
  low-degree (pseudo-peripheral) start, children visited in
  (parent-rank, degree) order, whole order reversed. The classic
  bandwidth-minimizing order; neighbor ids cluster tightest here.

Both return a permutation ``perm`` with ``perm[new_id] = old_id`` (so
``inv_perm[old_id] = new_id`` is its argsort). :func:`reorder_graph`
applies one to a :class:`~repro.graph.structs.Graph` and records
``perm``/``inv_perm`` on the result; ``bucketize`` propagates them onto the
:class:`~repro.graph.structs.BucketedGraph` and the decompose engines
un-permute their coreness output transparently, so *every caller keeps
original-id semantics end to end* — reordering is purely a layout decision.

Degree-0 nodes are appended at the end of every order (they join no bucket
and their coreness is fixed at ``ext`` from the start).

:func:`bitmap_density` is the metric the pass optimizes: the fraction of
set bits in the bucket-adjacency bitmap, i.e. how often the static frontier
filter *cannot* rule out a tile. Lower is better; ``bench_kcore`` fig13
reports it ordered vs. unordered.

For paper-scale parts the full traversal's working set (frontier arrays +
the whole CSR) is itself a resource problem, so :func:`sampled_order`
computes the same BFS/RCM orders from a bounded **edge-sample skeleton**:
every positive-degree node keeps at least one (and at most
``edge_budget // n`` evenly-strided) neighbors, so the traversal touches
``O(max(n, edge_budget))`` slots instead of ``O(m)`` while still producing
a full, valid permutation. ``reorder_graph(..., sample_edges=...)``
plumbs it through; the trade is a denser bitmap than the exact order, by a
bounded factor on the power-law fixtures (pinned in tests).
"""
from __future__ import annotations

from typing import Optional

import numpy as np

from repro.graph.structs import BucketedGraph, Graph

REORDER_METHODS = ("identity", "bfs", "rcm")


def _flat_neighbors(g: Graph, frontier: np.ndarray):
    """Concatenated adjacency of ``frontier`` plus the parent rank of each
    slot, without a Python loop over frontier nodes."""
    starts = g.indptr[frontier]
    lens = (g.indptr[frontier + 1] - starts).astype(np.int64)
    total = int(lens.sum())
    if total == 0:
        return np.zeros(0, np.int64), np.zeros(0, np.int64)
    # Standard CSR flat-gather trick: per-slot index = slot rank + the gap
    # between each row's start and the running total of previous rows.
    shift = np.repeat(starts - np.concatenate([[0], np.cumsum(lens)[:-1]]), lens)
    flat = g.indices[np.arange(total, dtype=np.int64) + shift].astype(np.int64)
    parent_rank = np.repeat(np.arange(frontier.size, dtype=np.int64), lens)
    return flat, parent_rank


def _level_order(g: Graph, *, degree_sorted_children: bool, start_low_degree: bool) -> np.ndarray:
    """Level-synchronous (Cuthill–McKee-style) traversal over all components.

    Returns the visitation order (``order[i] = old id``) of all nodes with
    degree > 0; isolated nodes are NOT included (callers append them).
    """
    n = g.n_nodes
    deg = g.degrees.astype(np.int64)
    visited = np.zeros(n, dtype=bool)
    out = np.empty(int((deg > 0).sum()), dtype=np.int64)
    pos = 0
    # Component seeds in degree order (ascending for CM, descending for BFS);
    # a single pointer sweep keeps seed selection O(n log n) total.
    seeds = np.argsort(deg if start_low_degree else -deg, kind="stable")
    seeds = seeds[deg[seeds] > 0]
    si = 0
    while pos < out.size:
        while si < seeds.size and visited[seeds[si]]:
            si += 1
        start = int(seeds[si])
        visited[start] = True
        out[pos] = start
        pos += 1
        frontier = np.array([start], dtype=np.int64)
        while frontier.size:
            flat, parent_rank = _flat_neighbors(g, frontier)
            fresh = ~visited[flat]
            cand, pr = flat[fresh], parent_rank[fresh]
            if cand.size == 0:
                break
            if degree_sorted_children:
                # Cuthill–McKee: children grouped by parent visitation rank,
                # lowest-degree first within each group.
                cand = cand[np.lexsort((deg[cand], pr))]
            # else: adjacency order within parent groups (flat gather already
            # emits slots grouped by parent rank) — plain BFS.
            # First-occurrence dedup that respects the order just established.
            _, first = np.unique(cand, return_index=True)
            level = cand[np.sort(first)]
            visited[level] = True
            out[pos : pos + level.size] = level
            pos += level.size
            frontier = level
    return out


def bfs_order(g: Graph) -> np.ndarray:
    """BFS visitation order (``perm[new_id] = old_id``), hubs first.

    Each component is traversed from its highest-degree node; degree-0 nodes
    are appended at the end in ascending id order.
    """
    core = _level_order(g, degree_sorted_children=False, start_low_degree=False)
    isolated = np.nonzero(g.degrees == 0)[0].astype(np.int64)
    return np.concatenate([core, isolated])


def rcm_order(g: Graph) -> np.ndarray:
    """Reverse Cuthill–McKee order (``perm[new_id] = old_id``).

    Cuthill–McKee from the lowest-degree node of each component with
    degree-sorted children, reversed; degree-0 nodes appended at the end
    (outside the reversal — they carry no adjacency to compress).
    """
    core = _level_order(g, degree_sorted_children=True, start_low_degree=True)
    isolated = np.nonzero(g.degrees == 0)[0].astype(np.int64)
    return np.concatenate([core[::-1], isolated])


def invert_order(perm: np.ndarray) -> np.ndarray:
    """``inv_perm`` with ``inv_perm[perm] == arange(n)``."""
    inv = np.empty(perm.size, dtype=np.int64)
    inv[perm] = np.arange(perm.size, dtype=np.int64)
    return inv


def sample_edge_skeleton(g: Graph, edge_budget: int) -> Graph:
    """Bounded edge-sample skeleton of ``g`` for out-of-core ordering.

    Deterministic per-row strided sampling: every node of degree > 0 keeps
    ``min(deg, k)`` neighbors at evenly-spaced positions of its (sorted)
    adjacency row, with ``k = max(1, edge_budget // n_pos)``. Evenly-strided
    picks cover the row's id span, which is what the orders care about; the
    per-node floor of one neighbor guarantees no positive-degree node is
    isolated in the skeleton, so the skeleton traversal places *every* node.
    Sampled slots number ``<= max(n_pos, edge_budget)``.
    """
    deg = g.degrees.astype(np.int64)
    rows = np.nonzero(deg > 0)[0].astype(np.int64)
    if rows.size == 0:
        return Graph.empty(g.n_nodes)
    k = max(1, int(edge_budget) // rows.size)
    kv = np.minimum(deg[rows], k)
    total = int(kv.sum())
    row_rep = np.repeat(rows, kv)
    kv_rep = np.repeat(kv, kv)
    # j-th pick of each row: position floor(j * deg / kv) within the row.
    j = np.arange(total, dtype=np.int64) - np.repeat(
        np.concatenate([[0], np.cumsum(kv)[:-1]]), kv
    )
    pos = (j * deg[row_rep]) // kv_rep
    picked = g.indices[g.indptr[row_rep] + pos].astype(np.int64)
    return Graph.from_edges(row_rep, picked, n_nodes=g.n_nodes)


def sampled_order(g: Graph, method: str = "rcm", edge_budget: int = 1 << 20) -> np.ndarray:
    """BFS/RCM order computed from an edge sample under a slot budget.

    The ROADMAP out-of-core follow-up: the exact orders traverse the full
    CSR, which at paper scale does not fit next to the part being built.
    This computes the same traversal on the :func:`sample_edge_skeleton`
    (``O(max(n, edge_budget))`` slots) and returns a full valid permutation
    over all ``n`` nodes — nodes isolated in ``g`` are appended at the end
    exactly as in the exact orders.
    """
    if method not in ("bfs", "rcm"):
        raise ValueError(f"sampled order needs 'bfs' or 'rcm', got {method!r}")
    skel = sample_edge_skeleton(g, edge_budget)
    return bfs_order(skel) if method == "bfs" else rcm_order(skel)


def permute_graph(g: Graph, perm: np.ndarray) -> Graph:
    """Relabel ``g``'s CSR by ``perm`` (``perm[new_id] = old_id``),
    recording ``perm``/``inv_perm`` on the result."""
    inv = invert_order(perm)
    n = g.n_nodes
    # Relabel the symmetric CSR directly — a bijection needs no re-dedup.
    src = inv[np.repeat(np.arange(n, dtype=np.int64), g.degrees)]
    dst = inv[g.indices]
    order = np.lexsort((dst, src))
    counts = np.bincount(src, minlength=n)
    indptr = np.zeros(n + 1, dtype=np.int64)
    np.cumsum(counts, out=indptr[1:])
    return Graph(
        indptr=indptr,
        indices=dst[order].astype(np.int32),
        n_nodes=n,
        perm=perm,
        inv_perm=inv,
    )


def reorder_graph(g: Graph, method: str = "rcm", sample_edges: Optional[int] = None) -> Graph:
    """Relabel ``g`` by a locality-aware order, recording the permutation.

    ``method`` is one of ``"identity"`` (returns ``g`` unchanged), ``"bfs"``
    or ``"rcm"``. The returned graph's CSR is in the new id space; its
    ``perm``/``inv_perm`` fields let downstream components translate back,
    which :func:`~repro.graph.build.bucketize` and both decompose engines do
    automatically — callers keep original-id semantics throughout.

    ``sample_edges`` switches the *ordering computation* to the sampled
    variant (:func:`sampled_order`) under that slot budget — the traversal's
    working set stops scaling with ``m``. The relabeling itself still
    touches the whole CSR (it has to produce the reordered graph).

    Reordering an already-reordered graph is rejected: permutations would
    have to be composed and no call site needs that.
    """
    if method == "identity":
        return g
    if method not in REORDER_METHODS:
        raise ValueError(f"unknown reorder method {method!r}; pick from {REORDER_METHODS}")
    if g.perm is not None:
        raise ValueError("graph is already reordered; compose orders explicitly if needed")
    if sample_edges is not None:
        perm = sampled_order(g, method, edge_budget=sample_edges)
    else:
        perm = bfs_order(g) if method == "bfs" else rcm_order(g)
    return permute_graph(g, perm)


def bitmap_density(bg: BucketedGraph) -> float:
    """Fraction of set bits in the bucket-adjacency bitmap (1.0 = the static
    frontier filter can never rule out any tile; lower = sparser = better).

    1.0 for graphs with fewer than two tiles (nothing to filter)."""
    adj = bg.bucket_adjacency()
    if adj.size <= 1:
        return 1.0
    return float(adj.mean())


def neighbor_spans(g: Graph) -> np.ndarray:
    """Per-node neighbor-id span ``max(N(v)) - min(N(v)) + 1`` (0 for
    isolated nodes) — the locality profile the tile autotuner reads.

    CSR rows are sorted by construction (``from_edges`` packs and sorts,
    relabeling is monotone or re-sorted), so the span is last-minus-first.
    """
    span = np.zeros(g.n_nodes, dtype=np.int64)
    nz = np.nonzero(g.degrees > 0)[0]
    span[nz] = (
        g.indices[g.indptr[nz + 1] - 1].astype(np.int64)
        - g.indices[g.indptr[nz]].astype(np.int64)
        + 1
    )
    return span
