"""Graph substrate: containers, generators, oracles and IO.

Host-side graphs are numpy CSR (``Graph``); device-side graphs are
degree-bucketed padded adjacency tiles (``BucketedGraph``) built by
:mod:`repro.graph.build` for MXU/VPU-friendly dense compute.
"""
from repro.graph.structs import Graph, BucketedGraph, Bucket
from repro.graph.build import bucketize, induced_subgraph, external_info
from repro.graph.generators import erdos_renyi, barabasi_albert, rmat
from repro.graph.oracle import peel_coreness, nx_coreness

__all__ = [
    "Graph",
    "BucketedGraph",
    "Bucket",
    "bucketize",
    "induced_subgraph",
    "external_info",
    "erdos_renyi",
    "barabasi_albert",
    "rmat",
    "peel_coreness",
    "nx_coreness",
]
