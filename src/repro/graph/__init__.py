"""Graph substrate: containers, generators, oracles and IO.

Host-side graphs are numpy CSR (``Graph``); device-side graphs are
degree-bucketed padded adjacency tiles (``BucketedGraph``) built by
:mod:`repro.graph.build` for MXU/VPU-friendly dense compute.
"""
from repro.graph.structs import Graph, BucketedGraph, Bucket
from repro.graph.build import autotune_tile_caps, bucketize, induced_subgraph, external_info
from repro.graph.generators import erdos_renyi, barabasi_albert, rmat
from repro.graph.io import (
    EdgeStore,
    IngestStats,
    csr_from_edge_chunks,
    graph_edge_chunks,
    iter_edgelist_chunks,
    stream_edgelist,
)
from repro.graph.oracle import peel_coreness, nx_coreness
from repro.graph.reorder import (
    REORDER_METHODS,
    bfs_order,
    bitmap_density,
    rcm_order,
    reorder_graph,
    sample_edge_skeleton,
    sampled_order,
)

__all__ = [
    "Graph",
    "BucketedGraph",
    "Bucket",
    "autotune_tile_caps",
    "bucketize",
    "induced_subgraph",
    "external_info",
    "erdos_renyi",
    "barabasi_albert",
    "rmat",
    "EdgeStore",
    "IngestStats",
    "csr_from_edge_chunks",
    "graph_edge_chunks",
    "iter_edgelist_chunks",
    "stream_edgelist",
    "peel_coreness",
    "nx_coreness",
    "REORDER_METHODS",
    "bfs_order",
    "bitmap_density",
    "rcm_order",
    "reorder_graph",
    "sample_edge_skeleton",
    "sampled_order",
]
