"""Graph containers.

``Graph`` is the host-side representation: undirected simple graph in CSR
form (numpy, int32). Construction symmetrizes, removes self-loops and
deduplicates parallel edges, so every downstream component can assume a
simple undirected graph — the setting of the paper.

``BucketedGraph`` is the device-ready representation: nodes are grouped by
degree into power-of-two-width buckets and each bucket's adjacency is padded
to a dense ``[nodes, width]`` tile. Dense tiles are what the TPU wants
(lane-aligned loads, compare-and-reduce on the VPU) and bound the padding
overhead by 2x; this replaces the paper's vertex-centric RDD partitions.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence

import numpy as np


@dataclasses.dataclass(frozen=True)
class Graph:
    """Undirected simple graph in CSR form.

    Attributes:
      indptr:  ``[n_nodes + 1]`` int64 row offsets.
      indices: ``[2 * n_edges]`` int32 neighbor ids (both directions stored).
      n_nodes: number of vertices.
      perm:    optional ``[n_nodes]`` int64 layout permutation,
               ``perm[new_id] = old_id`` — set by
               :func:`~repro.graph.reorder.reorder_graph` when the CSR has
               been relabeled into a locality-aware order. ``None`` means
               the CSR is in original-id order.
      inv_perm: the inverse (``inv_perm[old_id] = new_id``); set iff
               ``perm`` is.

    When ``perm`` is set, the CSR arrays index *new* (reordered) ids, but
    the public contract stays original-id: :func:`~repro.graph.build.bucketize`
    permutes ``ext`` inputs in, and the decompose engines permute coreness
    outputs back, so callers never see reordered ids.
    """

    indptr: np.ndarray
    indices: np.ndarray
    n_nodes: int
    perm: Optional[np.ndarray] = None
    inv_perm: Optional[np.ndarray] = None

    # ------------------------------------------------------------------ #
    # Constructors
    # ------------------------------------------------------------------ #
    @staticmethod
    def from_edges(src: np.ndarray, dst: np.ndarray, n_nodes: Optional[int] = None) -> "Graph":
        """Build from a (possibly directed / duplicated) edge list.

        Self-loops are dropped; the edge set is symmetrized and deduplicated.
        Expressed through the same chunk-level steps the streaming ingest
        uses (:func:`~repro.graph.build.canonical_slots` +
        :func:`~repro.graph.build.finalize_key_bin` over the single bin
        ``[0, n)``), so the two build paths are bit-identical by
        construction, not just by test.
        """
        # Late import: build.py imports this module at load time.
        from repro.graph.build import canonical_slots, finalize_key_bin

        src = np.asarray(src, dtype=np.int64)
        dst = np.asarray(dst, dtype=np.int64)
        if n_nodes is None:
            n_nodes = int(max(src.max(initial=-1), dst.max(initial=-1)) + 1)
        u, v = canonical_slots(src, dst)
        if u.size and max(u.max(), v.max()) >= n_nodes:
            raise ValueError("edge endpoint out of range")
        counts, indices = finalize_key_bin(
            u * np.int64(n_nodes) + v, int(n_nodes), 0, int(n_nodes)
        )
        indptr = np.zeros(n_nodes + 1, dtype=np.int64)
        np.cumsum(counts, out=indptr[1:])
        return Graph(indptr=indptr, indices=indices, n_nodes=int(n_nodes))

    @staticmethod
    def empty(n_nodes: int) -> "Graph":
        return Graph(
            indptr=np.zeros(n_nodes + 1, dtype=np.int64),
            indices=np.zeros(0, dtype=np.int32),
            n_nodes=n_nodes,
        )

    # ------------------------------------------------------------------ #
    # Views
    # ------------------------------------------------------------------ #
    @property
    def n_edges(self) -> int:
        """Number of undirected edges."""
        return self.indices.shape[0] // 2

    @property
    def degrees(self) -> np.ndarray:
        return np.diff(self.indptr).astype(np.int32)

    def neighbors(self, v: int) -> np.ndarray:
        return self.indices[self.indptr[v] : self.indptr[v + 1]]

    def memory_bytes(self) -> int:
        """Host bytes of the CSR arrays (the paper's 'resource' unit)."""
        return self.indptr.nbytes + self.indices.nbytes

    def validate(self) -> None:
        deg = self.degrees
        assert deg.min(initial=0) >= 0
        assert self.indptr[-1] == self.indices.shape[0]
        if self.indices.size:
            assert self.indices.min() >= 0 and self.indices.max() < self.n_nodes


@dataclasses.dataclass(frozen=True)
class Bucket:
    """A degree bucket of padded dense adjacency.

    Attributes:
      node_ids:  ``[nb]`` int32 original node ids (padded rows use the
                 sentinel id ``n_nodes``).
      neigh:     ``[nb, width]`` int32 neighbor ids, padded with ``n_nodes``
                 (the sentinel row of the gathered coreness vector).
      deg:       ``[nb]`` int32 true in-part degree per row (0 for pad rows).
      width:     static pad width (power of two).
    """

    node_ids: np.ndarray
    neigh: np.ndarray
    deg: np.ndarray
    width: int

    @property
    def n_rows(self) -> int:
        return self.node_ids.shape[0]

    def memory_bytes(self) -> int:
        return self.node_ids.nbytes + self.neigh.nbytes + self.deg.nbytes


@dataclasses.dataclass(frozen=True)
class BucketedGraph:
    """Degree-bucketed padded adjacency for a (sub)graph part.

    ``ext`` carries the paper's *external information* E(v) per node
    (``0`` for a monolithic decomposition). ``n_nodes`` is the node count of
    the part; neighbor ids in buckets index into ``[0, n_nodes]`` where
    ``n_nodes`` is the padding sentinel.

    ``bucket_adj`` is the symmetric ``[n_buckets, n_buckets]`` bool bitmap of
    bucket adjacency: ``bucket_adj[i, j]`` iff some node in bucket ``i`` has
    a neighbor in bucket ``j`` (diagonal always set). Computed once at
    :func:`~repro.graph.build.bucketize` time, it makes active-frontier sweep
    scheduling *sound*: a bucket whose own rows and whose adjacent buckets
    were all quiescent last sweep cannot change this sweep, so the engines
    skip its gather + h-index outright.

    ``perm``/``inv_perm`` (propagated from a reordered source
    :class:`Graph`) record the layout permutation the tiles were built in:
    node ids inside the buckets are *new* (reordered) ids, ``ext`` and
    ``degrees`` are stored in new-id order, and the decompose engines gather
    ``coreness[inv_perm]`` on the way out so results are reported in
    original-id order. ``None`` = identity layout.
    """

    n_nodes: int
    buckets: List[Bucket]
    ext: np.ndarray  # [n_nodes] int32
    degrees: np.ndarray  # [n_nodes] int32, in-part degree
    bucket_adj: Optional[np.ndarray] = None  # [n_buckets, n_buckets] bool
    node_bucket: Optional[np.ndarray] = None  # [n_nodes + 1] int32, -1 = none
    perm: Optional[np.ndarray] = None  # [n_nodes] int64, new -> old
    inv_perm: Optional[np.ndarray] = None  # [n_nodes] int64, old -> new

    def memory_bytes(self) -> int:
        return int(
            sum(b.memory_bytes() for b in self.buckets) + self.ext.nbytes + self.degrees.nbytes
        )

    def bucket_adjacency(self) -> np.ndarray:
        """The bucket-adjacency bitmap; all-True (always rescan every bucket,
        the pre-frontier behavior) when none was recorded at build time."""
        nb = len(self.buckets)
        if self.bucket_adj is not None:
            assert self.bucket_adj.shape == (nb, nb)
            return self.bucket_adj
        return np.ones((nb, nb), dtype=bool)

    def node_bucket_map(self) -> np.ndarray:
        """[n_nodes + 1] node -> owning bucket index (-1 for degree-0 nodes
        and the sentinel slot). Recorded at bucketize time; derived from the
        buckets when absent (hand-built instances)."""
        if self.node_bucket is not None:
            return self.node_bucket
        m = np.full(self.n_nodes + 1, -1, dtype=np.int32)
        for bi, b in enumerate(self.buckets):
            real = b.node_ids[b.node_ids < self.n_nodes]
            m[real] = bi
        return m

    @property
    def rows_per_full_sweep(self) -> int:
        """Bucket rows a full (non-frontier) sweep gathers, padding included."""
        return int(sum(b.n_rows for b in self.buckets))

    @property
    def widths(self) -> Sequence[int]:
        return [b.width for b in self.buckets]

    @property
    def padded_slots(self) -> int:
        return int(sum(b.neigh.size for b in self.buckets))
