"""Coreness oracles for correctness testing.

:func:`peel_coreness` is the Batagelj–Zaversnik bucket-queue peeling
algorithm (O(n + m), numpy) — the classical exact algorithm the paper's
Section 2 starts from. :func:`nx_coreness` wraps networkx as an independent
second opinion; tests cross-check all engines against these.

:func:`peel_kcore_mask` extracts the exact k-core membership mask — the
paper's *Exact-Divide* extraction primitive.
"""
from __future__ import annotations

import numpy as np

from repro.graph.structs import Graph


def peel_coreness(g: Graph) -> np.ndarray:
    """Exact coreness via BZ peeling. Returns ``[n_nodes]`` int32."""
    n = g.n_nodes
    deg = g.degrees.astype(np.int64).copy()
    indptr, indices = g.indptr, g.indices

    # Bucket sort nodes by degree.
    max_deg = int(deg.max(initial=0))
    bin_start = np.zeros(max_deg + 2, dtype=np.int64)
    np.cumsum(np.bincount(deg, minlength=max_deg + 1), out=bin_start[1:])
    order = np.argsort(deg, kind="stable").astype(np.int64)
    pos = np.empty(n, dtype=np.int64)
    pos[order] = np.arange(n)
    bin_ptr = bin_start[:-1].copy()  # current start of each degree bin

    core = deg.copy()
    for i in range(n):
        v = order[i]
        dv = core[v]
        for u in indices[indptr[v] : indptr[v + 1]]:
            if core[u] > dv:
                du = core[u]
                # Swap u with the first node of its bin, shrink the bin.
                pu, pw = pos[u], bin_ptr[du]
                w = order[pw]
                if u != w:
                    order[pu], order[pw] = w, u
                    pos[u], pos[w] = pw, pu
                bin_ptr[du] += 1
                core[u] -= 1
    return core.astype(np.int32)


def nx_coreness(g: Graph) -> np.ndarray:
    """networkx cross-check (slow; tests only)."""
    import networkx as nx

    G = nx.Graph()
    G.add_nodes_from(range(g.n_nodes))
    src = np.repeat(np.arange(g.n_nodes), g.degrees)
    G.add_edges_from(zip(src.tolist(), g.indices.tolist()))
    cores = nx.core_number(G)
    return np.array([cores[i] for i in range(g.n_nodes)], dtype=np.int32)


def peel_kcore_mask(g: Graph, k: int) -> np.ndarray:
    """Exact k-core membership mask by iterative removal of deg<k nodes."""
    alive = np.ones(g.n_nodes, dtype=bool)
    deg = g.degrees.astype(np.int64).copy()
    src = np.repeat(np.arange(g.n_nodes, dtype=np.int64), g.degrees)
    frontier = np.nonzero(alive & (deg < k))[0]
    while frontier.size:
        alive[frontier] = False
        f = np.zeros(g.n_nodes, dtype=bool)
        f[frontier] = True
        # Decrement degrees of alive neighbors of removed nodes.
        hits = f[src] & alive[g.indices]
        dec = np.bincount(g.indices[hits], minlength=g.n_nodes)
        deg -= dec
        frontier = np.nonzero(alive & (deg < k) & (dec > 0))[0]
    return alive
