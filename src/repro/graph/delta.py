"""CSR delta application — edge churn without a full rebuild.

``apply_edge_deltas`` applies one batch of edge inserts/deletes to a
:class:`~repro.graph.structs.Graph` by rebuilding ONLY the CSR rows of the
edit endpoints; every untouched row is block-copied. The edited rows go
through the same canonicalization the builders use
(:func:`~repro.graph.build.canonical_slots` symmetrize-and-drop-loops,
``np.unique``-sorted packed keys), so the output is **bit-identical** to
:meth:`Graph.from_edges` on the post-edit edge set — the invariant the
incremental maintenance engine (:mod:`repro.core.incremental`) and its
differential tests rest on.

Batch semantics are set-like: the new edge set is ``(E \\ deletes) ∪
inserts`` (an edge both deleted and inserted in one batch survives).
Deleting an absent edge and inserting a present one are no-ops; the
*effective* edits — the edges that actually flipped — are reported
separately because the dirty-region bounds of the incremental engine are
only as tight as the effective batch size ``b``.

Inserts may reference node ids beyond ``n_nodes``; the graph grows (new
trailing rows), mirroring a social graph gaining users. Deletes never grow
the id space.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from repro.graph.build import canonical_slots
from repro.graph.structs import Graph


@dataclasses.dataclass(frozen=True)
class EdgeEdits:
    """One batch of raw edge edits (directed/duplicated input is fine).

    Arrays are int64; self-loops and duplicates are tolerated and
    canonicalized away at apply time, exactly like builder input.
    """

    ins_src: np.ndarray
    ins_dst: np.ndarray
    del_src: np.ndarray
    del_dst: np.ndarray

    @staticmethod
    def of(ins_src=(), ins_dst=(), del_src=(), del_dst=()) -> "EdgeEdits":
        return EdgeEdits(
            ins_src=np.asarray(ins_src, dtype=np.int64),
            ins_dst=np.asarray(ins_dst, dtype=np.int64),
            del_src=np.asarray(del_src, dtype=np.int64),
            del_dst=np.asarray(del_dst, dtype=np.int64),
        )

    @staticmethod
    def inserts(src, dst) -> "EdgeEdits":
        return EdgeEdits.of(ins_src=src, ins_dst=dst)

    @staticmethod
    def deletes(src, dst) -> "EdgeEdits":
        return EdgeEdits.of(del_src=src, del_dst=dst)

    @property
    def n_raw(self) -> int:
        return int(self.ins_src.size + self.del_src.size)

    def concat(self, other: "EdgeEdits") -> "EdgeEdits":
        return EdgeEdits(
            ins_src=np.concatenate([self.ins_src, other.ins_src]),
            ins_dst=np.concatenate([self.ins_dst, other.ins_dst]),
            del_src=np.concatenate([self.del_src, other.del_src]),
            del_dst=np.concatenate([self.del_dst, other.del_dst]),
        )


@dataclasses.dataclass(frozen=True)
class DeltaResult:
    """Outcome of one delta application.

    ``ins_u``/``ins_v`` and ``del_u``/``del_v`` hold the EFFECTIVE
    undirected edits (``u < v``, deduplicated, no-ops removed): exactly the
    edges present in the new graph but not the old, and vice versa.
    ``rows_rebuilt`` counts CSR rows rewritten (the edit endpoints).
    """

    graph: Graph
    ins_u: np.ndarray
    ins_v: np.ndarray
    del_u: np.ndarray
    del_v: np.ndarray
    rows_rebuilt: int

    @property
    def n_inserted(self) -> int:
        return int(self.ins_u.size)

    @property
    def n_deleted(self) -> int:
        return int(self.del_u.size)

    @property
    def n_effective(self) -> int:
        return self.n_inserted + self.n_deleted


def _row_slot_indices(indptr: np.ndarray, rows: np.ndarray) -> np.ndarray:
    """Concatenated CSR slot indices of ``rows`` (ascending row order)."""
    counts = (indptr[rows + 1] - indptr[rows]).astype(np.int64)
    keep = counts > 0
    rows, counts = rows[keep], counts[keep]
    total = int(counts.sum())
    if total == 0:
        return np.empty(0, dtype=np.int64)
    step = np.ones(total, dtype=np.int64)
    starts = indptr[rows].astype(np.int64)
    ends = np.cumsum(counts)
    step[0] = starts[0]
    step[ends[:-1]] = starts[1:] - (starts[:-1] + counts[:-1] - 1)
    return np.cumsum(step)


def apply_edge_deltas(
    g: Graph, edits: EdgeEdits, n_nodes: Optional[int] = None
) -> DeltaResult:
    """Apply one edit batch; returns the new graph + effective edits.

    Only the rows of edit endpoints are rebuilt (sorted-unique neighbor
    order, same as :meth:`Graph.from_edges`); all other rows are copied as
    contiguous blocks. ``n_nodes`` forces the output node count (must cover
    every insert endpoint); by default the graph grows to the max raw
    insert endpoint, ``from_edges``-style.
    """
    if g.perm is not None:
        raise ValueError(
            "apply_edge_deltas operates on original-id CSRs; reorder after "
            "applying deltas, not before"
        )
    ins_max = int(max(
        edits.ins_src.max(initial=-1), edits.ins_dst.max(initial=-1)
    ))
    n_new = max(g.n_nodes, ins_max + 1)
    if n_nodes is not None:
        if n_nodes < n_new:
            raise ValueError(f"n_nodes={n_nodes} < required {n_new}")
        n_new = int(n_nodes)

    iu, iv = canonical_slots(edits.ins_src, edits.ins_dst)
    du, dv = canonical_slots(edits.del_src, edits.del_dst)
    if du.size and int(max(du.max(), dv.max())) >= g.n_nodes:
        # Deleting an edge at an unknown id is a no-op by set semantics.
        keep = (du < g.n_nodes) & (dv < g.n_nodes)
        du, dv = du[keep], dv[keep]
    stride = np.int64(n_new)
    ins_keys = np.unique(iu * stride + iv)
    del_keys = np.unique(du * stride + dv)

    # Grow trailing rows first so affected-row logic sees one id space.
    indptr = g.indptr
    if n_new > g.n_nodes:
        indptr = np.concatenate([
            indptr,
            np.full(n_new - g.n_nodes, indptr[-1], dtype=np.int64),
        ])

    aff = np.unique(np.concatenate([ins_keys // stride, del_keys // stride]))
    if aff.size == 0:
        empty = np.empty(0, dtype=np.int64)
        return DeltaResult(
            graph=Graph(indptr=indptr, indices=g.indices, n_nodes=n_new),
            ins_u=empty, ins_v=empty, del_u=empty, del_v=empty,
            rows_rebuilt=0,
        )

    slots = _row_slot_indices(indptr, aff)
    counts_old = (indptr[aff + 1] - indptr[aff]).astype(np.int64)
    old_keys = (
        np.repeat(aff, counts_old) * stride
        + g.indices[slots].astype(np.int64)
    )
    # Set semantics: (E \ deletes) ∪ inserts. union1d/setdiff1d sort their
    # output, so final keys land u-major v-minor — from_edges order.
    final = np.union1d(np.setdiff1d(old_keys, del_keys), ins_keys)
    eff_ins = ins_keys[~np.isin(ins_keys, old_keys)]
    eff_del = np.setdiff1d(np.intersect1d(del_keys, old_keys), ins_keys)

    # Splice: new counts for affected rows, block-copy everything else.
    deg = np.diff(indptr)
    new_counts = deg.copy()
    new_counts[aff] = np.bincount(
        np.searchsorted(aff, final // stride), minlength=aff.size
    )
    new_indptr = np.zeros(n_new + 1, dtype=np.int64)
    np.cumsum(new_counts, out=new_indptr[1:])
    new_indices = np.empty(int(new_indptr[-1]), dtype=np.int32)
    final_vals = (final % stride).astype(np.int32)

    fin_pos = 0
    prev = 0  # first row of the next untouched block
    for i, r in enumerate(aff.tolist()):
        if prev < r:  # untouched rows [prev, r) — one contiguous block
            new_indices[new_indptr[prev]:new_indptr[r]] = (
                g.indices[indptr[prev]:indptr[r]]
            )
        cnt = int(new_counts[r])
        new_indices[new_indptr[r]:new_indptr[r] + cnt] = (
            final_vals[fin_pos:fin_pos + cnt]
        )
        fin_pos += cnt
        prev = r + 1
    if prev < n_new:
        new_indices[new_indptr[prev]:] = g.indices[indptr[prev]:]

    half = eff_ins[(eff_ins // stride) < (eff_ins % stride)]
    dhalf = eff_del[(eff_del // stride) < (eff_del % stride)]
    return DeltaResult(
        graph=Graph(indptr=new_indptr, indices=new_indices, n_nodes=n_new),
        ins_u=half // stride, ins_v=half % stride,
        del_u=dhalf // stride, del_v=dhalf % stride,
        rows_rebuilt=int(aff.size),
    )
