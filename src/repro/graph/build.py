"""Builders: bucketing, induced subgraphs and external information.

These are the host-side preprocessing steps of DC-kCore:

* :func:`induced_subgraph` implements the divide step's subgraph extraction
  (with old->new relabeling), for both Exact- and Rough-Divide. It runs as
  **chunked passes over CSR row ranges**: per-chunk transient host bytes are
  bounded by ``chunk_slots``, never by the edge count, and the output CSR is
  bit-identical at every chunk size (row ranges preserve the parent CSR's
  row-major, column-sorted emission order under the monotone relabeling).
* :func:`external_info` implements Definition 3 of the paper:
  ``E(v) = |N_G(v) ∩ V_upper|`` for every surviving node ``v`` — same
  chunked row-range structure.
* :class:`DivideStats` tracks the divide step's peak transient host bytes
  against the dense (``np.repeat``-over-all-rows) baseline, mirroring
  :class:`~repro.graph.io.IngestStats` for the ingest step.
* :func:`bucketize` converts a CSR part into the TPU-friendly
  degree-bucketed padded representation, splitting degree classes into
  row-tiles whose size is chosen by :func:`autotune_tile_caps` from the
  part's degree/locality profile (the ``max_bucket_rows="auto"`` path).
* :func:`canonical_slots` / :func:`finalize_key_bin` are the pure per-chunk
  steps of the streaming CSR build (:mod:`repro.graph.io`): chunk-local
  canonicalization on the way into the spill store, and per-node-range
  dedup + degree counting on the way out. Together they reproduce
  :meth:`Graph.from_edges <repro.graph.structs.Graph.from_edges>`
  bit-for-bit without ever holding the full edge list.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional, Sequence, Tuple

import numpy as np

from repro.graph.structs import Bucket, BucketedGraph, Graph

# Bucket pad widths: powers of two. Smallest kept modest so tiny-degree nodes
# don't blow up the padded footprint; largest grows to cover any max degree.
_MIN_WIDTH = 8

# Default chunk budget (in adjacency slots, i.e. directed edges) of the
# chunked divide passes. One chunk's int64 temporaries are ~25 bytes/slot,
# so the default bounds the divide transient at ~100 MiB regardless of
# graph size; graphs smaller than this run in a single chunk, so the small
# fixtures pay no chunking overhead at all.
DEFAULT_DIVIDE_CHUNK_SLOTS = 1 << 22


@dataclasses.dataclass
class DivideStats:
    """Transient-byte accounting of one chunked divide pass (or several —
    :func:`~repro.core.dckcore.dc_kcore` threads one instance through all of
    a part's extraction calls).

    ``peak_transient_bytes`` tracks the live numpy temporaries of the
    chunked passes — the per-chunk source/column/mask arrays plus the
    persistent ``O(n)`` relabeling and count arrays — everything *except*
    the output CSR, which any extraction must produce.
    ``baseline_transient_bytes`` is what the dense (pre-chunking)
    implementation would have peaked at for the same calls: each function
    reports its own dense working-set model through :meth:`note_pass`
    (e.g. ``np.repeat`` source + edge mask over all slots, compacted
    pairs over kept slots), and the baseline is the **max** over the
    noted passes — the dense code held one pass's transient at a time, so
    summing would overstate the comparison. The regression gate is
    ``peak_transient_bytes < baseline_transient_bytes`` with the peak
    scaling with ``chunk_slots``, not the edge count.

    **Thread safety.** An instance is plain mutable state and must be owned
    by exactly one thread at a time. The extraction passes themselves
    (:func:`induced_subgraph`, :func:`external_info`,
    :func:`~repro.core.divide.exact_candidates`) touch no shared mutable
    state — they read their argument arrays and write fresh outputs — so
    the overlapped pipeline's prefetch worker runs them concurrently with
    the main thread by giving each stage its *own* ``DivideStats`` and
    folding them together afterwards with :meth:`merge`.
    """

    chunk_slots: int
    n_chunks: int = 0
    input_slots: int = 0   # slots scanned across all chunked passes
    kept_slots: int = 0    # slots surviving the masks across all passes
    peak_transient_bytes: int = 0
    baseline_transient_bytes: int = 0

    def merge(self, other: "DivideStats") -> None:
        """Fold another pass's accounting into this one (counter sums, peak
        and baseline maxes). Because :meth:`bump` and :meth:`note_pass` are
        max-reductions and the counters are sums, threading one instance
        through two passes and merging two per-pass instances record the
        **same** numbers — which is what keeps the overlapped pipeline's
        per-part reports byte-identical to the sequential schedule's."""
        self.n_chunks += other.n_chunks
        self.input_slots += other.input_slots
        self.kept_slots += other.kept_slots
        self.peak_transient_bytes = max(
            self.peak_transient_bytes, other.peak_transient_bytes
        )
        self.baseline_transient_bytes = max(
            self.baseline_transient_bytes, other.baseline_transient_bytes
        )

    def bump(self, live_bytes: int) -> None:
        self.peak_transient_bytes = max(self.peak_transient_bytes, int(live_bytes))

    def note_pass(self, slots: int, kept: int,
                  slot_bytes: int = 9, kept_bytes: int = 20) -> None:
        """Record one dense-equivalent pass: ``slot_bytes`` per scanned slot
        (source vector + masks) plus ``kept_bytes`` per surviving slot
        (compacted/relabeled copies); the caller supplies the constants of
        its own dense model. The baseline keeps the max."""
        self.baseline_transient_bytes = max(
            self.baseline_transient_bytes,
            int(slots) * int(slot_bytes) + int(kept) * int(kept_bytes),
        )


def _resolve_chunk_slots(chunk_slots: Optional[int]) -> int:
    if chunk_slots is None:
        return DEFAULT_DIVIDE_CHUNK_SLOTS
    return max(1, int(chunk_slots))


def iter_row_ranges(indptr: np.ndarray, chunk_slots: int) -> Iterator[Tuple[int, int]]:
    """Yield CSR row ranges ``(lo, hi)`` holding at most ``chunk_slots``
    adjacency slots each — the unit of every chunked divide pass.

    A single row wider than the budget becomes its own over-budget range
    (a CSR row is indivisible here, like a dedup bin in
    :func:`~repro.graph.io._plan_bins`); every range holds at least one row
    so the scan always terminates.
    """
    n = indptr.shape[0] - 1
    chunk_slots = max(1, int(chunk_slots))
    lo = 0
    while lo < n:
        hi = int(np.searchsorted(indptr, int(indptr[lo]) + chunk_slots, side="right")) - 1
        hi = min(max(hi, lo + 1), n)
        yield lo, hi
        lo = hi


def _iter_adjacency_chunks(g: Graph, chunk_slots: int):
    """Yield ``(lo, hi, src, cols)`` per row range: the range's column slice
    (a view into the CSR) and its row-aligned source vector — the shared
    chunk body of every chunked divide pass."""
    for lo, hi in iter_row_ranges(g.indptr, chunk_slots):
        cols = g.indices[g.indptr[lo] : g.indptr[hi]]  # contiguous view
        src = np.repeat(
            np.arange(lo, hi, dtype=np.int64),
            np.diff(g.indptr[lo : hi + 1]).astype(np.int64),
        )
        yield lo, hi, src, cols


def _bucket_widths(max_deg: int) -> Sequence[int]:
    widths = []
    w = _MIN_WIDTH
    while True:
        widths.append(w)
        if w >= max_deg:
            break
        w *= 2
    return widths


def _degree_classes(deg: np.ndarray):
    """Yield ``(width, member_ids)`` per non-empty power-of-two degree class.

    The single source of the class boundaries — :func:`bucketize` tiles by
    it and :func:`autotune_tile_caps` keys its caps by it, so the two can
    never disagree about which class a node falls in. ``member_ids`` are
    ascending (the order tiles are cut in); degree-0 nodes belong to no
    class.
    """
    max_deg = int(deg.max(initial=0))
    if max_deg == 0:
        return
    for lo_excl_idx, width in enumerate(_bucket_widths(max_deg)):
        lo = 0 if lo_excl_idx == 0 else width // 2
        members = np.nonzero((deg > lo) & (deg <= width))[0]
        if members.size:
            yield width, members


def canonical_slots(src: np.ndarray, dst: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
    """Canonicalize one edge chunk: drop self-loops, emit both directed slots.

    This is the symmetrization step of :meth:`Graph.from_edges` applied to a
    bounded chunk — no dedup (duplicates across chunks cannot be seen here;
    :func:`finalize_key_bin` removes them globally). Negative endpoints are
    rejected immediately so a bad line surfaces at ingest time, not after
    the whole file has been spilled.
    """
    src = np.asarray(src, dtype=np.int64)
    dst = np.asarray(dst, dtype=np.int64)
    if src.shape != dst.shape:
        raise ValueError(f"src/dst shape mismatch: {src.shape} vs {dst.shape}")
    if src.size and (src.min() < 0 or dst.min() < 0):
        raise ValueError("edge endpoint out of range")
    keep = src != dst
    src, dst = src[keep], dst[keep]
    return np.concatenate([src, dst]), np.concatenate([dst, src])


def finalize_key_bin(
    keys: np.ndarray, n_nodes: int, lo: int, hi: int
) -> Tuple[np.ndarray, np.ndarray]:
    """Dedup one node-range bin of packed edge keys into CSR row material.

    ``keys`` are ``u * n_nodes + v`` for every directed slot whose source
    ``u`` lies in ``[lo, hi)`` (one spill bin of the external dedup).
    ``np.unique`` sorts them — u-major, v-minor — which is exactly the order
    :meth:`Graph.from_edges` emits, so concatenating bins over ascending
    disjoint ranges yields the identical global CSR. Returns
    ``(row_counts [hi - lo], neighbor_ids int32)``.
    """
    uniq = np.unique(np.asarray(keys, dtype=np.int64))
    u = uniq // n_nodes
    counts = np.bincount(u - lo, minlength=hi - lo)
    return counts, (uniq % n_nodes).astype(np.int32)


def induced_subgraph(
    g: Graph,
    keep_mask: np.ndarray,
    chunk_slots: Optional[int] = None,
    stats: Optional[DivideStats] = None,
) -> Tuple[Graph, np.ndarray]:
    """Induced subgraph on ``keep_mask`` with relabeled ids.

    Returns ``(subgraph, node_ids)`` where ``node_ids[new_id] = old_id``.

    Runs as two chunked passes over CSR row ranges of at most ``chunk_slots``
    adjacency slots (``None`` = :data:`DEFAULT_DIVIDE_CHUNK_SLOTS`): pass 1
    counts surviving columns per kept row, pass 2 writes the relabeled
    columns straight into the preallocated output ``indices`` array. Row
    ranges are scanned in ascending order and relabeling is monotone, so the
    output is **bit-identical at every chunk size** to a single dense pass —
    and transient host bytes are bounded by the chunk budget plus ``O(n)``
    id maps, never by the edge count. ``stats`` (a :class:`DivideStats`)
    tracks the transient peak.
    """
    keep_mask = np.asarray(keep_mask, dtype=bool)
    if keep_mask.shape != (g.n_nodes,):
        raise ValueError("mask shape mismatch")
    node_ids = np.nonzero(keep_mask)[0].astype(np.int64)
    n_sub = node_ids.shape[0]
    new_id = np.full(g.n_nodes, -1, dtype=np.int64)
    new_id[node_ids] = np.arange(n_sub, dtype=np.int64)
    budget = _resolve_chunk_slots(chunk_slots)
    persistent = keep_mask.nbytes + node_ids.nbytes + new_id.nbytes

    # Pass 1: count surviving columns per kept row (chunk-bounded scratch).
    counts = np.zeros(n_sub, dtype=np.int64)
    for lo, hi, src, cols in _iter_adjacency_chunks(g, budget):
        keep_edge = keep_mask[src] & keep_mask[cols]
        cnt = np.bincount(src[keep_edge] - lo, minlength=hi - lo)
        rows_kept = keep_mask[lo:hi]
        counts[new_id[lo:hi][rows_kept]] = cnt[rows_kept]
        if stats is not None:
            stats.n_chunks += 1
            stats.input_slots += int(src.size)
            stats.kept_slots += int(keep_edge.sum())
            stats.bump(
                persistent + counts.nbytes
                + src.nbytes + keep_edge.nbytes * 2 + cnt.nbytes
            )
    indptr = np.zeros(n_sub + 1, dtype=np.int64)
    np.cumsum(counts, out=indptr[1:])
    if stats is not None:
        # Dense model of the whole extraction: np.repeat source + edge mask
        # over all slots, compacted int64 pairs + int32 cast over kept.
        stats.note_pass(2 * g.n_edges, int(indptr[-1]), slot_bytes=9, kept_bytes=20)

    # Pass 2: fill the output. Kept rows appear in ascending order across
    # chunks, so each chunk's surviving columns land in one contiguous
    # region of the output stream — a running cursor suffices.
    sub_indices = np.empty(int(indptr[-1]), dtype=np.int32)
    out_pos = 0
    for lo, hi, src, cols in _iter_adjacency_chunks(g, budget):
        keep_edge = keep_mask[src] & keep_mask[cols]
        sub_dst = new_id[cols[keep_edge]]
        sub_indices[out_pos : out_pos + sub_dst.size] = sub_dst
        out_pos += int(sub_dst.size)
        if stats is not None:
            stats.bump(
                persistent + counts.nbytes
                + src.nbytes + keep_edge.nbytes * 2 + sub_dst.nbytes * 2
            )
    sub = Graph(indptr=indptr, indices=sub_indices, n_nodes=int(n_sub))
    return sub, node_ids


def external_info(
    g: Graph,
    keep_mask: np.ndarray,
    upper_mask: np.ndarray,
    chunk_slots: Optional[int] = None,
    stats: Optional[DivideStats] = None,
) -> np.ndarray:
    """E(v) = number of neighbors of ``v`` inside ``upper_mask``.

    Returned per *surviving* node (``keep_mask`` order, relabeled ids).
    ``upper_mask`` marks nodes whose coreness is already finalized at a value
    >= the part's threshold (Definition 3). One chunked pass over CSR row
    ranges (``chunk_slots`` adjacency slots of transient, ``None`` =
    :data:`DEFAULT_DIVIDE_CHUNK_SLOTS`); each range's counts land in a
    disjoint slice of the per-node accumulator, so the result is exact at
    every chunk size.
    """
    keep_mask = np.asarray(keep_mask, dtype=bool)
    upper_mask = np.asarray(upper_mask, dtype=bool)
    ext_full = np.zeros(g.n_nodes, dtype=np.int64)
    budget = _resolve_chunk_slots(chunk_slots)
    persistent = keep_mask.nbytes + upper_mask.nbytes + ext_full.nbytes
    contributed = 0
    for lo, hi, src, cols in _iter_adjacency_chunks(g, budget):
        contributes = keep_mask[src] & upper_mask[cols]
        ext_full[lo:hi] = np.bincount(src[contributes] - lo, minlength=hi - lo)
        if stats is not None:
            stats.n_chunks += 1
            stats.input_slots += int(src.size)
            contributed += int(contributes.sum())
            stats.bump(persistent + src.nbytes + contributes.nbytes * 2)
    if stats is not None:
        stats.kept_slots += contributed
        # Dense model: np.repeat source + mask over all slots, compacted
        # int64 source ids over contributing slots.
        stats.note_pass(2 * g.n_edges, contributed, slot_bytes=9, kept_bytes=8)
    return ext_full[keep_mask].astype(np.int32)


def _tile_row_cap(n_rows: int, row_align: int, max_bucket_rows) -> int:
    """Resolve a *uniform* per-bucket row cap (the non-``"auto"`` paths).

    ``None`` disables splitting (one tile per degree class — coarsest
    frontier granularity, smallest trace); an int caps tiles at that many
    rows uniformly across all degree classes (rounded up to ``row_align``).
    The ``"auto"`` policy no longer lands here: :func:`bucketize` routes it
    through :func:`autotune_tile_caps`, which picks *per-degree-class* caps
    from the part's locality profile.
    """
    if max_bucket_rows is None:
        return n_rows if n_rows > 0 else 1
    return _align_up(int(max_bucket_rows), row_align)


def _align_up(x: int, align: int) -> int:
    return max(align, -(-int(x) // align) * align)


def autotune_tile_caps(
    g: Graph,
    row_align: int = 8,
    tile_budget: int = 48,
    min_cap: int = 128,
    locality_boost: float = 3.0,
) -> Dict[int, int]:
    """Degree-profile tile autotuner: per-degree-class row caps.

    Returns ``{bucket_width: row_cap}`` for every non-empty degree class.
    Tiles are the scheduling unit of active-frontier sweeps, so the cap is
    a work/compile-time trade-off with an asymmetry the old uniform
    ``n_rows/48`` heuristic ignored:

    * The **static** filter (bucket-adjacency bitmap) only pays off for a
      tile whose rows' neighbor ids are co-located — then the tile is
      adjacent to few other tiles and the bitmap row is sparse. Splitting a
      class whose rows reach across the whole id range (hubs, or any class
      on an unordered graph) cannot sparsify the bitmap: every shard of it
      stays adjacent to everything.
    * The **dynamic** filter (row-exact dirty bits) gets finer with smaller
      tiles regardless of locality — a tile is skipped iff none of its own
      rows has a changed neighbor.

    So the tuner splits *everywhere* (dynamic wins) but spends the tile
    budget preferentially on classes with small neighbor spans (static
    wins), measured from the actual CSR via
    :func:`~repro.graph.reorder.neighbor_spans`:

    1. per class ``c``: rows ``n_c`` and mean neighbor-span fraction
       ``f_c = mean(span) / n`` (0 = perfectly local, 1 = global reach);
    2. tile share ``w_c = n_c * (1 + locality_boost * (1 - f_c))`` — a
       perfectly local class gets ``1 + locality_boost`` times the tiles of
       an equally-sized global one;
    3. ``cap_c = ceil(n_c / t_c)`` with ``t_c ∝ w_c`` summing to
       ``tile_budget``, clamped to ``>= min_cap`` and aligned to
       ``row_align``.

    ``min_cap`` bounds the total tile count on small parts (the unrolled
    sweep trace is linear in tiles); ``tile_budget`` bounds it on large
    ones. On an identity-ordered power-law graph every ``f_c ≈ 1`` and the
    allocation degenerates to the old uniform heuristic; after RCM/BFS
    reordering (:mod:`repro.graph.reorder`) the low-degree long-tail
    classes — most of the rows — have small spans and receive fine tiles,
    which is what makes the static filter fire.
    """
    from repro.graph.reorder import neighbor_spans

    deg = g.degrees
    n = max(g.n_nodes, 1)
    span = neighbor_spans(g)
    classes = []  # (width, n_rows, span_frac)
    for width, members in _degree_classes(deg):
        f_c = float(span[members].mean()) / n
        classes.append((width, members.size, min(f_c, 1.0)))
    if not classes:
        return {}

    weights = np.array(
        [n_c * (1.0 + locality_boost * (1.0 - f_c)) for _w, n_c, f_c in classes]
    )
    shares = weights / weights.sum() * tile_budget
    caps: Dict[int, int] = {}
    for (width, n_c, _f_c), t_c in zip(classes, shares):
        cap = -(-n_c // max(1.0, t_c))
        caps[width] = _align_up(max(cap, min_cap), row_align)
    return caps


def bucketize(
    g: Graph,
    ext: Optional[np.ndarray] = None,
    row_align: int = 8,
    max_bucket_rows="auto",
) -> BucketedGraph:
    """Convert a CSR part into degree-bucketed padded dense tiles.

    Nodes of degree 0 are excluded from every bucket: their coreness is
    exactly ``ext`` at initialization and never changes. Bucket rows are
    padded to a multiple of ``row_align`` (sublane alignment; the distributed
    engine re-pads rows to a multiple of the node-shard count).

    Each degree class is split into row-tiles; tiles are the scheduling unit
    of active-frontier sweeps, so finer tiles mean more precise skipping at
    the cost of a longer unrolled sweep trace. ``max_bucket_rows`` picks the
    policy:

    * ``"auto"`` (default) — per-degree-class caps from
      :func:`autotune_tile_caps`: the tile budget (~48 tiles) is spent
      preferentially on classes whose neighbor ids are co-located, where the
      static bucket-adjacency filter can actually fire. This is where
      locality-aware reordering (:func:`~repro.graph.reorder.reorder_graph`)
      pays off.
    * an ``int`` — uniform cap of that many rows per tile for every class.
    * ``None`` — no splitting: exactly one tile per degree class (coarsest
      frontier, smallest trace; the pre-frontier layout).

    The ``bucket_adj`` bitmap over tiles is recorded for the engines.

    If ``g`` is reordered (``g.perm`` set), ``ext`` must be given in
    **original**-id order — it is permuted into the layout order here, and
    the decompose engines un-permute coreness on the way out, so reordering
    stays invisible to callers. ``perm``/``inv_perm`` are propagated onto
    the returned :class:`~repro.graph.structs.BucketedGraph`.
    """
    deg = g.degrees
    n = g.n_nodes
    if ext is None:
        ext = np.zeros(n, dtype=np.int32)
    ext = np.asarray(ext, dtype=np.int32)
    if ext.shape != (n,):
        raise ValueError("ext shape mismatch")
    if g.perm is not None:
        ext = ext[g.perm]  # original-id order -> layout order

    buckets = []
    # node -> bucket index (sentinel slot n and degree-0 nodes map to -1).
    node_bucket = np.full(n + 1, -1, dtype=np.int32)
    if max_bucket_rows == "auto":
        caps = autotune_tile_caps(g, row_align=row_align)
    else:
        uniform = _tile_row_cap(int((deg > 0).sum()), row_align, max_bucket_rows)
        caps = None
    for width, members_all in _degree_classes(deg):
        row_cap = caps[width] if caps is not None else uniform
        for tile_lo in range(0, members_all.size, row_cap):
            members = members_all[tile_lo : tile_lo + row_cap]
            nb = _align_up(members.size, row_align)
            # Padded rows scatter into the sentinel slot `n` of the state
            # vector (re-pinned to -1 after each update), never into a node.
            node_ids = np.full(nb, n, dtype=np.int32)
            node_ids[: members.size] = members
            neigh = np.full((nb, width), n, dtype=np.int32)  # sentinel pad
            row_deg = np.zeros(nb, dtype=np.int32)
            row_deg[: members.size] = deg[members]
            # Fill rows: gather each member's adjacency slice.
            starts = g.indptr[members]
            lens = deg[members]
            flat_idx = (starts[:, None] + np.arange(width)[None, :]).astype(np.int64)
            valid = np.arange(width)[None, :] < lens[:, None]
            flat_idx = np.where(valid, flat_idx, 0)
            vals = g.indices[flat_idx]
            neigh[: members.size] = np.where(valid, vals, n)
            node_bucket[members] = len(buckets)
            buckets.append(
                Bucket(node_ids=node_ids, neigh=neigh, deg=row_deg, width=width)
            )

    # Bucket-adjacency bitmap for frontier scheduling. An endpoint of any
    # edge has degree >= 1, so every real neighbor id maps to a bucket;
    # sentinel-padded slots map to -1 and are dropped. Diagonal is kept set
    # (conservative: a bucket that changed rescans itself next sweep) and the
    # matrix is symmetrized — CSR symmetry makes it symmetric already, but
    # padding asymmetries must never weaken the soundness argument.
    nb = len(buckets)
    adj = np.zeros((nb, nb), dtype=bool)
    np.fill_diagonal(adj, True)
    for bi, b in enumerate(buckets):
        touched = np.unique(node_bucket[b.neigh.ravel()])
        adj[bi, touched[touched >= 0]] = True
    adj |= adj.T

    return BucketedGraph(
        n_nodes=n, buckets=buckets, ext=ext, degrees=deg.astype(np.int32),
        bucket_adj=adj, node_bucket=node_bucket,
        perm=g.perm, inv_perm=g.inv_perm,
    )
