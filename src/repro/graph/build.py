"""Builders: bucketing, induced subgraphs and external information.

These are the host-side preprocessing steps of DC-kCore:

* :func:`induced_subgraph` implements the divide step's subgraph extraction
  (with old->new relabeling), for both Exact- and Rough-Divide.
* :func:`external_info` implements Definition 3 of the paper:
  ``E(v) = |N_G(v) ∩ V_upper|`` for every surviving node ``v``.
* :func:`bucketize` converts a CSR part into the TPU-friendly
  degree-bucketed padded representation.
"""
from __future__ import annotations

from typing import Optional, Sequence, Tuple

import numpy as np

from repro.graph.structs import Bucket, BucketedGraph, Graph

# Bucket pad widths: powers of two. Smallest kept modest so tiny-degree nodes
# don't blow up the padded footprint; largest grows to cover any max degree.
_MIN_WIDTH = 8


def _bucket_widths(max_deg: int) -> Sequence[int]:
    widths = []
    w = _MIN_WIDTH
    while True:
        widths.append(w)
        if w >= max_deg:
            break
        w *= 2
    return widths


def induced_subgraph(g: Graph, keep_mask: np.ndarray) -> Tuple[Graph, np.ndarray]:
    """Induced subgraph on ``keep_mask`` with relabeled ids.

    Returns ``(subgraph, node_ids)`` where ``node_ids[new_id] = old_id``.
    """
    keep_mask = np.asarray(keep_mask, dtype=bool)
    if keep_mask.shape != (g.n_nodes,):
        raise ValueError("mask shape mismatch")
    node_ids = np.nonzero(keep_mask)[0].astype(np.int64)
    new_id = np.full(g.n_nodes, -1, dtype=np.int64)
    new_id[node_ids] = np.arange(node_ids.shape[0], dtype=np.int64)

    deg = g.degrees
    # Row lengths of surviving rows; then filter columns by mask.
    src = np.repeat(np.arange(g.n_nodes, dtype=np.int64), deg)
    keep_edge = keep_mask[src] & keep_mask[g.indices]
    sub_src = new_id[src[keep_edge]]
    sub_dst = new_id[g.indices[keep_edge]]

    n_sub = node_ids.shape[0]
    counts = np.bincount(sub_src, minlength=n_sub)
    indptr = np.zeros(n_sub + 1, dtype=np.int64)
    np.cumsum(counts, out=indptr[1:])
    # Edges are emitted in (src-sorted, dst-sorted) order already because the
    # parent CSR is sorted and relabeling is monotone.
    sub = Graph(indptr=indptr, indices=sub_dst.astype(np.int32), n_nodes=int(n_sub))
    return sub, node_ids


def external_info(g: Graph, keep_mask: np.ndarray, upper_mask: np.ndarray) -> np.ndarray:
    """E(v) = number of neighbors of ``v`` inside ``upper_mask``.

    Returned per *surviving* node (``keep_mask`` order, relabeled ids).
    ``upper_mask`` marks nodes whose coreness is already finalized at a value
    >= the part's threshold (Definition 3).
    """
    keep_mask = np.asarray(keep_mask, dtype=bool)
    upper_mask = np.asarray(upper_mask, dtype=bool)
    deg = g.degrees
    src = np.repeat(np.arange(g.n_nodes, dtype=np.int64), deg)
    contributes = keep_mask[src] & upper_mask[g.indices]
    ext_full = np.bincount(src[contributes], minlength=g.n_nodes)
    return ext_full[keep_mask].astype(np.int32)


def _tile_row_cap(n_rows: int, row_align: int, max_bucket_rows) -> int:
    """Resolve the per-bucket row cap used for frontier granularity.

    ``"auto"`` bounds the total tile count to roughly 48 (plus one per
    degree class) so the unrolled sweep trace stays cheap while small/medium
    parts still get fine-grained frontier scheduling; an int caps directly;
    ``None`` disables splitting (one tile per degree class).
    """
    if max_bucket_rows is None:
        return n_rows if n_rows > 0 else 1
    if max_bucket_rows == "auto":
        cap = max(128, -(-n_rows // 48))
    else:
        cap = int(max_bucket_rows)
    return max(row_align, -(-cap // row_align) * row_align)


def bucketize(
    g: Graph,
    ext: Optional[np.ndarray] = None,
    row_align: int = 8,
    max_bucket_rows="auto",
) -> BucketedGraph:
    """Convert a CSR part into degree-bucketed padded dense tiles.

    Nodes of degree 0 are excluded from every bucket: their coreness is
    exactly ``ext`` at initialization and never changes. Bucket rows are
    padded to a multiple of ``row_align`` (sublane alignment; the distributed
    engine re-pads rows to a multiple of the node-shard count).

    Each degree class is split into row-tiles of at most ``max_bucket_rows``
    rows (see :func:`_tile_row_cap`); tiles are the scheduling unit of
    active-frontier sweeps, so finer tiles mean more precise skipping. The
    ``bucket_adj`` bitmap over tiles is recorded for the engines.
    """
    deg = g.degrees
    n = g.n_nodes
    if ext is None:
        ext = np.zeros(n, dtype=np.int32)
    ext = np.asarray(ext, dtype=np.int32)
    if ext.shape != (n,):
        raise ValueError("ext shape mismatch")

    buckets = []
    # node -> bucket index (sentinel slot n and degree-0 nodes map to -1).
    node_bucket = np.full(n + 1, -1, dtype=np.int32)
    max_deg = int(deg.max(initial=0))
    row_cap = _tile_row_cap(int((deg > 0).sum()), row_align, max_bucket_rows)
    if max_deg > 0:
        for lo_excl_idx, width in enumerate(_bucket_widths(max_deg)):
            lo = 0 if lo_excl_idx == 0 else width // 2
            members_all = np.nonzero((deg > lo) & (deg <= width))[0]
            if members_all.size == 0:
                continue
            for tile_lo in range(0, members_all.size, row_cap):
                members = members_all[tile_lo : tile_lo + row_cap]
                nb = int(np.ceil(members.size / row_align) * row_align)
                # Padded rows scatter into the sentinel slot `n` of the state
                # vector (re-pinned to -1 after each update), never into a node.
                node_ids = np.full(nb, n, dtype=np.int32)
                node_ids[: members.size] = members
                neigh = np.full((nb, width), n, dtype=np.int32)  # sentinel pad
                row_deg = np.zeros(nb, dtype=np.int32)
                row_deg[: members.size] = deg[members]
                # Fill rows: gather each member's adjacency slice.
                starts = g.indptr[members]
                lens = deg[members]
                flat_idx = (starts[:, None] + np.arange(width)[None, :]).astype(np.int64)
                valid = np.arange(width)[None, :] < lens[:, None]
                flat_idx = np.where(valid, flat_idx, 0)
                vals = g.indices[flat_idx]
                neigh[: members.size] = np.where(valid, vals, n)
                node_bucket[members] = len(buckets)
                buckets.append(
                    Bucket(node_ids=node_ids, neigh=neigh, deg=row_deg, width=width)
                )

    # Bucket-adjacency bitmap for frontier scheduling. An endpoint of any
    # edge has degree >= 1, so every real neighbor id maps to a bucket;
    # sentinel-padded slots map to -1 and are dropped. Diagonal is kept set
    # (conservative: a bucket that changed rescans itself next sweep) and the
    # matrix is symmetrized — CSR symmetry makes it symmetric already, but
    # padding asymmetries must never weaken the soundness argument.
    nb = len(buckets)
    adj = np.zeros((nb, nb), dtype=bool)
    np.fill_diagonal(adj, True)
    for bi, b in enumerate(buckets):
        touched = np.unique(node_bucket[b.neigh.ravel()])
        adj[bi, touched[touched >= 0]] = True
    adj |= adj.T

    return BucketedGraph(
        n_nodes=n, buckets=buckets, ext=ext, degrees=deg.astype(np.int32),
        bucket_adj=adj, node_bucket=node_bucket,
    )
