"""Builders: bucketing, induced subgraphs and external information.

These are the host-side preprocessing steps of DC-kCore:

* :func:`induced_subgraph` implements the divide step's subgraph extraction
  (with old->new relabeling), for both Exact- and Rough-Divide.
* :func:`external_info` implements Definition 3 of the paper:
  ``E(v) = |N_G(v) ∩ V_upper|`` for every surviving node ``v``.
* :func:`bucketize` converts a CSR part into the TPU-friendly
  degree-bucketed padded representation, splitting degree classes into
  row-tiles whose size is chosen by :func:`autotune_tile_caps` from the
  part's degree/locality profile (the ``max_bucket_rows="auto"`` path).
* :func:`canonical_slots` / :func:`finalize_key_bin` are the pure per-chunk
  steps of the streaming CSR build (:mod:`repro.graph.io`): chunk-local
  canonicalization on the way into the spill store, and per-node-range
  dedup + degree counting on the way out. Together they reproduce
  :meth:`Graph.from_edges <repro.graph.structs.Graph.from_edges>`
  bit-for-bit without ever holding the full edge list.
"""
from __future__ import annotations

from typing import Dict, Optional, Sequence, Tuple

import numpy as np

from repro.graph.structs import Bucket, BucketedGraph, Graph

# Bucket pad widths: powers of two. Smallest kept modest so tiny-degree nodes
# don't blow up the padded footprint; largest grows to cover any max degree.
_MIN_WIDTH = 8


def _bucket_widths(max_deg: int) -> Sequence[int]:
    widths = []
    w = _MIN_WIDTH
    while True:
        widths.append(w)
        if w >= max_deg:
            break
        w *= 2
    return widths


def _degree_classes(deg: np.ndarray):
    """Yield ``(width, member_ids)`` per non-empty power-of-two degree class.

    The single source of the class boundaries — :func:`bucketize` tiles by
    it and :func:`autotune_tile_caps` keys its caps by it, so the two can
    never disagree about which class a node falls in. ``member_ids`` are
    ascending (the order tiles are cut in); degree-0 nodes belong to no
    class.
    """
    max_deg = int(deg.max(initial=0))
    if max_deg == 0:
        return
    for lo_excl_idx, width in enumerate(_bucket_widths(max_deg)):
        lo = 0 if lo_excl_idx == 0 else width // 2
        members = np.nonzero((deg > lo) & (deg <= width))[0]
        if members.size:
            yield width, members


def canonical_slots(src: np.ndarray, dst: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
    """Canonicalize one edge chunk: drop self-loops, emit both directed slots.

    This is the symmetrization step of :meth:`Graph.from_edges` applied to a
    bounded chunk — no dedup (duplicates across chunks cannot be seen here;
    :func:`finalize_key_bin` removes them globally). Negative endpoints are
    rejected immediately so a bad line surfaces at ingest time, not after
    the whole file has been spilled.
    """
    src = np.asarray(src, dtype=np.int64)
    dst = np.asarray(dst, dtype=np.int64)
    if src.shape != dst.shape:
        raise ValueError(f"src/dst shape mismatch: {src.shape} vs {dst.shape}")
    if src.size and (src.min() < 0 or dst.min() < 0):
        raise ValueError("edge endpoint out of range")
    keep = src != dst
    src, dst = src[keep], dst[keep]
    return np.concatenate([src, dst]), np.concatenate([dst, src])


def finalize_key_bin(
    keys: np.ndarray, n_nodes: int, lo: int, hi: int
) -> Tuple[np.ndarray, np.ndarray]:
    """Dedup one node-range bin of packed edge keys into CSR row material.

    ``keys`` are ``u * n_nodes + v`` for every directed slot whose source
    ``u`` lies in ``[lo, hi)`` (one spill bin of the external dedup).
    ``np.unique`` sorts them — u-major, v-minor — which is exactly the order
    :meth:`Graph.from_edges` emits, so concatenating bins over ascending
    disjoint ranges yields the identical global CSR. Returns
    ``(row_counts [hi - lo], neighbor_ids int32)``.
    """
    uniq = np.unique(np.asarray(keys, dtype=np.int64))
    u = uniq // n_nodes
    counts = np.bincount(u - lo, minlength=hi - lo)
    return counts, (uniq % n_nodes).astype(np.int32)


def induced_subgraph(g: Graph, keep_mask: np.ndarray) -> Tuple[Graph, np.ndarray]:
    """Induced subgraph on ``keep_mask`` with relabeled ids.

    Returns ``(subgraph, node_ids)`` where ``node_ids[new_id] = old_id``.
    """
    keep_mask = np.asarray(keep_mask, dtype=bool)
    if keep_mask.shape != (g.n_nodes,):
        raise ValueError("mask shape mismatch")
    node_ids = np.nonzero(keep_mask)[0].astype(np.int64)
    new_id = np.full(g.n_nodes, -1, dtype=np.int64)
    new_id[node_ids] = np.arange(node_ids.shape[0], dtype=np.int64)

    deg = g.degrees
    # Row lengths of surviving rows; then filter columns by mask.
    src = np.repeat(np.arange(g.n_nodes, dtype=np.int64), deg)
    keep_edge = keep_mask[src] & keep_mask[g.indices]
    sub_src = new_id[src[keep_edge]]
    sub_dst = new_id[g.indices[keep_edge]]

    n_sub = node_ids.shape[0]
    counts = np.bincount(sub_src, minlength=n_sub)
    indptr = np.zeros(n_sub + 1, dtype=np.int64)
    np.cumsum(counts, out=indptr[1:])
    # Edges are emitted in (src-sorted, dst-sorted) order already because the
    # parent CSR is sorted and relabeling is monotone.
    sub = Graph(indptr=indptr, indices=sub_dst.astype(np.int32), n_nodes=int(n_sub))
    return sub, node_ids


def external_info(g: Graph, keep_mask: np.ndarray, upper_mask: np.ndarray) -> np.ndarray:
    """E(v) = number of neighbors of ``v`` inside ``upper_mask``.

    Returned per *surviving* node (``keep_mask`` order, relabeled ids).
    ``upper_mask`` marks nodes whose coreness is already finalized at a value
    >= the part's threshold (Definition 3).
    """
    keep_mask = np.asarray(keep_mask, dtype=bool)
    upper_mask = np.asarray(upper_mask, dtype=bool)
    deg = g.degrees
    src = np.repeat(np.arange(g.n_nodes, dtype=np.int64), deg)
    contributes = keep_mask[src] & upper_mask[g.indices]
    ext_full = np.bincount(src[contributes], minlength=g.n_nodes)
    return ext_full[keep_mask].astype(np.int32)


def _tile_row_cap(n_rows: int, row_align: int, max_bucket_rows) -> int:
    """Resolve a *uniform* per-bucket row cap (the non-``"auto"`` paths).

    ``None`` disables splitting (one tile per degree class — coarsest
    frontier granularity, smallest trace); an int caps tiles at that many
    rows uniformly across all degree classes (rounded up to ``row_align``).
    The ``"auto"`` policy no longer lands here: :func:`bucketize` routes it
    through :func:`autotune_tile_caps`, which picks *per-degree-class* caps
    from the part's locality profile.
    """
    if max_bucket_rows is None:
        return n_rows if n_rows > 0 else 1
    return _align_up(int(max_bucket_rows), row_align)


def _align_up(x: int, align: int) -> int:
    return max(align, -(-int(x) // align) * align)


def autotune_tile_caps(
    g: Graph,
    row_align: int = 8,
    tile_budget: int = 48,
    min_cap: int = 128,
    locality_boost: float = 3.0,
) -> Dict[int, int]:
    """Degree-profile tile autotuner: per-degree-class row caps.

    Returns ``{bucket_width: row_cap}`` for every non-empty degree class.
    Tiles are the scheduling unit of active-frontier sweeps, so the cap is
    a work/compile-time trade-off with an asymmetry the old uniform
    ``n_rows/48`` heuristic ignored:

    * The **static** filter (bucket-adjacency bitmap) only pays off for a
      tile whose rows' neighbor ids are co-located — then the tile is
      adjacent to few other tiles and the bitmap row is sparse. Splitting a
      class whose rows reach across the whole id range (hubs, or any class
      on an unordered graph) cannot sparsify the bitmap: every shard of it
      stays adjacent to everything.
    * The **dynamic** filter (row-exact dirty bits) gets finer with smaller
      tiles regardless of locality — a tile is skipped iff none of its own
      rows has a changed neighbor.

    So the tuner splits *everywhere* (dynamic wins) but spends the tile
    budget preferentially on classes with small neighbor spans (static
    wins), measured from the actual CSR via
    :func:`~repro.graph.reorder.neighbor_spans`:

    1. per class ``c``: rows ``n_c`` and mean neighbor-span fraction
       ``f_c = mean(span) / n`` (0 = perfectly local, 1 = global reach);
    2. tile share ``w_c = n_c * (1 + locality_boost * (1 - f_c))`` — a
       perfectly local class gets ``1 + locality_boost`` times the tiles of
       an equally-sized global one;
    3. ``cap_c = ceil(n_c / t_c)`` with ``t_c ∝ w_c`` summing to
       ``tile_budget``, clamped to ``>= min_cap`` and aligned to
       ``row_align``.

    ``min_cap`` bounds the total tile count on small parts (the unrolled
    sweep trace is linear in tiles); ``tile_budget`` bounds it on large
    ones. On an identity-ordered power-law graph every ``f_c ≈ 1`` and the
    allocation degenerates to the old uniform heuristic; after RCM/BFS
    reordering (:mod:`repro.graph.reorder`) the low-degree long-tail
    classes — most of the rows — have small spans and receive fine tiles,
    which is what makes the static filter fire.
    """
    from repro.graph.reorder import neighbor_spans

    deg = g.degrees
    n = max(g.n_nodes, 1)
    span = neighbor_spans(g)
    classes = []  # (width, n_rows, span_frac)
    for width, members in _degree_classes(deg):
        f_c = float(span[members].mean()) / n
        classes.append((width, members.size, min(f_c, 1.0)))
    if not classes:
        return {}

    weights = np.array(
        [n_c * (1.0 + locality_boost * (1.0 - f_c)) for _w, n_c, f_c in classes]
    )
    shares = weights / weights.sum() * tile_budget
    caps: Dict[int, int] = {}
    for (width, n_c, _f_c), t_c in zip(classes, shares):
        cap = -(-n_c // max(1.0, t_c))
        caps[width] = _align_up(max(cap, min_cap), row_align)
    return caps


def bucketize(
    g: Graph,
    ext: Optional[np.ndarray] = None,
    row_align: int = 8,
    max_bucket_rows="auto",
) -> BucketedGraph:
    """Convert a CSR part into degree-bucketed padded dense tiles.

    Nodes of degree 0 are excluded from every bucket: their coreness is
    exactly ``ext`` at initialization and never changes. Bucket rows are
    padded to a multiple of ``row_align`` (sublane alignment; the distributed
    engine re-pads rows to a multiple of the node-shard count).

    Each degree class is split into row-tiles; tiles are the scheduling unit
    of active-frontier sweeps, so finer tiles mean more precise skipping at
    the cost of a longer unrolled sweep trace. ``max_bucket_rows`` picks the
    policy:

    * ``"auto"`` (default) — per-degree-class caps from
      :func:`autotune_tile_caps`: the tile budget (~48 tiles) is spent
      preferentially on classes whose neighbor ids are co-located, where the
      static bucket-adjacency filter can actually fire. This is where
      locality-aware reordering (:func:`~repro.graph.reorder.reorder_graph`)
      pays off.
    * an ``int`` — uniform cap of that many rows per tile for every class.
    * ``None`` — no splitting: exactly one tile per degree class (coarsest
      frontier, smallest trace; the pre-frontier layout).

    The ``bucket_adj`` bitmap over tiles is recorded for the engines.

    If ``g`` is reordered (``g.perm`` set), ``ext`` must be given in
    **original**-id order — it is permuted into the layout order here, and
    the decompose engines un-permute coreness on the way out, so reordering
    stays invisible to callers. ``perm``/``inv_perm`` are propagated onto
    the returned :class:`~repro.graph.structs.BucketedGraph`.
    """
    deg = g.degrees
    n = g.n_nodes
    if ext is None:
        ext = np.zeros(n, dtype=np.int32)
    ext = np.asarray(ext, dtype=np.int32)
    if ext.shape != (n,):
        raise ValueError("ext shape mismatch")
    if g.perm is not None:
        ext = ext[g.perm]  # original-id order -> layout order

    buckets = []
    # node -> bucket index (sentinel slot n and degree-0 nodes map to -1).
    node_bucket = np.full(n + 1, -1, dtype=np.int32)
    if max_bucket_rows == "auto":
        caps = autotune_tile_caps(g, row_align=row_align)
    else:
        uniform = _tile_row_cap(int((deg > 0).sum()), row_align, max_bucket_rows)
        caps = None
    for width, members_all in _degree_classes(deg):
        row_cap = caps[width] if caps is not None else uniform
        for tile_lo in range(0, members_all.size, row_cap):
            members = members_all[tile_lo : tile_lo + row_cap]
            nb = _align_up(members.size, row_align)
            # Padded rows scatter into the sentinel slot `n` of the state
            # vector (re-pinned to -1 after each update), never into a node.
            node_ids = np.full(nb, n, dtype=np.int32)
            node_ids[: members.size] = members
            neigh = np.full((nb, width), n, dtype=np.int32)  # sentinel pad
            row_deg = np.zeros(nb, dtype=np.int32)
            row_deg[: members.size] = deg[members]
            # Fill rows: gather each member's adjacency slice.
            starts = g.indptr[members]
            lens = deg[members]
            flat_idx = (starts[:, None] + np.arange(width)[None, :]).astype(np.int64)
            valid = np.arange(width)[None, :] < lens[:, None]
            flat_idx = np.where(valid, flat_idx, 0)
            vals = g.indices[flat_idx]
            neigh[: members.size] = np.where(valid, vals, n)
            node_bucket[members] = len(buckets)
            buckets.append(
                Bucket(node_ids=node_ids, neigh=neigh, deg=row_deg, width=width)
            )

    # Bucket-adjacency bitmap for frontier scheduling. An endpoint of any
    # edge has degree >= 1, so every real neighbor id maps to a bucket;
    # sentinel-padded slots map to -1 and are dropped. Diagonal is kept set
    # (conservative: a bucket that changed rescans itself next sweep) and the
    # matrix is symmetrized — CSR symmetry makes it symmetric already, but
    # padding asymmetries must never weaken the soundness argument.
    nb = len(buckets)
    adj = np.zeros((nb, nb), dtype=bool)
    np.fill_diagonal(adj, True)
    for bi, b in enumerate(buckets):
        touched = np.unique(node_bucket[b.neigh.ravel()])
        adj[bi, touched[touched >= 0]] = True
    adj |= adj.T

    return BucketedGraph(
        n_nodes=n, buckets=buckets, ext=ext, degrees=deg.astype(np.int32),
        bucket_adj=adj, node_bucket=node_bucket,
        perm=g.perm, inv_perm=g.inv_perm,
    )
