"""Batched serving loop: prefill + greedy decode over the KV caches."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.model import decode_step, prefill


def greedy_generate(params, prompt, cfg, n_new: int, extras=None,
                    max_len: Optional[int] = None, jit: bool = True):
    """prompt: [B, S] int32 -> generated [B, n_new] int32 (greedy)."""
    b, s = prompt.shape
    max_len = max_len or (s + n_new)
    step_fn = decode_step
    if jit:
        step_fn = jax.jit(decode_step, static_argnames=("cfg",))
    logits, caches = prefill(params, prompt, cfg, extras=extras, max_len=max_len)
    # Mask padded vocab before argmax.
    vmask = jnp.arange(logits.shape[-1]) < cfg.vocab_size
    token = jnp.argmax(jnp.where(vmask, logits[:, -1], -jnp.inf), axis=-1)[:, None]
    out = [token]
    pos = jnp.full((b,), s, jnp.int32)
    for _ in range(n_new - 1):
        logits, caches = step_fn(params, caches, token.astype(jnp.int32), pos, cfg)
        token = jnp.argmax(jnp.where(vmask, logits[:, -1], -jnp.inf), axis=-1)[:, None]
        out.append(token)
        pos = pos + 1
    return jnp.concatenate(out, axis=1).astype(jnp.int32)
