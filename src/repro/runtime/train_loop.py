"""Training loop with checkpoint/restart, failure injection and metrics.

``make_train_step`` builds the pure step function (loss -> grads -> clip ->
optimizer); ``TrainLoop`` owns the impure parts: data, checkpoint manager,
failure injection, resume. Resuming from a checkpoint is bit-identical to
an uninterrupted run (step-indexed data + saved optimizer state + saved
step counter) — tests/test_fault.py pins this down.
"""
from __future__ import annotations

import dataclasses
import time
from functools import partial
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
from repro.ckpt import CheckpointManager, latest_step, restore_pytree
from repro.models.model import loss_fn
from repro.optim import Optimizer, apply_updates, clip_by_global_norm


def make_train_step(cfg, optimizer: Optimizer, max_grad_norm: float = 1.0,
                    accum_steps: int = 1, grad_shardings=None,
                    accum_dtype=jnp.float32):
    """(params, opt_state, step, batch) -> (params, opt_state, metrics).

    ``accum_steps > 1`` enables gradient accumulation: the global batch is
    split into microbatches scanned sequentially, so live activation memory
    is per-*microbatch* — the knob that fits big-model training into the
    16 GB/chip budget (combined with remat; see EXPERIMENTS.md).

    ``grad_shardings`` (a NamedSharding tree matching params) constrains the
    per-microbatch gradients to the parameter layout, which lets GSPMD emit
    reduce-scatters into the shard instead of full all-reduces — measured
    2x on the grad-reduce wire term (EXPERIMENTS.md §Perf / grok-1).
    ``accum_dtype=bfloat16`` halves both the accumulation buffer and the
    reduce wire (Adafactor's update clipping tolerates the coarser sum)."""

    grad_fn = jax.value_and_grad(partial(loss_fn, cfg=cfg), has_aux=True)

    def constrain(g):
        if grad_shardings is None:
            return g
        return jax.tree.map(
            lambda gi, sh: jax.lax.with_sharding_constraint(gi, sh), g, grad_shardings
        )

    def step_fn(params, opt_state, step, batch):
        if accum_steps == 1:
            (loss, metrics), grads = grad_fn(params, batch)
            grads = constrain(grads)
        else:
            def split(x):
                return x.reshape((accum_steps, x.shape[0] // accum_steps) + x.shape[1:])

            micro = jax.tree.map(split, batch)

            def body(carry, mb):
                g_acc, l_acc = carry
                (l, _m), g = grad_fn(params, mb)
                g = constrain(g)
                g_acc = jax.tree.map(lambda a, b: a + b.astype(a.dtype), g_acc, g)
                g_acc = constrain(g_acc)
                return (g_acc, l_acc + l), None

            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, accum_dtype), params)
            (grads, loss_sum), _ = jax.lax.scan(body, (g0, jnp.float32(0)), micro)
            grads = jax.tree.map(lambda g: (g / accum_steps).astype(jnp.float32), grads)
            loss = loss_sum / accum_steps
            metrics = {"ce": loss, "aux": jnp.float32(0)}
        grads, gnorm = clip_by_global_norm(grads, max_grad_norm)
        updates, opt_state = optimizer.update(grads, opt_state, params, step)
        params = apply_updates(params, updates)
        out = {
            "loss": loss.astype(jnp.float32),
            "ce": metrics["ce"].astype(jnp.float32),
            "grad_norm": gnorm,
        }
        return params, opt_state, out

    return step_fn


@dataclasses.dataclass
class TrainLoop:
    cfg: Any
    params: Any
    optimizer: Optimizer
    data: Any  # exposes batch_at(step)
    ckpt_dir: Optional[str] = None
    ckpt_every: int = 50
    ckpt_blocking: bool = False  # True: synchronous saves (a crash can never
    # lose the latest scheduled checkpoint; async saves trade that for speed)
    failure_injector: Optional[Any] = None
    jit: bool = True

    def __post_init__(self):
        self.opt_state = self.optimizer.init(self.params)
        self.step = 0
        self.manager = CheckpointManager(self.ckpt_dir) if self.ckpt_dir else None
        fn = make_train_step(self.cfg, self.optimizer)
        self._step_fn = jax.jit(fn, donate_argnums=(0, 1)) if self.jit else fn

    # ------------------------------------------------------------------ #
    def try_resume(self) -> bool:
        if self.manager is None or latest_step(self.manager.path) is None:
            return False
        state = {"params": self.params, "opt": self.opt_state}
        restored, step, _ = restore_pytree(self.manager.path, state)
        self.params = jax.tree.map(jnp.asarray, restored["params"])
        self.opt_state = jax.tree.map(jnp.asarray, restored["opt"])
        self.step = step
        return True

    def save(self, blocking: bool = True):
        if self.manager is not None:
            self.manager.save(
                {"params": self.params, "opt": self.opt_state}, self.step,
                blocking=blocking,
            )

    # ------------------------------------------------------------------ #
    def run(self, n_steps: int, log_every: int = 10) -> Dict[str, list]:
        history: Dict[str, list] = {"loss": [], "step": [], "tokens_per_s": []}
        t_last = time.time()
        target = self.step + n_steps
        while self.step < target:
            if self.failure_injector is not None:
                self.failure_injector.maybe_fail(self.step)
            batch = self.data.batch_at(self.step)
            batch = {k: jnp.asarray(v) for k, v in batch.items()}
            self.params, self.opt_state, metrics = self._step_fn(
                self.params, self.opt_state, jnp.asarray(self.step), batch
            )
            self.step += 1
            if self.step % log_every == 0 or self.step == target:
                loss = float(metrics["loss"])
                dt = time.time() - t_last
                toks = batch["tokens"].size * log_every / max(dt, 1e-9)
                history["loss"].append(loss)
                history["step"].append(self.step)
                history["tokens_per_s"].append(toks)
                t_last = time.time()
            if self.manager is not None and self.step % self.ckpt_every == 0:
                self.save(blocking=self.ckpt_blocking)
        if self.manager is not None:
            self.save(blocking=True)
            self.manager.wait()
        return history
