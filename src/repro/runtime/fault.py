"""Fault tolerance utilities: failure injection and idempotent retries.

Synchronous SPMD handles intra-step consistency (lockstep collectives); the
framework-level story is:

* training — checkpoint/restart (TrainLoop.try_resume), bit-identical
  resume from step-indexed data;
* k-core — every part of the divide step is an idempotent sub-task over
  immutable inputs; ``run_with_retries`` re-runs a failed/straggling part
  without touching finished parts (the paper's 27.5 h WX-136B run is a
  sequence of such parts);
* stragglers — host-side input lag is absorbed by the Prefetcher queue; a
  slow *worker* in synchronous SPMD is indistinguishable from a slow step,
  so mitigation happens at the part/job scheduler level via retry +
  checkpoint granularity (documented in DESIGN.md).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional, Set


class InjectedFailure(RuntimeError):
    pass


@dataclasses.dataclass
class FailureInjector:
    """Raise at the given steps — once each (simulated worker loss)."""

    fail_at: Set[int]
    raised: Set[int] = dataclasses.field(default_factory=set)

    def maybe_fail(self, step: int):
        if step in self.fail_at and step not in self.raised:
            self.raised.add(step)
            raise InjectedFailure(f"injected failure at step {step}")


def run_with_retries(fn: Callable, retries: int = 2, backoff_s: float = 0.0,
                     on_retry: Optional[Callable] = None):
    """Run an idempotent sub-task, retrying on failure."""
    last = None
    for attempt in range(retries + 1):
        try:
            return fn()
        except Exception as e:  # noqa: BLE001 — deliberate catch-all boundary
            last = e
            if on_retry is not None:
                on_retry(attempt, e)
            if backoff_s:
                time.sleep(backoff_s * (attempt + 1))
    raise last
