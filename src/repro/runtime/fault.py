"""Fault tolerance utilities: failure injection and idempotent retries.

Synchronous SPMD handles intra-step consistency (lockstep collectives); the
framework-level story is:

* training — checkpoint/restart (TrainLoop.try_resume), bit-identical
  resume from step-indexed data;
* k-core — every part of the divide step is an idempotent sub-task over
  immutable inputs; a failed/straggling part is re-run without touching
  finished parts (the paper's 27.5 h WX-136B run is a sequence of such
  parts). ``run_with_retries`` is the standalone form; the part-parallel
  pipeline wires the same discipline through
  :func:`repro.core.partsched.conquer_wave`'s watchdog/retry layer;
* stragglers — host-side input lag is absorbed by the Prefetcher queue; a
  slow *worker* in synchronous SPMD is indistinguishable from a slow step,
  so mitigation happens at the part/job scheduler level via retry +
  checkpoint granularity (documented in DESIGN.md).

:class:`FaultPlan` is the chaos-engineering half: a declarative plan of
crashes, hangs and slowdowns injected into *named sites* of the pipeline
(``slice_conquer``, ``boundary_fold``, ``checkpoint_save``, ``prefetch``,
``serve_update``). Production code calls ``plan.visit(site)`` at each site
— a no-op unless the plan armed a fault there — so the chaos tests, the
CLI (``--fault``) and the bench harness all share one mechanism. Injected
hangs park on an Event with a bounded timeout and then raise, so an
abandoned worker thread always terminates (the test suite's thread-leak
gate stays sound under chaos).
"""
from __future__ import annotations

import dataclasses
import threading
import time
from typing import Callable, List, Optional, Sequence, Set

# Sites known to the pipeline. visit() accepts any name (a plan targeting
# an unknown site simply never fires), but the CLI validates against this
# list to catch typos in --fault.
FAULT_SITES = (
    "slice_conquer",    # conquer_wave: one part's conquer on a slice worker
    "boundary_fold",    # dckcore: E(v) boundary fold after a part finishes
    "checkpoint_save",  # dckcore: part-boundary pipeline-state save
    "prefetch",         # dckcore: background extract/bucketize worker
    "serve_update",     # kcore_serve: incremental update-worker batch
)


class InjectedFailure(RuntimeError):
    pass


@dataclasses.dataclass
class FailureInjector:
    """Raise at the given steps — once each (simulated worker loss)."""

    fail_at: Set[int]
    raised: Set[int] = dataclasses.field(default_factory=set)

    def maybe_fail(self, step: int):
        if step in self.fail_at and step not in self.raised:
            self.raised.add(step)
            raise InjectedFailure(f"injected failure at step {step}")


def run_with_retries(fn: Callable, retries: int = 2, backoff_s: float = 0.0,
                     on_retry: Optional[Callable] = None):
    """Run an idempotent sub-task, retrying on failure."""
    last = None
    for attempt in range(retries + 1):
        try:
            return fn()
        except Exception as e:  # noqa: BLE001 — deliberate catch-all boundary
            last = e
            if on_retry is not None:
                on_retry(attempt, e)
            if backoff_s:
                time.sleep(backoff_s * (attempt + 1))
    raise last


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """One armed fault: ``kind`` at the ``at``-th visit of ``site``.

    ``kind``: ``crash`` raises :class:`InjectedFailure`; ``hang`` parks the
    visiting thread until released (or ``delay_s`` elapses, then raises —
    a hang never outlives the run); ``slow`` sleeps ``delay_s`` and
    returns. ``at`` counts visits to the site from 0; ``count`` visits
    starting there fire (so ``at=0, count=10**9`` ≈ "every visit").
    """

    site: str
    kind: str = "crash"  # crash | hang | slow
    at: int = 0
    count: int = 1
    delay_s: float = 30.0

    def __post_init__(self):
        if self.kind not in ("crash", "hang", "slow"):
            raise ValueError(f"unknown fault kind {self.kind!r}")

    @classmethod
    def parse(cls, text: str) -> "FaultSpec":
        """Parse the CLI form ``site:kind[:at[:count[:delay_s]]]``."""
        parts = text.split(":")
        if not 2 <= len(parts) <= 5:
            raise ValueError(
                f"bad fault spec {text!r} — want site:kind[:at[:count[:delay_s]]]"
            )
        site, kind = parts[0], parts[1]
        if site not in FAULT_SITES:
            raise ValueError(
                f"unknown fault site {site!r} — known sites: {', '.join(FAULT_SITES)}"
            )
        at = int(parts[2]) if len(parts) > 2 else 0
        count = int(parts[3]) if len(parts) > 3 else 1
        delay = float(parts[4]) if len(parts) > 4 else 30.0
        return cls(site=site, kind=kind, at=at, count=count, delay_s=delay)


class FaultPlan:
    """Declarative chaos: inject faults into named pipeline sites.

    Thread-safe — sites are visited from slice workers, checkpoint
    threads and the prefetcher concurrently. Each injection (and each
    visit-counter advance for a site that fires) is recorded in
    ``events`` for the fault-event log the CI chaos leg uploads.
    """

    def __init__(self, specs: Sequence[FaultSpec] = ()):
        self.specs = list(specs)
        self.events: List[dict] = []
        self._visits: dict = {}
        self._lock = threading.Lock()
        # Set when the owning run abandons injected hangs: parked threads
        # wake and raise, so they can never outlive the run.
        self._release = threading.Event()

    @classmethod
    def parse(cls, texts: Sequence[str]) -> "FaultPlan":
        return cls([FaultSpec.parse(t) for t in texts])

    def release(self):
        """Wake every thread parked in an injected hang (it then raises)."""
        self._release.set()

    def _record(self, kind: str, **ctx):
        self.events.append({"event": "inject", "kind": kind, **ctx})

    def visit(self, site: str, **ctx) -> None:
        """Pass through the named site; inject a fault if one is armed.

        ``ctx`` (cursor, slice, attempt, ...) is stamped into the event
        log. Crash/hang raise :class:`InjectedFailure`; slow sleeps.
        """
        with self._lock:
            n = self._visits.get(site, 0)
            self._visits[site] = n + 1
            hit = None
            for spec in self.specs:
                if spec.site == site and spec.at <= n < spec.at + spec.count:
                    hit = spec
                    break
            if hit is not None:
                self._record(hit.kind, site=site, visit=n, **ctx)
        if hit is None:
            return
        if hit.kind == "crash":
            raise InjectedFailure(f"injected crash at {site} (visit {n})")
        if hit.kind == "slow":
            time.sleep(hit.delay_s)
            return
        # hang: park until released or delay_s elapses — then raise, so an
        # abandoned (blacklisted) worker thread always terminates.
        self._release.wait(timeout=hit.delay_s)
        raise InjectedFailure(f"injected hang at {site} (visit {n}) ended")

    def visits(self, site: str) -> int:
        with self._lock:
            return self._visits.get(site, 0)
