from repro.runtime.train_loop import TrainLoop, make_train_step
from repro.runtime.fault import (
    FAULT_SITES,
    FailureInjector,
    FaultPlan,
    FaultSpec,
    InjectedFailure,
    run_with_retries,
)
from repro.runtime.serve_loop import greedy_generate

__all__ = [
    "TrainLoop",
    "make_train_step",
    "FailureInjector",
    "FaultPlan",
    "FaultSpec",
    "FAULT_SITES",
    "InjectedFailure",
    "run_with_retries",
    "greedy_generate",
]
