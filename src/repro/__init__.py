"""DC-kCore on JAX/TPU.

Reproduction + beyond-paper optimization of "K-Core Decomposition on Super
Large Graphs with Limited Resources" (Gao et al., SAC '22) as a
production-grade multi-pod JAX framework. See README.md / DESIGN.md.
"""

__version__ = "1.0.0"
