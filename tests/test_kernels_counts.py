"""Partial-counts Pallas kernel: shape/tiling sweeps vs ref.py, and
equivalence with the distributed engine's pure-jnp path."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core.distributed import _partial_counts
from repro.kernels.counts import partial_counts_op, partial_counts_pallas, partial_counts_ref


@pytest.mark.parametrize("n", [8, 40, 128])
@pytest.mark.parametrize("w", [8, 64, 600])
@pytest.mark.parametrize("cand", [4, 64, 130])
def test_counts_shape_sweep(n, w, cand):
    rng = np.random.default_rng(n * 7 + w + cand)
    x = rng.integers(-1, w + 4, size=(n, w)).astype(np.int32)
    ext = rng.integers(0, 6, size=n).astype(np.int32)
    got = np.asarray(partial_counts_op(jnp.asarray(x), jnp.asarray(ext), cand=cand))
    want = np.asarray(partial_counts_ref(jnp.asarray(x), jnp.asarray(ext), cand))
    np.testing.assert_array_equal(got, want)


@pytest.mark.parametrize("tile_c,slot_chunk", [(16, 8), (128, 512), (64, 32)])
def test_counts_tiling_sweep(tile_c, slot_chunk):
    rng = np.random.default_rng(tile_c + slot_chunk)
    n, w, cand = 16, 96, 40
    x = rng.integers(-1, 50, size=(n, w)).astype(np.int32)
    ext = rng.integers(0, 3, size=n).astype(np.int32)
    got = np.asarray(
        partial_counts_pallas(
            jnp.asarray(x), jnp.asarray(ext), cand=cand,
            tile_c=tile_c, slot_chunk=slot_chunk,
        )
    )
    want = np.asarray(partial_counts_ref(jnp.asarray(x), jnp.asarray(ext), cand))
    np.testing.assert_array_equal(got, want)


def test_counts_matches_distributed_engine_path():
    rng = np.random.default_rng(3)
    n, w, cand = 24, 32, 16
    x = jnp.asarray(rng.integers(-1, 30, size=(n, w)).astype(np.int32))
    ext = jnp.asarray(rng.integers(0, 4, size=n).astype(np.int32))
    engine = np.asarray(_partial_counts(x, ext, cand))
    kernel = np.asarray(partial_counts_op(x, ext, cand=cand))
    np.testing.assert_array_equal(engine, kernel)
