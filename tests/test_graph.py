"""Graph substrate tests: construction, generators, oracle, bucketing."""
import numpy as np
import pytest

from repro.graph.build import bucketize, external_info, induced_subgraph
from repro.graph.generators import barabasi_albert, erdos_renyi, rmat
from repro.graph.oracle import nx_coreness, peel_coreness, peel_kcore_mask
from repro.graph.structs import Graph


def test_from_edges_symmetrize_dedup():
    g = Graph.from_edges([0, 1, 1, 2, 0], [1, 0, 2, 1, 0], n_nodes=4)
    # self loop (0,0) dropped; (0,1) dup dropped; symmetric.
    assert g.n_edges == 2
    assert set(g.neighbors(1).tolist()) == {0, 2}
    assert g.degrees.tolist() == [1, 2, 1, 0]
    g.validate()


def test_generators_basic():
    for g in [erdos_renyi(500, 6.0, seed=1), barabasi_albert(500, 4, seed=1), rmat(9, 8, seed=1)]:
        g.validate()
        assert g.n_edges > 0
        # Undirected: each edge counted twice in indices.
        assert g.indices.shape[0] == 2 * g.n_edges


def test_ba_powerlaw_tail():
    g = barabasi_albert(3000, 5, seed=0)
    deg = g.degrees
    assert deg.max() > 10 * np.median(deg[deg > 0])  # heavy tail


def test_peel_matches_networkx(er_graph, ba_graph):
    for g in [er_graph, ba_graph]:
        np.testing.assert_array_equal(peel_coreness(g), nx_coreness(g))


def test_peel_kcore_mask(ba_graph):
    core = peel_coreness(ba_graph)
    for k in [2, 3, 5]:
        mask = peel_kcore_mask(ba_graph, k)
        np.testing.assert_array_equal(mask, core >= k)


def test_induced_subgraph_and_external_info(rmat_graph):
    g = rmat_graph
    core = peel_coreness(g)
    k = int(np.median(core)) + 1  # guarantee both sides non-empty
    upper = core >= k
    assert upper.any() and (~upper).any()
    sub, ids = induced_subgraph(g, upper)
    assert sub.n_nodes == int(upper.sum())
    # Every kept edge exists in the original graph.
    for v_new in range(min(sub.n_nodes, 50)):
        v_old = ids[v_new]
        neigh_old = set(g.neighbors(v_old).tolist())
        for u_new in sub.neighbors(v_new):
            assert int(ids[u_new]) in neigh_old
    # External info of the complement counts cross edges exactly.
    ext = external_info(g, ~upper, upper)
    lower_ids = np.nonzero(~upper)[0]
    for i in np.random.default_rng(0).choice(len(lower_ids), size=30):
        v = lower_ids[i]
        expect = int(np.sum(upper[g.neighbors(v)]))
        assert ext[i] == expect


def test_bucketize_roundtrip(rmat_graph):
    g = rmat_graph
    bg = bucketize(g)
    deg = g.degrees
    seen = np.zeros(g.n_nodes, dtype=bool)
    for b in bg.buckets:
        rows = b.node_ids[b.node_ids < g.n_nodes]
        assert not seen[rows].any()
        seen[rows] = True
        for r, v in enumerate(rows[: min(len(rows), 20)]):
            row = b.neigh[r]
            real = row[row < g.n_nodes]
            assert sorted(real.tolist()) == sorted(g.neighbors(v).tolist())
            assert b.deg[r] == deg[v]
            assert deg[v] <= b.width
    # All nonzero-degree nodes covered exactly once; zero-degree excluded.
    np.testing.assert_array_equal(seen, deg > 0)
    # Padding bounded: total slots <= 2x edges (power-of-two buckets) + rows.
    assert bg.padded_slots <= 4 * g.indices.shape[0] + sum(b.n_rows * 1 for b in bg.buckets) * 8


def test_edge_cases():
    """Empty graphs, isolated nodes, self-loop-only inputs."""
    import jax
    from repro.core.decompose import decompose
    from repro.core.dckcore import dc_kcore

    empty = Graph.from_edges(np.array([], dtype=np.int64), np.array([], dtype=np.int64), n_nodes=5)
    core, _ = dc_kcore(empty, thresholds=(2,))
    np.testing.assert_array_equal(core, np.zeros(5, np.int32))

    loops = Graph.from_edges([0, 1, 2], [0, 1, 2], n_nodes=3)  # all self-loops
    assert loops.n_edges == 0
    core, _ = dc_kcore(loops, thresholds=())
    np.testing.assert_array_equal(core, np.zeros(3, np.int32))

    pair = Graph.from_edges([0], [1], n_nodes=4)  # one edge + 2 isolated
    core, _ = dc_kcore(pair, thresholds=(1,))
    np.testing.assert_array_equal(core, np.array([1, 1, 0, 0], np.int32))
