"""Streaming-ingest equivalence tests.

The out-of-core CSR build must be **bit-identical** to the in-memory
loaders at every chunk size — including chunk=1 and chunk > n_edges — on
file and array sources, adversarial inputs (self-loops, duplicates, both
directions), and empty/edge-case graphs. Alongside equivalence:

  * the tracked transient peak stays below the in-memory loader's array
    working set (the host-side resource claim, bench fig14's gate);
  * `EdgeStore.dup_degrees` upper-bounds true degrees and feeds
    `plan_thresholds` / `rough_candidates` without the CSR resident;
  * spill directories are cleaned up.
"""
import os

import numpy as np
import pytest

from repro.core.divide import plan_thresholds, rough_candidates
from repro.graph.generators import barabasi_albert, erdos_renyi, rmat
from repro.graph.io import (
    EdgeStore,
    csr_from_edge_chunks,
    graph_edge_chunks,
    iter_edgelist_chunks,
    load_edgelist,
    save_edgelist,
    stream_edgelist,
)
from repro.graph.structs import Graph


def assert_same_graph(a: Graph, b: Graph):
    assert a.n_nodes == b.n_nodes
    np.testing.assert_array_equal(a.indptr, b.indptr)
    np.testing.assert_array_equal(a.indices, b.indices)
    assert a.indptr.dtype == b.indptr.dtype
    assert a.indices.dtype == b.indices.dtype


@pytest.fixture(params=["er", "ba", "rmat"])
def fixture_graph(request, er_graph, ba_graph, rmat_graph):
    return {"er": er_graph, "ba": ba_graph, "rmat": rmat_graph}[request.param]


@pytest.mark.parametrize("chunk", [17, 1000, 10**7])
def test_stream_edgelist_bit_identical(fixture_graph, tmp_path, chunk):
    path = str(tmp_path / "edges.txt")
    save_edgelist(path, fixture_graph)
    mem = load_edgelist(path)
    streamed, stats = stream_edgelist(path, chunk_edges=chunk)
    assert_same_graph(streamed, mem)
    assert stats.n_chunks == -(-fixture_graph.n_edges // chunk)


def test_stream_edgelist_chunk_one(tmp_path):
    """chunk=1 (one edge per chunk) on a small graph, plus comment lines."""
    g = erdos_renyi(60, 4.0, seed=5)
    path = str(tmp_path / "edges.txt")
    save_edgelist(path, g)
    with open(path) as f:
        body = f.read()
    with open(path, "w") as f:
        f.write("# SNAP-style comment\n\n" + body)
    mem = load_edgelist(path)
    streamed, stats = stream_edgelist(path, chunk_edges=1)
    assert_same_graph(streamed, mem)
    assert stats.n_chunks == g.n_edges


@pytest.mark.parametrize("seed", range(8))
@pytest.mark.parametrize("chunk", [1, 3, 10**6])
def test_chunked_build_matches_from_edges_adversarial(seed, chunk):
    """Directed duplicates, self-loops, multi-chunk split points."""
    rng = np.random.default_rng(seed)
    n = int(rng.integers(2, 50))
    m = int(rng.integers(0, 5 * n))
    src = rng.integers(0, n, size=m)
    dst = rng.integers(0, n, size=m)
    # Force self-loops and duplicate edges into the stream.
    if m >= 4:
        src[0] = dst[0] = 0
        src[1], dst[1] = src[2], dst[2]
    ref = Graph.from_edges(src, dst, n_nodes=n)
    chunks = [(src[i : i + chunk], dst[i : i + chunk]) for i in range(0, m, chunk)]
    got, _stats = csr_from_edge_chunks(iter(chunks), n_nodes=n, chunk_edges=chunk)
    assert_same_graph(got, ref)


def test_n_nodes_inference_counts_self_loop_max_id():
    """from_edges infers n BEFORE dropping self-loops; streaming must too."""
    src = np.array([0, 1, 9], dtype=np.int64)
    dst = np.array([1, 0, 9], dtype=np.int64)  # max id only in a self-loop
    ref = Graph.from_edges(src, dst)
    got, _ = csr_from_edge_chunks([(src, dst)])
    assert got.n_nodes == ref.n_nodes == 10
    assert_same_graph(got, ref)


def test_empty_and_range_errors(tmp_path):
    got, _ = csr_from_edge_chunks([], n_nodes=5)
    assert_same_graph(got, Graph.empty(5))
    with pytest.raises(ValueError, match="out of range"):
        csr_from_edge_chunks([(np.array([0]), np.array([7]))], n_nodes=4)
    with pytest.raises(ValueError, match="out of range"):
        csr_from_edge_chunks([(np.array([-1]), np.array([2]))], n_nodes=4)


def test_out_of_range_self_loop_parity():
    """from_edges range-checks AFTER dropping self-loops: an oversized id
    that appears only in a self-loop loads fine — streaming must agree."""
    src, dst = np.array([0, 9]), np.array([1, 9])
    ref = Graph.from_edges(src, dst, n_nodes=5)  # (9,9) dropped, loads
    got, _ = csr_from_edge_chunks([(src, dst)], n_nodes=5)
    assert_same_graph(got, ref)
    # But the same id on a real edge is rejected by both paths.
    with pytest.raises(ValueError, match="out of range"):
        Graph.from_edges(np.array([0, 9]), np.array([1, 2]), n_nodes=5)
    with pytest.raises(ValueError, match="out of range"):
        csr_from_edge_chunks([(np.array([0, 9]), np.array([1, 2]))], n_nodes=5)


def test_graph_edge_chunks_roundtrip(rmat_graph):
    """The synthetic-graph adapter re-streams each undirected edge once."""
    for chunk in (64, 4096, 10**7):
        total = 0
        for src, dst in graph_edge_chunks(rmat_graph, chunk):
            assert src.size == dst.size <= chunk
            assert (src < dst).all()
            total += src.size
        assert total == rmat_graph.n_edges
    rebuilt, _ = csr_from_edge_chunks(
        graph_edge_chunks(rmat_graph, 1024), n_nodes=rmat_graph.n_nodes,
        chunk_edges=1024,
    )
    assert_same_graph(rebuilt, rmat_graph)


def test_transient_bytes_bounded_by_chunk_not_edges(rmat_graph):
    """Peak transient < in-memory baseline, and shrinking the chunk shrinks
    the peak — the bound tracks the chunk budget, not the edge count."""
    peaks = {}
    for chunk in (1 << 10, 1 << 14):
        _, stats = csr_from_edge_chunks(
            graph_edge_chunks(rmat_graph, chunk), n_nodes=rmat_graph.n_nodes,
            chunk_edges=chunk,
        )
        assert stats.peak_transient_bytes < stats.baseline_transient_bytes
        peaks[chunk] = stats.peak_transient_bytes
    assert peaks[1 << 10] < peaks[1 << 14]


def test_edge_store_degrees_and_planning(rmat_graph, tmp_path):
    """Divide planning runs from the spill store's degree counts alone."""
    store = EdgeStore(workdir=str(tmp_path / "store"))
    with store:
        for src, dst in graph_edge_chunks(rmat_graph, 4096):
            store.append(src, dst)
        dup = store.dup_degrees(rmat_graph.n_nodes)
        true_deg = rmat_graph.degrees.astype(np.int64)
        assert (dup >= true_deg).all()
        # save_edgelist emits each undirected edge once -> no duplicates here.
        np.testing.assert_array_equal(dup, true_deg)
        budget = rmat_graph.memory_bytes() // 3
        assert plan_thresholds(dup, budget) == plan_thresholds(rmat_graph, budget)
        t = 8
        np.testing.assert_array_equal(
            rough_candidates(dup.astype(np.int32), np.zeros(rmat_graph.n_nodes, np.int32), t),
            rough_candidates(rmat_graph.degrees, np.zeros(rmat_graph.n_nodes, np.int32), t),
        )


def test_edge_store_cleanup():
    store = EdgeStore()
    workdir = store.workdir
    store.append(np.array([0, 1]), np.array([1, 2]))
    store.cleanup()
    assert not os.path.exists(workdir)


def test_plan_thresholds_accepts_degree_array(ba_graph):
    budget = ba_graph.memory_bytes() // 4
    assert plan_thresholds(ba_graph.degrees, budget) == plan_thresholds(ba_graph, budget)
