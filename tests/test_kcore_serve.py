"""Serving-layer tests: snapshot publication, torn-state safety, CLI.

The publish/swap ordering contract under test: a snapshot is built
COMPLETELY (fresh read-only arrays, checksum stamped) before the single
reference assignment that publishes it, so a reader that grabbed the front
pointer at any instant — including mid-swap — holds a self-consistent
object. The torn-state test hammers queries from reader threads while a
writer republishes as fast as it can, and every observed snapshot must
self-verify and carry a non-decreasing version.
"""
from __future__ import annotations

import threading

import numpy as np
import pytest

from repro.core.snapshot_pub import CorenessSnapshot, SnapshotPublisher
from repro.graph.editlog import EditLog
from repro.graph.generators import rmat
from repro.graph.oracle import peel_coreness, peel_kcore_mask


@pytest.fixture(scope="module")
def served_graph():
    g = rmat(9, 8, seed=6)
    return g, peel_coreness(g).astype(np.int32)


def test_queries_match_oracle(served_graph):
    g, core = served_graph
    pub = SnapshotPublisher()
    pub.publish(g, core)
    rng = np.random.default_rng(0)
    ids = rng.integers(-5, g.n_nodes + 5, 64)
    got = pub.query_coreness(ids)
    ok = (ids >= 0) & (ids < g.n_nodes)
    assert np.array_equal(got[ok], core[ids[ok]])
    assert np.all(got[~ok] == 0)

    for k in (1, 2, int(core.max())):
        members = pub.query_kcore_members(k)
        assert np.array_equal(members, np.nonzero(peel_kcore_mask(g, k))[0])
        flags = pub.query_in_kcore(ids, k)
        assert np.array_equal(flags[ok], core[ids[ok]] >= k)
        assert not flags[~ok].any()

    k_max, top = pub.query_top_kcore()
    assert k_max == int(core.max())
    assert np.array_equal(top, np.nonzero(core >= k_max)[0])


def test_snapshot_is_immutable_and_detached(served_graph):
    g, core = served_graph
    pub = SnapshotPublisher()
    scratch = core.copy()
    snap = pub.publish(g, scratch)
    scratch[:] = -1  # the caller may reuse its buffer after publish
    assert np.array_equal(snap.coreness, core)
    with pytest.raises(ValueError):
        snap.coreness[0] = 7
    assert snap.verify()


def test_query_before_first_publish_raises():
    pub = SnapshotPublisher()
    assert pub.snapshot is None
    with pytest.raises(RuntimeError, match="no snapshot"):
        pub.query_coreness([0])


def test_checksum_detects_torn_payload(served_graph):
    g, core = served_graph
    snap = SnapshotPublisher().publish(g, core)
    mixed = core.copy()
    mixed[0] += 1  # one element from "another version"
    torn = CorenessSnapshot(graph=g, coreness=mixed, version=snap.version,
                            checksum=snap.checksum,
                            published_at=snap.published_at)
    assert snap.verify() and not torn.verify()


def test_swap_never_observes_torn_state(served_graph):
    g, core = served_graph
    pub = SnapshotPublisher()
    pub.publish(g, core)
    stop = threading.Event()
    failures = []

    def writer():
        rng = np.random.default_rng(1)
        for _ in range(300):
            # Distinct payload every publish: a torn read WOULD mismatch.
            delta = rng.integers(0, 3, core.size).astype(np.int32)
            pub.publish(g, core + delta, n_edits=1)
        stop.set()

    def reader(seed):
        rng = np.random.default_rng(seed)
        last_version = 0
        while not stop.is_set() or rng.random() < 0.5:
            snap = pub.snapshot
            if not snap.verify():
                failures.append(("torn", snap.version))
                return
            if snap.version < last_version:
                failures.append(("version went backwards", snap.version))
                return
            last_version = snap.version
            ids = rng.integers(0, g.n_nodes, 32)
            got = pub.query_coreness(ids)
            if got.size != 32:
                failures.append(("bad shape", snap.version))
                return
            if stop.is_set():
                return

    threads = [threading.Thread(target=writer, name="kcore-serve-test-w")]
    threads += [
        threading.Thread(target=reader, args=(s,), name="kcore-serve-test-r")
        for s in range(4)
    ]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=60)
    assert not failures, failures
    assert pub.metrics()["n_publishes"] == 301


def test_metrics_shape(served_graph):
    g, core = served_graph
    pub = SnapshotPublisher()
    pub.note_pending(7)
    pub.publish(g, core, n_edits=5)
    for _ in range(20):
        pub.query_coreness([0, 1, 2])
    m = pub.metrics()
    assert m["n_publishes"] == 1
    assert m["n_edits_published"] == 5
    assert m["pending_edits"] == 2  # 7 noted - 5 folded in
    assert m["n_queries"] == 20
    assert 0.0 <= m["query_p50_ms"] <= m["query_p99_ms"]
    assert m["updates_per_s"] > 0
    assert m["staleness_mean_edits"] == 2.0


def test_serve_cli_end_to_end(tmp_path):
    from repro.launch.kcore_serve import main

    rng = np.random.default_rng(5)
    n = 2 ** 8
    with EditLog(str(tmp_path / "log")) as log:
        for _ in range(5):
            log.append(rng.integers(0, n, 2), rng.integers(0, n, 2))
            log.append(rng.integers(0, n, 1), rng.integers(0, n, 1),
                       delete=True)
            log.seal_batch()
        m = main(["--graph", "rmat:8:4", "--edit-log", log.workdir,
                  "--engine", "count", "--max-batches", "5",
                  "--query-batch", "16", "--json"])
    assert m["batches_drained"] == 5
    assert m["pending_edits"] == 0
    assert m["n_publishes"] == 6  # boot + one per batch
    assert m["n_queries"] > 0
    assert 0.0 <= m["query_p50_ms"] <= m["query_p99_ms"]
    # The update worker (kcore-serve-update) must be joined on exit — the
    # conftest leak gate fails this test otherwise.


def test_serve_cli_idle_timeout_exit(tmp_path):
    from repro.launch.kcore_serve import main

    with EditLog(str(tmp_path / "log")) as log:
        log.append([0], [1])
        log.seal_batch()
        m = main(["--graph", "rmat:8:4", "--edit-log", log.workdir,
                  "--engine", "count", "--idle-timeout-s", "0.2",
                  "--json"])
    assert m["batches_drained"] == 1
