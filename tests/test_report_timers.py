"""Regression tests for the timer/bugfix satellites.

- Duration accounting must use the monotonic ``time.perf_counter``: an NTP
  step (modeled here as a ``time.time`` that runs BACKWARDS) must not
  produce negative ``idle_fraction``/``save_wall_s`` or out-of-range
  utilization.
- ``SweepSnapshot.restore`` must log a one-line warning (path + reason)
  when it silently degrades to part-boundary resume.
- ``run_with_capacity_replan`` must respond to ``SliceCapacityError`` by
  re-dividing with smaller parts, not aborting — including the planted
  oversized-part integration case from the acceptance criteria.
"""
from __future__ import annotations

import logging
import time

import numpy as np
import pytest

import repro.ckpt as ckpt_mod
from repro.core.decompose import decompose
from repro.core.dckcore import SweepSnapshot, dc_kcore
from repro.core.divide import plan_thresholds
from repro.core.partsched import SliceCapacityError
from repro.graph.build import bucketize
from repro.graph.generators import rmat
from repro.launch.kcore import run_with_capacity_replan


@pytest.fixture
def clock_stepping_backwards(monkeypatch):
    """time.time() that loses ~1s per call — the NTP-step nightmare.

    perf_counter is left alone (it is monotonic by contract); any duration
    still measured off the wall clock goes negative and trips the
    invariant assertions below.
    """
    start = time.time()
    calls = [0]

    def broken_time():
        calls[0] += 1
        return start - calls[0]

    monkeypatch.setattr(time, "time", broken_time)
    return calls


def test_decompose_wall_time_survives_wall_clock_step(
    clock_stepping_backwards,
):
    res = decompose(bucketize(rmat(8, 6, seed=1)), op="count")
    assert res.wall_time_s >= 0


def test_report_invariants_survive_wall_clock_step(
    tmp_path, clock_stepping_backwards
):
    g = rmat(9, 6, seed=3)
    core, report = dc_kcore(
        g, thresholds=[8], engine="count",
        checkpoint_dir=str(tmp_path / "ck"), sweep_checkpoint_every=2,
    )
    assert 0.0 <= report.idle_fraction <= 1.0
    assert report.total_time_s >= 0
    assert report.total_decompose_time_s >= 0
    assert report.preprocess_time_s >= 0
    assert report.total_save_time_s >= 0
    assert report.total_save_wall_s >= 0
    for p in report.parts:
        assert p.save_time_s >= 0
        assert p.save_wall_s >= 0
    from repro.graph.oracle import peel_coreness

    assert np.array_equal(core, peel_coreness(g))


def test_report_invariants_overlap_mode(tmp_path, clock_stepping_backwards):
    g = rmat(9, 6, seed=3)
    _, report = dc_kcore(
        g, thresholds=[8], engine="count", overlap=True,
        checkpoint_dir=str(tmp_path / "ck"),
    )
    assert 0.0 <= report.idle_fraction <= 1.0
    assert report.total_save_wall_s >= 0


# ---------------------------------------------------------------------------
# SweepSnapshot.restore degradation warnings
# ---------------------------------------------------------------------------

def test_restore_warns_on_unreadable_snapshot(monkeypatch, caplog, tmp_path):
    sweep_dir = str(tmp_path / "sweep")
    monkeypatch.setattr(ckpt_mod, "latest_step", lambda d: 3)

    def boom(*args, **kwargs):
        raise IOError("truncated payload")

    # restore goes through the CRC-checking fallback path; only a
    # non-corruption failure (e.g. truncated payload) warns — CRC
    # mismatches are quarantined inside the fallback itself.
    monkeypatch.setattr(ckpt_mod, "restore_pytree_with_fallback", boom)
    with caplog.at_level(logging.WARNING, logger="repro.core.dckcore"):
        assert SweepSnapshot.restore(sweep_dir) is None
    assert "unreadable" in caplog.text
    assert sweep_dir in caplog.text
    assert "truncated payload" in caplog.text


def test_restore_warns_on_format_mismatch(monkeypatch, caplog, tmp_path):
    sweep_dir = str(tmp_path / "sweep")
    monkeypatch.setattr(ckpt_mod, "latest_step", lambda d: 3)
    monkeypatch.setattr(
        ckpt_mod, "restore_pytree_with_fallback",
        lambda *a, **k: (
            {"part_coreness": np.zeros(4, np.int32)}, 3, {"format": "bogus"}
        ),
    )
    with caplog.at_level(logging.WARNING, logger="repro.core.dckcore"):
        assert SweepSnapshot.restore(sweep_dir) is None
    assert "bogus" in caplog.text
    assert sweep_dir in caplog.text


def test_restore_silent_when_no_snapshot(monkeypatch, caplog, tmp_path):
    # Nothing saved yet is the normal case — no warning noise.
    with caplog.at_level(logging.WARNING, logger="repro.core.dckcore"):
        assert SweepSnapshot.restore(str(tmp_path / "empty")) is None
    assert caplog.text == ""


# ---------------------------------------------------------------------------
# Capacity wiring: SliceCapacityError -> re-divide, not abort
# ---------------------------------------------------------------------------

def test_replan_helper_retries_with_smaller_parts_and_no_resume():
    g = rmat(10, 8, seed=0)
    calls = []

    def fake_dc(graph, thresholds, **kw):
        calls.append((tuple(thresholds), kw.get("resume")))
        if len(calls) == 1:
            raise SliceCapacityError("planted oversized part")
        return "core", "report"

    core, report, th, n_replans = run_with_capacity_replan(
        g, [], replan_budget_bytes=80_000, dc=fake_dc, resume=True,
    )
    assert (core, report) == ("core", "report")
    assert n_replans == 1
    assert calls[0] == ((), True)
    # Retry re-divided at the halved budget with a doubled part allowance,
    # and forced resume off (the aborted attempt's checkpoints describe a
    # different partition).
    expected = tuple(plan_thresholds(g.degrees, 40_000, max_parts=16))
    assert calls[1] == (expected, False)
    assert list(th) == list(expected)


def test_replan_helper_reraises_without_budget():
    g = rmat(9, 6, seed=0)
    calls = []

    def fake_dc(graph, thresholds, **kw):
        calls.append(1)
        raise SliceCapacityError("no budget to replan from")

    with pytest.raises(SliceCapacityError):
        run_with_capacity_replan(g, [], replan_budget_bytes=None, dc=fake_dc)
    assert len(calls) == 1


def test_replan_helper_gives_up_after_max_replans():
    g = rmat(9, 6, seed=0)
    calls = []

    def fake_dc(graph, thresholds, **kw):
        calls.append(1)
        raise SliceCapacityError("hopeless")

    with pytest.raises(SliceCapacityError):
        run_with_capacity_replan(
            g, [], replan_budget_bytes=1 << 30, max_replans=2, dc=fake_dc,
        )
    assert len(calls) == 3  # initial + 2 replans


def test_planted_oversized_part_triggers_redivide_not_abort():
    """Acceptance case: a monolithic plan whose one part exceeds every
    slice's capacity must converge through re-divides to a completed,
    oracle-consistent run."""
    from repro.graph.oracle import peel_coreness

    g = rmat(10, 8, seed=0)
    core, report, thresholds, n_replans = run_with_capacity_replan(
        g, [], replan_budget_bytes=120_000, engine="count",
        part_parallel=2, slice_capacity_bytes=60_000,
    )
    assert n_replans >= 1, "the planted part must actually trip capacity"
    assert len(thresholds) > 0, "re-divide must have split the graph"
    assert np.array_equal(core, peel_coreness(g))
    assert report.part_parallel == 2
