"""Shared fixtures. NOTE: XLA_FLAGS / device-count overrides are deliberately
NOT set here — smoke tests and benches must see the single real CPU device.
Multi-device tests spawn subprocesses with their own env (see
tests/distributed_helpers.py)."""
import numpy as np
import pytest

from repro.graph.generators import barabasi_albert, erdos_renyi, rmat


@pytest.fixture(scope="session")
def ba_graph():
    return barabasi_albert(n=2000, m=5, seed=7)


@pytest.fixture(scope="session")
def rmat_graph():
    """Power-law graph with a wide coreness spread (0..~33) — the main
    fixture for divide/conquer tests."""
    return rmat(11, 8, seed=7)


@pytest.fixture(scope="session")
def er_graph():
    return erdos_renyi(n=1500, avg_deg=8.0, seed=3)


@pytest.fixture
def rng():
    return np.random.default_rng(0)
