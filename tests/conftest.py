"""Shared fixtures. NOTE: XLA_FLAGS / device-count overrides are deliberately
NOT set here — smoke tests and benches must see the single real CPU device.
Multi-device tests spawn subprocesses with their own env (see
tests/distributed_helpers.py)."""
import threading
import time

import numpy as np
import pytest

from repro.graph.generators import barabasi_albert, erdos_renyi, rmat

# Worker threads the pipeline may spin up; every dc_kcore /
# CheckpointManager exit path must drain them (close()/wait()), so one
# outliving a test is a leak — equivalent to a missed wait()-on-exit.
_PIPELINE_THREAD_PREFIXES = (
    "ckpt-save", "dckcore-prefetch", "dckcore-conquer", "kcore-serve",
)


@pytest.fixture(autouse=True)
def no_leaked_pipeline_threads():
    """Fail any test that leaks a checkpoint-save or prefetch worker."""
    yield
    deadline = time.time() + 2.0  # grace: drains already in progress
    while time.time() < deadline:
        leaked = [
            t for t in threading.enumerate()
            if t.name.startswith(_PIPELINE_THREAD_PREFIXES) and t.is_alive()
        ]
        if not leaked:
            return
        time.sleep(0.05)
    raise AssertionError(
        f"leaked pipeline worker threads: {[t.name for t in leaked]} — "
        f"a CheckpointManager.wait() or _PartPipeline.close() is missing"
    )


@pytest.fixture
def worker_harness():
    """Multi-process test harness (one child interpreter per mesh slice).

    Teardown is a process-leak gate, the subprocess analogue of the thread
    gate above: a child outliving the test body means a join() is missing
    (or a multi-process rendezvous deadlocked) — the leaked children are
    killed and the test fails naming their PIDs."""
    from distributed_helpers import WorkerHarness

    h = WorkerHarness()
    yield h
    pids = h.terminate_leaked()
    if pids:
        raise AssertionError(
            f"leaked worker subprocesses (pids {pids}) — a "
            f"WorkerHarness.join() is missing or a rendezvous deadlocked"
        )


@pytest.fixture(scope="session")
def ba_graph():
    return barabasi_albert(n=2000, m=5, seed=7)


@pytest.fixture(scope="session")
def rmat_graph():
    """Power-law graph with a wide coreness spread (0..~33) — the main
    fixture for divide/conquer tests."""
    return rmat(11, 8, seed=7)


@pytest.fixture(scope="session")
def er_graph():
    return erdos_renyi(n=1500, avg_deg=8.0, seed=3)


@pytest.fixture
def rng():
    return np.random.default_rng(0)
