"""Elastic scaling: checkpoints are mesh-agnostic — a run saved on an
8-device mesh restores (and keeps training, bit-identically in math) on a
4-device mesh. Subprocess per device count."""
from distributed_helpers import run_with_devices

_SAVE = r"""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P, NamedSharding
from repro.ckpt import save_pytree
from repro.compat import make_mesh
mesh = make_mesh((4, 2), ("data", "model"))
w = jax.device_put(jnp.arange(64*32, dtype=jnp.float32).reshape(64, 32),
                   NamedSharding(mesh, P("data", "model")))
b = jax.device_put(jnp.ones((32,), jnp.float32), NamedSharding(mesh, P("model")))
save_pytree("%DIR%", {"w": w, "b": b}, step=3, extra={"mesh": "4x2"})
print("SAVED")
"""

_RESTORE = r"""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P, NamedSharding
from repro.ckpt import restore_pytree
from repro.compat import make_mesh
assert len(jax.devices()) == 4
mesh = make_mesh((2, 2), ("data", "model"))
template = {"w": np.zeros((64, 32), np.float32), "b": np.zeros((32,), np.float32)}
shardings = {"w": NamedSharding(mesh, P("data", "model")),
             "b": NamedSharding(mesh, P("model"))}
tree, step, extra = restore_pytree("%DIR%", template, shardings=shardings)
assert step == 3 and extra["mesh"] == "4x2"
np.testing.assert_array_equal(np.asarray(tree["w"]),
                              np.arange(64*32, dtype=np.float32).reshape(64, 32))
assert tree["w"].sharding.mesh.shape["data"] == 2  # re-sharded onto new mesh
print("RESTORED")
"""


def test_elastic_remesh_8_to_4(tmp_path):
    d = str(tmp_path / "ck")
    out = run_with_devices(_SAVE.replace("%DIR%", d), n_devices=8)
    assert "SAVED" in out
    out = run_with_devices(_RESTORE.replace("%DIR%", d), n_devices=4)
    assert "RESTORED" in out
