"""Differential suite for incremental coreness maintenance.

The contract under test is absolute: after every edit batch,
``apply_updates`` must be BIT-IDENTICAL to a from-scratch decompose (and
the BZ peeling oracle) on the post-edit graph — whichever internal mode it
took (seed-restricted incremental re-sweep, full fallback, noop). The CSR
delta layer carries the same discipline: its spliced graph must be
bit-identical to ``Graph.from_edges`` on the post-edit edge set.

Property tests run under hypothesis when installed; seeded ports of each
property always run.
"""
from __future__ import annotations

import numpy as np
import pytest

from repro.core.decompose import decompose
from repro.core.incremental import apply_updates
from repro.graph.build import bucketize
from repro.graph.delta import EdgeEdits, apply_edge_deltas
from repro.graph.editlog import EditLog, EditLogReader
from repro.graph.generators import barabasi_albert, erdos_renyi, rmat
from repro.graph.oracle import peel_coreness
from repro.graph.structs import Graph

try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False


# ---------------------------------------------------------------------------
# helpers
# ---------------------------------------------------------------------------

def _canonical_pairs(src, dst):
    src = np.asarray(src, np.int64)
    dst = np.asarray(dst, np.int64)
    keep = src != dst
    src, dst = src[keep], dst[keep]
    lo, hi = np.minimum(src, dst), np.maximum(src, dst)
    return set(zip(lo.tolist(), hi.tolist()))


def _reference_graph(edge_set, n_nodes):
    if not edge_set:
        return Graph.empty(n_nodes)
    u = np.array([p[0] for p in edge_set] + [p[1] for p in edge_set], np.int64)
    v = np.array([p[1] for p in edge_set] + [p[0] for p in edge_set], np.int64)
    return Graph.from_edges(u, v, n_nodes=n_nodes)


def _assert_graph_identical(a: Graph, b: Graph):
    assert a.n_nodes == b.n_nodes
    assert np.array_equal(a.indptr, b.indptr)
    assert np.array_equal(a.indices, b.indices)
    assert a.indptr.dtype == b.indptr.dtype
    assert a.indices.dtype == b.indices.dtype


def _random_batch(rng, g, n_ins, n_del):
    """n_ins random inserts + n_del deletes of EXISTING edges."""
    n = g.n_nodes
    iu = rng.integers(0, n, n_ins)
    iv = rng.integers(0, n, n_ins)
    du, dv = [], []
    nz = np.nonzero(np.diff(g.indptr) > 0)[0]
    for _ in range(n_del):
        if nz.size == 0:
            break
        r = int(nz[rng.integers(0, nz.size)])
        du.append(r)
        dv.append(int(g.indices[rng.integers(g.indptr[r], g.indptr[r + 1])]))
    return EdgeEdits.of(iu, iv, du, dv)


def _run_churn(g, n_steps, seed, *, batch_hi=1, op="count",
               dirty_budget_frac=0.5):
    """Drive a churn stream; assert bit-identity after EVERY batch."""
    core = peel_coreness(g).astype(np.int32)
    rng = np.random.default_rng(seed)
    modes = {}
    for step in range(n_steps):
        k = int(rng.integers(1, batch_hi + 1))
        ins = int(rng.integers(0, k + 1))
        edits = _random_batch(rng, g, ins, k - ins)
        res = apply_updates(g, core, edits, op=op,
                            dirty_budget_frac=dirty_budget_frac)
        g, core = res.graph, res.coreness
        oracle = peel_coreness(g)
        assert np.array_equal(core, oracle), (step, res.mode)
        assert core.dtype == np.int32
        modes[res.mode] = modes.get(res.mode, 0) + 1
    # End-to-end engine check too (decompose, not just the peeling oracle).
    full = decompose(bucketize(g), op=op)
    assert np.array_equal(core, full.coreness)
    return modes


# ---------------------------------------------------------------------------
# CSR delta application
# ---------------------------------------------------------------------------

def test_delta_bit_identical_to_from_edges_random():
    rng = np.random.default_rng(0)
    n = 60
    for trial in range(25):
        m = int(rng.integers(0, 300))
        u, v = rng.integers(0, n, m), rng.integers(0, n, m)
        g = Graph.from_edges(u, v, n_nodes=n)
        mi, md = int(rng.integers(0, 40)), int(rng.integers(0, 40))
        e = EdgeEdits.of(rng.integers(0, n, mi), rng.integers(0, n, mi),
                         rng.integers(0, n, md), rng.integers(0, n, md))
        res = apply_edge_deltas(g, e)
        E = _canonical_pairs(u, v)
        ins = _canonical_pairs(e.ins_src, e.ins_dst)
        dels = _canonical_pairs(e.del_src, e.del_dst)
        _assert_graph_identical(
            res.graph, _reference_graph((E - dels) | ins, res.graph.n_nodes)
        )
        # Effective edits = exactly the edges that flipped.
        assert res.n_inserted == len(ins - E)
        assert res.n_deleted == len((dels & E) - ins)


def test_delta_set_semantics_insert_and_delete_same_edge():
    g = Graph.from_edges(np.array([0]), np.array([1]), n_nodes=3)
    # Deleted AND inserted in one batch -> survives, zero effective edits.
    res = apply_edge_deltas(g, EdgeEdits.of([0], [1], [0], [1]))
    _assert_graph_identical(res.graph, g)
    assert res.n_effective == 0


def test_delta_noops_and_unknown_ids():
    g = Graph.from_edges(np.array([0, 1]), np.array([1, 2]), n_nodes=3)
    res = apply_edge_deltas(
        g, EdgeEdits.of(ins_src=[0], ins_dst=[1],      # already present
                        del_src=[0, 5], del_dst=[2, 6])  # absent / unknown id
    )
    _assert_graph_identical(res.graph, g)
    assert res.n_effective == 0


def test_delta_grows_node_space():
    g = Graph.from_edges(np.array([0]), np.array([1]), n_nodes=2)
    res = apply_edge_deltas(g, EdgeEdits.inserts([1, 5], [5, 4]))
    assert res.graph.n_nodes == 6
    ref = _reference_graph({(0, 1), (1, 5), (4, 5)}, 6)
    _assert_graph_identical(res.graph, ref)
    with pytest.raises(ValueError):
        apply_edge_deltas(g, EdgeEdits.inserts([5], [1]), n_nodes=3)


def test_delta_explicit_n_nodes_pads_isolated_rows():
    g = Graph.from_edges(np.array([0]), np.array([1]), n_nodes=2)
    res = apply_edge_deltas(g, EdgeEdits.of(), n_nodes=5)
    assert res.graph.n_nodes == 5
    assert np.array_equal(res.graph.degrees, [1, 1, 0, 0, 0])


def test_delta_rejects_reordered_graph():
    from repro.graph.reorder import reorder_graph

    g = reorder_graph(rmat(8, 4, seed=1), "bfs")
    with pytest.raises(ValueError, match="original-id"):
        apply_edge_deltas(g, EdgeEdits.inserts([0], [1]))


# ---------------------------------------------------------------------------
# Edit log (EdgeStore chunk format)
# ---------------------------------------------------------------------------

def test_editlog_roundtrip(tmp_path):
    with EditLog(str(tmp_path / "log")) as log:
        reader = EditLogReader(log.workdir)
        assert reader.poll() == 0 and reader.read_batch() is None
        log.append([0, 5, 5], [5, 0, 5])            # dup + self-loop
        log.append([1], [2], delete=True)
        log.seal_batch()
        log.append([7], [8])
        log.seal_batch()
        log.seal_batch()                            # empty batch is legal
        assert reader.poll() == 3
        b0 = reader.read_batch()
        # canonical_slots: both directions, loop dropped, dup kept raw
        assert _canonical_pairs(b0.ins_src, b0.ins_dst) == {(0, 5)}
        assert _canonical_pairs(b0.del_src, b0.del_dst) == {(1, 2)}
        b1 = reader.read_batch(chunk_slots=1)       # bounded-chunk reads
        assert _canonical_pairs(b1.ins_src, b1.ins_dst) == {(7, 8)}
        assert reader.read_batch().n_raw == 0
        assert reader.poll() == 0


def test_editlog_torn_frame_is_not_sealed(tmp_path):
    with EditLog(str(tmp_path / "log")) as log:
        log.append([0], [1])
        log.seal_batch()
        reader = EditLogReader(log.workdir)
        # A half-written trailing frame (one int64 of two) must be ignored.
        with open(log.frames_path, "ab") as f:
            np.array([99], dtype=np.int64).tofile(f)
        assert reader.poll() == 1
        assert reader.read_batch() is not None
        assert reader.read_batch() is None


def test_editlog_feeds_apply_updates(tmp_path):
    g = rmat(9, 6, seed=4)
    core = peel_coreness(g).astype(np.int32)
    rng = np.random.default_rng(2)
    with EditLog(str(tmp_path / "log")) as log:
        for _ in range(4):
            log.append(rng.integers(0, g.n_nodes, 2),
                       rng.integers(0, g.n_nodes, 2))
            log.seal_batch()
        reader = EditLogReader(log.workdir)
        while (batch := reader.read_batch()) is not None:
            res = apply_updates(g, core, batch, op="count")
            g, core = res.graph, res.coreness
    assert np.array_equal(core, peel_coreness(g))


# ---------------------------------------------------------------------------
# The counterexample that forces the fall-region flood
# ---------------------------------------------------------------------------

def test_triangle_delete_third_corner_must_fall():
    """Delete one edge of a triangle: both endpoints' seeds start AT their
    final value (no sweep-time change event ever fires), so the third
    corner — whose coreness must drop 2 -> 1 — is only reached because the
    fall region is flooded into the initial frontier. A dirty-propagation-
    only scheme returns stale coreness here."""
    g = Graph.from_edges(np.array([0, 1, 2]), np.array([1, 2, 0]), n_nodes=3)
    core = peel_coreness(g)
    assert core.tolist() == [2, 2, 2]
    res = apply_updates(g, core, EdgeEdits.deletes([0], [1]),
                        op="count", dirty_budget_frac=1.0)
    assert res.mode == "incremental"
    assert res.coreness.tolist() == [1, 1, 1]
    assert bool(res.dirty_mask[2]), "third corner must be in the fall region"


# ---------------------------------------------------------------------------
# Churn streams: bit-identity on every fixture family
# ---------------------------------------------------------------------------

def test_churn_heavy_tailed_unit_edits():
    modes = _run_churn(rmat(10, 8, seed=7), 12, seed=11)
    # Heavy-tailed coreness keeps subcores local: the incremental path must
    # actually be exercised, not just fall back every time.
    assert modes.get("incremental", 0) >= 8, modes


def test_churn_heavy_tailed_batches():
    _run_churn(rmat(10, 8, seed=3), 8, seed=13, batch_hi=4)


def test_churn_er():
    _run_churn(erdos_renyi(400, 6.0, seed=3), 8, seed=5, batch_hi=2)


def test_churn_ba_uniform_coreness_falls_back():
    # BA graphs have near-uniform coreness (= m): the equal-coreness
    # subcore IS the graph, so the engine must detect the explosion and
    # take the full-resweep fallback — still bit-identical.
    modes = _run_churn(barabasi_albert(600, 4, seed=7), 6, seed=9)
    assert modes.get("incremental", 0) <= modes.get("full", 0) + 1, modes


def test_churn_sorted_engine():
    _run_churn(rmat(9, 6, seed=5), 5, seed=17, op="sorted")


def test_insert_only_and_delete_only_streams():
    g = rmat(9, 8, seed=8)
    core = peel_coreness(g).astype(np.int32)
    rng = np.random.default_rng(4)
    for _ in range(5):
        res = apply_updates(g, core, _random_batch(rng, g, 2, 0), op="count")
        g, core = res.graph, res.coreness
        assert np.array_equal(core, peel_coreness(g))
    for _ in range(5):
        res = apply_updates(g, core, _random_batch(rng, g, 0, 2), op="count")
        g, core = res.graph, res.coreness
        assert np.array_equal(core, peel_coreness(g))


def test_new_nodes_enter_through_inserts():
    g = Graph.from_edges(np.array([0, 1]), np.array([1, 2]), n_nodes=3)
    core = peel_coreness(g)
    res = apply_updates(g, core, EdgeEdits.inserts([2, 3, 4], [3, 4, 5]),
                        op="count", dirty_budget_frac=1.0)
    assert res.graph.n_nodes == 6
    assert np.array_equal(res.coreness, peel_coreness(res.graph))


# ---------------------------------------------------------------------------
# Dirty-region bounds + fallback behavior
# ---------------------------------------------------------------------------

def test_dirty_region_covers_all_movers_and_bounds_work():
    g = rmat(10, 8, seed=7)
    core = peel_coreness(g).astype(np.int32)
    full_rows = sum(
        decompose(bucketize(g), op="count").active_rows_per_iter
    )
    rng = np.random.default_rng(21)
    saw_incremental = False
    for _ in range(10):
        old_core = core.copy()
        res = apply_updates(g, core, _random_batch(rng, g, 1, 0), op="count")
        g, core = res.graph, res.coreness
        if res.mode != "incremental":
            continue
        saw_incremental = True
        # Soundness: every node whose coreness moved is in the seed region.
        moved = np.nonzero(core[: old_core.size] != old_core)[0]
        assert np.all(res.dirty_mask[moved]), "mover outside dirty region"
        # Locality: the restricted re-sweep gathers (far) fewer rows than
        # one full from-scratch run on a unit edit.
        assert res.dirty_count == int(res.dirty_mask.sum())
        assert res.dirty_frac <= 0.5
        assert res.gathered_rows < full_rows
    assert saw_incremental


def test_fallback_budget_zero_forces_full_mode():
    g = rmat(9, 6, seed=2)
    core = peel_coreness(g)
    u = 0
    v = next(x for x in range(1, g.n_nodes)
             if x not in set(g.neighbors(u).tolist()))
    res = apply_updates(g, core, EdgeEdits.inserts([u], [v]),
                        op="count", dirty_budget_frac=0.0)
    assert res.mode == "full"
    assert bool(res.dirty_mask.all())
    assert np.array_equal(res.coreness, peel_coreness(res.graph))


def test_noop_batch():
    g = rmat(8, 6, seed=2)
    core = peel_coreness(g)
    res = apply_updates(g, core, EdgeEdits.of(), op="count")
    assert res.mode == "noop"
    assert res.gathered_rows == 0
    assert np.array_equal(res.coreness, core)
    assert res.graph is not g or True  # graph object passthrough is fine


def test_apply_updates_validates_coreness_shape():
    g = rmat(8, 6, seed=2)
    with pytest.raises(ValueError, match="coreness shape"):
        apply_updates(g, np.zeros(3, np.int32), EdgeEdits.of())


# ---------------------------------------------------------------------------
# decompose(seed_nodes=...) — the engine hook the tentpole rides on
# ---------------------------------------------------------------------------

def test_seed_nodes_requires_frontier():
    bg = bucketize(rmat(8, 6, seed=1))
    with pytest.raises(ValueError, match="frontier"):
        decompose(bg, seed_nodes=np.ones(bg.n_nodes, bool), frontier=False)


def test_seed_nodes_full_mask_matches_unrestricted():
    g = rmat(9, 8, seed=6)
    bg = bucketize(g)
    ref = decompose(bg, op="count")
    res = decompose(bg, op="count", seed_nodes=np.ones(g.n_nodes, bool))
    assert np.array_equal(res.coreness, ref.coreness)


@pytest.mark.parametrize("order", ["bfs", "rcm"])
def test_seed_nodes_maps_through_reordering(order):
    """Seeds are original ids; a wrong perm mapping would activate the
    wrong buckets and leave the planted inflated estimates standing."""
    from repro.graph.reorder import reorder_graph

    g = rmat(9, 8, seed=6)
    oracle = peel_coreness(g)
    rng = np.random.default_rng(0)
    # Degree-0 rows own no bucket and are never swept; an inflated seed
    # there could never be corrected (apply_updates clamps est to the new
    # degree for exactly this reason) — plant on real rows only.
    candidates = np.nonzero(g.degrees > 0)[0]
    planted = rng.choice(candidates, size=12, replace=False)
    est = oracle.astype(np.int32).copy()
    est[planted] += 1  # valid upper bound, wrong by 1 at the seeds
    bg = bucketize(reorder_graph(g, order))
    res = decompose(bg, op="count", init_coreness=est,
                    seed_nodes=planted.astype(np.int64))
    assert np.array_equal(res.coreness, oracle)


# ---------------------------------------------------------------------------
# Properties (hypothesis when installed + always-on seeded ports)
# ---------------------------------------------------------------------------

def _property_case(seed):
    rng = np.random.default_rng(seed)
    n = int(rng.integers(4, 40))
    m = int(rng.integers(0, 4 * n))
    g = Graph.from_edges(rng.integers(0, n, m), rng.integers(0, n, m),
                         n_nodes=n)
    core = peel_coreness(g).astype(np.int32)
    for _ in range(3):
        k = int(rng.integers(0, 5))
        kd = int(rng.integers(0, 5))
        edits = EdgeEdits.of(rng.integers(0, n, k), rng.integers(0, n, k),
                             rng.integers(0, n, kd), rng.integers(0, n, kd))
        budget = float(rng.choice([0.0, 0.5, 1.0]))
        res = apply_updates(g, core, edits, op="count",
                            dirty_budget_frac=budget)
        g, core = res.graph, res.coreness
        assert np.array_equal(core, peel_coreness(g)), res.mode


@pytest.mark.parametrize("seed", range(8))
def test_property_seeded_ports(seed):
    _property_case(seed)


if HAVE_HYPOTHESIS:

    @settings(max_examples=25, deadline=None)
    @given(st.integers(min_value=0, max_value=10_000))
    def test_property_hypothesis(seed):
        _property_case(seed)
