"""Core DC-kCore tests: h-index operators, decompose engine, divide/merge.

Property tests (hypothesis) pin the paper's invariants:
  * Algorithm 2 vectorized forms == literal scalar transcription.
  * decompose(monolithic) == BZ peeling oracle.
  * dc_kcore(any thresholds, either strategy) == oracle (divide-invariance).
  * coreness <= degree; k-core subgraph min-degree property.
  * monotonicity: adding edges never decreases coreness.
"""
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis",
    reason="hypothesis not installed; seeded ports of the key properties "
    "run in tests/test_kcore_properties.py",
)
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.decompose import decompose
from repro.core.dckcore import dc_kcore
from repro.core.hindex import hindex_brute, hindex_count, hindex_sorted
from repro.graph.build import bucketize
from repro.graph.generators import barabasi_albert, erdos_renyi
from repro.graph.oracle import peel_coreness
from repro.graph.structs import Graph


# --------------------------------------------------------------------- #
# H-index operators
# --------------------------------------------------------------------- #
@given(
    cores=st.lists(st.integers(min_value=0, max_value=40), min_size=0, max_size=24),
    ext=st.integers(min_value=0, max_value=12),
    pad=st.integers(min_value=0, max_value=8),
)
@settings(max_examples=200, deadline=None)
def test_hindex_forms_agree(cores, ext, pad):
    row = np.array(cores + [-1] * pad, dtype=np.int32).reshape(1, -1)
    if row.shape[1] == 0:
        row = np.full((1, 1), -1, dtype=np.int32)
    e = jnp.array([ext], dtype=jnp.int32)
    expect = hindex_brute(row[0], ext)
    got_sorted = int(hindex_sorted(jnp.asarray(row), e)[0])
    got_count = int(hindex_count(jnp.asarray(row), e, cand_chunk=7)[0])
    assert got_sorted == expect
    assert got_count == expect


def test_hindex_known_values():
    # h-index of [3,3,3] is 3; of [1,1,1] is 1; ext shifts thresholds.
    row = jnp.array([[3, 3, 3, -1]], dtype=jnp.int32)
    assert int(hindex_sorted(row, jnp.array([0]))[0]) == 3
    row = jnp.array([[1, 1, 1, -1]], dtype=jnp.int32)
    assert int(hindex_sorted(row, jnp.array([0]))[0]) == 1
    # ext=2: two virtual infinite neighbors. [1,1,1] with ext 2 -> value 3:
    # need cores >= 3 among 3 real? i=1: cores>=3? no -> C=2+? check brute.
    assert int(hindex_sorted(row, jnp.array([2]))[0]) == hindex_brute(
        np.array([1, 1, 1]), 2
    )


# --------------------------------------------------------------------- #
# Monolithic decomposition vs oracle
# --------------------------------------------------------------------- #
@pytest.mark.parametrize("gauss_seidel", [True, False])
def test_decompose_matches_oracle_rmat(rmat_graph, gauss_seidel):
    bg = bucketize(rmat_graph)
    res = decompose(bg, gauss_seidel=gauss_seidel)
    np.testing.assert_array_equal(res.coreness, peel_coreness(rmat_graph))
    assert res.iterations >= 1
    assert res.comm_per_iter[-1] == 0


def test_decompose_matches_oracle_er(er_graph):
    bg = bucketize(er_graph)
    res = decompose(bg)
    np.testing.assert_array_equal(res.coreness, peel_coreness(er_graph))


def test_decompose_count_op(er_graph):
    bg = bucketize(er_graph)
    res = decompose(bg, op="count")
    np.testing.assert_array_equal(res.coreness, peel_coreness(er_graph))


@given(st.data())
@settings(max_examples=25, deadline=None)
def test_decompose_random_graphs(data):
    n = data.draw(st.integers(min_value=2, max_value=60))
    m = data.draw(st.integers(min_value=0, max_value=3 * n))
    seed = data.draw(st.integers(min_value=0, max_value=2**31))
    rng = np.random.default_rng(seed)
    src = rng.integers(0, n, size=m)
    dst = rng.integers(0, n, size=m)
    g = Graph.from_edges(src, dst, n_nodes=n)
    res = decompose(bucketize(g))
    np.testing.assert_array_equal(res.coreness, peel_coreness(g))


# --------------------------------------------------------------------- #
# Divide and conquer == oracle (the paper's Section 5.2 claim)
# --------------------------------------------------------------------- #
@pytest.mark.parametrize("strategy", ["rough", "exact"])
@pytest.mark.parametrize("thresholds", [(8,), (4, 12), (3, 8, 16)])
def test_dckcore_matches_oracle(rmat_graph, strategy, thresholds):
    core, report = dc_kcore(rmat_graph, thresholds=thresholds, strategy=strategy)
    np.testing.assert_array_equal(core, peel_coreness(rmat_graph))
    assert len(report.parts) >= 1
    assert report.peak_bytes > 0


def test_dckcore_monolithic_baseline(er_graph):
    core, report = dc_kcore(er_graph, thresholds=())
    np.testing.assert_array_equal(core, peel_coreness(er_graph))
    assert len(report.parts) == 1


@given(st.data())
@settings(max_examples=20, deadline=None)
def test_dckcore_divide_invariance(data):
    """Any threshold set, either strategy: result equals oracle."""
    n = data.draw(st.integers(min_value=3, max_value=50))
    m = data.draw(st.integers(min_value=1, max_value=3 * n))
    seed = data.draw(st.integers(min_value=0, max_value=2**31))
    n_thresh = data.draw(st.integers(min_value=1, max_value=3))
    thresholds = data.draw(
        st.lists(st.integers(min_value=1, max_value=12), min_size=n_thresh, max_size=n_thresh)
    )
    strategy = data.draw(st.sampled_from(["rough", "exact"]))
    rng = np.random.default_rng(seed)
    g = Graph.from_edges(rng.integers(0, n, size=m), rng.integers(0, n, size=m), n_nodes=n)
    core, _ = dc_kcore(g, thresholds=thresholds, strategy=strategy)
    np.testing.assert_array_equal(core, peel_coreness(g))


def test_coreness_invariants(rmat_graph):
    core = peel_coreness(rmat_graph)
    deg = rmat_graph.degrees
    assert (core <= deg).all()
    # k-core subgraph property: nodes with core >= k have >= k neighbors
    # inside the k-core subgraph.
    for k in [2, 4]:
        mask = core >= k
        ids = np.nonzero(mask)[0]
        for v in ids[:50]:
            assert np.sum(mask[rmat_graph.neighbors(v)]) >= k


@given(st.data())
@settings(max_examples=20, deadline=None)
def test_monotone_under_edge_addition(data):
    n = data.draw(st.integers(min_value=4, max_value=40))
    m = data.draw(st.integers(min_value=2, max_value=2 * n))
    extra = data.draw(st.integers(min_value=1, max_value=n))
    seed = data.draw(st.integers(min_value=0, max_value=2**31))
    rng = np.random.default_rng(seed)
    src = rng.integers(0, n, size=m + extra)
    dst = rng.integers(0, n, size=m + extra)
    g1 = Graph.from_edges(src[:m], dst[:m], n_nodes=n)
    g2 = Graph.from_edges(src, dst, n_nodes=n)
    c1 = decompose(bucketize(g1)).coreness
    c2 = decompose(bucketize(g2)).coreness
    assert (c2 >= c1).all()


def test_divide_reduces_peak_bytes(rmat_graph):
    """The paper's resource claim: divided parts need less peak memory."""
    _, mono = dc_kcore(rmat_graph, thresholds=())
    _, div = dc_kcore(rmat_graph, thresholds=(8,))
    assert div.peak_bytes < mono.peak_bytes
