"""Fault injection for the resumable DC-kCore pipeline.

The paper's stability claim at 136B-edge scale: a crash in part k must not
forfeit parts 1..k-1. Pinned here:

  * Kill-after-part-1 (an `on_part_done` hook that raises) on the rmat14
    fixture, resume from the checkpoint dir: coreness is byte-identical to
    the uninterrupted run and oracle-exact, and only the unfinished parts
    are re-run.
  * A half-written `step_*.tmp` directory (what a kill mid-save leaves) is
    ignored on resume.
  * A resumed-complete run returns the stored result without re-running.
  * The checkpoint holds host merge state only (no graph/tiles), and a
    thresholds mismatch or wrong graph is rejected.

Sweep-granularity resume (mid-part, `sweep_checkpoint_every`):

  * A **crash storm** on rmat14 kills the run at *every* sweep snapshot
    save (`on_sweep_saved` raises unconditionally) and resumes each time:
    every crash/resume cycle lands on a sweep boundary, mid-snapshot-save
    `.tmp` junk is injected along the way, and the final coreness is
    byte-identical to the uninterrupted run and oracle-exact, with every
    multi-sweep part provably warm-restarted mid-part.
  * A stale sweep snapshot — wrong cursor, wrong part size, wrong graph —
    is ignored and resume falls back to the part-boundary checkpoint.
  * rmat15 at budget-planned thresholds runs the same mid-sweep cycle in
    the scheduled (slow) job.

Overlapped mode (``overlap=True``): the same storms crash while a prefetch
worker AND an async checkpoint save are in flight — the pipeline must
drain both before the crash propagates, so resume stays byte-identical
(rmat14 in tier-1, rmat15 slow-marked).
"""
import json
import os

import numpy as np
import pytest

from repro.ckpt import latest_step
from repro.core.dckcore import (
    PipelineState,
    SweepSnapshot,
    _sweep_dir,
    dc_kcore,
    graph_fingerprint,
)
from repro.graph.generators import rmat
from repro.graph.oracle import peel_coreness


class SimulatedCrash(Exception):
    pass


def kill_after(part_idx: int):
    def hook(idx, report):
        if idx == part_idx:
            raise SimulatedCrash(f"killed after part {idx}")
    return hook


def kill_every_sweep_save(cursor, sweep, save_s):
    """on_sweep_saved hook: crash at every sweep boundary (after the
    snapshot save completed — the worst surviving state)."""
    raise SimulatedCrash(f"killed after sweep {sweep} of part {cursor}")


def plant_tmp_junk(sweep_dir):
    """What a kill mid-snapshot-save leaves: a half-written step dir."""
    tmp = os.path.join(sweep_dir, "step_00009999.tmp")
    os.makedirs(tmp, exist_ok=True)
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        f.write("{ half written")
    return tmp


@pytest.fixture(scope="module")
def rmat14_graph():
    """The acceptance fixture: power-law, wide coreness spread (0..~68)."""
    return rmat(14, 8, seed=7)


@pytest.fixture(scope="module")
def rmat14_runs(rmat14_graph, tmp_path_factory):
    """One kill/resume cycle on rmat14, shared by the assertions below."""
    g = rmat14_graph
    thresholds = (16, 8)
    ck = str(tmp_path_factory.mktemp("rmat14") / "ck")

    base_core, base_rep = dc_kcore(g, thresholds=thresholds, strategy="rough")

    with pytest.raises(SimulatedCrash):
        dc_kcore(g, thresholds=thresholds, strategy="rough",
                 checkpoint_dir=ck, on_part_done=kill_after(0))
    # Simulate a second kill mid-save: a half-written part dir.
    tmp_dir = os.path.join(ck, "step_00000002.tmp")
    os.makedirs(tmp_dir)
    with open(os.path.join(tmp_dir, "manifest.json"), "w") as f:
        f.write("{ half written")

    res_core, res_rep = dc_kcore(g, thresholds=thresholds, strategy="rough",
                                 checkpoint_dir=ck, resume=True)
    return dict(g=g, thresholds=thresholds, ck=ck,
                base_core=base_core, base_rep=base_rep,
                res_core=res_core, res_rep=res_rep)


def test_resume_is_byte_identical_and_oracle_exact(rmat14_runs):
    r = rmat14_runs
    np.testing.assert_array_equal(r["res_core"], r["base_core"])
    np.testing.assert_array_equal(r["res_core"], peel_coreness(r["g"]))
    assert r["res_core"].dtype == r["base_core"].dtype


def test_resume_skips_finished_parts_and_ignores_tmp(rmat14_runs):
    r = rmat14_runs
    # Part 1 was restored, not re-run (resume started from step 1, not from
    # the junk .tmp), and the junk was reclaimed by part 2's atomic save —
    # .tmp dirs are never restored from, only overwritten.
    assert r["res_rep"].resumed_parts == 1
    assert [p.name for p in r["res_rep"].parts] == [p.name for p in r["base_rep"].parts]
    assert latest_step(r["ck"]) == len(r["thresholds"]) + 1
    assert not os.path.exists(os.path.join(r["ck"], "step_00000002.tmp"))
    # Retention: the newest boundaries (retain=2) are kept on disk — the
    # latest plus one predecessor a corrupt latest can fall back to.
    steps = sorted(d for d in os.listdir(r["ck"]) if d.startswith("step_"))
    last = len(r["thresholds"]) + 1
    assert steps == [f"step_{last - 1:08d}", f"step_{last:08d}"]


def test_resume_of_complete_run_returns_stored_result(rmat14_runs):
    r = rmat14_runs
    core, rep = dc_kcore(r["g"], thresholds=r["thresholds"], strategy="rough",
                         checkpoint_dir=r["ck"], resume=True)
    np.testing.assert_array_equal(core, r["base_core"])
    assert rep.resumed_parts == len(r["res_rep"].parts)
    assert rep.total_iterations == r["res_rep"].total_iterations  # restored reports


def test_checkpoint_holds_host_state_only(rmat14_runs):
    """What's in the checkpoint: the four merge arrays + JSON extra. What's
    not: the remaining graph, tiles, or anything device-shaped."""
    r = rmat14_runs
    step_dir = os.path.join(r["ck"], f"step_{latest_step(r['ck']):08d}")
    with open(os.path.join(step_dir, "manifest.json")) as f:
        manifest = json.load(f)
    stems = sorted(name.split("__")[0] for name in manifest["files"])
    assert stems == ["coreness", "ext_remaining", "finalized", "remaining_ids"]
    extra = manifest["extra"]
    assert extra["complete"] and extra["parts_done"] == len(r["thresholds"])
    assert [int(t) for t in extra["thresholds"]] == sorted(r["thresholds"], reverse=True)
    assert len(extra["reports"]) == len(r["res_rep"].parts)


def test_stale_checkpoints_purged_by_fresh_run(tmp_path):
    """A fresh (non-resume) run in a previously-used dir removes stale
    steps, so resume cannot restore a different run's state."""
    ck = str(tmp_path / "ck")
    g_a = rmat(10, 8, seed=3)
    dc_kcore(g_a, thresholds=(8, 4), checkpoint_dir=ck)  # 3 steps on disk
    g_b = rmat(10, 8, seed=21)  # same n, different graph
    with pytest.raises(SimulatedCrash):
        dc_kcore(g_b, thresholds=(8, 4), checkpoint_dir=ck,
                 on_part_done=kill_after(0))
    # Only run B's first boundary remains; no stale A steps above it.
    steps = sorted(d for d in os.listdir(ck) if d.startswith("step_"))
    assert steps == ["step_00000001"]
    core, rep = dc_kcore(g_b, thresholds=(8, 4), checkpoint_dir=ck, resume=True)
    np.testing.assert_array_equal(core, peel_coreness(g_b))
    assert rep.resumed_parts == 1


def test_resume_rejects_different_graph_same_node_count(tmp_path):
    ck = str(tmp_path / "ck")
    g_a = rmat(10, 8, seed=3)
    dc_kcore(g_a, thresholds=(8,), checkpoint_dir=ck)
    g_b = rmat(10, 8, seed=21)
    assert g_a.n_nodes == g_b.n_nodes
    with pytest.raises(ValueError, match="different graph"):
        dc_kcore(g_b, thresholds=(8,), checkpoint_dir=ck, resume=True)


def test_threshold_and_graph_mismatch_rejected(rmat14_runs):
    r = rmat14_runs
    with pytest.raises(ValueError, match="thresholds"):
        dc_kcore(r["g"], thresholds=(32,), strategy="rough",
                 checkpoint_dir=r["ck"], resume=True)
    with pytest.raises(ValueError, match="node"):
        dc_kcore(rmat(8, 4, seed=1), thresholds=r["thresholds"],
                 checkpoint_dir=r["ck"], resume=True)
    with pytest.raises(ValueError, match="checkpoint_dir"):
        dc_kcore(r["g"], resume=True)


def test_resume_with_empty_dir_runs_fresh(tmp_path):
    g = rmat(10, 8, seed=3)
    core, rep = dc_kcore(g, thresholds=(8,), checkpoint_dir=str(tmp_path / "ck"),
                         resume=True)
    np.testing.assert_array_equal(core, peel_coreness(g))
    assert rep.resumed_parts == 0
    assert all(p.save_time_s > 0 for p in rep.parts)


def test_kill_at_every_part_boundary(tmp_path):
    """Crash after each part in turn; every resume lands oracle-exact."""
    g = rmat(10, 8, seed=11)
    thresholds = (16, 4)
    oracle = peel_coreness(g)
    base, _ = dc_kcore(g, thresholds=thresholds)
    n_parts = 3  # core>=16, core>=4, rest
    for k in range(n_parts):
        ck = str(tmp_path / f"ck{k}")
        with pytest.raises(SimulatedCrash):
            dc_kcore(g, thresholds=thresholds, checkpoint_dir=ck,
                     on_part_done=kill_after(k))
        core, rep = dc_kcore(g, thresholds=thresholds, checkpoint_dir=ck,
                             resume=True)
        np.testing.assert_array_equal(core, base)
        np.testing.assert_array_equal(core, oracle)
        assert rep.resumed_parts == k + 1


# --------------------------------------------------------------------- #
# Sweep-granularity (mid-part) resume
# --------------------------------------------------------------------- #
@pytest.fixture(scope="module")
def rmat14_sweep_storm(rmat14_runs, tmp_path_factory):
    """Crash storm on the acceptance fixture: kill at EVERY sweep-snapshot
    save, resume after each crash, until the run completes. Each cycle
    advances at least one sweep (a snapshot is only written when the
    estimates moved), so the storm terminates — and together the cycles
    cover every sweep boundary of every part. Junk `.tmp` dirs are planted
    mid-storm to model kills mid-snapshot-save."""
    g = rmat14_runs["g"]
    thresholds = rmat14_runs["thresholds"]
    ck = str(tmp_path_factory.mktemp("rmat14_sweeps") / "ck")
    cycles = 0
    while True:
        try:
            core, rep = dc_kcore(
                g, thresholds=thresholds, strategy="rough",
                checkpoint_dir=ck, resume=cycles > 0,
                sweep_checkpoint_every=1,
                on_sweep_saved=kill_every_sweep_save,
            )
            break
        except SimulatedCrash:
            cycles += 1
            if cycles in (2, 5):
                plant_tmp_junk(_sweep_dir(ck))
            assert cycles < 500, "crash storm does not terminate"
    return dict(core=core, rep=rep, cycles=cycles, ck=ck)


def test_sweep_storm_byte_identical_and_oracle_exact(rmat14_runs, rmat14_sweep_storm):
    s = rmat14_sweep_storm
    np.testing.assert_array_equal(s["core"], rmat14_runs["base_core"])
    np.testing.assert_array_equal(s["core"], peel_coreness(rmat14_runs["g"]))
    assert s["core"].dtype == rmat14_runs["base_core"].dtype


def test_sweep_storm_covered_every_boundary(rmat14_runs, rmat14_sweep_storm):
    """The storm crashed exactly once per productive sweep of the
    uninterrupted run (a sweep snapshot is saved — and crashed on — iff the
    sweep changed an estimate; the final no-change sweep of each part saves
    nothing). So each part was warm-restarted all the way up to its last
    productive sweep, and the cycle count equals the total count of
    productive sweeps — every sweep boundary was a crash site."""
    s = rmat14_sweep_storm
    rep, base_rep = s["rep"], rmat14_runs["base_rep"]
    assert [p.name for p in rep.parts] == [p.name for p in base_rep.parts]
    multi = [(p, b) for p, b in zip(rep.parts, base_rep.parts) if b.iterations > 1]
    assert multi, "fixture degenerated to single-sweep parts"
    for p, b in multi:
        # The final completing run re-entered this part at its last
        # productive sweep and needed only the closing no-change sweep.
        assert p.resumed_at_sweep == b.iterations - 1
        assert p.iterations == 1
    assert s["cycles"] == sum(
        b.iterations - 1 for b in base_rep.parts if b.iterations > 1
    )


def test_sweep_storm_disk_stays_bounded(rmat14_sweep_storm):
    """After completion: at most retain=2 pipeline steps on disk, no sweep
    snapshots (all purged at their part boundary), junk .tmp never restored
    from."""
    ck = rmat14_sweep_storm["ck"]
    steps = sorted(d for d in os.listdir(ck) if d.startswith("step_") and not d.endswith(".tmp"))
    assert 1 <= len(steps) <= 2
    sweeps = [d for d in os.listdir(_sweep_dir(ck)) if d.startswith("step_") and not d.endswith(".tmp")]
    assert sweeps == []


def test_midpart_crash_without_any_boundary_resumes(tmp_path):
    """A run killed during part 0 leaves sweep snapshots but no pipeline
    boundary at all; resume must still warm-restart mid-part."""
    g = rmat(10, 8, seed=11)
    thresholds = (16, 4)
    base, _ = dc_kcore(g, thresholds=thresholds)
    ck = str(tmp_path / "ck")
    calls = []

    def kill_at_second(cursor, sweep, save_s):
        calls.append((cursor, sweep))
        if len(calls) == 2:
            raise SimulatedCrash

    with pytest.raises(SimulatedCrash):
        dc_kcore(g, thresholds=thresholds, checkpoint_dir=ck,
                 sweep_checkpoint_every=1, on_sweep_saved=kill_at_second)
    assert latest_step(ck) is None  # no part boundary exists
    snap = SweepSnapshot.restore(_sweep_dir(ck))
    assert snap is not None and snap.parts_done == 0
    core, rep = dc_kcore(g, thresholds=thresholds, checkpoint_dir=ck,
                         resume=True, sweep_checkpoint_every=1)
    np.testing.assert_array_equal(core, base)
    np.testing.assert_array_equal(core, peel_coreness(g))
    assert rep.parts[0].resumed_at_sweep == snap.sweep
    assert rep.resumed_parts == 0


def test_stale_sweep_snapshot_falls_back_to_part_boundary(tmp_path):
    """Snapshots that fail validation — finished part's cursor, wrong part
    size, wrong graph — are ignored; resume enters the next part from the
    boundary checkpoint, and the result is still byte-identical."""
    g = rmat(10, 8, seed=11)
    thresholds = (16, 4)
    base, base_rep = dc_kcore(g, thresholds=thresholds)
    part0_n = base_rep.parts[0].n_nodes

    def stale_cases(state_fp):
        # (parts_done, n_part, threshold, fingerprint): each wrong one way.
        yield dict(parts_done=0, n_part=part0_n, threshold=16, fp=state_fp)   # finished part
        yield dict(parts_done=1, n_part=part0_n + 7, threshold=4, fp=state_fp)  # wrong size
        bad_fp = dict(state_fp, deg_crc32=state_fp["deg_crc32"] ^ 1)
        yield dict(parts_done=1, n_part=part0_n, threshold=4, fp=bad_fp)      # wrong graph

    for i, case in enumerate(stale_cases(graph_fingerprint(g))):
        ck = str(tmp_path / f"ck{i}")
        with pytest.raises(SimulatedCrash):
            dc_kcore(g, thresholds=thresholds, checkpoint_dir=ck,
                     on_part_done=kill_after(0), sweep_checkpoint_every=1)
        SweepSnapshot(
            coreness=np.zeros(case["n_part"], np.int32),
            parts_done=case["parts_done"], sweep=5, n_part=case["n_part"],
            threshold=case["threshold"],
            thresholds=sorted(thresholds, reverse=True),
            fingerprint=case["fp"],
        ).save(_sweep_dir(ck))
        core, rep = dc_kcore(g, thresholds=thresholds, checkpoint_dir=ck,
                             resume=True, sweep_checkpoint_every=1)
        np.testing.assert_array_equal(core, base)
        assert rep.resumed_parts == 1
        # Fallback: no part was warm-restarted from the stale snapshot.
        assert all(p.resumed_at_sweep == 0 for p in rep.parts)


def test_stale_snapshot_cannot_shadow_new_saves(tmp_path):
    """A crash can land between a part's boundary save and the sweeps
    purge, leaving a stale snapshot on disk. Snapshot step numbering is
    parts_done-major, so the next part's saves out-number it (the keep=1
    GC must never prefer the stale one), and a later mid-part resume
    warm-restarts from the NEW part's snapshot."""
    g = rmat(10, 8, seed=11)
    thresholds = (16, 4)
    base, _ = dc_kcore(g, thresholds=thresholds)
    ck = str(tmp_path / "ck")
    with pytest.raises(SimulatedCrash):
        dc_kcore(g, thresholds=thresholds, checkpoint_dir=ck,
                 on_part_done=kill_after(0), sweep_checkpoint_every=1)
    # The crash-between-save-and-purge artifact: part 0's last snapshot
    # still on disk next to the part-1 boundary.
    stale = SweepSnapshot(
        coreness=np.zeros(7, np.int32), parts_done=0, sweep=9, n_part=7,
        threshold=16, thresholds=sorted(thresholds, reverse=True),
        fingerprint=graph_fingerprint(g),
    )
    stale.save(_sweep_dir(ck))
    # Resume and crash again at part 1's second sweep snapshot.
    calls = []

    def kill_at_second(cursor, sweep, save_s):
        calls.append((cursor, sweep))
        if len(calls) == 2:
            raise SimulatedCrash

    with pytest.raises(SimulatedCrash):
        dc_kcore(g, thresholds=thresholds, checkpoint_dir=ck, resume=True,
                 sweep_checkpoint_every=1, on_sweep_saved=kill_at_second)
    # Part 1's snapshot won the retention, not the stale part-0 one.
    snap = SweepSnapshot.restore(_sweep_dir(ck))
    assert snap is not None and snap.parts_done == 1
    assert snap.sweep == calls[-1][1]
    core, rep = dc_kcore(g, thresholds=thresholds, checkpoint_dir=ck,
                         resume=True, sweep_checkpoint_every=1)
    np.testing.assert_array_equal(core, base)
    assert rep.parts[1].resumed_at_sweep == snap.sweep


def test_sweep_checkpoint_requires_checkpoint_dir(rmat14_runs):
    with pytest.raises(ValueError, match="checkpoint_dir"):
        dc_kcore(rmat14_runs["g"], thresholds=(8,), sweep_checkpoint_every=1)


def test_sweep_resume_without_flag_ignores_snapshots(tmp_path):
    """Resuming WITHOUT sweep_checkpoint_every must not touch snapshots
    (the decompose_fn contract only carries the warm-restart kwargs when
    the feature is on) — still byte-identical via the part boundary."""
    g = rmat(10, 8, seed=11)
    thresholds = (16, 4)
    base, _ = dc_kcore(g, thresholds=thresholds)
    ck = str(tmp_path / "ck")
    with pytest.raises(SimulatedCrash):
        dc_kcore(g, thresholds=thresholds, checkpoint_dir=ck,
                 on_part_done=kill_after(0), sweep_checkpoint_every=1)
    core, rep = dc_kcore(g, thresholds=thresholds, checkpoint_dir=ck,
                         resume=True)
    np.testing.assert_array_equal(core, base)
    assert all(p.resumed_at_sweep == 0 for p in rep.parts)


@pytest.mark.slow
def test_sweep_storm_paper_shaped(tmp_path):
    """Scheduled-only: the mid-sweep crash storm at rmat15 scale with
    budget-planned thresholds (paper-shaped part counts)."""
    from repro.core.divide import plan_thresholds

    g = rmat(15, 16, seed=3)
    thresholds = plan_thresholds(g, g.memory_bytes() // 3) or [24]
    base, _ = dc_kcore(g, thresholds=thresholds, strategy="rough")
    ck = str(tmp_path / "ck")
    cycles = 0

    def killer(cursor, sweep, save_s):
        # Crash at the first snapshot save of the first four runs (four
        # mid-part re-entries), then let the fifth run complete — bounded
        # cost at this scale, same mid-sweep coverage shape as the rmat14
        # storm.
        if cycles < 4:
            raise SimulatedCrash

    while True:
        try:
            core, rep = dc_kcore(g, thresholds=thresholds, strategy="rough",
                                 checkpoint_dir=ck, resume=cycles > 0,
                                 sweep_checkpoint_every=2,
                                 on_sweep_saved=killer)
            break
        except SimulatedCrash:
            cycles += 1
    np.testing.assert_array_equal(core, base)
    np.testing.assert_array_equal(core, peel_coreness(g))
    assert cycles == 4
    assert any(p.resumed_at_sweep > 0 for p in rep.parts)


@pytest.mark.slow
def test_kill_and_resume_paper_shaped(tmp_path):
    """Scheduled-only: the same fault-injection cycle on the largest bench
    fixture (rmat15, budget-planned thresholds) — paper-shaped part counts
    and a multi-minute budget the tier-1 suite shouldn't pay."""
    from repro.core.divide import plan_thresholds

    g = rmat(15, 16, seed=3)
    thresholds = plan_thresholds(g, g.memory_bytes() // 3) or [24]
    ck = str(tmp_path / "ck")
    base, _ = dc_kcore(g, thresholds=thresholds, strategy="rough")
    with pytest.raises(SimulatedCrash):
        dc_kcore(g, thresholds=thresholds, strategy="rough",
                 checkpoint_dir=ck, on_part_done=kill_after(0))
    core, rep = dc_kcore(g, thresholds=thresholds, strategy="rough",
                         checkpoint_dir=ck, resume=True)
    np.testing.assert_array_equal(core, base)
    np.testing.assert_array_equal(core, peel_coreness(g))
    assert rep.resumed_parts >= 1


# --------------------------------------------------------------------- #
# Overlapped-mode fault injection (prefetch worker + async saves in flight)
# --------------------------------------------------------------------- #
@pytest.fixture(scope="module")
def rmat14_overlap_storm(rmat14_runs, tmp_path_factory):
    """The sweep crash storm with ``overlap=True``: every crash fires from
    ``on_sweep_saved`` while the prefetch worker is divides-deep in the
    NEXT part and the just-enqueued snapshot save is still on the checkpoint
    manager's thread — the worst moment the pipeline has. The contract: the
    pipeline drains both before the exception leaves ``dc_kcore``, so every
    resume sees the same deterministic disk state the sequential storm does.
    """
    g = rmat14_runs["g"]
    thresholds = rmat14_runs["thresholds"]
    ck = str(tmp_path_factory.mktemp("rmat14_overlap") / "ck")
    cycles = 0
    while True:
        try:
            core, rep = dc_kcore(
                g, thresholds=thresholds, strategy="rough",
                checkpoint_dir=ck, resume=cycles > 0,
                sweep_checkpoint_every=1,
                on_sweep_saved=kill_every_sweep_save,
                overlap=True,
            )
            break
        except SimulatedCrash:
            cycles += 1
            if cycles in (2, 5):
                plant_tmp_junk(_sweep_dir(ck))
            assert cycles < 500, "crash storm does not terminate"
    return dict(core=core, rep=rep, cycles=cycles, ck=ck)


def test_overlap_storm_byte_identical_and_oracle_exact(
    rmat14_runs, rmat14_overlap_storm
):
    s = rmat14_overlap_storm
    np.testing.assert_array_equal(s["core"], rmat14_runs["base_core"])
    np.testing.assert_array_equal(s["core"], peel_coreness(rmat14_runs["g"]))
    assert s["core"].dtype == rmat14_runs["base_core"].dtype


def test_overlap_storm_matches_sequential_storm_shape(
    rmat14_runs, rmat14_overlap_storm
):
    """Overlap changes wall-clock only: the overlapped storm crashes at the
    same sweep boundaries as the sequential run would (same productive-sweep
    count) and at least one part is provably warm-restarted mid-part."""
    s = rmat14_overlap_storm
    base_rep = rmat14_runs["base_rep"]
    assert [p.name for p in s["rep"].parts] == [p.name for p in base_rep.parts]
    assert s["cycles"] == sum(
        b.iterations - 1 for b in base_rep.parts if b.iterations > 1
    )
    assert any(p.resumed_at_sweep > 0 for p in s["rep"].parts)


def test_overlap_storm_disk_stays_bounded(rmat14_overlap_storm):
    """Async saves must not change the retention story: at most retain=2
    boundary steps, no snapshots (purged through clear_steps, which waits
    out pending writes), planted junk never restored from."""
    ck = rmat14_overlap_storm["ck"]
    steps = sorted(
        d for d in os.listdir(ck)
        if d.startswith("step_") and not d.endswith(".tmp")
    )
    assert 1 <= len(steps) <= 2
    sweeps = [
        d for d in os.listdir(_sweep_dir(ck))
        if d.startswith("step_") and not d.endswith(".tmp")
    ]
    assert sweeps == []


def test_overlap_kill_at_every_part_boundary(tmp_path):
    """Boundary crashes in overlapped mode: the crash fires from
    ``on_part_done`` right after the boundary save was *enqueued* (not yet
    necessarily written) and possibly with a prefetched next part in
    flight; the drained save must land and every resume (also overlapped)
    must be byte-identical to the sequential run."""
    g = rmat(10, 8, seed=11)
    thresholds = (16, 4)
    base, _ = dc_kcore(g, thresholds=thresholds)
    for k in range(3):  # core>=16, core>=4, rest
        ck = str(tmp_path / f"ck{k}")
        with pytest.raises(SimulatedCrash):
            dc_kcore(g, thresholds=thresholds, checkpoint_dir=ck,
                     on_part_done=kill_after(k), overlap=True)
        core, rep = dc_kcore(g, thresholds=thresholds, checkpoint_dir=ck,
                             resume=True, overlap=True)
        np.testing.assert_array_equal(core, base)
        assert rep.resumed_parts == k + 1


# --------------------------------------------------------------------- #
# Fused-engine fault injection (engine="fused" + overlap)
# --------------------------------------------------------------------- #
@pytest.fixture(scope="module")
def rmat14_fused_storm(rmat14_runs, tmp_path_factory):
    """Bounded crash storm on the fused engine with ``overlap=True``: crash
    at the first sweep-snapshot save of the first three runs (three mid-part
    re-entries on the fused path, each warm-restarting one sweep deeper),
    then let the fourth run complete. Bounded — not the every-boundary storm
    above — because a fused interpret-mode sweep costs ~2x the unfused one;
    the coverage that matters is that the fused engine honors the same
    ``on_sweep``/``init_coreness`` snapshot contract, which three mid-part
    re-entries plus planted ``.tmp`` junk exercise."""
    g = rmat14_runs["g"]
    thresholds = rmat14_runs["thresholds"]
    ck = str(tmp_path_factory.mktemp("rmat14_fused") / "ck")
    cycles = 0

    def killer(cursor, sweep, save_s):
        if cycles < 3:
            raise SimulatedCrash(f"killed after sweep {sweep} of part {cursor}")

    while True:
        try:
            core, rep = dc_kcore(
                g, thresholds=thresholds, strategy="rough",
                checkpoint_dir=ck, resume=cycles > 0,
                sweep_checkpoint_every=1,
                on_sweep_saved=killer,
                engine="fused", overlap=True,
            )
            break
        except SimulatedCrash:
            cycles += 1
            if cycles == 2:
                plant_tmp_junk(_sweep_dir(ck))
            assert cycles < 10, "bounded fused storm does not terminate"
    return dict(core=core, rep=rep, cycles=cycles, ck=ck)


def test_fused_storm_byte_identical_and_oracle_exact(rmat14_runs, rmat14_fused_storm):
    """Byte-identity here is cross-engine too: the baseline run used the
    sorted engine, the storm ran fused end to end."""
    s = rmat14_fused_storm
    np.testing.assert_array_equal(s["core"], rmat14_runs["base_core"])
    np.testing.assert_array_equal(s["core"], peel_coreness(rmat14_runs["g"]))
    assert s["core"].dtype == rmat14_runs["base_core"].dtype


def test_fused_storm_warm_restarted_midpart(rmat14_runs, rmat14_fused_storm):
    """Each crash landed one sweep deeper into part 0, so the completing
    run re-entered part 0 exactly at sweep 3 and finished the remainder."""
    s = rmat14_fused_storm
    base_rep = rmat14_runs["base_rep"]
    assert s["cycles"] == 3
    assert [p.name for p in s["rep"].parts] == [p.name for p in base_rep.parts]
    p0, b0 = s["rep"].parts[0], base_rep.parts[0]
    assert p0.resumed_at_sweep == 3
    assert p0.iterations == b0.iterations - 3
    assert all(p.resumed_at_sweep == 0 for p in s["rep"].parts[1:])


def test_fused_storm_disk_stays_bounded(rmat14_fused_storm):
    """Same retention contract as the unfused storms: at most retain=2
    boundary steps on disk, snapshots purged, planted junk never restored
    from."""
    ck = rmat14_fused_storm["ck"]
    steps = sorted(
        d for d in os.listdir(ck)
        if d.startswith("step_") and not d.endswith(".tmp")
    )
    assert 1 <= len(steps) <= 2
    sweeps = [
        d for d in os.listdir(_sweep_dir(ck))
        if d.startswith("step_") and not d.endswith(".tmp")
    ]
    assert sweeps == []


@pytest.mark.slow
def test_fused_overlap_storm_paper_shaped(tmp_path):
    """Scheduled-only: the fused-engine overlapped mid-sweep crash storm at
    rmat15 scale with budget-planned thresholds — four crashes with
    prefetch + async saves in flight, then a completing run; byte-identical
    to the sequential sorted-engine result."""
    from repro.core.divide import plan_thresholds

    g = rmat(15, 16, seed=3)
    thresholds = plan_thresholds(g, g.memory_bytes() // 3) or [24]
    base, _ = dc_kcore(g, thresholds=thresholds, strategy="rough")
    ck = str(tmp_path / "ck")
    cycles = 0

    def killer(cursor, sweep, save_s):
        if cycles < 4:
            raise SimulatedCrash

    while True:
        try:
            core, rep = dc_kcore(g, thresholds=thresholds, strategy="rough",
                                 checkpoint_dir=ck, resume=cycles > 0,
                                 sweep_checkpoint_every=2,
                                 on_sweep_saved=killer,
                                 engine="fused", overlap=True)
            break
        except SimulatedCrash:
            cycles += 1
    np.testing.assert_array_equal(core, base)
    np.testing.assert_array_equal(core, peel_coreness(g))
    assert cycles == 4
    assert any(p.resumed_at_sweep > 0 for p in rep.parts)


@pytest.mark.slow
def test_overlap_storm_paper_shaped(tmp_path):
    """Scheduled-only: the overlapped mid-sweep crash storm at rmat15
    scale — four crashes with prefetch + async saves in flight, then a
    completing run; byte-identical to the sequential result."""
    from repro.core.divide import plan_thresholds

    g = rmat(15, 16, seed=3)
    thresholds = plan_thresholds(g, g.memory_bytes() // 3) or [24]
    base, _ = dc_kcore(g, thresholds=thresholds, strategy="rough")
    ck = str(tmp_path / "ck")
    cycles = 0

    def killer(cursor, sweep, save_s):
        if cycles < 4:
            raise SimulatedCrash

    while True:
        try:
            core, rep = dc_kcore(g, thresholds=thresholds, strategy="rough",
                                 checkpoint_dir=ck, resume=cycles > 0,
                                 sweep_checkpoint_every=2,
                                 on_sweep_saved=killer, overlap=True)
            break
        except SimulatedCrash:
            cycles += 1
    np.testing.assert_array_equal(core, base)
    np.testing.assert_array_equal(core, peel_coreness(g))
    assert cycles == 4
    assert any(p.resumed_at_sweep > 0 for p in rep.parts)


def test_pipeline_state_roundtrip(tmp_path):
    """PipelineState save/restore is exact on arrays, cursor and reports."""
    g = rmat(9, 6, seed=2)
    ck = str(tmp_path / "ck")
    _, rep = dc_kcore(g, thresholds=(8,), checkpoint_dir=ck)
    state = PipelineState.restore(ck, g.n_nodes)
    assert state.complete and state.parts_done == 1
    assert state.coreness.dtype == np.int32 and state.finalized.dtype == bool
    np.testing.assert_array_equal(state.coreness, peel_coreness(g))
    assert (state.finalized).all()
    assert [p.name for p in state.reports] == [p.name for p in rep.parts]
    assert state.remaining_ids.size == 0 and state.ext_remaining.size == 0
