"""Fault injection for the resumable DC-kCore pipeline.

The paper's stability claim at 136B-edge scale: a crash in part k must not
forfeit parts 1..k-1. Pinned here:

  * Kill-after-part-1 (an `on_part_done` hook that raises) on the rmat14
    fixture, resume from the checkpoint dir: coreness is byte-identical to
    the uninterrupted run and oracle-exact, and only the unfinished parts
    are re-run.
  * A half-written `step_*.tmp` directory (what a kill mid-save leaves) is
    ignored on resume.
  * A resumed-complete run returns the stored result without re-running.
  * The checkpoint holds host merge state only (no graph/tiles), and a
    thresholds mismatch or wrong graph is rejected.
"""
import json
import os

import numpy as np
import pytest

from repro.ckpt import latest_step
from repro.core.dckcore import PipelineState, dc_kcore
from repro.graph.generators import rmat
from repro.graph.oracle import peel_coreness


class SimulatedCrash(Exception):
    pass


def kill_after(part_idx: int):
    def hook(idx, report):
        if idx == part_idx:
            raise SimulatedCrash(f"killed after part {idx}")
    return hook


@pytest.fixture(scope="module")
def rmat14_graph():
    """The acceptance fixture: power-law, wide coreness spread (0..~68)."""
    return rmat(14, 8, seed=7)


@pytest.fixture(scope="module")
def rmat14_runs(rmat14_graph, tmp_path_factory):
    """One kill/resume cycle on rmat14, shared by the assertions below."""
    g = rmat14_graph
    thresholds = (16, 8)
    ck = str(tmp_path_factory.mktemp("rmat14") / "ck")

    base_core, base_rep = dc_kcore(g, thresholds=thresholds, strategy="rough")

    with pytest.raises(SimulatedCrash):
        dc_kcore(g, thresholds=thresholds, strategy="rough",
                 checkpoint_dir=ck, on_part_done=kill_after(0))
    # Simulate a second kill mid-save: a half-written part dir.
    tmp_dir = os.path.join(ck, "step_00000002.tmp")
    os.makedirs(tmp_dir)
    with open(os.path.join(tmp_dir, "manifest.json"), "w") as f:
        f.write("{ half written")

    res_core, res_rep = dc_kcore(g, thresholds=thresholds, strategy="rough",
                                 checkpoint_dir=ck, resume=True)
    return dict(g=g, thresholds=thresholds, ck=ck,
                base_core=base_core, base_rep=base_rep,
                res_core=res_core, res_rep=res_rep)


def test_resume_is_byte_identical_and_oracle_exact(rmat14_runs):
    r = rmat14_runs
    np.testing.assert_array_equal(r["res_core"], r["base_core"])
    np.testing.assert_array_equal(r["res_core"], peel_coreness(r["g"]))
    assert r["res_core"].dtype == r["base_core"].dtype


def test_resume_skips_finished_parts_and_ignores_tmp(rmat14_runs):
    r = rmat14_runs
    # Part 1 was restored, not re-run (resume started from step 1, not from
    # the junk .tmp), and the junk was reclaimed by part 2's atomic save —
    # .tmp dirs are never restored from, only overwritten.
    assert r["res_rep"].resumed_parts == 1
    assert [p.name for p in r["res_rep"].parts] == [p.name for p in r["base_rep"].parts]
    assert latest_step(r["ck"]) == len(r["thresholds"]) + 1
    assert not os.path.exists(os.path.join(r["ck"], "step_00000002.tmp"))
    # Retention: only the latest boundary is kept on disk (state is O(n)).
    steps = sorted(d for d in os.listdir(r["ck"]) if d.startswith("step_"))
    assert steps == [f"step_{len(r['thresholds']) + 1:08d}"]


def test_resume_of_complete_run_returns_stored_result(rmat14_runs):
    r = rmat14_runs
    core, rep = dc_kcore(r["g"], thresholds=r["thresholds"], strategy="rough",
                         checkpoint_dir=r["ck"], resume=True)
    np.testing.assert_array_equal(core, r["base_core"])
    assert rep.resumed_parts == len(r["res_rep"].parts)
    assert rep.total_iterations == r["res_rep"].total_iterations  # restored reports


def test_checkpoint_holds_host_state_only(rmat14_runs):
    """What's in the checkpoint: the four merge arrays + JSON extra. What's
    not: the remaining graph, tiles, or anything device-shaped."""
    r = rmat14_runs
    step_dir = os.path.join(r["ck"], f"step_{latest_step(r['ck']):08d}")
    with open(os.path.join(step_dir, "manifest.json")) as f:
        manifest = json.load(f)
    stems = sorted(name.split("__")[0] for name in manifest["files"])
    assert stems == ["coreness", "ext_remaining", "finalized", "remaining_ids"]
    extra = manifest["extra"]
    assert extra["complete"] and extra["parts_done"] == len(r["thresholds"])
    assert [int(t) for t in extra["thresholds"]] == sorted(r["thresholds"], reverse=True)
    assert len(extra["reports"]) == len(r["res_rep"].parts)


def test_stale_checkpoints_purged_by_fresh_run(tmp_path):
    """A fresh (non-resume) run in a previously-used dir removes stale
    steps, so resume cannot restore a different run's state."""
    ck = str(tmp_path / "ck")
    g_a = rmat(10, 8, seed=3)
    dc_kcore(g_a, thresholds=(8, 4), checkpoint_dir=ck)  # 3 steps on disk
    g_b = rmat(10, 8, seed=21)  # same n, different graph
    with pytest.raises(SimulatedCrash):
        dc_kcore(g_b, thresholds=(8, 4), checkpoint_dir=ck,
                 on_part_done=kill_after(0))
    # Only run B's first boundary remains; no stale A steps above it.
    steps = sorted(d for d in os.listdir(ck) if d.startswith("step_"))
    assert steps == ["step_00000001"]
    core, rep = dc_kcore(g_b, thresholds=(8, 4), checkpoint_dir=ck, resume=True)
    np.testing.assert_array_equal(core, peel_coreness(g_b))
    assert rep.resumed_parts == 1


def test_resume_rejects_different_graph_same_node_count(tmp_path):
    ck = str(tmp_path / "ck")
    g_a = rmat(10, 8, seed=3)
    dc_kcore(g_a, thresholds=(8,), checkpoint_dir=ck)
    g_b = rmat(10, 8, seed=21)
    assert g_a.n_nodes == g_b.n_nodes
    with pytest.raises(ValueError, match="different graph"):
        dc_kcore(g_b, thresholds=(8,), checkpoint_dir=ck, resume=True)


def test_threshold_and_graph_mismatch_rejected(rmat14_runs):
    r = rmat14_runs
    with pytest.raises(ValueError, match="thresholds"):
        dc_kcore(r["g"], thresholds=(32,), strategy="rough",
                 checkpoint_dir=r["ck"], resume=True)
    with pytest.raises(ValueError, match="node"):
        dc_kcore(rmat(8, 4, seed=1), thresholds=r["thresholds"],
                 checkpoint_dir=r["ck"], resume=True)
    with pytest.raises(ValueError, match="checkpoint_dir"):
        dc_kcore(r["g"], resume=True)


def test_resume_with_empty_dir_runs_fresh(tmp_path):
    g = rmat(10, 8, seed=3)
    core, rep = dc_kcore(g, thresholds=(8,), checkpoint_dir=str(tmp_path / "ck"),
                         resume=True)
    np.testing.assert_array_equal(core, peel_coreness(g))
    assert rep.resumed_parts == 0
    assert all(p.save_time_s > 0 for p in rep.parts)


def test_kill_at_every_part_boundary(tmp_path):
    """Crash after each part in turn; every resume lands oracle-exact."""
    g = rmat(10, 8, seed=11)
    thresholds = (16, 4)
    oracle = peel_coreness(g)
    base, _ = dc_kcore(g, thresholds=thresholds)
    n_parts = 3  # core>=16, core>=4, rest
    for k in range(n_parts):
        ck = str(tmp_path / f"ck{k}")
        with pytest.raises(SimulatedCrash):
            dc_kcore(g, thresholds=thresholds, checkpoint_dir=ck,
                     on_part_done=kill_after(k))
        core, rep = dc_kcore(g, thresholds=thresholds, checkpoint_dir=ck,
                             resume=True)
        np.testing.assert_array_equal(core, base)
        np.testing.assert_array_equal(core, oracle)
        assert rep.resumed_parts == k + 1


@pytest.mark.slow
def test_kill_and_resume_paper_shaped(tmp_path):
    """Scheduled-only: the same fault-injection cycle on the largest bench
    fixture (rmat15, budget-planned thresholds) — paper-shaped part counts
    and a multi-minute budget the tier-1 suite shouldn't pay."""
    from repro.core.divide import plan_thresholds

    g = rmat(15, 16, seed=3)
    thresholds = plan_thresholds(g, g.memory_bytes() // 3) or [24]
    ck = str(tmp_path / "ck")
    base, _ = dc_kcore(g, thresholds=thresholds, strategy="rough")
    with pytest.raises(SimulatedCrash):
        dc_kcore(g, thresholds=thresholds, strategy="rough",
                 checkpoint_dir=ck, on_part_done=kill_after(0))
    core, rep = dc_kcore(g, thresholds=thresholds, strategy="rough",
                         checkpoint_dir=ck, resume=True)
    np.testing.assert_array_equal(core, base)
    np.testing.assert_array_equal(core, peel_coreness(g))
    assert rep.resumed_parts >= 1


def test_pipeline_state_roundtrip(tmp_path):
    """PipelineState save/restore is exact on arrays, cursor and reports."""
    g = rmat(9, 6, seed=2)
    ck = str(tmp_path / "ck")
    _, rep = dc_kcore(g, thresholds=(8,), checkpoint_dir=ck)
    state = PipelineState.restore(ck, g.n_nodes)
    assert state.complete and state.parts_done == 1
    assert state.coreness.dtype == np.int32 and state.finalized.dtype == bool
    np.testing.assert_array_equal(state.coreness, peel_coreness(g))
    assert (state.finalized).all()
    assert [p.name for p in state.reports] == [p.name for p in rep.parts]
    assert state.remaining_ids.size == 0 and state.ext_remaining.size == 0
