"""Per-architecture smoke tests (deliverable f).

Each assigned arch instantiates a REDUCED same-family config and runs:
  * one forward pass — output shapes + finiteness (no NaNs);
  * one train step (loss + grads + optimizer update) — finite loss;
  * prefill + one decode step — parity with the full forward pass.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config, get_smoke_config
from repro.models.model import build_specs, decode_step, forward, loss_fn, prefill
from repro.models.module import abstract_params, count_params, init_params
from repro.optim import adamw, apply_updates, clip_by_global_norm, warmup_cosine


def extras_for(cfg, B, key=7):
    ex = {}
    if cfg.encoder is not None:
        ex["frames"] = jax.random.normal(
            jax.random.PRNGKey(key), (B, cfg.encoder.n_frames, cfg.d_model), cfg.dtype
        )
    elif cfg.cross_attn_every is not None:
        ex["vision_embeds"] = jax.random.normal(
            jax.random.PRNGKey(key), (B, cfg.n_vision_tokens, cfg.d_model), cfg.dtype
        )
    return ex


@pytest.fixture(params=ARCHS)
def arch(request):
    return request.param


def test_smoke_forward_and_decode(arch):
    cfg = get_smoke_config(arch)
    specs = build_specs(cfg)
    params = init_params(specs, jax.random.PRNGKey(0))
    B, S = 2, 32
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab_size)
    ex = extras_for(cfg, B)

    logits, aux, _ = forward(params, tokens, cfg, extras=ex)
    assert logits.shape == (B, S, cfg.vocab_padded)
    assert bool(jnp.isfinite(logits).all())

    # Decode parity: prefill S-1 tokens, decode the last -> last row of full.
    _, caches = prefill(params, tokens[:, :-1], cfg, extras=ex, max_len=S)
    lg, _ = decode_step(params, caches, tokens[:, -1:], jnp.full((B,), S - 1, jnp.int32), cfg)
    full, _, _ = forward(params, tokens, cfg, extras=ex)
    np.testing.assert_allclose(
        np.asarray(lg[:, 0]), np.asarray(full[:, -1]), atol=2e-3, rtol=1e-3
    )


def test_smoke_train_step(arch):
    cfg = get_smoke_config(arch)
    specs = build_specs(cfg)
    params = init_params(specs, jax.random.PRNGKey(0))
    B, S = 2, 16
    tokens = jax.random.randint(jax.random.PRNGKey(2), (B, S), 0, cfg.vocab_size)
    batch = {"tokens": tokens, "labels": tokens, "extras": extras_for(cfg, B)}
    opt = adamw(warmup_cosine(1e-3, 10, 100))
    state = opt.init(params)
    (loss, _), grads = jax.value_and_grad(
        lambda p: loss_fn(p, batch, cfg), has_aux=True
    )(params)
    assert bool(jnp.isfinite(loss))
    grads, gnorm = clip_by_global_norm(grads, 1.0)
    assert bool(jnp.isfinite(gnorm))
    updates, state = opt.update(grads, state, params, jnp.asarray(0))
    new_params = apply_updates(params, updates)
    # Parameters actually moved.
    moved = jax.tree.leaves(
        jax.tree.map(lambda a, b: float(jnp.abs(a - b).max()), params, new_params)
    )
    assert max(moved) > 0


def test_full_configs_build_specs_only():
    """FULL configs must produce spec trees (no allocation) with plausible
    parameter counts."""
    expect = {
        "gemma3-27b": (25e9, 30e9),
        "qwen3-8b": (7e9, 9.5e9),
        "granite-3-2b": (2e9, 3e9),
        "phi4-mini-3.8b": (3e9, 4.6e9),
        "qwen2-moe-a2.7b": (12e9, 16e9),
        "grok-1-314b": (290e9, 340e9),
        "mamba2-130m": (0.10e9, 0.16e9),
        "llama-3.2-vision-11b": (9e9, 12e9),
        "whisper-small": (0.15e9, 0.35e9),
        "jamba-1.5-large-398b": (370e9, 420e9),
    }
    for arch in ARCHS:
        cfg = get_config(arch)
        n = count_params(build_specs(cfg))
        lo, hi = expect[arch]
        assert lo <= n <= hi, f"{arch}: {n/1e9:.2f}B params out of range [{lo/1e9}, {hi/1e9}]"


def test_loss_decreases_quickly():
    """A few steps on repeated data should reduce the loss (end-to-end sanity)."""
    cfg = get_smoke_config("granite-3-2b")
    params = init_params(build_specs(cfg), jax.random.PRNGKey(0))
    tokens = jax.random.randint(jax.random.PRNGKey(3), (4, 32), 0, cfg.vocab_size)
    batch = {"tokens": tokens, "labels": tokens}
    opt = adamw(lambda s: 3e-3)
    state = opt.init(params)

    @jax.jit
    def step(params, state, i):
        (loss, _), grads = jax.value_and_grad(
            lambda p: loss_fn(p, batch, cfg), has_aux=True
        )(params)
        grads, _ = clip_by_global_norm(grads, 1.0)
        updates, state = opt.update(grads, state, params, i)
        return apply_updates(params, updates), state, loss

    losses = []
    for i in range(8):
        params, state, loss = step(params, state, jnp.asarray(i))
        losses.append(float(loss))
    assert losses[-1] < losses[0] * 0.9, losses
