"""Seeded (hypothesis-free) ports of the k-core property tests, plus the
active-frontier sweep-scheduling invariants.

The hypothesis suites in test_kcore_core.py / test_kernels_hindex.py skip
when hypothesis is not installed; the highest-value properties are ported
here to seeded ``numpy.random`` parametrized tests so the paper's
invariants stay covered offline:

  * Algorithm 2 vectorized forms == literal scalar transcription.
  * decompose(monolithic, any schedule) == BZ peeling oracle.
  * dc_kcore(any thresholds, either strategy) == oracle (divide-invariance).
  * monotonicity: adding edges never decreases coreness.

Frontier invariants pinned here:

  * frontier schedule returns coreness identical to full sweeps (all ops);
  * the bucket-adjacency bitmap covers every edge (the soundness
    certificate for skipping);
  * per-sweep active-row counts are exposed and never exceed a full sweep,
  * and total gathered rows never exceed the always-full-sweep baseline.
"""
import numpy as np
import pytest

from repro.core.decompose import decompose
from repro.core.dckcore import dc_kcore
from repro.core.divide import exact_candidates, plan_thresholds, rough_candidates
from repro.core.hindex import hindex_brute, hindex_count, hindex_sorted
from repro.graph.build import bucketize
from repro.graph.oracle import peel_coreness
from repro.graph.structs import Graph

import jax.numpy as jnp


# --------------------------------------------------------------------- #
# H-index operator agreement (port of test_hindex_forms_agree)
# --------------------------------------------------------------------- #
@pytest.mark.parametrize("seed", range(40))
def test_hindex_forms_agree_seeded(seed):
    rng = np.random.default_rng(seed)
    n_cores = int(rng.integers(0, 25))
    pad = int(rng.integers(0, 9))
    ext = int(rng.integers(0, 13))
    cores = rng.integers(0, 41, size=n_cores).tolist()
    row = np.array(cores + [-1] * pad, dtype=np.int32).reshape(1, -1)
    if row.shape[1] == 0:
        row = np.full((1, 1), -1, dtype=np.int32)
    e = jnp.array([ext], dtype=jnp.int32)
    expect = hindex_brute(row[0], ext)
    assert int(hindex_sorted(jnp.asarray(row), e)[0]) == expect
    assert int(hindex_count(jnp.asarray(row), e, cand_chunk=7)[0]) == expect


# --------------------------------------------------------------------- #
# decompose(random graph) == oracle (port of test_decompose_random_graphs)
# --------------------------------------------------------------------- #
@pytest.mark.parametrize("seed", range(15))
def test_decompose_random_graphs_seeded(seed):
    rng = np.random.default_rng(seed)
    n = int(rng.integers(2, 61))
    m = int(rng.integers(0, 3 * n + 1))
    g = Graph.from_edges(
        rng.integers(0, n, size=m), rng.integers(0, n, size=m), n_nodes=n
    )
    res = decompose(bucketize(g))
    np.testing.assert_array_equal(res.coreness, peel_coreness(g))


# --------------------------------------------------------------------- #
# Divide-invariance (port of test_dckcore_divide_invariance)
# --------------------------------------------------------------------- #
@pytest.mark.parametrize("strategy", ["rough", "exact"])
@pytest.mark.parametrize("seed", range(6))
def test_dckcore_divide_invariance_seeded(seed, strategy):
    rng = np.random.default_rng(100 + seed)
    n = int(rng.integers(3, 51))
    m = int(rng.integers(1, 3 * n + 1))
    thresholds = rng.integers(1, 13, size=int(rng.integers(1, 4))).tolist()
    g = Graph.from_edges(
        rng.integers(0, n, size=m), rng.integers(0, n, size=m), n_nodes=n
    )
    core, _ = dc_kcore(g, thresholds=thresholds, strategy=strategy)
    np.testing.assert_array_equal(core, peel_coreness(g))


# --------------------------------------------------------------------- #
# Monotonicity (port of test_monotone_under_edge_addition)
# --------------------------------------------------------------------- #
@pytest.mark.parametrize("seed", range(8))
def test_monotone_under_edge_addition_seeded(seed):
    rng = np.random.default_rng(200 + seed)
    n = int(rng.integers(4, 41))
    m = int(rng.integers(2, 2 * n + 1))
    extra = int(rng.integers(1, n + 1))
    src = rng.integers(0, n, size=m + extra)
    dst = rng.integers(0, n, size=m + extra)
    g1 = Graph.from_edges(src[:m], dst[:m], n_nodes=n)
    g2 = Graph.from_edges(src, dst, n_nodes=n)
    c1 = decompose(bucketize(g1)).coreness
    c2 = decompose(bucketize(g2)).coreness
    assert (c2 >= c1).all()


# --------------------------------------------------------------------- #
# Active-frontier scheduling invariants
# --------------------------------------------------------------------- #
@pytest.mark.parametrize("op", ["sorted", "count", "kernel"])
def test_frontier_schedule_exact_and_no_more_work(rmat_graph, op):
    bg = bucketize(rmat_graph)
    oracle = peel_coreness(rmat_graph)
    front = decompose(bg, op=op)
    full = decompose(bg, op=op, frontier=False)
    np.testing.assert_array_equal(front.coreness, oracle)
    np.testing.assert_array_equal(full.coreness, oracle)
    # Work metric exposed, bounded per sweep, and no worse in total.
    assert len(front.active_rows_per_iter) == front.iterations
    assert front.rows_per_full_sweep == bg.rows_per_full_sweep
    assert all(0 <= a <= bg.rows_per_full_sweep for a in front.active_rows_per_iter)
    assert front.gathered_rows <= full.gathered_rows
    assert full.gathered_rows == full.full_sweep_rows
    # Power-law fixture: the frontier must actually skip work.
    assert front.gathered_rows < full.gathered_rows


def test_frontier_reduces_work_jacobi(rmat_graph):
    bg = bucketize(rmat_graph)
    oracle = peel_coreness(rmat_graph)
    front = decompose(bg, gauss_seidel=False)
    full = decompose(bg, gauss_seidel=False, frontier=False)
    np.testing.assert_array_equal(front.coreness, oracle)
    np.testing.assert_array_equal(full.coreness, oracle)
    assert front.gathered_rows < full.gathered_rows


def test_bucket_adjacency_covers_every_edge(rmat_graph):
    """Soundness certificate: for every edge (u, v), the buckets owning u
    and v are marked adjacent, so a change at u can always re-activate v."""
    bg = bucketize(rmat_graph)
    adj = bg.bucket_adjacency()
    n = bg.n_nodes
    node_bucket = np.full(n, -1, dtype=np.int64)
    for bi, b in enumerate(bg.buckets):
        real = b.node_ids[b.node_ids < n]
        node_bucket[real] = bi
    deg = rmat_graph.degrees
    src = np.repeat(np.arange(n), deg)
    dst = rmat_graph.indices
    bs, bd = node_bucket[src], node_bucket[dst]
    assert (bs >= 0).all() and (bd >= 0).all()
    assert adj[bs, bd].all()
    assert (adj == adj.T).all()
    assert adj.diagonal().all()


def test_bucket_tiles_partition_nodes(rmat_graph):
    """Row tiles partition the positive-degree nodes exactly once."""
    bg = bucketize(rmat_graph)
    n = bg.n_nodes
    seen = np.zeros(n, dtype=np.int64)
    for b in bg.buckets:
        real = b.node_ids[b.node_ids < n]
        np.add.at(seen, real, 1)
    deg = rmat_graph.degrees
    assert (seen[deg > 0] == 1).all()
    assert (seen[deg == 0] == 0).all()


def test_dckcore_reports_work_metric(rmat_graph):
    core, report = dc_kcore(rmat_graph, thresholds=(8,), strategy="rough")
    np.testing.assert_array_equal(core, peel_coreness(rmat_graph))
    assert report.total_gathered_rows > 0
    assert report.total_gathered_rows <= report.total_full_sweep_rows
    for p in report.parts:
        assert len(p.active_rows_per_iter) == p.iterations
        assert p.gathered_rows == sum(p.active_rows_per_iter)


def test_frontier_resume_from_snapshot(rmat_graph):
    """Frontier scheduling composes with warm restart (init_coreness)."""
    bg = bucketize(rmat_graph)
    snap = {}
    decompose(bg, max_iter=3, on_sweep=lambda it, c: snap.update(c=np.asarray(c)))
    res = decompose(bg, init_coreness=snap["c"])
    np.testing.assert_array_equal(res.coreness, peel_coreness(rmat_graph))


# --------------------------------------------------------------------- #
# Divide-step properties, seeded ports (hypothesis versions live in
# tests/test_divide_properties.py)
# --------------------------------------------------------------------- #
def _tcore_oracle(g: Graph, ext: np.ndarray, t: int) -> np.ndarray:
    """Scalar peeling oracle for the generalized t-core with ext credit."""
    alive = np.ones(g.n_nodes, dtype=bool)
    while True:
        removed = False
        for v in range(g.n_nodes):
            if alive[v] and int(alive[g.neighbors(v)].sum()) + int(ext[v]) < t:
                alive[v] = False
                removed = True
        if not removed:
            return alive


@pytest.mark.parametrize("seed", range(10))
def test_exact_candidates_match_tcore_oracle_seeded(seed):
    rng = np.random.default_rng(300 + seed)
    n = int(rng.integers(2, 30))
    m = int(rng.integers(0, 3 * n))
    g = Graph.from_edges(
        rng.integers(0, n, size=m), rng.integers(0, n, size=m), n_nodes=n
    )
    ext = rng.integers(0, 5, size=n).astype(np.int32)
    t = int(rng.integers(1, 9))
    exact = exact_candidates(g, ext, t)
    np.testing.assert_array_equal(exact, _tcore_oracle(g, ext, t))
    rough = rough_candidates(g.degrees, ext, t)
    assert (rough | ~exact).all()  # rough is a superset of exact


def test_plan_thresholds_duplicate_run_regression():
    """The old planner `break`-ed when the overflow landed on a repeated
    degree value, silently under-dividing heavy-tailed graphs. With runs
    [5x4, 3x4, 2x4] and a 6-byte budget it planned only [5]; run-packing
    must cut again at 3 (and cut the trailing over-budget 2-run off the
    rest too)."""
    deg = np.array([5] * 4 + [3] * 4 + [2] * 4, dtype=np.int64)
    ts = plan_thresholds(deg, part_budget_bytes=6, bytes_per_edge=1)
    assert ts == [5, 3, 2]
    # A trailing over-budget run must be cut off from the degree<=1 tail,
    # not silently merged into the rest part (near-regular graph shape).
    deg_tail = np.array([3] * 6 + [1] * 10, dtype=np.int64)
    assert plan_thresholds(deg_tail, part_budget_bytes=4, bytes_per_edge=1) == [3]
    # Same shape but as a real graph path: thresholds planned off a star-rich
    # degree profile keep dc_kcore oracle-exact.
    rng = np.random.default_rng(9)
    g = Graph.from_edges(rng.integers(0, 40, 160), rng.integers(0, 40, 160), n_nodes=40)
    ts_g = plan_thresholds(g, g.memory_bytes() // 3)
    core, _ = dc_kcore(g, thresholds=ts_g, strategy="rough")
    np.testing.assert_array_equal(core, peel_coreness(g))


def test_plan_thresholds_budget_and_shape_seeded():
    bpe = 8
    for seed in range(12):
        rng = np.random.default_rng(400 + seed)
        deg = rng.integers(0, 50, size=int(rng.integers(2, 120))).astype(np.int64)
        budget = int(rng.integers(16, 3000))
        max_parts = int(rng.integers(2, 9))
        ts = plan_thresholds(deg, budget, max_parts=max_parts, bytes_per_edge=bpe)
        assert all(t > 1 for t in ts)
        assert all(a > b for a, b in zip(ts, ts[1:]))
        assert len(ts) <= max_parts - 1
        sdeg = np.sort(deg)[::-1]
        if int(sdeg.sum()) * bpe <= budget:
            assert ts == []
        elif (sdeg > 1).any():
            assert ts != []  # division needed and possible -> divide
        hi = np.inf
        for t in ts:
            part = sdeg[(sdeg >= t) & (sdeg < hi)]
            # Planned parts fit the budget unless indivisible (single run).
            assert int(part.sum()) * bpe <= budget or part.max() == part.min()
            hi = t
