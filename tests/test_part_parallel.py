"""Part-parallel conquer (``dc_kcore(part_parallel=S)``): scheduler
properties + the differential suite proving byte-identity to sequential.

Three layers, mirroring the implementation:

* **Scheduler** (pure numpy — runs in-process): :func:`assign_parts` /
  :func:`part_cost` unit + property tests. Hypothesis drives the property
  when installed; seeded ports keep the invariants covered either way
  (same convention as test_divide_chunked.py).
* **Thread mode** (in-process, single CPU device): slices are worker
  threads sharing the default engine — coreness, checkpoints and crash
  recovery must be byte-identical to the sequential loop across engines,
  reorderings and divide strategies.
* **Device mode** (subprocess per test, forced host device count): real
  mesh slices, the device-resident E(v) boundary fold, the modeled-cost
  pin against measured collective counters, and a two-rank multi-process
  differential through the :class:`WorkerHarness` fixture.

``REPRO_FORCE_DEVICES`` sets the virtual device count for device-mode
tests (CI runs the suite at 2 and 4; default 4). It must be even — the
suite always exercises 2 mesh slices.
"""
import hashlib
import os

import numpy as np
import pytest

from distributed_helpers import preamble, run_with_devices

from repro.core.dckcore import dc_kcore
from repro.core.distributed import planned_collective_schedule, planned_live_sets
from repro.core.partsched import (
    PartCost,
    SliceCapacityError,
    SliceSpec,
    assign_parts,
    conquer_wave,
    part_cost,
)
from repro.graph.generators import rmat
from repro.graph.oracle import peel_coreness

try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # seeded ports below keep the invariants covered
    HAVE_HYPOTHESIS = False

N_DEV = int(os.environ.get("REPRO_FORCE_DEVICES", "4"))
assert N_DEV % 2 == 0, "REPRO_FORCE_DEVICES must be even (suite uses 2 slices)"


# --------------------------------------------------------------------- #
# Scheduler: unit tests (pure planning layer, no devices).
# --------------------------------------------------------------------- #
def _cost(cursor, total, part_bytes=1):
    return PartCost(cursor=cursor, collective_bytes=total, hbm_bytes=0,
                    part_bytes=part_bytes)


def _slices(n, capacity=None):
    return [SliceSpec(index=i, n_node_shards=1, n_slot_shards=1,
                      capacity_bytes=capacity) for i in range(n)]


def test_assign_empty_schedule():
    sched = assign_parts([], _slices(3))
    assert sched.assignments == []
    assert sched.slice_loads() == [0, 0, 0]
    assert all(sched.parts_for(s) == [] for s in range(3))


def test_assign_single_part():
    sched = assign_parts([_cost(0, 100)], _slices(3))
    assert [a.slice_index for a in sched.assignments] == [0]
    assert sched.slice_loads() == [100, 0, 0]


def test_assign_more_parts_than_slices_queues_in_cursor_order():
    # 5 equal parts on 2 slices: LPT round-robins, each slice executes its
    # queue in ascending cursor order.
    sched = assign_parts([_cost(i, 10) for i in range(5)], _slices(2))
    assert sorted(sched.parts_for(0) + sched.parts_for(1)) == list(range(5))
    for s in range(2):
        q = sched.parts_for(s)
        assert q == sorted(q)
    assert sorted(sched.slice_loads()) == [20, 30]


def test_assign_lpt_places_big_parts_first():
    # costs 50, 30, 20 on 2 slices: LPT puts 50 alone, 30+20 together.
    sched = assign_parts(
        [_cost(0, 50), _cost(1, 30), _cost(2, 20)], _slices(2)
    )
    assert sorted(sched.slice_loads()) == [50, 50]
    by_cursor = {a.cursor: a.slice_index for a in sched.assignments}
    assert by_cursor[1] == by_cursor[2] != by_cursor[0]


def test_assign_output_in_plan_order():
    """Merged coreness folds back in plan order — the schedule's
    assignment list IS that order regardless of cost-sorted placement."""
    sched = assign_parts([_cost(2, 1), _cost(0, 99), _cost(1, 50)], _slices(2))
    assert [a.cursor for a in sched.assignments] == [0, 1, 2]


def test_assign_capacity_respected_and_total():
    slices = [
        SliceSpec(index=0, n_node_shards=1, n_slot_shards=1, capacity_bytes=10),
        SliceSpec(index=1, n_node_shards=1, n_slot_shards=1, capacity_bytes=100),
    ]
    # The big-footprint part must land on slice 1 even though slice 0 is
    # emptier; the small one then balances onto slice 0.
    sched = assign_parts(
        [_cost(0, 5, part_bytes=50), _cost(1, 5, part_bytes=5)], slices
    )
    by_cursor = {a.cursor: a.slice_index for a in sched.assignments}
    assert by_cursor[0] == 1 and by_cursor[1] == 0
    with pytest.raises(SliceCapacityError):
        assign_parts([_cost(0, 1, part_bytes=1000)], slices)


def test_assign_validates_slices():
    with pytest.raises(ValueError):
        assign_parts([_cost(0, 1)], [])
    with pytest.raises(ValueError):
        assign_parts([_cost(0, 1)], [
            SliceSpec(index=0, n_node_shards=1, n_slot_shards=1),
            SliceSpec(index=0, n_node_shards=1, n_slot_shards=1),
        ])


def test_conquer_wave_runs_all_and_reraises_earliest():
    sched = assign_parts([_cost(i, 10) for i in range(4)], _slices(2))
    ran = []
    out = conquer_wave(sched, lambda cur, s: ran.append((cur, s)) or cur * 2)
    assert sorted(out) == [0, 1, 2, 3]
    assert all(out[c] == c * 2 for c in out)
    assert sorted(c for c, _s in ran) == [0, 1, 2, 3]

    class Boom(Exception):
        pass

    def failing(cur, s):
        raise Boom(f"part {cur}")

    with pytest.raises(Boom) as ei:
        conquer_wave(sched, failing)
    # Deterministic: the earliest-cursor failure wins.
    assert "part 0" in str(ei.value)


# --------------------------------------------------------------------- #
# Scheduler: properties (hypothesis when available + seeded ports).
# --------------------------------------------------------------------- #
def _check_schedule_invariants(costs, n_slices, capacity=None):
    slices = _slices(n_slices, capacity)
    if capacity is not None and any(c.part_bytes > capacity for c in costs):
        with pytest.raises(SliceCapacityError):
            assign_parts(costs, slices)
        return
    sched = assign_parts(costs, slices)
    # Total: every part exactly once, merged list in plan (cursor) order.
    assert [a.cursor for a in sched.assignments] == sorted(c.cursor for c in costs)
    # Capacity respected on every placement.
    if capacity is not None:
        assert all(a.cost.part_bytes <= capacity for a in sched.assignments)
    # Load bookkeeping is conservative (no cost lost or invented).
    loads = sched.slice_loads()
    assert sum(loads) == sum(c.total for c in costs)
    # Uncapacitated LPT guarantee: makespan <= average + one part.
    if capacity is None and costs:
        avg = sum(c.total for c in costs) / n_slices
        assert max(loads) <= avg + max(c.total for c in costs)
    # Determinism: input order must not matter.
    shuffled = list(reversed(costs))
    assert assign_parts(shuffled, slices) == sched


def _random_costs(rng, n):
    return [
        PartCost(
            cursor=i,
            collective_bytes=int(rng.integers(0, 1 << 24)),
            hbm_bytes=int(rng.integers(0, 1 << 22)),
            part_bytes=int(rng.integers(1, 1 << 16)),
        )
        for i in range(n)
    ]


def test_assign_invariants_seeded():
    for seed in range(30):
        rng = np.random.default_rng(seed)
        n_parts = int(rng.integers(0, 9))
        n_slices = int(rng.integers(1, 5))
        cap = None if rng.random() < 0.5 else int(rng.integers(1, 1 << 17))
        _check_schedule_invariants(_random_costs(rng, n_parts), n_slices, cap)


if HAVE_HYPOTHESIS:

    @settings(deadline=None, max_examples=60)
    @given(
        seed=st.integers(0, 2**31 - 1),
        n_parts=st.integers(0, 12),
        n_slices=st.integers(1, 6),
        capacitated=st.booleans(),
    )
    def test_assign_invariants_hypothesis(seed, n_parts, n_slices, capacitated):
        rng = np.random.default_rng(seed)
        cap = int(rng.integers(1, 1 << 17)) if capacitated else None
        _check_schedule_invariants(_random_costs(rng, n_parts), n_slices, cap)


def test_planned_schedule_edge_cases():
    """The cost model's planned schedule is total: no buckets, all-empty
    buckets and single-bucket parts all price without special-casing."""
    spec4 = SliceSpec(index=0, n_node_shards=2, n_slot_shards=2)
    assert planned_collective_schedule([], spec4, 8, n_iters=5) == [0] * 5
    assert planned_live_sets([], n_iters=5) == [[]] * 5
    # A zero-row bucket contributes nothing; the nonempty one still prices.
    with_zero = planned_collective_schedule([0, 16], spec4, 8, n_iters=5)
    only = planned_collective_schedule([16], spec4, 8, n_iters=5)
    assert all(b > 0 for b in with_zero)
    # The dirty-bit psum term scales with bucket COUNT, so the two-bucket
    # layout costs at least the one-bucket one, never less.
    assert all(a >= b for a, b in zip(with_zero, only))


def test_part_cost_single_device_is_collective_free_but_ordered():
    spec1 = SliceSpec(index=0, n_node_shards=1, n_slot_shards=1)
    small = part_cost([(16, 8)], 8, 16, spec1)
    big = part_cost([(64, 8), (16, 32)], 8, 80, spec1)
    assert small.collective_bytes == big.collective_bytes == 0
    # HBM term keeps costs nonzero and size-ordered on 1-device slices.
    assert 0 < small.total < big.total
    assert small.part_bytes < big.part_bytes


# --------------------------------------------------------------------- #
# Thread mode: differential against the sequential loop (in-process).
# --------------------------------------------------------------------- #
@pytest.mark.parametrize("engine,int16", [("sorted", False), ("fused", False),
                                          ("fused", True), ("count", False)])
@pytest.mark.parametrize("strategy", ["rough", "exact"])
def test_thread_mode_matches_sequential(engine, int16, strategy):
    g = rmat(10, 8, seed=11)
    seq_core, seq_rep = dc_kcore(g, thresholds=(4, 10), strategy=strategy,
                                 engine=engine, int16=int16)
    par_core, par_rep = dc_kcore(g, thresholds=(4, 10), strategy=strategy,
                                 engine=engine, int16=int16, part_parallel=2)
    np.testing.assert_array_equal(par_core, seq_core)
    np.testing.assert_array_equal(par_core, peel_coreness(g))
    assert par_rep.part_parallel == 2
    assert len(par_rep.slice_busy_s) == 2
    assert [p.name for p in par_rep.parts] == [p.name for p in seq_rep.parts]
    # Every conquered part carries its placement stamp.
    assert all(p.slice_index >= 0 and p.wave >= 0 for p in par_rep.parts)
    if strategy == "exact":
        # Exact-Divide speculation is exact by construction: the wave chain
        # never mispredicts, so nothing is ever discarded.
        assert par_rep.speculation_discards == 0
        assert par_rep.prefetch_misses == 0


@pytest.mark.parametrize("reorder", ["rcm", "bfs"])
def test_thread_mode_with_reorder(reorder):
    g = rmat(10, 8, seed=3)
    seq_core, _ = dc_kcore(g, thresholds=(4, 10), reorder=reorder)
    par_core, _ = dc_kcore(g, thresholds=(4, 10), reorder=reorder,
                           part_parallel=2)
    np.testing.assert_array_equal(par_core, seq_core)
    np.testing.assert_array_equal(par_core, peel_coreness(g))


def test_thread_mode_matches_overlap_pipeline():
    """Three ways to run the same decomposition — sequential, overlapped
    (PR 6) and part-parallel — one answer."""
    g = rmat(10, 8, seed=7)
    seq, _ = dc_kcore(g, thresholds=(4, 10, 20))
    ovl, _ = dc_kcore(g, thresholds=(4, 10, 20), overlap=True)
    par, _ = dc_kcore(g, thresholds=(4, 10, 20), part_parallel=3)
    np.testing.assert_array_equal(seq, ovl)
    np.testing.assert_array_equal(seq, par)


def test_thread_mode_monolithic_and_many_slices():
    g = rmat(10, 8, seed=5)
    # Monolithic (no thresholds): one part, extra slices idle.
    seq, _ = dc_kcore(g, thresholds=())
    par, rep = dc_kcore(g, thresholds=(), part_parallel=4)
    np.testing.assert_array_equal(seq, par)
    # More slices than parts: trailing slices never get work.
    assert sum(1 for b in rep.slice_busy_s if b > 0) <= len(rep.parts)


def test_thread_mode_checkpoint_byte_identity(tmp_path):
    """Sequential and part-parallel runs leave interchangeable checkpoints:
    the final pipeline state restores to identical arrays either way."""
    from repro.core.dckcore import PipelineState

    g = rmat(10, 8, seed=11)
    ck_seq, ck_par = str(tmp_path / "seq"), str(tmp_path / "par")
    seq, _ = dc_kcore(g, thresholds=(4, 10), checkpoint_dir=ck_seq)
    par, _ = dc_kcore(g, thresholds=(4, 10), checkpoint_dir=ck_par,
                      part_parallel=2)
    np.testing.assert_array_equal(seq, par)
    s1 = PipelineState.restore(ck_seq, g.n_nodes)
    s2 = PipelineState.restore(ck_par, g.n_nodes)
    assert s1.parts_done == s2.parts_done and s1.complete and s2.complete
    np.testing.assert_array_equal(s1.coreness, s2.coreness)
    np.testing.assert_array_equal(s1.finalized, s2.finalized)


class SimulatedCrash(Exception):
    pass


def test_thread_mode_boundary_crash_storm(tmp_path):
    """Kill the part-parallel run at EVERY part boundary in turn; each
    resume (also part-parallel) must converge to the sequential answer
    with disk bounded to one retained step."""
    g = rmat(10, 8, seed=11)
    thresholds = (4, 10, 20)
    base, base_rep = dc_kcore(g, thresholds=thresholds)
    ck = str(tmp_path / "ck")

    def killer(idx, report):
        raise SimulatedCrash

    cycles = 0
    while True:
        try:
            core, rep = dc_kcore(
                g, thresholds=thresholds, part_parallel=2,
                checkpoint_dir=ck, resume=cycles > 0,
                on_part_done=killer if cycles < len(base_rep.parts) else None,
            )
            break
        except SimulatedCrash:
            cycles += 1
            assert cycles < 50, "storm did not converge"
    np.testing.assert_array_equal(core, base)
    np.testing.assert_array_equal(core, peel_coreness(g))
    assert cycles == len(base_rep.parts)
    steps = [d for d in os.listdir(ck)
             if d.startswith("step_") and not d.endswith(".tmp")]
    assert 1 <= len(steps) <= 2  # retain=2: latest boundary + fallback


def test_thread_mode_midsweep_crash_resumes(tmp_path):
    """A crash INSIDE a part (sweep snapshot granularity) on a
    part-parallel run: resume warm-restarts mid-part, byte-identical."""
    g = rmat(10, 8, seed=11)
    base, _ = dc_kcore(g, thresholds=(4, 10))
    ck = str(tmp_path / "ck")
    calls = []

    def kill_at_second(cursor, sweep, save_s):
        calls.append((cursor, sweep))
        if len(calls) == 2:
            raise SimulatedCrash

    with pytest.raises(SimulatedCrash):
        dc_kcore(g, thresholds=(4, 10), part_parallel=2, checkpoint_dir=ck,
                 sweep_checkpoint_every=1, on_sweep_saved=kill_at_second)
    core, rep = dc_kcore(g, thresholds=(4, 10), part_parallel=2,
                         checkpoint_dir=ck, resume=True,
                         sweep_checkpoint_every=1)
    np.testing.assert_array_equal(core, base)
    assert any(p.resumed_at_sweep > 0 for p in rep.parts)


def test_cross_mode_resume(tmp_path):
    """A sequential run killed mid-decomposition resumes part-parallel
    (and vice versa) — checkpoints carry no mode dependence."""
    g = rmat(10, 8, seed=11)
    thresholds = (4, 10, 20)
    base, _ = dc_kcore(g, thresholds=thresholds)

    def kill_first(idx, report):
        raise SimulatedCrash

    ck1 = str(tmp_path / "a")
    with pytest.raises(SimulatedCrash):
        dc_kcore(g, thresholds=thresholds, checkpoint_dir=ck1,
                 on_part_done=kill_first)
    core, _ = dc_kcore(g, thresholds=thresholds, checkpoint_dir=ck1,
                       resume=True, part_parallel=2)
    np.testing.assert_array_equal(core, base)

    ck2 = str(tmp_path / "b")
    with pytest.raises(SimulatedCrash):
        dc_kcore(g, thresholds=thresholds, checkpoint_dir=ck2,
                 part_parallel=2, on_part_done=kill_first)
    core, _ = dc_kcore(g, thresholds=thresholds, checkpoint_dir=ck2,
                       resume=True)
    np.testing.assert_array_equal(core, base)


def test_part_parallel_validation():
    g = rmat(8, 8, seed=1)
    with pytest.raises(ValueError):
        dc_kcore(g, thresholds=(4,), part_parallel=0)
    with pytest.raises(ValueError):
        dc_kcore(g, thresholds=(4,), part_parallel=2, overlap=True)
    with pytest.raises(ValueError):
        # A mesh plan without part_parallel is meaningless.
        dc_kcore(g, thresholds=(4,), part_parallel_plan=object())


# --------------------------------------------------------------------- #
# Device mode: real mesh slices in a subprocess (REPRO_FORCE_DEVICES).
# --------------------------------------------------------------------- #
def test_device_fold_matches_host_external_info():
    """The device-resident E(v) boundary fold is bit-exact vs the host
    chunked pass — counts AND the DivideStats bookkeeping — at several
    chunk sizes, and moves zero collective bytes on a 1-device plan."""
    out = run_with_devices(
        preamble(N_DEV)
        + rf"""
from repro.core.distributed import device_external_info
from repro.graph.build import DivideStats, external_info
from repro.launch.mesh import make_mesh_plan_for_devices
plan = make_mesh_plan_for_devices({N_DEV})
g = rmat(10, 8, seed=3)
rng = np.random.default_rng(0)
for trial in range(3):
    keep = rng.random(g.n_nodes) < (0.3, 0.7, 1.0)[trial]
    upper = rng.random(g.n_nodes) < 0.5
    for cs in (None, 1 << 12):
        hs, ds = DivideStats(chunk_slots=cs or 0), DivideStats(chunk_slots=cs or 0)
        host = external_info(g, keep, upper, chunk_slots=cs, stats=hs)
        dev, moved = device_external_info(g, keep, upper, plan,
                                          chunk_slots=cs, stats=ds)
        np.testing.assert_array_equal(dev, host)
        assert moved > 0
        assert (hs.n_chunks, hs.input_slots, hs.kept_slots) == \
               (ds.n_chunks, ds.input_slots, ds.kept_slots)
plan1 = make_mesh_plan_for_devices(1)
dev, moved = device_external_info(g, keep, upper, plan1)
np.testing.assert_array_equal(dev, external_info(g, keep, upper))
assert moved == 0
print("OK")
""",
        n_devices=N_DEV,
    )
    assert "OK" in out


def test_part_parallel_device_mode_matches():
    """Two real mesh slices conquering concurrently == sequential ==
    oracle; boundary exchange runs on the device (bytes counted) and both
    slices report busy time."""
    out = run_with_devices(
        preamble(N_DEV)
        + rf"""
from repro.launch.mesh import make_mesh_plan_for_devices
plan = make_mesh_plan_for_devices({N_DEV})
g = rmat(10, 8, seed=11)
seq, _ = dc_kcore(g, thresholds=(4, 10), strategy="exact")
par, rep = dc_kcore(g, thresholds=(4, 10), strategy="exact",
                    part_parallel=2, part_parallel_plan=plan)
np.testing.assert_array_equal(par, seq)
np.testing.assert_array_equal(par, peel_coreness(g))
assert rep.part_parallel == 2
assert rep.boundary_exchange_bytes > 0
assert len(rep.slice_busy_s) == 2
assert rep.conquer_wall_s > 0
assert all(0.0 <= u <= 1.0 for u in rep.slice_utilization)
assert all(p.slice_index in (0, 1) for p in rep.parts)
assert len({{p.slice_index for p in rep.parts}}) == 2  # both slices conquered
print("OK")
""",
        n_devices=N_DEV,
    )
    assert "OK" in out


def test_part_parallel_device_mode_crash_resume(tmp_path):
    """Mid-part crash while a slice is conquering on devices: the lead-part
    sweep-snapshot discipline leaves sequential-equivalent disk, and a
    part-parallel resume completes byte-identically with bounded disk."""
    out = run_with_devices(
        preamble(N_DEV)
        + rf"""
import os
from repro.launch.mesh import make_mesh_plan_for_devices
plan = make_mesh_plan_for_devices({N_DEV})
g = rmat(10, 8, seed=11)
base, _ = dc_kcore(g, thresholds=(4, 10), strategy="exact")
ck = {str(tmp_path / "ck")!r}
class Crash(Exception): pass
calls = []
def killer(cursor, sweep, save_s):
    calls.append((cursor, sweep))
    if len(calls) == 2: raise Crash
try:
    dc_kcore(g, thresholds=(4, 10), strategy="exact", part_parallel=2,
             part_parallel_plan=plan, checkpoint_dir=ck,
             sweep_checkpoint_every=1, on_sweep_saved=killer)
    raise SystemExit("no crash")
except Crash:
    pass
core, rep = dc_kcore(g, thresholds=(4, 10), strategy="exact", part_parallel=2,
                     part_parallel_plan=plan, checkpoint_dir=ck, resume=True,
                     sweep_checkpoint_every=1)
np.testing.assert_array_equal(core, base)
np.testing.assert_array_equal(core, peel_coreness(g))
assert any(p.resumed_at_sweep > 0 for p in rep.parts)
steps = [d for d in os.listdir(ck) if d.startswith("step_") and not d.endswith(".tmp")]
assert 1 <= len(steps) <= 2, steps  # retain=2: latest boundary + fallback
print("OK")
""",
        n_devices=N_DEV,
    )
    assert "OK" in out


def test_modeled_cost_pinned_to_measured_bytes():
    """The scheduler's collective term on a slice spec == the live slice
    engine's measured counter, byte for byte, on a frontier=False run
    (every sweep full => the planned schedule is exact)."""
    out = run_with_devices(
        preamble(N_DEV)
        + rf"""
from repro.core.distributed import decompose_distributed
from repro.core.partsched import cost_for_plan, slice_mesh_plans, spec_of
from repro.launch.mesh import make_mesh_plan_for_devices
plan = make_mesh_plan_for_devices({N_DEV})
g = rmat(9, 8, seed=2)
bg = bucketize(g)
for i, sp in enumerate(slice_mesh_plans(plan, 2)):
    spec = spec_of(sp, i)
    base = decompose_distributed(bg, sp, frontier=False)
    cost = cost_for_plan(bg, 7, spec, frontier=False,
                         n_iters=base.iterations, full_sweeps=base.iterations)
    assert cost.cursor == 7
    measured = sum(base.collective_bytes_per_iter)
    if spec.n_devices > 1:
        assert cost.collective_bytes == measured, (cost.collective_bytes, measured)
    else:
        assert cost.collective_bytes == 0 and measured == 0
print("OK")
""",
        n_devices=N_DEV,
    )
    assert "OK" in out


# --------------------------------------------------------------------- #
# Multi-process harness: rank fleet + failure capture + leak gate.
# --------------------------------------------------------------------- #
_RANK_SNIPPET = (
    preamble(N_DEV)
    + rf"""
import hashlib, os
from repro.launch.mesh import make_mesh_plan_for_devices
rank = int(os.environ["REPRO_RANK"]); world = int(os.environ["REPRO_WORLD"])
assert 0 <= rank < world
g = rmat(10, 8, seed=11)
if rank == 0:
    core, _ = dc_kcore(g, thresholds=(4, 10), strategy="exact")
else:
    plan = make_mesh_plan_for_devices({N_DEV})
    core, rep = dc_kcore(g, thresholds=(4, 10), strategy="exact",
                         part_parallel=2, part_parallel_plan=plan)
    assert rep.part_parallel == 2
print("DIGEST", hashlib.sha256(np.ascontiguousarray(core).tobytes()).hexdigest())
"""
)


def test_multiprocess_rank_differential(worker_harness):
    """Two ranks spawned concurrently — rank 0 sequential, rank 1
    part-parallel over real mesh slices — must print identical coreness
    digests (deterministic seeds make the comparison exact across
    process boundaries)."""
    for rank in range(2):
        worker_harness.spawn(_RANK_SNIPPET, n_devices=N_DEV, rank=rank, world=2)
    outs = worker_harness.join(timeout=600)
    digests = [line.split()[1] for out in outs for line in out.splitlines()
               if line.startswith("DIGEST")]
    assert len(digests) == 2
    assert digests[0] == digests[1]


def test_harness_surfaces_child_tracebacks(worker_harness):
    """A failing rank's traceback lands verbatim in the join() failure —
    and the passing rank's result is still collected first."""
    worker_harness.spawn("print('fine')", n_devices=2, rank=0, world=2)
    worker_harness.spawn(
        "raise ValueError('boom-part-parallel-7f3a')", n_devices=2,
        rank=1, world=2,
    )
    with pytest.raises(AssertionError) as ei:
        worker_harness.join(timeout=120)
    msg = str(ei.value)
    assert "boom-part-parallel-7f3a" in msg
    assert "rank 1/2" in msg


def test_harness_leak_gate_kills_strays(worker_harness):
    """A child that outlives the test body is detected and killed; the
    fixture would fail the test if we didn't reap it here."""
    import time

    worker_harness.spawn("import time; time.sleep(600)", n_devices=2)
    time.sleep(0.2)
    assert worker_harness.leaked()
    pids = worker_harness.terminate_leaked()
    assert pids
    assert not worker_harness.leaked()
