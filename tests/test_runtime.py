"""Optimizers, data pipeline, checkpointing and fault tolerance."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt import CheckpointManager, latest_step, restore_pytree, save_pytree
from repro.configs import get_smoke_config
from repro.data import MemmapTokens, Prefetcher, SyntheticTokens
from repro.models.model import build_specs
from repro.models.module import init_params
from repro.optim import adafactor, adamw, apply_updates, get_optimizer, warmup_cosine
from repro.runtime import FailureInjector, TrainLoop, run_with_retries
from repro.runtime.fault import InjectedFailure


# --------------------------------------------------------------------- #
# Optimizers
# --------------------------------------------------------------------- #
@pytest.mark.parametrize("make_opt", [lambda: adamw(lambda s: 0.1),
                                      lambda: adafactor(lambda s: 0.5)])
def test_optimizer_minimizes_quadratic(make_opt):
    opt = make_opt()
    params = {"w": jnp.array([[3.0, -2.0], [1.0, 4.0]]), "b": jnp.array([5.0])}
    state = opt.init(params)
    loss = lambda p: jnp.sum(p["w"] ** 2) + jnp.sum(p["b"] ** 2)
    l0 = float(loss(params))
    for i in range(60):
        grads = jax.grad(loss)(params)
        updates, state = opt.update(grads, state, params, jnp.asarray(i))
        params = apply_updates(params, updates)
    assert float(loss(params)) < 0.1 * l0


def test_adafactor_state_is_factored():
    opt = adafactor(lambda s: 0.1)
    params = {"w": jnp.zeros((64, 32))}
    state = opt.init(params)
    n_state = sum(x.size for x in jax.tree.leaves(state))
    assert n_state == 64 + 32  # rank-1 factorization, not 64*32


def test_warmup_cosine_shape():
    lr = warmup_cosine(1.0, 10, 100)
    assert float(lr(0)) == 0.0
    assert abs(float(lr(10)) - 1.0) < 1e-6
    assert float(lr(100)) < float(lr(50)) < float(lr(10))


# --------------------------------------------------------------------- #
# Data
# --------------------------------------------------------------------- #
def test_synthetic_deterministic():
    src = SyntheticTokens(vocab_size=100, seq_len=8, batch=2, seed=3)
    a, b = src.batch_at(5), src.batch_at(5)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    assert not np.array_equal(src.batch_at(6)["tokens"], a["tokens"])
    # labels are next-token shifted
    full_a = src.batch_at(5)
    np.testing.assert_array_equal(full_a["tokens"][:, 1:], full_a["labels"][:, :-1])


def test_memmap_loader_and_prefetch(tmp_path):
    path = str(tmp_path / "tokens.bin")
    data = np.arange(9 * 40, dtype=np.int32)
    data.tofile(path)
    src = MemmapTokens(path, seq_len=8, batch=2, host_index=1, host_count=2)
    b0 = src.batch_at(0)
    assert b0["tokens"].shape == (2, 8)
    # Host 1 starts at its own shard.
    assert b0["tokens"][0, 0] == src.rows_per_host * 9
    pf = Prefetcher(src, start_step=0, depth=2)
    s0, batch0 = pf.next()
    s1, batch1 = pf.next()
    assert (s0, s1) == (0, 1)
    np.testing.assert_array_equal(batch0["tokens"], src.batch_at(0)["tokens"])
    pf.close()


# --------------------------------------------------------------------- #
# Checkpointing
# --------------------------------------------------------------------- #
def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(6).reshape(2, 3), "b": {"c": jnp.ones((4,), jnp.bfloat16)}}
    save_pytree(str(tmp_path), tree, step=7, extra={"note": "x"})
    assert latest_step(str(tmp_path)) == 7
    restored, step, extra = restore_pytree(str(tmp_path), tree)
    assert step == 7 and extra == {"note": "x"}
    np.testing.assert_array_equal(np.asarray(restored["a"]), np.asarray(tree["a"]))
    assert restored["b"]["c"].dtype == np.asarray(tree["b"]["c"]).dtype


def test_checkpoint_manager_async_and_retention(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    tree = {"w": jnp.zeros((8,))}
    for s in [1, 2, 3, 4]:
        mgr.save(tree, s)
    mgr.wait()
    steps = sorted(
        int(d.split("_")[1]) for d in os.listdir(tmp_path) if d.startswith("step_")
    )
    assert steps == [3, 4]


# --------------------------------------------------------------------- #
# Fault tolerance: kill + restart is bit-identical
# --------------------------------------------------------------------- #
def _make_loop(tmp_path, injector=None):
    cfg = get_smoke_config("granite-3-2b")
    params = init_params(build_specs(cfg), jax.random.PRNGKey(0))
    data = SyntheticTokens(vocab_size=cfg.vocab_size, seq_len=16, batch=2, seed=1)
    return TrainLoop(
        cfg=cfg, params=params, optimizer=get_optimizer(cfg, lr=1e-3),
        data=data, ckpt_dir=str(tmp_path / "ckpt"), ckpt_every=5,
        ckpt_blocking=True,  # deterministic: a crash never races the save
        failure_injector=injector, jit=True,
    )


def test_train_resume_bit_identical(tmp_path):
    # Uninterrupted run of 12 steps.
    loop_a = _make_loop(tmp_path / "a")
    loop_a.run(12, log_every=1)
    ref = jax.tree.map(np.asarray, loop_a.params)

    # Run that dies at step 8 and restarts from the step-5 checkpoint.
    injector = FailureInjector(fail_at={8})
    loop_b = _make_loop(tmp_path / "b", injector)
    with pytest.raises(InjectedFailure):
        loop_b.run(12, log_every=1)
    loop_c = _make_loop(tmp_path / "b")
    assert loop_c.try_resume()
    assert loop_c.step == 5
    loop_c.run(12 - loop_c.step, log_every=1)
    got = jax.tree.map(np.asarray, loop_c.params)
    jax.tree.map(
        lambda a, b: np.testing.assert_array_equal(a, b), ref, got
    )


def test_run_with_retries():
    calls = {"n": 0}

    def flaky():
        calls["n"] += 1
        if calls["n"] < 3:
            raise RuntimeError("boom")
        return "ok"

    assert run_with_retries(flaky, retries=3) == "ok"
    assert calls["n"] == 3
    with pytest.raises(RuntimeError):
        run_with_retries(lambda: (_ for _ in ()).throw(RuntimeError("x")), retries=1)
