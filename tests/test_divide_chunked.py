"""Chunked-divide equivalence and transient-bound tests.

The divide step's extraction passes — `induced_subgraph`, `external_info`,
`exact_candidates` — now run chunked over CSR row ranges. Pinned here:

  * **bit-identity** with the dense (np.repeat-over-all-rows) reference at
    every chunk size, including chunk=1 and chunk > total slots, on random
    and heavy-tailed (rmat) graphs — hypothesis properties plus seeded
    ports so the suite never silently skips;
  * the **EdgeStore-direct** extraction (`induced_subgraph_from_store`,
    `rough_candidates_from_store`) matches / soundly supersets the CSR
    path, duplicates and self-loops included;
  * the **transient peak** tracks the chunk budget, not the edge count,
    and stays below the dense baseline (mirrors test_stream_ingest.py's
    bound checks; bench fig15 is the larger-scale gate);
  * `dc_kcore(divide_chunk=...)` is byte-identical to the default run.

The dense references are deliberately re-implemented here (the pre-chunking
code), so the production path is checked against an independent oracle.
"""
import numpy as np
import pytest

from repro.core.dckcore import dc_kcore
from repro.core.divide import (
    exact_candidates,
    rough_candidates,
    rough_candidates_from_store,
)
from repro.graph.build import (
    DivideStats,
    external_info,
    induced_subgraph,
    iter_row_ranges,
)
from repro.graph.generators import rmat
from repro.graph.io import EdgeStore, csr_from_edge_store, induced_subgraph_from_store
from repro.graph.oracle import peel_coreness
from repro.graph.structs import Graph

try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # seeded ports below keep the invariants covered
    HAVE_HYPOTHESIS = False


# --------------------------------------------------------------------- #
# Dense references: the pre-chunking implementations, kept verbatim as
# independent oracles.
# --------------------------------------------------------------------- #
def dense_induced_subgraph(g: Graph, keep_mask: np.ndarray):
    keep_mask = np.asarray(keep_mask, dtype=bool)
    node_ids = np.nonzero(keep_mask)[0].astype(np.int64)
    new_id = np.full(g.n_nodes, -1, dtype=np.int64)
    new_id[node_ids] = np.arange(node_ids.shape[0], dtype=np.int64)
    src = np.repeat(np.arange(g.n_nodes, dtype=np.int64), g.degrees)
    keep_edge = keep_mask[src] & keep_mask[g.indices]
    sub_src = new_id[src[keep_edge]]
    sub_dst = new_id[g.indices[keep_edge]]
    n_sub = node_ids.shape[0]
    counts = np.bincount(sub_src, minlength=n_sub)
    indptr = np.zeros(n_sub + 1, dtype=np.int64)
    np.cumsum(counts, out=indptr[1:])
    sub = Graph(indptr=indptr, indices=sub_dst.astype(np.int32), n_nodes=int(n_sub))
    return sub, node_ids


def dense_external_info(g: Graph, keep_mask, upper_mask):
    keep_mask = np.asarray(keep_mask, dtype=bool)
    upper_mask = np.asarray(upper_mask, dtype=bool)
    src = np.repeat(np.arange(g.n_nodes, dtype=np.int64), g.degrees)
    contributes = keep_mask[src] & upper_mask[g.indices]
    ext_full = np.bincount(src[contributes], minlength=g.n_nodes)
    return ext_full[keep_mask].astype(np.int32)


def dense_exact_candidates(g: Graph, ext, t):
    alive = np.ones(g.n_nodes, dtype=bool)
    deg = g.degrees.astype(np.int64) + ext.astype(np.int64)
    src = np.repeat(np.arange(g.n_nodes, dtype=np.int64), g.degrees)
    frontier = np.nonzero(alive & (deg < t))[0]
    while frontier.size:
        alive[frontier] = False
        f = np.zeros(g.n_nodes, dtype=bool)
        f[frontier] = True
        hits = f[src] & alive[g.indices]
        dec = np.bincount(g.indices[hits], minlength=g.n_nodes)
        deg -= dec
        frontier = np.nonzero(alive & (deg < t) & (dec > 0))[0]
    return alive


def assert_same_graph(a: Graph, b: Graph):
    assert a.n_nodes == b.n_nodes
    np.testing.assert_array_equal(a.indptr, b.indptr)
    np.testing.assert_array_equal(a.indices, b.indices)
    assert a.indptr.dtype == b.indptr.dtype
    assert a.indices.dtype == b.indices.dtype


def random_case(seed: int):
    """(graph, keep_mask, upper_mask, ext, t) with loops/duplicates forced."""
    rng = np.random.default_rng(seed)
    n = int(rng.integers(1, 48))
    m = int(rng.integers(0, 5 * n))
    src = rng.integers(0, n, size=m)
    dst = rng.integers(0, n, size=m)
    if m >= 4:
        src[0] = dst[0] = 0                  # self-loop
        src[1], dst[1] = src[2], dst[2]      # duplicate edge
    g = Graph.from_edges(src, dst, n_nodes=n)
    keep = rng.random(n) < 0.6
    upper = ~keep & (rng.random(n) < 0.7)
    ext = rng.integers(0, 5, size=n).astype(np.int32)
    t = int(rng.integers(1, 10))
    return g, keep, upper, ext, t


def check_all_equivalences(g, keep, upper, ext, t, chunk):
    ref_sub, ref_ids = dense_induced_subgraph(g, keep)
    sub, ids = induced_subgraph(g, keep, chunk_slots=chunk)
    assert_same_graph(sub, ref_sub)
    np.testing.assert_array_equal(ids, ref_ids)
    assert ids.dtype == ref_ids.dtype

    ref_ext = dense_external_info(g, keep, upper)
    got_ext = external_info(g, keep, upper, chunk_slots=chunk)
    np.testing.assert_array_equal(got_ext, ref_ext)
    assert got_ext.dtype == ref_ext.dtype

    np.testing.assert_array_equal(
        exact_candidates(g, ext, t, chunk_slots=chunk),
        dense_exact_candidates(g, ext, t),
    )


# --------------------------------------------------------------------- #
# Seeded ports (always run, hypothesis or not)
# --------------------------------------------------------------------- #
@pytest.mark.parametrize("seed", range(12))
@pytest.mark.parametrize("chunk", [1, 3, 257, 10**9, None])
def test_chunked_divide_bit_identical_seeded(seed, chunk):
    """chunk=1, tiny, medium, > total slots, and the default budget."""
    g, keep, upper, ext, t = random_case(seed)
    check_all_equivalences(g, keep, upper, ext, t, chunk)


@pytest.mark.parametrize("chunk", [1, 129, 4096, 10**9])
def test_chunked_divide_heavy_tailed(chunk):
    """Power-law graph (hub rows much wider than the small chunk sizes —
    chunk=1 forces every row into its own over-budget range)."""
    g = rmat(9, 8, seed=7)
    rng = np.random.default_rng(0)
    keep = rng.random(g.n_nodes) < 0.5
    upper = ~keep & (rng.random(g.n_nodes) < 0.5)
    ext = rng.integers(0, 3, g.n_nodes).astype(np.int32)
    check_all_equivalences(g, keep, upper, ext, 6, chunk)


@pytest.mark.parametrize("chunk", [513, 8192, 10**9])
def test_chunked_divide_rmat_fixture(rmat_graph, chunk):
    rng = np.random.default_rng(1)
    keep = rng.random(rmat_graph.n_nodes) < 0.6
    upper = ~keep
    ext = np.zeros(rmat_graph.n_nodes, np.int32)
    check_all_equivalences(rmat_graph, keep, upper, ext, 8, chunk)


def test_empty_and_degenerate_graphs():
    for g in (Graph.empty(0), Graph.empty(7)):
        mask = np.ones(g.n_nodes, dtype=bool)
        for chunk in (1, None):
            sub, ids = induced_subgraph(g, mask, chunk_slots=chunk)
            assert_same_graph(sub, dense_induced_subgraph(g, mask)[0])
            np.testing.assert_array_equal(
                external_info(g, mask, ~mask, chunk_slots=chunk),
                dense_external_info(g, mask, ~mask),
            )


def test_iter_row_ranges_partitions_rows(rmat_graph):
    """Ranges partition the rows; every range fits the budget unless it is
    a single over-budget row."""
    indptr = rmat_graph.indptr
    for budget in (1, 100, 10**9):
        ranges = list(iter_row_ranges(indptr, budget))
        assert ranges[0][0] == 0 and ranges[-1][1] == rmat_graph.n_nodes
        for (lo, hi), (lo2, _hi2) in zip(ranges, ranges[1:]):
            assert hi == lo2
        for lo, hi in ranges:
            slots = int(indptr[hi] - indptr[lo])
            assert slots <= budget or hi == lo + 1


# --------------------------------------------------------------------- #
# EdgeStore-direct extraction
# --------------------------------------------------------------------- #
@pytest.mark.parametrize("seed", range(8))
@pytest.mark.parametrize("chunk", [1, 7, 10**6])
def test_store_induced_matches_csr_path(seed, chunk):
    """induced_subgraph_from_store == induced_subgraph(csr, mask), with
    duplicates and self-loops in the stream, at every chunk size."""
    rng = np.random.default_rng(100 + seed)
    n = int(rng.integers(2, 40))
    m = int(rng.integers(0, 5 * n))
    src = rng.integers(0, n, size=m)
    dst = rng.integers(0, n, size=m)
    if m >= 4:
        src[0] = dst[0] = 0
        src[1], dst[1] = src[2], dst[2]
    mask = rng.random(n) < 0.6
    with EdgeStore() as store:
        for i in range(0, m, chunk):
            store.append(src[i : i + chunk], dst[i : i + chunk])
        full, _ = csr_from_edge_store(store, n, chunk_edges=chunk)
        ref_sub, ref_ids = induced_subgraph(full, mask)
        got, ids, stats = induced_subgraph_from_store(store, mask, n, chunk_edges=chunk)
        assert_same_graph(got, ref_sub)
        np.testing.assert_array_equal(ids, ref_ids)
        # Divide planning from the store: superset of the CSR-path mask.
        ext = np.zeros(n, np.int32)
        rough_store = rough_candidates_from_store(store, n, ext, 3)
        rough_csr = rough_candidates(full.degrees, ext, 3)
        assert (rough_store | ~rough_csr).all()  # csr mask -> store mask


def test_store_rough_equals_csr_without_duplicates(rmat_graph):
    """No duplicate edges in the stream => dup degrees are exact and the
    store-side Rough-Divide equals the CSR one bit for bit."""
    from repro.graph.io import graph_edge_chunks

    n = rmat_graph.n_nodes
    ext = np.zeros(n, np.int32)
    with EdgeStore() as store:
        for src, dst in graph_edge_chunks(rmat_graph, 4096):
            store.append(src, dst)
        for t in (2, 8, 32):
            np.testing.assert_array_equal(
                rough_candidates_from_store(store, n, ext, t),
                rough_candidates(rmat_graph.degrees, ext, t),
            )
        # First-part extraction without the full CSR ever resident: equals
        # the CSR-path part exactly (mask equality just proved).
        mask = rough_candidates_from_store(store, n, ext, 8)
        got, ids, _ = induced_subgraph_from_store(store, mask, n, chunk_edges=4096)
        ref_sub, ref_ids = induced_subgraph(rmat_graph, mask)
        assert_same_graph(got, ref_sub)
        np.testing.assert_array_equal(ids, ref_ids)


# --------------------------------------------------------------------- #
# Transient bounds (mirrors test_stream_ingest's bound checks)
# --------------------------------------------------------------------- #
def test_divide_transient_bounded_by_chunk_not_edges(rmat_graph):
    """Peak transient < dense baseline, and shrinking the chunk shrinks the
    peak — the bound tracks the chunk budget, not the edge count."""
    rng = np.random.default_rng(2)
    keep = rng.random(rmat_graph.n_nodes) < 0.6
    peaks = {}
    for chunk in (1 << 10, 1 << 14):
        st = DivideStats(chunk_slots=chunk)
        induced_subgraph(rmat_graph, keep, chunk_slots=chunk, stats=st)
        external_info(rmat_graph, keep, ~keep, chunk_slots=chunk, stats=st)
        assert st.input_slots == 2 * 2 * rmat_graph.n_edges  # both passes
        assert st.peak_transient_bytes < st.baseline_transient_bytes
        peaks[chunk] = st.peak_transient_bytes
    assert peaks[1 << 10] < peaks[1 << 14]


def test_exact_candidates_transient_bounded(rmat_graph):
    ext = np.zeros(rmat_graph.n_nodes, np.int32)
    peaks = {}
    for chunk in (1 << 9, 1 << 13):
        st = DivideStats(chunk_slots=chunk)
        exact_candidates(rmat_graph, ext, 8, chunk_slots=chunk, stats=st)
        assert st.peak_transient_bytes < st.baseline_transient_bytes
        peaks[chunk] = st.peak_transient_bytes
    assert peaks[1 << 9] < peaks[1 << 13]


# --------------------------------------------------------------------- #
# Pipeline-level bit-identity of the divide_chunk knob
# --------------------------------------------------------------------- #
def test_dc_kcore_divide_chunk_byte_identical(rmat_graph):
    base, base_rep = dc_kcore(rmat_graph, thresholds=(16, 8))
    for chunk in (97, 1 << 12):
        core, rep = dc_kcore(rmat_graph, thresholds=(16, 8), divide_chunk=chunk)
        np.testing.assert_array_equal(core, base)
        assert core.dtype == base.dtype
        assert [p.name for p in rep.parts] == [p.name for p in base_rep.parts]
        assert all(p.divide_transient_bytes > 0 for p in rep.parts
                   if p.threshold is not None)
    np.testing.assert_array_equal(base, peel_coreness(rmat_graph))


def test_dc_kcore_exact_strategy_chunked(rmat_graph):
    base, _ = dc_kcore(rmat_graph, thresholds=(12,), strategy="exact")
    core, _ = dc_kcore(rmat_graph, thresholds=(12,), strategy="exact",
                       divide_chunk=101)
    np.testing.assert_array_equal(core, base)
    np.testing.assert_array_equal(core, peel_coreness(rmat_graph))


# --------------------------------------------------------------------- #
# Hypothesis properties (seeded ports above keep coverage when absent)
# --------------------------------------------------------------------- #
if HAVE_HYPOTHESIS:

    @st.composite
    def graph_mask_chunk(draw):
        n = draw(st.integers(min_value=1, max_value=36))
        m = draw(st.integers(min_value=0, max_value=4 * n))
        seed = draw(st.integers(min_value=0, max_value=2**31 - 1))
        rng = np.random.default_rng(seed)
        src = rng.integers(0, n, size=m)
        dst = rng.integers(0, n, size=m)
        g = Graph.from_edges(src, dst, n_nodes=n)
        keep = rng.random(n) < draw(st.floats(min_value=0.0, max_value=1.0))
        upper = ~keep & (rng.random(n) < 0.5)
        ext = rng.integers(0, 5, size=n).astype(np.int32)
        t = draw(st.integers(min_value=1, max_value=10))
        chunk = draw(
            st.one_of(
                st.integers(min_value=1, max_value=max(1, 2 * m + 1)),
                st.just(10**9),
                st.none(),
            )
        )
        return g, keep, upper, ext, t, chunk

    @st.composite
    def heavy_tailed_case(draw):
        scale = draw(st.integers(min_value=5, max_value=9))
        ef = draw(st.integers(min_value=2, max_value=8))
        seed = draw(st.integers(min_value=0, max_value=2**31 - 1))
        g = rmat(scale, ef, seed=seed)
        rng = np.random.default_rng(seed)
        keep = rng.random(g.n_nodes) < 0.6
        upper = ~keep & (rng.random(g.n_nodes) < 0.5)
        ext = rng.integers(0, 4, g.n_nodes).astype(np.int32)
        t = draw(st.integers(min_value=1, max_value=12))
        chunk = draw(st.one_of(
            st.integers(min_value=1, max_value=4 * g.n_edges + 1), st.none()
        ))
        return g, keep, upper, ext, t, chunk

    @given(data=graph_mask_chunk())
    @settings(max_examples=80, deadline=None)
    def test_chunked_divide_bit_identical_property(data):
        g, keep, upper, ext, t, chunk = data
        check_all_equivalences(g, keep, upper, ext, t, chunk)

    @given(data=heavy_tailed_case())
    @settings(max_examples=20, deadline=None)
    def test_chunked_divide_heavy_tailed_property(data):
        g, keep, upper, ext, t, chunk = data
        check_all_equivalences(g, keep, upper, ext, t, chunk)

    @given(
        seed=st.integers(min_value=0, max_value=2**31 - 1),
        chunk=st.integers(min_value=1, max_value=64),
    )
    @settings(max_examples=40, deadline=None)
    def test_store_induced_matches_csr_path_property(seed, chunk):
        rng = np.random.default_rng(seed)
        n = int(rng.integers(2, 32))
        m = int(rng.integers(0, 4 * n))
        src = rng.integers(0, n, size=m)
        dst = rng.integers(0, n, size=m)
        mask = rng.random(n) < 0.6
        with EdgeStore() as store:
            for i in range(0, m, chunk):
                store.append(src[i : i + chunk], dst[i : i + chunk])
            full, _ = csr_from_edge_store(store, n, chunk_edges=chunk)
            ref_sub, ref_ids = induced_subgraph(full, mask)
            got, ids, _ = induced_subgraph_from_store(
                store, mask, n, chunk_edges=chunk
            )
            assert_same_graph(got, ref_sub)
            np.testing.assert_array_equal(ids, ref_ids)
