"""Multi-step decode: ring-buffer window caches stay exact across many
steps (positions wrap the window several times), and greedy generation
matches teacher-forced argmax for a sliding-window arch."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke_config
from repro.models.model import build_specs, decode_step, forward, prefill
from repro.models.module import init_params
from repro.runtime import greedy_generate


def test_multistep_decode_parity_sliding_window():
    cfg = get_smoke_config("gemma3-27b")  # window 8, 5 local : 1 global
    params = init_params(build_specs(cfg), jax.random.PRNGKey(0))
    B, S, N = 2, 24, 12  # decode 12 steps => window wraps multiple times
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, S + N), 0, cfg.vocab_size)
    _, caches = prefill(params, tokens[:, :S], cfg, max_len=S + N)
    full, _, _ = forward(params, tokens, cfg)
    for t in range(N):
        pos = jnp.full((B,), S + t, jnp.int32)
        lg, caches = decode_step(params, caches, tokens[:, S + t : S + t + 1], pos, cfg)
        np.testing.assert_allclose(
            np.asarray(lg[:, 0]), np.asarray(full[:, S + t]), atol=2e-3, rtol=1e-3,
            err_msg=f"step {t}",
        )


def test_greedy_generation_matches_teacher_forcing():
    cfg = get_smoke_config("qwen3-8b")
    params = init_params(build_specs(cfg), jax.random.PRNGKey(3))
    prompt = jax.random.randint(jax.random.PRNGKey(4), (2, 10), 0, cfg.vocab_size)
    n_new = 6
    out = greedy_generate(params, prompt, cfg, n_new, jit=False)
    seq = prompt
    vmask = None
    for t in range(n_new):
        logits, _, _ = forward(params, seq, cfg)
        if vmask is None:
            vmask = jnp.arange(logits.shape[-1]) < cfg.vocab_size
        nxt = jnp.argmax(jnp.where(vmask, logits[:, -1], -jnp.inf), axis=-1)
        np.testing.assert_array_equal(np.asarray(out[:, t]), np.asarray(nxt))
        seq = jnp.concatenate([seq, nxt[:, None].astype(seq.dtype)], axis=1)


def test_ssm_multistep_decode_parity():
    cfg = get_smoke_config("mamba2-130m")
    params = init_params(build_specs(cfg), jax.random.PRNGKey(5))
    B, S, N = 2, 20, 8
    tokens = jax.random.randint(jax.random.PRNGKey(6), (B, S + N), 0, cfg.vocab_size)
    _, caches = prefill(params, tokens[:, :S], cfg, max_len=S + N)
    full, _, _ = forward(params, tokens, cfg)
    for t in range(N):
        pos = jnp.full((B,), S + t, jnp.int32)
        lg, caches = decode_step(params, caches, tokens[:, S + t : S + t + 1], pos, cfg)
        np.testing.assert_allclose(
            np.asarray(lg[:, 0]), np.asarray(full[:, S + t]), atol=5e-3, rtol=2e-3,
            err_msg=f"step {t}",
        )
