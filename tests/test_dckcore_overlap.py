"""Overlapped (staged-pipeline) DC-kCore == sequential, byte for byte.

``overlap=True`` moves the divide passes, the next part's bucketize and
the checkpoint saves off the critical path — speculatively for the divide
(the worker bets every candidate of the conquering part finalizes). These
tests pin the contract that makes that safe: the flag changes wall-clock
only, never a byte of coreness, on every fixture, strategy, reorder and
threshold plan; Exact-Divide speculation always validates (it finalizes
all candidates by construction); a Rough-Divide miss degrades to the
sequential recompute, not to a wrong answer.
"""
import numpy as np
import pytest

from repro.core.dckcore import MergeIncompleteError, dc_kcore
from repro.graph.oracle import peel_coreness

THRESHOLDS = (4, 12)


def _run_both(g, **kw):
    core_seq, rep_seq = dc_kcore(g, overlap=False, **kw)
    core_ov, rep_ov = dc_kcore(g, overlap=True, **kw)
    np.testing.assert_array_equal(core_seq, core_ov)
    assert rep_seq.overlap is False and rep_ov.overlap is True
    assert rep_seq.prefetch_hits == rep_seq.prefetch_misses == 0
    return core_ov, rep_ov


@pytest.mark.parametrize("strategy", ["rough", "exact"])
def test_overlap_identical_ba(ba_graph, strategy):
    core, _ = _run_both(ba_graph, thresholds=THRESHOLDS, strategy=strategy)
    np.testing.assert_array_equal(core, peel_coreness(ba_graph))


@pytest.mark.parametrize("strategy", ["rough", "exact"])
def test_overlap_identical_rmat(rmat_graph, strategy):
    core, rep = _run_both(
        rmat_graph, thresholds=(3, 8, 16), strategy=strategy
    )
    np.testing.assert_array_equal(core, peel_coreness(rmat_graph))
    # Every threshold part that ran submitted a speculation; each either
    # hit or missed — none may be silently dropped.
    submitted = sum(1 for p in rep.parts if p.threshold is not None)
    assert rep.prefetch_hits + rep.prefetch_misses == submitted


@pytest.mark.parametrize("strategy", ["rough", "exact"])
def test_overlap_identical_er(er_graph, strategy):
    _run_both(er_graph, thresholds=THRESHOLDS, strategy=strategy)


def test_overlap_identical_with_reorder(rmat_graph):
    _run_both(
        rmat_graph, thresholds=THRESHOLDS, strategy="rough", reorder="bfs"
    )


def test_overlap_monolithic_baseline(er_graph):
    """No thresholds = one rest part = nothing to prefetch; the flag must
    still be a no-op for correctness."""
    core, rep = _run_both(er_graph, thresholds=())
    np.testing.assert_array_equal(core, peel_coreness(er_graph))
    assert rep.prefetch_hits == rep.prefetch_misses == 0


def test_exact_divide_speculation_always_hits(rmat_graph):
    """Exact-Divide finalizes every candidate by construction, so the
    prefetch worker's bet can never miss — and the parts that follow a
    hit arrive with their divide already done (prefetched=True)."""
    _, rep = dc_kcore(
        rmat_graph, thresholds=(3, 8, 16), strategy="exact", overlap=True
    )
    assert rep.prefetch_misses == 0
    assert rep.prefetch_hits >= 1
    ran = [p for p in rep.parts]
    # The first part is always divided synchronously; every later part
    # follows a hit and must have been prefetched.
    assert not ran[0].prefetched
    assert all(p.prefetched for p in ran[1:])


def test_overlap_empty_thresholds_in_plan(ba_graph):
    """Thresholds above the max coreness yield empty parts mid-plan; the
    scheduler must consume their cursors identically in both modes."""
    _run_both(ba_graph, thresholds=(100, 4), strategy="exact")


def test_merge_gate_is_a_real_exception():
    """The final all-finalized gate must survive ``python -O`` — it is an
    exception type, not a bare assert."""
    assert issubclass(MergeIncompleteError, RuntimeError)
