"""Run snippets in subprocesses with N virtual XLA host devices.

The main pytest process must keep a single CPU device (smoke tests / benches
depend on it), so every multi-device test spawns a fresh interpreter with
``--xla_force_host_platform_device_count=N``. Two entry points:

* :func:`run_with_devices` — one blocking child, raise on nonzero exit
  (the original helper; every call site keeps working unchanged).
* :class:`WorkerHarness` — spawn several children concurrently (the
  multi-process part-parallel tests run one child per mesh slice), join
  them all, and fail with every child's stdout/stderr embedded in the
  assertion. Children get deterministic seeds (``PYTHONHASHSEED=0``) and
  their rank/world exported as ``REPRO_RANK`` / ``REPRO_WORLD``.

``preamble(n)`` is the shared import block for child snippets — the same
text the distributed suite used to inline as ``_COMMON``, parameterized
by the asserted device count.
"""
import os
import subprocess
import sys
from typing import Dict, List, Optional

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def preamble(n_devices: int) -> str:
    """Shared import block for multi-device child snippets: the engine
    surface under test plus an assertion that the forced device count
    actually took (a silent 1-device fallback would make every
    differential test vacuously pass)."""
    return rf"""
import jax, numpy as np
import jax.numpy as jnp
from repro.core.distributed import MeshPlan, decompose_distributed, make_distributed_decompose, sweep_collective_bytes
from repro.core.dckcore import dc_kcore
from repro.graph.build import bucketize
from repro.graph.generators import rmat, erdos_renyi
from repro.graph.oracle import peel_coreness
assert len(jax.devices()) == {int(n_devices)}, jax.devices()
"""


def _child_env(n_devices: int, extra_env: Optional[Dict[str, str]] = None):
    env = dict(os.environ)
    kept = [
        t for t in env.get("XLA_FLAGS", "").split()
        if not t.startswith("--xla_force_host_platform_device_count")
    ]
    kept.append(f"--xla_force_host_platform_device_count={int(n_devices)}")
    env["XLA_FLAGS"] = " ".join(kept)
    env["PYTHONPATH"] = (
        os.path.join(REPO, "src") + os.pathsep + env.get("PYTHONPATH", "")
    )
    env["PYTHONHASHSEED"] = "0"
    if extra_env:
        env.update({k: str(v) for k, v in extra_env.items()})
    return env


def run_with_devices(code: str, n_devices: int, timeout: int = 600) -> str:
    proc = subprocess.run(
        [sys.executable, "-c", code],
        capture_output=True,
        text=True,
        env=_child_env(n_devices),
        timeout=timeout,
        cwd=REPO,
    )
    if proc.returncode != 0:
        raise AssertionError(
            f"subprocess failed (rc={proc.returncode})\nSTDOUT:\n{proc.stdout}\nSTDERR:\n{proc.stderr}"
        )
    return proc.stdout


class WorkerHarness:
    """Spawn/join a fleet of child interpreters (one per mesh slice).

    Every spawned child is tracked; :meth:`join` reaps them all and raises
    one AssertionError embedding each failed child's rank, stdout and
    stderr (child tracebacks land in stderr, so they surface verbatim in
    the pytest failure). The ``worker_harness`` fixture calls
    :meth:`terminate_leaked` on teardown and fails the test if any child
    outlived the test body — the subprocess analogue of the pipeline
    thread-leak gate.
    """

    def __init__(self):
        self._procs: List[subprocess.Popen] = []
        self._meta: List[dict] = []

    def run(self, code: str, n_devices: int, timeout: int = 600) -> str:
        """Blocking single-child convenience — same contract as
        :func:`run_with_devices`."""
        return run_with_devices(code, n_devices, timeout=timeout)

    def spawn(
        self,
        code: str,
        n_devices: int,
        rank: int = 0,
        world: int = 1,
        extra_env: Optional[Dict[str, str]] = None,
    ) -> subprocess.Popen:
        env = _child_env(
            n_devices,
            {"REPRO_RANK": str(rank), "REPRO_WORLD": str(world),
             **(extra_env or {})},
        )
        proc = subprocess.Popen(
            [sys.executable, "-c", code],
            stdout=subprocess.PIPE,
            stderr=subprocess.PIPE,
            text=True,
            env=env,
            cwd=REPO,
        )
        self._procs.append(proc)
        self._meta.append({"rank": rank, "world": world})
        return proc

    def join(self, timeout: int = 600) -> List[str]:
        """Reap every spawned child; return their stdouts in spawn order.

        Raises a single AssertionError describing EVERY failed child (a
        multi-process deadlock usually kills several ranks at once — the
        first failure alone rarely names the culprit)."""
        outs, failures = [], []
        for proc, meta in zip(self._procs, self._meta):
            try:
                out, err = proc.communicate(timeout=timeout)
            except subprocess.TimeoutExpired:
                proc.kill()
                out, err = proc.communicate()
                failures.append(
                    f"rank {meta['rank']}/{meta['world']}: TIMEOUT after "
                    f"{timeout}s\nSTDOUT:\n{out}\nSTDERR:\n{err}"
                )
                outs.append(out)
                continue
            outs.append(out)
            if proc.returncode != 0:
                failures.append(
                    f"rank {meta['rank']}/{meta['world']}: rc={proc.returncode}"
                    f"\nSTDOUT:\n{out}\nSTDERR:\n{err}"
                )
        self._procs, self._meta = [], []
        if failures:
            raise AssertionError(
                f"{len(failures)} worker(s) failed:\n" + "\n---\n".join(failures)
            )
        return outs

    def leaked(self) -> List[subprocess.Popen]:
        return [p for p in self._procs if p.poll() is None]

    def terminate_leaked(self) -> List[int]:
        """Kill any still-running children; return their PIDs (the fixture
        turns a nonempty list into a test failure)."""
        pids = []
        for p in self.leaked():
            pids.append(p.pid)
            p.kill()
            try:
                p.communicate(timeout=30)
            except subprocess.TimeoutExpired:
                pass
        return pids
