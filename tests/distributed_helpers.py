"""Run snippets in a subprocess with N virtual XLA host devices.

The main pytest process must keep a single CPU device (smoke tests / benches
depend on it), so every multi-device test spawns a fresh interpreter with
``--xla_force_host_platform_device_count=N``."""
import os
import subprocess
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_with_devices(code: str, n_devices: int, timeout: int = 600) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = (
        f"--xla_force_host_platform_device_count={n_devices} "
        + env.get("XLA_FLAGS", "").replace(
            next((t for t in env.get("XLA_FLAGS", "").split() if "device_count" in t), ""), ""
        )
    ).strip()
    env["PYTHONPATH"] = os.path.join(REPO, "src") + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.run(
        [sys.executable, "-c", code],
        capture_output=True,
        text=True,
        env=env,
        timeout=timeout,
        cwd=REPO,
    )
    if proc.returncode != 0:
        raise AssertionError(
            f"subprocess failed (rc={proc.returncode})\nSTDOUT:\n{proc.stdout}\nSTDERR:\n{proc.stderr}"
        )
    return proc.stdout
