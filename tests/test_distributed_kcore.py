"""Distributed (shard_map) k-core engine tests — 8 virtual devices.

Each test runs in a subprocess so the main process keeps 1 CPU device."""
import pytest

from distributed_helpers import preamble, run_with_devices

_COMMON = preamble(8)


def test_distributed_matches_oracle_2d():
    out = run_with_devices(
        _COMMON
        + r"""
mesh = jax.make_mesh((4, 2), ("data", "model"))
plan = MeshPlan(mesh=mesh, node_axes=("data",), slot_axes=("model",))
g = rmat(10, 8, seed=3)
bg = bucketize(g)
res = decompose_distributed(bg, plan)
np.testing.assert_array_equal(res.coreness, peel_coreness(g))
assert res.comm_per_iter[-1] == 0
# Always-full-sweep baseline: same fixed point, no less work than frontier.
base = decompose_distributed(bg, plan, frontier=False)
np.testing.assert_array_equal(base.coreness, res.coreness)
assert len(res.active_rows_per_iter) == res.iterations
assert res.gathered_rows <= base.gathered_rows == base.full_sweep_rows
print("OK iterations=", res.iterations)
""",
        n_devices=8,
    )
    assert "OK" in out


def test_distributed_matches_oracle_3d_podaxis():
    out = run_with_devices(
        _COMMON
        + r"""
mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
plan = MeshPlan(mesh=mesh, node_axes=("pod", "data"), slot_axes=("model",))
g = erdos_renyi(700, 10.0, seed=1)
bg = bucketize(g)
res = decompose_distributed(bg, plan)
np.testing.assert_array_equal(res.coreness, peel_coreness(g))
print("OK")
""",
        n_devices=8,
    )
    assert "OK" in out


def test_distributed_int16_wire():
    out = run_with_devices(
        _COMMON
        + r"""
mesh = jax.make_mesh((8,), ("data",))
plan = MeshPlan(mesh=mesh, node_axes=("data",), slot_axes=())
g = rmat(10, 6, seed=5)
bg = bucketize(g)
res32 = decompose_distributed(bg, plan)
res16 = decompose_distributed(bg, plan, wire_dtype=jnp.int16)
np.testing.assert_array_equal(res16.coreness, res32.coreness)
np.testing.assert_array_equal(res16.coreness, peel_coreness(g))
print("OK")
""",
        n_devices=8,
    )
    assert "OK" in out


def test_dckcore_with_distributed_engine():
    """Full divide-and-conquer with the shard_map conquer engine."""
    out = run_with_devices(
        _COMMON
        + r"""
mesh = jax.make_mesh((4, 2), ("data", "model"))
plan = MeshPlan(mesh=mesh, node_axes=("data",), slot_axes=("model",))
g = rmat(10, 8, seed=11)
core, report = dc_kcore(g, thresholds=(4, 10), strategy="rough",
                        decompose_fn=make_distributed_decompose(plan))
np.testing.assert_array_equal(core, peel_coreness(g))
mono_core, mono = dc_kcore(g, thresholds=(), decompose_fn=make_distributed_decompose(plan))
np.testing.assert_array_equal(mono_core, peel_coreness(g))
# Paper claims: divided peak memory and communication both drop.
assert report.peak_bytes < mono.peak_bytes
print("comm", report.total_comm, mono.total_comm)
print("OK")
""",
        n_devices=8,
    )
    assert "OK" in out


def test_dckcore_distributed_midsweep_resume(tmp_path):
    """Sweep-granularity checkpointing through the shard_map engine: the
    on_sweep/init_coreness contract carries across decompose_fn, a run
    killed at a sweep boundary resumes mid-part byte-identically."""
    out = run_with_devices(
        _COMMON
        + rf"""
mesh = jax.make_mesh((4, 2), ("data", "model"))
plan = MeshPlan(mesh=mesh, node_axes=("data",), slot_axes=("model",))
g = rmat(10, 8, seed=11)
fn = make_distributed_decompose(plan)
base, _ = dc_kcore(g, thresholds=(4, 10), strategy="rough", decompose_fn=fn)
ck = {str(tmp_path / 'ck')!r}
class Crash(Exception): pass
calls = []
def killer(cursor, sweep, save_s):
    calls.append((cursor, sweep))
    if len(calls) == 2: raise Crash
try:
    dc_kcore(g, thresholds=(4, 10), strategy="rough", decompose_fn=fn,
             checkpoint_dir=ck, sweep_checkpoint_every=1, on_sweep_saved=killer)
    raise SystemExit("no crash")
except Crash:
    pass
core, rep = dc_kcore(g, thresholds=(4, 10), strategy="rough", decompose_fn=fn,
                     checkpoint_dir=ck, resume=True, sweep_checkpoint_every=1)
np.testing.assert_array_equal(core, base)
np.testing.assert_array_equal(core, peel_coreness(g))
assert any(p.resumed_at_sweep > 0 for p in rep.parts), [p.resumed_at_sweep for p in rep.parts]
print("OK")
""",
        n_devices=8,
    )
    assert "OK" in out


def test_collective_bytes_accounting():
    out = run_with_devices(
        _COMMON
        + r"""
mesh = jax.make_mesh((4, 2), ("data", "model"))
plan = MeshPlan(mesh=mesh, node_axes=("data",), slot_axes=("model",))
g = rmat(9, 8, seed=2)
bg = bucketize(g)
b = sweep_collective_bytes(bg, plan, cand=16)
assert b > 0
# int16 wire halves only the all-gather term.
b16 = sweep_collective_bytes(bg, plan, cand=16, wire_bytes=2)
assert b16 < b
# Frontier mask: quiescent buckets skip their collectives entirely.
act = np.zeros(len(bg.buckets), dtype=bool)
act[:2] = True
b_act = sweep_collective_bytes(bg, plan, cand=16, active=act)
assert 0 < b_act < b
assert sweep_collective_bytes(bg, plan, cand=16, active=~act) + b_act == b
print("OK", b, b16, b_act)
""",
        n_devices=8,
    )
    assert "OK" in out


def test_distributed_reorder_and_measured_bytes():
    """Reordered layout in the shard_map engine: coreness still comes back
    in original-id order, and the measured per-sweep collective counters
    track the frontier (first sweep == analytic full-sweep model + the
    dirty-bit psum the analytic model omits)."""
    out = run_with_devices(
        _COMMON
        + r"""
from repro.core.distributed import measured_sweep_bytes, shard_buckets
from repro.core.hindex import hindex_of_sequence
from repro.graph.reorder import reorder_graph
mesh = jax.make_mesh((4, 2), ("data", "model"))
plan = MeshPlan(mesh=mesh, node_axes=("data",), slot_axes=("model",))
g = rmat(10, 8, seed=3)
rg = reorder_graph(g, "rcm")
bg = bucketize(rg)
res = decompose_distributed(bg, plan)
np.testing.assert_array_equal(res.coreness, peel_coreness(g))
# Measured counters: one entry per sweep, all positive, non-increasing
# overall work as the frontier shrinks to quiescence.
assert len(res.collective_bytes_per_iter) == res.iterations
assert all(b > 0 for b in res.collective_bytes_per_iter)
assert res.collective_bytes == sum(res.collective_bytes_per_iter)
# First sweep is a full sweep: measured == analytic + the two terms the
# analytic model omits — the per-bucket int32 ids all_gather and the
# [n_buckets] dirty-bit psum (2*(k-1)/k ring over the 8-device mesh).
cand = max(1, hindex_of_sequence(bg.degrees.astype(np.int64) + bg.ext))
analytic = sweep_collective_bytes(bg, plan, cand=cand)
ns = plan.n_node_shards
ids_gather = sum((ns - 1) * (-(-b.n_rows // ns)) * 4 for b in bg.buckets)
dirty_psum = int(2 * (8 - 1) / 8 * len(bg.buckets) * 4)
assert res.collective_bytes_per_iter[0] == analytic + ids_gather + dirty_psum
# Frontier shrinks => later sweeps move fewer bytes than the first.
assert res.collective_bytes_per_iter[-1] < res.collective_bytes_per_iter[0]
# The baseline (frontier off) repeats the full sweep every time (no dirty
# psum, ids gather still issued).
base = decompose_distributed(bg, plan, frontier=False)
assert all(b == analytic + ids_gather for b in base.collective_bytes_per_iter)
assert res.collective_bytes < base.collective_bytes
print("OK", res.collective_bytes, base.collective_bytes)
""",
        n_devices=8,
    )
    assert "OK" in out


@pytest.mark.parametrize(
    "shape,axes,node_axes",
    [
        # The global plans --devices can build, plus every slice shape the
        # part-parallel scheduler emits from them on 8 devices: slicing
        # (4,2)/(8,) into 2 slices gives (2,2)/(4,); into 4 gives (1,2)/(2,).
        # The measured-vs-modeled pin must hold on ALL of them — the
        # scheduler prices parts per slice with this exact formula.
        ((4, 2), ("data", "model"), ("data",)),
        ((8,), ("data",), ("data",)),
        ((2, 2), ("data", "model"), ("data",)),
        ((4,), ("data",), ("data",)),
        ((1, 2), ("data", "model"), ("data",)),
        ((2,), ("data",), ("data",)),
    ],
)
def test_planned_schedule_pins_measured_bytes(shape, axes, node_axes):
    """The dry-run's planned collective schedule against one measured run:
    on a frontier=False run every sweep is full, the planned schedule is
    exact, and the model must reproduce the live engine's per-iteration
    counter byte for byte. On a frontier run only sweep 0 is guaranteed
    full — the default decayed schedule must pin exactly that iteration,
    and its modeled tail must decay monotonically toward the densest-class
    floor. Parametrized over every mesh shape the part-parallel scheduler
    can emit (global plans and their slices) so the scheduler's cost model
    stays pinned to the live counters on the exact layouts it prices."""
    out = run_with_devices(
        _COMMON
        + rf"""
from repro.core.distributed import planned_collective_schedule
from repro.core.hindex import hindex_of_sequence
mesh = jax.make_mesh({shape!r}, {axes!r})
plan = MeshPlan(mesh=mesh, node_axes={node_axes!r}, slot_axes=tuple(a for a in {axes!r} if a == "model"))
g = rmat(9, 8, seed=2)
bg = bucketize(g)
cand = max(1, hindex_of_sequence(bg.degrees.astype(np.int64) + bg.ext))
rows = [b.n_rows for b in bg.buckets]
# frontier=False: every sweep full, no dirty psum — model is exact per iter.
base = decompose_distributed(bg, plan, frontier=False)
sched = planned_collective_schedule(rows, plan, cand,
                                    n_iters=base.iterations,
                                    full_sweeps=base.iterations,
                                    frontier=False)
assert sched == list(base.collective_bytes_per_iter), (
    sched, base.collective_bytes_per_iter)
# frontier run: the default decayed schedule pins the guaranteed-full
# first sweep (ids all_gather + dirty psum included).
res = decompose_distributed(bg, plan)
dflt = planned_collective_schedule(rows, plan, cand, n_iters=12)
assert dflt[0] == res.collective_bytes_per_iter[0], (
    dflt[0], res.collective_bytes_per_iter[0])
# Modeled tail: monotone non-increasing, strictly below a full sweep once
# the geometric decay has concentrated the frontier in the dense classes.
assert all(a >= b for a, b in zip(dflt, dflt[1:]))
assert dflt[-1] < dflt[0]
# int16 wire shrinks every planned iteration (the estimate all_gather
# term) — except on single-node-shard slices, where no estimate is ever
# gathered over the node axis and the wire dtype must be a no-op.
d16 = planned_collective_schedule(rows, plan, cand, n_iters=12, wire_bytes=2)
if plan.n_node_shards > 1:
    assert all(a < b for a, b in zip(d16, dflt))
else:
    assert d16 == dflt
print("OK")
""",
        n_devices=8,
    )
    assert "OK" in out


def test_distributed_with_pallas_counts_kernel():
    """Distributed sweep with the Pallas partial-counts kernel == oracle."""
    out = run_with_devices(
        _COMMON
        + r"""
mesh = jax.make_mesh((2, 2), ("data", "model"))
plan = MeshPlan(mesh=mesh, node_axes=("data",), slot_axes=("model",))
g = rmat(9, 8, seed=13)
bg = bucketize(g)
res = decompose_distributed(bg, plan, use_kernel=True)
np.testing.assert_array_equal(res.coreness, peel_coreness(g))
print("OK")
""",
        n_devices=8,
    )
    assert "OK" in out
