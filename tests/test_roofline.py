"""Roofline machinery validation.

1. loop_multipliers recovers scan trip counts from compiled HLO.
2. parse_collectives multiplies collectives inside scan bodies.
3. The analytic FLOPs model matches XLA's count on a no-loop (single-layer,
   full-attention, unrolled) config — the basis for using the analytic model
   on scanned stacks where XLA's count is loop-blind (verified 8x off).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from distributed_helpers import run_with_devices
from repro.compat import cost_analysis_dict
from repro.configs.base import ModelConfig, SHAPES, ShapeConfig
from repro.models.model import build_specs, forward
from repro.models.module import count_params, init_params
from repro.roofline import flops_model
from repro.roofline.analysis import loop_multipliers, parse_collectives, split_computations


def test_loop_multipliers_scan():
    def body(x, w):
        return jnp.tanh(x @ w), None

    def f(x, ws):
        y, _ = jax.lax.scan(body, x, ws)
        return y.sum()

    x = jax.ShapeDtypeStruct((16, 64), jnp.float32)
    ws = jax.ShapeDtypeStruct((7, 64, 64), jnp.float32)
    txt = jax.jit(f).lower(x, ws).compile().as_text()
    mult = loop_multipliers(txt)
    assert max(mult.values()) >= 7.0  # forward (and backward-less) body x7


def test_collectives_loop_corrected():
    out = run_with_devices(
        r"""
import jax, jax.numpy as jnp, json
from jax.sharding import PartitionSpec as P, NamedSharding
from repro.compat import make_mesh
from repro.roofline.analysis import parse_collectives
mesh = make_mesh((4, 2), ("data", "model"))
def body(x, w):
    return jnp.tanh(x @ w), None
def f(x, ws):
    y, _ = jax.lax.scan(body, x, ws)
    return y
xs = jax.ShapeDtypeStruct((64, 256), jnp.float32, sharding=NamedSharding(mesh, P("data", "model")))
ws = jax.ShapeDtypeStruct((6, 256, 256), jnp.float32, sharding=NamedSharding(mesh, P(None, "model", None)))
txt = jax.jit(f).lower(xs, ws).compile().as_text()
stats = parse_collectives(txt)
# one all-reduce per scan step (contraction over model-sharded dim) = 6 total
print("COUNT", stats.count.get("all-reduce", 0))
""",
        n_devices=8,
    )
    count = int(out.strip().split("COUNT")[-1])
    assert count >= 6


def _tiny_cfg():
    return ModelConfig(
        name="probe", family="dense", n_layers=1, d_model=256, n_heads=4,
        n_kv_heads=4, head_dim=64, d_ff=1024, vocab_size=4096,
        dtype=jnp.float32, param_dtype=jnp.float32,
        attention_impl="full", tie_embeddings=True,
    )


def test_analytic_flops_matches_hlo_unrolled():
    cfg = _tiny_cfg()
    shape = ShapeConfig("probe", "prefill", seq_len=512, global_batch=4)
    specs = build_specs(cfg)
    params_abs = jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, s.dtype), specs,
        is_leaf=lambda x: hasattr(x, "axes") and hasattr(x, "shape"),
    )
    tokens = jax.ShapeDtypeStruct((4, 512), jnp.int32)

    def fwd(p, t):
        logits, _, _ = forward(p, t, cfg)
        return logits

    compiled = jax.jit(fwd).lower(params_abs, tokens).compile()
    hlo_flops = float(cost_analysis_dict(compiled)["flops"])
    analytic = flops_model.cost(cfg, shape, count_params(specs), n_chips=1).flops_total
    # n_layers=1 => the stack scan has trip count 1, so HLO is loop-exact
    # here; softmax/norm flops make HLO slightly larger.
    assert hlo_flops == pytest.approx(analytic, rel=0.15), (hlo_flops, analytic)


def test_memory_model_sane():
    from repro.configs import get_config

    cfg = get_config("grok-1-314b")
    shape = SHAPES["train_4k"]
    n = 316_489_340_928
    m = flops_model.device_memory_model(cfg, shape, n, n_chips=256, dp=16, accum_steps=16)
    assert m["params"] == pytest.approx(n * 2 / 256)
    assert 0 < m["total"] < 16 * 2**30  # grok fits by design choices
    # decode: KV cache dominates params for gemma3 decode_32k
    cfg2 = get_config("gemma3-27b")
    m2 = flops_model.device_memory_model(cfg2, SHAPES["decode_32k"], 28_000_000_000, 256, 16)
    assert m2["kv_cache"] > 0
