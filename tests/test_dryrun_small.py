"""Dry-run machinery on a small virtual mesh (subprocess; fast CI proxy for
the 512-chip sweep — the full sweep is `python -m repro.launch.dryrun --all
--both-meshes` and its artifacts live in benchmarks/artifacts/dryrun)."""
import pytest

from distributed_helpers import run_with_devices

_CODE = r"""
import jax, json
from repro.compat import cost_analysis_dict, make_mesh
from repro.launch.specs import input_specs, rules_for
from repro.launch.steps import step_fn_for
from repro.sharding.policy import active_mesh
from repro.configs import SHAPES
from repro.roofline.analysis import parse_collectives

mesh = make_mesh((4, 2), ("data", "model"))
arch, shape_name = "%ARCH%", "%SHAPE%"
specs, cfg, log = input_specs(arch, shape_name, mesh)
kind = SHAPES[shape_name].kind
fn, order = step_fn_for(cfg, kind, accum_steps=2 if kind == "train" else 1)
kwargs = {k: specs[k] for k in order}
with mesh, active_mesh(mesh):
    lowered = jax.jit(fn).lower(**kwargs)
    compiled = lowered.compile()
mem = compiled.memory_analysis()
cost = cost_analysis_dict(compiled)
colls = parse_collectives(compiled.as_text())
assert cost["flops"] > 0
assert mem.temp_size_in_bytes >= 0
print("OK", arch, shape_name, int(cost["flops"]), colls.total_wire)
"""


@pytest.mark.parametrize(
    "arch,shape",
    [
        ("granite-3-2b", "train_4k"),
        ("qwen2-moe-a2.7b", "prefill_32k"),
        ("mamba2-130m", "decode_32k"),
        ("whisper-small", "decode_32k"),
    ],
)
def test_dryrun_cell_small_mesh(arch, shape):
    out = run_with_devices(
        _CODE.replace("%ARCH%", arch).replace("%SHAPE%", shape), n_devices=8,
        timeout=900,
    )
    assert "OK" in out
