"""Property-based tests for the Divide step (hypothesis).

Pinned properties (paper Section 4.2 + the resource planner):

  * `exact_candidates` == an independent scalar peeling oracle for the
    generalized t-core with external information (Definition 3 analog).
  * `rough_candidates` is always a superset of `exact_candidates`.
  * `plan_thresholds` emits strictly decreasing thresholds > 1, at most
    `max_parts - 1` of them, and never plans a part whose padded edge
    estimate exceeds the budget — except the unavoidable case of a part
    that is a single equal-degree run (indivisible by a degree threshold).

Seeded (hypothesis-free) ports of the same properties — plus the
duplicate-threshold regression — live in tests/test_kcore_properties.py so
the invariants stay covered when hypothesis is absent.
"""
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis",
    reason="hypothesis not installed; seeded ports of the divide properties "
    "run in tests/test_kcore_properties.py",
)
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.divide import (  # noqa: E402
    exact_candidates,
    plan_thresholds,
    rough_candidates,
)
from repro.graph.structs import Graph  # noqa: E402


def tcore_oracle(g: Graph, ext: np.ndarray, t: int) -> np.ndarray:
    """Scalar peeling oracle for the generalized t-core: repeatedly delete
    any node with deg_alive(v) + ext(v) < t (ext neighbors behave as
    infinite-coreness, Corollary 1 analog)."""
    alive = np.ones(g.n_nodes, dtype=bool)
    while True:
        removed = False
        for v in range(g.n_nodes):
            if not alive[v]:
                continue
            d = int(alive[g.neighbors(v)].sum()) + int(ext[v])
            if d < t:
                alive[v] = False
                removed = True
        if not removed:
            return alive


@st.composite
def graph_ext_t(draw):
    n = draw(st.integers(min_value=1, max_value=28))
    m = draw(st.integers(min_value=0, max_value=3 * n))
    seed = draw(st.integers(min_value=0, max_value=2**31 - 1))
    rng = np.random.default_rng(seed)
    g = Graph.from_edges(
        rng.integers(0, n, size=m), rng.integers(0, n, size=m), n_nodes=n
    )
    ext = rng.integers(0, 5, size=n).astype(np.int32)
    t = draw(st.integers(min_value=1, max_value=10))
    return g, ext, t


@given(data=graph_ext_t())
@settings(max_examples=120, deadline=None)
def test_exact_candidates_is_generalized_tcore(data):
    g, ext, t = data
    np.testing.assert_array_equal(exact_candidates(g, ext, t), tcore_oracle(g, ext, t))


@given(data=graph_ext_t())
@settings(max_examples=120, deadline=None)
def test_rough_is_superset_of_exact(data):
    g, ext, t = data
    rough = rough_candidates(g.degrees, ext, t)
    exact = exact_candidates(g, ext, t)
    assert (rough | ~exact).all()  # exact -> rough


def planned_part_estimates(deg: np.ndarray, thresholds, bytes_per_edge: int):
    """(estimate_bytes, degree_span) of every *planned* part — nodes with
    deg >= t_k below the previous cut; the implicit 'rest' is not planned."""
    deg = np.sort(np.asarray(deg, dtype=np.int64))[::-1]
    out = []
    hi = np.inf
    for t in thresholds:
        sel = deg[(deg >= t) & (deg < hi)]
        out.append((int(sel.sum()) * bytes_per_edge, int(sel.max() - sel.min()) if sel.size else 0))
        hi = t
    return out


@given(
    degs=st.lists(st.integers(min_value=0, max_value=60), min_size=1, max_size=120),
    budget=st.integers(min_value=1, max_value=4000),
    max_parts=st.integers(min_value=2, max_value=8),
)
@settings(max_examples=200, deadline=None)
def test_plan_thresholds_respects_budget(degs, budget, max_parts):
    deg = np.array(degs, dtype=np.int64)
    bpe = 8
    ts = plan_thresholds(deg, budget, max_parts=max_parts, bytes_per_edge=bpe)
    assert all(t > 1 for t in ts)
    assert all(a > b for a, b in zip(ts, ts[1:]))  # strictly decreasing
    assert len(ts) <= max_parts - 1
    if int(deg.sum()) * bpe <= budget:
        assert ts == []
    elif (deg > 1).any():
        # Division was needed and possible: the planner must divide.
        assert ts != []
    for est, span in planned_part_estimates(deg, ts, bpe):
        # Within budget, or a single indivisible equal-degree run.
        assert est <= budget or span == 0


def greedy_run_packing(deg, budget, max_parts, bpe):
    """Independent reference: pack descending equal-degree runs greedily;
    cut before the run that would overflow a non-empty part. This is what
    the planner must compute — the old duplicate-degree early-`break`
    truncated it."""
    values, counts = np.unique(np.asarray(deg, dtype=np.int64), return_counts=True)
    runs = [(int(v), int(v) * int(c) * bpe) for v, c in zip(values[::-1], counts[::-1])]
    if sum(b for _, b in runs) <= budget:
        return []
    ts, acc, prev = [], 0, None
    for v, b in runs:
        if v <= 1:
            break
        if acc > 0 and acc + b > budget:
            ts.append(prev)
            acc = 0
            if len(ts) >= max_parts - 1:
                break
        acc += b
        prev = v
    if (acc > 0 and prev is not None and prev > 1
            and len(ts) < max_parts - 1 and (not ts or prev < ts[-1])):
        ts.append(prev)  # close the trailing group off the deg<=1 rest
    return ts


@given(
    degs=st.lists(st.integers(min_value=0, max_value=40), min_size=2, max_size=80),
    budget=st.integers(min_value=16, max_value=2000),
    max_parts=st.integers(min_value=2, max_value=8),
)
@settings(max_examples=200, deadline=None)
def test_plan_thresholds_survives_duplicate_runs(degs, budget, max_parts):
    """Regression shape for the old early-`break`: heavy duplicate runs must
    not terminate planning early — the plan equals greedy run-packing."""
    deg = np.repeat(np.array(degs, dtype=np.int64), 3)  # force duplicates
    ts = plan_thresholds(deg, budget, max_parts=max_parts, bytes_per_edge=8)
    assert len(set(ts)) == len(ts)  # no duplicate thresholds, ever
    assert ts == greedy_run_packing(deg, budget, max_parts, 8)
