"""Differential harness for the fused Pallas sweep engine.

``engine="fused"`` is only shippable if it is indistinguishable from the
engines we already trust, so every test here is differential: the fused
kernel (interpret mode) vs the sorted/count/kernel engines and the
pure-jnp kernel reference, asserting bit-identical coreness, per-sweep
changed counts, and dirty bits — across tile sizes (including tile=1 and
tile > rows), Gauss-Seidel and Jacobi, frontier on/off, the cond and
compaction dispatch modes, snapshot/resume, reordered layouts, and the
opt-in int16 estimate mode with its overflow guard.

Deterministic seeded sweeps run unconditionally (the repo's seeded-port
convention); the hypothesis fuzz section at the bottom skips cleanly when
hypothesis is not installed.

Trajectory contract (see core/decompose.py): the cond dispatch is
bit-identical to the unfused engines SWEEP BY SWEEP; the compaction
dispatch is sweep-identical under Jacobi reads, and under Gauss-Seidel
matches the final fixed point (unique and exact) while within-group reads
are Jacobi — both cases are pinned below exactly as specified.
"""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core.decompose import decompose
from repro.core.dckcore import dc_kcore
from repro.graph.build import bucketize
from repro.graph.generators import barabasi_albert, erdos_renyi, rmat
from repro.graph.oracle import peel_coreness
from repro.graph.reorder import reorder_graph
from repro.graph.structs import Graph
from repro.kernels.fused import (
    fused_sweep_op,
    fused_sweep_pallas,
    fused_sweep_ref,
)
from repro.roofline.kcore_model import (
    achieved_bw_fraction,
    roofline_time_s,
    sweep_tile_cost,
)

FORCE_COND = 10**9  # fused_compaction_min_tiles value that pins cond mode


def _star_plus_clique(leaves: int, clique: int = 6) -> Graph:
    """A hub of degree ``leaves`` + a small clique: heavy-tailed with a
    non-trivial core (clique coreness = clique-1, everything else 1)."""
    hub_src = np.zeros(leaves, dtype=np.int64)
    hub_dst = np.arange(1, leaves + 1, dtype=np.int64)
    cs, cd = np.triu_indices(clique, k=1)
    base = leaves + 1
    src = np.concatenate([hub_src, cs + base])
    dst = np.concatenate([hub_dst, cd + base])
    return Graph.from_edges(src, dst, n_nodes=leaves + 1 + clique)


def _small_graphs():
    return [
        ("ba", barabasi_albert(80, 3, seed=1)),
        ("er", erdos_renyi(60, 4.0, seed=2)),
        ("star+clique", _star_plus_clique(50)),
    ]


def _assert_trajectory_equal(a, b, ctx=""):
    np.testing.assert_array_equal(a.coreness, b.coreness, err_msg=ctx)
    assert a.iterations == b.iterations, ctx
    assert a.comm_per_iter == b.comm_per_iter, ctx
    # active_rows_per_iter is derived from the dirty bits + adjacency
    # filter, so equality here pins the dirty-bit trajectory too.
    assert a.active_rows_per_iter == b.active_rows_per_iter, ctx


# --------------------------------------------------------------------- #
# Kernel-level differential: fused op vs the pure-jnp reference
# --------------------------------------------------------------------- #
@pytest.mark.parametrize("seed", range(6))
@pytest.mark.parametrize("track_dirty", [True, False])
def test_kernel_vs_ref_seeded(seed, track_dirty):
    # The kernel (like kernels/hindex) predicates candidate chunks off
    # above the tile's current-estimate max — sound only on states the
    # engine can reach (estimates are monotone-decreasing upper bounds).
    # So: start from a valid upper-bound state, compare sweep 1, scatter,
    # and compare sweep 2 on the reached state (predication now active).
    rng = np.random.default_rng(seed)
    n = int(rng.integers(10, 80))
    rows = int(rng.integers(1, 30))
    w = int(2 ** rng.integers(3, 7))
    ext = jnp.asarray(np.concatenate(
        [rng.integers(0, 4, n), [0]]).astype(np.int32))
    c = jnp.concatenate([
        ext[:-1] + w + jnp.asarray(rng.integers(0, 5, n).astype(np.int32)),
        jnp.full((1,), -1, jnp.int32),
    ])
    # Unique node ids (a node lives in exactly one bucket row), ~20%
    # replaced by sentinel pad rows.
    rows = min(rows, n)
    ids_np = rng.permutation(n)[:rows].astype(np.int32)
    ids_np[rng.random(rows) < 0.2] = n
    ids = jnp.asarray(ids_np)
    neigh = jnp.asarray(np.where(
        rng.random((rows, w)) < 0.3, n,
        rng.integers(0, n, (rows, w))).astype(np.int32))
    cand = int(rng.integers(1, w + 10))
    for _sweep in range(2):
        est, ch, dirty = fused_sweep_op(
            c, ext, ids, neigh, cand=cand, track_dirty=track_dirty)
        est_r, ch_r, dirty_r = fused_sweep_ref(
            c, ext, ids, neigh, cand=cand, track_dirty=track_dirty)
        np.testing.assert_array_equal(np.asarray(est), np.asarray(est_r))
        np.testing.assert_array_equal(np.asarray(ch), np.asarray(ch_r))
        np.testing.assert_array_equal(np.asarray(dirty), np.asarray(dirty_r))
        c = c.at[ids].set(est).at[-1].set(-1)


@pytest.mark.parametrize("tile_n", [1, 4, 8, 32])
def test_kernel_tile_sweep_including_tile1_and_tile_gt_rows(tile_n):
    # tile_n=32 > rows=16 is exercised through the padded launch; tile_n=1
    # runs one grid step per row.
    rng = np.random.default_rng(tile_n)
    n, rows, w = 40, 16, 8
    # Valid upper-bound state (>= any reachable h-index; see above).
    c = jnp.asarray(np.concatenate(
        [w + rng.integers(0, 5, n), [-1]]).astype(np.int32))
    ext = jnp.asarray(np.zeros(n + 1, np.int32))
    ids = jnp.asarray(rng.permutation(n)[:rows].astype(np.int32))
    neigh = jnp.asarray(rng.integers(0, n + 1, (rows, w)).astype(np.int32))
    pad = (-rows) % tile_n
    ids_p = jnp.pad(ids, (0, pad), constant_values=n)
    neigh_p = jnp.pad(neigh, ((0, pad), (0, 0)), constant_values=n)
    est, ch, dirty = fused_sweep_pallas(
        c, ext, ids_p, neigh_p, cand=8, tile_n=tile_n)
    est_r, ch_r, dirty_r = fused_sweep_ref(c, ext, ids, neigh, cand=8)
    np.testing.assert_array_equal(np.asarray(est[:rows, 0]), np.asarray(est_r))
    np.testing.assert_array_equal(np.asarray(ch[:rows, 0]), np.asarray(ch_r))
    np.testing.assert_array_equal(np.asarray(dirty), np.asarray(dirty_r))


# --------------------------------------------------------------------- #
# Engine-level differential: cond dispatch is trajectory-identical
# --------------------------------------------------------------------- #
@pytest.mark.parametrize("max_bucket_rows", [1, 4, "auto", 10**9])
@pytest.mark.parametrize("base_op", ["sorted", "count", "kernel"])
def test_fused_cond_trajectory_vs_engines(base_op, max_bucket_rows):
    for name, g in _small_graphs():
        bg = bucketize(g, max_bucket_rows=max_bucket_rows)
        oracle = peel_coreness(g)
        base = decompose(bg, op=base_op)
        fused = decompose(bg, op="fused",
                          fused_compaction_min_tiles=FORCE_COND)
        assert fused.fused_mode == "cond"
        ctx = f"{name} tiles={max_bucket_rows} vs {base_op}"
        np.testing.assert_array_equal(base.coreness, oracle, err_msg=ctx)
        _assert_trajectory_equal(fused, base, ctx)


@pytest.mark.parametrize("gauss_seidel", [True, False])
@pytest.mark.parametrize("frontier", [True, False])
def test_fused_cond_schedule_matrix(gauss_seidel, frontier, rmat_graph):
    bg = bucketize(rmat_graph)
    base = decompose(bg, op="count", gauss_seidel=gauss_seidel,
                     frontier=frontier)
    fused = decompose(bg, op="fused", gauss_seidel=gauss_seidel,
                      frontier=frontier,
                      fused_compaction_min_tiles=FORCE_COND)
    _assert_trajectory_equal(fused, base,
                             f"gs={gauss_seidel} frontier={frontier}")


# --------------------------------------------------------------------- #
# Compaction dispatch
# --------------------------------------------------------------------- #
def test_compaction_jacobi_trajectory_identical(rmat_graph):
    # Many tiles (uniform cap 16 on n=2048) so compaction engages for
    # real; under Jacobi reads every bucket sees the frozen sweep-start
    # state, so compaction must equal the unfused Jacobi trajectory
    # sweep by sweep.
    bg = bucketize(rmat_graph, max_bucket_rows=16)
    fused = decompose(bg, op="fused", gauss_seidel=False,
                      fused_compaction_min_tiles=1)
    assert fused.fused_mode == "compaction"
    base = decompose(bg, op="count", gauss_seidel=False)
    _assert_trajectory_equal(fused, base, "compaction jacobi")


def test_compaction_gauss_seidel_fixed_point(rmat_graph):
    # Gauss-Seidel compaction is Jacobi WITHIN a width group, so the
    # per-sweep trajectory may differ — but the fixed point is unique, so
    # the final coreness must still be bit-identical to the oracle and to
    # the cond dispatch.
    oracle = peel_coreness(rmat_graph)
    bg = bucketize(rmat_graph, max_bucket_rows=16)
    fused = decompose(bg, op="fused", fused_compaction_min_tiles=1)
    assert fused.fused_mode == "compaction"
    np.testing.assert_array_equal(fused.coreness, oracle)


def test_compaction_crossover_default(rmat_graph):
    # The default threshold picks cond for the autotuned (~48-tile) layout
    # and compaction once the tile count crosses it.
    few = decompose(bucketize(rmat_graph), op="fused")
    many = decompose(bucketize(rmat_graph, max_bucket_rows=8), op="fused")
    assert few.fused_mode == "cond"
    assert many.fused_mode == "compaction"
    np.testing.assert_array_equal(few.coreness, many.coreness)


# --------------------------------------------------------------------- #
# Snapshot contract: on_sweep / init_coreness resume on the fused path
# --------------------------------------------------------------------- #
@pytest.mark.parametrize("int16", [False, True])
def test_fused_on_sweep_resume_roundtrip(rmat_graph, int16):
    bg = bucketize(rmat_graph)
    snaps = {}
    full = decompose(bg, op="fused", int16=int16,
                     on_sweep=lambda it, view: snaps.update(
                         {it: np.asarray(view)}))
    assert len(snaps) == full.iterations
    for it, arr in snaps.items():
        assert arr.dtype == np.int32  # snapshot contract is int32-always
    # Warm-restart from a mid-run snapshot: identical fixed point in the
    # remaining iterations, on the fused path — and a fused snapshot must
    # restart an UNFUSED engine identically too (dtype-blind contract).
    mid = min(2, full.iterations - 1) or 1
    resumed = decompose(bg, op="fused", int16=int16,
                        init_coreness=snaps[mid])
    np.testing.assert_array_equal(resumed.coreness, full.coreness)
    assert resumed.iterations <= full.iterations - mid + 1
    cross = decompose(bg, op="sorted", init_coreness=snaps[mid])
    np.testing.assert_array_equal(cross.coreness, full.coreness)


def test_fused_reordered_layout_and_snapshot(rmat_graph):
    # Reordered layout: coreness and snapshot views stay original-id.
    oracle = peel_coreness(rmat_graph)
    rg = reorder_graph(rmat_graph, "rcm")
    views = []
    res = decompose(bucketize(rg), op="fused",
                    on_sweep=lambda it, v: views.append(np.asarray(v)))
    np.testing.assert_array_equal(res.coreness, oracle)
    np.testing.assert_array_equal(views[-1], oracle)
    # A snapshot taken under the reordered layout restarts the identity
    # layout (and vice versa) — the fused engine keeps that invariant.
    mid = views[min(1, len(views) - 1)]
    back = decompose(bucketize(rmat_graph), op="fused", init_coreness=mid)
    np.testing.assert_array_equal(back.coreness, oracle)


# --------------------------------------------------------------------- #
# int16 estimate mode
# --------------------------------------------------------------------- #
def test_int16_bit_identity_near_boundary():
    # Hub degree 30000: starting estimates reach 30000 — a few bits under
    # the int16 boundary — and must survive narrowing bit-exactly.
    g = _star_plus_clique(30_000)
    oracle = peel_coreness(g)
    bg = bucketize(g)
    r32 = decompose(bg, op="fused")
    r16 = decompose(bg, op="fused", int16=True)
    assert r16.est_dtype == "int16"
    np.testing.assert_array_equal(r32.coreness, oracle)
    np.testing.assert_array_equal(r16.coreness, oracle)
    assert r16.comm_per_iter == r32.comm_per_iter
    # The halved wire must show up as modeled bytes saved.
    assert r16.sweep_bytes < r32.sweep_bytes


def test_int16_overflow_guard_falls_back():
    # Hub degree 2^15 + 200: a wrapped int16 start would go negative and
    # poison the fixed point. The guard must reject int16 (est_dtype
    # int32), not silently wrap — and coreness must stay exact.
    g = _star_plus_clique((1 << 15) + 200)
    bg = bucketize(g)
    res = decompose(bg, op="fused", int16=True)
    assert res.est_dtype == "int32"  # fallback, by the overflow guard
    np.testing.assert_array_equal(res.coreness, peel_coreness(g))


def test_int16_requires_fused():
    g = barabasi_albert(50, 2, seed=0)
    with pytest.raises(ValueError, match="int16"):
        decompose(bucketize(g), op="sorted", int16=True)


# --------------------------------------------------------------------- #
# dc_kcore / engine plumbing
# --------------------------------------------------------------------- #
def test_dckcore_engine_fused_end_to_end(rmat_graph):
    oracle = peel_coreness(rmat_graph)
    core_s, rep_s = dc_kcore(rmat_graph, thresholds=(8,))
    core_f, rep_f = dc_kcore(rmat_graph, thresholds=(8,), engine="fused")
    np.testing.assert_array_equal(core_s, oracle)
    np.testing.assert_array_equal(core_f, core_s)
    core_16, _ = dc_kcore(rmat_graph, thresholds=(8,), engine="fused",
                          int16=True)
    np.testing.assert_array_equal(core_16, core_s)


def test_dckcore_engine_conflicts_with_custom_fn(rmat_graph):
    with pytest.raises(ValueError, match="decompose_fn"):
        dc_kcore(rmat_graph, thresholds=(8,), engine="fused",
                 decompose_fn=lambda bg, **kw: decompose(bg, **kw))
    with pytest.raises(ValueError, match="decompose_fn"):
        dc_kcore(rmat_graph, thresholds=(8,), int16=True,
                 decompose_fn=lambda bg, **kw: decompose(bg, **kw))


# --------------------------------------------------------------------- #
# Roofline cost model plumbing (fig17's input)
# --------------------------------------------------------------------- #
def test_sweep_cost_accounting(rmat_graph):
    bg = bucketize(rmat_graph)
    unfused = decompose(bg, op="count")
    fused = decompose(bg, op="fused")
    for res in (unfused, fused):
        assert len(res.sweep_bytes_per_iter) == res.iterations
        assert len(res.sweep_flops_per_iter) == res.iterations
        assert res.sweep_bytes > 0 and res.sweep_flops > 0
    # Same frontier trajectory, same FLOPs; the fused form only removes
    # HBM round-trips.
    assert fused.sweep_flops_per_iter == unfused.sweep_flops_per_iter
    assert fused.sweep_bytes < unfused.sweep_bytes
    assert all(f <= u for f, u in zip(fused.sweep_bytes_per_iter,
                                      unfused.sweep_bytes_per_iter))
    rt = roofline_time_s(fused.sweep_bytes, fused.sweep_flops)
    assert rt > 0
    assert achieved_bw_fraction(fused.sweep_bytes, 0.0) == 0.0
    assert achieved_bw_fraction(fused.sweep_bytes, rt) == pytest.approx(
        fused.sweep_bytes / rt / 819e9, rel=1e-6)


def test_sweep_tile_cost_shape_rules():
    b32, f32 = sweep_tile_cost(100, 64, 32)
    b16, f16 = sweep_tile_cost(100, 64, 32, wire_bytes=2)
    bu, fu = sweep_tile_cost(100, 64, 32, fused=False)
    assert f32 == f16 == fu  # FLOPs don't depend on wire or fusion
    assert b16 < b32 < bu
    # cand clamps to width exactly as the kernels clamp it.
    assert sweep_tile_cost(10, 8, 10**6) == sweep_tile_cost(10, 8, 8)
    bnd, _ = sweep_tile_cost(100, 64, 32, track_dirty=False)
    assert bnd < b32


# --------------------------------------------------------------------- #
# Hypothesis fuzz: random + heavy-tailed graphs, every engine
# --------------------------------------------------------------------- #
try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # the seeded sweeps above are the offline ports
    given = None

if given is not None:

    @st.composite
    def graphs(draw):
        n = draw(st.integers(min_value=4, max_value=48))
        n_edges = draw(st.integers(min_value=1, max_value=4 * n))
        seed = draw(st.integers(min_value=0, max_value=2**31 - 1))
        rng = np.random.default_rng(seed)
        src = rng.integers(0, n, n_edges)
        dst = rng.integers(0, n, n_edges)
        if draw(st.booleans()):
            # Heavy tail: one hub wired to every node.
            src = np.concatenate([src, np.zeros(n - 1, dtype=np.int64)])
            dst = np.concatenate([dst, np.arange(1, n, dtype=np.int64)])
        return Graph.from_edges(src, dst, n_nodes=n)

    @settings(max_examples=25, deadline=None)
    @given(g=graphs(),
           tiles=st.sampled_from([1, 2, 3, 10**9]),
           base_op=st.sampled_from(["sorted", "count", "kernel"]),
           gauss_seidel=st.booleans())
    def test_fuzz_fused_trajectory(g, tiles, base_op, gauss_seidel):
        bg = bucketize(g, max_bucket_rows=tiles)
        base = decompose(bg, op=base_op, gauss_seidel=gauss_seidel)
        fused = decompose(bg, op="fused", gauss_seidel=gauss_seidel,
                          fused_compaction_min_tiles=FORCE_COND)
        np.testing.assert_array_equal(base.coreness, peel_coreness(g))
        _assert_trajectory_equal(fused, base)
        # Compaction at the same tiling: exact fixed point always, exact
        # trajectory under Jacobi.
        comp = decompose(bg, op="fused", gauss_seidel=gauss_seidel,
                         fused_compaction_min_tiles=1)
        if not gauss_seidel or len(bg.buckets) == 0:
            _assert_trajectory_equal(comp, base)
        else:
            np.testing.assert_array_equal(comp.coreness, base.coreness)

    @settings(max_examples=10, deadline=None)
    @given(g=graphs())
    def test_fuzz_int16_identity(g):
        bg = bucketize(g)
        r32 = decompose(bg, op="fused")
        r16 = decompose(bg, op="fused", int16=True)
        assert r16.est_dtype == "int16"  # fuzz degrees stay < 2^15
        np.testing.assert_array_equal(r16.coreness, r32.coreness)
        assert r16.comm_per_iter == r32.comm_per_iter
