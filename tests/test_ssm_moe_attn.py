"""Layer-level unit tests: SSD vs sequential oracle, MoE dispatch vs dense
reference, chunked attention vs full attention, sliding windows."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig, MoEConfig, SSMConfig
from repro.models.attention import attention, attention_specs
from repro.models.moe import moe, moe_specs
from repro.models.module import init_params
from repro.models.ssm import ssd_chunked, ssd_sequential_ref


# --------------------------------------------------------------------- #
# SSD
# --------------------------------------------------------------------- #
@pytest.mark.parametrize("chunk", [4, 8, 16, 64])
@pytest.mark.parametrize("seq", [16, 33, 64])
def test_ssd_chunked_matches_sequential(chunk, seq):
    rng = jax.random.PRNGKey(chunk * 100 + seq)
    b, h, p, n = 2, 3, 8, 4
    k1, k2, k3, k4, k5 = jax.random.split(rng, 5)
    x = jax.random.normal(k1, (b, seq, h, p), jnp.float32)
    B = jax.random.normal(k2, (b, seq, n), jnp.float32)
    C = jax.random.normal(k3, (b, seq, n), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(k4, (b, seq, h), jnp.float32))
    A = -jnp.exp(jax.random.normal(k5, (h,), jnp.float32) * 0.5)
    y_chunk, _ = ssd_chunked(x, B, C, dt, A, chunk=chunk)
    y_ref = ssd_sequential_ref(x, B, C, dt, A)
    np.testing.assert_allclose(np.asarray(y_chunk), np.asarray(y_ref), atol=2e-4, rtol=2e-4)


def test_ssd_state_carry_consistency():
    """Final state from chunked == final state from one-step recurrence."""
    rng = jax.random.PRNGKey(0)
    b, s, h, p, n = 1, 24, 2, 4, 4
    ks = jax.random.split(rng, 5)
    x = jax.random.normal(ks[0], (b, s, h, p))
    B = jax.random.normal(ks[1], (b, s, n))
    C = jax.random.normal(ks[2], (b, s, n))
    dt = jax.nn.softplus(jax.random.normal(ks[3], (b, s, h)))
    A = -jnp.exp(jax.random.normal(ks[4], (h,)) * 0.5)
    _, h_fin = ssd_chunked(x, B, C, dt, A, chunk=8)
    hs = jnp.zeros((b, h, n, p), jnp.float32)
    for t in range(s):
        a = jnp.exp(dt[:, t] * A[None, :])
        hs = a[:, :, None, None] * hs + jnp.einsum(
            "bn,bhp,bh->bhnp", B[:, t], x[:, t], dt[:, t]
        )
    np.testing.assert_allclose(np.asarray(h_fin), np.asarray(hs), atol=2e-4, rtol=2e-4)


# --------------------------------------------------------------------- #
# MoE
# --------------------------------------------------------------------- #
def _moe_cfg(n_experts=8, top_k=2, cf=8.0):
    return ModelConfig(
        name="t", family="moe", n_layers=1, d_model=32, n_heads=2, n_kv_heads=2,
        head_dim=16, d_ff=0, vocab_size=64, dtype=jnp.float32,
        moe=MoEConfig(n_experts=n_experts, top_k=top_k, d_expert=16,
                      capacity_factor=cf),
    )


def _moe_dense_ref(params, x, cfg):
    """Loop-over-experts dense reference (no capacity dropping)."""
    m = cfg.moe
    b, s, d = x.shape
    xf = x.reshape(-1, d)
    logits = xf @ params["router"]
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, m.top_k)
    top_w = top_p / top_p.sum(-1, keepdims=True)
    out = jnp.zeros_like(xf)
    for slot in range(m.top_k):
        for e in range(m.n_experts):
            sel = top_e[:, slot] == e
            h = jax.nn.silu(xf @ params["wi_gate"][e]) * (xf @ params["wi_up"][e])
            y = h @ params["wo"][e]
            out = out + jnp.where(sel[:, None], top_w[:, slot:slot + 1] * y, 0.0)
    return out.reshape(b, s, d)


def test_moe_matches_dense_reference_with_big_capacity():
    cfg = _moe_cfg(cf=16.0)  # capacity large enough: nothing dropped
    params = init_params(moe_specs(cfg), jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, cfg.d_model), jnp.float32)
    got, aux = moe(params, x, cfg)
    want = _moe_dense_ref(params, x, cfg)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-4, rtol=1e-4)
    assert float(aux) > 0.5  # switch aux loss ~1 for near-uniform routing


def test_moe_capacity_drops_tokens():
    cfg = _moe_cfg(cf=0.25)  # tiny capacity: most tokens dropped
    params = init_params(moe_specs(cfg), jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, cfg.d_model), jnp.float32)
    got, _ = moe(params, x, cfg)
    assert bool(jnp.isfinite(got).all())
    # Dropped tokens produce zero output rows; at cf=0.25 some must be zero.
    row_norm = jnp.abs(got).sum(-1).reshape(-1)
    assert float((row_norm == 0).mean()) > 0.1


def test_moe_shared_experts():
    cfg = ModelConfig(
        name="t", family="moe", n_layers=1, d_model=32, n_heads=2, n_kv_heads=2,
        head_dim=16, d_ff=0, vocab_size=64, dtype=jnp.float32,
        moe=MoEConfig(n_experts=4, top_k=2, d_expert=16, n_shared=2, d_shared=32),
    )
    params = init_params(moe_specs(cfg), jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 8, 32), jnp.float32)
    out, _ = moe(params, x, cfg)
    assert out.shape == x.shape and bool(jnp.isfinite(out).all())


# --------------------------------------------------------------------- #
# Attention
# --------------------------------------------------------------------- #
def _attn_cfg(**kw):
    base = dict(
        name="t", family="dense", n_layers=1, d_model=64, n_heads=4,
        n_kv_heads=2, head_dim=16, d_ff=128, vocab_size=64, dtype=jnp.float32,
        attn_chunk=16,
    )
    base.update(kw)
    return ModelConfig(**base)


def _run_attn(cfg, window=None, seq=64):
    params = init_params(attention_specs(cfg), jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, seq, cfg.d_model), jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(seq, dtype=jnp.int32), (2, seq))
    out, _ = attention(params, x, cfg, positions=pos, causal=True, window=window)
    return out


def test_chunked_attention_matches_full():
    cfg_full = _attn_cfg(attention_impl="full")
    cfg_chunk = _attn_cfg(attention_impl="chunked")
    a = _run_attn(cfg_full)
    b = _run_attn(cfg_chunk)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-5, rtol=2e-5)


def test_chunked_sliding_window_matches_full():
    a = _run_attn(_attn_cfg(attention_impl="full"), window=8)
    b = _run_attn(_attn_cfg(attention_impl="chunked"), window=8)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-5, rtol=2e-5)


def test_sliding_window_limits_context():
    """Token far beyond the window must not influence the output."""
    cfg = _attn_cfg(attention_impl="full")
    params = init_params(attention_specs(cfg), jax.random.PRNGKey(0))
    seq, w = 32, 4
    x = jax.random.normal(jax.random.PRNGKey(1), (1, seq, cfg.d_model), jnp.float32)
    pos = jnp.arange(seq, dtype=jnp.int32)[None]
    out1, _ = attention(params, x, cfg, positions=pos, causal=True, window=w)
    x2 = x.at[0, 0].set(x[0, 0] + 100.0)  # outside window of last token
    out2, _ = attention(params, x2, cfg, positions=pos, causal=True, window=w)
    np.testing.assert_allclose(
        np.asarray(out1[0, -1]), np.asarray(out2[0, -1]), atol=1e-5
    )
    assert float(jnp.abs(out1[0, 0] - out2[0, 0]).max()) > 1e-3  # but locally it did


def test_moe_grouped_dispatch_matches_dense():
    """g>1 dispatch groups (the sharded path) == dense reference when the
    capacity is large enough that nothing drops."""
    from repro.sharding import policy as sp

    cfg = _moe_cfg(cf=16.0)
    params = init_params(moe_specs(cfg), jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 8, cfg.d_model), jnp.float32)
    want = _moe_dense_ref(params, x, cfg)
    got1, _ = moe(params, x, cfg)  # g=1 (no active mesh)
    # Force g=4 grouping under a real (trivial, 1-device) mesh so the
    # logical constraints resolve.
    from repro.compat import make_mesh

    mesh = make_mesh((1,), ("data",))
    saved = (sp._ACTIVE_AXES, sp._ACTIVE_RULES)
    try:
        sp._ACTIVE_AXES = {"data": 4}
        sp._ACTIVE_RULES = {"batch": ("data",), "experts": ("data",)}
        with mesh:
            got4, _ = jax.jit(lambda p, xx: moe(p, xx, cfg))(params, x)
    finally:
        sp._ACTIVE_AXES, sp._ACTIVE_RULES = saved
    np.testing.assert_allclose(np.asarray(got1), np.asarray(want), atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(got4), np.asarray(want), atol=1e-4, rtol=1e-4)
