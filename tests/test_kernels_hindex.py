"""Pallas hindex kernel: shape/dtype sweeps and engine integration.

Every configuration is validated against the pure-jnp oracle ``ref.py``
(interpret mode executes the kernel body in Python on CPU)."""
import numpy as np
import jax.numpy as jnp
import pytest

pytest.importorskip(
    "hypothesis",
    reason="hypothesis not installed; seeded ports of the key properties "
    "run in tests/test_kcore_properties.py",
)
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.decompose import decompose
from repro.core.hindex import hindex_brute, hindex_of_sequence
from repro.graph.build import bucketize
from repro.graph.oracle import peel_coreness
from repro.kernels.hindex import hindex_op, hindex_pallas, hindex_ref


@pytest.mark.parametrize("n", [8, 16, 64, 256])
@pytest.mark.parametrize("w", [8, 32, 128, 512])
def test_kernel_shape_sweep(n, w):
    rng = np.random.default_rng(n * 1000 + w)
    x = rng.integers(-1, w, size=(n, w)).astype(np.int32)
    ext = rng.integers(0, 8, size=n).astype(np.int32)
    cur = (np.maximum(x, 0).sum(axis=1) % (w + 4)).astype(np.int32) + ext + w
    cand = min(w, 64)
    got = np.asarray(hindex_op(jnp.asarray(x), jnp.asarray(ext), jnp.asarray(cur), cand=cand))
    want = np.asarray(hindex_ref(jnp.asarray(x), jnp.asarray(ext), cand=cand))
    np.testing.assert_array_equal(got, want)


@pytest.mark.parametrize("tile_n", [8, 16, 32])
@pytest.mark.parametrize("cand_chunk", [16, 128])
def test_kernel_tiling_sweep(tile_n, cand_chunk):
    rng = np.random.default_rng(tile_n + cand_chunk)
    n, w = 64, 64
    x = rng.integers(-1, 40, size=(n, w)).astype(np.int32)
    ext = rng.integers(0, 4, size=n).astype(np.int32)
    cur = np.full(n, w + 8, np.int32)
    got = np.asarray(
        hindex_pallas(
            jnp.asarray(x), jnp.asarray(ext), jnp.asarray(cur),
            cand=w, tile_n=tile_n, cand_chunk=cand_chunk,
        )
    )
    want = np.asarray(hindex_ref(jnp.asarray(x), jnp.asarray(ext), cand=w))
    np.testing.assert_array_equal(got, want)


def test_kernel_int16_inputs():
    """Engines may ship int16 estimates on the wire; kernel upcasts."""
    rng = np.random.default_rng(5)
    x = rng.integers(-1, 30, size=(16, 32)).astype(np.int16)
    ext = np.zeros(16, np.int16)
    cur = np.full(16, 40, np.int16)
    got = np.asarray(hindex_op(jnp.asarray(x), jnp.asarray(ext), jnp.asarray(cur), cand=32))
    want = np.asarray(hindex_ref(jnp.asarray(x).astype(jnp.int32), jnp.asarray(ext).astype(jnp.int32), cand=32))
    np.testing.assert_array_equal(got, want)


@given(st.data())
@settings(max_examples=40, deadline=None)
def test_kernel_vs_brute_property(data):
    n = 8
    w = data.draw(st.sampled_from([8, 16, 32]))
    rows = data.draw(
        st.lists(
            st.lists(st.integers(min_value=-1, max_value=40), min_size=w, max_size=w),
            min_size=n, max_size=n,
        )
    )
    exts = data.draw(st.lists(st.integers(min_value=0, max_value=10), min_size=n, max_size=n))
    x = np.array(rows, dtype=np.int32)
    ext = np.array(exts, dtype=np.int32)
    cur = np.full(n, w + 12, np.int32)
    got = np.asarray(hindex_op(jnp.asarray(x), jnp.asarray(ext), jnp.asarray(cur), cand=w))
    for r in range(n):
        assert got[r] == hindex_brute(x[r], int(ext[r]))


def test_candidate_window_bound_is_safe():
    """Degeneracy-bounded window == unbounded window on real estimates.

    The bound only holds for inputs that are h-index estimates (<= deg+ext);
    build them from a real graph state."""
    rng = np.random.default_rng(9)
    deg = rng.integers(1, 32, size=64)
    w = 32
    x = np.full((64, w), -1, dtype=np.int32)
    for r in range(64):
        x[r, : deg[r]] = rng.integers(0, deg[rng.integers(0, 64)] + 1, size=deg[r])
    ext = rng.integers(0, 4, size=64).astype(np.int32)
    cur = (deg + ext).astype(np.int32)
    u = max(1, hindex_of_sequence(deg + ext))
    got = np.asarray(hindex_op(jnp.asarray(x), jnp.asarray(ext), jnp.asarray(cur), cand=u))
    full = np.asarray(hindex_op(jnp.asarray(x), jnp.asarray(ext), jnp.asarray(cur), cand=w))
    np.testing.assert_array_equal(got, full)


def test_decompose_with_kernel_op(rmat_graph):
    bg = bucketize(rmat_graph)
    res = decompose(bg, op="kernel")
    np.testing.assert_array_equal(res.coreness, peel_coreness(rmat_graph))
