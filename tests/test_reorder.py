"""Locality-aware reordering + tile autotuner tests.

Pins the PR's invariants:
  * perm/inv_perm are inverse bijections for BFS and RCM on every fixture.
  * Reordering preserves the graph (degrees, adjacency) up to relabeling.
  * Permutation invariance: coreness computed on a reordered layout equals
    the peeling oracle in ORIGINAL id order — both engines un-permute
    transparently, dc_kcore included.
  * The degree-profile autotuner emits aligned per-class caps and the
    resulting tiling still covers every node exactly once.
  * RCM measurably reduces bucket-adjacency bitmap density on the
    power-law fixture (the static-frontier-filter payoff).
"""
import numpy as np
import pytest

from repro.core.decompose import decompose
from repro.core.dckcore import dc_kcore
from repro.graph.build import autotune_tile_caps, bucketize
from repro.graph.oracle import peel_coreness
from repro.graph.reorder import (
    bfs_order,
    bitmap_density,
    invert_order,
    neighbor_spans,
    rcm_order,
    reorder_graph,
    sample_edge_skeleton,
    sampled_order,
)
from repro.graph.structs import Graph

METHODS = ["bfs", "rcm"]


@pytest.fixture(params=["er", "ba", "rmat"])
def any_graph(request, er_graph, ba_graph, rmat_graph):
    return {"er": er_graph, "ba": ba_graph, "rmat": rmat_graph}[request.param]


@pytest.mark.parametrize("order_fn", [bfs_order, rcm_order])
def test_perm_roundtrip(any_graph, order_fn):
    g = any_graph
    perm = order_fn(g)
    assert perm.shape == (g.n_nodes,)
    inv = invert_order(perm)
    np.testing.assert_array_equal(inv[perm], np.arange(g.n_nodes))
    np.testing.assert_array_equal(perm[inv], np.arange(g.n_nodes))
    np.testing.assert_array_equal(np.sort(perm), np.arange(g.n_nodes))


@pytest.mark.parametrize("method", METHODS)
def test_reorder_preserves_graph(any_graph, method):
    g = any_graph
    rg = reorder_graph(g, method)
    assert rg.n_nodes == g.n_nodes and rg.n_edges == g.n_edges
    np.testing.assert_array_equal(invert_order(rg.perm), rg.inv_perm)
    # Degrees and adjacency carry over through the relabeling.
    np.testing.assert_array_equal(rg.degrees[rg.inv_perm], g.degrees)
    rng = np.random.default_rng(0)
    for v in rng.integers(0, g.n_nodes, size=40):
        expect = set(rg.inv_perm[g.neighbors(v)].tolist())
        assert set(rg.neighbors(int(rg.inv_perm[v])).tolist()) == expect
    rg.validate()


def test_reorder_identity_and_errors(rmat_graph):
    assert reorder_graph(rmat_graph, "identity") is rmat_graph
    with pytest.raises(ValueError):
        reorder_graph(rmat_graph, "degree-sort")
    rg = reorder_graph(rmat_graph, "rcm")
    with pytest.raises(ValueError):
        reorder_graph(rg, "bfs")  # no implicit composition


def test_reorder_edge_cases():
    # Empty graph and isolated nodes: isolated ids land at the end.
    empty = Graph.empty(4)
    for method in METHODS:
        rg = reorder_graph(empty, method)
        np.testing.assert_array_equal(np.sort(rg.perm), np.arange(4))
    pair = Graph.from_edges([0], [3], n_nodes=6)
    for method in METHODS:
        rg = reorder_graph(pair, method)
        # The two connected nodes come first, isolated nodes after.
        assert set(rg.perm[:2].tolist()) == {0, 3}
        assert (rg.degrees[2:] == 0).all()


@pytest.mark.parametrize("method", METHODS)
def test_reordered_coreness_matches_oracle(any_graph, method):
    """Permutation invariance: the engine output is in original-id order."""
    g = any_graph
    res = decompose(bucketize(reorder_graph(g, method)))
    np.testing.assert_array_equal(res.coreness, peel_coreness(g))


@pytest.mark.parametrize("method", METHODS)
def test_dckcore_reorder_matches_oracle(rmat_graph, method):
    """Divide + conquer on reordered parts (ext permuted in, coreness
    permuted out per part) still merges to the exact oracle."""
    core, report = dc_kcore(rmat_graph, thresholds=(4, 12), reorder=method)
    np.testing.assert_array_equal(core, peel_coreness(rmat_graph))
    assert all(0.0 < p.bitmap_density <= 1.0 for p in report.parts)


def test_reorder_resume_snapshot_roundtrip(rmat_graph):
    """init_coreness / on_sweep speak original-id order even on a reordered
    layout: a snapshot taken mid-run restarts to the same fixed point."""
    bg = bucketize(reorder_graph(rmat_graph, "rcm"))
    snaps = {}
    decompose(bg, on_sweep=lambda it, c: snaps.__setitem__(it, np.asarray(c)))
    mid = snaps[2]
    res = decompose(bg, init_coreness=mid)
    np.testing.assert_array_equal(res.coreness, peel_coreness(rmat_graph))


def test_autotune_caps_shape(rmat_graph):
    caps = autotune_tile_caps(rmat_graph, row_align=8)
    assert caps, "power-law fixture must produce degree classes"
    for width, cap in caps.items():
        assert width >= 8 and cap % 8 == 0 and cap >= 8
    # Empty graph: no classes, no caps.
    assert autotune_tile_caps(Graph.empty(10)) == {}


@pytest.mark.parametrize("method", ["identity", "rcm"])
def test_bucketize_auto_covers_all_nodes(rmat_graph, method):
    g = reorder_graph(rmat_graph, method)
    bg = bucketize(g)
    seen = np.zeros(g.n_nodes, dtype=bool)
    for b in bg.buckets:
        rows = b.node_ids[b.node_ids < g.n_nodes]
        assert not seen[rows].any()
        seen[rows] = True
    np.testing.assert_array_equal(seen, g.degrees > 0)
    if method == "rcm":
        np.testing.assert_array_equal(bg.perm, g.perm)
        np.testing.assert_array_equal(bg.inv_perm, g.inv_perm)


def test_rcm_reduces_bitmap_density(rmat_graph):
    """The acceptance gate: on the power-law fixture, RCM tightens neighbor
    spans and the autotuned tiling yields a sparser adjacency bitmap."""
    g = rmat_graph
    rg = reorder_graph(g, "rcm")
    assert neighbor_spans(rg).mean() < neighbor_spans(g).mean()
    d_id = bitmap_density(bucketize(g))
    d_rcm = bitmap_density(bucketize(rg))
    assert d_rcm < d_id


def test_bucketize_ext_permutation(rmat_graph):
    """ext is accepted in original-id order and stored in layout order."""
    g = rmat_graph
    ext = np.arange(g.n_nodes, dtype=np.int32) % 7
    rg = reorder_graph(g, "bfs")
    bg = bucketize(rg, ext=ext)
    np.testing.assert_array_equal(bg.ext, ext[rg.perm])
    # And the fixed point with external information stays order-invariant.
    res_id = decompose(bucketize(g, ext=ext))
    res_bfs = decompose(bg)
    np.testing.assert_array_equal(res_bfs.coreness, res_id.coreness)


# --------------------------------------------------------------------- #
# Sampled (out-of-core) ordering
# --------------------------------------------------------------------- #
@pytest.mark.parametrize("method", METHODS)
@pytest.mark.parametrize("budget", [64, 2048, 1 << 22])
def test_sampled_order_is_valid_permutation(any_graph, method, budget):
    g = any_graph
    perm = sampled_order(g, method, edge_budget=budget)
    np.testing.assert_array_equal(np.sort(perm), np.arange(g.n_nodes))
    # Deterministic: strided sampling has no RNG.
    np.testing.assert_array_equal(perm, sampled_order(g, method, edge_budget=budget))


def test_sample_skeleton_bounded_and_covering(rmat_graph):
    g = rmat_graph
    for budget in (256, 4096):
        sk = sample_edge_skeleton(g, budget)
        assert sk.n_nodes == g.n_nodes
        # Bounded: at most max(n_pos, budget) sampled slots before
        # symmetrization -> at most 2x that many directed slots.
        n_pos = int((g.degrees > 0).sum())
        assert sk.indices.size <= 2 * max(n_pos, budget)
        # Covering: every positive-degree node keeps at least one neighbor.
        np.testing.assert_array_equal(sk.degrees > 0, g.degrees > 0)
        # Every skeleton edge is a real edge.
        rng = np.random.default_rng(1)
        for v in rng.integers(0, g.n_nodes, size=30):
            assert set(sk.neighbors(v).tolist()) <= set(g.neighbors(v).tolist())


def test_sampled_order_full_budget_equals_exact(rmat_graph):
    """With a per-node cap >= max degree the skeleton is the whole graph:
    the sampled order degrades gracefully to the exact one."""
    g = rmat_graph
    n_pos = int((g.degrees > 0).sum())
    budget = n_pos * int(g.degrees.max())  # k = budget // n_pos >= max_deg
    np.testing.assert_array_equal(sampled_order(g, "rcm", edge_budget=budget), rcm_order(g))
    np.testing.assert_array_equal(sampled_order(g, "bfs", edge_budget=budget), bfs_order(g))


@pytest.mark.parametrize("method", METHODS)
def test_sampled_reorder_coreness_and_density(rmat_graph, method):
    """Sampled ordering keeps oracle exactness and lands within a bounded
    factor of the exact order's bitmap density (and never above identity)."""
    g = rmat_graph
    rg = reorder_graph(g, method, sample_edges=2048)
    res = decompose(bucketize(rg))
    np.testing.assert_array_equal(res.coreness, peel_coreness(g))
    d_sampled = bitmap_density(bucketize(rg))
    d_full = bitmap_density(bucketize(reorder_graph(g, method)))
    d_id = bitmap_density(bucketize(g))
    assert d_sampled <= d_id
    assert d_sampled <= 1.25 * d_full  # measured ~1.02-1.08x on this fixture


def test_dckcore_sampled_reorder_matches_oracle(rmat_graph):
    core, report = dc_kcore(rmat_graph, thresholds=(4, 12), reorder="rcm",
                            reorder_sample_edges=1024)
    np.testing.assert_array_equal(core, peel_coreness(rmat_graph))
    assert all(0.0 < p.bitmap_density <= 1.0 for p in report.parts)
