"""End-to-end behaviour tests for the paper's system.

1. DC-kCore full pipeline (budget-planned thresholds, rough divide, the
   jit conquer engine) == oracle, with the paper's resource claim (peak
   part memory < monolithic) holding.
2. LM training end-to-end: a reduced assigned-arch config trains for 30
   steps through the full stack (data -> loss -> grads -> AdamW -> ckpt)
   and the loss drops.
3. Serving end-to-end: prefill + greedy decode produce deterministic
   tokens consistent with teacher-forced argmax.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import dc_kcore, plan_thresholds
from repro.configs import get_smoke_config
from repro.data import SyntheticTokens
from repro.graph import rmat
from repro.graph.oracle import peel_coreness
from repro.models.model import build_specs, forward
from repro.models.module import init_params
from repro.optim import get_optimizer
from repro.runtime import TrainLoop, greedy_generate


def test_kcore_pipeline_end_to_end():
    g = rmat(13, 12, seed=4)
    budget = g.memory_bytes() // 2
    thresholds = plan_thresholds(g, budget) or [16]
    core, report = dc_kcore(g, thresholds=thresholds, strategy="rough")
    np.testing.assert_array_equal(core, peel_coreness(g))
    _, mono = dc_kcore(g, thresholds=())
    assert report.peak_bytes < mono.peak_bytes
    assert report.total_comm > 0 and report.total_iterations >= 2


def test_lm_training_end_to_end(tmp_path):
    cfg = get_smoke_config("qwen3-8b")
    loop = TrainLoop(
        cfg=cfg,
        params=init_params(build_specs(cfg), jax.random.PRNGKey(0)),
        optimizer=get_optimizer(cfg, lr=3e-3, warmup=5, total=30),
        data=SyntheticTokens(vocab_size=cfg.vocab_size, seq_len=32, batch=4, seed=0),
        ckpt_dir=str(tmp_path / "ck"),
        ckpt_every=10,
        ckpt_blocking=True,
    )
    hist = loop.run(30, log_every=5)
    assert hist["loss"][-1] < hist["loss"][0]
    # A fresh loop resumes from the saved state at the right step.
    loop2 = TrainLoop(
        cfg=cfg,
        params=init_params(build_specs(cfg), jax.random.PRNGKey(0)),
        optimizer=get_optimizer(cfg, lr=3e-3, warmup=5, total=30),
        data=SyntheticTokens(vocab_size=cfg.vocab_size, seq_len=32, batch=4, seed=0),
        ckpt_dir=str(tmp_path / "ck"),
    )
    assert loop2.try_resume() and loop2.step == 30


def test_serving_end_to_end():
    cfg = get_smoke_config("granite-3-2b")
    params = init_params(build_specs(cfg), jax.random.PRNGKey(1))
    prompt = jax.random.randint(jax.random.PRNGKey(2), (2, 16), 0, cfg.vocab_size)
    out = greedy_generate(params, prompt, cfg, n_new=4, jit=False)
    assert out.shape == (2, 4)
    # Cross-check against teacher-forced argmax over the full sequence.
    seq = jnp.concatenate([prompt, out[:, :3].astype(prompt.dtype)], axis=1)
    logits, _, _ = forward(params, seq, cfg)
    vmask = jnp.arange(logits.shape[-1]) < cfg.vocab_size
    expect = jnp.argmax(jnp.where(vmask, logits[:, 15:], -jnp.inf), axis=-1)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(expect[:, :4]))
