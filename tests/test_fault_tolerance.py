"""Fault-tolerant elastic conquer: chaos differential suite.

Four layers, mirroring the recovery machinery:

* **FaultPlan** (pure, in-process): spec parsing, visit windows, the
  bounded-hang contract (a parked thread always terminates).
* **conquer_wave watchdog** (deterministic, controlled ``run_part``):
  fail-fast semantics preserved, retry with backoff, crash-exhaustion
  blacklist + re-plan over survivors, hang detection, all-slices-dead.
* **dc_kcore chaos differential**: faults injected at every
  ``slice_conquer`` visit — the part-parallel run completes (possibly
  degraded to fewer slices), byte-identical to the fault-free sequential
  baseline, with every retry/blacklist accounted in the report.
* **Checkpoint integrity**: per-leaf CRC32, typed corruption errors,
  quarantine (``step_N.corrupt``) + fallback to the previous retained
  step, and the dc_kcore resume path over a corrupted latest step.

The elastic 8->4 remesh check (formerly tests/test_elastic.py) folds in
here: degraded restore onto a smaller mesh is the same elasticity story,
now exercised through ``restore_pytree_with_fallback``.
"""
import json
import os
import threading
import time

import numpy as np
import pytest

from distributed_helpers import run_with_devices

from repro.ckpt import (
    DEFAULT_RETAIN,
    CheckpointCorruptError,
    CheckpointManager,
    latest_step,
    quarantine_step,
    restore_pytree,
    restore_pytree_with_fallback,
    save_pytree,
)
from repro.core.dckcore import dc_kcore
from repro.core.partsched import (
    PartCost,
    SliceCapacityError,
    SliceSpec,
    WatchdogConfig,
    WaveTelemetry,
    assign_parts,
    conquer_wave,
)
from repro.graph.generators import rmat
from repro.runtime import FAULT_SITES, FaultPlan, FaultSpec, InjectedFailure


# --------------------------------------------------------------------- #
# FaultPlan: specs, visit windows, bounded hangs.
# --------------------------------------------------------------------- #
def test_fault_spec_parse_forms():
    s = FaultSpec.parse("slice_conquer:crash")
    assert (s.site, s.kind, s.at, s.count) == ("slice_conquer", "crash", 0, 1)
    s = FaultSpec.parse("checkpoint_save:hang:3:2:0.5")
    assert (s.kind, s.at, s.count, s.delay_s) == ("hang", 3, 2, 0.5)
    assert FaultSpec.parse("prefetch:slow:1").at == 1


@pytest.mark.parametrize("bad", [
    "slice_conquer",                    # no kind
    "nope:crash",                       # unknown site
    "slice_conquer:explode",            # unknown kind
    "slice_conquer:crash:0:1:2:3",      # too many fields
])
def test_fault_spec_parse_rejects(bad):
    with pytest.raises(ValueError):
        FaultSpec.parse(bad)


def test_fault_plan_visit_window_and_events():
    plan = FaultPlan([FaultSpec("prefetch", "crash", at=1, count=2)])
    plan.visit("prefetch", cursor=0)  # visit 0: before the window
    for k in (1, 2):
        with pytest.raises(InjectedFailure):
            plan.visit("prefetch", cursor=k)
    plan.visit("prefetch", cursor=3)  # visit 3: past the window
    assert plan.visits("prefetch") == 4
    assert [e["visit"] for e in plan.events] == [1, 2]
    assert all(e["event"] == "inject" and e["kind"] == "crash"
               for e in plan.events)


def test_fault_plan_unknown_site_never_fires():
    plan = FaultPlan([FaultSpec("slice_conquer", "crash")])
    plan.visit("boundary_fold")  # armed elsewhere: plain pass-through
    assert plan.events == []


def test_fault_plan_hang_is_bounded_and_releasable():
    plan = FaultPlan([FaultSpec("serve_update", "hang", delay_s=30.0)])
    t0 = time.perf_counter()
    release = threading.Timer(0.05, plan.release)
    release.start()
    try:
        with pytest.raises(InjectedFailure):
            plan.visit("serve_update")
    finally:
        release.cancel()
    assert time.perf_counter() - t0 < 5.0  # woke on release, not delay_s
    # A tiny delay bounds the park even without a release.
    plan2 = FaultPlan([FaultSpec("serve_update", "hang", delay_s=0.01)])
    with pytest.raises(InjectedFailure):
        plan2.visit("serve_update")


# --------------------------------------------------------------------- #
# conquer_wave watchdog: deterministic controlled-run_part harness.
# --------------------------------------------------------------------- #
def _schedule(n_parts, n_slices):
    costs = [PartCost(cursor=c, collective_bytes=100, hbm_bytes=0,
                      part_bytes=1) for c in range(n_parts)]
    slices = [SliceSpec(index=s, n_node_shards=1, n_slot_shards=1)
              for s in range(n_slices)]
    return assign_parts(costs, slices), slices


def test_conquer_wave_fail_fast_raises_earliest_cursor():
    schedule, slices = _schedule(4, 2)

    def run_part(cursor, s):
        if cursor in (1, 2):
            raise RuntimeError(f"boom {cursor}")
        return cursor * 10

    with pytest.raises(RuntimeError, match="boom 1"):
        conquer_wave(schedule, run_part, slices=slices)


def test_conquer_wave_retry_commits_identical_result():
    schedule, slices = _schedule(4, 2)
    fails = {1: 2}  # cursor 1 fails twice, then succeeds
    tel = WaveTelemetry()

    def run_part(cursor, s):
        if fails.get(cursor, 0) > 0:
            fails[cursor] -= 1
            raise RuntimeError("transient")
        return cursor * 10

    results = conquer_wave(
        schedule, run_part, slices=slices,
        watchdog=WatchdogConfig(max_retries=2, backoff_s=0.001),
        telemetry=tel,
    )
    assert results == {c: c * 10 for c in range(4)}
    assert tel.retries == 2 and tel.blacklisted == [] and tel.replans == 0


def test_conquer_wave_exhausted_retries_blacklist_and_replan():
    schedule, slices = _schedule(6, 2)
    victim = schedule.parts_for(0)[0]
    tel = WaveTelemetry()

    def run_part(cursor, s):
        if cursor == victim and s == 0:
            raise RuntimeError("slice 0 is broken")
        return cursor * 10

    results = conquer_wave(
        schedule, run_part, slices=slices,
        watchdog=WatchdogConfig(max_retries=1, backoff_s=0.001),
        telemetry=tel,
    )
    # Every part completed — the victim re-planned onto the survivor.
    assert results == {c: c * 10 for c in range(6)}
    assert tel.blacklisted == [0] and tel.replans == 1 and tel.degraded
    kinds = [e["event"] for e in tel.events]
    assert kinds.count("retry") == 1 and "blacklist" in kinds \
        and "replan" in kinds


def test_conquer_wave_hang_is_declared_dead_and_replanned():
    schedule, slices = _schedule(4, 2)
    victim = schedule.parts_for(1)[0]
    unhang = threading.Event()
    tel = WaveTelemetry()

    def run_part(cursor, s, heartbeat=None):
        if cursor == victim and s == 1:
            unhang.wait(timeout=10)
            raise RuntimeError("woke from hang")
        heartbeat()
        return cursor * 10

    try:
        results = conquer_wave(
            schedule, run_part, slices=slices,
            watchdog=WatchdogConfig(slice_timeout_s=0.2, poll_s=0.02,
                                    max_retries=0, drain_timeout_s=5.0),
            telemetry=tel,
        )
    finally:
        unhang.set()
    assert results == {c: c * 10 for c in range(4)}
    assert tel.blacklisted == [1]
    assert any(e["event"] == "blacklist" and e["reason"] == "hang"
               for e in tel.events)


def test_conquer_wave_all_slices_dead_raises():
    schedule, slices = _schedule(3, 2)

    def run_part(cursor, s):
        raise RuntimeError("every slice is broken")

    with pytest.raises(RuntimeError, match="every slice is broken"):
        conquer_wave(
            schedule, run_part, slices=slices,
            watchdog=WatchdogConfig(max_retries=0, backoff_s=0.001),
        )


def test_conquer_wave_replan_capacity_exhaustion_raises():
    # The survivor cannot admit the victim's part: re-plan fails and the
    # wave raises the declare-dead error instead of spinning.
    costs = [PartCost(cursor=0, collective_bytes=100, hbm_bytes=0,
                      part_bytes=100)]
    slices = [SliceSpec(index=0, n_node_shards=1, n_slot_shards=1,
                        capacity_bytes=200),
              SliceSpec(index=1, n_node_shards=1, n_slot_shards=1,
                        capacity_bytes=10)]
    schedule = assign_parts(costs, slices)

    def run_part(cursor, s):
        raise RuntimeError("slice 0 is broken")

    with pytest.raises(SliceCapacityError):
        conquer_wave(
            schedule, run_part, slices=slices,
            watchdog=WatchdogConfig(max_retries=0, backoff_s=0.001),
        )


# --------------------------------------------------------------------- #
# dc_kcore chaos differential: byte-identity under injected faults.
# --------------------------------------------------------------------- #
@pytest.fixture(scope="module")
def chaos_graph():
    g = rmat(10, 8, seed=11)
    base, _ = dc_kcore(g, thresholds=(4, 10))
    return g, base


def test_dckcore_crash_at_every_conquer_visit(chaos_graph):
    """A single injected crash at the k-th slice_conquer visit, for every
    k the fault-free run performs: the run completes byte-identical with
    exactly that one retry accounted."""
    g, base = chaos_graph
    probe = FaultPlan()  # counts visits without arming anything
    core, _ = dc_kcore(g, thresholds=(4, 10), part_parallel=2,
                       max_retries=2, fault_plan=probe)
    np.testing.assert_array_equal(core, base)
    n_visits = probe.visits("slice_conquer")
    assert n_visits >= 3  # one per part at minimum
    for k in range(n_visits):
        plan = FaultPlan([FaultSpec("slice_conquer", "crash", at=k)])
        core, report = dc_kcore(g, thresholds=(4, 10), part_parallel=2,
                                max_retries=2, fault_plan=plan)
        np.testing.assert_array_equal(core, base)
        fired = len(plan.events)
        assert fired == 1, (k, plan.events)
        assert report.retries == 1
        retry_events = [e for e in report.fault_events
                        if e["event"] == "retry"]
        assert len(retry_events) == 1
        # Per-part attribution: at most the one retry (a retried attempt
        # later discarded by a speculation miss re-runs clean next wave).
        assert sum(p.retries for p in report.parts) <= 1


def test_dckcore_hang_blacklists_and_degrades(chaos_graph):
    """An injected hang trips the watchdog: the slice is blacklisted, its
    parts re-plan onto the survivor (2 -> 1 ≡ sequential), and the run
    completes byte-identical, reported as degraded."""
    g, base = chaos_graph
    # The timeout must be << the hang delay but leave a legitimate sweep
    # (or a cold compile, which also stalls the heartbeat) well clear.
    plan = FaultPlan([FaultSpec("slice_conquer", "hang", at=0, delay_s=60.0)])
    core, report = dc_kcore(g, thresholds=(4, 10), part_parallel=2,
                            slice_timeout_s=2.0, max_retries=0,
                            fault_plan=plan)
    np.testing.assert_array_equal(core, base)
    assert len(report.blacklisted_slices) == 1
    assert report.degraded_waves >= 1
    assert any(e["event"] == "blacklist" and e["reason"] == "hang"
               for e in report.fault_events)
    # The blacklist sticks for the rest of the run: every later wave is
    # effectively sequential, and no conquer worker outlives the run
    # (the autouse thread-leak gate enforces the second half).


def test_dckcore_mainthread_sites_fail_fast(chaos_graph, tmp_path):
    """boundary_fold / checkpoint_save faults are main-thread: they kill
    the run (recovery = checkpointed resume, not in-run retry) — even
    with the watchdog armed."""
    g, _ = chaos_graph
    plan = FaultPlan([FaultSpec("boundary_fold", "crash")])
    with pytest.raises(InjectedFailure):
        dc_kcore(g, thresholds=(4, 10), part_parallel=2, max_retries=2,
                 fault_plan=plan)
    plan = FaultPlan([FaultSpec("checkpoint_save", "crash")])
    with pytest.raises(InjectedFailure):
        dc_kcore(g, thresholds=(4, 10), part_parallel=2, max_retries=2,
                 checkpoint_dir=str(tmp_path / "ck"), fault_plan=plan)


def test_dckcore_crash_then_resume_after_degraded_run(chaos_graph, tmp_path):
    """Degrade the run (a slice crash past its retry budget blacklists
    it), then kill it at a boundary checkpoint save; resume with no
    faults is byte-identical to sequential, with the saved parts
    restored — degraded-mode checkpoints carry no mode dependence."""
    g, base = chaos_graph
    ck = str(tmp_path / "ck")
    plan = FaultPlan([FaultSpec("slice_conquer", "crash", at=0),
                      FaultSpec("checkpoint_save", "crash", at=1)])
    with pytest.raises(InjectedFailure):
        dc_kcore(g, thresholds=(4, 10), part_parallel=2, checkpoint_dir=ck,
                 max_retries=0, fault_plan=plan)
    # Both faults fired: the conquer crash (-> blacklist at retries=0)
    # and the boundary-save kill.
    assert sorted(e["site"] for e in plan.events) == \
        ["checkpoint_save", "slice_conquer"]
    core, report = dc_kcore(g, thresholds=(4, 10), part_parallel=2,
                            checkpoint_dir=ck, resume=True)
    np.testing.assert_array_equal(core, base)
    assert report.resumed_parts >= 1


def test_dckcore_watchdog_requires_part_parallel(chaos_graph):
    g, _ = chaos_graph
    with pytest.raises(ValueError, match="part_parallel"):
        dc_kcore(g, thresholds=(4,), slice_timeout_s=1.0)
    with pytest.raises(ValueError, match="ckpt_retain"):
        dc_kcore(g, thresholds=(4,), ckpt_retain=0)


# --------------------------------------------------------------------- #
# Checkpoint integrity: CRC, quarantine, fallback.
# --------------------------------------------------------------------- #
def _tree(seed=0):
    rng = np.random.default_rng(seed)
    return {"w": rng.standard_normal((8, 4)).astype(np.float32),
            "steps": np.arange(5, dtype=np.int32)}


def _corrupt_leaf(ckdir, step):
    sd = os.path.join(ckdir, f"step_{step:08d}")
    leaf = next(f for f in sorted(os.listdir(sd)) if f.endswith(".npy"))
    p = os.path.join(sd, leaf)
    raw = bytearray(open(p, "rb").read())
    raw[-4] ^= 0xFF  # flip data bits past the npy header
    open(p, "wb").write(bytes(raw))


def test_crc_roundtrip_and_corruption_detected(tmp_path):
    d = str(tmp_path)
    save_pytree(d, _tree(), step=1)
    tree, step, _ = restore_pytree(d, _tree())  # intact: CRC passes
    assert step == 1
    _corrupt_leaf(d, 1)
    with pytest.raises(CheckpointCorruptError, match="CRC mismatch"):
        restore_pytree(d, _tree())


def test_corrupt_manifest_is_typed(tmp_path):
    d = str(tmp_path)
    save_pytree(d, _tree(), step=1)
    mf = os.path.join(d, "step_00000001", "manifest.json")
    open(mf, "w").write("{not json")
    with pytest.raises(CheckpointCorruptError):
        restore_pytree(d, _tree())


def test_pre_crc_manifest_still_loads(tmp_path):
    d = str(tmp_path)
    save_pytree(d, _tree(), step=1)
    mf = os.path.join(d, "step_00000001", "manifest.json")
    manifest = json.load(open(mf))
    del manifest["crc32"]  # a checkpoint written before CRC stamping
    json.dump(manifest, open(mf, "w"))
    _, step, _ = restore_pytree(d, _tree())
    assert step == 1


def test_fallback_quarantines_and_restores_previous(tmp_path):
    d = str(tmp_path)
    save_pytree(d, _tree(seed=1), step=1)
    save_pytree(d, _tree(seed=2), step=2)
    _corrupt_leaf(d, 2)
    seen = []
    tree, step, _ = restore_pytree_with_fallback(
        d, _tree(), on_corrupt=lambda s, e: seen.append(s))
    assert step == 1 and seen == [2]
    np.testing.assert_array_equal(tree["w"], _tree(seed=1)["w"])
    # Step 2 is quarantined for postmortem and invisible to latest_step.
    assert os.path.isdir(os.path.join(d, "step_00000002.corrupt"))
    assert latest_step(d) == 1


def test_fallback_raises_when_nothing_intact(tmp_path):
    d = str(tmp_path)
    save_pytree(d, _tree(), step=1)
    _corrupt_leaf(d, 1)
    with pytest.raises(FileNotFoundError, match="no intact"):
        restore_pytree_with_fallback(d, _tree())
    assert latest_step(d) is None


def test_quarantine_step_replaces_stale_quarantine(tmp_path):
    d = str(tmp_path)
    save_pytree(d, _tree(seed=1), step=1)
    q = quarantine_step(d, 1)
    assert q.endswith(".corrupt") and os.path.isdir(q)
    save_pytree(d, _tree(seed=2), step=1)
    quarantine_step(d, 1)  # second quarantine of the same step: replaced
    assert latest_step(d) is None


def test_dckcore_resume_falls_back_over_corrupt_boundary(tmp_path):
    """Corrupt the latest boundary checkpoint: resume quarantines it and
    restarts from the previous retained step — byte-identical."""
    g = rmat(10, 8, seed=11)
    base, _ = dc_kcore(g, thresholds=(4, 10))
    ck = str(tmp_path / "ck")
    dc_kcore(g, thresholds=(4, 10), checkpoint_dir=ck)
    steps = sorted(d for d in os.listdir(ck) if d.startswith("step_"))
    assert len(steps) == 2  # retain=2 default
    _corrupt_leaf(ck, int(steps[-1].split("_")[1]))
    core, report = dc_kcore(g, thresholds=(4, 10), checkpoint_dir=ck,
                            resume=True)
    np.testing.assert_array_equal(core, base)
    assert report.quarantined_steps == 1
    assert any(e["event"] == "quarantine" for e in report.fault_events)
    assert any(d.endswith(".corrupt") for d in os.listdir(ck))


def test_dckcore_resume_every_step_corrupt_restarts_fresh(tmp_path):
    g = rmat(10, 8, seed=11)
    base, _ = dc_kcore(g, thresholds=(4, 10))
    ck = str(tmp_path / "ck")
    dc_kcore(g, thresholds=(4, 10), checkpoint_dir=ck)
    for d in list(os.listdir(ck)):
        if d.startswith("step_"):
            _corrupt_leaf(ck, int(d.split("_")[1]))
    core, report = dc_kcore(g, thresholds=(4, 10), checkpoint_dir=ck,
                            resume=True)
    np.testing.assert_array_equal(core, base)
    assert report.quarantined_steps == 2
    assert report.resumed_parts == 0  # nothing intact: fresh run


# --------------------------------------------------------------------- #
# CheckpointManager: retention knob + async error surfacing.
# --------------------------------------------------------------------- #
def test_manager_retain_default_and_keep_alias(tmp_path):
    m = CheckpointManager(str(tmp_path))
    assert m.retain == DEFAULT_RETAIN == 2
    m2 = CheckpointManager(str(tmp_path), keep=5)
    assert m2.retain == 5 and m2.keep == 5
    m3 = CheckpointManager(str(tmp_path), retain=1)
    assert m3.keep == 1


def test_manager_async_error_surfaces_on_next_save(tmp_path, monkeypatch):
    import repro.ckpt.checkpoint as ckmod

    m = CheckpointManager(str(tmp_path), retain=2)
    real = ckmod.save_pytree

    def boom(*a, **k):
        raise OSError("disk on fire")

    monkeypatch.setattr(ckmod, "save_pytree", boom)
    m.save(_tree(), step=1, blocking=False)
    m._pending.join()  # let the worker fail without draining the error
    monkeypatch.setattr(ckmod, "save_pytree", real)
    with pytest.raises(OSError, match="disk on fire"):
        m.save(_tree(), step=2, blocking=False)
    m.wait()


def test_manager_on_done_error_surfaces_on_clear_steps(tmp_path):
    m = CheckpointManager(str(tmp_path), retain=2)

    def bad_hook(step, secs):
        raise ValueError("hook exploded")

    m.save(_tree(), step=1, blocking=False, on_done=bad_hook)
    m._pending.join()
    with pytest.raises(ValueError, match="hook exploded"):
        m.clear_steps()
    m.wait()


def test_clear_steps_purges_quarantined_and_tmp(tmp_path):
    d = str(tmp_path)
    m = CheckpointManager(d, retain=3)
    m.save(_tree(), step=1, blocking=True)
    m.save(_tree(), step=2, blocking=True)
    quarantine_step(d, 2)
    os.makedirs(os.path.join(d, "step_00000009.tmp"))
    m.clear_steps()
    left = [x for x in os.listdir(d) if x.startswith("step_")]
    assert left == []


# --------------------------------------------------------------------- #
# Capacity re-plan exhaustion (launcher-level retry loop).
# --------------------------------------------------------------------- #
def test_capacity_replan_exhaustion_reraises(tmp_path):
    from repro.launch.kcore import run_with_capacity_replan

    g = rmat(8, 4, seed=3)
    ck = str(tmp_path / "ck")
    calls = []
    exc = SliceCapacityError("part 0 fits no slice")

    def dc_stub(graph, thresholds, **kw):
        calls.append((tuple(thresholds), kw.get("resume")))
        raise exc

    with pytest.raises(SliceCapacityError) as ei:
        run_with_capacity_replan(
            g, [4], replan_budget_bytes=1 << 20, max_replans=3,
            dc=dc_stub, checkpoint_dir=ck, resume=True)
    assert ei.value is exc                  # the original error, not a wrap
    assert len(calls) == 1 + 3              # first try + max_replans
    assert calls[0][1] is True              # resume honored on the first try
    assert all(r is False for _, r in calls[1:])  # forced off on retries
    assert not os.path.exists(ck)           # no checkpoint litter


# --------------------------------------------------------------------- #
# Elastic remesh (folded in from tests/test_elastic.py): a checkpoint
# saved on an 8-device mesh restores re-sharded onto 4, through the
# integrity-checking fallback path.
# --------------------------------------------------------------------- #
_ELASTIC_SAVE = r"""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P, NamedSharding
from repro.ckpt import save_pytree
from repro.compat import make_mesh
mesh = make_mesh((4, 2), ("data", "model"))
w = jax.device_put(jnp.arange(64*32, dtype=jnp.float32).reshape(64, 32),
                   NamedSharding(mesh, P("data", "model")))
b = jax.device_put(jnp.ones((32,), jnp.float32), NamedSharding(mesh, P("model")))
save_pytree("%DIR%", {"w": w, "b": b}, step=3, extra={"mesh": "4x2"})
print("SAVED")
"""

_ELASTIC_RESTORE = r"""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P, NamedSharding
from repro.ckpt import restore_pytree_with_fallback
from repro.compat import make_mesh
assert len(jax.devices()) == 4
mesh = make_mesh((2, 2), ("data", "model"))
template = {"w": np.zeros((64, 32), np.float32), "b": np.zeros((32,), np.float32)}
shardings = {"w": NamedSharding(mesh, P("data", "model")),
             "b": NamedSharding(mesh, P("model"))}
tree, step, extra = restore_pytree_with_fallback("%DIR%", template,
                                                 shardings=shardings)
assert step == 3 and extra["mesh"] == "4x2"
np.testing.assert_array_equal(np.asarray(tree["w"]),
                              np.arange(64*32, dtype=np.float32).reshape(64, 32))
assert tree["w"].sharding.mesh.shape["data"] == 2  # re-sharded onto new mesh
print("RESTORED")
"""


def test_elastic_remesh_8_to_4(tmp_path):
    d = str(tmp_path / "ck")
    out = run_with_devices(_ELASTIC_SAVE.replace("%DIR%", d), n_devices=8)
    assert "SAVED" in out
    out = run_with_devices(_ELASTIC_RESTORE.replace("%DIR%", d), n_devices=4)
    assert "RESTORED" in out
