"""Kernel microbenchmarks: hindex operator variants on one bucket tile.

NOTE: the Pallas kernel runs in interpret mode on this container (Python
per-block execution) — its wall time here is NOT indicative of TPU time;
the jnp variants are the CPU-comparable rows. Validation (kernel == ref)
is in tests/test_kernels_hindex.py.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.hindex import hindex_count, hindex_sorted
from repro.kernels.hindex import hindex_op

ROWS = []


def emit(name, us, derived=""):
    line = f"{name},{us:.1f},{derived}"
    ROWS.append(line)
    print(line, flush=True)


def _bench(fn, *args, iters=5):
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else jax.block_until_ready(fn(*args))
    t0 = time.time()
    for _ in range(iters):
        jax.block_until_ready(fn(*args))
    return (time.time() - t0) / iters * 1e6


def run_all():
    rng = np.random.default_rng(0)
    for (n, w) in [(1024, 64), (4096, 128)]:
        x = jnp.asarray(rng.integers(-1, w, size=(n, w)).astype(np.int32))
        ext = jnp.zeros((n,), jnp.int32)
        cur = jnp.full((n,), w, jnp.int32)
        cand = min(w, 64)

        f_sorted = jax.jit(hindex_sorted)
        f_count = jax.jit(lambda a, b: hindex_count(a, b, cand_chunk=cand))
        emit(f"hindex/jnp-sorted/{n}x{w}", _bench(f_sorted, x, ext))
        emit(f"hindex/jnp-count/{n}x{w}", _bench(f_count, x, ext))
        t0 = time.time()
        hindex_op(x, ext, cur, cand=cand).block_until_ready()
        emit(f"hindex/pallas-interpret/{n}x{w}", (time.time() - t0) * 1e6,
             "interpret-mode;not-TPU-indicative")
    return ROWS
