"""Roofline table from dry-run artifacts (deliverable g).

Reads ``benchmarks/artifacts/dryrun/*.json`` (produced by
``python -m repro.launch.dryrun --all --both-meshes``) and emits one CSV row
per (arch x shape x mesh) cell with the three roofline terms, the dominant
bottleneck and the useful-flops fraction.
"""
from __future__ import annotations

import glob
import json
import os

ROWS = []
ARTIFACT_DIR = os.path.join(os.path.dirname(os.path.abspath(__file__)), "artifacts", "dryrun")


def emit(name, us, derived=""):
    line = f"{name},{us:.1f},{derived}"
    ROWS.append(line)
    print(line, flush=True)


def run_all(artifact_dir: str = ARTIFACT_DIR):
    files = sorted(glob.glob(os.path.join(artifact_dir, "*.json")))
    if not files:
        emit("dryrun/NO-ARTIFACTS", 0.0, "run python -m repro.launch.dryrun --all --both-meshes")
        return ROWS
    for f in files:
        r = json.load(open(f))
        rl = r["roofline"]
        dom = max(rl["compute_s"], rl["memory_s"], rl["collective_s"])
        useful = rl.get("useful_fraction")
        # Perf-variant artifacts carry a filename tag after the mesh.
        stem = os.path.basename(f)[: -len(".json")]
        variant = stem.split(r["mesh"], 1)[-1] or ""
        emit(
            f"roofline/{r['arch']}/{r['shape']}/{r['mesh']}{variant}",
            dom * 1e6,
            f"bottleneck={rl['bottleneck']};compute_s={rl['compute_s']:.3e};"
            f"memory_s={rl['memory_s']:.3e};collective_s={rl['collective_s']:.3e};"
            f"useful_frac={useful:.3f};fits16gb={r.get('fits_16gb')}",
        )
    # The paper-scale k-core dry-runs (launch/kcore_dryrun.py artifacts).
    kdir = os.path.join(os.path.dirname(artifact_dir.rstrip("/")), "kcore")
    for f in sorted(glob.glob(os.path.join(kdir, "*.json"))):
        r = json.load(open(f))
        rl = r.get("roofline")
        if rl is None:
            emit(f"kcore-roofline/{r['case']}/{r['mesh']}", 0.0,
                 f"INFEASIBLE:{r.get('skipped_compile','')}")
            continue
        dom = max(rl["compute_s"], rl["memory_s"], rl["collective_s"])
        emit(
            f"kcore-roofline/{r['case']}/{r['mesh']}",
            dom * 1e6,
            f"bottleneck={rl['bottleneck']};compute_s={rl['compute_s']:.3e};"
            f"memory_s={rl['memory_s']:.3e};collective_s={rl['collective_s']:.3e};"
            f"fits16gb={r.get('fits_16gb')}",
        )
    return ROWS
