"""DC-kCore benchmarks — one function per paper table/figure.

The paper's graphs (com-friendster 1.8B edges, WX-15B, WX-136B) do not fit
this container; each benchmark uses *shape-matched* synthetic graphs
(R-MAT power-law = payment-network analog, BA = social-network analog)
scaled to CPU budget. The metrics mirror the paper's:

  Table 3  end-to-end time: Spark-kCore analog (Jacobi, frozen reads) vs
           PSGraph analog (monolithic in-place) vs DC-kCore (rough divide)
  Fig 7    per-part decomposition time
  Fig 8    per-part communication amount (changed-estimate count)
  Fig 9    Rough- vs Exact-Divide extraction time
  Fig 10   total communication vs number of parts (2-4)
  Fig 11   preprocessing cost vs number of parts
  Fig 12*  frontier work: total gathered rows, active-frontier scheduling
           vs the always-full-sweep baseline (*not in the paper — the
           work-per-iteration metric this repo adds alongside the paper's
           communication amount)
  Fig 13*  locality-aware reordering: bucket-adjacency bitmap density and
           rows gathered under identity vs RCM vs BFS node orders (*repo
           addition — the static-frontier-filter payoff of
           repro.graph.reorder, tiled by the degree-profile autotuner)
  Fig 14*  out-of-core resource story on the host: streaming-ingest peak
           transient bytes vs the in-memory loader at several chunk sizes
           (bit-identical CSR required), and per-part checkpoint save
           overhead of the resumable pipeline (*repo addition)
  Fig 15*  divide-step transient: peak host bytes of the chunked
           induced-subgraph/external-info passes vs the dense
           np.repeat-over-all-rows baseline, at several chunk budgets on
           rmat14/rmat15 — the divide-side completion of fig14's ingest
           story (*repo addition; bit-identical part CSR required)
  Fig 16*  stage overlap: wall-clock per part and accelerator-idle
           fraction of the staged pipeline, ``overlap=True`` vs
           sequential, on rmat14/rmat15 with checkpointing on — the
           divide/prefetch + async-checkpoint payoff (*repo addition;
           byte-identical coreness required)
  Fig 17*  fused sweep kernel: fused vs unfused per-sweep wall time,
           modeled achieved-vs-roofline HBM bytes, and the int16
           estimate mode's bytes-moved reduction, on rmat14/rmat15
           (*repo addition; bit-identical coreness required; also
           written standalone to ``BENCH_fig17.json``)
  Fig 18*  part-parallel conquer: wall-clock, per-slice utilization,
           wave count and speculation counters of the wave scheduler,
           ``part_parallel=2`` vs sequential, on rmat14/rmat15 with
           Exact-Divide (*repo addition; byte-identical coreness and
           zero discards required)
  §5.2     correctness: every engine == BZ peeling oracle

Besides the ``name,us_per_call,derived`` CSV on stdout, every emit is kept
as a structured record (the ``k=v;k2=v2`` derived pairs parsed into
fields); :func:`write_artifact` dumps them to ``BENCH_kcore.json`` so the
perf trajectory — wall-clocks, rows gathered, collective bytes, idle
fraction — is tracked across PRs instead of evaporating with the CI log.
"""
from __future__ import annotations

import json
import time
from typing import Dict, List

import numpy as np

from repro.core.decompose import decompose
from repro.core.dckcore import dc_kcore
from repro.graph.build import bucketize
from repro.graph.generators import barabasi_albert, rmat
from repro.graph.oracle import peel_coreness
from repro.graph.reorder import bitmap_density, reorder_graph

ROWS: List[str] = []
RECORDS: List[dict] = []


def _parse_derived(derived: str) -> Dict[str, object]:
    """Best-effort ``k=v;k2=v2`` -> fields (numbers when they parse)."""
    fields: Dict[str, object] = {}
    for pair in derived.split(";"):
        k, sep, v = pair.partition("=")
        if not sep or not k:
            continue
        try:
            fields[k] = int(v)
        except ValueError:
            try:
                fields[k] = float(v)
            except ValueError:
                fields[k] = v
    return fields


def emit(name: str, us_per_call: float, derived: str, **fields):
    line = f"{name},{us_per_call:.1f},{derived}"
    ROWS.append(line)
    rec = {"name": name, "us_per_call": round(us_per_call, 1)}
    rec.update(_parse_derived(derived))
    rec.update(fields)
    RECORDS.append(rec)
    print(line, flush=True)


def write_artifact(path: str = "BENCH_kcore.json") -> str:
    """Persist every record emitted so far (call after run_all)."""
    with open(path, "w") as f:
        json.dump(
            {"bench": "kcore", "generated_unix": time.time(),
             "records": RECORDS},
            f, indent=1,
        )
    print(f"# wrote {len(RECORDS)} records to {path}", flush=True)
    return path


def _graphs():
    """(name, graph, divide_threshold): scaled analogs of the paper's three.

    Divide thresholds sit near the 80th coreness percentile of each graph —
    the regime the paper targets (a dense top part vs a large sparse rest).
    A pure BA graph is deliberately NOT used as the social analog: BA
    coreness is ~constant (= m), which makes any division degenerate."""
    return [
        ("cf-analog(rmat13d)", rmat(13, 24, a=0.5, b=0.2, c=0.2, seed=1), 40),
        ("wx15-analog(rmat14)", rmat(14, 12, seed=2), 16),
        ("wx136-analog(rmat15)", rmat(15, 16, seed=3), 24),
    ]


def correctness():
    """Paper §5.2: results of all engines are completely consistent."""
    for name, g, t in _graphs()[:2]:
        oracle = peel_coreness(g)
        mono = decompose(bucketize(g)).coreness
        div, _ = dc_kcore(g, thresholds=(t,), strategy="rough")
        ok = (mono == oracle).all() and (div == oracle).all()
        emit(f"correctness/{name}", 0.0, f"consistent={bool(ok)}")
        assert ok


def table3_end_to_end():
    for name, g, t in _graphs():
        t0 = time.time()
        res = decompose(bucketize(g), gauss_seidel=False)
        spark_s = time.time() - t0

        t0 = time.time()
        res_ps = decompose(bucketize(g))
        ps_s = time.time() - t0

        t0 = time.time()
        _, rep = dc_kcore(g, thresholds=(t,), strategy="rough")
        dc_s = time.time() - t0
        emit(f"table3/{name}/spark-analog", spark_s * 1e6, f"iters={res.iterations}")
        emit(f"table3/{name}/psgraph-analog", ps_s * 1e6, f"iters={res_ps.iterations}")
        emit(f"table3/{name}/dc-kcore", dc_s * 1e6,
             f"speedup_vs_ps={ps_s / dc_s:.2f}x;peak_bytes_ratio="
             f"{rep.peak_bytes / res_ps.peak_bytes:.2f}")


def fig7_part_times():
    name, g, t = _graphs()[1]
    _, rep = dc_kcore(g, thresholds=(t,), strategy="rough")
    for p in rep.parts:
        emit(f"fig7/{name}/part[{p.name}]", p.decompose_time_s * 1e6,
             f"iters={p.iterations};n={p.n_nodes};m={p.n_edges}")


def fig8_comm_amount():
    for name, g, t in _graphs()[:2]:
        mono = decompose(bucketize(g))
        _, rep = dc_kcore(g, thresholds=(t,), strategy="rough")
        emit(f"fig8/{name}/monolithic", 0.0,
             f"comm={mono.comm_amount};work={mono.gathered_rows}")
        for p in rep.parts:
            emit(f"fig8/{name}/part[{p.name}]", 0.0,
                 f"comm={p.comm_amount};work={p.gathered_rows}")
        emit(f"fig8/{name}/dc-total", 0.0,
             f"comm={rep.total_comm};reduction={1 - rep.total_comm / max(mono.comm_amount,1):.2%}")


def fig9_divide_strategies():
    from repro.core.divide import timed_candidates

    for name, g, t in _graphs():
        ext = np.zeros(g.n_nodes, dtype=np.int32)
        _, rough_s = timed_candidates(g, ext, t, "rough")
        _, exact_s = timed_candidates(g, ext, t, "exact")
        emit(f"fig9/{name}+{t}/rough", rough_s * 1e6, "")
        emit(f"fig9/{name}+{t}/exact", exact_s * 1e6,
             f"rough_speedup={exact_s / max(rough_s, 1e-9):.1f}x")


def fig12_frontier_work():
    """Work per iteration: active-frontier scheduling vs full sweeps.

    Total gathered bucket rows across all sweeps, same fixed point. The
    frontier must strictly reduce work on the power-law fixtures (the
    acceptance gate for the scheduler)."""
    for name, g, t in _graphs():
        bg = bucketize(g)
        front = decompose(bg)
        full = decompose(bg, frontier=False)
        assert (front.coreness == full.coreness).all()
        saved = 1 - front.gathered_rows / max(full.gathered_rows, 1)
        emit(f"fig12/{name}/full-sweeps", 0.0,
             f"gathered_rows={full.gathered_rows};iters={full.iterations}")
        emit(f"fig12/{name}/frontier", 0.0,
             f"gathered_rows={front.gathered_rows};iters={front.iterations};"
             f"saved={saved:.2%}")
        assert front.gathered_rows < full.gathered_rows, name
        # Divided: per-part work rides along in the reports.
        _, rep = dc_kcore(g, thresholds=(t,), strategy="rough")
        emit(f"fig12/{name}/dc-kcore", 0.0,
             f"gathered_rows={rep.total_gathered_rows};"
             f"full_sweep_rows={rep.total_full_sweep_rows}")


def fig13_reorder_density():
    """Locality-aware reordering: bitmap density + rows gathered, ordered
    vs unordered.

    For each power-law fixture, tile with the degree-profile autotuner under
    identity / RCM / BFS node orders and report the bucket-adjacency bitmap
    density (fraction of tile pairs the static frontier filter can NOT rule
    out) alongside the frontier work metric. Coreness must stay exactly the
    peeling oracle under every order (the reordering is a pure layout
    change), and both locality-aware orders must measurably reduce density
    versus identity — the acceptance gate for the reordering pass."""
    for name, g, t in _graphs():
        oracle = peel_coreness(g)
        density: Dict[str, float] = {}
        for method in ("identity", "rcm", "bfs"):
            rg = reorder_graph(g, method)
            bg = bucketize(rg)
            res = decompose(bg)
            assert (res.coreness == oracle).all(), (name, method)
            density[method] = bitmap_density(bg)
            emit(f"fig13/{name}/{method}", 0.0,
                 f"density={density[method]:.3f};tiles={len(bg.buckets)};"
                 f"gathered_rows={res.gathered_rows};iters={res.iterations}")
        assert density["rcm"] < density["identity"], name
        assert density["bfs"] < density["identity"], name
        # Divided pipeline under RCM: per-part densities ride in the report.
        core, rep = dc_kcore(g, thresholds=(t,), strategy="rough", reorder="rcm")
        np.testing.assert_array_equal(core, oracle)
        for p in rep.parts:
            emit(f"fig13/{name}/dc-rcm/part[{p.name}]", 0.0,
                 f"density={p.bitmap_density:.3f};gathered_rows={p.gathered_rows}")


def fig14_streaming_ingest_and_resume():
    """Host-side resource story: bounded-transient ingest + resumable parts.

    Streaming ingest must (a) reproduce the in-memory CSR bit-for-bit and
    (b) keep its tracked transient peak measurably below the in-memory
    loader's array working set, with the transient bounded by the chunk
    budget rather than the edge count (the acceptance gate for the
    out-of-core path). Checkpoint saves must stay a small fraction of the
    part decompose time — stability is supposed to be cheap."""
    from repro.graph.io import csr_from_edge_chunks, graph_edge_chunks
    import tempfile

    name, g, t = _graphs()[2]  # largest fixture (rmat15)
    baseline = None
    for chunk in (1 << 14, 1 << 16, 1 << 18):
        t0 = time.time()
        gs, st = csr_from_edge_chunks(
            graph_edge_chunks(g, chunk), n_nodes=g.n_nodes, chunk_edges=chunk
        )
        build_s = time.time() - t0
        assert np.array_equal(gs.indptr, g.indptr)
        assert np.array_equal(gs.indices, g.indices)
        baseline = st.baseline_transient_bytes
        emit(f"fig14/{name}/ingest-chunk={chunk}", build_s * 1e6,
             f"peak_transient={st.peak_transient_bytes};bins={st.n_bins};"
             f"saved_vs_baseline={1 - st.peak_transient_bytes / baseline:.2%}")
        assert st.peak_transient_bytes < baseline, chunk
    emit(f"fig14/{name}/ingest-baseline", 0.0, f"transient={baseline}")

    with tempfile.TemporaryDirectory() as d:
        _, rep = dc_kcore(g, thresholds=(t,), strategy="rough",
                          checkpoint_dir=d)
        decompose_s = sum(p.decompose_time_s for p in rep.parts)
        emit(f"fig14/{name}/part-checkpointing", rep.total_save_time_s * 1e6,
             f"parts={len(rep.parts)};"
             f"save_frac={rep.total_save_time_s / max(decompose_s, 1e-9):.2%}")


def fig15_divide_transient():
    """Divide-step resource story: chunked extraction vs the dense path.

    For the paper-shaped fixtures (rmat14, rmat15), run the full per-part
    extraction sequence — Rough-Divide candidates, induced part subgraph,
    external-info fold, remaining-graph shrink — at several chunk budgets
    and report the tracked peak transient host bytes against the dense
    baseline (the np.repeat source vector + edge mask + compacted pairs the
    pre-chunking implementation held). Gates: the part CSR must be
    bit-identical to the unchunked extraction at every budget, the peak
    must stay below the dense baseline and must scale with the chunk
    budget, not the edge count."""
    from repro.core.divide import rough_candidates
    from repro.graph.build import DivideStats, external_info, induced_subgraph

    for name, g, t in _graphs()[1:]:  # rmat14, rmat15
        ext = np.zeros(g.n_nodes, dtype=np.int32)
        mask = rough_candidates(g.degrees, ext, t)
        ref_part, ref_ids = induced_subgraph(g, mask)
        peaks = {}
        for chunk in (1 << 12, 1 << 14, 1 << 16):
            st = DivideStats(chunk_slots=chunk)
            t0 = time.time()
            part, ids = induced_subgraph(g, mask, chunk_slots=chunk, stats=st)
            external_info(g, mask, ~mask, chunk_slots=chunk, stats=st)
            induced_subgraph(g, ~mask, chunk_slots=chunk, stats=st)
            wall = time.time() - t0
            assert np.array_equal(part.indptr, ref_part.indptr)
            assert np.array_equal(part.indices, ref_part.indices)
            assert np.array_equal(ids, ref_ids)
            peaks[chunk] = st.peak_transient_bytes
            emit(f"fig15/{name}/divide-chunk={chunk}", wall * 1e6,
                 f"peak_transient={st.peak_transient_bytes};"
                 f"chunks={st.n_chunks};"
                 f"saved_vs_dense={1 - st.peak_transient_bytes / st.baseline_transient_bytes:.2%}")
            assert st.peak_transient_bytes < st.baseline_transient_bytes, chunk
        emit(f"fig15/{name}/divide-dense-baseline", 0.0,
             f"transient={st.baseline_transient_bytes}")
        # The peak tracks the chunk budget, not the edge count.
        assert peaks[1 << 12] < peaks[1 << 14] < peaks[1 << 16]


def fig16_overlap_pipeline():
    """Stage overlap: the staged pipeline's payoff, overlap vs sequential.

    Paper-shaped fixtures (rmat14, rmat15), Exact-Divide (host extraction
    is the expensive pass overlap exists to hide, and exact speculation
    always validates), multi-part plans, checkpointing on (so the async
    save path is exercised too). Gates: coreness byte-identical with the
    flag on and off, and on the largest fixture the accelerator-idle
    fraction must be measurably lower with ``overlap=True`` — the
    acceptance criterion for the pipelined part loop."""
    import tempfile

    for name, g, t in _graphs()[1:]:  # rmat14, rmat15
        thresholds = (max(2, t // 2), t)  # 3 parts: two divides + rest
        # Warm the jit caches (same graph + thresholds = same tile shapes)
        # so neither measured mode pays XLA compilation — it would swamp
        # both the wall-clock and the idle fraction of whichever runs first.
        dc_kcore(g, thresholds=thresholds, strategy="exact")
        results = {}
        for overlap in (False, True):
            with tempfile.TemporaryDirectory() as d:
                t0 = time.time()
                core, rep = dc_kcore(
                    g, thresholds=thresholds, strategy="exact",
                    checkpoint_dir=d, overlap=overlap,
                )
                wall = time.time() - t0
            results[overlap] = (core, rep, wall)
            mode = "overlap" if overlap else "sequential"
            emit(
                f"fig16/{name}/{mode}", wall * 1e6,
                f"idle_fraction={rep.idle_fraction:.4f};"
                f"wall_per_part={wall / max(len(rep.parts), 1):.4f};"
                f"parts={len(rep.parts)};"
                f"prefetch_hits={rep.prefetch_hits};"
                f"prefetch_misses={rep.prefetch_misses};"
                f"save_blocked_s={rep.total_save_time_s:.4f};"
                f"save_wall_s={rep.total_save_wall_s:.4f}",
                gathered_rows=rep.total_gathered_rows,
                collective_bytes=rep.total_collective_bytes,
            )
        core_seq, rep_seq, wall_seq = results[False]
        core_ov, rep_ov, wall_ov = results[True]
        assert np.array_equal(core_seq, core_ov), name
        assert rep_ov.prefetch_misses == 0, name  # exact always validates
        emit(
            f"fig16/{name}/overlap-vs-sequential", 0.0,
            f"idle_reduction={rep_seq.idle_fraction - rep_ov.idle_fraction:.4f};"
            f"wall_speedup={wall_seq / max(wall_ov, 1e-9):.3f}x",
        )
        if name.endswith("(rmat15)"):
            assert rep_ov.idle_fraction < rep_seq.idle_fraction, (
                name, rep_ov.idle_fraction, rep_seq.idle_fraction,
            )


def fig17_fused_sweep():
    """Fused sweep engine: fused-vs-unfused per-sweep wall time plus
    modeled achieved-vs-roofline HBM bytes, and the int16 estimate mode's
    measured bytes-moved reduction.

    Both engines run the same frontier schedule, so the comparison is
    per-sweep dispatch cost: the unfused baseline is ``op="count"`` (the
    same suffix-count math, separate gather / h-index / push dispatches)
    vs ``op="fused"`` (one kernel per row tile; interpret mode here, so
    wall times measure dispatch structure, not TPU bandwidth — the
    roofline fraction is the target-chip projection from the modeled
    bytes). Gates: coreness bit-identical across engines and modes, and
    int16 must report strictly fewer modeled bytes moved."""
    from repro.roofline import hw
    from repro.roofline.kcore_model import roofline_time_s

    for name, g, _t in _graphs()[1:]:  # rmat14, rmat15
        bg = bucketize(g)
        results = {}
        for engine in ("count", "fused"):
            decompose(bg, op=engine)  # warm the jit/kernel caches
            t0 = time.time()
            res = decompose(bg, op=engine)
            wall = time.time() - t0
            results[engine] = (res, wall)
            rt = roofline_time_s(res.sweep_bytes, res.sweep_flops)
            bound = ("memory" if res.sweep_bytes / hw.HBM_BW
                     >= res.sweep_flops / hw.PEAK_FLOPS_BF16 else "compute")
            emit(
                f"fig17/{name}/{engine}", wall / res.iterations * 1e6,
                f"iters={res.iterations};"
                f"sweep_bytes={res.sweep_bytes};"
                f"sweep_flops={res.sweep_flops};"
                f"roofline_s={rt:.3e};"
                f"roofline_bound={bound};"
                f"achieved_frac_interpret={res.sweep_bytes / wall / hw.HBM_BW:.3e};"
                f"fused_mode={res.fused_mode or 'n/a'};"
                f"est_dtype={res.est_dtype}",
                wall_s=wall,
            )
        res_c, wall_c = results["count"]
        res_f, wall_f = results["fused"]
        assert np.array_equal(res_c.coreness, res_f.coreness), name
        # int16 mode: same coreness, strictly fewer modeled bytes moved.
        decompose(bg, op="fused", int16=True)  # warm
        t0 = time.time()
        res16 = decompose(bg, op="fused", int16=True)
        wall16 = time.time() - t0
        assert np.array_equal(res16.coreness, res_f.coreness), name
        assert res16.est_dtype == "int16", name
        assert res16.sweep_bytes < res_f.sweep_bytes, name
        emit(
            f"fig17/{name}/fused-int16", wall16 / res16.iterations * 1e6,
            f"iters={res16.iterations};"
            f"sweep_bytes={res16.sweep_bytes};"
            f"bytes_reduction={1 - res16.sweep_bytes / res_f.sweep_bytes:.3f};"
            f"est_dtype={res16.est_dtype}",
            wall_s=wall16,
        )
        emit(
            f"fig17/{name}/fused-vs-unfused", 0.0,
            f"sweep_bytes_saved={res_c.sweep_bytes - res_f.sweep_bytes};"
            f"bytes_ratio={res_f.sweep_bytes / max(res_c.sweep_bytes, 1):.3f};"
            f"wall_ratio={wall_f / max(wall_c, 1e-9):.3f}",
        )


def fig18_part_parallel():
    """Part-parallel conquer: wall-clock and per-slice utilization of the
    wave scheduler, ``part_parallel=2`` (thread mode — slices share the
    single CPU device, so this measures scheduling overhead + host-side
    concurrency, not a 2x device speedup) vs sequential, on rmat14/rmat15
    with Exact-Divide (the wave chain never mispredicts). Gates: coreness
    byte-identical with the flag on and off, zero speculative discards,
    and every conquered part carries a placement stamp."""
    for name, g, t in _graphs()[1:]:  # rmat14, rmat15
        thresholds = (max(2, t // 2), t)  # 3 parts: two divides + rest
        dc_kcore(g, thresholds=thresholds, strategy="exact")  # warm jit
        t0 = time.time()
        core_seq, rep_seq = dc_kcore(g, thresholds=thresholds, strategy="exact")
        wall_seq = time.time() - t0
        t0 = time.time()
        core_par, rep = dc_kcore(g, thresholds=thresholds, strategy="exact",
                                 part_parallel=2)
        wall_par = time.time() - t0
        assert np.array_equal(core_par, core_seq), name
        assert rep.speculation_discards == 0, name  # exact always validates
        assert all(p.slice_index >= 0 and p.wave >= 0 for p in rep.parts), name
        util = ";".join(f"slice{i}={u:.3f}"
                        for i, u in enumerate(rep.slice_utilization))
        emit(
            f"fig18/{name}/sequential", wall_seq * 1e6,
            f"parts={len(rep_seq.parts)}",
        )
        emit(
            f"fig18/{name}/part-parallel-2", wall_par * 1e6,
            f"conquer_wall_s={rep.conquer_wall_s:.4f};"
            f"{util};"
            f"waves={max(p.wave for p in rep.parts) + 1};"
            f"prefetch_hits={rep.prefetch_hits};"
            f"speculation_discards={rep.speculation_discards};"
            f"boundary_exchange_bytes={rep.boundary_exchange_bytes};"
            f"wall_ratio_vs_seq={wall_par / max(wall_seq, 1e-9):.3f}",
            gathered_rows=rep.total_gathered_rows,
        )


def fig19_incremental_serve():
    """Incremental maintenance + serving: sustained updates/sec vs query
    p50/p99 latency through the real serve stack (editlog -> update worker
    -> apply_updates -> snapshot publish, queries racing the swaps), at
    two churn batch sizes. Gate: every batch drains and the final
    coreness matches the peeling oracle on the final graph."""
    import tempfile

    from repro.graph.delta import EdgeEdits, apply_edge_deltas
    from repro.graph.editlog import EditLog
    from repro.graph.oracle import peel_coreness
    from repro.launch import kcore_serve
    from repro.launch.kcore import load_graph

    spec, seed, n_batches = "rmat:12:8", 2, 24
    for batch_edges in (1, 8):
        rng = np.random.default_rng(seed)
        g0, _ = load_graph(spec, seed)
        n = g0.n_nodes
        stream = []
        with EditLog(tempfile.mkdtemp(prefix="fig19_")) as log:
            for _ in range(n_batches):
                iu = rng.integers(0, n, batch_edges)
                iv = rng.integers(0, n, batch_edges)
                log.append(iu, iv)
                stream.append((iu, iv))
                log.seal_batch()
            m = kcore_serve.main(
                ["--graph", spec, "--seed", str(seed), "--edit-log",
                 log.workdir, "--engine", "count", "--max-batches",
                 str(n_batches), "--query-batch", "64", "--json"]
            )
        assert m["batches_drained"] == n_batches
        # Gate: replay the stream through the delta layer alone and pin
        # the served end state against the peeling oracle.
        g = g0
        for iu, iv in stream:
            g = apply_edge_deltas(g, EdgeEdits.inserts(iu, iv)).graph
        assert m["final_k_max"] == int(peel_coreness(g).max(initial=0))
        modes = ";".join(f"mode_{k}={v}" for k, v in
                         sorted(m["update_modes"].items()))
        emit(
            f"fig19/{spec}/batch={batch_edges}",
            (1e6 / m["updates_per_s"]) if m["updates_per_s"] else 0.0,
            f"updates_per_s={m['updates_per_s']:.2f};"
            f"publishes_per_s={m['publishes_per_s']:.2f};"
            f"query_p50_ms={m['query_p50_ms']:.4f};"
            f"query_p99_ms={m['query_p99_ms']:.4f};"
            f"staleness_mean_edits={m['staleness_mean_edits']:.2f};"
            f"staleness_max_edits={m['staleness_max_edits']:.0f};"
            f"queries={m['n_queries']};{modes}",
        )


def write_fig17_artifact(path: str = "BENCH_fig17.json") -> str:
    """Persist just the fig17 records (uploaded by CI next to the full
    artifact so the fused-engine trajectory is a first-class file)."""
    recs = [r for r in RECORDS if r["name"].startswith("fig17/")]
    with open(path, "w") as f:
        json.dump(
            {"bench": "kcore-fig17-fused", "generated_unix": time.time(),
             "records": recs},
            f, indent=1,
        )
    print(f"# wrote {len(recs)} fig17 records to {path}", flush=True)
    return path


def fig10_fig11_parts():
    name, g, _ = _graphs()[1]
    deg = g.degrees
    qs = {2: [16], 3: [8, 32], 4: [8, 16, 48]}
    mono = decompose(bucketize(g))
    emit(f"fig10/{name}/psgraph-analog", 0.0, f"comm={mono.comm_amount}")
    for n_parts, thresholds in qs.items():
        _, rep = dc_kcore(g, thresholds=thresholds, strategy="rough")
        emit(f"fig10/{name}/parts={n_parts}", 0.0, f"comm={rep.total_comm}")
        emit(f"fig11/{name}/parts={n_parts}", rep.preprocess_time_s * 1e6,
             f"peak_bytes={rep.peak_bytes}")


def run_all():
    correctness()
    table3_end_to_end()
    fig7_part_times()
    fig8_comm_amount()
    fig9_divide_strategies()
    fig10_fig11_parts()
    fig12_frontier_work()
    fig13_reorder_density()
    fig14_streaming_ingest_and_resume()
    fig15_divide_transient()
    fig16_overlap_pipeline()
    fig17_fused_sweep()
    fig18_part_parallel()
    fig19_incremental_serve()
    write_artifact()
    write_fig17_artifact()
    return ROWS
