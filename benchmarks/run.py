"""Benchmark harness: ``PYTHONPATH=src python -m benchmarks.run``.

One section per paper table/figure (bench_kcore), kernel microbenches
(bench_kernels) and the dry-run roofline table (bench_dryrun).
Prints ``name,us_per_call,derived`` CSV; the kcore section also writes
its structured records to ``BENCH_kcore.json`` (uploaded as a CI
artifact from the scheduled slow job, so the perf trajectory persists
across PRs).
"""
from __future__ import annotations

import argparse


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", choices=["kcore", "kernels", "dryrun"], default=None)
    args, _ = ap.parse_known_args()

    print("name,us_per_call,derived")
    if args.only in (None, "kcore"):
        from benchmarks import bench_kcore

        bench_kcore.run_all()
    if args.only in (None, "kernels"):
        from benchmarks import bench_kernels

        bench_kernels.run_all()
    if args.only in (None, "dryrun"):
        from benchmarks import bench_dryrun

        bench_dryrun.run_all()


if __name__ == "__main__":
    main()
