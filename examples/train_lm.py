"""Train an LM end-to-end for a few hundred steps through the full stack:
data pipeline, AdamW + warmup-cosine, grad clipping, checkpointing.

Default: a reduced config sized for this 1-core CPU container. On real
hardware, ``--full --arch mamba2-130m`` trains the actual ~130M assigned
config through the identical code path.

    PYTHONPATH=src python examples/train_lm.py [--steps 200] [--full]
"""
import argparse
import dataclasses

import jax

from repro.configs import get_config, get_smoke_config
from repro.data import SyntheticTokens
from repro.models.model import build_specs
from repro.models.module import count_params, init_params
from repro.optim import get_optimizer
from repro.runtime import TrainLoop

ap = argparse.ArgumentParser()
ap.add_argument("--steps", type=int, default=200)
ap.add_argument("--arch", default="granite-3-2b")
ap.add_argument("--full", action="store_true",
                help="train the FULL assigned config (real hardware)")
args = ap.parse_args()

if args.full:
    cfg = get_config(args.arch)
else:
    cfg = get_smoke_config(args.arch)
    cfg = dataclasses.replace(cfg, d_model=128, n_layers=4, d_ff=512, vocab_size=2048)
specs = build_specs(cfg)
print(f"{cfg.name}-reduced: {count_params(specs)/1e6:.2f}M params")

loop = TrainLoop(
    cfg=cfg,
    params=init_params(specs, jax.random.PRNGKey(0)),
    optimizer=get_optimizer(cfg, lr=3e-3, warmup=20, total=args.steps),
    data=SyntheticTokens(vocab_size=cfg.vocab_size, seq_len=64, batch=8, seed=0),
)
hist = loop.run(args.steps, log_every=20)
for s, l, t in zip(hist["step"], hist["loss"], hist["tokens_per_s"]):
    print(f"step {s:5d}  loss {l:7.4f}  {t:8.0f} tok/s")
assert hist["loss"][-1] < hist["loss"][0], "loss did not decrease"
print("loss decreased — training path OK")
