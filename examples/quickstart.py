"""Quickstart: DC-kCore on a small power-law graph, verified vs peeling.

    PYTHONPATH=src python examples/quickstart.py
"""
from repro.core import dc_kcore
from repro.graph import rmat
from repro.graph.oracle import peel_coreness

g = rmat(scale=12, edge_factor=12, seed=0)
print(f"graph: {g.n_nodes:,} nodes, {g.n_edges:,} edges")

# Monolithic (the PSGraph baseline of the paper).
core_mono, rep_mono = dc_kcore(g, thresholds=())

# Divide-and-conquer: split at coreness 16 (Rough-Divide), conquer each part.
core_dc, rep_dc = dc_kcore(g, thresholds=(16,), strategy="rough")

oracle = peel_coreness(g)
assert (core_mono == oracle).all() and (core_dc == oracle).all()
print(f"k_max = {int(oracle.max())} — all three methods consistent")
print(f"monolithic: comm={rep_mono.total_comm:,} peak={rep_mono.peak_bytes/2**20:.1f} MiB")
print(f"dc-kcore:   comm={rep_dc.total_comm:,} peak={rep_dc.peak_bytes/2**20:.1f} MiB "
      f"({rep_dc.peak_bytes/rep_mono.peak_bytes:.0%} of monolithic)")
for p in rep_dc.parts:
    print(f"  part {p.name:>9}: n={p.n_nodes:,} iters={p.iterations} comm={p.comm_amount:,}")
