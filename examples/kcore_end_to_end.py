"""End-to-end driver (the paper's workload): resource-budgeted DC-kCore on a
multi-million-edge graph with checkpoint/restart.

Demonstrates the full production path:
  1. budget-driven threshold planning (the paper's "limited resources" knob),
  2. sequential conquer with per-sweep coreness snapshots,
  3. a simulated mid-run failure + restart from the snapshot,
  4. correctness check against the BZ peeling oracle.

    PYTHONPATH=src python examples/kcore_end_to_end.py
"""
import os
import tempfile
import time

import numpy as np

from repro.ckpt import latest_step, restore_pytree, save_pytree
from repro.core import dc_kcore
from repro.core.decompose import decompose
from repro.core.divide import plan_thresholds
from repro.graph import bucketize, rmat
from repro.graph.oracle import peel_coreness

g = rmat(scale=16, edge_factor=16, seed=7)  # ~65k nodes, ~1M edges (CPU scale)
print(f"graph: {g.n_nodes:,} nodes, {g.n_edges:,} edges, "
      f"{g.memory_bytes()/2**20:.0f} MiB CSR")

budget = g.memory_bytes() // 2  # force a division: half the monolithic bytes
thresholds = plan_thresholds(g, budget) or [24]
print(f"budget {budget/2**20:.0f} MiB/part -> thresholds {thresholds}")

ckpt_dir = os.path.join(tempfile.gettempdir(), "dckcore_ckpt")
os.makedirs(ckpt_dir, exist_ok=True)

fail_once = {"armed": True}


def decompose_with_snapshots(bg):
    """Conquer engine with per-sweep snapshots + one injected failure."""
    resume = None
    if latest_step(ckpt_dir) is not None:
        state, it, _ = restore_pytree(ckpt_dir, {"c": np.zeros(bg.n_nodes, np.int32)})
        if state["c"].shape == (bg.n_nodes,):
            resume = state["c"]
            print(f"    resumed part from snapshot at sweep {it}")

    def on_sweep(it, c):
        save_pytree(ckpt_dir, {"c": np.asarray(c)}, step=it)
        if fail_once["armed"] and it == 2 and bg.n_nodes > 1000:
            fail_once["armed"] = False
            raise RuntimeError("simulated worker failure at sweep 2")

    return decompose(bg, init_coreness=resume, on_sweep=on_sweep)


t0 = time.time()
try:
    core, report = dc_kcore(g, thresholds=thresholds, decompose_fn=decompose_with_snapshots)
except RuntimeError as e:
    print(f"  !! {e} — restarting from snapshot")
    core, report = dc_kcore(g, thresholds=thresholds, decompose_fn=decompose_with_snapshots)
print(f"\ndone in {time.time()-t0:.1f}s  k_max={int(core.max())} "
      f"comm={report.total_comm:,} peak={report.peak_bytes/2**20:.1f} MiB")

print("verifying against BZ peeling oracle...")
oracle = peel_coreness(g)
assert (core == oracle).all(), "MISMATCH"
print("CONSISTENT — coreness exact despite division, budget cap and restart")
