"""Batched serving: prefill a prompt batch, then greedy-decode new tokens
through the KV/SSM caches (ring buffers for sliding-window layers).

    PYTHONPATH=src python examples/serve_lm.py --arch gemma3-27b
"""
import argparse
import time

import jax

from repro.configs import get_smoke_config
from repro.models.model import build_specs
from repro.models.module import init_params
from repro.runtime import greedy_generate

ap = argparse.ArgumentParser()
ap.add_argument("--arch", default="gemma3-27b")
ap.add_argument("--batch", type=int, default=4)
ap.add_argument("--new-tokens", type=int, default=24)
args = ap.parse_args()

cfg = get_smoke_config(args.arch)
params = init_params(build_specs(cfg), jax.random.PRNGKey(0))
prompt = jax.random.randint(jax.random.PRNGKey(1), (args.batch, 48), 0, cfg.vocab_size)
t0 = time.time()
out = greedy_generate(params, prompt, cfg, args.new_tokens)
dt = time.time() - t0
print(f"{cfg.name}-reduced: {out.shape[0]}x{out.shape[1]} tokens in {dt:.2f}s "
      f"({out.size/dt:.0f} tok/s incl. compile)")
print(out)
