"""Paper §5.6: the communication-vs-preprocessing tradeoff of 2-4 parts.

    PYTHONPATH=src python examples/multipart_divide.py
"""
from repro.core import dc_kcore
from repro.graph import rmat
from repro.graph.oracle import peel_coreness

g = rmat(scale=14, edge_factor=12, seed=2)
oracle = peel_coreness(g)
print(f"graph: {g.n_nodes:,} nodes {g.n_edges:,} edges k_max={oracle.max()}")

_, mono = dc_kcore(g, thresholds=())
print(f"\n{'parts':>6} {'comm':>10} {'preprocess_s':>13} {'peak MiB':>9}")
print(f"{1:>6} {mono.total_comm:>10,} {mono.preprocess_time_s:>13.2f} "
      f"{mono.peak_bytes/2**20:>9.1f}")
for thresholds in [(16,), (8, 32), (8, 16, 48)]:
    core, rep = dc_kcore(g, thresholds=thresholds, strategy="rough")
    assert (core == oracle).all()
    print(f"{len(thresholds)+1:>6} {rep.total_comm:>10,} {rep.preprocess_time_s:>13.2f} "
          f"{rep.peak_bytes/2**20:>9.1f}")
print("\nmore parts -> less communication & smaller peak, more preprocessing "
      "(paper Figs 10-11)")
